//! Transfer smoke: leave-one-device-out cross-validation over the full
//! widened device registry (the four paper devices plus the four
//! synthetic cross-generation parts) in quick mode. Records wall time,
//! the device×device transfer-error matrix and every source fold's
//! fitted weight table to `BENCH_transfer.json`, and hard-fails if any
//! fold errors out or produces a degenerate prediction.

use uniperf::coordinator::{Config, FitBackend};
use uniperf::crossval::{run_crossval, CrossvalOpts, Split};
use uniperf::gpusim::registry;
use uniperf::util::bench::Bench;
use uniperf::util::json::Json;

fn main() {
    let mut b = Bench::end_to_end();
    // one timed iteration is 8 quick campaigns + 8 transfer folds
    b.samples = 2;

    let devices = registry::builtins().names();
    let n_devices = devices.len();
    assert!(n_devices >= 8, "widened registry should hold >= 8 devices");
    let opts = CrossvalOpts {
        base: Config {
            devices,
            backend: FitBackend::Native,
            ..Config::default()
        },
        split: Split::LeaveOneDeviceOut,
        quick: true,
    };
    // keep the last timed result for verification instead of paying for
    // an extra untimed run (the transfer split is deterministic, so any
    // iteration's result is *the* result)
    let mut last = None;
    b.run("transfer/lodo/quick/registry", || {
        last = Some(run_crossval(&opts).expect("transfer fold failed"));
    });
    let r = last.expect("bench ran at least once");
    println!("{}", r.render());
    assert_eq!(r.folds.len(), n_devices, "one fold per source device");
    let tm = r.transfer.as_ref().expect("device split yields a transfer matrix");
    assert_eq!(tm.devices.len(), n_devices);
    for f in &r.folds {
        assert!(!f.entries.is_empty(), "empty fold {}", f.fold);
        assert!(!f.weights.is_empty(), "fold {} lost its weight table", f.fold);
        for e in &f.entries {
            assert!(
                e.predicted_s.is_finite() && e.actual_s > 0.0,
                "degenerate prediction for {}->{}/{}/{}",
                f.fold,
                e.device,
                e.kernel,
                e.case
            );
        }
    }
    for (si, row) in tm.err.iter().enumerate() {
        for (ti, cell) in row.iter().enumerate() {
            if si == ti {
                assert!(cell.is_none(), "diagonal ({si},{ti}) must be held out");
            } else {
                let e = cell.expect("off-diagonal cell missing");
                assert!(e.is_finite(), "transfer error ({si},{ti}) not finite");
            }
        }
    }
    println!("overall transfer geomean relative error: {:.3}", tm.overall_err());

    b.finish("transfer");
    let mut j = b.to_json("transfer");
    if let Json::Obj(m) = &mut j {
        m.insert("crossval_device".into(), r.to_json());
    }
    std::fs::write("BENCH_transfer.json", j.pretty()).expect("write BENCH_transfer.json");
    println!("wrote BENCH_transfer.json");
}
