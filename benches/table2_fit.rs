//! E2 — regenerate Table 2 (paper §4.3): benchmark the weight fit itself
//! on the full measurement campaign, comparing the native Cholesky
//! backend against the AOT-compiled JAX/Pallas artifact, and print the
//! fitted weight table.

use uniperf::gpusim::SimGpu;
use uniperf::harness::{run_campaign, Protocol};
use uniperf::perfmodel::{fit, NativeSolver, Solver};
use uniperf::report::render_table2;
use uniperf::runtime::XlaSolver;
use uniperf::stats::{ExtractOpts, Schema};
use uniperf::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    let schema = Schema::full();
    let gpu = SimGpu::named("r9_fury").unwrap();
    let cases = uniperf::kernels::measurement_suite(&gpu.profile);
    let (pm, _) = run_campaign(
        &gpu,
        &cases,
        &schema,
        &Protocol::default(),
        ExtractOpts::default(),
        uniperf::util::executor::default_workers(),
    )
    .expect("campaign");
    println!(
        "campaign: {} cases x {} properties ({} active)\n",
        pm.n_cases(),
        pm.n_props(),
        pm.active_columns().len()
    );

    let native = NativeSolver::new();
    b.run("table2_fit/native-cholesky", || {
        fit("r9_fury", &pm, &schema, &native).expect("fit")
    });

    match XlaSolver::from_artifacts() {
        Ok(solver) => {
            b.run("table2_fit/xla-pallas-aot", || {
                fit("r9_fury", &pm, &schema, &solver).expect("fit")
            });
            // agreement between backends on the real campaign
            let mn = fit("r9_fury", &pm, &schema, &native).unwrap();
            let mx = fit("r9_fury", &pm, &schema, &solver).unwrap();
            let max_dev = mn
                .weights
                .iter()
                .zip(&mx.weights)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            println!("\nmax |native - xla| weight deviation: {max_dev:.3e}");
            println!("\n{}", render_table2(&mx, &schema));
        }
        Err(e) => println!("xla backend skipped: {e}"),
    }
    b.finish("table2_fit");
}
