//! Batched-vs-scalar prediction throughput bench: fit two devices,
//! warm the extraction cache over the full evaluation zoo, then push
//! the same request stream through the scalar `Engine::predict` loop
//! and the batched SoA path (`Engine::predict_batch`), best of 5 each.
//! Records both throughputs to `BENCH_predict.json` and hard-fails if
//! any request errors, if the two passes disagree on a single
//! prediction bit, or if the batched evaluator does not beat the
//! scalar loop.

use std::time::Instant;
use uniperf::coordinator::{fit_models, Config, FitBackend};
use uniperf::engine::Engine;
use uniperf::harness::Protocol;
use uniperf::service::{PredictRequest, Request};
use uniperf::util::json::Json;

fn main() {
    let cfg = Config {
        devices: vec!["k40c".into(), "titan_x".into()],
        backend: FitBackend::Native,
        protocol: Protocol { runs: 8, ..Protocol::default() },
        ..Config::default()
    };
    let t_fit = Instant::now();
    let store = fit_models(&cfg).expect("fit --save flow failed");
    let fit_s = t_fit.elapsed().as_secs_f64();
    println!(
        "fitted {} devices in {fit_s:.1}s (one-time artifact cost)",
        store.len()
    );
    // one resolution worker: the comparison isolates the evaluator, not
    // the parallel-resolve executor
    let engine = Engine::new(Config { workers: 1, ..cfg });
    engine.install_store(store).expect("artifact must validate");

    // request stream: all 9 zoo classes x 4 size cases x both devices
    let kernels = [
        "fd5", "mm_skinny", "conv7", "nbody", "reduce_tree", "scan_hs", "st3d7", "bmm8",
        "gather_s2",
    ];
    let mut reqs: Vec<PredictRequest> = Vec::new();
    for dev in ["k40c", "titan_x"] {
        for k in kernels {
            for case in ["a", "b", "c", "d"] {
                let line = format!(
                    r#"{{"device": "{dev}", "kernel": "{k}", "case": "{case}"}}"#
                );
                match Request::parse(&line).expect("request line") {
                    Request::Predict(p) => reqs.push(p),
                    other => panic!("expected a predict request, got {other:?}"),
                }
            }
        }
    }
    let n = reqs.len();

    // warm-up: every distinct kernel structure pays its one extraction
    // here, so both timed passes measure pure resolution + evaluation
    for r in &reqs {
        let p = engine.predict(r);
        assert!(p.is_ok(), "warm-up request errored: {p:?}");
    }
    let misses = engine.cache().misses();
    assert!(
        (misses as usize) <= kernels.len(),
        "structural sharing must dedupe cases and devices: {misses} misses for {} classes",
        kernels.len()
    );

    const REPS: usize = 5;

    // scalar: one tape walk (and one row allocation) per request
    let mut scalar_s = f64::INFINITY;
    let mut scalar: Vec<f64> = Vec::new();
    for _ in 0..REPS {
        let t0 = Instant::now();
        let out: Vec<_> = reqs.iter().map(|r| engine.predict(r)).collect();
        scalar_s = scalar_s.min(t0.elapsed().as_secs_f64());
        scalar = out
            .into_iter()
            .map(|p| p.expect("scalar request errored").predicted_s)
            .collect();
    }

    // batched: requests sharing a compiled tape program are grouped,
    // identical bindings collapse to one lane, and each instruction is
    // walked once across the whole lane block
    let mut batched_s = f64::INFINITY;
    let mut batched: Vec<f64> = Vec::new();
    for _ in 0..REPS {
        let batch = reqs.clone();
        let t0 = Instant::now();
        let out = engine.predict_batch(batch, 1);
        batched_s = batched_s.min(t0.elapsed().as_secs_f64());
        batched = out
            .into_iter()
            .map(|p| p.expect("batched request errored").predicted_s)
            .collect();
    }
    assert_eq!(
        engine.cache().misses(),
        misses,
        "timed passes must stay warm (no re-extraction)"
    );

    // the batched path is a pure throughput change: bit-identical
    assert_eq!(scalar.len(), batched.len());
    for (i, (a, b)) in scalar.iter().zip(&batched).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "request {i}: scalar {a} vs batched {b} predictions diverged"
        );
    }

    let scalar_rps = n as f64 / scalar_s;
    let batched_rps = n as f64 / batched_s;
    println!(
        "scalar:  {n} requests in {:.3} ms ({scalar_rps:.0} req/s)",
        scalar_s * 1e3
    );
    println!(
        "batched: {n} requests in {:.3} ms ({batched_rps:.0} req/s, {:.2}x scalar)",
        batched_s * 1e3,
        batched_rps / scalar_rps
    );
    assert!(
        batched_rps > scalar_rps,
        "batched SoA evaluation ({batched_rps:.0} req/s) must beat the scalar loop \
         ({scalar_rps:.0} req/s)"
    );

    let j = Json::obj(vec![
        ("suite", Json::Str("predict".into())),
        ("fit_s", Json::Num(fit_s)),
        ("requests_per_pass", Json::Num(n as f64)),
        ("reps", Json::Num(REPS as f64)),
        (
            "scalar",
            Json::obj(vec![
                ("seconds", Json::Num(scalar_s)),
                ("rps", Json::Num(scalar_rps)),
            ]),
        ),
        (
            "batched",
            Json::obj(vec![
                ("seconds", Json::Num(batched_s)),
                ("rps", Json::Num(batched_rps)),
            ]),
        ),
        ("batched_over_scalar", Json::Num(batched_rps / scalar_rps)),
        ("extractions", Json::Num(misses as f64)),
        ("identical_predictions", Json::Bool(true)),
    ]);
    std::fs::write("BENCH_predict.json", j.pretty()).expect("write BENCH_predict.json");
    println!("wrote BENCH_predict.json");
}
