//! Serve smoke + throughput bench: fit two devices, stand up the
//! prediction service, and push the full evaluation zoo through it
//! cold (extraction on every new kernel structure) and warm (pure
//! cache-hit tape evaluation). Records cold/warm throughput, the
//! latency percentiles and the cache counters to `BENCH_serve.json`,
//! and hard-fails if any request errors, if the warm path does not
//! beat the cold path, or if the warm pass ever misses the cache.

use std::time::Instant;
use uniperf::coordinator::{fit_models, Config, FitBackend};
use uniperf::gpusim::registry::builtins;
use uniperf::harness::Protocol;
use uniperf::report::render_service;
use uniperf::service::{Service, ServiceConfig};
use uniperf::util::json::Json;

fn main() {
    let cfg = Config {
        devices: vec!["k40c".into(), "titan_x".into()],
        backend: FitBackend::Native,
        protocol: Protocol { runs: 8, ..Protocol::default() },
        ..Config::default()
    };
    let t_fit = Instant::now();
    let store = fit_models(&cfg).expect("fit --save flow failed");
    let fit_s = t_fit.elapsed().as_secs_f64();
    println!(
        "fitted {} devices in {fit_s:.1}s (one-time artifact cost)",
        store.len()
    );
    let svc = Service::new(store, builtins().clone(), ServiceConfig::default())
        .expect("artifact must validate against the registry it was fitted on");

    // request stream: all 9 zoo classes x 4 size cases x both devices
    let kernels = [
        "fd5", "mm_skinny", "conv7", "nbody", "reduce_tree", "scan_hs", "st3d7", "bmm8",
        "gather_s2",
    ];
    let mut lines = Vec::new();
    for dev in ["k40c", "titan_x"] {
        for k in kernels {
            for case in ["a", "b", "c", "d"] {
                lines.push(format!(
                    r#"{{"device": "{dev}", "kernel": "{k}", "case": "{case}"}}"#
                ));
            }
        }
    }
    let n = lines.len();

    // cold pass: every distinct kernel structure pays one extraction
    let t0 = Instant::now();
    let cold_out = svc.run_batch(lines.clone());
    let cold_s = t0.elapsed().as_secs_f64();
    for r in &cold_out {
        assert!(r.get("error").is_none(), "cold-pass request errored: {r}");
    }
    let misses_after_cold = svc.cache().misses();
    assert!(misses_after_cold > 0, "cold pass must extract something");
    assert!(
        (misses_after_cold as usize) <= kernels.len(),
        "structural sharing must dedupe cases and devices: {misses_after_cold} misses \
         for {} classes",
        kernels.len()
    );

    // warm passes: best of 5, every request a cache hit
    let mut warm_s = f64::INFINITY;
    let mut warm_out = Vec::new();
    for _ in 0..5 {
        let t0 = Instant::now();
        warm_out = svc.run_batch(lines.clone());
        warm_s = warm_s.min(t0.elapsed().as_secs_f64());
        for r in &warm_out {
            assert!(r.get("error").is_none(), "warm-pass request errored: {r}");
            assert_eq!(
                r.get_str("cache"),
                Some("hit"),
                "warm request re-ran extraction: {r}"
            );
        }
    }
    assert_eq!(
        svc.cache().misses(),
        misses_after_cold,
        "warm passes must not add cache misses"
    );
    // deterministic predictions: warm answers equal cold answers
    for (c, w) in cold_out.iter().zip(&warm_out) {
        assert_eq!(c.get_f64("predicted_s"), w.get_f64("predicted_s"), "{c} vs {w}");
    }

    let cold_rps = n as f64 / cold_s;
    let warm_rps = n as f64 / warm_s;
    println!(
        "cold: {n} requests in {:.1} ms ({cold_rps:.0} req/s)",
        cold_s * 1e3
    );
    println!(
        "warm: {n} requests in {:.3} ms ({warm_rps:.0} req/s, {:.1}x cold)",
        warm_s * 1e3,
        warm_rps / cold_rps
    );
    assert!(
        warm_rps > cold_rps,
        "warm-cache throughput ({warm_rps:.0} req/s) must beat the cold path \
         ({cold_rps:.0} req/s)"
    );

    let summary = svc.summary();
    print!("{}", render_service(&summary));
    assert_eq!(summary.errors, 0, "no request may error");
    assert!(summary.cache_hits > 0, "cache-hit counter must register warm traffic");
    assert_eq!(
        summary.cache_hits + summary.cache_misses,
        summary.requests,
        "every request either hits or misses"
    );

    let j = Json::obj(vec![
        ("suite", Json::Str("serve".into())),
        ("fit_s", Json::Num(fit_s)),
        ("requests_per_pass", Json::Num(n as f64)),
        (
            "cold",
            Json::obj(vec![
                ("seconds", Json::Num(cold_s)),
                ("rps", Json::Num(cold_rps)),
            ]),
        ),
        (
            "warm",
            Json::obj(vec![
                ("seconds", Json::Num(warm_s)),
                ("rps", Json::Num(warm_rps)),
            ]),
        ),
        ("warm_over_cold", Json::Num(warm_rps / cold_rps)),
        ("service", summary.to_json()),
    ]);
    std::fs::write("BENCH_serve.json", j.pretty()).expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json");
}
