//! Serve smoke + throughput bench: fit two devices, stand up the
//! prediction service, and push the full evaluation zoo through it
//! cold (extraction on every new kernel structure), warm (pure
//! cache-hit tape evaluation), and over TCP — the threaded
//! per-connection listener against the serial conversational loop,
//! then the epoll reactor against the threaded listener under the
//! idle-heavy pipelining workload the reactor exists for (a horde of
//! idle keep-alive connections plus 32 active pipelining clients).
//! Records cold/warm/threaded/event-driven throughput, the latency
//! percentiles, the mean formed-batch width and the cache counters
//! (including evictions) to `BENCH_serve.json`, and hard-fails if any
//! request errors, if the warm path does not beat the cold path, if
//! the warm pass ever misses the cache, if the threaded listener does
//! not beat the serial loop, or (on Linux) if the reactor does not
//! beat the threaded listener or never forms a cross-connection batch.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Instant;
use uniperf::coordinator::{fit_models, Config, FitBackend};
use uniperf::gpusim::registry::builtins;
use uniperf::harness::Protocol;
use uniperf::report::{render_service, ServiceSummary};
use uniperf::service::{reactor, tcp, Service, ServiceConfig};
use uniperf::util::json::Json;

/// Conversational TCP client: send each line, wait for its response.
fn tcp_roundtrips(addr: std::net::SocketAddr, lines: &[String]) -> Vec<String> {
    let stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut stream = stream;
    let mut out = Vec::new();
    for line in lines {
        writeln!(stream, "{line}").expect("send");
        stream.flush().expect("flush");
        let mut resp = String::new();
        reader.read_line(&mut resp).expect("recv");
        out.push(resp.trim_end().to_string());
    }
    out
}

/// Pipelining client: send `depth` request lines at once, read the
/// `depth` responses back, repeat until the stream is drained. Returns
/// the per-round latencies in seconds; every response must be a clean
/// prediction.
fn pipelined_rounds(addr: std::net::SocketAddr, lines: &[String], depth: usize) -> Vec<f64> {
    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_nodelay(true).expect("nodelay");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut stream = stream;
    let mut rounds = Vec::new();
    for chunk in lines.chunks(depth) {
        let mut burst = String::new();
        for line in chunk {
            burst.push_str(line);
            burst.push('\n');
        }
        let t0 = Instant::now();
        stream.write_all(burst.as_bytes()).expect("send");
        stream.flush().expect("flush");
        for _ in chunk {
            let mut resp = String::new();
            reader.read_line(&mut resp).expect("recv");
            let j = Json::parse(resp.trim_end()).expect("response JSON");
            assert!(j.get("error").is_none(), "pipelined request errored: {resp}");
        }
        rounds.push(t0.elapsed().as_secs_f64());
    }
    rounds
}

/// Nearest-rank percentile over an ascending-sorted sample.
fn pct(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx]
}

fn main() {
    let cfg = Config {
        devices: vec!["k40c".into(), "titan_x".into()],
        backend: FitBackend::Native,
        protocol: Protocol { runs: 8, ..Protocol::default() },
        ..Config::default()
    };
    let t_fit = Instant::now();
    let store = fit_models(&cfg).expect("fit --save flow failed");
    let fit_s = t_fit.elapsed().as_secs_f64();
    println!(
        "fitted {} devices in {fit_s:.1}s (one-time artifact cost)",
        store.len()
    );
    // the event-driven section stands up fresh services over the same
    // fitted artifact so both transports start from identical state
    let event_store = store.clone();
    let svc = Service::new(store, builtins().clone(), ServiceConfig::default())
        .expect("artifact must validate against the registry it was fitted on");

    // request stream: all 9 zoo classes x 4 size cases x both devices
    let kernels = [
        "fd5", "mm_skinny", "conv7", "nbody", "reduce_tree", "scan_hs", "st3d7", "bmm8",
        "gather_s2",
    ];
    let mut lines = Vec::new();
    for dev in ["k40c", "titan_x"] {
        for k in kernels {
            for case in ["a", "b", "c", "d"] {
                lines.push(format!(
                    r#"{{"device": "{dev}", "kernel": "{k}", "case": "{case}"}}"#
                ));
            }
        }
    }
    let n = lines.len();

    // cold pass: every distinct kernel structure pays one extraction
    let t0 = Instant::now();
    let cold_out = svc.run_batch(lines.clone());
    let cold_s = t0.elapsed().as_secs_f64();
    for r in &cold_out {
        assert!(r.get("error").is_none(), "cold-pass request errored: {r}");
    }
    let misses_after_cold = svc.cache().misses();
    assert!(misses_after_cold > 0, "cold pass must extract something");
    assert!(
        (misses_after_cold as usize) <= kernels.len(),
        "structural sharing must dedupe cases and devices: {misses_after_cold} misses \
         for {} classes",
        kernels.len()
    );

    // warm passes: best of 5, every request a cache hit
    let mut warm_s = f64::INFINITY;
    let mut warm_out = Vec::new();
    for _ in 0..5 {
        let t0 = Instant::now();
        warm_out = svc.run_batch(lines.clone());
        warm_s = warm_s.min(t0.elapsed().as_secs_f64());
        for r in &warm_out {
            assert!(r.get("error").is_none(), "warm-pass request errored: {r}");
            assert_eq!(
                r.get_str("cache"),
                Some("hit"),
                "warm request re-ran extraction: {r}"
            );
        }
    }
    assert_eq!(
        svc.cache().misses(),
        misses_after_cold,
        "warm passes must not add cache misses"
    );
    // deterministic predictions: warm answers equal cold answers
    for (c, w) in cold_out.iter().zip(&warm_out) {
        assert_eq!(c.get_f64("predicted_s"), w.get_f64("predicted_s"), "{c} vs {w}");
    }

    let cold_rps = n as f64 / cold_s;
    let warm_rps = n as f64 / warm_s;
    println!(
        "cold: {n} requests in {:.1} ms ({cold_rps:.0} req/s)",
        cold_s * 1e3
    );
    println!(
        "warm: {n} requests in {:.3} ms ({warm_rps:.0} req/s, {:.1}x cold)",
        warm_s * 1e3,
        warm_rps / cold_rps
    );
    assert!(
        warm_rps > cold_rps,
        "warm-cache throughput ({warm_rps:.0} req/s) must beat the cold path \
         ({cold_rps:.0} req/s)"
    );

    // --- threaded TCP listener vs the serial conversational loop ---
    // Both paths answer the same warm request stream over real
    // sockets, one round trip per request. The serial baseline is one
    // client draining the whole stream alone (what the pre-refactor
    // single-connection loop could sustain at best); the threaded pass
    // runs N such clients concurrently on per-connection threads.
    let svc = Arc::new(svc);
    let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind");
    let addr = listener.local_addr().expect("listener addr");
    let server = {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || {
            tcp::serve_threaded(&svc, listener, 64).expect("threaded listener failed")
        })
    };

    let t0 = Instant::now();
    let serial_out = tcp_roundtrips(addr, &lines);
    let serial_s = t0.elapsed().as_secs_f64();
    for r in &serial_out {
        assert!(
            Json::parse(r).expect("response JSON").get("error").is_none(),
            "serial TCP request errored: {r}"
        );
    }
    let serial_rps = n as f64 / serial_s;

    let n_clients = 4;
    let t0 = Instant::now();
    let all: Vec<Vec<String>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n_clients)
            .map(|_| scope.spawn(|| tcp_roundtrips(addr, &lines)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    let threaded_s = t0.elapsed().as_secs_f64();
    for responses in &all {
        for r in responses {
            assert!(
                Json::parse(r).expect("response JSON").get("error").is_none(),
                "threaded TCP request errored: {r}"
            );
        }
    }
    let threaded_rps = (n_clients * n) as f64 / threaded_s;
    println!(
        "serial TCP: {n} round trips in {:.1} ms ({serial_rps:.0} req/s)",
        serial_s * 1e3
    );
    println!(
        "threaded TCP: {n_clients} x {n} round trips in {:.1} ms \
         ({threaded_rps:.0} req/s, {:.2}x serial)",
        threaded_s * 1e3,
        threaded_rps / serial_rps
    );
    assert!(
        threaded_rps > serial_rps,
        "threaded listener ({threaded_rps:.0} req/s) must beat the serial \
         conversational loop ({serial_rps:.0} req/s)"
    );

    // deterministic drain: shutdown, then the listener joins every
    // connection before returning
    let bye = tcp_roundtrips(addr, &[r#"{"cmd": "shutdown"}"#.to_string()]);
    assert_eq!(
        Json::parse(&bye[0]).expect("shutdown response").get_str("ok"),
        Some("shutdown")
    );
    server.join().expect("server thread");

    let summary = svc.summary();
    print!("{}", render_service(&summary));
    assert_eq!(summary.errors, 0, "no request may error");
    assert!(summary.cache_hits > 0, "cache-hit counter must register warm traffic");
    assert_eq!(
        summary.cache_hits + summary.cache_misses + 1,
        summary.requests,
        "every request either hits or misses, except the one shutdown command"
    );
    assert_eq!(
        summary.cache_evictions, 0,
        "the evaluation zoo must fit the default cache capacity"
    );

    // --- event-driven reactor vs threaded listener, idle-heavy load ---
    // The workload the reactor exists for: up to 1k idle keep-alive
    // connections (gracefully fewer under a tight fd budget — both
    // sides of every connection live in this process) plus 32 active
    // clients pipelining the zoo stream at depth 8. Identical fresh
    // services, identical streams; the reactor must win on throughput
    // with zero errors and real cross-connection batch formation.
    const ACTIVE_CLIENTS: usize = 32;
    const PIPELINE_DEPTH: usize = 8;
    let run_event = |use_reactor: bool| -> (f64, Vec<f64>, ServiceSummary, usize) {
        let svc = Arc::new(
            Service::new(event_store.clone(), builtins().clone(), ServiceConfig::default())
                .expect("event-driven service"),
        );
        let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind");
        let addr = listener.local_addr().expect("listener addr");
        let server = {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || {
                if use_reactor {
                    let rcfg =
                        reactor::ReactorConfig { max_conns: 2048, ..Default::default() };
                    reactor::serve_reactor(&svc, listener, rcfg).expect("reactor listener")
                } else {
                    tcp::serve_threaded(&svc, listener, 2048).expect("threaded listener")
                }
            })
        };
        let mut idle = Vec::new();
        for _ in 0..1000 {
            match TcpStream::connect(addr) {
                Ok(s) => idle.push(s),
                Err(_) => break,
            }
        }
        if idle.len() < 1000 {
            // fd ceiling hit: give back headroom for the active
            // clients, then let the server reap and any accept
            // backoff expire
            for _ in 0..96 {
                drop(idle.pop());
            }
            std::thread::sleep(std::time::Duration::from_millis(250));
        }
        let n_idle = idle.len();
        let t0 = Instant::now();
        let rounds: Vec<Vec<f64>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..ACTIVE_CLIENTS)
                .map(|_| scope.spawn(|| pipelined_rounds(addr, &lines, PIPELINE_DEPTH)))
                .collect();
            handles.into_iter().map(|h| h.join().expect("client thread")).collect()
        });
        let wall_s = t0.elapsed().as_secs_f64();
        let mut lat: Vec<f64> = rounds.into_iter().flatten().collect();
        lat.sort_by(f64::total_cmp);
        let bye = tcp_roundtrips(addr, &[r#"{"cmd": "shutdown"}"#.to_string()]);
        assert_eq!(
            Json::parse(&bye[0]).expect("shutdown response").get_str("ok"),
            Some("shutdown")
        );
        let summary = server.join().expect("server drains with the idle horde attached");
        drop(idle);
        (wall_s, lat, summary, n_idle)
    };
    let event = if reactor::supported() {
        let (thr_s, thr_lat, thr_sum, thr_idle) = run_event(false);
        let (rct_s, rct_lat, rct_sum, rct_idle) = run_event(true);
        let total = (ACTIVE_CLIENTS * n) as f64;
        let (thr_rps, rct_rps) = (total / thr_s, total / rct_s);
        for (name, sum) in [("threaded", &thr_sum), ("reactor", &rct_sum)] {
            assert_eq!(sum.errors, 0, "{name} event-driven pass had request errors");
            assert_eq!(sum.shed, 0, "{name} event-driven pass shed load");
        }
        println!(
            "event-driven threaded: {total:.0} piped requests + {thr_idle} idle conns \
             in {:.1} ms ({thr_rps:.0} req/s)",
            thr_s * 1e3
        );
        println!(
            "event-driven reactor:  {total:.0} piped requests + {rct_idle} idle conns \
             in {:.1} ms ({rct_rps:.0} req/s, {:.2}x threaded, mean batch width {:.1})",
            rct_s * 1e3,
            rct_rps / thr_rps,
            rct_sum.batch_mean
        );
        assert!(
            rct_sum.batch_mean > 1.0,
            "cross-connection batch formation never engaged: mean formed-batch width {}",
            rct_sum.batch_mean
        );
        assert!(
            rct_rps > thr_rps,
            "the reactor ({rct_rps:.0} req/s) must beat the threaded listener \
             ({thr_rps:.0} req/s) under idle-heavy pipelining load"
        );
        Some(Json::obj(vec![
            ("active_clients", Json::Num(ACTIVE_CLIENTS as f64)),
            ("pipeline_depth", Json::Num(PIPELINE_DEPTH as f64)),
            ("requests", Json::Num(total)),
            (
                "threaded",
                Json::obj(vec![
                    ("idle_connections", Json::Num(thr_idle as f64)),
                    ("seconds", Json::Num(thr_s)),
                    ("rps", Json::Num(thr_rps)),
                    ("round_p50_ms", Json::Num(pct(&thr_lat, 50.0) * 1e3)),
                    ("round_p99_ms", Json::Num(pct(&thr_lat, 99.0) * 1e3)),
                ]),
            ),
            (
                "reactor",
                Json::obj(vec![
                    ("idle_connections", Json::Num(rct_idle as f64)),
                    ("seconds", Json::Num(rct_s)),
                    ("rps", Json::Num(rct_rps)),
                    ("round_p50_ms", Json::Num(pct(&rct_lat, 50.0) * 1e3)),
                    ("round_p99_ms", Json::Num(pct(&rct_lat, 99.0) * 1e3)),
                    ("batch_width_mean", Json::Num(rct_sum.batch_mean)),
                    ("batch_width_p50", Json::Num(rct_sum.batch_p50)),
                    ("batch_width_p99", Json::Num(rct_sum.batch_p99)),
                ]),
            ),
            ("reactor_over_threaded", Json::Num(rct_rps / thr_rps)),
        ]))
    } else {
        println!("event-driven section skipped: epoll reactor unsupported on this target");
        None
    };

    let j = Json::obj(vec![
        ("suite", Json::Str("serve".into())),
        ("fit_s", Json::Num(fit_s)),
        ("requests_per_pass", Json::Num(n as f64)),
        (
            "cold",
            Json::obj(vec![
                ("seconds", Json::Num(cold_s)),
                ("rps", Json::Num(cold_rps)),
            ]),
        ),
        (
            "warm",
            Json::obj(vec![
                ("seconds", Json::Num(warm_s)),
                ("rps", Json::Num(warm_rps)),
            ]),
        ),
        ("warm_over_cold", Json::Num(warm_rps / cold_rps)),
        (
            "tcp_serial",
            Json::obj(vec![
                ("seconds", Json::Num(serial_s)),
                ("rps", Json::Num(serial_rps)),
            ]),
        ),
        (
            "tcp_threaded",
            Json::obj(vec![
                ("clients", Json::Num(n_clients as f64)),
                ("seconds", Json::Num(threaded_s)),
                ("rps", Json::Num(threaded_rps)),
            ]),
        ),
        ("threaded_over_serial", Json::Num(threaded_rps / serial_rps)),
        (
            "event_driven",
            match event {
                Some(section) => section,
                None => Json::Null,
            },
        ),
        ("service", summary.to_json()),
    ]);
    std::fs::write("BENCH_serve.json", j.pretty()).expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json");
}
