//! Simulated-GPU substrate throughput: the analytic timing engine per
//! kernel class (the substrate must be fast enough that a full Table-1
//! campaign — 4 devices × ~390 cases × 30 runs — completes in seconds),
//! and the numeric interpreter on small validation sizes.

use uniperf::gpusim::{base_time, execute, SimGpu};
use uniperf::kernels::{measure, testks};
use uniperf::qpoly::env;
use uniperf::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    let gpu = SimGpu::named("titan_x").unwrap();

    let timing_cases = vec![
        ("mm_tiled", measure::mm_tiled(16, 16), env(&[("n", 1024), ("m", 1024), ("l", 1024)])),
        ("vsadd_s2", measure::vsadd(2, 256), env(&[("nt", 1 << 22)])),
        ("fd5", testks::fd_stencil(16, 16), env(&[("n", 2048)])),
        ("conv7", testks::convolution(16, 16), env(&[("n", 512)])),
        ("nbody", testks::nbody(256), env(&[("n", 4096)])),
    ];
    for (name, kernel, e) in &timing_cases {
        b.run(&format!("sim/timing-engine/{name}"), || {
            base_time(&gpu.profile, kernel, e).expect("base_time")
        });
    }

    // full 30-run protocol including noise generation
    let (_, kernel, e) = &timing_cases[0];
    b.run("sim/30-run-protocol/mm_tiled", || gpu.time(kernel, e, 30).expect("time"));

    // numeric interpreter (validation path), small sizes
    let interp_cases = vec![
        ("mm_tiled/n=32", measure::mm_tiled(8, 8), env(&[("n", 32), ("m", 32), ("l", 32)])),
        ("fd5/n=32", testks::fd_stencil(8, 8), env(&[("n", 32)])),
        ("nbody/n=128", testks::nbody(32), env(&[("n", 128)])),
    ];
    for (name, kernel, e) in &interp_cases {
        b.run(&format!("sim/interpreter/{name}"), || execute(kernel, e).expect("execute"));
    }
    b.finish("simulator");
}
