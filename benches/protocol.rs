//! E4 — validate the paper's §4.2 timing-protocol claims against the
//! simulated devices:
//!
//! * "the minimum differed from the average by less than 5% when
//!   execution times significantly exceeded the launch overhead";
//! * empty-kernel launch overhead grows with the number of work groups
//!   (the two-property overhead model of §2.4);
//! * the first run is slower (first-touch) and the second run noisier.

use uniperf::gpusim::{all_devices, SimGpu};
use uniperf::harness::{calibrate_overhead, Protocol};
use uniperf::kernels::measure;
use uniperf::qpoly::env;
use uniperf::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    let protocol = Protocol::default();

    println!("-- min-vs-mean agreement (times >> overhead) --");
    for d in all_devices() {
        let gpu = SimGpu::new(d.clone());
        let k = measure::global_access(measure::GlobalAccessConfig::Copy, 256);
        let e = env(&[("n", 1 << 24)]);
        let times = gpu.time(&k, &e, protocol.runs).unwrap();
        let mn = protocol.reduce(&times).unwrap();
        let mean = protocol.reduce_mean(&times).unwrap();
        let dev = (mean - mn) / mn;
        println!(
            "{:<10} min {:>10.4} ms   mean {:>10.4} ms   delta {:>5.2}%  {}",
            d.name,
            mn * 1e3,
            mean * 1e3,
            100.0 * dev,
            if dev < 0.05 { "(<5% HOLDS)" } else { "(DEVIATES)" }
        );
    }

    println!("\n-- empty-kernel overhead vs group count (should grow) --");
    for d in all_devices() {
        let gpu = SimGpu::new(d.clone());
        let k = measure::empty(16, 16);
        let mut prev = 0.0;
        let mut monotone = true;
        let mut line = format!("{:<10}", d.name);
        for p in [8i64, 10, 12] {
            let e = env(&[("n", 1 << p)]);
            let t = protocol.reduce(&gpu.time(&k, &e, protocol.runs).unwrap()).unwrap();
            line += &format!("  2^{p}: {:>8.2} µs", t * 1e6);
            monotone &= t > prev;
            prev = t;
        }
        println!("{line}  {}", if monotone { "(grows HOLDS)" } else { "(DEVIATES)" });
    }

    println!("\n-- first-touch + second-run artifacts --");
    let gpu = SimGpu::named("titan_x").unwrap();
    let k = measure::global_access(measure::GlobalAccessConfig::Copy, 256);
    let times = gpu.time(&k, &env(&[("n", 1 << 22)]), 30).unwrap();
    let floor = times[4..].iter().cloned().fold(f64::INFINITY, f64::min);
    println!(
        "run0/min = {:.2} (first touch), |run1-min|/min = {:.2}%",
        times[0] / floor,
        100.0 * (times[1] - floor).abs() / floor
    );

    // and the calibration itself, benchmarked
    for d in all_devices() {
        let gpu = SimGpu::new(d);
        b.run(&format!("protocol/calibrate-overhead/{}", gpu.profile.name), || {
            calibrate_overhead(&gpu, &protocol).expect("calibrate")
        });
    }
    b.finish("protocol");
}
