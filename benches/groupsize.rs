//! E5 — the paper's §5 group-size claim: "Run-time generally varied by
//! less than 30% due to thread group size changes ... unless the work
//! group size affects the kernel properties in some way".
//!
//! We therefore split the sweep:
//! * property-stable kernels (vsadd, sg_copy, arith — the extracted
//!   counts are identical across the three group shapes): spread < 30%;
//! * property-changing kernels (tiled MM / transpose — the tile size is
//!   the group size, so loads/barriers per output change): reported but
//!   exempt, with the *model tracking the change* (its prediction ratio
//!   follows the simulated ratio).

use uniperf::gpusim::SimGpu;
use uniperf::harness::Protocol;
use uniperf::kernels::measure;
use uniperf::qpoly::env;
use uniperf::stats::{extract, ExtractOpts, Schema};
use uniperf::util::bench::Bench;

fn main() {
    let mut b = Bench::end_to_end();
    let gpu = SimGpu::named("k40c").unwrap();
    let protocol = Protocol::default();
    let schema = Schema::full();

    println!("-- property-stable kernels: spread must be < 30% --");
    let mut all_hold = true;
    // vsadd and sg_copy over the OneDLarge set; arith over TwoD shapes
    for (label, cases) in [
        (
            "vsadd/s=1/n=2^22",
            [256i64, 384, 512]
                .iter()
                .map(|&l| (measure::vsadd(1, l), env(&[("nt", 1i64 << 22)]), format!("g={l}")))
                .collect::<Vec<_>>(),
        ),
        (
            "sg_copy/n=2^24",
            [256i64, 384, 512]
                .iter()
                .map(|&l| {
                    (
                        measure::global_access(measure::GlobalAccessConfig::Copy, l),
                        env(&[("n", 1i64 << 24)]),
                        format!("g={l}"),
                    )
                })
                .collect(),
        ),
        (
            "arith_mul/n=528/k=512",
            [(16i64, 12i64), (16, 16), (32, 16)]
                .iter()
                .map(|&(gx, gy)| {
                    (
                        measure::arith(measure::ArithType::Mul, gx, gy),
                        env(&[("n", 528), ("k", 512)]),
                        format!("g={gx}x{gy}"),
                    )
                })
                .collect(),
        ),
    ] {
        let times: Vec<f64> = cases
            .iter()
            .map(|(k, e, _)| protocol.reduce(&gpu.time(k, e, protocol.runs).unwrap()).unwrap())
            .collect();
        let (lo, hi) = (
            times.iter().cloned().fold(f64::INFINITY, f64::min),
            times.iter().cloned().fold(0.0f64, f64::max),
        );
        let spread = (hi - lo) / lo;
        let holds = spread < 0.30;
        all_hold &= holds;
        println!(
            "{label:<24} times {:?} ms  spread {:>5.1}% {}",
            times.iter().map(|t| (t * 1e5).round() / 100.0).collect::<Vec<_>>(),
            100.0 * spread,
            if holds { "(<30% HOLDS)" } else { "(DEVIATES)" }
        );
    }

    println!("\n-- property-changing kernels (exempt): model must track the change --");
    // tiled MM: tile size = group size, so properties change. Check that
    // the *ratio* predicted by raw property counts follows the simulator.
    let shapes = [(16i64, 12i64), (16, 16), (32, 16)];
    let mut sim_times = Vec::new();
    let mut load_counts = Vec::new();
    for (gx, gy) in shapes {
        let k = measure::mm_tiled(gx, gy);
        let e = env(&[("n", 528), ("m", 544), ("l", 528)]);
        sim_times.push(protocol.reduce(&gpu.time(&k, &e, protocol.runs).unwrap()).unwrap());
        let props = extract(&k, &e, ExtractOpts::default()).unwrap();
        let v = props.eval(&schema, &e).unwrap();
        // total global loads as the traffic proxy
        let loads: f64 = schema
            .props()
            .iter()
            .enumerate()
            .filter(|(_, p)| {
                matches!(p, uniperf::stats::Prop::MemGlobal { dir: uniperf::stats::Dir::Load, .. })
            })
            .map(|(i, _)| v[i])
            .sum();
        load_counts.push(loads);
    }
    let sim_ratio = sim_times[2] / sim_times[0];
    let count_ratio = load_counts[2] / load_counts[0];
    println!(
        "mm_tiled 32x16 vs 16x12: sim ratio {:.2}, load-count ratio {:.2} (same direction: {})",
        sim_ratio,
        count_ratio,
        (sim_ratio < 1.0) == (count_ratio < 1.0)
    );

    // timing throughput of the sweep itself
    for lsize in [256i64, 384, 512] {
        let k = measure::vsadd(1, lsize);
        let e = env(&[("nt", 1i64 << 22)]);
        b.run(&format!("groupsize/vsadd-sim/g={lsize}"), || {
            gpu.time(&k, &e, protocol.runs).unwrap()
        });
    }
    println!(
        "\ngroup-size claim (property-stable kernels): {}",
        if all_hold { "HOLDS" } else { "DEVIATES" }
    );
    b.finish("groupsize");
}
