//! Campaign-plane smoke: the compile-once measurement plane end to
//! end. Three sections, all hard-failing on contract violations:
//!
//! 1. **Cold vs warm per-case timing** on one device's full
//!    measurement suite: a populated `MeasCacheFile` must replay every
//!    raw stream bit-identically, with zero simulator draws, and must
//!    be strictly faster than cold measurement.
//! 2. **Flat vs nested scheduling** over four devices: the flat
//!    shared-pool fan-out (full worker budget at every level) against
//!    an emulation of the old static `device_workers × inner_workers`
//!    split. The results must be byte-identical; the flat schedule
//!    must not be materially slower.
//! 3. **Warm crossval replay**: a quick two-device transfer split run
//!    cold then warm through the same cache file — the warm run must
//!    perform zero simulations, finish faster, and reproduce the cold
//!    run's JSON record byte for byte.
//!
//! Records everything to `BENCH_campaign.json` (consumed by CI's perf
//! trajectory artifacts).

use std::sync::Arc;
use std::time::Instant;

use uniperf::coordinator::{Config, FitBackend};
use uniperf::crossval::{quick_campaign_case, run_crossval, CrossvalOpts, Split};
use uniperf::gpusim::{self, SimGpu, TimingCache};
use uniperf::harness::{measure_cases, MeasCacheFile, Protocol};
use uniperf::kernels::{self, KernelCase};
use uniperf::stats::{ExtractOpts, Schema};
use uniperf::util::bench::Bench;
use uniperf::util::executor::{default_workers, par_map};
use uniperf::util::json::Json;

fn tmp(name: &str) -> std::path::PathBuf {
    let p = std::env::temp_dir()
        .join(format!("uniperf_bench_campaign_{name}_{}", std::process::id()));
    let _ = std::fs::remove_file(&p);
    p
}

fn main() {
    let schema = Schema::full();
    let protocol = Protocol::default();
    let extract = ExtractOpts::default();
    let workers = default_workers();
    let mut b = Bench::end_to_end();
    // one timed iteration is a full campaign; two samples keep the
    // bench CI-sized
    b.samples = 2;

    // --- 1. cold vs warm per-case timing ----------------------------
    let profile = gpusim::device("k40c").expect("k40c profile");
    let cases = kernels::measurement_suite(&profile);
    let n_cases = cases.len();

    let cold_gpu = SimGpu::new(profile.clone());
    let mut cold_result = None;
    let cold_s = b.run("campaign/k40c/cold", || {
        cold_result = Some(
            measure_cases(&cold_gpu, &cases, &schema, &protocol, extract, workers)
                .expect("cold campaign"),
        );
    });

    let cache_path = tmp("k40c");
    let cache = Arc::new(
        MeasCacheFile::open(&cache_path, &protocol, gpusim::DEFAULT_SEED)
            .expect("open meas cache"),
    );
    let warm_gpu = SimGpu::new(profile)
        .with_meas_cache(Some(cache.clone() as Arc<dyn TimingCache>));
    // one populating pass (cold, write-through), then every timed
    // iteration replays from the cache
    let populate = measure_cases(&warm_gpu, &cases, &schema, &protocol, extract, workers)
        .expect("populating campaign");
    assert!(
        !cache.is_empty() && cache.len() <= n_cases,
        "populating pass must fill the cache (got {} entries for {n_cases} cases)",
        cache.len()
    );
    let draws_before_warm = gpusim::sim_draws();
    let mut warm_result = None;
    let warm_s = b.run("campaign/k40c/warm", || {
        warm_result = Some(
            measure_cases(&warm_gpu, &cases, &schema, &protocol, extract, workers)
                .expect("warm campaign"),
        );
    });
    assert_eq!(
        gpusim::sim_draws(),
        draws_before_warm,
        "warm iterations must not touch the simulator"
    );
    let cold_ms = cold_result.expect("cold ran");
    let warm_ms = warm_result.expect("warm ran");
    assert_eq!(cold_ms.len(), warm_ms.len());
    for ((c, w), p) in cold_ms.iter().zip(&warm_ms).zip(&populate) {
        assert_eq!(c.label, w.label, "case order must be preserved");
        assert_eq!(
            c.time_s.to_bits(),
            w.time_s.to_bits(),
            "bit divergence in replayed time for {}",
            c.label
        );
        assert_eq!(p.time_s.to_bits(), w.time_s.to_bits(), "{}", c.label);
        let cb: Vec<u64> = c.props.iter().map(|x| x.to_bits()).collect();
        let wb: Vec<u64> = w.props.iter().map(|x| x.to_bits()).collect();
        assert_eq!(cb, wb, "bit divergence in properties for {}", c.label);
    }
    assert!(
        warm_s.median_ns < cold_s.median_ns,
        "warm replay must beat cold measurement (warm {:.0} ns vs cold {:.0} ns)",
        warm_s.median_ns,
        cold_s.median_ns
    );
    let cold_cps = n_cases as f64 * 1e9 / cold_s.median_ns;
    let warm_cps = n_cases as f64 * 1e9 / warm_s.median_ns;
    println!(
        "cold {cold_cps:.1} cases/s, warm {warm_cps:.1} cases/s ({:.1}x)",
        cold_s.median_ns / warm_s.median_ns
    );

    // --- 2. flat vs nested scheduling over four devices -------------
    let suites: Vec<(SimGpu, Vec<KernelCase>)> = ["k40c", "r9_fury", "p100", "c2070"]
        .iter()
        .map(|d| {
            let p = gpusim::device(d).expect("builtin device");
            let mut cs = kernels::measurement_suite(&p);
            cs.retain(|c| quick_campaign_case(&c.label));
            (SimGpu::new(p), cs)
        })
        .collect();
    let run_sched = |outer: usize, inner: usize| -> Vec<Vec<u64>> {
        par_map((0..suites.len()).collect(), outer, |i| {
            let (gpu, cs) = &suites[i];
            measure_cases(gpu, cs, &schema, &protocol, extract, inner)
                .expect("scheduled campaign")
                .iter()
                .map(|m| m.time_s.to_bits())
                .collect()
        })
    };
    // the old static split: devices get the outer budget, each campaign
    // only its integer share of what is left
    let device_workers = workers.min(suites.len()).max(1);
    let inner_workers = (workers / device_workers).max(1);
    let mut nested_times = None;
    let nested_s = b.run("campaign/4dev/nested-static-split", || {
        nested_times = Some(run_sched(device_workers, inner_workers));
    });
    let mut flat_times = None;
    let flat_s = b.run("campaign/4dev/flat-shared-pool", || {
        flat_times = Some(run_sched(workers, workers));
    });
    assert_eq!(
        nested_times, flat_times,
        "scheduling must never change measurement bytes"
    );
    let flat_ratio = nested_s.median_ns / flat_s.median_ns;
    println!("flat shared-pool speedup over nested static split: {flat_ratio:.2}x");
    assert!(
        flat_s.median_ns <= nested_s.median_ns * 1.25,
        "flat scheduling materially slower than the nested split \
         (flat {:.0} ns vs nested {:.0} ns)",
        flat_s.median_ns,
        nested_s.median_ns
    );

    // --- 3. warm crossval replay -------------------------------------
    let cv_cache = tmp("crossval");
    let opts = CrossvalOpts {
        base: Config {
            devices: vec!["k40c".into(), "r9_fury".into()],
            backend: FitBackend::Native,
            meas_cache: Some(cv_cache.clone()),
            ..Config::default()
        },
        split: Split::LeaveOneDeviceOut,
        quick: true,
    };
    let t0 = Instant::now();
    let cold_cv = run_crossval(&opts).expect("cold crossval");
    let cold_cv_s = t0.elapsed().as_secs_f64();
    let draws_before_cv = gpusim::sim_draws();
    let t1 = Instant::now();
    let warm_cv = run_crossval(&opts).expect("warm crossval");
    let warm_cv_s = t1.elapsed().as_secs_f64();
    assert_eq!(
        gpusim::sim_draws(),
        draws_before_cv,
        "warm crossval must replay with zero simulation"
    );
    assert_eq!(
        cold_cv.to_json().pretty(),
        warm_cv.to_json().pretty(),
        "warm crossval replay diverged from the cold run"
    );
    assert!(cold_cv.overall_err().is_finite(), "fold error not finite");
    for f in &cold_cv.folds {
        assert!(!f.entries.is_empty(), "empty fold {}", f.fold);
        for e in &f.entries {
            assert!(
                e.predicted_s.is_finite() && e.actual_s > 0.0,
                "degenerate fold entry {}/{}/{}",
                e.device,
                e.kernel,
                e.case
            );
        }
    }
    assert!(
        warm_cv_s < cold_cv_s,
        "warm crossval ({warm_cv_s:.3}s) must beat cold ({cold_cv_s:.3}s)"
    );
    println!("crossval device-split: cold {cold_cv_s:.3}s, warm {warm_cv_s:.3}s");

    b.finish("campaign");
    let mut j = b.to_json("campaign");
    if let Json::Obj(m) = &mut j {
        m.insert("cases".into(), Json::Num(n_cases as f64));
        m.insert("cold_cases_per_s".into(), Json::Num(cold_cps));
        m.insert("warm_cases_per_s".into(), Json::Num(warm_cps));
        m.insert(
            "warm_speedup".into(),
            Json::Num(cold_s.median_ns / warm_s.median_ns),
        );
        m.insert("flat_vs_nested_speedup".into(), Json::Num(flat_ratio));
        m.insert("crossval_cold_s".into(), Json::Num(cold_cv_s));
        m.insert("crossval_warm_s".into(), Json::Num(warm_cv_s));
        m.insert("meascache_entries".into(), Json::Num(cache.len() as f64));
    }
    std::fs::write("BENCH_campaign.json", j.pretty()).expect("write BENCH_campaign.json");
    println!("wrote BENCH_campaign.json");
    let _ = std::fs::remove_file(&cache_path);
    let _ = std::fs::remove_file(&cv_cache);
}
