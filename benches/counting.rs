//! The paper's "economical alternative" + "rapid evaluation" claims:
//! benchmark (a) symbolic property extraction per kernel class, (b)
//! re-evaluation of the symbolic counts at a new size (claimed cheap),
//! and (c) the model-evaluation inner product (claimed "rapid, runtime").

use uniperf::kernels::{measure, testks};
use uniperf::qpoly::env;
use uniperf::stats::{extract, ExtractOpts, Schema};
use uniperf::util::bench::Bench;
use uniperf::util::linalg::dot;

fn main() {
    let mut b = Bench::new();
    let schema = Schema::full();

    let kernels = vec![
        ("mm_tiled", measure::mm_tiled(16, 16), env(&[("n", 512), ("m", 512), ("l", 512)])),
        ("mm_naive", measure::mm_naive(16, 16), env(&[("n", 512)])),
        ("transpose_tiled", measure::transpose(measure::TransposeVariant::Tiled, 16, 16), env(&[("n", 2048)])),
        ("fd5", testks::fd_stencil(16, 16), env(&[("n", 2048)])),
        ("conv7", testks::convolution(16, 16), env(&[("n", 256)])),
        ("nbody", testks::nbody(256), env(&[("n", 2048)])),
    ];

    // (a) full symbolic extraction (classification + counting + schedule)
    for (name, kernel, e) in &kernels {
        b.run(&format!("counting/extract/{name}"), || {
            extract(kernel, e, ExtractOpts::default()).expect("extract")
        });
    }

    // (b) symbolic re-evaluation at a new size (the "fully parametric" claim)
    for (name, kernel, e) in &kernels {
        let props = extract(kernel, e, ExtractOpts::default()).unwrap();
        let mut e2 = e.clone();
        for v in e2.values_mut() {
            *v *= 2;
        }
        b.run(&format!("counting/reeval/{name}"), || {
            props.eval(&schema, &e2).expect("eval")
        });
    }

    // (c) model evaluation: one inner product over the property vector
    let (_, kernel, e) = &kernels[0];
    let props = extract(kernel, e, ExtractOpts::default()).unwrap();
    let v = props.eval(&schema, e).unwrap();
    let w: Vec<f64> = (0..schema.len()).map(|i| 1e-12 * (i + 1) as f64).collect();
    b.run("counting/predict-inner-product", || dot(&w, &v));

    b.finish_json("counting");
}
