//! E1 — regenerate Table 1 (paper §5): per-device end-to-end pipeline
//! (measurement campaign → fit → test-kernel prediction), reporting both
//! the wall time of the pipeline and the resulting error rows.

use uniperf::coordinator::{run_device, Config, FitBackend};
use uniperf::report::{Table1, Table1Entry};
use uniperf::stats::Schema;
use uniperf::util::bench::Bench;

fn main() {
    let mut b = Bench::end_to_end();
    let schema = Schema::full();
    let cfg = Config { backend: FitBackend::Native, ..Config::default() };

    let mut table = Table1::default();
    for device in ["titan_x", "c2070", "k40c", "r9_fury"] {
        let mut last = None;
        b.run(&format!("table1/pipeline/{device}"), || {
            let dr = run_device(device, &schema, &cfg).expect("pipeline");
            last = Some(dr);
        });
        let dr = last.unwrap();
        for (kernel, case, pred, act) in &dr.tests {
            table.push(Table1Entry {
                device: device.into(),
                kernel: kernel.clone(),
                case: case.clone(),
                predicted_s: *pred,
                actual_s: *act,
            });
        }
    }
    println!("\n{}", table.render());
    println!(
        "table1 overall geomean relative error: {:.3} (paper: 0.11)",
        table.overall_err()
    );
    b.finish("table1");
}
