//! Ablations over the model's design choices (DESIGN.md §5, A1–A3):
//!
//! * A1 — drop the `min(loads, stores)` roofline properties (§2.1's
//!   "efficiency gains if both loads and stores are present");
//! * A2 — collapse the utilization-ratio classes onto pure stride bins;
//! * A3 — shrink the measurement set (drop whole kernel classes) and
//!   watch test-kernel error degrade.

use uniperf::gpusim::SimGpu;
use uniperf::harness::{run_campaign, Protocol, PropsCache};
use uniperf::kernels;
use uniperf::perfmodel::{fit, Model, NativeSolver, PropertyMatrix};
use uniperf::stats::{ExtractOpts, Prop, Schema};
use uniperf::util::bench::Bench;
use uniperf::util::linalg::geometric_mean;

/// Test-kernel geomean error of a model on one device.
fn test_err(
    gpu: &SimGpu,
    model: &Model,
    schema: &Schema,
    extract_opts: ExtractOpts,
) -> f64 {
    let protocol = Protocol::default();
    let mut cache = PropsCache::default();
    let mut errs = Vec::new();
    for case in kernels::test_suite(&gpu.profile) {
        let props = cache.props_for(&case, extract_opts).unwrap();
        let pred = model.predict_kernel(schema, &props, &case.env).unwrap();
        let actual =
            protocol.reduce(&gpu.time(&case.kernel, &case.env, protocol.runs).unwrap()).unwrap();
        errs.push((pred - actual).abs() / actual);
    }
    geometric_mean(&errs)
}

fn zero_columns(pm: &PropertyMatrix, schema: &Schema, pred: impl Fn(&Prop) -> bool) -> PropertyMatrix {
    let mut out = pm.clone();
    let cols: Vec<usize> = schema
        .props()
        .iter()
        .enumerate()
        .filter(|(_, p)| pred(p))
        .map(|(i, _)| i)
        .collect();
    for c in &mut out.cases {
        for &j in &cols {
            c.props[j] = 0.0;
        }
    }
    out
}

fn main() {
    let mut b = Bench::end_to_end();
    let device = "titan_x";
    let gpu = SimGpu::named(device).unwrap();
    let schema = Schema::full();
    let protocol = Protocol::default();
    let solver = NativeSolver::new();
    let workers = uniperf::util::executor::default_workers();

    let cases = kernels::measurement_suite(&gpu.profile);
    let (pm, _) =
        run_campaign(&gpu, &cases, &schema, &protocol, ExtractOpts::default(), workers).unwrap();

    // baseline
    let base_model = fit(device, &pm, &schema, &solver).unwrap();
    let base = test_err(&gpu, &base_model, &schema, ExtractOpts::default());
    println!("baseline                         test geomean {base:.3}");

    // A1: no min(loads, stores) roofline columns
    let pm_a1 = zero_columns(&pm, &schema, |p| matches!(p, Prop::MemMin { .. }));
    let m_a1 = fit(device, &pm_a1, &schema, &solver).unwrap();
    // (prediction also without those columns: zero weights make it moot)
    let a1 = test_err(&gpu, &m_a1, &schema, ExtractOpts::default());
    println!("A1 drop min(loads,stores)        test geomean {a1:.3}  (delta {:+.3})", a1 - base);

    // A2: collapse utilization-ratio classes at extraction time
    let opts2 = ExtractOpts { collapse_utilization: true, ..Default::default() };
    let (pm_a2, _) = run_campaign(&gpu, &cases, &schema, &protocol, opts2, workers).unwrap();
    let m_a2 = fit(device, &pm_a2, &schema, &solver).unwrap();
    let a2 = test_err(&gpu, &m_a2, &schema, opts2);
    println!("A2 collapse utilization classes  test geomean {a2:.3}  (delta {:+.3})", a2 - base);

    // A3: shrink the measurement set by dropping kernel classes
    for drop_prefixes in [
        vec!["arith_"],
        vec!["filled_"],
        vec!["arith_", "filled_", "transpose", "mm_naive"],
    ] {
        let mut pm_small = PropertyMatrix::default();
        for c in &pm.cases {
            if !drop_prefixes.iter().any(|p| c.label.starts_with(p)) {
                pm_small.push(c.label.clone(), c.props.clone(), c.time_s);
            }
        }
        match fit(device, &pm_small, &schema, &solver) {
            Ok(m) => {
                let e = test_err(&gpu, &m, &schema, ExtractOpts::default());
                println!(
                    "A3 drop {:<24} test geomean {e:.3}  ({} cases, delta {:+.3})",
                    format!("{drop_prefixes:?}"),
                    pm_small.n_cases(),
                    e - base
                );
            }
            Err(err) => println!("A3 drop {drop_prefixes:?}: fit failed ({err})"),
        }
    }

    // E7 (§6.2 extension): bin local loads by bank-conflict stride
    let opts7 = ExtractOpts { bin_local_strides: true, ..Default::default() };
    let (pm_e7, _) = run_campaign(&gpu, &cases, &schema, &protocol, opts7, workers).unwrap();
    let m_e7 = fit(device, &pm_e7, &schema, &solver).unwrap();
    let e7 = test_err(&gpu, &m_e7, &schema, opts7);
    println!(
        "E7 bin local bank-conflict strides  test geomean {e7:.3}  (delta {:+.3}, train {:.3} vs {:.3})",
        e7 - base,
        m_e7.train_rel_err_geomean,
        base_model.train_rel_err_geomean
    );

    // wall-clock of the full ablation-relevant fit
    b.run("ablation/fit-full-campaign", || fit(device, &pm, &schema, &solver).unwrap());
    b.finish("ablation");
}
