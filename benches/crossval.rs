//! Crossval smoke: run the held-out cross-validation subsystem in quick
//! mode, record its wall time plus every fold's fitted weight table
//! (the CI perf-trajectory artifact `BENCH_crossval.json`, which
//! thereby doubles as the weight-drift record across PRs), and
//! hard-fail if any fold errors out or produces a degenerate
//! prediction.

use uniperf::coordinator::{Config, FitBackend};
use uniperf::crossval::{run_crossval, CrossvalOpts, Split};
use uniperf::util::bench::Bench;
use uniperf::util::json::Json;

fn main() {
    let mut b = Bench::end_to_end();
    // each timed iteration is a full (quick) campaign + 18 folds; a few
    // samples suffice for the trajectory without dragging CI out
    b.samples = 3;

    // timed: quick leave-one-kernel-out on two devices
    let timed = CrossvalOpts {
        base: Config {
            devices: vec!["titan_x".into(), "r9_fury".into()],
            backend: FitBackend::Native,
            ..Config::default()
        },
        split: Split::LeaveOneKernelOut,
        quick: true,
    };
    b.run("crossval/loko/quick/2dev", || {
        run_crossval(&timed).expect("crossval fold failed")
    });

    // verification run: all four devices, both splits, quick mode — any
    // fold error panics, which fails the CI job
    let mut opts = CrossvalOpts {
        base: Config { backend: FitBackend::Native, ..Config::default() },
        split: Split::LeaveOneKernelOut,
        quick: true,
    };
    let loko = run_crossval(&opts).expect("crossval fold failed");
    println!("{}", loko.render());
    assert_eq!(loko.folds.len(), 9 * 4, "one fold per (kernel, device)");
    for f in &loko.folds {
        assert!(!f.entries.is_empty(), "empty fold {}/{}", f.device, f.fold);
        for e in &f.entries {
            assert!(
                e.predicted_s.is_finite() && e.actual_s > 0.0,
                "degenerate prediction for {}/{}/{}",
                e.device,
                e.kernel,
                e.case
            );
        }
    }

    opts.split = Split::LeaveOneSizeCaseOut;
    let loso = run_crossval(&opts).expect("crossval fold failed");
    println!("{}", loso.render());
    assert_eq!(loso.folds.len(), 2 * 4, "quick mode keeps size cases a/b");

    println!(
        "held-out geomean relative error: kernel-split {:.3}, case-split {:.3}",
        loko.overall_err(),
        loso.overall_err()
    );
    // the kernel-split must see a non-zero uniform-store weight on at
    // least one device now that sg_storeuni closed the §4.1 gap
    let uniform_store_fitted = loko.folds.iter().any(|f| {
        f.weights
            .iter()
            .any(|(label, w)| label.contains("stride-0 stores") && *w != 0.0)
    });
    assert!(uniform_store_fitted, "no fold fitted the uniform-store column");

    // persist timings + the per-fold fitted weight tables (and held-out
    // errors) so weight drift is trackable across PRs from the artifact
    b.finish("crossval");
    let mut j = b.to_json("crossval");
    if let Json::Obj(m) = &mut j {
        m.insert("crossval_kernel".into(), loko.to_json());
        m.insert("crossval_case".into(), loso.to_json());
    }
    std::fs::write("BENCH_crossval.json", j.pretty()).expect("write BENCH_crossval.json");
    println!("wrote BENCH_crossval.json");
}
