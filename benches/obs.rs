//! Observability overhead bench: the ISSUE-9 contract is that span
//! recording costs at most 3% of warm-cache serving throughput, and
//! nothing at all when disabled. This bench pushes the full evaluation
//! zoo (9 kernel classes x 4 size cases x 2 devices, one 72-wide warm
//! batch per pass) through an in-process service twice — first with the
//! recorder off, then with it on (`span::enable` is one-way within a
//! process, so the disabled passes must run first) — takes the best of
//! many passes to shave scheduler noise, checks the response bytes are
//! identical across the toggle, and hard-fails if instrumented
//! throughput drops below 97% of uninstrumented. Records both rates,
//! the overhead percentage and the recorder fill levels to
//! `BENCH_obs.json`.

use std::time::Instant;
use uniperf::gpusim::registry::builtins;
use uniperf::obs::span;
use uniperf::perfmodel::Model;
use uniperf::service::{ModelStore, Service, ServiceConfig, StoredModel};
use uniperf::stats::{ExtractOpts, Schema};
use uniperf::util::json::Json;

/// Registry-valid two-device store with hand-set weights: no fit
/// needed, deterministic predictions, and the warm path it exercises
/// (parse -> cache hit -> batched tape eval -> render) is identical to
/// a fitted model's.
fn toy_store() -> ModelStore {
    let schema = Schema::full();
    let mut store = ModelStore::new(&schema, ExtractOpts::default());
    for (device, group_w, const_w) in [("k40c", 2e-9, 5e-6), ("titan_x", 1e-9, 3e-6)] {
        let mut weights = vec![0.0; schema.len()];
        weights[schema.len() - 2] = group_w;
        weights[schema.len() - 1] = const_w;
        let model = Model {
            device: device.into(),
            weights,
            active: vec![schema.len() - 2, schema.len() - 1],
            train_rel_err_geomean: 0.1,
            solver: "native-cholesky",
        };
        store.insert(StoredModel::new(model, 8e-6, 400, builtins().get(device).unwrap()));
    }
    store
}

/// Best-of-`passes` wall time for one warm batch over `lines`, plus the
/// (deterministic) responses of the final pass for byte comparison.
fn measure(svc: &Service, lines: &[String], passes: usize) -> (f64, Vec<String>) {
    let mut best = f64::INFINITY;
    let mut out = Vec::new();
    for _ in 0..passes {
        let t0 = Instant::now();
        let responses = svc.run_batch(lines.to_vec());
        best = best.min(t0.elapsed().as_secs_f64());
        out = responses.iter().map(Json::compact).collect();
    }
    (best, out)
}

fn main() {
    let svc = Service::new(
        toy_store(),
        builtins().clone(),
        // one worker: single-threaded passes give the stablest clock for
        // a 3% comparison, and keep the engine spans on the serving thread
        ServiceConfig { workers: 1, ..ServiceConfig::default() },
    )
    .expect("toy store must validate against the registry");

    let kernels = [
        "fd5", "mm_skinny", "conv7", "nbody", "reduce_tree", "scan_hs", "st3d7", "bmm8",
        "gather_s2",
    ];
    let mut lines = Vec::new();
    for dev in ["k40c", "titan_x"] {
        for k in kernels {
            for case in ["a", "b", "c", "d"] {
                lines.push(format!(
                    r#"{{"device": "{dev}", "kernel": "{k}", "case": "{case}"}}"#
                ));
            }
        }
    }
    let n = lines.len();

    // cold pass pays every extraction once; everything after is warm
    let t0 = Instant::now();
    for r in svc.run_batch(lines.clone()) {
        assert!(r.get("error").is_none(), "cold-pass request errored: {r}");
    }
    let cold_s = t0.elapsed().as_secs_f64();
    let misses = svc.cache().misses();
    println!("cold: {n} requests in {:.1} ms ({misses} extractions)", cold_s * 1e3);

    const WARMUP: usize = 30;
    const PASSES: usize = 40;
    assert!(!span::enabled(), "recorder must start disabled");
    measure(&svc, &lines, WARMUP);
    let (off_s, off_out) = measure(&svc, &lines, PASSES);
    assert_eq!(
        svc.cache().misses(),
        misses,
        "warm passes must not add cache misses"
    );

    // one-way switch: everything after this line is instrumented, with
    // the production slow-root threshold in force
    span::enable(500.0);
    measure(&svc, &lines, WARMUP / 3);
    let (on_s, on_out) = measure(&svc, &lines, PASSES);
    assert_eq!(
        off_out, on_out,
        "span recording must not change a single response byte"
    );

    let off_rps = n as f64 / off_s;
    let on_rps = n as f64 / on_s;
    let overhead_pct = (off_rps / on_rps - 1.0) * 100.0;
    let spans_held = span::recent().len();
    println!(
        "uninstrumented: {n} warm requests in {:.3} ms ({off_rps:.0} req/s)",
        off_s * 1e3
    );
    println!(
        "instrumented:   {n} warm requests in {:.3} ms ({on_rps:.0} req/s, \
         {overhead_pct:+.2}% overhead, {spans_held} spans held)",
        on_s * 1e3
    );
    assert!(
        spans_held > 0,
        "the instrumented passes must actually have recorded spans"
    );
    assert!(
        on_rps >= 0.97 * off_rps,
        "span recording costs {overhead_pct:.2}% of warm throughput \
         ({on_rps:.0} vs {off_rps:.0} req/s); the observability contract caps it at 3%"
    );

    let j = Json::obj(vec![
        ("suite", Json::Str("obs".into())),
        ("requests_per_pass", Json::Num(n as f64)),
        ("passes", Json::Num(PASSES as f64)),
        ("cold_seconds", Json::Num(cold_s)),
        (
            "uninstrumented",
            Json::obj(vec![
                ("seconds", Json::Num(off_s)),
                ("rps", Json::Num(off_rps)),
            ]),
        ),
        (
            "instrumented",
            Json::obj(vec![
                ("seconds", Json::Num(on_s)),
                ("rps", Json::Num(on_rps)),
            ]),
        ),
        ("overhead_pct", Json::Num(overhead_pct)),
        ("spans_held", Json::Num(spans_held as f64)),
        ("slow_spans_held", Json::Num(span::slow().len() as f64)),
        ("bytes_identical", Json::Bool(true)),
    ]);
    std::fs::write("BENCH_obs.json", j.pretty()).expect("write BENCH_obs.json");
    println!("wrote BENCH_obs.json");
}
