//! Chaos smoke bench: the threaded prediction server under a
//! deterministic fault plan (aborted + delayed connections, degraded
//! predictions), driven by resilient reconnecting clients. Records
//! throughput and the injection/robustness counters to
//! `BENCH_chaos.json`, and hard-fails on any panic, any malformed
//! response line, any unserved request, or accounting drift —
//! "degrades loudly, never silently" as an executable check.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Instant;
use uniperf::coordinator::{fit_models, Config, FitBackend};
use uniperf::engine::Engine;
use uniperf::gpusim::registry::builtins;
use uniperf::harness::Protocol;
use uniperf::report::render_service;
use uniperf::service::{tcp, Service, ServiceConfig};
use uniperf::util::fault::FaultPlan;
use uniperf::util::json::Json;

/// A client that survives the `conn.abort` fault site: a connection the
/// server drops unanswered is replaced and the current line resent.
/// Aborts happen before anything is served, so no line is answered
/// twice.
fn resilient_client(addr: std::net::SocketAddr, lines: &[String]) -> Vec<String> {
    let connect = || {
        let stream = TcpStream::connect(addr).expect("connect");
        let reader = BufReader::new(stream.try_clone().expect("clone"));
        (stream, reader)
    };
    let (mut stream, mut reader) = connect();
    let mut out = Vec::new();
    for line in lines {
        let mut attempts = 0;
        loop {
            attempts += 1;
            assert!(attempts <= 10, "line never served after 10 attempts: {line}");
            let sent = writeln!(stream, "{line}").and_then(|_| stream.flush());
            if sent.is_err() {
                (stream, reader) = connect();
                continue;
            }
            let mut resp = String::new();
            match reader.read_line(&mut resp) {
                Ok(0) | Err(_) => {
                    (stream, reader) = connect();
                }
                Ok(_) => {
                    out.push(resp.trim_end().to_string());
                    break;
                }
            }
        }
    }
    out
}

fn main() {
    // one fitted device; titan_x requests are answered degraded from it
    let fit_cfg = Config {
        devices: vec!["k40c".into()],
        backend: FitBackend::Native,
        protocol: Protocol { runs: 8, ..Protocol::default() },
        ..Config::default()
    };
    let t_fit = Instant::now();
    let store = fit_models(&fit_cfg).expect("fit failed");
    let fit_s = t_fit.elapsed().as_secs_f64();
    println!("fitted {} device(s) in {fit_s:.1}s", store.len());

    let plan = Arc::new(
        FaultPlan::new(2024)
            .site_max("conn.abort", 1.0, 2)
            .site_max("conn.slow", 1.0, 2),
    );
    let engine = Engine::new(Config {
        registry: builtins().clone(),
        degraded: true,
        faults: Some(plan.clone()),
        ..Config::default()
    });
    engine.install_store(store).expect("artifact must validate");
    let svc = Arc::new(
        Service::over(Arc::new(engine), ServiceConfig::default()).expect("service"),
    );

    // request stream: all 9 zoo classes x 4 cases, fitted + degraded
    let kernels = [
        "fd5", "mm_skinny", "conv7", "nbody", "reduce_tree", "scan_hs", "st3d7", "bmm8",
        "gather_s2",
    ];
    let mut lines = Vec::new();
    for dev in ["k40c", "titan_x"] {
        for k in kernels {
            for case in ["a", "b", "c", "d"] {
                lines.push(format!(
                    r#"{{"device": "{dev}", "kernel": "{k}", "case": "{case}"}}"#
                ));
            }
        }
    }
    let n = lines.len();

    let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind");
    let addr = listener.local_addr().expect("addr");
    let server = {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || {
            tcp::serve_threaded(&svc, listener, 64).expect("threaded listener failed")
        })
    };

    let n_clients = 3;
    let t0 = Instant::now();
    let all: Vec<Vec<String>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n_clients)
            .map(|_| scope.spawn(|| resilient_client(addr, &lines)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    let chaos_s = t0.elapsed().as_secs_f64();

    // every request served exactly once, every line well-formed JSON,
    // no errors — and titan_x answers carry the degraded flag
    let mut degraded_seen = 0u64;
    for responses in &all {
        assert_eq!(responses.len(), n, "a client lost responses under chaos");
        for r in responses {
            let j = Json::parse(r)
                .unwrap_or_else(|e| panic!("malformed response under chaos: {r}: {e}"));
            assert!(j.get("error").is_none(), "request errored under chaos: {r}");
            if j.get("degraded") == Some(&Json::Bool(true)) {
                assert_eq!(j.get_str("served_by"), Some("k40c"), "{r}");
                degraded_seen += 1;
            }
        }
    }
    assert_eq!(
        degraded_seen,
        (n_clients * n / 2) as u64,
        "every titan_x answer must be flagged degraded"
    );

    // deterministic drain, then conserved accounting
    let bye = resilient_client(addr, &[r#"{"cmd": "shutdown"}"#.to_string()]);
    assert_eq!(
        Json::parse(&bye[0]).expect("shutdown response").get_str("ok"),
        Some("shutdown")
    );
    let summary = server.join().expect("server panicked under chaos");
    print!("{}", render_service(&summary));
    assert_eq!(
        summary.requests,
        (n_clients * n) as u64 + 1,
        "aborted connections must not distort request accounting"
    );
    assert_eq!(summary.errors, 0);
    assert_eq!(summary.conn_aborted, plan.injected("conn.abort"));
    assert_eq!(plan.injected("conn.abort"), 2, "both planned aborts must fire");
    assert_eq!(summary.conn_slowed, plan.injected("conn.slow"));
    assert_eq!(summary.degraded_served, degraded_seen);

    let rps = (n_clients * n) as f64 / chaos_s;
    println!(
        "chaos: {n_clients} x {n} round trips in {:.1} ms ({rps:.0} req/s) with \
         {} aborted + {} slowed connections",
        chaos_s * 1e3,
        summary.conn_aborted,
        summary.conn_slowed
    );

    let j = Json::obj(vec![
        ("suite", Json::Str("chaos".into())),
        ("fit_s", Json::Num(fit_s)),
        ("clients", Json::Num(n_clients as f64)),
        ("requests_per_client", Json::Num(n as f64)),
        ("seconds", Json::Num(chaos_s)),
        ("rps", Json::Num(rps)),
        ("degraded_served", Json::Num(summary.degraded_served as f64)),
        ("faults", plan.counters_json()),
        ("service", summary.to_json()),
    ]);
    std::fs::write("BENCH_chaos.json", j.pretty()).expect("write BENCH_chaos.json");
    println!("wrote BENCH_chaos.json");
}
