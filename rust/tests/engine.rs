//! Engine parity and concurrency tests.
//!
//! **Parity.** The engine refactor moved the
//! measurement→extraction→fit→predict pipeline out of `coordinator`,
//! `crossval` and `service` into one shared core. These tests pin that
//! the engine-routed paths emit *byte-identical* JSON/report output to
//! the pre-refactor pipelines, which are re-assembled here by hand
//! from the stable lower layers (`harness::run_campaign` /
//! `measure_cases` + `perfmodel::fit`) exactly as the old
//! `coordinator::run_device` and `crossval::build_ctx`/`run_fold`
//! bodies did. The simulator is deterministic, so equality is exact —
//! these hand-assembled references are the golden fixtures, rebuilt
//! fresh each run instead of rotting on disk.
//!
//! **Concurrency.** The threaded TCP listener is pitted against a
//! single-threaded reference service with exact cache
//! hit/miss/eviction accounting, and drained deterministically via
//! `{"cmd": "shutdown"}`.

use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use uniperf::coordinator::{run_device, Config, FitBackend};
use uniperf::crossval::{
    quick_campaign_case, run_crossval, CrossvalOpts, CrossvalResult, FoldResult, Split,
};
use uniperf::engine::Engine;
use uniperf::gpusim::registry::builtins;
use uniperf::gpusim::SimGpu;
use uniperf::harness::{measure_cases, run_campaign, Protocol};
use uniperf::kernels;
use uniperf::perfmodel::{fit, Model, NativeSolver, PropertyMatrix};
use uniperf::report::{Table1, Table1Entry};
use uniperf::service::{
    KernelRef, ModelStore, PredictRequest, Service, ServiceConfig, StoredModel,
};
use uniperf::stats::{ExtractOpts, Schema};
use uniperf::util::json::Json;

fn quick_config() -> Config {
    Config {
        devices: vec!["k40c".into()],
        backend: FitBackend::Native,
        protocol: Protocol { runs: 8, ..Protocol::default() },
        workers: 4,
        ..Config::default()
    }
}

fn temp_path(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("uniperf_engine_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create temp dir");
    dir.join(name)
}

/// The pre-refactor `coordinator::run_device` body, re-assembled from
/// the lower layers: campaign → fit → test-kernel measure + predict.
fn reference_run_device(
    device: &str,
    cfg: &Config,
) -> (Model, f64, usize, Vec<(String, String, f64, f64)>) {
    let schema = Schema::full();
    let profile = cfg.registry.get(device).expect("device").clone();
    let gpu = SimGpu::new(profile);
    let cases = kernels::measurement_suite(&gpu.profile);
    let (pm, overhead) =
        run_campaign(&gpu, &cases, &schema, &cfg.protocol, cfg.extract, cfg.workers)
            .expect("campaign");
    let model = fit(device, &pm, &schema, &NativeSolver::new()).expect("fit");
    let suite = kernels::test_suite(&gpu.profile);
    let ms = measure_cases(&gpu, &suite, &schema, &cfg.protocol, cfg.extract, cfg.workers)
        .expect("measure tests");
    let tests = suite
        .iter()
        .zip(&ms)
        .map(|(case, m)| {
            let mut parts = case.label.split('/');
            (
                parts.next().unwrap_or("?").to_string(),
                parts.next().unwrap_or("?").to_string(),
                model.predict(&m.props),
                m.time_s,
            )
        })
        .collect();
    (model, overhead, pm.n_cases(), tests)
}

/// Engine-routed `run_device` is byte-identical to the hand-assembled
/// pre-refactor pipeline: same fitted weights (to_json bytes), same
/// overhead, same case count, same test predictions bit for bit.
#[test]
fn engine_run_device_matches_hand_assembled_pipeline() {
    let cfg = quick_config();
    let schema = Schema::full();
    let dr = run_device("k40c", &schema, &cfg).expect("engine-routed run_device");
    let (model, overhead, n_cases, tests) = reference_run_device("k40c", &cfg);

    assert_eq!(
        dr.model.to_json(&schema).pretty(),
        model.to_json(&schema).pretty(),
        "fitted model diverged from the pre-refactor pipeline"
    );
    assert_eq!(dr.launch_overhead_s, overhead);
    assert_eq!(dr.n_measurement_cases, n_cases);
    assert_eq!(dr.tests, tests, "test-kernel predictions must be bit-identical");
}

/// Quick-mode zoo filter (the pre-refactor private predicate).
fn reference_quick_zoo(label: &str) -> bool {
    let mut parts = label.split('/');
    let _ = parts.next();
    matches!(parts.next(), Some("a") | Some("b"))
}

/// The pre-refactor `crossval` quick leave-one-size-case-out run on
/// one device, re-assembled by hand: measure the cut-down campaign and
/// zoo once, then per fold train on the retained cases (§4.2 floor on
/// training cases only) and predict the held-out letter.
fn reference_crossval_case_quick(cfg: &Config) -> CrossvalResult {
    let schema = Schema::full();
    let profile = cfg.registry.get(&cfg.devices[0]).expect("device").clone();
    let gpu = SimGpu::new(profile);
    let mut cases = kernels::measurement_suite(&gpu.profile);
    cases.retain(|c| quick_campaign_case(&c.label));
    let (campaign, overhead) =
        run_campaign(&gpu, &cases, &schema, &cfg.protocol, cfg.extract, cfg.workers)
            .expect("campaign");
    let mut zoo_cases = kernels::eval_suite(&gpu.profile);
    zoo_cases.retain(|c| reference_quick_zoo(&c.label));
    let ms = measure_cases(&gpu, &zoo_cases, &schema, &cfg.protocol, cfg.extract, cfg.workers)
        .expect("zoo");
    struct Zc {
        kernel: String,
        case: String,
        label: String,
        props: Vec<f64>,
        time_s: f64,
    }
    let zoo: Vec<Zc> = zoo_cases
        .iter()
        .zip(ms)
        .map(|(c, m)| {
            let mut parts = c.label.split('/');
            Zc {
                kernel: parts.next().unwrap_or("?").to_string(),
                case: parts.next().unwrap_or("?").to_string(),
                label: m.label,
                props: m.props,
                time_s: m.time_s,
            }
        })
        .collect();

    // fold keys in first-seen order
    let mut letters: Vec<String> = Vec::new();
    for z in &zoo {
        if !letters.contains(&z.case) {
            letters.push(z.case.clone());
        }
    }
    let floor = cfg.protocol.min_time_factor * overhead;
    let solver = NativeSolver::new();
    let mut folds = Vec::new();
    let mut table = Table1::default();
    for letter in &letters {
        let mut pm: PropertyMatrix = campaign.clone();
        for z in &zoo {
            if &z.case != letter && z.time_s >= floor {
                pm.push(z.label.clone(), z.props.clone(), z.time_s);
            }
        }
        let model = fit(&gpu.profile.name, &pm, &schema, &solver).expect("fold fit");
        let entries: Vec<Table1Entry> = zoo
            .iter()
            .filter(|z| &z.case == letter)
            .map(|z| Table1Entry {
                device: gpu.profile.name.clone(),
                kernel: z.kernel.clone(),
                case: z.case.clone(),
                predicted_s: model.predict(&z.props),
                actual_s: z.time_s,
            })
            .collect();
        for e in &entries {
            table.push(e.clone());
        }
        folds.push(FoldResult {
            device: gpu.profile.name.clone(),
            fold: letter.clone(),
            n_train: pm.n_cases(),
            train_err: model.train_rel_err_geomean,
            weights: model.weight_report(&schema),
            entries,
        });
    }
    CrossvalResult { split: Split::LeaveOneSizeCaseOut, folds, table, transfer: None }
}

/// Engine-routed `crossval --quick` (size-case split) emits the same
/// JSON and the same rendered report, byte for byte, as the
/// hand-assembled pre-refactor fold pipeline.
#[test]
fn engine_crossval_quick_matches_hand_assembled_folds() {
    let cfg = quick_config();
    let opts = CrossvalOpts {
        base: cfg.clone(),
        split: Split::LeaveOneSizeCaseOut,
        quick: true,
    };
    let engine_routed = run_crossval(&opts).expect("engine-routed crossval");
    let reference = reference_crossval_case_quick(&cfg);
    assert_eq!(
        engine_routed.to_json().pretty(),
        reference.to_json().pretty(),
        "crossval JSON diverged from the pre-refactor fold pipeline"
    );
    assert_eq!(
        engine_routed.render(),
        reference.render(),
        "crossval report diverged from the pre-refactor fold pipeline"
    );
}

/// The acceptance pin for the serving path: `fit → save → load →
/// predict` through the engine answers with exactly the in-memory
/// pipeline's predictions, and the file round trip changes nothing —
/// byte-identical responses between the in-memory store and the loaded
/// artifact.
#[test]
fn engine_fit_save_load_predict_is_bit_identical() {
    let cfg = quick_config();
    let schema = Schema::full();
    let engine = Engine::new(cfg.clone());
    let store = engine.fit_store().expect("fit");
    let path = temp_path("models.json");
    store.save(&path, &schema).expect("save");
    engine.install_store(store).expect("install in-memory store");

    let engine_loaded = Engine::new(cfg.clone());
    engine_loaded
        .install_store(ModelStore::load(&path, &schema).expect("load"))
        .expect("install loaded store");

    // engine predictions equal run_device's own test-kernel predictions
    let dr = run_device("k40c", &schema, &cfg).expect("pipeline");
    for (kernel, case, pred, _actual) in &dr.tests {
        let req = PredictRequest {
            id: None,
            device: "k40c".into(),
            kref: KernelRef::Named { name: kernel.clone(), case: Some(case.clone()) },
            env: None,
            deadline_ms: None,
        };
        let mem = engine.predict(&req).expect("predict (memory)");
        let loaded = engine_loaded.predict(&req).expect("predict (loaded)");
        assert_eq!(mem.predicted_s, *pred, "{kernel}/{case} diverged from run_device");
        assert_eq!(loaded.predicted_s, *pred, "{kernel}/{case} diverged through the file");
    }

    // and the rendered service responses are byte-identical mem vs file
    let svc_mem = Service::over(Arc::new(engine), ServiceConfig::default()).unwrap();
    let svc_loaded =
        Service::over(Arc::new(engine_loaded), ServiceConfig::default()).unwrap();
    for kernel in ["fd5", "mm_skinny", "conv7", "nbody"] {
        for case in ["a", "b", "c", "d"] {
            let line =
                format!(r#"{{"device": "k40c", "kernel": "{kernel}", "case": "{case}"}}"#);
            let (a, b) = (svc_mem.respond(&line), svc_loaded.respond(&line));
            assert!(a.get("error").is_none(), "{line} -> {a}");
            assert_eq!(a.compact(), b.compact(), "{line}");
        }
    }
}

fn toy_store() -> ModelStore {
    let schema = Schema::full();
    let mut weights = vec![0.0; schema.len()];
    weights[schema.len() - 2] = 2e-9;
    weights[schema.len() - 1] = 5e-6;
    let model = Model {
        device: "k40c".into(),
        weights,
        active: vec![schema.len() - 2, schema.len() - 1],
        train_rel_err_geomean: 0.1,
        solver: "native-cholesky",
    };
    let mut store = ModelStore::new(&schema, ExtractOpts::default());
    store.insert(StoredModel::new(model, 8e-6, 400, builtins().get("k40c").unwrap()));
    store
}

/// Conversational TCP client: send each line, read each response.
fn tcp_client(addr: std::net::SocketAddr, lines: &[String]) -> Vec<String> {
    use std::io::{BufRead, BufReader, Write};
    let stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut stream = stream;
    let mut out = Vec::new();
    for line in lines {
        writeln!(stream, "{line}").expect("send");
        stream.flush().expect("flush");
        let mut resp = String::new();
        reader.read_line(&mut resp).expect("recv");
        out.push(resp.trim_end().to_string());
    }
    out
}

/// N concurrent TCP clients against the threaded listener: every
/// response equals the single-threaded reference, the drain is
/// deterministic, and the cache accounting is exact — each kernel
/// class extracted exactly once across all connections, zero
/// evictions at the default capacity.
#[test]
fn threaded_tcp_clients_agree_with_single_threaded_reference() {
    let kernels = ["fd5", "nbody", "reduce_tree"];
    let lines: Vec<String> = (0..24)
        .map(|i| {
            let k = kernels[i % kernels.len()];
            let case = ["a", "b", "c", "d"][(i / kernels.len()) % 4];
            format!(r#"{{"id": {i}, "device": "k40c", "kernel": "{k}", "case": "{case}"}}"#)
        })
        .collect();

    // single-threaded reference
    let reference: Vec<Json> = {
        let svc = Service::new(
            toy_store(),
            builtins().clone(),
            ServiceConfig { workers: 1, ..ServiceConfig::default() },
        )
        .unwrap();
        lines.iter().map(|l| svc.respond(l)).collect()
    };

    let svc = Arc::new(
        Service::new(toy_store(), builtins().clone(), ServiceConfig::default()).unwrap(),
    );
    let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind");
    let addr = listener.local_addr().unwrap();
    let server = {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || {
            uniperf::service::tcp::serve_threaded(&svc, listener, 16).expect("serve")
        })
    };

    let n_clients = 6;
    let all: Vec<Vec<String>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n_clients)
            .map(|_| scope.spawn(|| tcp_client(addr, &lines)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("client")).collect()
    });
    for responses in &all {
        assert_eq!(responses.len(), lines.len());
        for (resp, want) in responses.iter().zip(&reference) {
            let got = Json::parse(resp).expect("response JSON");
            assert!(got.get("error").is_none(), "{resp}");
            // the `cache` field is advisory under cold-batch races;
            // predictions and ids must match exactly
            assert_eq!(got.get_f64("predicted_s"), want.get_f64("predicted_s"));
            assert_eq!(got.get_f64("id"), want.get_f64("id"));
        }
    }

    // deterministic drain
    let bye = tcp_client(addr, &[r#"{"cmd": "shutdown"}"#.to_string()]);
    assert_eq!(Json::parse(&bye[0]).unwrap().get_str("ok"), Some("shutdown"));
    let summary = server.join().expect("server thread");

    // exact accounting: every prediction either hit or missed; each
    // kernel class was extracted exactly once across every connection;
    // nothing was evicted at the default capacity
    let total = (n_clients * lines.len()) as u64;
    assert_eq!(summary.requests, total + 1, "predictions + the shutdown command");
    assert_eq!(summary.errors, 0);
    assert_eq!(summary.cache_hits + summary.cache_misses, total);
    assert_eq!(summary.cache_misses as usize, kernels.len());
    assert_eq!(summary.distinct_kernels, kernels.len());
    assert_eq!(summary.cache_evictions, 0);
}

/// Hot reload end to end through the service: a rewritten artifact
/// swaps in between polls, a garbage rewrite keeps the old weights
/// serving.
#[test]
fn service_watch_hot_reloads_rewritten_artifacts() {
    let schema = Schema::full();
    let path = temp_path("watch_models.json");
    toy_store().save(&path, &schema).expect("save v1");
    let mut svc = Service::new(
        ModelStore::load(&path, &schema).unwrap(),
        builtins().clone(),
        ServiceConfig { workers: 1, ..ServiceConfig::default() },
    )
    .unwrap();
    svc.watch(&path);

    let line = r#"{"device": "k40c", "kernel": "fd5", "case": "a"}"#;
    let p1 = svc.respond(line).get_f64("predicted_s").unwrap();

    // rewrite with doubled weights: the next poll swaps the store
    let mut v2 = toy_store();
    let mut m2 = v2.get("k40c").unwrap().clone();
    for w in &mut m2.model.weights {
        *w *= 2.0;
    }
    v2.insert(m2);
    v2.save(&path, &schema).expect("save v2");
    assert_eq!(svc.poll_reload(), Some(Ok(true)));
    let p2 = svc.respond(line).get_f64("predicted_s").unwrap();
    assert_eq!(p2, 2.0 * p1, "reloaded weights must serve");

    // garbage rewrite: reload fails, old store keeps serving
    std::fs::write(&path, "{broken").unwrap();
    assert!(matches!(svc.poll_reload(), Some(Err(_))));
    assert_eq!(svc.respond(line).get_f64("predicted_s"), Some(p2));
}
