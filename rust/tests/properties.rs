//! Property-based tests over the analysis substrates, using the in-tree
//! harness (`uniperf::util::prop`). Each property runs 256 seeded cases.

use uniperf::isl::{box_to_trip_set, BoxDomain, Dim};
use uniperf::lpir::builder::{gid_lin_1d, KernelBuilder};
use uniperf::lpir::{Access, DType, Expr, Layout};
use uniperf::perfmodel::{NativeSolver, Solver};
use uniperf::prop_assert;
use uniperf::qpoly::{env, Atom, LinExpr, QPoly};
use uniperf::stats::{extract, ExtractOpts, Schema};
use uniperf::util::linalg::{dot, Mat};
use uniperf::util::prop::{check, gen_usize, quickcheck, Config};
use uniperf::util::rng::Rng;

#[test]
fn qpoly_arithmetic_is_a_homomorphism_under_eval() {
    quickcheck("qpoly_homomorphism", |rng| {
        // random small qpolys over {n, m}
        let rand_qpoly = |rng: &mut Rng| {
            let mut q = QPoly::constant(rng.range_i64(-3, 4) as f64);
            for _ in 0..gen_usize(rng, 0, 4) {
                let atom = if rng.f64() < 0.7 {
                    QPoly::param(if rng.f64() < 0.5 { "n" } else { "m" })
                } else {
                    QPoly::from_atom(Atom::FloorDiv(
                        LinExpr::var("n").add(&LinExpr::constant(rng.range_i64(0, 16))),
                        rng.range_i64(1, 8),
                    ))
                };
                q = q.mul(&atom).add(&QPoly::constant(rng.range_i64(-2, 3) as f64));
            }
            q
        };
        let a = rand_qpoly(rng);
        let b = rand_qpoly(rng);
        let e = env(&[("n", rng.range_i64(0, 100)), ("m", rng.range_i64(0, 100))]);
        let (av, bv) = (a.eval(&e).unwrap(), b.eval(&e).unwrap());
        let sum = a.add(&b).eval(&e).unwrap();
        let prod = a.mul(&b).eval(&e).unwrap();
        prop_assert!((sum - (av + bv)).abs() < 1e-6, "add: {sum} vs {}", av + bv);
        // products of counts can be large; compare with relative tolerance
        let want = av * bv;
        prop_assert!(
            (prod - want).abs() <= 1e-9 * want.abs().max(1.0),
            "mul: {prod} vs {want}"
        );
        Ok(())
    });
}

#[test]
fn symbolic_box_count_matches_enumeration() {
    quickcheck("box_count_vs_enumeration", |rng| {
        let mut dims = Vec::new();
        for i in 0..gen_usize(rng, 1, 4) {
            let name = format!("d{i}");
            match rng.range_i64(0, 3) {
                0 => dims.push(Dim::simple(&name, LinExpr::var("n"))),
                1 => dims.push(Dim::strided(&name, LinExpr::var("n"), rng.range_i64(1, 5))),
                _ => dims.push(Dim::tiles(&name, LinExpr::var("n"), rng.range_i64(1, 9))),
            }
        }
        let b = BoxDomain::new(dims);
        let e = env(&[("n", rng.range_i64(1, 30))]);
        let sym = b.count().eval(&e).unwrap();
        let enumerated = box_to_trip_set(&b).count_at(&e).unwrap() as f64;
        prop_assert!(sym == enumerated, "sym {sym} vs enum {enumerated}");
        Ok(())
    });
}

#[test]
fn extraction_is_deterministic_and_size_consistent() {
    quickcheck("extract_deterministic", |rng| {
        let lsize = *rng.choose(&[64i64, 128, 256]);
        let stride = rng.range_i64(1, 4);
        let k = KernelBuilder::new("k", &["n"])
            .group_dims_1d(LinExpr::var("n"), lsize)
            .global_array(
                "a",
                DType::F32,
                vec![LinExpr::var("n").scale(stride)],
                Layout::RowMajor,
                false,
            )
            .global_array("o", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("o", vec![gid_lin_1d(lsize)]),
                Expr::load("a", vec![gid_lin_1d(lsize).scale(stride)]),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap();
        let e1 = env(&[("n", lsize * rng.range_i64(8, 64))]);
        let p1 = extract(&k, &e1, ExtractOpts::default()).map_err(|e| e)?;
        let p2 = extract(&k, &e1, ExtractOpts::default()).map_err(|e| e)?;
        let schema = Schema::full();
        let (v1, v2) = (p1.eval(&schema, &e1).unwrap(), p2.eval(&schema, &e1).unwrap());
        prop_assert!(v1 == v2, "extraction not deterministic");
        // doubling n doubles every count except Const
        let mut e2 = e1.clone();
        e2.insert("n", e1["n"] * 2);
        let v3 = p1.eval(&schema, &e2).unwrap();
        for (i, p) in schema.props().iter().enumerate() {
            if v1[i] == 0.0 {
                continue;
            }
            let factor = v3[i] / v1[i];
            let want = if matches!(p, uniperf::stats::Prop::Const) { 1.0 } else { 2.0 };
            prop_assert!(
                (factor - want).abs() < 1e-9,
                "{}: factor {factor}, want {want}",
                p.label()
            );
        }
        Ok(())
    });
}

#[test]
fn fit_recovers_generating_weights() {
    check("fit_recovery", Config { cases: 64, ..Config::default() }, |rng| {
        let n_props = gen_usize(rng, 1, 8);
        let n_cases = n_props + gen_usize(rng, 4, 40);
        let true_w: Vec<f64> =
            (0..n_props).map(|_| 10f64.powf(-12.0 + 4.0 * rng.f64())).collect();
        let mut rows = Vec::new();
        for _ in 0..n_cases {
            let props: Vec<f64> =
                true_w.iter().map(|_| (rng.range_u64(1, 1000) * 100) as f64).collect();
            let t: f64 = props.iter().zip(&true_w).map(|(p, w)| p * w).sum();
            rows.push(props.iter().map(|p| p / t).collect::<Vec<f64>>());
        }
        let b = Mat::from_rows(rows);
        let w = NativeSolver::new().solve(&b).map_err(|e| e)?;
        // the fitted weights must reproduce every training time
        for i in 0..b.rows {
            let pred = dot(&w, b.row(i));
            prop_assert!((pred - 1.0).abs() < 1e-6, "row {i}: scaled pred {pred}");
        }
        Ok(())
    });
}

#[test]
fn simulated_times_are_positive_monotone_in_size() {
    check("sim_monotone", Config { cases: 32, ..Config::default() }, |rng| {
        let devices = ["titan_x", "k40c", "c2070", "r9_fury"];
        let gpu = uniperf::gpusim::SimGpu::named(*rng.choose(&devices)).unwrap();
        let k = uniperf::kernels::measure::global_access(
            uniperf::kernels::measure::GlobalAccessConfig::Copy,
            256,
        );
        let p = rng.range_i64(16, 22);
        let t1 = gpu.breakdown(&k, &env(&[("n", 1 << p)])).map_err(|e| e)?.total;
        let t2 = gpu.breakdown(&k, &env(&[("n", 1 << (p + 2))])).map_err(|e| e)?.total;
        prop_assert!(t1 > 0.0 && t2 > t1, "t1={t1} t2={t2}");
        // 4x the data must approach 4x the time once the launch overhead
        // stops dominating
        if t1 > 4.0 * gpu.profile.launch_base {
            prop_assert!(t2 > 1.5 * t1, "above overhead: t1={t1} t2={t2}");
        }
        Ok(())
    });
}

#[test]
fn schedule_never_unbalances_loops() {
    quickcheck("schedule_balanced", |rng| {
        // random chain of instructions across a sequential loop
        let use_seq = rng.f64() < 0.5;
        let n = LinExpr::var("n");
        let mut b = KernelBuilder::new("k", &["n"])
            .group_dims_1d(n.clone(), 128)
            .global_array("a", DType::F32, vec![n.clone()], Layout::RowMajor, false)
            .global_array("o", DType::F32, vec![n.clone()], Layout::RowMajor, true)
            .local_array("t", DType::F32, &[128]);
        if use_seq {
            b = b.seq_dim("s", LinExpr::constant(rng.range_i64(1, 5)));
        }
        let within: Vec<&str> =
            if use_seq { vec!["g0", "l0", "s"] } else { vec!["g0", "l0"] };
        let k = b
            .insn(
                Access::new("t", vec![LinExpr::var("l0")]),
                Expr::load("a", vec![gid_lin_1d(128)]),
                &within,
                &[],
            )
            .insn(
                Access::new("o", vec![gid_lin_1d(128)]),
                Expr::load(
                    "t",
                    vec![LinExpr::constant(127).sub(&LinExpr::var("l0"))],
                ),
                &within,
                &[0],
            )
            .build()
            .unwrap();
        let s = uniperf::schedule::schedule(&k).map_err(|e| e)?;
        let mut depth = 0i64;
        for item in &s.items {
            match item {
                uniperf::schedule::SchedItem::OpenLoop(_) => depth += 1,
                uniperf::schedule::SchedItem::CloseLoop(_) => depth -= 1,
                _ => {}
            }
            prop_assert!(depth >= 0, "negative loop depth");
        }
        prop_assert!(depth == 0, "unbalanced loops");
        // the cross-lane read needs at least one barrier
        prop_assert!(s.barrier_sites() >= 1, "missing barrier");
        Ok(())
    });
}

/// Reference (pre-interning) string-keyed evaluation of a [`LinExpr`]:
/// the seed implementation probed a `BTreeMap<String, i64>` per term.
fn string_keyed_lin_eval(
    e: &LinExpr,
    env: &std::collections::BTreeMap<String, i64>,
) -> Result<i64, String> {
    let mut acc = e.c;
    for (v, k) in &e.terms {
        let val = env
            .get(v.as_str())
            .ok_or_else(|| format!("unbound parameter '{v}'"))?;
        acc += k * val;
    }
    Ok(acc)
}

/// Reference string-keyed evaluation of a [`QPoly`].
fn string_keyed_qpoly_eval(
    q: &QPoly,
    env: &std::collections::BTreeMap<String, i64>,
) -> Result<f64, String> {
    let mut acc = 0.0;
    for (m, c) in &q.terms {
        let mut term = *c;
        for (atom, e) in m {
            let v = match atom {
                Atom::Param(p) => *env
                    .get(p.as_str())
                    .ok_or_else(|| format!("unbound parameter '{p}'"))?,
                Atom::FloorDiv(num, den) => {
                    string_keyed_lin_eval(num, env)?.div_euclid(*den)
                }
            } as f64;
            term *= v.powi(*e as i32);
        }
        acc += term;
    }
    Ok(acc)
}

#[test]
fn interned_env_eval_agrees_with_string_keyed_path() {
    use uniperf::qpoly::tape::{LinTape, PwTape};
    use uniperf::qpoly::PwQPoly;
    quickcheck("interned_vs_string_keyed", |rng| {
        // random affine expression over {n, m, q}
        let names = ["n", "m", "q"];
        let mut lin = LinExpr::constant(rng.range_i64(-10, 11));
        for name in &names {
            lin.add_term(*name, rng.range_i64(-5, 6));
        }
        // random qpoly mixing params and floor-div atoms
        let mut poly = QPoly::constant(rng.range_i64(-3, 4) as f64);
        for _ in 0..gen_usize(rng, 0, 4) {
            let atom = if rng.f64() < 0.6 {
                QPoly::param(rng.choose(&names))
            } else {
                QPoly::from_atom(Atom::FloorDiv(
                    LinExpr::var(rng.choose(&names))
                        .add(&LinExpr::constant(rng.range_i64(0, 16))),
                    rng.range_i64(1, 8),
                ))
            };
            poly = poly.mul(&atom).add(&QPoly::constant(rng.range_i64(-2, 3) as f64));
        }
        // one binding, realized both as an interned Env and a String map
        let vals: Vec<i64> = names.iter().map(|_| rng.range_i64(0, 200)).collect();
        let interned = env(&[
            ("n", vals[0]),
            ("m", vals[1]),
            ("q", vals[2]),
        ]);
        let strings: std::collections::BTreeMap<String, i64> = names
            .iter()
            .zip(&vals)
            .map(|(n, v)| (n.to_string(), *v))
            .collect();

        // LinExpr: interned eval == string-keyed reference == compiled tape
        let a = lin.eval(&interned)?;
        let b = string_keyed_lin_eval(&lin, &strings)?;
        let t = LinTape::compile(&lin).eval(&interned)?;
        prop_assert!(a == b, "lin interned {a} vs string {b}");
        prop_assert!(a == t, "lin interned {a} vs tape {t}");

        // QPoly: interned eval == string-keyed reference == compiled tape
        let qa = poly.eval(&interned)?;
        let qb = string_keyed_qpoly_eval(&poly, &strings)?;
        let qt = PwTape::compile(&PwQPoly::from_qpoly(poly.clone())).eval(&interned)?;
        prop_assert!(qa == qb, "qpoly interned {qa} vs string {qb}");
        prop_assert!(qa == qt, "qpoly interned {qa} vs tape {qt}");

        // unbound parameters error identically on both paths
        let partial = env(&[("n", vals[0])]);
        if lin.coeff("m") != 0 {
            prop_assert!(lin.eval(&partial).is_err(), "missing binding not detected");
        }
        Ok(())
    });
}

#[test]
fn overflow_errors_agree_between_tree_tape_and_batch() {
    use uniperf::qpoly::tape::{EnvFrame, LinTape, PwTape, TapeScratch};
    use uniperf::qpoly::PwQPoly;
    quickcheck("overflow_tree_vs_tape", |rng| {
        // coefficients and bindings spanning both the comfortable range
        // and the i64 cliff edge: products like (1<<40)*(1<<40) and
        // 2*(1<<62) must error identically on every evaluation path
        let names = ["n", "m"];
        let coeffs = [-3i64, -1, 0, 1, 2, 5, 1 << 40];
        let vals = [0i64, 1, 13, 1 << 20, 1 << 40, 1 << 62];
        let mut lin = LinExpr::constant(rng.range_i64(-8, 9));
        for name in &names {
            lin.add_term(*name, *rng.choose(&coeffs));
        }
        let envs: Vec<_> = (0..gen_usize(rng, 1, 5))
            .map(|_| env(&[("n", *rng.choose(&vals)), ("m", *rng.choose(&vals))]))
            .collect();
        let env_refs: Vec<&_> = envs.iter().collect();
        let mut frame = EnvFrame::new();
        frame.load(&env_refs);

        // LinExpr: the checked tree evaluator and the compiled tape
        // agree lane by lane — same value or the exact same error
        let tape = LinTape::compile(&lin);
        for e in &envs {
            let (a, b) = (lin.eval(e), tape.eval(e));
            prop_assert!(a == b, "lin tree {a:?} vs tape {b:?}");
        }
        // ...and the batch either matches every lane bit for bit or
        // reports exactly the scalar error of an overflowing lane
        // (never a silently wrapped value)
        let mut out = vec![0i64; envs.len()];
        match tape.eval_many(&frame, &mut out) {
            Ok(()) => {
                for (j, e) in envs.iter().enumerate() {
                    let want = lin.eval(e)?;
                    prop_assert!(out[j] == want, "lane {j}: {} vs {want}", out[j]);
                }
            }
            Err(err) => {
                prop_assert!(err.contains("overflow"), "unexpected batch error: {err}");
                prop_assert!(
                    envs.iter().any(|e| lin.eval(e) == Err(err.clone())),
                    "batch error '{err}' is no lane's scalar error"
                );
            }
        }

        // QPoly with floor-div atoms over the same cliff-edge bindings
        let mut poly = QPoly::constant(rng.range_i64(-2, 3) as f64);
        for _ in 0..gen_usize(rng, 1, 4) {
            let atom = QPoly::from_atom(Atom::FloorDiv(
                LinExpr::var(rng.choose(&names)).scale(*rng.choose(&coeffs)),
                rng.range_i64(1, 8),
            ));
            poly = poly.mul(&atom).add(&QPoly::constant(rng.range_i64(-2, 3) as f64));
        }
        let ptape = PwTape::compile(&PwQPoly::from_qpoly(poly.clone()));
        for e in &envs {
            let (a, b) = (poly.eval(e), ptape.eval(e));
            prop_assert!(a == b, "qpoly tree {a:?} vs tape {b:?}");
        }
        let mut scratch = TapeScratch::new();
        let mut pout = vec![0.0f64; envs.len()];
        match ptape.eval_many(&frame, &mut scratch, &mut pout) {
            Ok(()) => {
                for (j, e) in envs.iter().enumerate() {
                    let want = poly.eval(e)?;
                    prop_assert!(
                        pout[j].to_bits() == want.to_bits(),
                        "lane {j}: batched {} vs scalar {want}",
                        pout[j]
                    );
                }
            }
            Err(err) => {
                prop_assert!(err.contains("overflow"), "unexpected batch error: {err}");
                prop_assert!(
                    envs.iter().any(|e| poly.eval(e) == Err(err.clone())),
                    "batch error '{err}' is no lane's scalar error"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn kernel_props_batch_eval_is_bit_identical_to_scalar() {
    use uniperf::kernels::testks as tk;
    use uniperf::stats::BatchArena;
    // zoo kernels with distinct piecewise/guard structure, extracted
    // once; randomized lane batches (group-multiple sizes plus
    // off-by-one guard-boundary neighbours, duplicates allowed) must
    // come out of the SoA batch path exactly equal to the scalar rows
    let schema = Schema::full();
    let zoo: Vec<(uniperf::lpir::Kernel, &str, i64)> = vec![
        (tk::reduce_tree(256), "n", 256),
        (tk::scan_hs(256), "n", 256),
        (tk::bmm(128), "nb", 128),
        (tk::gather_strided(128), "n", 128),
        (tk::stencil3d(16, 16), "n", 16),
    ];
    let extracted: Vec<_> = zoo
        .iter()
        .map(|(k, p, base)| {
            let e0 = env(&[(*p, base * 64)]);
            let props = extract(k, &e0, ExtractOpts::default()).unwrap();
            (props, *p, *base, k.name.clone())
        })
        .collect();
    let m = schema.len();
    quickcheck("props_batch_vs_scalar", |rng| {
        let mut arena = BatchArena::new();
        let mut flat: Vec<f64> = Vec::new();
        for (props, p, base, name) in &extracted {
            let envs: Vec<_> = (0..gen_usize(rng, 1, 6))
                .map(|_| {
                    let mult = rng.range_i64(1, 65);
                    let jitter = *rng.choose(&[-1i64, 0, 0, 1]);
                    env(&[(*p, (base * mult + jitter).max(1))])
                })
                .collect();
            let env_refs: Vec<&_> = envs.iter().collect();
            props.eval_batch(&schema, &env_refs, &mut arena, &mut flat)?;
            for (j, e) in envs.iter().enumerate() {
                let want = props.eval(&schema, e)?;
                for i in 0..m {
                    prop_assert!(
                        flat[j * m + i].to_bits() == want[i].to_bits(),
                        "{name} {}: lane {j} batched {} vs scalar {}",
                        schema.props()[i].label(),
                        flat[j * m + i],
                        want[i]
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn kernel_props_tape_eval_matches_symbolic_eval() {
    use uniperf::stats::Prop;
    // tapes (used by KernelProps::eval) must agree with direct PwQPoly
    // evaluation on every extracted property of a real kernel
    let k = uniperf::kernels::measure::mm_tiled(16, 16);
    let e0 = env(&[("n", 256), ("m", 256), ("l", 256)]);
    let props = extract(&k, &e0, ExtractOpts::default()).unwrap();
    let schema = Schema::full();
    for nn in [64i64, 128, 512, 1024] {
        let e = env(&[("n", nn), ("m", nn), ("l", nn)]);
        let dense = props.eval(&schema, &e).unwrap();
        for (p, q) in props.sym() {
            if let Some(i) = schema.index_of(p) {
                if matches!(p, Prop::MemMin { .. }) {
                    continue; // filled from the min rule, not the tape
                }
                let direct = q.eval(&e).unwrap();
                assert_eq!(dense[i], direct, "{} at n={nn}", p.label());
            }
        }
    }
}

#[test]
fn zoo_kernel_counts_match_closed_forms() {
    // Each zoo kernel's extracted op/byte counts (evaluated through the
    // compiled tapes of KernelProps::eval) must equal hand-derived
    // closed-form counts at randomized parameter values.
    use uniperf::isl::progression::StrideClass;
    use uniperf::kernels::testks as tk;
    use uniperf::lpir::OpKind;
    use uniperf::stats::{Dir, Prop};
    quickcheck("zoo_closed_form_counts", |rng| {
        let schema = Schema::full();
        let eval = |k: &uniperf::lpir::Kernel,
                    e: &uniperf::util::intern::Env|
         -> Result<Vec<f64>, String> {
            extract(k, e, ExtractOpts::default())?.eval(&schema, e)
        };
        let idx = |p: &Prop| schema.index_of(p).unwrap();
        let load = |class: StrideClass| Prop::MemGlobal { bits: 32, dir: Dir::Load, class };
        let store = |class: StrideClass| Prop::MemGlobal { bits: 32, dir: Dir::Store, class };
        let chk = |got: f64, want: f64, what: &str| -> Result<(), String> {
            if got == want {
                Ok(())
            } else {
                Err(format!("{what}: got {got}, want {want}"))
            }
        };

        // --- reduce_tree: k halving steps over lsize lanes ----------------
        let lsize = *rng.choose(&[128i64, 192, 224, 256, 384, 512]);
        let groups = rng.range_i64(1, 9);
        let n = (lsize * groups) as f64;
        let steps = tk::reduce_steps(lsize) as f64;
        let e = env(&[("n", lsize * groups)]);
        let v = eval(&tk::reduce_tree(lsize), &e)?;
        chk(v[idx(&Prop::LocalLoad { bits: 32 })], (2.0 * steps + 1.0) * n, "reduce local")?;
        chk(v[idx(&Prop::Op { kind: OpKind::AddSub, bits: 32 })], steps * n, "reduce adds")?;
        chk(v[idx(&load(StrideClass::Unit))], n, "reduce unit loads")?;
        chk(v[idx(&store(StrideClass::Uniform))], n, "reduce uniform stores")?;
        chk(v[idx(&Prop::Barriers)], (steps + 1.0) * n, "reduce barriers")?;
        chk(v[idx(&Prop::WorkGroups)], groups as f64, "reduce groups")?;

        // --- scan_hs: k doubling steps, barrier-free final read -----------
        let v = eval(&tk::scan_hs(lsize), &e)?;
        chk(v[idx(&Prop::LocalLoad { bits: 32 })], (2.0 * steps + 1.0) * n, "scan local")?;
        chk(v[idx(&Prop::Op { kind: OpKind::AddSub, bits: 32 })], steps * n, "scan adds")?;
        chk(v[idx(&load(StrideClass::Unit))], n, "scan unit loads")?;
        chk(v[idx(&store(StrideClass::Unit))], n, "scan unit stores")?;
        chk(v[idx(&Prop::Barriers)], steps * n, "scan barriers")?;
        chk(v[idx(&Prop::WorkGroups)], groups as f64, "scan groups")?;

        // --- st3d7: 6 adds (5 in Σ_6 + the final combine), 2 muls,
        //     7 unit loads per grid point -----------------------------------
        let (gx, gy) = (16i64, 16i64);
        let nn = 16 * rng.range_i64(1, 5);
        let n3 = (nn * nn * nn) as f64;
        let e = env(&[("n", nn)]);
        let v = eval(&tk::stencil3d(gx, gy), &e)?;
        chk(v[idx(&Prop::Op { kind: OpKind::AddSub, bits: 32 })], 6.0 * n3, "st3d adds")?;
        chk(v[idx(&Prop::Op { kind: OpKind::Mul, bits: 32 })], 2.0 * n3, "st3d muls")?;
        chk(v[idx(&load(StrideClass::Unit))], 7.0 * n3, "st3d loads")?;
        chk(v[idx(&store(StrideClass::Unit))], n3, "st3d stores")?;
        chk(v[idx(&Prop::Barriers)], 0.0, "st3d barriers")?;
        chk(v[idx(&Prop::WorkGroups)], ((nn / gx) * (nn / gy)) as f64, "st3d groups")?;

        // --- bmm8: one 8x8x8 product per thread, batch-innermost ----------
        let nb = lsize * rng.range_i64(1, 9);
        let d3 = (tk::BMM_D * tk::BMM_D * tk::BMM_D) as f64; // 512
        let e = env(&[("nb", nb)]);
        let v = eval(&tk::bmm(lsize), &e)?;
        chk(v[idx(&Prop::Op { kind: OpKind::Mul, bits: 32 })], d3 * nb as f64, "bmm muls")?;
        chk(v[idx(&Prop::Op { kind: OpKind::AddSub, bits: 32 })], d3 * nb as f64, "bmm adds")?;
        chk(v[idx(&load(StrideClass::Unit))], 2.0 * d3 * nb as f64, "bmm loads")?;
        chk(
            v[idx(&store(StrideClass::Unit))],
            (tk::BMM_D * tk::BMM_D * nb) as f64,
            "bmm stores",
        )?;
        chk(v[idx(&Prop::WorkGroups)], (nb / lsize) as f64, "bmm groups")?;

        // --- gather_s2: 8 unit coefficient loads + 8 half-utilized
        //     stride-2 gather loads per row --------------------------------
        let n = lsize * rng.range_i64(1, 9);
        let diags = tk::GATHER_DIAGS as f64;
        let e = env(&[("n", n)]);
        let v = eval(&tk::gather_strided(lsize), &e)?;
        chk(v[idx(&Prop::Op { kind: OpKind::Mul, bits: 32 })], diags * n as f64, "ell muls")?;
        chk(
            v[idx(&Prop::Op { kind: OpKind::AddSub, bits: 32 })],
            diags * n as f64,
            "ell adds",
        )?;
        chk(v[idx(&load(StrideClass::Unit))], diags * n as f64, "ell unit loads")?;
        chk(
            v[idx(&load(StrideClass::Frac { numer: 1, denom: 2 }))],
            diags * n as f64,
            "ell stride-2 gather loads",
        )?;
        chk(v[idx(&store(StrideClass::Unit))], n as f64, "ell stores")?;
        chk(v[idx(&Prop::WorkGroups)], (n / lsize) as f64, "ell groups")?;
        Ok(())
    });
}

#[test]
fn interpreter_matches_references_on_library_kernels() {
    // the compiled (slot-frame) interpreter must reproduce the plain
    // reference implementations on two library kernels
    use uniperf::gpusim::{execute, seed_value};

    // 1. tiled matrix multiply
    let k = uniperf::kernels::measure::mm_tiled(16, 16);
    let (n, m, l) = (32i64, 32i64, 32i64);
    let st = execute(&k, &env(&[("n", n), ("m", m), ("l", l)])).unwrap();
    let cc = st.get("cc").unwrap();
    for i in 0..n as usize {
        for j in 0..l as usize {
            let want: f64 = (0..m as usize)
                .map(|kk| {
                    seed_value("a", i * m as usize + kk)
                        * seed_value("b", kk * l as usize + j)
                })
                .sum();
            assert!(
                (cc[i * l as usize + j] - want).abs() < 1e-9,
                "mm_tiled at ({i},{j})"
            );
        }
    }

    // 2. finite-difference stencil
    let k = uniperf::kernels::testks::fd_stencil(16, 16);
    let n = 32usize;
    let st = execute(&k, &env(&[("n", n as i64)])).unwrap();
    let want = uniperf::kernels::testks::fd_reference(n);
    let out = st.get("out").unwrap();
    for i in 0..want.len() {
        assert!((out[i] - want[i]).abs() < 1e-9, "fd at {i}");
    }
}
