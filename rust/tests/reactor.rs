//! Event-driven transport tests: byte parity against the threaded
//! listener, hostile framing (slowloris, oversized lines, half-written
//! lines at close), cross-connection batch formation, backpressure
//! shedding, fault-site behavior and the 1k-idle-connection drain.
//!
//! Every test degrades to a skip on targets without the raw-epoll
//! reactor (`reactor::supported()`), where `--transport threaded` is
//! the only listener.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use uniperf::engine::{Config as EngineConfig, Engine};
use uniperf::gpusim::registry::builtins;
use uniperf::perfmodel::Model;
use uniperf::report::ServiceSummary;
use uniperf::service::reactor::{self, ReactorConfig};
use uniperf::service::{tcp, ModelStore, Service, ServiceConfig, StoredModel};
use uniperf::stats::{ExtractOpts, Schema};
use uniperf::util::fault::FaultPlan;
use uniperf::util::json::Json;

/// A k40c+titan_x store over the work-group and constant columns —
/// registry-valid, no fit required, deterministic predictions.
fn toy_store() -> ModelStore {
    let schema = Schema::full();
    let mut store = ModelStore::new(&schema, ExtractOpts::default());
    for (device, group_w, const_w) in [("k40c", 2e-9, 5e-6), ("titan_x", 1e-9, 3e-6)] {
        let mut weights = vec![0.0; schema.len()];
        weights[schema.len() - 2] = group_w;
        weights[schema.len() - 1] = const_w;
        let model = Model {
            device: device.into(),
            weights,
            active: vec![schema.len() - 2, schema.len() - 1],
            train_rel_err_geomean: 0.1,
            solver: "native-cholesky",
        };
        store.insert(StoredModel::new(model, 8e-6, 400, builtins().get(device).unwrap()));
    }
    store
}

fn toy_service(cfg: ServiceConfig) -> Service {
    Service::new(toy_store(), builtins().clone(), cfg).expect("service")
}

type Server = (std::net::SocketAddr, std::thread::JoinHandle<ServiceSummary>);

fn spawn_reactor(svc: &Arc<Service>, cfg: ReactorConfig) -> Server {
    let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind");
    let addr = listener.local_addr().expect("addr");
    let svc = Arc::clone(svc);
    let handle = std::thread::spawn(move || {
        reactor::serve_reactor(&svc, listener, cfg).expect("serve_reactor")
    });
    (addr, handle)
}

fn spawn_threaded(svc: &Arc<Service>, max_conns: usize) -> Server {
    let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind");
    let addr = listener.local_addr().expect("addr");
    let svc = Arc::clone(svc);
    let handle = std::thread::spawn(move || {
        tcp::serve_threaded(&svc, listener, max_conns).expect("serve_threaded")
    });
    (addr, handle)
}

/// Conversational client: send each line, read each response line.
fn client(addr: std::net::SocketAddr, lines: &[String]) -> Vec<String> {
    let stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut stream = stream;
    let mut out = Vec::new();
    for line in lines {
        writeln!(stream, "{line}").expect("send");
        stream.flush().expect("flush");
        let mut resp = String::new();
        reader.read_line(&mut resp).expect("recv");
        out.push(resp.trim_end().to_string());
    }
    out
}

/// Reconnect-and-resend client for the `conn.abort` fault site (aborts
/// always strike before a byte is served, so no line is answered
/// twice).
fn resilient_client(addr: std::net::SocketAddr, lines: &[String]) -> Vec<String> {
    let connect = || {
        let stream = TcpStream::connect(addr).expect("connect");
        let reader = BufReader::new(stream.try_clone().expect("clone"));
        (stream, reader)
    };
    let (mut stream, mut reader) = connect();
    let mut out = Vec::new();
    for line in lines {
        let mut attempts = 0;
        loop {
            attempts += 1;
            assert!(attempts <= 10, "too many retries for {line}");
            if writeln!(stream, "{line}").and_then(|_| stream.flush()).is_err() {
                let (s, r) = connect();
                stream = s;
                reader = r;
                continue;
            }
            let mut resp = String::new();
            match reader.read_line(&mut resp) {
                Ok(0) | Err(_) => {
                    let (s, r) = connect();
                    stream = s;
                    reader = r;
                }
                Ok(_) => {
                    out.push(resp.trim_end().to_string());
                    break;
                }
            }
        }
    }
    out
}

fn shutdown(addr: std::net::SocketAddr) {
    let bye = client(addr, &[r#"{"cmd": "shutdown"}"#.to_string()]);
    assert_eq!(Json::parse(&bye[0]).expect("shutdown json").get_str("ok"), Some("shutdown"));
}

macro_rules! skip_without_reactor {
    () => {
        if !reactor::supported() {
            eprintln!("skipping: epoll reactor unsupported on this target");
            return;
        }
    };
}

/// The acceptance-criteria parity pin: the reactor answers a golden
/// conversational stream — predictions, cache hits, matrix, malformed
/// JSON, unknown kernel, an unexpired deadline — byte-identically to
/// `serve_threaded` over the same store, and the deadline-expired and
/// shutdown contracts match field-wise (their responses embed measured
/// wait times).
#[test]
fn reactor_matches_threaded_byte_for_byte_on_golden_streams() {
    skip_without_reactor!();
    let golden: Vec<String> = vec![
        r#"{"id": 0, "device": "k40c", "kernel": "fd5", "case": "a"}"#.into(),
        r#"{"id": 1, "device": "k40c", "kernel": "fd5", "case": "a"}"#.into(),
        r#"{"id": 2, "device": "titan_x", "kernel": "nbody", "case": "b"}"#.into(),
        r#"{"id": 3, "device": "k40c", "kernel": "fd5", "case": "a", "deadline_ms": 60000}"#
            .into(),
        r#"{"cmd": "matrix", "kernel": "fd5", "case": "a", "devices": ["k40c", "titan_x"], "id": "m1"}"#
            .into(),
        r#"{"id": 4, "device": "k40c", "kernel": "nope"}"#.into(),
        r#"this is not json"#.into(),
        r#"{"id": 5, "device": "quadro", "kernel": "fd5"}"#.into(),
    ];

    // fresh service per transport: both start cold, so the hit/miss
    // sequences match exactly
    let svc_t = Arc::new(toy_service(ServiceConfig::default()));
    let (addr_t, server_t) = spawn_threaded(&svc_t, 8);
    let from_threaded = client(addr_t, &golden);

    let svc_r = Arc::new(toy_service(ServiceConfig::default()));
    let (addr_r, server_r) = spawn_reactor(&svc_r, ReactorConfig::default());
    let from_reactor = client(addr_r, &golden);

    assert_eq!(from_reactor.len(), from_threaded.len());
    for (i, (r, t)) in from_reactor.iter().zip(&from_threaded).enumerate() {
        assert_eq!(r, t, "response {i} diverged for request {}", golden[i]);
    }

    // deadline-expired: field-wise (the error text embeds the measured
    // wait, which is not reproducible byte-for-byte)
    let expired = r#"{"id": "late", "device": "k40c", "kernel": "fd5", "deadline_ms": 0}"#;
    for addr in [addr_t, addr_r] {
        let resp = client(addr, &[expired.to_string()]);
        let j = Json::parse(&resp[0]).expect("deadline json");
        assert_eq!(j.get_str("reason"), Some("deadline"), "{}", resp[0]);
        assert_eq!(j.get_str("id"), Some("late"));
        assert!(j.get_str("error").unwrap().contains("deadline exceeded"));
    }

    shutdown(addr_t);
    shutdown(addr_r);
    let sum_t = server_t.join().expect("threaded server");
    let sum_r = server_r.join().expect("reactor server");
    for (name, s) in [("threaded", &sum_t), ("reactor", &sum_r)] {
        assert_eq!(s.requests, golden.len() as u64 + 2, "{name} requests");
        // malformed + unknown kernel + unknown device + expired deadline
        assert_eq!(s.errors, 4, "{name} errors");
        assert_eq!(s.deadline_expired, 1, "{name} deadline_expired");
        assert_eq!(s.shed, 0, "{name} shed");
    }
}

/// Slowloris: a request line dribbled one byte at a time is framed and
/// answered once the newline lands — and the reactor never stalls the
/// other connections while waiting.
#[test]
fn slowloris_byte_at_a_time_line_is_served() {
    skip_without_reactor!();
    let svc = Arc::new(toy_service(ServiceConfig::default()));
    let (addr, server) = spawn_reactor(&svc, ReactorConfig::default());

    let line = r#"{"id": "slow", "device": "k40c", "kernel": "fd5", "case": "a"}"#;
    let mut slow = TcpStream::connect(addr).expect("connect");
    slow.set_nodelay(true).expect("nodelay");
    let mut slow_reader = BufReader::new(slow.try_clone().expect("clone"));
    for b in line.as_bytes() {
        slow.write_all(std::slice::from_ref(b)).expect("dribble");
        slow.flush().expect("flush");
        std::thread::sleep(std::time::Duration::from_micros(200));
    }

    // a concurrent fast client is not blocked behind the dribbler
    let fast = client(
        addr,
        &[r#"{"id": "fast", "device": "k40c", "kernel": "fd5", "case": "a"}"#.to_string()],
    );
    assert_eq!(Json::parse(&fast[0]).unwrap().get_str("id"), Some("fast"));

    slow.write_all(b"\n").expect("newline");
    slow.flush().expect("flush");
    let mut resp = String::new();
    slow_reader.read_line(&mut resp).expect("slow response");
    let j = Json::parse(resp.trim_end()).expect("json");
    assert!(j.get("error").is_none(), "{resp}");
    assert_eq!(j.get_str("id"), Some("slow"));

    shutdown(addr);
    let summary = server.join().expect("server");
    assert_eq!(summary.errors, 0);
    assert_eq!(summary.requests, 3);
}

/// Oversized lines answer a bounded error with the salvaged id, and
/// the stream resynchronizes at the newline — the framing invariants
/// the buffered reader pins, now on the nonblocking path.
#[test]
fn oversized_line_resyncs_at_newline() {
    skip_without_reactor!();
    let cfg = ServiceConfig { max_line: 256, ..ServiceConfig::default() };
    let svc = Arc::new(toy_service(cfg));
    let (addr, server) = spawn_reactor(&svc, ReactorConfig::default());

    let huge = format!(r#"{{"id": "big", "junk": "{}"}}"#, "x".repeat(4096));
    let good = r#"{"id": "after", "device": "k40c", "kernel": "fd5", "case": "a"}"#;
    let responses = client(addr, &[huge, good.to_string()]);

    let j0 = Json::parse(&responses[0]).expect("oversized json");
    assert!(j0.get_str("error").unwrap().contains("256 byte cap"), "{}", responses[0]);
    assert_eq!(j0.get_str("id"), Some("big"), "id salvaged from the retained prefix");
    let j1 = Json::parse(&responses[1]).expect("resynced json");
    assert!(j1.get("error").is_none(), "{}", responses[1]);
    assert_eq!(j1.get_str("id"), Some("after"));

    shutdown(addr);
    let summary = server.join().expect("server");
    assert_eq!(summary.requests, 3);
    assert_eq!(summary.errors, 1);
}

/// A half-written line at connection close: the final unterminated
/// line is served (same as the buffered framer at EOF) and the
/// connection closes after the answer is flushed.
#[test]
fn half_written_line_at_close_is_answered() {
    skip_without_reactor!();
    let svc = Arc::new(toy_service(ServiceConfig::default()));
    let (addr, server) = spawn_reactor(&svc, ReactorConfig::default());

    let stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut stream = stream;
    writeln!(stream, r#"{{"id": 0, "device": "k40c", "kernel": "fd5", "case": "a"}}"#)
        .expect("send");
    // no trailing newline, then half-close: EOF with a pending line
    write!(stream, r#"{{"id": 1, "device": "k40c", "kernel": "fd5", "case": "a"}}"#)
        .expect("send half");
    stream.flush().expect("flush");
    stream.shutdown(std::net::Shutdown::Write).expect("half-close");

    let mut r0 = String::new();
    reader.read_line(&mut r0).expect("first response");
    assert_eq!(Json::parse(r0.trim_end()).unwrap().get_f64("id"), Some(0.0));
    let mut r1 = String::new();
    reader.read_line(&mut r1).expect("unterminated-line response");
    let j1 = Json::parse(r1.trim_end()).expect("json");
    assert!(j1.get("error").is_none(), "{r1}");
    assert_eq!(j1.get_f64("id"), Some(1.0));
    // server closes once everything owed is flushed
    let mut rest = String::new();
    assert_eq!(reader.read_line(&mut rest).expect("eof"), 0);

    shutdown(addr);
    let summary = server.join().expect("server");
    assert_eq!(summary.requests, 3);
    assert_eq!(summary.errors, 0);
}

/// Cross-connection batch formation: N one-shot clients inside one
/// formation window coalesce into wide `predict_batch` calls — the
/// mean formed-batch width must exceed 1 (the whole point of the
/// reactor), and every client still gets its own answer.
#[test]
fn cross_connection_requests_coalesce_into_wide_batches() {
    skip_without_reactor!();
    let svc = Arc::new(toy_service(ServiceConfig::default()));
    // generous window so all clients land in the first batch
    let cfg = ReactorConfig { batch_ms: 100.0, ..ReactorConfig::default() };
    let (addr, server) = spawn_reactor(&svc, cfg);

    let n = 8;
    let mut conns: Vec<(TcpStream, BufReader<TcpStream>)> = (0..n)
        .map(|_| {
            let s = TcpStream::connect(addr).expect("connect");
            let r = BufReader::new(s.try_clone().expect("clone"));
            (s, r)
        })
        .collect();
    for (i, (s, _)) in conns.iter_mut().enumerate() {
        writeln!(s, r#"{{"id": {i}, "device": "k40c", "kernel": "fd5", "case": "a"}}"#)
            .expect("send");
        s.flush().expect("flush");
    }
    for (i, (_, r)) in conns.iter_mut().enumerate() {
        let mut resp = String::new();
        r.read_line(&mut resp).expect("recv");
        let j = Json::parse(resp.trim_end()).expect("json");
        assert!(j.get("error").is_none(), "{resp}");
        assert_eq!(j.get_f64("id"), Some(i as f64));
    }
    drop(conns);

    shutdown(addr);
    let summary = server.join().expect("server");
    assert_eq!(summary.requests, n as u64 + 1);
    assert_eq!(summary.errors, 0);
    assert!(
        summary.batch_mean > 1.0,
        "cross-connection coalescing must engage: mean width {}",
        summary.batch_mean
    );
}

/// Backpressure: a pipelined burst against a one-deep queue sheds the
/// overflow in stream order with `"reason": "overloaded"` +
/// `retry_after_ms`, and live requests still answer correctly.
#[test]
fn bounded_queue_sheds_pipelined_overload_in_order() {
    skip_without_reactor!();
    let cfg = ServiceConfig { queue_cap: 1, ..ServiceConfig::default() };
    let svc = Arc::new(toy_service(cfg));
    // a wide formation window keeps the one queued line pending while
    // the rest of the burst arrives, forcing deterministic sheds
    let rcfg = ReactorConfig { batch_ms: 200.0, ..ReactorConfig::default() };
    let (addr, server) = spawn_reactor(&svc, rcfg);

    let stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut stream = stream;
    let n = 8;
    let mut burst = String::new();
    for i in 0..n {
        burst.push_str(&format!(
            "{{\"id\": {i}, \"device\": \"k40c\", \"kernel\": \"fd5\", \"case\": \"a\"}}\n"
        ));
    }
    stream.write_all(burst.as_bytes()).expect("burst");
    stream.flush().expect("flush");

    let mut served = 0;
    let mut shed = 0;
    for i in 0..n {
        let mut resp = String::new();
        reader.read_line(&mut resp).expect("recv");
        let j = Json::parse(resp.trim_end()).expect("json");
        assert_eq!(j.get_f64("id"), Some(i as f64), "stream order: {resp}");
        if j.get_str("reason") == Some("overloaded") {
            assert!(j.get_f64("retry_after_ms").is_some(), "{resp}");
            shed += 1;
        } else {
            assert!(j.get("error").is_none(), "{resp}");
            served += 1;
        }
    }
    assert_eq!(served + shed, n);
    assert!(served >= 1, "the queued request must be served");
    assert!(shed >= 1, "a one-deep queue must shed a pipelined burst");

    shutdown(addr);
    let summary = server.join().expect("server");
    assert_eq!(summary.shed, shed as u64);
    assert_eq!(summary.requests, n as u64 + 1);
}

/// The `conn.abort`/`conn.slow` fault sites behave exactly as on the
/// threaded transport: aborts strike before a byte is served and a
/// resilient client recovers, slowdowns only delay, accounting is
/// conserved, and the drain stays deterministic.
#[test]
fn fault_sites_match_threaded_semantics() {
    skip_without_reactor!();
    let plan = Arc::new(
        FaultPlan::new(7)
            .site_max("conn.abort", 1.0, 2)
            .site_max("conn.slow", 1.0, 2),
    );
    let engine = Engine::new(EngineConfig {
        registry: builtins().clone(),
        workers: 2,
        faults: Some(plan.clone()),
        ..EngineConfig::default()
    });
    engine.install_store(toy_store()).expect("install");
    let svc = Arc::new(
        Service::over(Arc::new(engine), ServiceConfig::default()).expect("service"),
    );
    let (addr, server) = spawn_reactor(&svc, ReactorConfig::default());

    let lines: Vec<String> = (0..4)
        .map(|i| format!(r#"{{"id": {i}, "device": "k40c", "kernel": "fd5", "case": "a"}}"#))
        .collect();
    let responses = resilient_client(addr, &lines);
    assert_eq!(responses.len(), lines.len(), "every line answered exactly once");
    for (i, r) in responses.iter().enumerate() {
        let j = Json::parse(r).expect("json");
        assert!(j.get("error").is_none(), "{r}");
        assert_eq!(j.get_f64("id"), Some(i as f64));
    }
    assert_eq!(plan.injected("conn.abort"), 2, "both aborts spent");

    let bye = resilient_client(addr, &[r#"{"cmd": "shutdown"}"#.to_string()]);
    assert_eq!(Json::parse(&bye[0]).unwrap().get_str("ok"), Some("shutdown"));
    let summary = server.join().expect("server");
    assert_eq!(summary.conn_aborted, 2);
    assert!(summary.conn_slowed >= 1, "the surviving connection was slowed");
    assert_eq!(summary.requests, 5);
    assert_eq!(summary.errors, 0);
}

/// The connection guard and the health surface: above `max_conns` a
/// connection gets one overload line and a close, and
/// `{"cmd": "health"}` exposes the new queue/batch/accept sections.
#[test]
fn connection_guard_and_health_surface() {
    skip_without_reactor!();
    let svc = Arc::new(toy_service(ServiceConfig::default()));
    let cfg = ReactorConfig { max_conns: 2, ..ReactorConfig::default() };
    let (addr, server) = spawn_reactor(&svc, cfg);

    // two held connections occupy the cap (a served request each
    // proves full installation)
    let mut held = Vec::new();
    for _ in 0..2 {
        let s = TcpStream::connect(addr).expect("connect");
        let mut r = BufReader::new(s.try_clone().expect("clone"));
        let mut s = s;
        writeln!(s, r#"{{"device": "k40c", "kernel": "fd5", "case": "a"}}"#).expect("send");
        s.flush().expect("flush");
        let mut resp = String::new();
        r.read_line(&mut resp).expect("recv");
        assert!(Json::parse(resp.trim_end()).unwrap().get("error").is_none());
        held.push((s, r));
    }

    let over = TcpStream::connect(addr).expect("over-cap connect");
    let mut over_reader = BufReader::new(over);
    let mut line = String::new();
    over_reader.read_line(&mut line).expect("guard line");
    let j = Json::parse(line.trim_end()).expect("json");
    assert_eq!(j.get_str("reason"), Some("overloaded"), "{line}");
    assert!(j.get_str("error").unwrap().contains("2 concurrent connections"));
    let mut rest = String::new();
    assert_eq!(over_reader.read_line(&mut rest).expect("eof"), 0, "guard closes");

    // health over a held connection: the new observability sections
    let (s, r) = &mut held[0];
    writeln!(s, r#"{{"cmd": "health", "id": "h"}}"#).expect("send health");
    s.flush().expect("flush");
    let mut resp = String::new();
    r.read_line(&mut resp).expect("health");
    let h = Json::parse(resp.trim_end()).expect("health json");
    assert_eq!(h.get_str("ok"), Some("health"));
    let queue = h.get("queue").expect("queue section");
    assert!(queue.get_f64("depth").is_some() && queue.get_f64("cap").is_some(), "{h}");
    let batch = h.get("batch").expect("batch section");
    for k in ["width_p50", "width_p99", "width_mean"] {
        assert!(batch.get_f64(k).is_some(), "missing {k}: {h}");
    }
    let counters = h.get("counters").expect("counters");
    assert_eq!(counters.get_f64("accept_errors"), Some(0.0));
    assert_eq!(counters.get_f64("accept_backoffs"), Some(0.0));
    assert_eq!(counters.get_f64("shed"), Some(1.0), "the guard shed: {h}");

    writeln!(s, r#"{{"cmd": "shutdown"}}"#).expect("send shutdown");
    s.flush().expect("flush");
    let mut bye = String::new();
    r.read_line(&mut bye).expect("bye");
    drop(held);
    let summary = server.join().expect("server");
    assert_eq!(summary.shed, 1);
}

/// The ISSUE's drain pin: a horde of idle keep-alive connections (1k
/// where the fd budget allows; gracefully fewer under a tight
/// `ulimit -n`) plus one active client, then shutdown — the reactor
/// joins cleanly while the idle connections are still open, with
/// conserved accounting against a single-threaded reference.
#[test]
fn idle_connection_horde_drains_cleanly() {
    skip_without_reactor!();
    let svc = Arc::new(toy_service(ServiceConfig::default()));
    let cfg = ReactorConfig { max_conns: 2048, ..ReactorConfig::default() };
    let (addr, server) = spawn_reactor(&svc, cfg);

    // open up to 1k idle connections; an EMFILE-bound environment
    // caps the horde instead of failing the test (both sides of each
    // connection live in this process, doubling the fd cost)
    let mut idle = Vec::new();
    for _ in 0..1000 {
        match TcpStream::connect(addr) {
            Ok(s) => idle.push(s),
            Err(_) => break,
        }
    }
    if idle.len() < 1000 {
        // the connect loop stopped at the fd ceiling: give back some
        // headroom for the active client and the server's accept path,
        // then let the reactor reap the closed pairs and let any
        // EMFILE accept backoff expire
        for _ in 0..64 {
            drop(idle.pop());
        }
        std::thread::sleep(std::time::Duration::from_millis(250));
    }
    assert!(idle.len() >= 128, "need a meaningful horde, got {}", idle.len());

    // one active client works through the horde
    let lines: Vec<String> = (0..32)
        .map(|i| {
            let kernel = ["fd5", "nbody"][i % 2];
            format!(r#"{{"id": {i}, "device": "k40c", "kernel": "{kernel}", "case": "a"}}"#)
        })
        .collect();
    let responses = client(addr, &lines);

    // single-threaded reference service answers the same stream
    let reference = toy_service(ServiceConfig { workers: 1, ..ServiceConfig::default() });
    for (i, (line, got)) in lines.iter().zip(&responses).enumerate() {
        let want = reference.respond(line).compact();
        assert_eq!(got, &want, "response {i} diverged from the reference");
    }

    // drain with the horde still attached
    shutdown(addr);
    let summary = server.join().expect("reactor drains despite idle horde");
    assert_eq!(summary.requests, lines.len() as u64 + 1);
    assert_eq!(summary.errors, 0);
    assert_eq!(summary.shed, 0);
    drop(idle);
}
