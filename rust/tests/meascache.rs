//! Integration contract for the persistent campaign measurement cache
//! (`--meas-cache`, format `uniperf-meascache-v1`): a warm cache
//! replays a whole cross-validation run bit-identically with **zero**
//! simulator draws, an incompatible file is refused without being
//! modified (the run proceeds cold), and a torn final line degrades to
//! a partial warm start instead of an error. The file-format unit
//! contract lives next to the implementation in
//! `rust/src/harness/meascache.rs`; these tests pin the engine-level
//! layering: `Config.meas_cache` → `Engine` → `SimGpu` → the harness
//! retry loop.

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use uniperf::coordinator::{Config, FitBackend};
use uniperf::crossval::{run_crossval, CrossvalOpts, Split};
use uniperf::gpusim;
use uniperf::harness::{MeasCacheFile, Protocol};

/// Serializes the measuring tests in this binary: [`gpusim::sim_draws`]
/// is a process-global counter, so "zero draws during the warm run" is
/// only meaningful while no sibling test is measuring concurrently.
static MEAS_LOCK: Mutex<()> = Mutex::new(());

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir()
        .join(format!("uniperf_meascache_it_{name}_{}", std::process::id()));
    let _ = std::fs::remove_file(&p);
    p
}

/// Quick two-device transfer split — the acceptance scenario: warm
/// `crossval --split device` must replay with zero simulation.
fn transfer_opts(cache: &Path) -> CrossvalOpts {
    CrossvalOpts {
        base: Config {
            devices: vec!["k40c".into(), "r9_fury".into()],
            backend: FitBackend::Native,
            meas_cache: Some(cache.to_path_buf()),
            ..Config::default()
        },
        split: Split::LeaveOneDeviceOut,
        quick: true,
    }
}

/// Cheaper single-device split for the refusal/torn-tail scenarios.
fn single_device_opts(cache: &Path) -> CrossvalOpts {
    CrossvalOpts {
        base: Config {
            devices: vec!["c2070".into()],
            backend: FitBackend::Native,
            meas_cache: Some(cache.to_path_buf()),
            ..Config::default()
        },
        split: Split::LeaveOneSizeCaseOut,
        quick: true,
    }
}

#[test]
fn warm_transfer_crossval_replays_bit_identically_with_zero_simulation() {
    let _g = MEAS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let cache = tmp("warm");

    let before_cold = gpusim::sim_draws();
    let cold = run_crossval(&transfer_opts(&cache)).expect("cold crossval");
    assert!(
        gpusim::sim_draws() > before_cold,
        "the cold run must actually simulate"
    );

    let bytes = std::fs::read(&cache).expect("cold run persists its streams");
    assert!(bytes.ends_with(b"\n"), "every record is one complete line");
    let records = bytes.split(|&b| b == b'\n').filter(|l| !l.is_empty()).count();
    assert!(records > 1, "expected header + streams, got {records} line(s)");

    let before_warm = gpusim::sim_draws();
    let warm = run_crossval(&transfer_opts(&cache)).expect("warm crossval");
    assert_eq!(
        gpusim::sim_draws() - before_warm,
        0,
        "a warm cache must replay without touching the simulator"
    );

    // byte-identical downstream artifacts: transfer matrix, report,
    // full JSON record
    assert_eq!(cold.transfer, warm.transfer);
    assert_eq!(cold.render(), warm.render());
    assert_eq!(cold.to_json().pretty(), warm.to_json().pretty());

    // a fully warm replay appends nothing
    assert_eq!(std::fs::read(&cache).expect("reread"), bytes);

    // the campaign plane surfaced the replay: hits are monotonic and a
    // warm two-device run scores many (exact counts are asserted in
    // the unit tests; globals are shared across the test process)
    let snap = uniperf::obs::metrics::campaign().snapshot();
    assert!(snap.counter("meascache_hits_total") > 0, "replays must be counted");
    let _ = std::fs::remove_file(&cache);
}

#[test]
fn incompatible_cache_is_refused_cold_run_proceeds_file_untouched() {
    let _g = MEAS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let cache = tmp("refused");

    // seed a file recorded under a *different* timing protocol (one
    // extra run per case) but this build's noise seed
    let other = Protocol { runs: Protocol::default().runs + 1, ..Protocol::default() };
    drop(MeasCacheFile::open(&cache, &other, gpusim::DEFAULT_SEED).expect("seed file"));
    let before = std::fs::read(&cache).expect("seeded header");

    let draws_before = gpusim::sim_draws();
    let r = run_crossval(&single_device_opts(&cache)).expect("refused cache still runs");
    assert!(
        gpusim::sim_draws() > draws_before,
        "with the cache refused, the run must measure cold"
    );
    assert!(r.overall_err().is_finite());
    assert_eq!(
        std::fs::read(&cache).expect("reread"),
        before,
        "a refused cache file is left byte-identical on disk"
    );
    let _ = std::fs::remove_file(&cache);
}

#[test]
fn torn_final_line_degrades_to_a_partial_warm_start() {
    let _g = MEAS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let cache = tmp("torn");

    let cold = run_crossval(&single_device_opts(&cache)).expect("cold crossval");
    // simulate a crash mid-append: chop the last record mid-line
    let mut bytes = std::fs::read(&cache).expect("cold cache");
    assert!(bytes.len() > 40, "cache unexpectedly small");
    bytes.truncate(bytes.len() - 17);
    std::fs::write(&cache, &bytes).expect("tear tail");

    // the torn cache opens, replays everything before the tear, and
    // re-measures only the torn stream — determinism makes the rerun
    // byte-identical to the cold one either way
    let warm = run_crossval(&single_device_opts(&cache)).expect("torn cache still runs");
    assert_eq!(cold.render(), warm.render());
    assert_eq!(cold.to_json().pretty(), warm.to_json().pretty());
    let _ = std::fs::remove_file(&cache);
}
