//! Observability-plane integration tests: the `{"cmd": "metrics"}` /
//! `{"cmd": "health"}` / summary surfaces agree because they render one
//! registry snapshot; response bytes are bit-identical whether span
//! recording is on or off; and with recording on, every accepted
//! request line lands in exactly one well-nested span tree — including
//! the shed and deadline-expired paths that never reach the evaluator.
//!
//! The span recorder is process-global and tests here only ever
//! *enable* it, so the byte-parity phase (which needs it off) runs
//! before the enable inside one test function, and span assertions
//! filter to trace ids minted after a marker span.

use uniperf::gpusim::registry::builtins;
use uniperf::obs::span::{self, Span};
use uniperf::perfmodel::Model;
use uniperf::service::{ModelStore, Service, ServiceConfig, StoredModel};
use uniperf::stats::{ExtractOpts, Schema};

/// A k40c+titan_x store over the work-group and constant columns —
/// registry-valid, no fit required, deterministic predictions (same
/// shape as the transport parity tests).
fn toy_store() -> ModelStore {
    let schema = Schema::full();
    let mut store = ModelStore::new(&schema, ExtractOpts::default());
    for (device, group_w, const_w) in [("k40c", 2e-9, 5e-6), ("titan_x", 1e-9, 3e-6)] {
        let mut weights = vec![0.0; schema.len()];
        weights[schema.len() - 2] = group_w;
        weights[schema.len() - 1] = const_w;
        let model = Model {
            device: device.into(),
            weights,
            active: vec![schema.len() - 2, schema.len() - 1],
            train_rel_err_geomean: 0.1,
            solver: "native-cholesky",
        };
        store.insert(StoredModel::new(model, 8e-6, 400, builtins().get(device).unwrap()));
    }
    store
}

fn toy_service(cfg: ServiceConfig) -> Service {
    Service::new(toy_store(), builtins().clone(), cfg).expect("service")
}

/// A deterministic request stream: no timing-dependent response fields
/// (`stats`/`metrics`/`trace` embed measured latencies and are pinned
/// field-wise elsewhere).
fn golden_stream() -> Vec<String> {
    vec![
        r#"{"id": 0, "device": "k40c", "kernel": "fd5", "case": "a"}"#.into(),
        r#"{"id": 1, "device": "k40c", "kernel": "fd5", "case": "a"}"#.into(),
        r#"{"cmd": "matrix", "kernel": "fd5", "case": "a", "devices": ["k40c", "titan_x"], "id": "m1"}"#
            .into(),
        r#"{"id": 2, "device": "k40c", "kernel": "nope"}"#.into(),
        r#"this is not json"#.into(),
        r#"{"cmd": "health"}"#.into(),
    ]
}

/// The three exposure surfaces — Prometheus exposition, the health
/// block, and the structured summary — are all views of one snapshot
/// and can never disagree.
#[test]
fn metrics_cmd_health_and_summary_agree() {
    let svc = toy_service(ServiceConfig { workers: 1, ..ServiceConfig::default() });
    // one real width-3 batch (the single-request `respond` path is
    // deliberately not batch-accounted; width 3 also keeps this
    // binary's conservation test free to filter on its own width-2
    // batch span once tracing is on)
    for p in svc.run_batch(vec![
        r#"{"id": 0, "device": "k40c", "kernel": "fd5", "case": "a"}"#.into(),
        r#"{"id": 1, "device": "k40c", "kernel": "fd5", "case": "a"}"#.into(),
        r#"{"id": 2, "device": "k40c", "kernel": "fd5", "case": "a"}"#.into(),
    ]) {
        assert!(p.get_str("error").is_none(), "{}", p.compact());
    }

    let m = svc.respond(r#"{"cmd": "metrics", "id": "mx"}"#);
    assert_eq!(m.get_str("ok"), Some("metrics"), "{}", m.compact());
    assert_eq!(m.get_str("id"), Some("mx"));
    let text = m.get_str("exposition").expect("exposition text").to_string();

    // the metrics request itself is counted before rendering
    assert!(text.contains("# TYPE uniperf_requests_total counter\nuniperf_requests_total 4\n"), "{text}");
    assert!(text.contains("uniperf_cache_misses_total 1\n"), "{text}");
    assert!(text.contains("uniperf_cache_hits_total 2\n"), "{text}");
    assert!(text.contains("uniperf_errors_total 0\n"), "{text}");
    assert!(text.contains("# TYPE uniperf_queue_cap gauge"), "{text}");
    assert!(text.contains("# TYPE uniperf_request_latency_us histogram"), "{text}");
    assert!(text.contains("uniperf_request_latency_us_count 3\n"), "{text}");
    assert!(text.contains("uniperf_batches_total 1\n"), "{text}");
    assert!(text.contains("uniperf_batch_width_sum 3\n"), "{text}");
    assert!(text.contains("uniperf_batch_width_count 1\n"), "{text}");

    // health and the summary read the same snapshot
    let h = svc.respond(r#"{"cmd": "health"}"#);
    assert_eq!(h.get_str("ok"), Some("health"), "{}", h.compact());
    let cache = h.get("cache").expect("cache block");
    assert_eq!(cache.get_f64("misses"), Some(1.0), "{}", h.compact());
    assert_eq!(cache.get_f64("hits"), Some(2.0));
    let counters = h.get("counters").expect("counters block");
    assert_eq!(counters.get_f64("shed"), Some(0.0));
    let s = svc.summary();
    assert_eq!(s.requests, 5, "batch of 3 + metrics + health");
    assert_eq!(s.cache_misses, 1);
    assert_eq!(s.cache_hits, 2);
}

/// Phase 1: with the recorder off (and again with it on), the golden
/// stream's response bytes are identical — tracing is observably free
/// at the protocol surface. Phase 2: with the recorder on, every
/// accepted line is accounted for in exactly one well-nested span tree,
/// including the shed and deadline paths. One test function because the
/// recorder is process-global: phase 1 must run before the enable.
#[test]
fn tracing_toggle_keeps_bytes_identical_and_spans_conserve() {
    // --- phase 1: byte parity across the recorder toggle ---
    assert!(!span::enabled(), "recorder must start disabled");
    let golden = golden_stream();
    let cold = toy_service(ServiceConfig { workers: 1, ..ServiceConfig::default() });
    let bytes_off: Vec<String> =
        golden.iter().map(|l| cold.respond(l).compact()).collect();

    span::enable(f64::INFINITY); // keep the slow ring out of play
    let warm = toy_service(ServiceConfig { workers: 1, ..ServiceConfig::default() });
    let bytes_on: Vec<String> =
        golden.iter().map(|l| warm.respond(l).compact()).collect();
    assert_eq!(bytes_off, bytes_on, "span recording must not change response bytes");

    // --- phase 2: span conservation over shed + deadline + predict ---
    let marker = {
        let s = Span::root("test.marker");
        s.trace_id()
    };
    assert!(marker > 0);

    // queue_cap 2, batch 8: lines 1-2 are answered, lines 3-5 shed
    let svc = toy_service(ServiceConfig {
        workers: 1,
        batch: 8,
        queue_cap: 2,
        ..ServiceConfig::default()
    });
    let input = concat!(
        r#"{"id": 0, "device": "k40c", "kernel": "fd5", "case": "a"}"#, "\n",
        r#"{"id": 1, "device": "k40c", "kernel": "fd5", "deadline_ms": 0}"#, "\n",
        r#"{"id": 2, "device": "k40c", "kernel": "fd5", "case": "a"}"#, "\n",
        r#"not even json"#, "\n",
        r#"{"id": 4, "device": "k40c", "kernel": "fd5", "case": "a"}"#, "\n",
    );
    let mut out = Vec::new();
    let summary = svc.serve(input.as_bytes(), &mut out).expect("serve");
    assert_eq!(summary.requests, 5);
    assert_eq!(summary.shed, 3);
    assert_eq!(summary.deadline_expired, 1);

    let ours: Vec<span::SpanRec> =
        span::recent().into_iter().filter(|s| s.trace > marker).collect();

    // the three shed lines never reach the evaluator; each still gets
    // its own root span
    let shed_roots: Vec<&span::SpanRec> = ours
        .iter()
        .filter(|s| s.name == "svc.request" && s.parent == 0)
        .collect();
    assert_eq!(shed_roots.len(), 3, "one root span per shed line: {ours:?}");
    for s in &shed_roots {
        assert_eq!(s.meta.as_deref(), Some("shed"));
    }

    // exactly one batch tree holds the two answered lines (the width-2
    // meta scopes the filter: other tests in this binary only ever
    // respond one line at a time)
    let batches: Vec<&span::SpanRec> = ours
        .iter()
        .filter(|s| s.name == "svc.batch" && s.meta.as_deref() == Some("width=2"))
        .collect();
    assert_eq!(batches.len(), 1, "{ours:?}");
    let batch = batches[0];
    assert_eq!(batch.parent, 0);
    assert_eq!(batch.meta.as_deref(), Some("width=2"));
    let tree: Vec<&span::SpanRec> =
        ours.iter().filter(|s| s.trace == batch.trace).collect();

    let requests: Vec<&&span::SpanRec> =
        tree.iter().filter(|s| s.name == "svc.request").collect();
    assert_eq!(requests.len(), 2, "{tree:?}");
    let mut kinds: Vec<&str> =
        requests.iter().filter_map(|s| s.meta.as_deref()).collect();
    kinds.sort_unstable();
    assert_eq!(kinds, ["deadline", "predict"]);
    for r in &requests {
        assert_eq!(r.parent, batch.span, "requests parent under the batch root");
    }

    // shared evaluator + renderer children, and the engine/tape spans
    // they adopt (workers=1 keeps resolution on the serving thread)
    for name in ["svc.eval", "svc.render"] {
        let n = tree.iter().filter(|s| s.name == name && s.parent == batch.span).count();
        assert_eq!(n, 1, "{name} under the batch root: {tree:?}");
    }
    assert!(
        tree.iter().any(|s| s.name == "engine.extract"),
        "the cold store's first lookup misses and the miss extracts: {tree:?}"
    );
    assert!(
        tree.iter().any(|s| s.name == "tape.eval_batch"),
        "the batched tape walk is spanned: {tree:?}"
    );

    // well-nested by construction: every child interval sits inside its
    // parent's (2 µs slack for independent truncation to µs)
    for s in &tree {
        if s.parent == 0 {
            continue;
        }
        let p = tree
            .iter()
            .find(|c| c.span == s.parent)
            .unwrap_or_else(|| panic!("parent of {s:?} present in trace"));
        assert!(s.start_us + 2 >= p.start_us, "child starts after parent: {s:?} in {p:?}");
        assert!(
            s.start_us + s.dur_us <= p.start_us + p.dur_us + 2,
            "child ends before parent: {s:?} in {p:?}"
        );
    }

    // conservation: 5 accepted lines == 2 in the batch tree + 3 shed
    assert_eq!(requests.len() + shed_roots.len(), 5);
}
