//! End-to-end tests of the prediction service: the `fit --save` →
//! `predict --models` round trip (pinned bit-identical to the
//! in-memory pipeline), artifact staleness rejection, structural cache
//! sharing across renamed inline kernels, and concurrent store/cache
//! access from multiple worker threads.

use uniperf::coordinator::{fit_models, run_device, Config, FitBackend};
use uniperf::gpusim::registry::{builtins, DeviceRegistry};
use uniperf::harness::Protocol;
use uniperf::perfmodel::Model;
use uniperf::service::{ModelStore, Service, ServiceConfig, StoredModel};
use uniperf::stats::{ExtractOpts, Schema};
use uniperf::util::json::Json;

/// One-device config with a shortened (but still protocol-shaped)
/// timing run count; the simulator is deterministic, so every fit over
/// this config produces the identical model.
fn quick_config() -> Config {
    Config {
        devices: vec!["k40c".into()],
        backend: FitBackend::Native,
        protocol: Protocol { runs: 8, ..Protocol::default() },
        workers: 4,
        ..Config::default()
    }
}

fn temp_path(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("uniperf_service_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create temp dir");
    dir.join(name)
}

/// A k40c store over hand-made weights (no campaign) for cheap tests.
fn toy_store() -> ModelStore {
    let schema = Schema::full();
    let mut weights = vec![0.0; schema.len()];
    weights[schema.len() - 2] = 2e-9; // work groups
    weights[schema.len() - 1] = 5e-6; // const
    let model = Model {
        device: "k40c".into(),
        weights,
        active: vec![schema.len() - 2, schema.len() - 1],
        train_rel_err_geomean: 0.1,
        solver: "native-cholesky",
    };
    let mut store = ModelStore::new(&schema, ExtractOpts::default());
    store.insert(StoredModel::new(model, 8e-6, 400, builtins().get("k40c").unwrap()));
    store
}

/// A service over [`toy_store`] weights.
fn toy_service(workers: usize) -> Service {
    let cfg = ServiceConfig { workers, ..ServiceConfig::default() };
    Service::new(toy_store(), builtins().clone(), cfg).unwrap()
}

/// The ISSUE's acceptance pin: `fit --save models.json` then `predict
/// --models models.json` answers with exactly what the in-memory
/// pipeline produces — bit-identical response JSON through the file
/// round trip, and predictions equal to `run_device`'s own
/// `model.predict` on the §5 suite.
#[test]
fn fit_save_predict_roundtrips_bit_identically() {
    let cfg = quick_config();
    let schema = Schema::full();

    // fit --save
    let store_mem = fit_models(&cfg).unwrap();
    let path = temp_path("models.json");
    store_mem.save(&path, &schema).unwrap();

    // load for serving; the artifact is a serialization fixed point
    let store_loaded = ModelStore::load(&path, &schema).unwrap();
    assert_eq!(
        store_mem.to_json(&schema).pretty(),
        store_loaded.to_json(&schema).pretty(),
        "save/load must be byte-stable"
    );

    let svc_mem =
        Service::new(store_mem, builtins().clone(), ServiceConfig::default()).unwrap();
    let svc_loaded =
        Service::new(store_loaded, builtins().clone(), ServiceConfig::default()).unwrap();

    // bit-identical responses between the in-memory store and the file
    // round trip, over named cases and a custom env
    let mut lines: Vec<String> = Vec::new();
    for kernel in ["fd5", "mm_skinny", "conv7", "nbody", "reduce_tree", "bmm8"] {
        for case in ["a", "b", "c", "d"] {
            lines.push(format!(
                r#"{{"device": "k40c", "kernel": "{kernel}", "case": "{case}"}}"#
            ));
        }
    }
    lines.push(r#"{"device": "k40c", "kernel": "fd5", "env": {"n": 4096}}"#.into());
    for line in &lines {
        let (a, b) = (svc_mem.respond(line), svc_loaded.respond(line));
        assert!(a.get("error").is_none(), "{line} -> {a}");
        assert_eq!(a.compact(), b.compact(), "{line}");
    }

    // ...and the served predictions equal the in-memory pipeline's own
    // test-kernel predictions (same weights, same property vectors)
    let dr = run_device("k40c", &schema, &cfg).unwrap();
    for (kernel, case, pred, _actual) in &dr.tests {
        let line = format!(
            r#"{{"device": "k40c", "kernel": "{kernel}", "case": "{case}"}}"#
        );
        let resp = svc_loaded.respond(&line);
        assert_eq!(
            resp.get_f64("predicted_s"),
            Some(*pred),
            "{kernel}/{case}: served prediction diverged from the pipeline"
        );
    }
}

#[test]
fn stale_artifacts_are_refused_at_service_construction() {
    let schema = Schema::full();
    let profile = builtins().get("k40c").unwrap().clone();
    let mut weights = vec![0.0; schema.len()];
    weights[schema.len() - 1] = 1e-6;
    let model = Model {
        device: "k40c".into(),
        weights,
        active: vec![schema.len() - 1],
        train_rel_err_geomean: 0.1,
        solver: "native-cholesky",
    };
    let mut store = ModelStore::new(&schema, ExtractOpts::default());
    store.insert(StoredModel::new(model, 8e-6, 400, &profile));

    // same registry: fine
    Service::new(store.clone(), builtins().clone(), ServiceConfig::default()).unwrap();

    // an artifact fitted under an ablation flag is refused by a
    // default-configured service (the weights expect collapsed vectors)
    let mut ablated = ModelStore::new(
        &schema,
        ExtractOpts { collapse_utilization: true, ..ExtractOpts::default() },
    );
    ablated.insert(store.get("k40c").unwrap().clone());
    let e = Service::new(ablated, builtins().clone(), ServiceConfig::default()).unwrap_err();
    assert!(e.contains("extraction options"), "{e}");

    // a registry whose k40c profile was edited after the fit: refused
    let mut edited = profile;
    edited.dram_bw *= 1.05;
    let mut registry = builtins().clone();
    registry.register(edited).unwrap();
    let e = Service::new(store, registry, ServiceConfig::default()).unwrap_err();
    assert!(e.contains("stale"), "{e}");
}

/// Renamed inames/arrays in inline kernel specs share one cache entry
/// (the structural hash ignores names), and the warm request skips
/// extraction entirely.
#[test]
fn inline_kernels_share_cache_entries_across_renames() {
    let svc = toy_service(2);
    let spec_a = r#"{"name": "mine", "params": ["n"],
        "dims": [{"iname": "g0", "tag": "group0", "hi": "n", "tiles": 128},
                 {"iname": "l0", "tag": "local0", "hi": 128}],
        "arrays": [{"name": "src", "dtype": "f32", "shape": ["n"]},
                   {"name": "dst", "dtype": "f32", "shape": ["n"], "output": true}],
        "insns": [{"store": "dst", "idx": ["128*g0 + l0"],
                   "expr": {"load": {"array": "src", "idx": ["128*g0 + l0"]}},
                   "within": ["g0", "l0"]}]}"#;
    // same structure, every identifier renamed (quoted/expression forms
    // only — "local0"/"group0" are tag keywords, not identifiers)
    let spec_b = spec_a
        .replace("mine", "yours")
        .replace("\"g0\"", "\"grp\"")
        .replace("*g0 +", "*grp +")
        .replace("\"l0\"", "\"lane\"")
        .replace("+ l0", "+ lane")
        .replace("src", "input")
        .replace("dst", "dest_buf");
    let line_a = format!(r#"{{"device": "k40c", "lpir": {spec_a}, "env": {{"n": 65536}}}}"#);
    let line_b = format!(r#"{{"device": "k40c", "lpir": {spec_b}, "env": {{"n": 65536}}}}"#);
    let ra = svc.respond(&line_a);
    let rb = svc.respond(&line_b);
    assert!(ra.get("error").is_none(), "{ra}");
    assert_eq!(ra.get_str("cache"), Some("miss"));
    assert_eq!(rb.get_str("cache"), Some("hit"), "renamed twin must hit: {rb}");
    assert_eq!(ra.get_f64("predicted_s"), rb.get_f64("predicted_s"));
    assert_eq!(svc.cache().len(), 1);
    // a structurally different kernel (wider group) is a new entry
    let spec_c = spec_a.replace("128", "256");
    let line_c = format!(r#"{{"device": "k40c", "lpir": {spec_c}, "env": {{"n": 65536}}}}"#);
    assert_eq!(svc.respond(&line_c).get_str("cache"), Some("miss"));
    assert_eq!(svc.cache().len(), 2);
}

/// Satellite: concurrent ModelStore + cache access from multiple
/// service worker threads — many threads fire overlapping request
/// streams at one service; every response must equal the
/// single-threaded reference, and the cache counters must add up.
#[test]
fn concurrent_workers_agree_with_single_threaded_reference() {
    let kernels = ["fd5", "nbody", "reduce_tree", "scan_hs", "bmm8", "gather_s2"];
    let lines: Vec<String> = (0..48)
        .map(|i| {
            let k = kernels[i % kernels.len()];
            let case = ["a", "b", "c", "d"][(i / kernels.len()) % 4];
            format!(r#"{{"id": {i}, "device": "k40c", "kernel": "{k}", "case": "{case}"}}"#)
        })
        .collect();

    // single-threaded reference
    let reference: Vec<String> = {
        let svc = toy_service(1);
        lines.iter().map(|l| svc.respond(l).compact()).collect()
    };

    // 8 OS threads, each pushing the full stream through one shared
    // service (on top of the service's own batch workers)
    let svc = toy_service(4);
    let n_threads = 8;
    let all: Vec<Vec<Json>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n_threads)
            .map(|_| scope.spawn(|| svc.run_batch(lines.clone())))
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker thread panicked")).collect()
    });
    for out in &all {
        assert_eq!(out.len(), lines.len());
        for (resp, reference_resp) in out.iter().zip(&reference) {
            let r = Json::parse(reference_resp).unwrap();
            assert!(resp.get("error").is_none(), "{resp}");
            assert_eq!(resp.get_f64("predicted_s"), r.get_f64("predicted_s"));
            assert_eq!(resp.get_f64("id"), r.get_f64("id"));
        }
    }
    // counters add up exactly: every request either hit or missed, and
    // the distinct kernel structures were extracted exactly once each
    let s = svc.summary();
    let total = (n_threads * lines.len()) as u64;
    assert_eq!(s.requests, total);
    assert_eq!(s.errors, 0);
    assert_eq!(s.cache_hits + s.cache_misses, total);
    assert_eq!(s.cache_misses as usize, kernels.len());
    assert_eq!(s.distinct_kernels, kernels.len());
    assert_eq!(s.batches, n_threads as u64);
}

/// An inline kernel whose extents scale a parameter by 2: with `n`
/// bound to `2^62` (exactly representable in JSON's f64, in range for
/// i64), the `2*n` extent overflows i64 during evaluation.
const WIDE_SPEC: &str = r#"{"name": "wide", "params": ["n"],
    "dims": [{"iname": "g0", "tag": "group0", "hi": "2*n", "tiles": 128},
             {"iname": "l0", "tag": "local0", "hi": 128}],
    "arrays": [{"name": "src", "dtype": "f32", "shape": ["2*n"]},
               {"name": "dst", "dtype": "f32", "shape": ["2*n"], "output": true}],
    "insns": [{"store": "dst", "idx": ["128*g0 + l0"],
               "expr": {"load": {"array": "src", "idx": ["128*g0 + l0"]}},
               "within": ["g0", "l0"]}]}"#;

/// The ISSUE's acceptance pin: an overflowing client-supplied binding
/// comes back as `{"error": ...}` naming the overflow — never a
/// silently wrapped prediction.
#[test]
fn overflowing_env_binding_answers_with_an_error() {
    let svc = toy_service(1);
    let n = 1i64 << 62;
    let line = format!(r#"{{"device": "k40c", "lpir": {WIDE_SPEC}, "env": {{"n": {n}}}}}"#);
    let resp = svc.respond(&line);
    let err = resp.get_str("error").unwrap_or_default();
    assert!(err.contains("overflow"), "want an overflow error, got: {resp}");
    assert!(resp.get("predicted_s").is_none(), "{resp}");
    // the same kernel at a sane size still predicts
    let line = format!(r#"{{"device": "k40c", "lpir": {WIDE_SPEC}, "env": {{"n": 65536}}}}"#);
    let ok = svc.respond(&line);
    assert!(ok.get("error").is_none(), "{ok}");
    assert!(ok.get_f64("predicted_s").is_some(), "{ok}");
}

/// The batched SoA prediction path is a pure throughput change: a
/// mixed request stream answers bit-identically to scalar
/// [`uniperf::engine::Engine::predict`], and a failing request (an
/// overflowing binding, an unknown kernel, a device without weights)
/// gets its own error without poisoning its batchmates.
#[test]
fn predict_batch_agrees_with_scalar_predict() {
    use uniperf::engine::Engine;
    use uniperf::service::{PredictRequest, Request};

    let engine = Engine::new(Config { workers: 1, ..Config::default() });
    engine.install_store(toy_store()).unwrap();

    let n = 1i64 << 62;
    let lines = [
        r#"{"device": "k40c", "kernel": "fd5", "case": "a"}"#.to_string(),
        r#"{"device": "k40c", "kernel": "fd5", "case": "b"}"#.to_string(),
        r#"{"device": "k40c", "kernel": "nbody", "case": "c"}"#.to_string(),
        r#"{"device": "k40c", "kernel": "fd5", "env": {"n": 4096}}"#.to_string(),
        format!(r#"{{"device": "k40c", "lpir": {WIDE_SPEC}, "env": {{"n": {n}}}}}"#),
        r#"{"device": "k40c", "kernel": "no_such_kernel", "case": "a"}"#.to_string(),
        r#"{"device": "titan_x", "kernel": "fd5", "case": "a"}"#.to_string(),
    ];
    let reqs: Vec<PredictRequest> = lines
        .iter()
        .map(|l| match Request::parse(l).unwrap() {
            Request::Predict(p) => p,
            other => panic!("expected a predict request, got {other:?}"),
        })
        .collect();
    let batched = engine.predict_batch(reqs.clone(), 2);
    assert_eq!(batched.len(), reqs.len());
    for (line, (req, b)) in lines.iter().zip(reqs.iter().zip(&batched)) {
        match (engine.predict(req), b) {
            (Ok(a), Ok(bp)) => assert_eq!(
                a.predicted_s.to_bits(),
                bp.predicted_s.to_bits(),
                "{line}: batched prediction diverged from scalar"
            ),
            (Err(ea), Err(eb)) => assert_eq!(&ea, eb, "{line}"),
            (a, b) => panic!("{line}: scalar {a:?} vs batched {b:?}"),
        }
    }
    // the overflowing lane answered with its own overflow error...
    let overflow = batched[4].as_ref().unwrap_err();
    assert!(overflow.contains("overflow"), "{overflow}");
    // ...and every well-formed batchmate still predicted
    for b in &batched[..4] {
        assert!(b.is_ok(), "{b:?}");
    }
}

/// Tentpole: the persistent extraction cache survives a process
/// restart. A second service over the same `--props-cache` file
/// answers the same stream with zero fresh extractions and identical
/// predictions, while a fingerprint-mismatched file is refused — the
/// service then runs cold and never trusts (or modifies) the file.
#[test]
fn props_cache_file_warm_starts_a_restarted_service() {
    use std::sync::Arc;
    use uniperf::engine::Engine;

    let path = temp_path("props_cache_warm.jsonl");
    let _ = std::fs::remove_file(&path);

    let build = |cache_path: &std::path::Path| -> Service {
        let engine = Engine::new(Config {
            workers: 1,
            props_cache: Some(cache_path.to_path_buf()),
            ..Config::default()
        });
        engine.install_store(toy_store()).unwrap();
        let cfg = ServiceConfig { workers: 1, ..ServiceConfig::default() };
        Service::over(Arc::new(engine), cfg).unwrap()
    };

    let lines: Vec<String> = ["fd5", "nbody", "reduce_tree", "bmm8"]
        .iter()
        .flat_map(|k| {
            ["a", "b"].iter().map(move |c| {
                format!(r#"{{"device": "k40c", "kernel": "{k}", "case": "{c}"}}"#)
            })
        })
        .collect();

    // first life: cold — one extraction per kernel structure, appended
    let first: Vec<Json> = {
        let svc = build(&path);
        let out: Vec<Json> = lines.iter().map(|l| svc.respond(l)).collect();
        for r in &out {
            assert!(r.get("error").is_none(), "{r}");
        }
        assert!(svc.cache().misses() > 0);
        assert_eq!(svc.cache().disk_hits(), 0);
        out
    };

    // second life: the whole stream lands on the preloaded corpus
    let svc = build(&path);
    for (line, a) in lines.iter().zip(&first) {
        let b = svc.respond(line);
        assert!(b.get("error").is_none(), "{b}");
        assert_eq!(
            a.get_f64("predicted_s"),
            b.get_f64("predicted_s"),
            "{line}: warm-started prediction diverged"
        );
    }
    assert_eq!(svc.cache().misses(), 0, "a restart must not re-extract");
    assert!(svc.cache().disk_hits() > 0);
    drop(svc);

    // a file recorded under another schema is refused, not trusted: the
    // service starts cold and leaves the file byte-identical
    let alien = temp_path("props_cache_alien.jsonl");
    let text = std::fs::read_to_string(&path).unwrap();
    let forged = text.replacen(&Schema::full().fingerprint(), "0000000000000bad", 1);
    assert_ne!(forged, text, "the forgery must actually rewrite the fingerprint");
    std::fs::write(&alien, &forged).unwrap();
    let svc = build(&alien);
    for line in &lines {
        let r = svc.respond(line);
        assert!(r.get("error").is_none(), "{r}");
    }
    assert!(svc.cache().misses() > 0, "a mismatched file must not warm-start");
    assert_eq!(svc.cache().disk_hits(), 0);
    assert_eq!(
        std::fs::read_to_string(&alien).unwrap(),
        forged,
        "a refused cache file must never be modified"
    );
}

/// The `--devices` template written by `devices --export` loads back
/// and runs the service path for its skeleton device end to end (fit a
/// toy store is out of scope here — just registry + suite validity).
#[test]
fn exported_template_joins_the_registry() {
    let template = uniperf::gpusim::registry::export_template();
    let path = temp_path("profiles_template.json");
    std::fs::write(&path, template.pretty()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let mut registry = DeviceRegistry::empty();
    let names = registry.extend_from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(names.len(), 2);
    let custom = registry.get("my_device").unwrap();
    // the skeleton's capability-derived suite is valid: every case
    // respects the group cap
    for case in uniperf::kernels::measurement_suite(custom) {
        let (a, b) = case.group;
        assert!(a * b <= custom.max_group_size as i64, "{}: {a}x{b}", case.label);
    }
    // and its size_exp override steers the mm_tiled class
    assert_eq!(custom.class_size_exp("mm_tiled", 11), 8);
}
