//! End-to-end tests of the prediction service: the `fit --save` →
//! `predict --models` round trip (pinned bit-identical to the
//! in-memory pipeline), artifact staleness rejection, structural cache
//! sharing across renamed inline kernels, and concurrent store/cache
//! access from multiple worker threads.

use uniperf::coordinator::{fit_models, run_device, Config, FitBackend};
use uniperf::gpusim::registry::{builtins, DeviceRegistry};
use uniperf::harness::Protocol;
use uniperf::perfmodel::Model;
use uniperf::service::{ModelStore, Service, ServiceConfig, StoredModel};
use uniperf::stats::{ExtractOpts, Schema};
use uniperf::util::json::Json;

/// One-device config with a shortened (but still protocol-shaped)
/// timing run count; the simulator is deterministic, so every fit over
/// this config produces the identical model.
fn quick_config() -> Config {
    Config {
        devices: vec!["k40c".into()],
        backend: FitBackend::Native,
        protocol: Protocol { runs: 8, ..Protocol::default() },
        workers: 4,
        ..Config::default()
    }
}

fn temp_path(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("uniperf_service_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create temp dir");
    dir.join(name)
}

/// A service over hand-made weights (no campaign) for cheap tests.
fn toy_service(workers: usize) -> Service {
    let schema = Schema::full();
    let mut weights = vec![0.0; schema.len()];
    weights[schema.len() - 2] = 2e-9; // work groups
    weights[schema.len() - 1] = 5e-6; // const
    let model = Model {
        device: "k40c".into(),
        weights,
        active: vec![schema.len() - 2, schema.len() - 1],
        train_rel_err_geomean: 0.1,
        solver: "native-cholesky",
    };
    let mut store = ModelStore::new(&schema, ExtractOpts::default());
    store.insert(StoredModel::new(model, 8e-6, 400, builtins().get("k40c").unwrap()));
    let cfg = ServiceConfig { workers, ..ServiceConfig::default() };
    Service::new(store, builtins().clone(), cfg).unwrap()
}

/// The ISSUE's acceptance pin: `fit --save models.json` then `predict
/// --models models.json` answers with exactly what the in-memory
/// pipeline produces — bit-identical response JSON through the file
/// round trip, and predictions equal to `run_device`'s own
/// `model.predict` on the §5 suite.
#[test]
fn fit_save_predict_roundtrips_bit_identically() {
    let cfg = quick_config();
    let schema = Schema::full();

    // fit --save
    let store_mem = fit_models(&cfg).unwrap();
    let path = temp_path("models.json");
    store_mem.save(&path, &schema).unwrap();

    // load for serving; the artifact is a serialization fixed point
    let store_loaded = ModelStore::load(&path, &schema).unwrap();
    assert_eq!(
        store_mem.to_json(&schema).pretty(),
        store_loaded.to_json(&schema).pretty(),
        "save/load must be byte-stable"
    );

    let svc_mem =
        Service::new(store_mem, builtins().clone(), ServiceConfig::default()).unwrap();
    let svc_loaded =
        Service::new(store_loaded, builtins().clone(), ServiceConfig::default()).unwrap();

    // bit-identical responses between the in-memory store and the file
    // round trip, over named cases and a custom env
    let mut lines: Vec<String> = Vec::new();
    for kernel in ["fd5", "mm_skinny", "conv7", "nbody", "reduce_tree", "bmm8"] {
        for case in ["a", "b", "c", "d"] {
            lines.push(format!(
                r#"{{"device": "k40c", "kernel": "{kernel}", "case": "{case}"}}"#
            ));
        }
    }
    lines.push(r#"{"device": "k40c", "kernel": "fd5", "env": {"n": 4096}}"#.into());
    for line in &lines {
        let (a, b) = (svc_mem.respond(line), svc_loaded.respond(line));
        assert!(a.get("error").is_none(), "{line} -> {a}");
        assert_eq!(a.compact(), b.compact(), "{line}");
    }

    // ...and the served predictions equal the in-memory pipeline's own
    // test-kernel predictions (same weights, same property vectors)
    let dr = run_device("k40c", &schema, &cfg).unwrap();
    for (kernel, case, pred, _actual) in &dr.tests {
        let line = format!(
            r#"{{"device": "k40c", "kernel": "{kernel}", "case": "{case}"}}"#
        );
        let resp = svc_loaded.respond(&line);
        assert_eq!(
            resp.get_f64("predicted_s"),
            Some(*pred),
            "{kernel}/{case}: served prediction diverged from the pipeline"
        );
    }
}

#[test]
fn stale_artifacts_are_refused_at_service_construction() {
    let schema = Schema::full();
    let profile = builtins().get("k40c").unwrap().clone();
    let mut weights = vec![0.0; schema.len()];
    weights[schema.len() - 1] = 1e-6;
    let model = Model {
        device: "k40c".into(),
        weights,
        active: vec![schema.len() - 1],
        train_rel_err_geomean: 0.1,
        solver: "native-cholesky",
    };
    let mut store = ModelStore::new(&schema, ExtractOpts::default());
    store.insert(StoredModel::new(model, 8e-6, 400, &profile));

    // same registry: fine
    Service::new(store.clone(), builtins().clone(), ServiceConfig::default()).unwrap();

    // an artifact fitted under an ablation flag is refused by a
    // default-configured service (the weights expect collapsed vectors)
    let mut ablated = ModelStore::new(
        &schema,
        ExtractOpts { collapse_utilization: true, ..ExtractOpts::default() },
    );
    ablated.insert(store.get("k40c").unwrap().clone());
    let e = Service::new(ablated, builtins().clone(), ServiceConfig::default()).unwrap_err();
    assert!(e.contains("extraction options"), "{e}");

    // a registry whose k40c profile was edited after the fit: refused
    let mut edited = profile;
    edited.dram_bw *= 1.05;
    let mut registry = builtins().clone();
    registry.register(edited).unwrap();
    let e = Service::new(store, registry, ServiceConfig::default()).unwrap_err();
    assert!(e.contains("stale"), "{e}");
}

/// Renamed inames/arrays in inline kernel specs share one cache entry
/// (the structural hash ignores names), and the warm request skips
/// extraction entirely.
#[test]
fn inline_kernels_share_cache_entries_across_renames() {
    let svc = toy_service(2);
    let spec_a = r#"{"name": "mine", "params": ["n"],
        "dims": [{"iname": "g0", "tag": "group0", "hi": "n", "tiles": 128},
                 {"iname": "l0", "tag": "local0", "hi": 128}],
        "arrays": [{"name": "src", "dtype": "f32", "shape": ["n"]},
                   {"name": "dst", "dtype": "f32", "shape": ["n"], "output": true}],
        "insns": [{"store": "dst", "idx": ["128*g0 + l0"],
                   "expr": {"load": {"array": "src", "idx": ["128*g0 + l0"]}},
                   "within": ["g0", "l0"]}]}"#;
    // same structure, every identifier renamed (quoted/expression forms
    // only — "local0"/"group0" are tag keywords, not identifiers)
    let spec_b = spec_a
        .replace("mine", "yours")
        .replace("\"g0\"", "\"grp\"")
        .replace("*g0 +", "*grp +")
        .replace("\"l0\"", "\"lane\"")
        .replace("+ l0", "+ lane")
        .replace("src", "input")
        .replace("dst", "dest_buf");
    let line_a = format!(r#"{{"device": "k40c", "lpir": {spec_a}, "env": {{"n": 65536}}}}"#);
    let line_b = format!(r#"{{"device": "k40c", "lpir": {spec_b}, "env": {{"n": 65536}}}}"#);
    let ra = svc.respond(&line_a);
    let rb = svc.respond(&line_b);
    assert!(ra.get("error").is_none(), "{ra}");
    assert_eq!(ra.get_str("cache"), Some("miss"));
    assert_eq!(rb.get_str("cache"), Some("hit"), "renamed twin must hit: {rb}");
    assert_eq!(ra.get_f64("predicted_s"), rb.get_f64("predicted_s"));
    assert_eq!(svc.cache().len(), 1);
    // a structurally different kernel (wider group) is a new entry
    let spec_c = spec_a.replace("128", "256");
    let line_c = format!(r#"{{"device": "k40c", "lpir": {spec_c}, "env": {{"n": 65536}}}}"#);
    assert_eq!(svc.respond(&line_c).get_str("cache"), Some("miss"));
    assert_eq!(svc.cache().len(), 2);
}

/// Satellite: concurrent ModelStore + cache access from multiple
/// service worker threads — many threads fire overlapping request
/// streams at one service; every response must equal the
/// single-threaded reference, and the cache counters must add up.
#[test]
fn concurrent_workers_agree_with_single_threaded_reference() {
    let kernels = ["fd5", "nbody", "reduce_tree", "scan_hs", "bmm8", "gather_s2"];
    let lines: Vec<String> = (0..48)
        .map(|i| {
            let k = kernels[i % kernels.len()];
            let case = ["a", "b", "c", "d"][(i / kernels.len()) % 4];
            format!(r#"{{"id": {i}, "device": "k40c", "kernel": "{k}", "case": "{case}"}}"#)
        })
        .collect();

    // single-threaded reference
    let reference: Vec<String> = {
        let svc = toy_service(1);
        lines.iter().map(|l| svc.respond(l).compact()).collect()
    };

    // 8 OS threads, each pushing the full stream through one shared
    // service (on top of the service's own batch workers)
    let svc = toy_service(4);
    let n_threads = 8;
    let all: Vec<Vec<Json>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n_threads)
            .map(|_| scope.spawn(|| svc.run_batch(lines.clone())))
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker thread panicked")).collect()
    });
    for out in &all {
        assert_eq!(out.len(), lines.len());
        for (resp, reference_resp) in out.iter().zip(&reference) {
            let r = Json::parse(reference_resp).unwrap();
            assert!(resp.get("error").is_none(), "{resp}");
            assert_eq!(resp.get_f64("predicted_s"), r.get_f64("predicted_s"));
            assert_eq!(resp.get_f64("id"), r.get_f64("id"));
        }
    }
    // counters add up exactly: every request either hit or missed, and
    // the distinct kernel structures were extracted exactly once each
    let s = svc.summary();
    let total = (n_threads * lines.len()) as u64;
    assert_eq!(s.requests, total);
    assert_eq!(s.errors, 0);
    assert_eq!(s.cache_hits + s.cache_misses, total);
    assert_eq!(s.cache_misses as usize, kernels.len());
    assert_eq!(s.distinct_kernels, kernels.len());
    assert_eq!(s.batches, n_threads as u64);
}

/// The `--devices` template written by `devices --export` loads back
/// and runs the service path for its skeleton device end to end (fit a
/// toy store is out of scope here — just registry + suite validity).
#[test]
fn exported_template_joins_the_registry() {
    let template = uniperf::gpusim::registry::export_template();
    let path = temp_path("profiles_template.json");
    std::fs::write(&path, template.pretty()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let mut registry = DeviceRegistry::empty();
    let names = registry.extend_from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(names.len(), 2);
    let custom = registry.get("my_device").unwrap();
    // the skeleton's capability-derived suite is valid: every case
    // respects the group cap
    for case in uniperf::kernels::measurement_suite(custom) {
        let (a, b) = case.group;
        assert!(a * b <= custom.max_group_size as i64, "{}: {a}x{b}", case.label);
    }
    // and its size_exp override steers the mm_tiled class
    assert_eq!(custom.class_size_exp("mm_tiled", 11), 8);
}
