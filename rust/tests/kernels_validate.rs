//! Numeric validation sweep: every kernel class in the library executes
//! correctly on the simulated device at small sizes, across all the
//! group shapes the paper's configuration tables use.

use uniperf::gpusim::{execute, seed_value};
use uniperf::kernels::measure::{
    arith, filled, global_access, mm_naive, mm_tiled, transpose, vsadd, ArithType,
    GlobalAccessConfig, TransposeVariant,
};
use uniperf::kernels::testks::{
    bmm, bmm_reference, conv_reference, convolution, fd_reference, fd_stencil,
    gather_reference, gather_strided, nbody, nbody_reference, reduce_reference, reduce_tree,
    scan_hs, scan_reference, stencil3d, stencil3d_reference,
};
use uniperf::qpoly::env;

/// All 2-D group shapes appearing in the six group sets.
const SHAPES_2D: [(i64, i64); 5] = [(16, 12), (16, 14), (16, 16), (24, 16), (32, 16)];

/// All 1-D group sizes appearing in the three 1-D group sets (and hence
/// in the zoo kernels' configuration tables).
const SHAPES_1D: [i64; 6] = [128, 192, 224, 256, 384, 512];

#[test]
fn mm_tiled_all_group_shapes() {
    for (gx, gy) in SHAPES_2D {
        let k = mm_tiled(gx, gy);
        let (n, m, l) = (2 * gy, 2 * gx, 2 * gx);
        let e = env(&[("n", n), ("m", m), ("l", l)]);
        let st = execute(&k, &e).unwrap_or_else(|err| panic!("{gx}x{gy}: {err}"));
        let cc = st.get("cc").unwrap();
        for i in 0..n as usize {
            for j in 0..l as usize {
                let want: f64 = (0..m as usize)
                    .map(|kk| {
                        seed_value("a", i * m as usize + kk) * seed_value("b", kk * l as usize + j)
                    })
                    .sum();
                assert!(
                    (cc[i * l as usize + j] - want).abs() < 1e-9,
                    "mm_tiled {gx}x{gy} at ({i},{j})"
                );
            }
        }
    }
}

#[test]
fn transpose_all_variants_and_shapes() {
    for (gx, gy) in SHAPES_2D {
        for variant in [
            TransposeVariant::Tiled,
            TransposeVariant::CoalescedWrite,
            TransposeVariant::CoalescedRead,
        ] {
            let k = transpose(variant, gx, gy);
            // size divisible by both tile and lane shapes
            let n = 2 * gx * gy / gcd(gx, gy);
            let e = env(&[("n", n)]);
            let st = execute(&k, &e).unwrap_or_else(|err| panic!("{variant:?} {gx}x{gy}: {err}"));
            let out = st.get("out").unwrap();
            let pitch = n as usize;
            for i in 0..n as usize {
                for j in 0..n as usize {
                    assert_eq!(
                        out[j * pitch + i],
                        seed_value("a", i * pitch + j),
                        "{variant:?} {gx}x{gy} at ({i},{j})"
                    );
                }
            }
        }
    }
}

#[test]
fn vsadd_and_global_access_all_lsizes() {
    for lsize in [128i64, 192, 224, 256, 384, 512] {
        for s in 1..=3i64 {
            let k = vsadd(s, lsize);
            let e = env(&[("nt", lsize)]);
            let st = execute(&k, &e).unwrap();
            let out = st.get("out").unwrap();
            let (s1, s2) = (seed_value("s1", 0), seed_value("s2", 0));
            for i in 0..lsize as usize {
                let idx = s as usize * i;
                let want = s1 * seed_value("x", idx) + s2 * seed_value("y", idx);
                assert!((out[idx] - want).abs() < 1e-12, "vsadd s={s} l={lsize}");
            }
        }
        for cfg in [
            GlobalAccessConfig::Copy,
            GlobalAccessConfig::Add4,
            GlobalAccessConfig::StoreIndex,
            GlobalAccessConfig::StoreUniform,
        ] {
            let k = global_access(cfg, lsize);
            let e = env(&[("n", 2 * lsize)]);
            execute(&k, &e).unwrap_or_else(|err| panic!("{cfg:?} l={lsize}: {err}"));
        }
    }
}

#[test]
fn filled_and_arith_classes() {
    for lsize in [128i64, 256] {
        for s in [2i64, 3] {
            let k = filled(s, lsize);
            let st = execute(&k, &env(&[("nt", lsize)])).unwrap();
            let out = st.get("out").unwrap();
            for i in 0..lsize as usize {
                let tuple: f64 =
                    (0..s as usize).map(|c| seed_value("x", c + s as usize * i)).sum();
                assert!((out[i] - 256.0 * tuple).abs() < 1e-9, "filled s={s}");
            }
        }
    }
    for ty in ArithType::all() {
        let k = arith(ty, 16, 16);
        let st = execute(&k, &env(&[("n", 16), ("k", 32)])).unwrap();
        assert!(st.get("out").unwrap().iter().all(|x| x.is_finite()), "{ty:?}");
    }
}

#[test]
fn test_kernels_all_device_group_configs() {
    // fd across the three 256-thread shapes used by §5 configs
    for (gx, gy) in [(16, 16), (16, 16), (16, 16)] {
        let k = fd_stencil(gx, gy);
        let n = 2 * gx.max(gy);
        let st = execute(&k, &env(&[("n", n)])).unwrap();
        let want = fd_reference(n as usize);
        let out = st.get("out").unwrap();
        for i in 0..want.len() {
            assert!((out[i] - want[i]).abs() < 1e-9, "fd {gx}x{gy} i={i}");
        }
    }
    // conv at the small end
    let k = convolution(16, 16);
    let n = 16usize;
    let st = execute(&k, &env(&[("n", n as i64)])).unwrap();
    let want = conv_reference(n);
    let out = st.get("out").unwrap();
    for i in 0..want.len() {
        assert!((out[i] - want[i]).abs() < 1e-9, "conv i={i}");
    }
    // nbody across 1-D lane sizes
    for lsize in [192i64, 256] {
        let k = nbody(lsize);
        let n = 2 * lsize;
        let st = execute(&k, &env(&[("n", n)])).unwrap();
        let want = nbody_reference(n as usize);
        let out = st.get("out").unwrap();
        for i in 0..n as usize {
            assert!(
                (out[i] - want[i]).abs() / want[i] < 1e-10,
                "nbody l={lsize} i={i}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Numeric-conformance sweep for the zoo kernels: execute at small sizes
// across every group shape their configuration tables use and compare
// elementwise against the scalar references (the mm_tiled pattern above).
// ---------------------------------------------------------------------------

#[test]
fn reduce_tree_all_group_shapes() {
    for lsize in SHAPES_1D {
        let k = reduce_tree(lsize);
        let n = 3 * lsize;
        let st = execute(&k, &env(&[("n", n)])).unwrap_or_else(|e| panic!("l={lsize}: {e}"));
        let out = st.get("rout").unwrap();
        let want = reduce_reference(n as usize, lsize as usize);
        for (g, w) in want.iter().enumerate() {
            assert!(
                (out[g] - w).abs() < 1e-9,
                "reduce_tree l={lsize} group {g}: {} vs {w}",
                out[g]
            );
        }
    }
}

#[test]
fn scan_all_group_shapes() {
    for lsize in SHAPES_1D {
        let k = scan_hs(lsize);
        let n = 2 * lsize;
        let st = execute(&k, &env(&[("n", n)])).unwrap_or_else(|e| panic!("l={lsize}: {e}"));
        let out = st.get("sout").unwrap();
        let want = scan_reference(n as usize, lsize as usize);
        for i in 0..n as usize {
            assert!(
                (out[i] - want[i]).abs() < 1e-9,
                "scan_hs l={lsize} i={i}: {} vs {}",
                out[i],
                want[i]
            );
        }
    }
}

#[test]
fn stencil3d_all_group_shapes() {
    for (gx, gy) in SHAPES_2D {
        let k = stencil3d(gx, gy);
        // smallest size divisible by both group extents
        let n = gx * gy / gcd(gx, gy);
        let st = execute(&k, &env(&[("n", n)])).unwrap_or_else(|e| panic!("{gx}x{gy}: {e}"));
        let out = st.get("o3").unwrap();
        let want = stencil3d_reference(n as usize);
        for i in 0..want.len() {
            assert!((out[i] - want[i]).abs() < 1e-9, "st3d7 {gx}x{gy} i={i}");
        }
    }
}

#[test]
fn bmm_all_group_shapes() {
    for lsize in SHAPES_1D {
        let k = bmm(lsize);
        let nb = 2 * lsize;
        let st = execute(&k, &env(&[("nb", nb)])).unwrap_or_else(|e| panic!("l={lsize}: {e}"));
        let out = st.get("bc").unwrap();
        let want = bmm_reference(nb as usize);
        for i in 0..want.len() {
            assert!((out[i] - want[i]).abs() < 1e-9, "bmm8 l={lsize} i={i}");
        }
    }
}

#[test]
fn gather_all_group_shapes() {
    for lsize in SHAPES_1D {
        let k = gather_strided(lsize);
        let n = 2 * lsize;
        let st = execute(&k, &env(&[("n", n)])).unwrap_or_else(|e| panic!("l={lsize}: {e}"));
        let out = st.get("ey").unwrap();
        let want = gather_reference(n as usize);
        for i in 0..n as usize {
            assert!((out[i] - want[i]).abs() < 1e-9, "gather_s2 l={lsize} i={i}");
        }
    }
}

#[test]
fn mm_naive_matches_tiled() {
    let e_naive = env(&[("n", 32)]);
    let st1 = execute(&mm_naive(16, 16), &e_naive).unwrap();
    let e_tiled = env(&[("n", 32), ("m", 32), ("l", 32)]);
    let st2 = execute(&mm_tiled(16, 16), &e_tiled).unwrap();
    let (c1, c2) = (st1.get("cc").unwrap(), st2.get("cc").unwrap());
    for i in 0..32 * 32 {
        assert!((c1[i] - c2[i]).abs() < 1e-9, "naive vs tiled at {i}");
    }
}

fn gcd(a: i64, b: i64) -> i64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}
