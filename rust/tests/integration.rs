//! Cross-module integration tests: the full pipeline, backend agreement,
//! persistence round trips, and the paper's qualitative claims.

use uniperf::coordinator::{run_device, run_pipeline, Config, FitBackend};
use uniperf::gpusim::SimGpu;
use uniperf::harness::{campaign_from_json, campaign_to_json, run_campaign, Protocol};
use uniperf::perfmodel::{fit, Model, NativeSolver, Solver};
use uniperf::report::{Table1, Table1Entry};
use uniperf::runtime::XlaSolver;
use uniperf::stats::{ExtractOpts, Schema};
use uniperf::util::json::Json;

fn workers() -> usize {
    uniperf::util::executor::default_workers()
}

#[test]
fn full_pipeline_two_devices_reproduces_error_structure() {
    let cfg = Config {
        devices: vec!["k40c".into(), "r9_fury".into()],
        backend: FitBackend::Native,
        ..Config::default()
    };
    let result = run_pipeline(&cfg).expect("pipeline");
    assert_eq!(result.per_device.len(), 2);
    let t1 = &result.table1;
    // 2 devices x 4 kernels x 4 cases
    assert_eq!(t1.entries.len(), 32);
    // the regular device fits better than the irregular one (paper §5)
    let k40 = t1.device_err("k40c");
    let fury = t1.device_err("r9_fury");
    assert!(k40 < fury, "k40c {k40} should beat r9_fury {fury}");
    // overall error in a plausible band (paper: 0.11 overall)
    assert!(t1.overall_err() < 0.40, "overall {}", t1.overall_err());
}

#[test]
fn campaign_persists_and_refits_identically() {
    let gpu = SimGpu::named("c2070").unwrap();
    let schema = Schema::full();
    // a cut-down campaign for speed: one class
    let cases: Vec<_> = uniperf::kernels::measurement_suite(&gpu.profile)
        .into_iter()
        .filter(|c| c.label.starts_with("sg_") || c.label.starts_with("empty/"))
        .collect();
    let (pm, overhead) = run_campaign(
        &gpu,
        &cases,
        &schema,
        &Protocol::default(),
        ExtractOpts::default(),
        workers(),
    )
    .expect("campaign");
    let j = campaign_to_json(&pm, "c2070", overhead);
    let (pm2, dev, ovh) = campaign_from_json(&Json::parse(&j.pretty()).unwrap()).unwrap();
    assert_eq!(dev, "c2070");
    assert_eq!(ovh, overhead);
    let m1 = fit("c2070", &pm, &schema, &NativeSolver::new()).unwrap();
    let m2 = fit("c2070", &pm2, &schema, &NativeSolver::new()).unwrap();
    assert_eq!(m1.weights, m2.weights);
}

#[test]
fn model_json_file_roundtrip() {
    let schema = Schema::full();
    let gpu = SimGpu::named("titan_x").unwrap();
    let cases: Vec<_> = uniperf::kernels::measurement_suite(&gpu.profile)
        .into_iter()
        .filter(|c| c.label.starts_with("sg_") || c.label.starts_with("empty/"))
        .collect();
    let (pm, _) = run_campaign(
        &gpu,
        &cases,
        &schema,
        &Protocol::default(),
        ExtractOpts::default(),
        workers(),
    )
    .unwrap();
    let model = fit("titan_x", &pm, &schema, &NativeSolver::new()).unwrap();
    let dir = std::env::temp_dir().join("uniperf_test_model");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.json");
    std::fs::write(&path, model.to_json(&schema).pretty()).unwrap();
    let loaded =
        Model::from_json(&Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap(), &schema)
            .unwrap();
    for c in &pm.cases {
        assert!((model.predict(&c.props) - loaded.predict(&c.props)).abs() < 1e-18);
    }
}

#[test]
fn xla_and_native_solvers_agree_on_campaign_data() {
    let Ok(xla) = XlaSolver::from_artifacts() else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    let gpu = SimGpu::named("k40c").unwrap();
    let schema = Schema::full();
    let cases = uniperf::kernels::measurement_suite(&gpu.profile);
    let (pm, _) = run_campaign(
        &gpu,
        &cases,
        &schema,
        &Protocol::default(),
        ExtractOpts::default(),
        workers(),
    )
    .unwrap();
    let mn = fit("k40c", &pm, &schema, &NativeSolver::new()).unwrap();
    let mx = fit("k40c", &pm, &schema, &xla).unwrap();
    // same predictions to floating-point agreement on every training case
    for c in &pm.cases {
        let (a, b) = (mn.predict(&c.props), mx.predict(&c.props));
        assert!(
            (a - b).abs() / a.abs().max(1e-12) < 1e-6,
            "{}: native {a} vs xla {b}",
            c.label
        );
    }
}

#[test]
fn run_device_writes_results_dir() {
    let out = std::env::temp_dir().join("uniperf_test_results");
    let _ = std::fs::remove_dir_all(&out);
    let cfg = Config {
        devices: vec!["c2070".into()],
        backend: FitBackend::Native,
        out_dir: Some(out.clone()),
        ..Config::default()
    };
    let schema = Schema::full();
    run_device("c2070", &schema, &cfg).unwrap();
    assert!(out.join("campaign_c2070.json").exists());
    assert!(out.join("model_c2070.json").exists());
}

#[test]
fn table1_render_is_stable_shape() {
    let mut t = Table1::default();
    for dev in ["titan_x", "k40c"] {
        for k in ["fd5", "nbody"] {
            for (i, case) in ["a", "b", "c", "d"].iter().enumerate() {
                t.push(Table1Entry {
                    device: dev.into(),
                    kernel: k.into(),
                    case: case.to_string(),
                    predicted_s: 1e-3 * (i + 1) as f64,
                    actual_s: 1.1e-3 * (i + 1) as f64,
                });
            }
        }
    }
    let r = t.render();
    assert_eq!(r.matches("a.").count(), 2); // one per kernel
    assert!(t.overall_err() > 0.0 && t.overall_err() < 0.2);
}

#[test]
fn unknown_device_is_an_error() {
    let cfg = Config {
        devices: vec!["gtx480".into()],
        backend: FitBackend::Native,
        ..Config::default()
    };
    assert!(run_pipeline(&cfg).is_err());
}

#[test]
fn xla_solver_name_reported_in_model() {
    let Ok(xla) = XlaSolver::from_artifacts() else {
        return;
    };
    assert_eq!(xla.name(), "xla-pallas-aot");
}
