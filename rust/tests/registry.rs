//! Integration tests for the data-driven device registry: profile JSON
//! round-trips (property-tested), capability-derived suite validity on
//! every registry device, and the acceptance path — a profile loaded
//! from JSON running the full pipeline end to end with derived kernel
//! configurations.

use uniperf::coordinator::{run_device, Config, FitBackend};
use uniperf::gpusim::{registry, DeviceProfile, DeviceRegistry, SimGpu};
use uniperf::kernels;
use uniperf::prop_assert;
use uniperf::stats::Schema;
use uniperf::util::json::Json;
use uniperf::util::prop::{check, gen_f64, Config as PropConfig};

/// Randomize a profile's numeric fields around a base profile,
/// keeping it valid (positive rates, legal group cap).
fn random_profile(rng: &mut uniperf::util::rng::Rng, idx: u32) -> DeviceProfile {
    let names = registry::builtins().names();
    let pick = rng.range_u64(0, names.len() as u64) as usize;
    let base = registry::builtins().get(&names[pick]).unwrap().clone();
    let mut p = DeviceProfile {
        name: format!("rand_{idx}"),
        full_name: format!("Randomized {}", base.full_name),
        sms: rng.range_u64(1, 200) as u32,
        clock_hz: gen_f64(rng, 0.3e9, 3.0e9),
        cores_per_sm: rng.range_u64(8, 256) as u32,
        warp_size: [8u32, 16, 32, 64][rng.range_u64(0, 4) as usize],
        dram_bw: gen_f64(rng, 5e9, 2e12),
        line_bytes: [32u32, 64, 128][rng.range_u64(0, 3) as usize],
        l2_bytes: rng.range_u64(1, 256) * (1 << 18),
        l1_bytes: rng.range_u64(1, 16) * (8 << 10),
        l2_bw_mult: gen_f64(rng, 1.5, 5.0),
        local_bw: gen_f64(rng, 1e11, 5e13),
        cyc_mad: 1.0,
        cyc_div: gen_f64(rng, 4.0, 20.0),
        cyc_exp: gen_f64(rng, 8.0, 30.0),
        cyc_special: gen_f64(rng, 2.0, 12.0),
        f64_ratio: gen_f64(rng, 2.0, 64.0),
        cyc_barrier: gen_f64(rng, 16.0, 64.0),
        launch_base: gen_f64(rng, 1e-6, 6e-5),
        launch_per_group: gen_f64(rng, 5e-10, 1e-8),
        max_groups_per_sm: rng.range_u64(4, 64) as u32,
        max_group_size: 16 * rng.range_u64(4, 65) as u32, // 64..=1024
        threads_per_sm: 2048,
        wave_latency: gen_f64(rng, 1e-6, 1e-5),
        overlap: gen_f64(rng, 0.0, 1.0),
        noise_sigma: gen_f64(rng, 0.005, 0.05),
        first_touch_factor: gen_f64(rng, 1.2, 3.0),
        second_run_sigma: gen_f64(rng, 0.02, 0.2),
        irregularity: gen_f64(rng, 0.0, 0.5),
        uncoalesced_penalty: gen_f64(rng, 1.0, 2.0),
        size_exp: std::collections::BTreeMap::new(),
    };
    // half the profiles opt into a per-class size-exponent override, so
    // the round-trip property covers the optional table too
    if rng.range_u64(0, 2) == 1 {
        let classes = uniperf::gpusim::device::SIZE_EXP_CLASSES;
        let class = classes[rng.range_u64(0, classes.len() as u64) as usize];
        p.size_exp.insert(class.to_string(), rng.range_u64(1, 27) as i64);
    }
    p
}

#[test]
fn device_profile_json_roundtrip_property() {
    let mut idx = 0u32;
    check("profile_json_roundtrip", PropConfig { cases: 64, seed: 0xDE71CE }, |rng| {
        idx += 1;
        let p = random_profile(rng, idx);
        prop_assert!(p.validate().is_ok(), "{}: generated profile invalid", p.name);
        let text = p.to_json().pretty();
        let back = DeviceProfile::from_json(
            &Json::parse(&text).map_err(|e| format!("parse: {e}"))?,
        )
        .map_err(|e| format!("from_json: {e}"))?;
        prop_assert!(back == p, "{}: round-trip mismatch", p.name);
        // compact form round-trips too
        let back2 = DeviceProfile::from_json(
            &Json::parse(&p.to_json().compact()).map_err(|e| format!("parse: {e}"))?,
        )
        .map_err(|e| format!("from_json: {e}"))?;
        prop_assert!(back2 == p, "{}: compact round-trip mismatch", p.name);
        Ok(())
    });
}

/// Every registry device — including the synthetic parts — gets a valid
/// capability-derived campaign and evaluation suite: group shapes
/// respect the device's cap, labels are unique, and every evaluation
/// case (whose smallest size must itself be measurable) simulates well
/// above the launch-overhead floor.
#[test]
fn capability_derived_suites_valid_on_every_registry_device() {
    for profile in registry::builtins().iter() {
        let cap = profile.max_group_size as i64;
        let campaign = kernels::measurement_suite(profile);
        let mut labels: Vec<&String> = campaign.iter().map(|c| &c.label).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), campaign.len(), "{}: duplicate labels", profile.name);
        for case in &campaign {
            assert!(
                case.group.0 * case.group.1 <= cap,
                "{}: campaign case {} exceeds the group cap",
                profile.name,
                case.label
            );
        }

        let gpu = SimGpu::new(profile.clone());
        let floor = profile.launch_floor_s();
        for case in kernels::eval_suite(profile) {
            assert!(case.group.0 * case.group.1 <= cap, "{}: {}", profile.name, case.label);
            let bd = gpu
                .breakdown(&case.kernel, &case.env)
                .unwrap_or_else(|e| panic!("{}: {}: {e}", profile.name, case.label));
            assert!(
                bd.total >= 1.3 * floor,
                "{}: {} runs at {:.1} µs, under 1.3x the {:.1} µs launch floor",
                profile.name,
                case.label,
                bd.total * 1e6,
                floor * 1e6
            );
        }
    }
}

/// The acceptance path: a device that exists only in a JSON file —
/// with a group-size cap (128) no built-in has — is registered via the
/// registry extension hook and runs the full pipeline end to end on
/// purely capability-derived kernel configurations.
#[test]
fn json_loaded_profile_runs_pipeline_end_to_end() {
    let custom = r#"{"devices": [{
        "name": "jsonpart",
        "full_name": "JSON-defined test part",
        "sms": 10, "clock_hz": 9.0e8, "cores_per_sm": 64, "warp_size": 32,
        "dram_bw": 8.0e10, "line_bytes": 64,
        "l2_bytes": 1048576, "l1_bytes": 32768, "local_bw": 5.0e11,
        "launch_base": 1.2e-5, "launch_per_group": 3.0e-9,
        "threads_per_sm": 1024, "max_groups_per_sm": 12,
        "max_group_size": 128
    }]}"#;
    let mut reg = DeviceRegistry::with_builtins();
    let loaded = reg.extend_from_json(&Json::parse(custom).unwrap()).unwrap();
    assert_eq!(loaded, vec!["jsonpart".to_string()]);

    let profile = reg.get("jsonpart").unwrap();
    // capability derivation copes with the 128-thread cap: every shape
    // fits, and the standard shape uses the full 128 threads
    let suite = kernels::eval_suite(profile);
    assert_eq!(suite.len(), 36);
    for case in &suite {
        assert!(case.group.0 * case.group.1 <= 128, "{}", case.label);
    }

    let cfg = Config {
        devices: vec!["jsonpart".into()],
        registry: reg,
        backend: FitBackend::Native,
        ..Config::default()
    };
    let schema = Schema::full();
    let dr = run_device("jsonpart", &schema, &cfg).expect("JSON device pipeline");
    assert_eq!(dr.tests.len(), 16);
    assert!(dr.launch_overhead_s > 0.0);
    assert!(dr.n_measurement_cases > 100, "{}", dr.n_measurement_cases);
    for (k, c, pred, act) in &dr.tests {
        assert!(pred.is_finite() && *act > 0.0, "{k}/{c}: pred={pred} act={act}");
    }
    // the fit is a real model, not a degenerate one
    assert!(
        dr.model.train_rel_err_geomean < 0.5,
        "train geomean {}",
        dr.model.train_rel_err_geomean
    );
}

/// An unregistered device stays an error even with a custom registry.
#[test]
fn unknown_device_rejected_through_registry() {
    let cfg = Config { backend: FitBackend::Native, ..Config::default() };
    let schema = Schema::full();
    assert!(run_device("gtx480", &schema, &cfg).is_err());
}
