//! Chaos tests: the serving stack under deterministic fault injection.
//!
//! A [`FaultPlan`] is seeded and counter-based, so every test here pins
//! *exact* accounting — "2 connections aborted, 1 reload failed, every
//! request answered exactly once" — instead of "roughly no crashes".
//! The fault classes exercised across this file:
//!
//! * **measurement** (`measure.fail`, `measure.outlier`) — campaigns
//!   retry, quarantine and fall back instead of aborting, and two runs
//!   under the same plan are byte-identical;
//! * **reload I/O** (`reload.io`) — a hot-reload poll that fails keeps
//!   the old store serving and surfaces the error on the health page;
//! * **connection** (`conn.abort`, `conn.slow`) — dropped and delayed
//!   TCP connections; resilient clients recover, the drain stays
//!   deterministic, and request accounting is conserved.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use uniperf::coordinator::{run_device, Config, FitBackend};
use uniperf::engine::Engine;
use uniperf::gpusim::registry::builtins;
use uniperf::harness::Protocol;
use uniperf::perfmodel::Model;
use uniperf::service::{tcp, ModelStore, Service, ServiceConfig, StoredModel};
use uniperf::stats::{ExtractOpts, Schema};
use uniperf::util::fault::FaultPlan;
use uniperf::util::json::Json;

fn temp_path(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("uniperf_chaos_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create temp dir");
    dir.join(name)
}

/// A k40c-only store whose two active weights are scaled by `scale` —
/// predictions scale exactly with it (power-of-two scales stay
/// bit-exact), which is what lets the reload assertions be `==`.
fn toy_store_k40c(scale: f64) -> ModelStore {
    let schema = Schema::full();
    let mut weights = vec![0.0; schema.len()];
    weights[schema.len() - 2] = 2e-9 * scale;
    weights[schema.len() - 1] = 5e-6 * scale;
    let model = Model {
        device: "k40c".into(),
        weights,
        active: vec![schema.len() - 2, schema.len() - 1],
        train_rel_err_geomean: 0.1,
        solver: "native-cholesky",
    };
    let mut store = ModelStore::new(&schema, ExtractOpts::default());
    store.insert(StoredModel::new(model, 8e-6, 400, builtins().get("k40c").unwrap()));
    store
}

/// A TCP client that survives chaos: when the server aborts the
/// connection before answering (the `conn.abort` site), reconnect and
/// resend the current line. Aborts always happen before a single byte
/// is served, so no line is ever answered twice.
fn resilient_client(addr: std::net::SocketAddr, lines: &[String]) -> Vec<String> {
    let connect = || {
        let stream = TcpStream::connect(addr).expect("connect");
        let reader = BufReader::new(stream.try_clone().expect("clone"));
        (stream, reader)
    };
    let (mut stream, mut reader) = connect();
    let mut out = Vec::new();
    for line in lines {
        let mut attempts = 0;
        loop {
            attempts += 1;
            assert!(attempts <= 10, "line never served after 10 attempts: {line}");
            let sent = writeln!(stream, "{line}").and_then(|_| stream.flush());
            if sent.is_err() {
                (stream, reader) = connect();
                continue;
            }
            let mut resp = String::new();
            match reader.read_line(&mut resp) {
                Ok(0) | Err(_) => {
                    // server dropped the connection unanswered; retry
                    (stream, reader) = connect();
                }
                Ok(_) => {
                    out.push(resp.trim_end().to_string());
                    break;
                }
            }
        }
    }
    out
}

/// The `--faults` file path: a plan loaded twice from disk replays the
/// same injection stream as the builder under the same seed, and its
/// counters surface on `counters_json`.
#[test]
fn fault_plans_load_from_files_and_replay_identically() {
    let path = temp_path("plan.json");
    std::fs::write(
        &path,
        r#"{"seed": 77, "sites": {"measure.fail": {"rate": 0.3},
             "conn.abort": {"rate": 1.0, "max": 2}}}"#,
    )
    .expect("write plan");
    let a = FaultPlan::load(&path).expect("load plan");
    let b = FaultPlan::load(&path).expect("load plan again");
    assert_eq!(a.seed(), 77);
    let sa: Vec<bool> = (0..256).map(|_| a.should_inject("measure.fail")).collect();
    let sb: Vec<bool> = (0..256).map(|_| b.should_inject("measure.fail")).collect();
    assert_eq!(sa, sb, "file-loaded plans must replay identically");
    let builder = FaultPlan::new(77).site("measure.fail", 0.3);
    let sc: Vec<bool> = (0..256).map(|_| builder.should_inject("measure.fail")).collect();
    assert_eq!(sa, sc, "the file path and the builder must share one stream");

    assert_eq!((0..8).filter(|_| a.should_inject("conn.abort")).count(), 2);
    let j = a.counters_json();
    assert_eq!(j.get("seed").and_then(Json::as_f64), Some(77.0));
    assert_eq!(
        j.get("conn.abort").and_then(|s| s.get_f64("injected")),
        Some(2.0)
    );
}

/// The measurement fault class: a campaign whose launch-overhead
/// calibration is killed by `measure.fail` falls back to the
/// zero-overhead default with a warning, the next case to exhaust its
/// retry budget is quarantined (not fatal), spurious `measure.outlier`
/// samples are absorbed by MAD rejection — and the whole degraded run
/// is byte-for-byte reproducible under the same plan.
#[test]
fn faulty_campaigns_degrade_gracefully_and_reproduce_exactly() {
    // workers: 1 pins the fault-counter order; retries: 2 means 3
    // attempts per timing call, so max: 6 kills exactly calibration
    // (attempts 1-3) and the first measured case (attempts 4-6)
    let run = || {
        let cfg = Config {
            devices: vec!["k40c".into()],
            backend: FitBackend::Native,
            protocol: Protocol { runs: 5, discard: 1, retries: 2, mad_k: 3.5, ..Protocol::default() },
            workers: 1,
            faults: Some(Arc::new(
                FaultPlan::new(42)
                    .site_max("measure.fail", 1.0, 6)
                    .site("measure.outlier", 0.05),
            )),
            ..Config::default()
        };
        run_device("k40c", &Schema::full(), &cfg).expect("faulty campaign must still fit")
    };
    let a = run();
    assert!(
        a.warnings.iter().any(|w| w.contains("calibration failed")),
        "zero-overhead fallback must be reported: {:?}",
        a.warnings
    );
    assert_eq!(a.launch_overhead_s, 0.0, "calibration failure falls back to zero");
    assert_eq!(a.quarantined.len(), 1, "exactly one case exhausts the retry budget");
    assert!(
        a.quarantined[0].1.contains("measure.fail"),
        "quarantine reason names the injected fault: {}",
        a.quarantined[0].1
    );

    let b = run();
    let schema = Schema::full();
    assert_eq!(
        a.model.to_json(&schema).pretty(),
        b.model.to_json(&schema).pretty(),
        "same plan, same seed -> byte-identical fitted model"
    );
    assert_eq!(a.warnings, b.warnings);
    assert_eq!(a.quarantined, b.quarantined);
    assert_eq!(a.tests, b.tests, "test-kernel predictions must reproduce exactly");
}

/// The flagship: a threaded TCP server under a multi-class fault plan
/// (connection aborts, connection slowdowns, a reload I/O failure)
/// with degraded-mode prediction on. Pins: no panic, every request
/// line answered exactly once with well-formed JSON, conserved
/// accounting (requests/errors/aborts/slowdowns/degraded all exact),
/// the bad reload kept the old weights serving and surfaced on the
/// health page, and the drain is deterministic.
#[test]
fn threaded_server_survives_multi_class_fault_plan() {
    let schema = Schema::full();
    let path = temp_path("chaos_models.json");
    toy_store_k40c(1.0).save(&path, &schema).expect("save v1");

    let plan = Arc::new(
        FaultPlan::new(7)
            .site_max("conn.abort", 1.0, 2)
            .site_max("conn.slow", 1.0, 2)
            .site_max("reload.io", 1.0, 1),
    );
    let engine = Engine::new(Config {
        registry: builtins().clone(),
        workers: 2,
        degraded: true,
        faults: Some(plan.clone()),
        ..Config::default()
    });
    engine
        .install_store(ModelStore::load(&path, &schema).expect("load v1"))
        .expect("install v1");
    let mut svc = Service::over(
        Arc::new(engine),
        ServiceConfig { workers: 2, ..ServiceConfig::default() },
    )
    .expect("service");
    svc.watch(&path);
    let svc = Arc::new(svc);

    let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind");
    let addr = listener.local_addr().expect("addr");
    let server = {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || tcp::serve_threaded(&svc, listener, 8).expect("serve"))
    };

    // phase 1: one resilient client through the abort gauntlet — its
    // first two connections die unanswered (conn.abort max 2), the
    // third is delayed (conn.slow) and then serves everything:
    // 3 k40c predictions, 1 degraded titan_x prediction, 1 garbage line
    let lines: Vec<String> = vec![
        r#"{"id": 0, "device": "k40c", "kernel": "fd5", "case": "a"}"#.into(),
        r#"{"id": 1, "device": "k40c", "kernel": "fd5", "case": "a"}"#.into(),
        r#"{"id": 2, "device": "k40c", "kernel": "fd5", "case": "a"}"#.into(),
        r#"{"id": 3, "device": "titan_x", "kernel": "fd5", "case": "a"}"#.into(),
        r#"this is not json"#.into(),
    ];
    let responses = resilient_client(addr, &lines);
    assert_eq!(responses.len(), lines.len(), "every line answered exactly once");
    let parsed: Vec<Json> = responses
        .iter()
        .map(|r| Json::parse(r).unwrap_or_else(|e| panic!("malformed response {r}: {e}")))
        .collect();
    let p1 = parsed[0].get_f64("predicted_s").expect("prediction");
    for (i, j) in parsed.iter().take(3).enumerate() {
        assert!(j.get("error").is_none(), "{j}");
        assert_eq!(j.get_f64("id"), Some(i as f64));
        assert_eq!(j.get_f64("predicted_s"), Some(p1), "deterministic predictions");
    }
    assert_eq!(parsed[3].get("degraded"), Some(&Json::Bool(true)), "{}", parsed[3]);
    assert_eq!(parsed[3].get_str("served_by"), Some("k40c"), "{}", parsed[3]);
    assert_eq!(parsed[3].get_f64("id"), Some(3.0));
    assert!(parsed[4].get_str("error").is_some(), "garbage must answer an error");
    assert_eq!(plan.injected("conn.abort"), 2, "both aborts spent in phase 1");

    // phase 2: rewrite the artifact; the first reload poll hits the
    // injected I/O failure and the OLD weights keep serving
    toy_store_k40c(2.0).save(&path, &schema).expect("save v2");
    let e = svc
        .poll_reload()
        .expect("watching")
        .expect_err("first poll after the rewrite must hit reload.io");
    assert!(e.contains("reload.io"), "{e}");
    let r = resilient_client(addr, &[lines[0].clone()]);
    let j = Json::parse(&r[0]).expect("well-formed");
    assert_eq!(j.get_f64("predicted_s"), Some(p1), "old store must keep serving: {j}");

    // the health surface reports the suppressed reload error
    let h = resilient_client(addr, &[r#"{"cmd": "health", "id": "h1"}"#.into()]);
    let h1 = Json::parse(&h[0]).expect("health JSON");
    assert_eq!(h1.get_str("ok"), Some("health"));
    assert_eq!(h1.get_str("id"), Some("h1"));
    let reloader = h1.get("reloader").expect("reloader section");
    assert_eq!(reloader.get("watching"), Some(&Json::Bool(true)));
    assert!(
        reloader.get_str("last_error").is_some_and(|e| e.contains("reload.io")),
        "health must surface the suppressed reload failure: {h1}"
    );
    let faults = h1.get("faults").expect("fault counters");
    assert_eq!(
        faults.get("conn.abort").and_then(|s| s.get_f64("injected")),
        Some(2.0)
    );
    assert_eq!(
        faults.get("reload.io").and_then(|s| s.get_f64("injected")),
        Some(1.0)
    );

    // phase 3: a further rewrite reloads cleanly (reload.io max: 1 is
    // spent) and the new weights serve — scaled by exactly 4
    toy_store_k40c(4.0).save(&path, &schema).expect("save v3");
    assert_eq!(svc.poll_reload(), Some(Ok(true)), "second rewrite must swap in");
    let r = resilient_client(addr, &[lines[0].clone()]);
    let j = Json::parse(&r[0]).expect("well-formed");
    assert_eq!(j.get_f64("predicted_s"), Some(4.0 * p1), "reloaded weights: {j}");
    let h = resilient_client(addr, &[r#"{"cmd": "health"}"#.into()]);
    let h2 = Json::parse(&h[0]).expect("health JSON");
    assert_eq!(
        h2.get("reloader").and_then(|r| r.get("last_error")),
        Some(&Json::Null),
        "a successful swap clears the health error: {h2}"
    );

    // deterministic drain
    let bye = resilient_client(addr, &[r#"{"cmd": "shutdown"}"#.into()]);
    assert_eq!(Json::parse(&bye[0]).expect("bye").get_str("ok"), Some("shutdown"));
    let summary = server.join().expect("server thread must not panic");

    // conserved accounting: 5 phase-1 lines + 1 old-weights check +
    // health + 1 new-weights check + health + shutdown = 10 requests,
    // of which exactly the garbage line errored
    assert_eq!(summary.requests, 10);
    assert_eq!(summary.errors, 1);
    assert_eq!(summary.degraded_served, 1);
    assert_eq!(summary.conn_aborted, 2);
    assert_eq!(summary.conn_slowed, 2);
    assert_eq!(summary.shed, 0);
    assert_eq!(summary.deadline_expired, 0);
    // every successful prediction either hit or missed the cache
    assert_eq!(summary.cache_hits + summary.cache_misses, 6);
    assert_eq!(summary.cache_evictions, 0);
}

/// Deadlines and the health/stats surface over real sockets, no faults:
/// a zero budget always expires with `"reason": "deadline"`, health
/// reports the store fingerprint and cache counters, stats embeds the
/// full summary — and the error accounting distinguishes all of them.
#[test]
fn deadlines_and_health_are_honored_over_tcp() {
    let svc = Arc::new(
        Service::new(
            toy_store_k40c(1.0),
            builtins().clone(),
            ServiceConfig { workers: 1, ..ServiceConfig::default() },
        )
        .expect("service"),
    );
    let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind");
    let addr = listener.local_addr().expect("addr");
    let server = {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || tcp::serve_threaded(&svc, listener, 8).expect("serve"))
    };

    let lines: Vec<String> = vec![
        r#"{"id": 0, "device": "k40c", "kernel": "fd5", "case": "a"}"#.into(),
        r#"{"id": 1, "device": "k40c", "kernel": "fd5", "case": "a", "deadline_ms": 0}"#.into(),
        r#"{"id": 2, "cmd": "health"}"#.into(),
        r#"{"id": 3, "cmd": "stats"}"#.into(),
        r#"{"id": 4, "device": "k40c", "kernel": "no_such_kernel"}"#.into(),
    ];
    let responses = resilient_client(addr, &lines);
    assert_eq!(responses.len(), lines.len());
    let parsed: Vec<Json> = responses
        .iter()
        .map(|r| Json::parse(r).unwrap_or_else(|e| panic!("malformed response {r}: {e}")))
        .collect();

    assert!(parsed[0].get("error").is_none(), "{}", parsed[0]);

    assert_eq!(parsed[1].get_str("reason"), Some("deadline"), "{}", parsed[1]);
    assert!(
        parsed[1].get_str("error").is_some_and(|e| e.contains("deadline exceeded")),
        "{}",
        parsed[1]
    );
    assert_eq!(parsed[1].get_f64("id"), Some(1.0));
    assert!(parsed[1].get("predicted_s").is_none(), "an expired request must not predict");

    let health = &parsed[2];
    assert_eq!(health.get_str("ok"), Some("health"));
    assert_eq!(
        health.get("store").and_then(|s| s.get_str("fingerprint")),
        Some(svc.store().fingerprint().as_str()),
        "{health}"
    );
    assert_eq!(
        health.get("cache").and_then(|c| c.get_f64("misses")),
        Some(1.0),
        "one extraction so far: {health}"
    );
    assert_eq!(health.get("faults"), Some(&Json::Null), "no plan installed");

    let stats = &parsed[3];
    assert_eq!(stats.get_str("ok"), Some("stats"));
    let sum = stats.get("summary").expect("summary");
    // the stats request counts itself: predict + deadline + health + stats
    assert_eq!(sum.get_f64("requests"), Some(4.0), "{stats}");
    assert_eq!(sum.get_f64("deadline_expired"), Some(1.0), "{stats}");

    assert!(parsed[4].get_str("error").is_some_and(|e| e.contains("unknown kernel")));

    let bye = resilient_client(addr, &[r#"{"cmd": "shutdown"}"#.into()]);
    assert_eq!(Json::parse(&bye[0]).expect("bye").get_str("ok"), Some("shutdown"));
    let summary = server.join().expect("server thread");
    assert_eq!(summary.requests, 6);
    assert_eq!(summary.errors, 2, "the expired deadline and the unknown kernel");
    assert_eq!(summary.deadline_expired, 1);
    assert_eq!(summary.conn_aborted, 0);
}
