//! Integration tests for the cross-validation subsystem and the PR-1
//! reproducibility invariants: held-out evaluation over the expanded
//! kernel zoo, the `eval_zoo` pipeline flag, and golden determinism of
//! campaign → fit → report under reruns and JSON persistence.

use uniperf::coordinator::{run_device, Config, FitBackend};
use uniperf::crossval::{quick_campaign_case, run_crossval, CrossvalOpts, Split};
use uniperf::gpusim::SimGpu;
use uniperf::harness::{campaign_from_json, campaign_to_json, measure_cases, run_campaign, Protocol};
use uniperf::perfmodel::{fit, NativeSolver};
use uniperf::report::{Table1, Table1Entry};
use uniperf::stats::{ExtractOpts, Schema};
use uniperf::util::json::Json;

fn workers() -> usize {
    uniperf::util::executor::default_workers()
}

/// The cut-down campaign used by the golden-determinism tests: the same
/// predicate quick-mode crossval uses, so the golden pins the campaign
/// that actually runs in CI's smoke step.
fn small_campaign_cases(device: &str) -> Vec<uniperf::kernels::KernelCase> {
    let profile = uniperf::gpusim::device(device).unwrap();
    uniperf::kernels::measurement_suite(&profile)
        .into_iter()
        .filter(|c| quick_campaign_case(&c.label))
        .collect()
}

#[test]
fn quick_crossval_loko_two_devices() {
    let opts = CrossvalOpts {
        base: Config {
            devices: vec!["k40c".into(), "r9_fury".into()],
            backend: FitBackend::Native,
            ..Config::default()
        },
        split: Split::LeaveOneKernelOut,
        quick: true,
    };
    let r = run_crossval(&opts).expect("crossval");
    // 9 kernel classes held out once per device
    assert_eq!(r.folds.len(), 18);
    for f in &r.folds {
        assert!(!f.entries.is_empty(), "empty fold {}/{}", f.device, f.fold);
        for e in &f.entries {
            assert_eq!(e.kernel, f.fold, "fold must hold out exactly its kernel");
            assert!(e.predicted_s.is_finite(), "{}/{}/{}", e.device, e.kernel, e.case);
            assert!(e.actual_s > 0.0);
        }
        assert!(f.n_train > f.entries.len(), "training set must dominate the fold");
    }
    // the table covers all 9 classes on both devices
    assert_eq!(r.table.kernels().len(), 9);
    assert_eq!(r.table.devices().len(), 2);
    assert!(r.overall_err().is_finite());
    let rendered = r.render();
    for needle in ["reduce_tree", "scan_hs", "st3d7", "bmm8", "gather_s2", "overall"] {
        assert!(rendered.contains(needle), "missing {needle}:\n{rendered}");
    }
}

#[test]
fn transfer_split_builds_device_matrix() {
    let opts = CrossvalOpts {
        base: Config {
            devices: vec!["k40c".into(), "r9_fury".into(), "p100".into()],
            backend: FitBackend::Native,
            ..Config::default()
        },
        split: Split::LeaveOneDeviceOut,
        quick: true,
    };
    let r = run_crossval(&opts).expect("transfer crossval");
    // one fold per source device, each predicting the other two
    assert_eq!(r.folds.len(), 3);
    let tm = r.transfer.as_ref().expect("device split yields a transfer matrix");
    assert_eq!(tm.devices, vec!["k40c", "r9_fury", "p100"]);
    for (si, f) in r.folds.iter().enumerate() {
        assert_eq!(f.fold, tm.devices[si], "fold order must follow device order");
        assert!(!f.weights.is_empty(), "fold {} lost its weight table", f.fold);
        // 2 target devices x 9 kernels x 2 quick size cases
        assert_eq!(f.entries.len(), 2 * 18, "fold {}", f.fold);
        for e in &f.entries {
            assert_ne!(e.device, f.fold, "a fold must not predict its own device");
            assert!(e.predicted_s.is_finite() && e.actual_s > 0.0, "{}/{}", e.device, e.kernel);
        }
    }
    for si in 0..3 {
        for ti in 0..3 {
            let cell = tm.err[si][ti];
            if si == ti {
                assert!(cell.is_none(), "diagonal must be held out");
            } else {
                assert!(cell.unwrap().is_finite(), "({si},{ti})");
            }
        }
    }
    // named lookup works for off-diagonal pairs
    let regular = tm.get("k40c", "p100").unwrap();
    assert!(regular.is_finite() && regular >= 0.0);
    let rendered = r.render();
    for needle in ["fit \\ pred", "k40c", "r9_fury", "p100", "geomean"] {
        assert!(rendered.contains(needle), "missing {needle}:\n{rendered}");
    }
    // the JSON record carries per-fold weights and the matrix
    let j = r.to_json();
    assert!(j.get("transfer").is_some());
    let folds = j.get("folds").and_then(Json::as_arr).unwrap();
    assert_eq!(folds.len(), 3);
    assert!(folds[0]
        .get("weights")
        .and_then(Json::as_arr)
        .map(|w| !w.is_empty())
        .unwrap_or(false));
}

#[test]
fn transfer_matrix_deterministic_across_reruns() {
    let opts = CrossvalOpts {
        base: Config {
            devices: vec!["c2070".into(), "vega64".into()],
            backend: FitBackend::Native,
            ..Config::default()
        },
        split: Split::LeaveOneDeviceOut,
        quick: true,
    };
    let r1 = run_crossval(&opts).expect("transfer run 1");
    let r2 = run_crossval(&opts).expect("transfer run 2");
    // golden-determinism pin: byte-identical matrix and render
    assert_eq!(r1.transfer, r2.transfer);
    assert_eq!(r1.render(), r2.render());
    assert_eq!(r1.to_json().pretty(), r2.to_json().pretty());
}

#[test]
fn crossval_is_deterministic_across_runs() {
    let opts = CrossvalOpts {
        base: Config {
            devices: vec!["c2070".into()],
            backend: FitBackend::Native,
            ..Config::default()
        },
        split: Split::LeaveOneSizeCaseOut,
        quick: true,
    };
    let r1 = run_crossval(&opts).expect("crossval run 1");
    let r2 = run_crossval(&opts).expect("crossval run 2");
    assert_eq!(r1.table.error_matrix(), r2.table.error_matrix());
    assert_eq!(r1.render(), r2.render());
}

#[test]
fn pipeline_eval_zoo_flag_expands_test_suite() {
    let cfg = Config {
        devices: vec!["k40c".into()],
        backend: FitBackend::Native,
        eval_zoo: true,
        ..Config::default()
    };
    let schema = Schema::full();
    let dr = run_device("k40c", &schema, &cfg).expect("pipeline");
    // 9 kernel classes x 4 size cases
    assert_eq!(dr.tests.len(), 36);
    let mut table = Table1::default();
    for (kernel, case, pred, act) in &dr.tests {
        assert!(pred.is_finite() && *act > 0.0, "{kernel}/{case}");
        table.push(Table1Entry {
            device: "k40c".into(),
            kernel: kernel.clone(),
            case: case.clone(),
            predicted_s: *pred,
            actual_s: *act,
        });
    }
    assert_eq!(table.kernels().len(), 9);
    let rendered = table.render();
    for needle in ["fd5", "nbody", "reduce_tree", "scan_hs", "st3d7", "bmm8", "gather_s2"] {
        assert!(rendered.contains(needle), "missing {needle}");
    }
}

#[test]
fn golden_determinism_campaign_fit_and_table() {
    let schema = Schema::full();
    let protocol = Protocol::default();
    let opts = ExtractOpts::default();
    let device = "c2070";

    // the same cut-down campaign, run twice from scratch
    let run_once = || {
        let gpu = SimGpu::named(device).unwrap();
        let cases = small_campaign_cases(device);
        let (pm, overhead) =
            run_campaign(&gpu, &cases, &schema, &protocol, opts, workers()).expect("campaign");
        let model = fit(device, &pm, &schema, &NativeSolver::new()).expect("fit");
        // predict + measure a slice of the evaluation zoo
        let zoo: Vec<_> = uniperf::kernels::eval_suite(&gpu.profile)
            .into_iter()
            .filter(|c| c.label.split('/').nth(1) == Some("a"))
            .collect();
        let ms = measure_cases(&gpu, &zoo, &schema, &protocol, opts, workers()).unwrap();
        let mut table = Table1::default();
        for (c, m) in zoo.iter().zip(&ms) {
            let mut parts = c.label.split('/');
            table.push(Table1Entry {
                device: device.into(),
                kernel: parts.next().unwrap().into(),
                case: parts.next().unwrap().into(),
                predicted_s: model.predict(&m.props),
                actual_s: m.time_s,
            });
        }
        (pm, overhead, model, table)
    };
    let (pm1, overhead1, model1, table1) = run_once();
    let (pm2, _, model2, table2) = run_once();

    // byte-identical model serialization across reruns
    let j1 = model1.to_json(&schema).pretty();
    let j2 = model2.to_json(&schema).pretty();
    assert_eq!(j1, j2, "model JSON must be byte-identical across reruns");
    // identical error matrices and rendering
    assert_eq!(table1.error_matrix(), table2.error_matrix());
    assert_eq!(table1.render(), table2.render());

    // JSON persistence round trip refits to the byte-identical model
    let cj = campaign_to_json(&pm1, device, overhead1);
    let (pm3, dev, _) = campaign_from_json(&Json::parse(&cj.pretty()).unwrap()).unwrap();
    assert_eq!(dev, device);
    assert_eq!(pm3.n_cases(), pm2.n_cases());
    let model3 = fit(device, &pm3, &schema, &NativeSolver::new()).unwrap();
    assert_eq!(j1, model3.to_json(&schema).pretty(), "round-trip model JSON differs");
}
