//! Schedule search + barrier insertion (paper §3.2, last paragraph).
//!
//! Barrier synchronizations "are not apparent in Loopy code without a
//! *schedule*": a linearization of the instructions, a nesting of the
//! sequential loops, and the locations of required work-group barriers.
//! This module finds such a schedule:
//!
//! 1. instructions are topologically sorted by their dependency DAG;
//! 2. sequential loops are opened/closed greedily around instructions
//!    (stack discipline, ordered by domain declaration order);
//! 3. a barrier is inserted whenever a work-group-shared ("local") array
//!    flows across SIMD lanes: a read of data written since the last
//!    barrier under a different lane mapping (RAW), or an overwrite of
//!    data read since the last barrier (WAR — this produces the classic
//!    trailing barrier of tiled matrix multiplication).
//!
//! The schedule is consumed by [`crate::stats`] (symbolic barrier counts)
//! and by [`crate::gpusim`] (execution order).

use crate::lpir::{IdxTag, Insn, Kernel, MemSpace};
use crate::qpoly::{LinExpr, PwQPoly};
use crate::util::intern::Sym;
use std::collections::{BTreeMap, BTreeSet};

/// One element of the linearized schedule.
#[derive(Clone, Debug, PartialEq)]
pub enum SchedItem {
    /// open a sequential (or unrolled) loop over this iname
    OpenLoop(Sym),
    CloseLoop(Sym),
    /// execute an instruction for all lanes of the group
    RunInsn(usize),
    /// work-group barrier
    Barrier,
}

/// A complete schedule for a kernel.
#[derive(Clone, Debug, Default)]
pub struct Schedule {
    pub items: Vec<SchedItem>,
}

impl Schedule {
    /// Total number of barrier *instructions* executed per work-group
    /// execution, symbolically: each barrier site is multiplied by the
    /// trip counts of its enclosing sequential loops.
    pub fn barriers_per_group(&self, kernel: &Kernel) -> PwQPoly {
        let mut total = PwQPoly::zero();
        let mut stack: Vec<Sym> = Vec::new();
        for item in &self.items {
            match item {
                SchedItem::OpenLoop(name) => stack.push(*name),
                SchedItem::CloseLoop(_) => {
                    stack.pop();
                }
                SchedItem::Barrier => {
                    let mut q = PwQPoly::constant(1.0);
                    for iname in &stack {
                        if let Some(dim) = kernel.domain.dim(*iname) {
                            let tc = PwQPoly { pieces: vec![(Vec::new(), dim.trip_count())] };
                            q = q.mul(&tc);
                        }
                    }
                    total = total.add(&q);
                }
                SchedItem::RunInsn(_) => {}
            }
        }
        total
    }

    /// Number of `Barrier` items (static barrier sites).
    pub fn barrier_sites(&self) -> usize {
        self.items.iter().filter(|i| matches!(i, SchedItem::Barrier)).count()
    }
}

/// Local-memory accesses of one instruction: (array, index, is_write).
fn local_accesses(kernel: &Kernel, insn: &Insn) -> Vec<(Sym, Vec<LinExpr>, bool)> {
    let mut out = Vec::new();
    if let Some(arr) = kernel.array(insn.lhs.array) {
        if arr.space == MemSpace::Local {
            out.push((insn.lhs.array, insn.lhs.idx.clone(), true));
            // an update instruction also reads its LHS
            if insn.is_update {
                out.push((insn.lhs.array, insn.lhs.idx.clone(), false));
            }
        }
    }
    insn.rhs.visit_loads(&mut |a, _| {
        if let Some(arr) = kernel.array(a.array) {
            if arr.space == MemSpace::Local {
                out.push((a.array, a.idx.clone(), false));
            }
        }
    });
    out
}

/// Pending cross-lane state since the last barrier. The lane "signature"
/// of an access is simply its index-expression vector: two accesses with
/// identical signatures touch the same element from the same lane, so no
/// cross-lane data flow occurs between them.
#[derive(Default)]
struct BarrierState {
    /// array -> index signatures written since last barrier
    writes: BTreeMap<Sym, Vec<Vec<LinExpr>>>,
    /// array -> index signatures read since last barrier
    reads: BTreeMap<Sym, Vec<Vec<LinExpr>>>,
}

impl BarrierState {
    fn clear(&mut self) {
        self.writes.clear();
        self.reads.clear();
    }

    /// Would executing `accesses` require a barrier first?
    fn needs_barrier(&self, accesses: &[(Sym, Vec<LinExpr>, bool)]) -> bool {
        for (arr, idx, is_write) in accesses {
            if *is_write {
                // WAR: overwriting data other lanes may still be reading
                if let Some(reads) = self.reads.get(arr) {
                    if reads.iter().any(|r| r != idx) {
                        return true;
                    }
                }
                // WAW across lanes is also ordered by a barrier
                if let Some(writes) = self.writes.get(arr) {
                    if writes.iter().any(|w| w != idx) {
                        return true;
                    }
                }
            } else {
                // RAW: reading data written under a different lane mapping
                if let Some(writes) = self.writes.get(arr) {
                    if writes.iter().any(|w| w != idx) {
                        return true;
                    }
                }
            }
        }
        false
    }

    fn record(&mut self, accesses: Vec<(Sym, Vec<LinExpr>, bool)>) {
        for (arr, idx, is_write) in accesses {
            let slot = if is_write { &mut self.writes } else { &mut self.reads };
            let v = slot.entry(arr).or_default();
            if !v.contains(&idx) {
                v.push(idx);
            }
        }
    }
}

/// Compute a schedule for the kernel. Returns an error on dependency
/// cycles.
pub fn schedule(kernel: &Kernel) -> Result<Schedule, String> {
    // --- 1. topological sort (stable: prefer lower ids) -------------------
    let n = kernel.insns.len();
    let mut indeg = vec![0usize; n];
    let mut out_edges: Vec<Vec<usize>> = vec![Vec::new(); n];
    for insn in &kernel.insns {
        for &d in &insn.deps {
            out_edges[d].push(insn.id);
            indeg[insn.id] += 1;
        }
    }
    let mut ready: BTreeSet<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut order = Vec::with_capacity(n);
    while let Some(&next) = ready.iter().next() {
        ready.remove(&next);
        order.push(next);
        for &succ in &out_edges[next] {
            indeg[succ] -= 1;
            if indeg[succ] == 0 {
                ready.insert(succ);
            }
        }
    }
    if order.len() != n {
        return Err(format!("dependency cycle among instructions of '{}'", kernel.name));
    }

    // --- 2. loop nesting (stack discipline) -------------------------------
    // Required sequential loops per instruction, in domain order.
    let seq_loops = |insn: &Insn| -> Vec<Sym> {
        kernel
            .domain
            .dims
            .iter()
            .filter(|d| {
                insn.within.contains(&d.name)
                    && matches!(kernel.tag(d.name), IdxTag::Seq | IdxTag::Unroll)
            })
            .map(|d| d.name)
            .collect()
    };

    let mut items = Vec::new();
    let mut stack: Vec<Sym> = Vec::new();
    let mut bstate = BarrierState::default();
    // loops whose current body contained a barrier: their close emits a
    // trailing barrier (iteration separation for local-memory reuse)
    let mut loop_had_barrier: BTreeMap<Sym, bool> = BTreeMap::new();

    for &id in &order {
        let insn = &kernel.insns[id];
        let want = seq_loops(insn);
        // common prefix of current stack and wanted nest
        let mut prefix = 0;
        while prefix < stack.len() && prefix < want.len() && stack[prefix] == want[prefix] {
            prefix += 1;
        }
        // close loops deeper than the common prefix (LIFO)
        while stack.len() > prefix {
            let closing = stack.pop().unwrap();
            if loop_had_barrier.remove(&closing).unwrap_or(false) {
                items.push(SchedItem::Barrier);
                bstate.clear();
            }
            items.push(SchedItem::CloseLoop(closing));
        }
        // open the missing loops
        for iname in want.iter().skip(stack.len()) {
            items.push(SchedItem::OpenLoop(*iname));
            stack.push(*iname);
            loop_had_barrier.insert(*iname, false);
        }

        // --- 3. barrier insertion -----------------------------------------
        let accesses = local_accesses(kernel, insn);
        if bstate.needs_barrier(&accesses) {
            items.push(SchedItem::Barrier);
            bstate.clear();
            for iname in &stack {
                loop_had_barrier.insert(*iname, true);
            }
        }
        bstate.record(accesses);
        items.push(SchedItem::RunInsn(id));
    }
    while let Some(closing) = stack.pop() {
        if loop_had_barrier.remove(&closing).unwrap_or(false) {
            items.push(SchedItem::Barrier);
            bstate.clear();
        }
        items.push(SchedItem::CloseLoop(closing));
    }
    Ok(Schedule { items })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lpir::builder::{gid, KernelBuilder};
    use crate::lpir::{Access, DType, Expr, Layout};
    use crate::qpoly::{env, LinExpr};

    /// A minimal prefetching kernel: stage a tile of `a` into local
    /// memory, then read it back transposed (cross-lane flow).
    fn prefetch_kernel() -> Kernel {
        KernelBuilder::new("prefetch", &["n"])
            .group_dims_2d(LinExpr::var("n"), 16, LinExpr::var("n"), 16)
            .global_array(
                "a",
                DType::F32,
                vec![LinExpr::var("n"), LinExpr::var("n")],
                Layout::RowMajor,
                false,
            )
            .global_array(
                "out",
                DType::F32,
                vec![LinExpr::var("n"), LinExpr::var("n")],
                Layout::RowMajor,
                true,
            )
            .local_array("tile", DType::F32, &[16, 16])
            .insn(
                Access::new("tile", vec![LinExpr::var("l1"), LinExpr::var("l0")]),
                Expr::load("a", vec![gid(1, 16), gid(0, 16)]),
                &["g0", "g1", "l0", "l1"],
                &[],
            )
            .insn(
                Access::new(
                    "out",
                    vec![
                        LinExpr::scaled_var("g0", 16).add(&LinExpr::var("l1")),
                        LinExpr::scaled_var("g1", 16).add(&LinExpr::var("l0")),
                    ],
                ),
                Expr::load("tile", vec![LinExpr::var("l0"), LinExpr::var("l1")]),
                &["g0", "g1", "l0", "l1"],
                &[0],
            )
            .build()
            .unwrap()
    }

    #[test]
    fn prefetch_needs_one_barrier() {
        let k = prefetch_kernel();
        let s = schedule(&k).unwrap();
        assert_eq!(s.barrier_sites(), 1);
        let runs: Vec<&SchedItem> = s.items.iter().collect();
        assert_eq!(
            runs,
            vec![&SchedItem::RunInsn(0), &SchedItem::Barrier, &SchedItem::RunInsn(1)]
        );
    }

    #[test]
    fn no_barrier_without_local_memory() {
        let k = KernelBuilder::new("copy", &["n"])
            .group_dims_1d(LinExpr::var("n"), 256)
            .global_array("a", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, false)
            .global_array("b", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("b", vec![gid(0, 256)]),
                Expr::load("a", vec![gid(0, 256)]),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap();
        let s = schedule(&k).unwrap();
        assert_eq!(s.barrier_sites(), 0);
    }

    #[test]
    fn same_lane_mapping_needs_no_barrier() {
        let k = KernelBuilder::new("same_lane", &["n"])
            .group_dims_1d(LinExpr::var("n"), 64)
            .global_array("a", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, false)
            .global_array("out", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .local_array("tile", DType::F32, &[64])
            .insn(
                Access::new("tile", vec![LinExpr::var("l0")]),
                Expr::load("a", vec![gid(0, 64)]),
                &["g0", "l0"],
                &[],
            )
            .insn(
                Access::new("out", vec![gid(0, 64)]),
                Expr::load("tile", vec![LinExpr::var("l0")]),
                &["g0", "l0"],
                &[0],
            )
            .build()
            .unwrap();
        let s = schedule(&k).unwrap();
        assert_eq!(s.barrier_sites(), 0);
    }

    /// Tiled-MM-shaped kernel: prefetch two tiles inside a sequential tile
    /// loop, consume them, write out at the end.
    fn tiled_mm_like() -> Kernel {
        let n = LinExpr::var("n");
        KernelBuilder::new("mm_like", &["n"])
            .group_dims_2d(n.clone(), 16, n.clone(), 16)
            .seq_tiles("kt", n.clone(), 16)
            .red_dim("ki", LinExpr::constant(16))
            .global_array("a", DType::F32, vec![n.clone(), n.clone()], Layout::RowMajor, false)
            .global_array("b", DType::F32, vec![n.clone(), n.clone()], Layout::RowMajor, false)
            .global_array("c", DType::F32, vec![n.clone(), n.clone()], Layout::RowMajor, true)
            .local_array("at", DType::F32, &[16, 16])
            .local_array("bt", DType::F32, &[16, 16])
            .private_array("acc", DType::F32, &[1])
            .insn(
                Access::new("at", vec![LinExpr::var("l1"), LinExpr::var("l0")]),
                Expr::load(
                    "a",
                    vec![gid(1, 16), LinExpr::scaled_var("kt", 16).add(&LinExpr::var("l0"))],
                ),
                &["g0", "g1", "l0", "l1", "kt"],
                &[],
            )
            .insn(
                Access::new("bt", vec![LinExpr::var("l1"), LinExpr::var("l0")]),
                Expr::load(
                    "b",
                    vec![LinExpr::scaled_var("kt", 16).add(&LinExpr::var("l1")), gid(0, 16)],
                ),
                &["g0", "g1", "l0", "l1", "kt"],
                &[],
            )
            .update_insn(
                Access::new("acc", vec![LinExpr::constant(0)]),
                Expr::sum(
                    "ki",
                    Expr::mul(
                        Expr::load("at", vec![LinExpr::var("l1"), LinExpr::var("ki")]),
                        Expr::load("bt", vec![LinExpr::var("ki"), LinExpr::var("l0")]),
                    ),
                ),
                &["g0", "g1", "l0", "l1", "kt"],
                &[0, 1],
            )
            .insn(
                Access::new("c", vec![gid(1, 16), gid(0, 16)]),
                Expr::load("acc", vec![LinExpr::constant(0)]),
                &["g0", "g1", "l0", "l1"],
                &[2],
            )
            .build()
            .unwrap()
    }

    #[test]
    fn tiled_mm_has_two_barriers_per_tile_iteration() {
        let k = tiled_mm_like();
        let s = schedule(&k).unwrap();
        // one barrier between prefetch and consume, one trailing barrier
        // at the end of each kt iteration
        assert_eq!(s.barrier_sites(), 2, "schedule: {:?}", s.items);
        let per_group = s.barriers_per_group(&k);
        assert_eq!(per_group.eval(&env(&[("n", 256)])).unwrap(), 2.0 * 16.0);
    }

    #[test]
    fn cycle_detection() {
        let mut k = prefetch_kernel();
        k.insns[0].deps = vec![1];
        assert!(schedule(&k).is_err());
    }

    #[test]
    fn loops_open_and_close_balanced() {
        let k = tiled_mm_like();
        let s = schedule(&k).unwrap();
        let mut depth = 0i64;
        for item in &s.items {
            match item {
                SchedItem::OpenLoop(_) => depth += 1,
                SchedItem::CloseLoop(_) => {
                    depth -= 1;
                    assert!(depth >= 0);
                }
                _ => {}
            }
        }
        assert_eq!(depth, 0);
    }
}
