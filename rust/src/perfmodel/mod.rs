//! The performance model: property matrix assembly, weight fitting
//! (paper §4.3) and run-time prediction (§2).
//!
//! The fit minimizes *relative* error: with property matrix `P`
//! (cases × properties) and measured times `T`,
//!
//! ```text
//! min_α Σ_j (1 - ⟨α, P_j⟩ / T_j)²   =   min_α ‖B α - 1‖²,   B_j = P_j / T_j
//! ```
//!
//! which is an ordinary least-squares problem in the scaled matrix `B`.
//! Two interchangeable solver backends exist:
//!
//! * [`NativeSolver`] — in-process Gram + Cholesky (ridge-regularised)
//!   with a Householder-QR fallback, built on [`crate::util::linalg`];
//! * `runtime::XlaSolver` — the AOT-compiled JAX/Pallas artifact executed
//!   through PJRT (the production path; see `python/compile/`).
//!
//! Both are cross-checked against each other in the integration tests.
//!
//! Prediction is the paper's "rapid evaluation": evaluate the symbolic
//! property vector at the target size, then one small inner product.

use crate::stats::{KernelProps, Schema};
use crate::util::json::Json;
use crate::util::linalg::{cholesky_solve, dot, qr_solve, Mat};

/// One measured case: a kernel's dense property vector + wall time.
#[derive(Clone, Debug)]
pub struct Case {
    /// display label, e.g. `mm_square/n=512/g=16x16`
    pub label: String,
    pub props: Vec<f64>,
    /// measured wall time in seconds
    pub time_s: f64,
}

/// The assembled measurement set.
#[derive(Clone, Debug, Default)]
pub struct PropertyMatrix {
    pub cases: Vec<Case>,
}

impl PropertyMatrix {
    pub fn push(&mut self, label: String, props: Vec<f64>, time_s: f64) {
        assert!(time_s > 0.0, "non-positive measured time for {label}");
        self.cases.push(Case { label, props, time_s });
    }

    pub fn n_cases(&self) -> usize {
        self.cases.len()
    }

    pub fn n_props(&self) -> usize {
        self.cases.first().map(|c| c.props.len()).unwrap_or(0)
    }

    /// Columns with at least one non-zero entry (only these are fittable;
    /// the paper notes the measurement set "contains instances of every
    /// property relevant to the test kernels").
    pub fn active_columns(&self) -> Vec<usize> {
        let p = self.n_props();
        (0..p)
            .filter(|&j| self.cases.iter().any(|c| c.props[j] != 0.0))
            .collect()
    }

    /// The relative-error-scaled matrix `B` restricted to `cols`.
    pub fn scaled_matrix(&self, cols: &[usize]) -> Mat {
        let mut m = Mat::zeros(self.n_cases(), cols.len());
        for (i, c) in self.cases.iter().enumerate() {
            for (k, &j) in cols.iter().enumerate() {
                *m.at_mut(i, k) = c.props[j] / c.time_s;
            }
        }
        m
    }
}

/// A solver for the least-squares system `min ‖B α - 1‖²`.
pub trait Solver {
    /// Returns the weight vector (length = `b.cols`).
    fn solve(&self, b: &Mat) -> Result<Vec<f64>, String>;

    /// Identifying name for reports.
    fn name(&self) -> &'static str;
}

/// In-process solver: column-equilibrated normal equations + Cholesky,
/// falling back to Householder QR when ill-conditioned.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeSolver {
    /// relative ridge (applied to the equilibrated Gram); 0 = none
    pub ridge: f64,
}

impl NativeSolver {
    pub fn new() -> Self {
        NativeSolver { ridge: 1e-10 }
    }
}

impl Solver for NativeSolver {
    fn solve(&self, b: &Mat) -> Result<Vec<f64>, String> {
        let (rows, cols) = (b.rows, b.cols);
        if rows < cols {
            return Err(format!("underdetermined fit: {rows} cases < {cols} properties"));
        }
        // column equilibration for conditioning
        let mut scale = vec![0.0f64; cols];
        for i in 0..rows {
            for j in 0..cols {
                scale[j] = scale[j].max(b.at(i, j).abs());
            }
        }
        for s in &mut scale {
            if *s == 0.0 {
                *s = 1.0;
            }
        }
        let mut bs = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                *bs.at_mut(i, j) = b.at(i, j) / scale[j];
            }
        }
        let ones = vec![1.0; rows];
        let g = bs.gram();
        let atb = bs.t_mul_vec(&ones);
        let w = match cholesky_solve(&g, &atb, self.ridge * rows as f64) {
            Some(w) => w,
            None => qr_solve(&bs, &ones),
        };
        Ok(w.iter().zip(&scale).map(|(wi, s)| wi / s).collect())
    }

    fn name(&self) -> &'static str {
        "native-cholesky"
    }
}

/// A fitted device model.
#[derive(Clone, Debug)]
pub struct Model {
    /// device the weights were fitted for
    pub device: String,
    /// dense weight vector in schema order (inactive columns are 0)
    pub weights: Vec<f64>,
    /// which columns were active during the fit
    pub active: Vec<usize>,
    /// geometric-mean relative error on the training set
    pub train_rel_err_geomean: f64,
    pub solver: &'static str,
}

impl Model {
    /// Predicted wall time (seconds) for a dense property vector — the
    /// paper's "rapid evaluation": one inner product.
    #[inline]
    pub fn predict(&self, props: &[f64]) -> f64 {
        dot(&self.weights, props)
    }

    /// Predict from symbolic properties at a parameter binding.
    pub fn predict_kernel(
        &self,
        schema: &Schema,
        props: &KernelProps,
        env: &crate::util::intern::Env,
    ) -> Result<f64, String> {
        Ok(self.predict(&props.eval(schema, env)?))
    }

    /// Relative absolute error |pred - actual| / actual (the paper's
    /// error measure).
    ///
    /// A non-positive or non-finite `actual` has no meaningful relative
    /// error; instead of dividing by zero (which yields `inf` or `NaN`
    /// depending on `pred`) the documented sentinel `f64::INFINITY` is
    /// returned, which propagates visibly through
    /// [`crate::util::linalg::geometric_mean`] rather than poisoning it
    /// as `NaN`.
    pub fn rel_err(pred: f64, actual: f64) -> f64 {
        if !actual.is_finite() || actual <= 0.0 {
            return f64::INFINITY;
        }
        (pred - actual).abs() / actual
    }

    /// Table-2-style weight report: (label, weight) for active columns
    /// with non-zero weights, in schema order.
    pub fn weight_report(&self, schema: &Schema) -> Vec<(String, f64)> {
        self.active
            .iter()
            .filter(|&&j| self.weights[j] != 0.0)
            .map(|&j| (schema.props()[j].label(), self.weights[j]))
            .collect()
    }

    /// Serialize to JSON (for campaign persistence).
    pub fn to_json(&self, schema: &Schema) -> Json {
        Json::obj(vec![
            ("device", Json::Str(self.device.clone())),
            ("solver", Json::Str(self.solver.to_string())),
            ("train_rel_err_geomean", Json::Num(self.train_rel_err_geomean)),
            (
                "weights",
                Json::Arr(
                    self.active
                        .iter()
                        .map(|&j| {
                            Json::obj(vec![
                                ("prop", Json::Str(schema.props()[j].label())),
                                ("index", Json::Num(j as f64)),
                                ("weight", Json::Num(self.weights[j])),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Deserialize from JSON produced by [`Model::to_json`].
    pub fn from_json(j: &Json, schema: &Schema) -> Result<Model, String> {
        let device = j
            .get("device")
            .and_then(Json::as_str)
            .ok_or("missing device")?
            .to_string();
        let mut weights = vec![0.0; schema.len()];
        let mut active = Vec::new();
        for w in j.get("weights").and_then(Json::as_arr).ok_or("missing weights")? {
            let idx = w.get("index").and_then(Json::as_f64).ok_or("missing index")? as usize;
            let val = w.get("weight").and_then(Json::as_f64).ok_or("missing weight")?;
            if idx >= schema.len() {
                return Err(format!("weight index {idx} out of range"));
            }
            weights[idx] = val;
            active.push(idx);
        }
        // preserve the stored solver name verbatim so serialization is
        // a fixed point (required by the persisted model artifacts of
        // [`crate::service::store`]: re-emitting a loaded store must
        // reproduce the file byte for byte) — including names of
        // solvers this build does not know, which go through the
        // global interner for true leak-once-per-distinct-name
        // `&'static str` semantics.
        let solver = match j.get("solver").and_then(Json::as_str) {
            Some(name) => crate::util::intern::Sym::intern(name).as_str(),
            None => "loaded",
        };
        Ok(Model {
            device,
            weights,
            active,
            train_rel_err_geomean: j
                .get("train_rel_err_geomean")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            solver,
        })
    }
}

/// Fit a model from a measurement set with the given solver.
pub fn fit(
    device: &str,
    pm: &PropertyMatrix,
    schema: &Schema,
    solver: &dyn Solver,
) -> Result<Model, String> {
    if pm.n_cases() == 0 {
        return Err("empty measurement set".into());
    }
    if pm.n_props() != schema.len() {
        return Err(format!(
            "property vectors have {} entries, schema expects {}",
            pm.n_props(),
            schema.len()
        ));
    }
    let active = pm.active_columns();
    let b = pm.scaled_matrix(&active);
    let w_active = solver.solve(&b)?;
    let mut weights = vec![0.0; schema.len()];
    for (k, &j) in active.iter().enumerate() {
        weights[j] = w_active[k];
    }
    // training diagnostics
    let errs: Vec<f64> = pm
        .cases
        .iter()
        .map(|c| Model::rel_err(dot(&weights, &c.props), c.time_s))
        .collect();
    Ok(Model {
        device: device.to_string(),
        weights,
        active,
        train_rel_err_geomean: crate::util::linalg::geometric_mean(&errs),
        solver: solver.name(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Synthetic measurement set: times generated from known weights
    /// (plus optional noise) must be recovered by the fit.
    fn synthetic(n_cases: usize, true_w: &[f64], noise: f64, seed: u64) -> PropertyMatrix {
        let mut rng = Rng::new(seed);
        let mut pm = PropertyMatrix::default();
        for i in 0..n_cases {
            let props: Vec<f64> = true_w
                .iter()
                .map(|_| (rng.range_u64(1, 1000) * 1000) as f64)
                .collect();
            let t: f64 =
                props.iter().zip(true_w).map(|(p, w)| p * w).sum::<f64>() * rng.lognormal(noise);
            pm.push(format!("case{i}"), props, t);
        }
        pm
    }

    fn raw_fit(pm: &PropertyMatrix, n_props: usize) -> Vec<f64> {
        let active: Vec<usize> = (0..n_props).collect();
        let b = pm.scaled_matrix(&active);
        NativeSolver::new().solve(&b).unwrap()
    }

    #[test]
    fn recovers_exact_weights_noiseless() {
        let true_w = [1e-9, 5e-10, 2e-8];
        let pm = synthetic(40, &true_w, 0.0, 7);
        let w = raw_fit(&pm, 3);
        for (wi, ti) in w.iter().zip(&true_w) {
            assert!((wi - ti).abs() / ti < 1e-8, "{w:?}");
        }
    }

    #[test]
    fn near_recovery_with_noise() {
        let true_w = [1e-9, 5e-10, 2e-8];
        let pm = synthetic(200, &true_w, 0.03, 11);
        let w = raw_fit(&pm, 3);
        for (wi, ti) in w.iter().zip(&true_w) {
            assert!((wi - ti).abs() / ti < 0.05, "{w:?}");
        }
    }

    #[test]
    fn underdetermined_rejected() {
        let true_w = [1e-9, 5e-10, 2e-8];
        let pm = synthetic(2, &true_w, 0.0, 3);
        let active: Vec<usize> = (0..3).collect();
        let b = pm.scaled_matrix(&active);
        assert!(NativeSolver::new().solve(&b).is_err());
    }

    #[test]
    fn collinear_columns_still_predict() {
        // two identical columns: weights are not unique, but the
        // prediction must still reproduce the generating times
        let mut pm = PropertyMatrix::default();
        let mut rng = Rng::new(5);
        for i in 0..20 {
            let a = rng.range_u64(1, 100) as f64 * 1e6;
            let props = vec![a, a, 2.0 * a];
            let t = 3e-9 * a;
            pm.push(format!("c{i}"), props, t);
        }
        let w = raw_fit(&pm, 3);
        for c in &pm.cases {
            let pred: f64 = w.iter().zip(&c.props).map(|(wi, p)| wi * p).sum();
            assert!((pred - c.time_s).abs() / c.time_s < 1e-6);
        }
    }

    #[test]
    fn full_fit_with_schema_roundtrip() {
        let schema = Schema::full();
        let p = schema.len();
        let active_cols = [0usize, 11, 40, p - 2, p - 1];
        let true_w = [2e-12, 1e-12, 8e-12, 3e-9, 1e-4];
        let mut rng = Rng::new(42);
        let mut pm = PropertyMatrix::default();
        for i in 0..30 {
            let mut props = vec![0.0; p];
            for &j in &active_cols {
                props[j] =
                    if j == p - 1 { 1.0 } else { (rng.range_u64(1, 500) * 100) as f64 };
            }
            let t: f64 = active_cols
                .iter()
                .zip(&true_w)
                .map(|(&j, w)| props[j] * w)
                .sum();
            pm.push(format!("case{i}"), props, t);
        }
        let model = fit("test_dev", &pm, &schema, &NativeSolver::new()).unwrap();
        assert!(model.train_rel_err_geomean < 1e-6, "{}", model.train_rel_err_geomean);
        // json roundtrip preserves predictions
        let j = model.to_json(&schema);
        let loaded = Model::from_json(&Json::parse(&j.pretty()).unwrap(), &schema).unwrap();
        for c in &pm.cases {
            assert!((model.predict(&c.props) - loaded.predict(&c.props)).abs() < 1e-15);
        }
        assert_eq!(model.weight_report(&schema).len(), active_cols.len());
    }

    #[test]
    fn rel_err_definition() {
        assert_eq!(Model::rel_err(1.5, 1.0), 0.5);
        assert_eq!(Model::rel_err(0.5, 1.0), 0.5);
    }

    #[test]
    fn rel_err_guards_degenerate_actual() {
        // zero, negative, NaN and infinite actuals all yield the
        // documented sentinel instead of a division by zero
        assert!(Model::rel_err(1.0, 0.0).is_infinite());
        assert!(Model::rel_err(0.0, 0.0).is_infinite()); // naive 0/0 = NaN
        assert!(Model::rel_err(1.0, -2.0).is_infinite());
        assert!(Model::rel_err(1.0, f64::NAN).is_infinite());
        assert!(Model::rel_err(1.0, f64::INFINITY).is_infinite());
        // the sentinel flows through a geomean as inf, not NaN
        let g = crate::util::linalg::geometric_mean(&[0.1, Model::rel_err(1.0, 0.0)]);
        assert!(g.is_infinite() && g > 0.0);
    }
}
