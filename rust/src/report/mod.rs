//! Report generation: the paper's Table 1 (predicted vs. actual times +
//! geometric-mean relative errors), Table 2 (fitted weights), the
//! held-out cross-validation matrix and the cross-device transfer-error
//! matrix.

use crate::perfmodel::Model;
use crate::stats::Schema;
use crate::util::json::Json;
use crate::util::linalg::geometric_mean;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One Table-1 cell: a test-kernel size case on one device.
#[derive(Clone, Debug)]
pub struct Table1Entry {
    pub device: String,
    /// kernel display name, e.g. `fd5`
    pub kernel: String,
    /// size case letter `a`–`d`
    pub case: String,
    pub predicted_s: f64,
    pub actual_s: f64,
}

impl Table1Entry {
    pub fn rel_err(&self) -> f64 {
        Model::rel_err(self.predicted_s, self.actual_s)
    }
}

/// The assembled Table 1.
#[derive(Clone, Debug, Default)]
pub struct Table1 {
    pub entries: Vec<Table1Entry>,
}

impl Table1 {
    pub fn push(&mut self, e: Table1Entry) {
        self.entries.push(e);
    }

    pub fn devices(&self) -> Vec<String> {
        let mut v: Vec<String> = Vec::new();
        for e in &self.entries {
            if !v.contains(&e.device) {
                v.push(e.device.clone());
            }
        }
        v
    }

    pub fn kernels(&self) -> Vec<String> {
        let mut v: Vec<String> = Vec::new();
        for e in &self.entries {
            if !v.contains(&e.kernel) {
                v.push(e.kernel.clone());
            }
        }
        v
    }

    /// Geometric-mean relative error of one kernel on one device.
    pub fn kernel_device_err(&self, kernel: &str, device: &str) -> f64 {
        let errs: Vec<f64> = self
            .entries
            .iter()
            .filter(|e| e.kernel == kernel && e.device == device)
            .map(|e| e.rel_err())
            .collect();
        geometric_mean(&errs)
    }

    /// Cross-kernel geometric mean for one device (Table 1's bottom row).
    pub fn device_err(&self, device: &str) -> f64 {
        let errs: Vec<f64> = self
            .kernels()
            .iter()
            .map(|k| self.kernel_device_err(k, device))
            .collect();
        geometric_mean(&errs)
    }

    /// Cross-GPU geometric mean for one kernel (Table 1's last column).
    pub fn kernel_err(&self, kernel: &str) -> f64 {
        let errs: Vec<f64> = self
            .devices()
            .iter()
            .map(|d| self.kernel_device_err(kernel, d))
            .collect();
        geometric_mean(&errs)
    }

    /// Overall geometric mean across kernels and devices.
    pub fn overall_err(&self) -> f64 {
        let errs: Vec<f64> = self.entries.iter().map(|e| e.rel_err()).collect();
        geometric_mean(&errs)
    }

    /// Render in the layout of the paper's Table 1: per kernel, one row
    /// per size case with predicted/actual (ms) pairs per device, plus
    /// geometric-mean error rows.
    pub fn render(&self) -> String {
        let devices = self.devices();
        let kernels = self.kernels();
        let mut s = String::new();
        let _ = write!(s, "{:<14}", "Kernel");
        for d in &devices {
            let _ = write!(s, " | {:>19}", d);
        }
        let _ = writeln!(s, " | cross-GPU");
        let _ = write!(s, "{:<14}", "");
        for _ in &devices {
            let _ = write!(s, " | {:>9} {:>9}", "pred(ms)", "act(ms)");
        }
        let _ = writeln!(s, " |  geomean");
        let line_len = 14 + devices.len() * 22 + 11;
        let _ = writeln!(s, "{}", "-".repeat(line_len));
        for k in &kernels {
            // per-device geomean header row for this kernel
            let _ = write!(s, "{:<14}", k);
            for d in &devices {
                let _ = write!(s, " | {:>19.2}", self.kernel_device_err(k, d));
            }
            let _ = writeln!(s, " | {:>8.2}", self.kernel_err(k));
            // the a.-d. case rows
            let cases: Vec<&Table1Entry> =
                self.entries.iter().filter(|e| &e.kernel == k).collect();
            let mut letters: Vec<&str> = cases.iter().map(|e| e.case.as_str()).collect();
            letters.sort();
            letters.dedup();
            for letter in letters {
                let _ = write!(s, "  {:<12}", format!("{letter}."));
                for d in &devices {
                    match cases
                        .iter()
                        .find(|e| e.case == letter && &e.device == d)
                    {
                        Some(e) => {
                            let _ = write!(
                                s,
                                " | {:>9.2} {:>9.2}",
                                e.predicted_s * 1e3,
                                e.actual_s * 1e3
                            );
                        }
                        None => {
                            let _ = write!(s, " | {:>9} {:>9}", "-", "-");
                        }
                    }
                }
                let _ = writeln!(s, " |");
            }
        }
        let _ = writeln!(s, "{}", "-".repeat(line_len));
        let _ = write!(s, "{:<14}", "cross-kernel");
        for d in &devices {
            let _ = write!(s, " | {:>19.2}", self.device_err(d));
        }
        let _ = writeln!(s, " | {:>8.2}", self.overall_err());
        s
    }

    /// Map (kernel -> (device -> geomean error)) for programmatic checks.
    pub fn error_matrix(&self) -> BTreeMap<String, BTreeMap<String, f64>> {
        let mut out = BTreeMap::new();
        for k in self.kernels() {
            let mut row = BTreeMap::new();
            for d in self.devices() {
                row.insert(d.clone(), self.kernel_device_err(&k, &d));
            }
            out.insert(k, row);
        }
        out
    }
}

/// Render a cross-validation summary: a Table-1-style matrix of
/// *held-out* geometric-mean relative errors (kernel × device) with the
/// cross-kernel and cross-GPU marginals and the overall geomean. The
/// entries of `t` are predictions from models that never saw the
/// corresponding kernel (or size case) during fitting.
pub fn render_crossval(split_label: &str, t: &Table1) -> String {
    let devices = t.devices();
    let kernels = t.kernels();
    let mut s = String::new();
    let _ = writeln!(
        s,
        "Cross-validation ({split_label}): held-out geometric-mean relative error"
    );
    let _ = write!(s, "{:<14}", "Kernel");
    for d in &devices {
        let _ = write!(s, " | {:>9}", d);
    }
    let _ = writeln!(s, " | cross-GPU");
    let line_len = 14 + devices.len() * 12 + 12;
    let _ = writeln!(s, "{}", "-".repeat(line_len));
    for k in &kernels {
        let _ = write!(s, "{:<14}", k);
        for d in &devices {
            let _ = write!(s, " | {:>9.3}", t.kernel_device_err(k, d));
        }
        let _ = writeln!(s, " | {:>9.3}", t.kernel_err(k));
    }
    let _ = writeln!(s, "{}", "-".repeat(line_len));
    let _ = write!(s, "{:<14}", "cross-kernel");
    for d in &devices {
        let _ = write!(s, " | {:>9.3}", t.device_err(d));
    }
    let _ = writeln!(s, " | {:>9.3}", t.overall_err());
    let _ = writeln!(
        s,
        "overall held-out geomean relative error: {:.3}",
        t.overall_err()
    );
    s
}

/// Cross-device transfer errors: `err[source][target]` is the
/// geometric-mean relative error of predicting the *target* device's
/// held-out zoo timings with weights fitted on the *source* device
/// (leave-one-device-out, in the spirit of the cross-machine follow-up
/// work arXiv:1904.09538). The diagonal is `None` — a device's own zoo
/// is in its training set under this split.
#[derive(Clone, Debug, PartialEq)]
pub struct TransferMatrix {
    /// row/column order (sources and targets are the same device list)
    pub devices: Vec<String>,
    /// `err[source_index][target_index]`
    pub err: Vec<Vec<Option<f64>>>,
}

impl TransferMatrix {
    /// Transfer error from `source` to `target`, if both are present
    /// and distinct.
    pub fn get(&self, source: &str, target: &str) -> Option<f64> {
        let si = self.devices.iter().position(|d| d == source)?;
        let ti = self.devices.iter().position(|d| d == target)?;
        self.err[si][ti]
    }

    /// Geomean transfer error over all (source, target) pairs.
    pub fn overall_err(&self) -> f64 {
        let errs: Vec<f64> = self.err.iter().flatten().filter_map(|e| *e).collect();
        geometric_mean(&errs)
    }

    /// JSON form (persisted with the crossval output for drift
    /// analysis; `null` on the diagonal).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "devices",
                Json::Arr(self.devices.iter().map(|d| Json::Str(d.clone())).collect()),
            ),
            (
                "err",
                Json::Arr(
                    self.err
                        .iter()
                        .map(|row| {
                            Json::Arr(
                                row.iter()
                                    .map(|e| e.map(Json::Num).unwrap_or(Json::Null))
                                    .collect(),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Render the device×device transfer-error matrix: rows are the fitted
/// (source) devices, columns the predicted (target) devices, plus the
/// per-source and per-target geomean marginals.
pub fn render_transfer(t: &TransferMatrix) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "Cross-device transfer (leave-one-device-out): geometric-mean relative error"
    );
    let _ = writeln!(s, "rows: fitted on (source) | columns: predicted (target)");
    let _ = write!(s, "{:<12}", "fit \\ pred");
    for d in &t.devices {
        let _ = write!(s, " | {:>9}", d);
    }
    let _ = writeln!(s, " | {:>9}", "geomean");
    let line_len = 12 + (t.devices.len() + 1) * 12;
    let _ = writeln!(s, "{}", "-".repeat(line_len));
    for (si, src) in t.devices.iter().enumerate() {
        let _ = write!(s, "{:<12}", src);
        for e in &t.err[si] {
            match e {
                Some(x) => {
                    let _ = write!(s, " | {:>9.3}", x);
                }
                None => {
                    let _ = write!(s, " | {:>9}", "-");
                }
            }
        }
        let row: Vec<f64> = t.err[si].iter().filter_map(|e| *e).collect();
        let _ = writeln!(s, " | {:>9.3}", geometric_mean(&row));
    }
    let _ = writeln!(s, "{}", "-".repeat(line_len));
    let _ = write!(s, "{:<12}", "geomean");
    for ti in 0..t.devices.len() {
        let col: Vec<f64> = t.err.iter().filter_map(|row| row[ti]).collect();
        let _ = write!(s, " | {:>9.3}", geometric_mean(&col));
    }
    let _ = writeln!(s, " | {:>9.3}", t.overall_err());
    s
}

/// Aggregate accounting of one prediction-service run (assembled by
/// [`crate::service::Service::summary`]): request/batch/error counts,
/// props-cache effectiveness, request-latency percentiles and the
/// extraction-time floor with cache hits excluded via the
/// [`crate::harness::Sample`] marker (a hit is a non-run, not a 0 s
/// run).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServiceSummary {
    pub requests: u64,
    pub errors: u64,
    pub batches: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// entries evicted by the props cache's second-chance policy
    pub cache_evictions: u64,
    /// distinct kernel structures extracted and cached
    pub distinct_kernels: usize,
    pub latency_p50_us: f64,
    pub latency_p90_us: f64,
    pub latency_p99_us: f64,
    pub latency_mean_us: f64,
    /// minimum symbolic-extraction time over the *timed* (cache-miss)
    /// extractions; `None` when every request hit the cache
    pub min_extract_us: Option<f64>,
    /// requests shed by the bounded pending queue / connection guard
    pub shed: u64,
    /// requests answered with a `"reason": "deadline"` error
    pub deadline_expired: u64,
    /// predictions served by a degraded-mode fallback device
    pub degraded_served: u64,
    /// TCP connections dropped by the `conn.abort` fault site
    pub conn_aborted: u64,
    /// TCP connections delayed by the `conn.slow` fault site
    pub conn_slowed: u64,
    /// measurement cases quarantined by the engine's campaigns
    pub quarantined: u64,
    /// failed `accept` calls absorbed by the listener (counted per
    /// failure; the log is rate-limited per errno)
    pub accept_errors: u64,
    /// fd-exhaustion accept backoffs taken by the reactor transport
    pub accept_backoffs: u64,
    /// formation-queue depth gauge after the reactor's last dispatch
    /// round (0 under the threaded transport)
    pub queue_depth: u64,
    /// formed-batch width percentiles (requests per executor batch):
    /// a mean above 1 proves cross-connection coalescing engaged
    pub batch_p50: f64,
    pub batch_p99: f64,
    pub batch_mean: f64,
}

impl ServiceSummary {
    /// Cache hit rate in [0, 1]; 0 when nothing was looked up.
    pub fn hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("cache_hits", Json::Num(self.cache_hits as f64)),
            ("cache_misses", Json::Num(self.cache_misses as f64)),
            ("cache_evictions", Json::Num(self.cache_evictions as f64)),
            ("distinct_kernels", Json::Num(self.distinct_kernels as f64)),
            ("hit_rate", Json::Num(self.hit_rate())),
            ("latency_p50_us", Json::Num(self.latency_p50_us)),
            ("latency_p90_us", Json::Num(self.latency_p90_us)),
            ("latency_p99_us", Json::Num(self.latency_p99_us)),
            ("latency_mean_us", Json::Num(self.latency_mean_us)),
            (
                "min_extract_us",
                self.min_extract_us.map(Json::Num).unwrap_or(Json::Null),
            ),
            ("shed", Json::Num(self.shed as f64)),
            ("deadline_expired", Json::Num(self.deadline_expired as f64)),
            ("degraded_served", Json::Num(self.degraded_served as f64)),
            ("conn_aborted", Json::Num(self.conn_aborted as f64)),
            ("conn_slowed", Json::Num(self.conn_slowed as f64)),
            ("quarantined", Json::Num(self.quarantined as f64)),
            ("accept_errors", Json::Num(self.accept_errors as f64)),
            ("accept_backoffs", Json::Num(self.accept_backoffs as f64)),
            ("queue_depth", Json::Num(self.queue_depth as f64)),
            ("batch_p50", Json::Num(self.batch_p50)),
            ("batch_p99", Json::Num(self.batch_p99)),
            ("batch_mean", Json::Num(self.batch_mean)),
        ])
    }

    /// Anything the robustness layer had to absorb (shed load, expired
    /// deadlines, degraded fallbacks, chaos-dropped connections,
    /// quarantined measurements)?
    pub fn any_degradation(&self) -> bool {
        self.shed != 0
            || self.deadline_expired != 0
            || self.degraded_served != 0
            || self.conn_aborted != 0
            || self.conn_slowed != 0
            || self.quarantined != 0
            || self.accept_errors != 0
            || self.accept_backoffs != 0
    }
}

/// Render the prediction-service summary.
pub fn render_service(s: &ServiceSummary) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "Prediction service summary");
    let _ = writeln!(
        out,
        "requests {}  errors {}  batches {}",
        s.requests, s.errors, s.batches
    );
    let _ = writeln!(
        out,
        "props cache: {} distinct kernels, {} hits / {} misses ({:.1}% hit rate), \
         {} evictions",
        s.distinct_kernels,
        s.cache_hits,
        s.cache_misses,
        100.0 * s.hit_rate(),
        s.cache_evictions
    );
    let _ = writeln!(
        out,
        "latency: p50 {:.1} µs  p90 {:.1} µs  p99 {:.1} µs  mean {:.1} µs",
        s.latency_p50_us, s.latency_p90_us, s.latency_p99_us, s.latency_mean_us
    );
    if s.batch_mean > 0.0 {
        let _ = writeln!(
            out,
            "batch width: p50 {:.0}  p99 {:.0}  mean {:.1}  (queue depth {})",
            s.batch_p50, s.batch_p99, s.batch_mean, s.queue_depth
        );
    }
    match s.min_extract_us {
        Some(t) => {
            let _ = writeln!(
                out,
                "extraction: min {:.1} µs over {} timed extractions ({} cached hits excluded)",
                t, s.cache_misses, s.cache_hits
            );
        }
        None => {
            let _ = writeln!(out, "extraction: all requests served from cache");
        }
    }
    // only when something was absorbed: a healthy run's report is
    // byte-identical to the pre-robustness format
    if s.any_degradation() {
        let _ = writeln!(
            out,
            "robustness: {} shed  {} deadline-expired  {} degraded  \
             {} conn aborted  {} conn slowed  {} quarantined  \
             {} accept errors  {} accept backoffs",
            s.shed,
            s.deadline_expired,
            s.degraded_served,
            s.conn_aborted,
            s.conn_slowed,
            s.quarantined,
            s.accept_errors,
            s.accept_backoffs
        );
    }
    out
}

/// Render the paper's Table 2: the fitted weight vector with
/// per-property labels, in units of seconds per operation.
pub fn render_table2(model: &Model, schema: &Schema) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "Property weights for {} (seconds per operation)", model.device);
    let _ = writeln!(s, "{:<42} {:>12}", "Property", "Weight");
    let _ = writeln!(s, "{}", "-".repeat(56));
    for (label, w) in model.weight_report(schema) {
        let _ = writeln!(s, "{:<42} {:>12.3e}", label, w);
    }
    let _ = writeln!(s, "{}", "-".repeat(56));
    let _ = writeln!(
        s,
        "training geomean relative error: {:.1}%  (solver: {})",
        100.0 * model.train_rel_err_geomean,
        model.solver
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_table() -> Table1 {
        let mut t = Table1::default();
        for (dev, k, case, p, a) in [
            ("titan_x", "fd5", "a", 0.32e-3, 0.41e-3),
            ("titan_x", "fd5", "b", 1.03e-3, 1.39e-3),
            ("titan_x", "nbody", "a", 0.48e-3, 0.16e-3),
            ("k40c", "fd5", "a", 0.70e-3, 0.70e-3),
            ("k40c", "nbody", "a", 0.99e-3, 0.24e-3),
        ] {
            t.push(Table1Entry {
                device: dev.into(),
                kernel: k.into(),
                case: case.into(),
                predicted_s: p,
                actual_s: a,
            });
        }
        t
    }

    #[test]
    fn geomeans_match_hand_computation() {
        let t = sample_table();
        // fd5 on titan_x: errs 0.2195..., 0.259
        let e1: f64 = (0.41 - 0.32) / 0.41;
        let e2: f64 = (1.39 - 1.03) / 1.39;
        let want = (e1 * e2).sqrt();
        assert!((t.kernel_device_err("fd5", "titan_x") - want).abs() < 1e-12);
        // nbody is the worst kernel in this sample
        assert!(t.kernel_err("nbody") > t.kernel_err("fd5"));
    }

    #[test]
    fn render_contains_all_sections() {
        let r = sample_table().render();
        for needle in ["fd5", "nbody", "titan_x", "k40c", "cross-kernel", "a.", "b."] {
            assert!(r.contains(needle), "missing {needle}:\n{r}");
        }
    }

    #[test]
    fn render_crossval_has_matrix_and_marginals() {
        let r = render_crossval("leave-one-kernel-out", &sample_table());
        for needle in [
            "leave-one-kernel-out",
            "fd5",
            "nbody",
            "titan_x",
            "k40c",
            "cross-GPU",
            "cross-kernel",
            "overall held-out geomean",
        ] {
            assert!(r.contains(needle), "missing {needle}:\n{r}");
        }
    }

    #[test]
    fn devices_and_kernels_in_first_seen_order() {
        let t = sample_table();
        assert_eq!(t.devices(), vec!["titan_x".to_string(), "k40c".to_string()]);
        assert_eq!(t.kernels(), vec!["fd5".to_string(), "nbody".to_string()]);
    }

    fn sample_transfer() -> TransferMatrix {
        TransferMatrix {
            devices: vec!["titan_x".into(), "k40c".into()],
            err: vec![vec![None, Some(0.2)], vec![Some(0.4), None]],
        }
    }

    #[test]
    fn transfer_matrix_lookup_and_marginals() {
        let t = sample_transfer();
        assert_eq!(t.get("titan_x", "k40c"), Some(0.2));
        assert_eq!(t.get("k40c", "titan_x"), Some(0.4));
        assert_eq!(t.get("titan_x", "titan_x"), None);
        assert_eq!(t.get("titan_x", "gtx480"), None);
        let want = (0.2f64 * 0.4).sqrt();
        assert!((t.overall_err() - want).abs() < 1e-12);
    }

    #[test]
    fn render_transfer_has_matrix_shape() {
        let r = render_transfer(&sample_transfer());
        for needle in ["titan_x", "k40c", "fit \\ pred", "geomean", "0.200", "0.400"] {
            assert!(r.contains(needle), "missing {needle}:\n{r}");
        }
        // one dash cell per diagonal entry
        assert_eq!(r.matches(" |         -").count(), 2, "{r}");
    }

    #[test]
    fn render_service_reports_cache_and_latency() {
        let s = ServiceSummary {
            requests: 288,
            errors: 0,
            batches: 5,
            cache_hits: 270,
            cache_misses: 18,
            cache_evictions: 3,
            distinct_kernels: 15,
            latency_p50_us: 12.3,
            latency_p90_us: 96.0,
            latency_p99_us: 180.0,
            latency_mean_us: 20.1,
            min_extract_us: Some(812.0),
            ..ServiceSummary::default()
        };
        assert!((s.hit_rate() - 270.0 / 288.0).abs() < 1e-12);
        let r = render_service(&s);
        // a healthy run shows no robustness line at all
        assert!(!r.contains("robustness:"), "{r}");
        assert!(!s.any_degradation());
        for needle in [
            "requests 288",
            "batches 5",
            "270 hits / 18 misses",
            "3 evictions",
            "p50 12.3",
            "p90 96.0",
            "p99 180.0",
            "min 812.0",
            "cached hits excluded",
        ] {
            assert!(r.contains(needle), "missing {needle}:\n{r}");
        }
        // an all-hit run has no timed extraction to report
        let warm = ServiceSummary { min_extract_us: None, ..s };
        assert!(render_service(&warm).contains("all requests served from cache"));
        assert_eq!(ServiceSummary::default().hit_rate(), 0.0);
        assert_eq!(warm.to_json().get("min_extract_us"), Some(&Json::Null));
        // a degraded run reports what was absorbed
        let rough = ServiceSummary { shed: 4, quarantined: 2, ..warm };
        assert!(rough.any_degradation());
        let r = render_service(&rough);
        assert!(r.contains("robustness: 4 shed"), "{r}");
        assert!(r.contains("2 quarantined"), "{r}");
        assert_eq!(rough.to_json().get_f64("shed"), Some(4.0));
    }

    #[test]
    fn transfer_matrix_json_shape() {
        let j = sample_transfer().to_json();
        let devs = j.get("devices").and_then(crate::util::json::Json::as_arr).unwrap();
        assert_eq!(devs.len(), 2);
        let err = j.get("err").and_then(crate::util::json::Json::as_arr).unwrap();
        assert_eq!(err[0].as_arr().unwrap()[0], crate::util::json::Json::Null);
        assert_eq!(err[0].as_arr().unwrap()[1].as_f64(), Some(0.2));
    }
}
