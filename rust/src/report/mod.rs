//! Report generation: the paper's Table 1 (predicted vs. actual times +
//! geometric-mean relative errors) and Table 2 (fitted weights).

use crate::perfmodel::Model;
use crate::stats::Schema;
use crate::util::linalg::geometric_mean;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One Table-1 cell: a test-kernel size case on one device.
#[derive(Clone, Debug)]
pub struct Table1Entry {
    pub device: String,
    /// kernel display name, e.g. `fd5`
    pub kernel: String,
    /// size case letter `a`–`d`
    pub case: String,
    pub predicted_s: f64,
    pub actual_s: f64,
}

impl Table1Entry {
    pub fn rel_err(&self) -> f64 {
        Model::rel_err(self.predicted_s, self.actual_s)
    }
}

/// The assembled Table 1.
#[derive(Clone, Debug, Default)]
pub struct Table1 {
    pub entries: Vec<Table1Entry>,
}

impl Table1 {
    pub fn push(&mut self, e: Table1Entry) {
        self.entries.push(e);
    }

    pub fn devices(&self) -> Vec<String> {
        let mut v: Vec<String> = Vec::new();
        for e in &self.entries {
            if !v.contains(&e.device) {
                v.push(e.device.clone());
            }
        }
        v
    }

    pub fn kernels(&self) -> Vec<String> {
        let mut v: Vec<String> = Vec::new();
        for e in &self.entries {
            if !v.contains(&e.kernel) {
                v.push(e.kernel.clone());
            }
        }
        v
    }

    /// Geometric-mean relative error of one kernel on one device.
    pub fn kernel_device_err(&self, kernel: &str, device: &str) -> f64 {
        let errs: Vec<f64> = self
            .entries
            .iter()
            .filter(|e| e.kernel == kernel && e.device == device)
            .map(|e| e.rel_err())
            .collect();
        geometric_mean(&errs)
    }

    /// Cross-kernel geometric mean for one device (Table 1's bottom row).
    pub fn device_err(&self, device: &str) -> f64 {
        let errs: Vec<f64> = self
            .kernels()
            .iter()
            .map(|k| self.kernel_device_err(k, device))
            .collect();
        geometric_mean(&errs)
    }

    /// Cross-GPU geometric mean for one kernel (Table 1's last column).
    pub fn kernel_err(&self, kernel: &str) -> f64 {
        let errs: Vec<f64> = self
            .devices()
            .iter()
            .map(|d| self.kernel_device_err(kernel, d))
            .collect();
        geometric_mean(&errs)
    }

    /// Overall geometric mean across kernels and devices.
    pub fn overall_err(&self) -> f64 {
        let errs: Vec<f64> = self.entries.iter().map(|e| e.rel_err()).collect();
        geometric_mean(&errs)
    }

    /// Render in the layout of the paper's Table 1: per kernel, one row
    /// per size case with predicted/actual (ms) pairs per device, plus
    /// geometric-mean error rows.
    pub fn render(&self) -> String {
        let devices = self.devices();
        let kernels = self.kernels();
        let mut s = String::new();
        let _ = write!(s, "{:<14}", "Kernel");
        for d in &devices {
            let _ = write!(s, " | {:>19}", d);
        }
        let _ = writeln!(s, " | cross-GPU");
        let _ = write!(s, "{:<14}", "");
        for _ in &devices {
            let _ = write!(s, " | {:>9} {:>9}", "pred(ms)", "act(ms)");
        }
        let _ = writeln!(s, " |  geomean");
        let line_len = 14 + devices.len() * 22 + 11;
        let _ = writeln!(s, "{}", "-".repeat(line_len));
        for k in &kernels {
            // per-device geomean header row for this kernel
            let _ = write!(s, "{:<14}", k);
            for d in &devices {
                let _ = write!(s, " | {:>19.2}", self.kernel_device_err(k, d));
            }
            let _ = writeln!(s, " | {:>8.2}", self.kernel_err(k));
            // the a.-d. case rows
            let cases: Vec<&Table1Entry> =
                self.entries.iter().filter(|e| &e.kernel == k).collect();
            let mut letters: Vec<&str> = cases.iter().map(|e| e.case.as_str()).collect();
            letters.sort();
            letters.dedup();
            for letter in letters {
                let _ = write!(s, "  {:<12}", format!("{letter}."));
                for d in &devices {
                    match cases
                        .iter()
                        .find(|e| e.case == letter && &e.device == d)
                    {
                        Some(e) => {
                            let _ = write!(
                                s,
                                " | {:>9.2} {:>9.2}",
                                e.predicted_s * 1e3,
                                e.actual_s * 1e3
                            );
                        }
                        None => {
                            let _ = write!(s, " | {:>9} {:>9}", "-", "-");
                        }
                    }
                }
                let _ = writeln!(s, " |");
            }
        }
        let _ = writeln!(s, "{}", "-".repeat(line_len));
        let _ = write!(s, "{:<14}", "cross-kernel");
        for d in &devices {
            let _ = write!(s, " | {:>19.2}", self.device_err(d));
        }
        let _ = writeln!(s, " | {:>8.2}", self.overall_err());
        s
    }

    /// Map (kernel -> (device -> geomean error)) for programmatic checks.
    pub fn error_matrix(&self) -> BTreeMap<String, BTreeMap<String, f64>> {
        let mut out = BTreeMap::new();
        for k in self.kernels() {
            let mut row = BTreeMap::new();
            for d in self.devices() {
                row.insert(d.clone(), self.kernel_device_err(&k, &d));
            }
            out.insert(k, row);
        }
        out
    }
}

/// Render a cross-validation summary: a Table-1-style matrix of
/// *held-out* geometric-mean relative errors (kernel × device) with the
/// cross-kernel and cross-GPU marginals and the overall geomean. The
/// entries of `t` are predictions from models that never saw the
/// corresponding kernel (or size case) during fitting.
pub fn render_crossval(split_label: &str, t: &Table1) -> String {
    let devices = t.devices();
    let kernels = t.kernels();
    let mut s = String::new();
    let _ = writeln!(
        s,
        "Cross-validation ({split_label}): held-out geometric-mean relative error"
    );
    let _ = write!(s, "{:<14}", "Kernel");
    for d in &devices {
        let _ = write!(s, " | {:>9}", d);
    }
    let _ = writeln!(s, " | cross-GPU");
    let line_len = 14 + devices.len() * 12 + 12;
    let _ = writeln!(s, "{}", "-".repeat(line_len));
    for k in &kernels {
        let _ = write!(s, "{:<14}", k);
        for d in &devices {
            let _ = write!(s, " | {:>9.3}", t.kernel_device_err(k, d));
        }
        let _ = writeln!(s, " | {:>9.3}", t.kernel_err(k));
    }
    let _ = writeln!(s, "{}", "-".repeat(line_len));
    let _ = write!(s, "{:<14}", "cross-kernel");
    for d in &devices {
        let _ = write!(s, " | {:>9.3}", t.device_err(d));
    }
    let _ = writeln!(s, " | {:>9.3}", t.overall_err());
    let _ = writeln!(
        s,
        "overall held-out geomean relative error: {:.3}",
        t.overall_err()
    );
    s
}

/// Render the paper's Table 2: the fitted weight vector with
/// per-property labels, in units of seconds per operation.
pub fn render_table2(model: &Model, schema: &Schema) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "Property weights for {} (seconds per operation)", model.device);
    let _ = writeln!(s, "{:<42} {:>12}", "Property", "Weight");
    let _ = writeln!(s, "{}", "-".repeat(56));
    for (label, w) in model.weight_report(schema) {
        let _ = writeln!(s, "{:<42} {:>12.3e}", label, w);
    }
    let _ = writeln!(s, "{}", "-".repeat(56));
    let _ = writeln!(
        s,
        "training geomean relative error: {:.1}%  (solver: {})",
        100.0 * model.train_rel_err_geomean,
        model.solver
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_table() -> Table1 {
        let mut t = Table1::default();
        for (dev, k, case, p, a) in [
            ("titan_x", "fd5", "a", 0.32e-3, 0.41e-3),
            ("titan_x", "fd5", "b", 1.03e-3, 1.39e-3),
            ("titan_x", "nbody", "a", 0.48e-3, 0.16e-3),
            ("k40c", "fd5", "a", 0.70e-3, 0.70e-3),
            ("k40c", "nbody", "a", 0.99e-3, 0.24e-3),
        ] {
            t.push(Table1Entry {
                device: dev.into(),
                kernel: k.into(),
                case: case.into(),
                predicted_s: p,
                actual_s: a,
            });
        }
        t
    }

    #[test]
    fn geomeans_match_hand_computation() {
        let t = sample_table();
        // fd5 on titan_x: errs 0.2195..., 0.259
        let e1: f64 = (0.41 - 0.32) / 0.41;
        let e2: f64 = (1.39 - 1.03) / 1.39;
        let want = (e1 * e2).sqrt();
        assert!((t.kernel_device_err("fd5", "titan_x") - want).abs() < 1e-12);
        // nbody is the worst kernel in this sample
        assert!(t.kernel_err("nbody") > t.kernel_err("fd5"));
    }

    #[test]
    fn render_contains_all_sections() {
        let r = sample_table().render();
        for needle in ["fd5", "nbody", "titan_x", "k40c", "cross-kernel", "a.", "b."] {
            assert!(r.contains(needle), "missing {needle}:\n{r}");
        }
    }

    #[test]
    fn render_crossval_has_matrix_and_marginals() {
        let r = render_crossval("leave-one-kernel-out", &sample_table());
        for needle in [
            "leave-one-kernel-out",
            "fd5",
            "nbody",
            "titan_x",
            "k40c",
            "cross-GPU",
            "cross-kernel",
            "overall held-out geomean",
        ] {
            assert!(r.contains(needle), "missing {needle}:\n{r}");
        }
    }

    #[test]
    fn devices_and_kernels_in_first_seen_order() {
        let t = sample_table();
        assert_eq!(t.devices(), vec!["titan_x".to_string(), "k40c".to_string()]);
        assert_eq!(t.kernels(), vec!["fd5".to_string(), "nbody".to_string()]);
    }
}
