//! Expression AST for kernel instructions.
//!
//! Instructions are scalar assignments `lhs = rhs` (paper §3.1) whose
//! right-hand sides contain arithmetic, array loads, and `reduce`
//! expressions over reduction inames. Index expressions are affine
//! ([`LinExpr`]) so the polyhedral analyses stay exact.

use crate::qpoly::LinExpr;
use crate::util::intern::Sym;
use std::fmt;

/// Scalar element types. The paper's model classifies operations and
/// accesses by 32-bit / 64-bit operand types (§2.2); 128-bit accesses
/// arise from 4-wide vector types.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DType {
    F32,
    F64,
    /// 4-wide f32 vector (one 128-bit access)
    F32x4,
    I32,
}

impl DType {
    /// Size of one element in bytes.
    pub fn size_bytes(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F64 => 8,
            DType::F32x4 => 16,
        }
    }

    /// Access-size bucket in bits (32 / 64 / 128) as used by the model.
    pub fn access_bits(&self) -> u32 {
        (self.size_bytes() * 8) as u32
    }

    /// Promotion for binary arithmetic.
    pub fn promote(a: DType, b: DType) -> DType {
        use DType::*;
        match (a, b) {
            (F64, _) | (_, F64) => F64,
            (F32x4, _) | (_, F32x4) => F32x4,
            (F32, _) | (_, F32) => F32,
            (I32, I32) => I32,
        }
    }

    pub fn is_float(&self) -> bool {
        !matches!(self, DType::I32)
    }
}

/// Operation-kind categories of the model (§2.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum OpKind {
    /// addition and subtraction share a category
    AddSub,
    Mul,
    Div,
    /// exponentiation (pow, exp)
    Exp,
    /// other special functions (rsqrt, sqrt, sin, ...)
    Special,
}

impl OpKind {
    pub fn all() -> [OpKind; 5] {
        [OpKind::AddSub, OpKind::Mul, OpKind::Div, OpKind::Exp, OpKind::Special]
    }

    pub fn label(&self) -> &'static str {
        match self {
            OpKind::AddSub => "add/sub",
            OpKind::Mul => "mul",
            OpKind::Div => "div",
            OpKind::Exp => "exp",
            OpKind::Special => "special",
        }
    }
}

/// Binary operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    /// float power
    Pow,
    Min,
    Max,
}

impl BinOp {
    pub fn op_kind(&self) -> OpKind {
        match self {
            BinOp::Add | BinOp::Sub | BinOp::Min | BinOp::Max => OpKind::AddSub,
            BinOp::Mul => OpKind::Mul,
            BinOp::Div => OpKind::Div,
            BinOp::Pow => OpKind::Exp,
        }
    }
}

/// Unary operators / intrinsic calls.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum UnOp {
    Neg,
    Sqrt,
    Rsqrt,
    Exp,
    Sin,
    Cos,
    Abs,
}

impl UnOp {
    pub fn op_kind(&self) -> OpKind {
        match self {
            UnOp::Neg => OpKind::AddSub,
            UnOp::Exp => OpKind::Exp,
            UnOp::Sqrt | UnOp::Rsqrt | UnOp::Sin | UnOp::Cos | UnOp::Abs => OpKind::Special,
        }
    }
}

/// Reduction operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RedOp {
    Sum,
    Max,
}

/// An array access with affine index expressions (over inames + params).
#[derive(Clone, Debug, PartialEq)]
pub struct Access {
    pub array: Sym,
    pub idx: Vec<LinExpr>,
}

impl Access {
    pub fn new(array: &str, idx: Vec<LinExpr>) -> Access {
        Access { array: Sym::intern(array), idx }
    }
}

impl fmt::Display for Access {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[", self.array)?;
        for (i, e) in self.idx.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{e}")?;
        }
        write!(f, "]")
    }
}

/// Right-hand-side expression.
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    /// floating literal
    Lit(f64),
    /// value of an iname or parameter (as a float)
    Idx(LinExpr),
    /// array load
    Load(Access),
    Un(UnOp, Box<Expr>),
    Bin(BinOp, Box<Expr>, Box<Expr>),
    /// explicit type conversion (e.g. index -> f64 for double-precision
    /// arithmetic kernels); conversions are not counted as arithmetic
    Cast(DType, Box<Expr>),
    /// `reduce(op, iname, body)` — body evaluated over the reduction
    /// iname's domain slice
    Reduce(RedOp, Sym, Box<Expr>),
}

impl Expr {
    pub fn lit(x: f64) -> Expr {
        Expr::Lit(x)
    }

    pub fn load(array: &str, idx: Vec<LinExpr>) -> Expr {
        Expr::Load(Access::new(array, idx))
    }

    pub fn bin(op: BinOp, a: Expr, b: Expr) -> Expr {
        Expr::Bin(op, Box::new(a), Box::new(b))
    }

    pub fn add(a: Expr, b: Expr) -> Expr {
        Expr::bin(BinOp::Add, a, b)
    }

    pub fn sub(a: Expr, b: Expr) -> Expr {
        Expr::bin(BinOp::Sub, a, b)
    }

    pub fn mul(a: Expr, b: Expr) -> Expr {
        Expr::bin(BinOp::Mul, a, b)
    }

    pub fn div(a: Expr, b: Expr) -> Expr {
        Expr::bin(BinOp::Div, a, b)
    }

    pub fn un(op: UnOp, a: Expr) -> Expr {
        Expr::Un(op, Box::new(a))
    }

    pub fn sum(iname: &str, body: Expr) -> Expr {
        Expr::Reduce(RedOp::Sum, Sym::intern(iname), Box::new(body))
    }

    pub fn cast(dtype: DType, e: Expr) -> Expr {
        Expr::Cast(dtype, Box::new(e))
    }

    /// Visit every load access, with the set of enclosing reduction inames.
    pub fn visit_loads<'a>(&'a self, f: &mut impl FnMut(&'a Access, &[Sym])) {
        fn go<'a>(
            e: &'a Expr,
            red: &mut Vec<Sym>,
            f: &mut impl FnMut(&'a Access, &[Sym]),
        ) {
            match e {
                Expr::Lit(_) | Expr::Idx(_) => {}
                Expr::Load(a) => f(a, red),
                Expr::Un(_, x) | Expr::Cast(_, x) => go(x, red, f),
                Expr::Bin(_, a, b) => {
                    go(a, red, f);
                    go(b, red, f);
                }
                Expr::Reduce(_, iname, body) => {
                    red.push(*iname);
                    go(body, red, f);
                    red.pop();
                }
            }
        }
        go(self, &mut Vec::new(), f)
    }

    /// Reduction inames used anywhere in this expression.
    pub fn reduction_inames(&self) -> Vec<Sym> {
        let mut out = Vec::new();
        fn go(e: &Expr, out: &mut Vec<Sym>) {
            match e {
                Expr::Un(_, x) | Expr::Cast(_, x) => go(x, out),
                Expr::Bin(_, a, b) => {
                    go(a, out);
                    go(b, out);
                }
                Expr::Reduce(_, iname, body) => {
                    if !out.contains(iname) {
                        out.push(*iname);
                    }
                    go(body, out);
                }
                _ => {}
            }
        }
        go(self, &mut out);
        out
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Lit(x) => write!(f, "{x}"),
            Expr::Idx(e) => write!(f, "({e})"),
            Expr::Load(a) => write!(f, "{a}"),
            Expr::Un(op, x) => write!(f, "{op:?}({x})"),
            Expr::Cast(dt, x) => write!(f, "({dt:?})({x})"),
            Expr::Bin(op, a, b) => write!(f, "({a} {op:?} {b})"),
            Expr::Reduce(op, iname, body) => write!(f, "reduce({op:?}, {iname}, {body})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qpoly::LinExpr;

    #[test]
    fn dtype_sizes_and_bits() {
        assert_eq!(DType::F32.access_bits(), 32);
        assert_eq!(DType::F64.access_bits(), 64);
        assert_eq!(DType::F32x4.access_bits(), 128);
        assert_eq!(DType::promote(DType::F32, DType::F64), DType::F64);
        assert_eq!(DType::promote(DType::I32, DType::F32), DType::F32);
    }

    #[test]
    fn op_kind_mapping() {
        assert_eq!(BinOp::Sub.op_kind(), OpKind::AddSub);
        assert_eq!(BinOp::Pow.op_kind(), OpKind::Exp);
        assert_eq!(UnOp::Rsqrt.op_kind(), OpKind::Special);
    }

    #[test]
    fn visit_loads_tracks_reduction_scope() {
        // sum(k, a[i,k] * b[k,j]) + c[i]
        let e = Expr::add(
            Expr::sum(
                "k",
                Expr::mul(
                    Expr::load("a", vec![LinExpr::var("i"), LinExpr::var("k")]),
                    Expr::load("b", vec![LinExpr::var("k"), LinExpr::var("j")]),
                ),
            ),
            Expr::load("c", vec![LinExpr::var("i")]),
        );
        let mut seen = Vec::new();
        e.visit_loads(&mut |a, red| seen.push((a.array, red.to_vec())));
        assert_eq!(seen.len(), 3);
        assert_eq!(seen[0], (Sym::intern("a"), vec![Sym::intern("k")]));
        assert_eq!(seen[1], (Sym::intern("b"), vec![Sym::intern("k")]));
        assert_eq!(seen[2], (Sym::intern("c"), vec![]));
        assert_eq!(e.reduction_inames(), vec![Sym::intern("k")]);
    }
}
