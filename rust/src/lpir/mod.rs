//! `lpir` — the polyhedral kernel IR (the Loopy analogue; paper §3.1).
//!
//! A [`Kernel`] consists of:
//! * a rectangular parametric *loop domain* ([`crate::isl::BoxDomain`]),
//! * *iname tags* mapping loop variables onto the GPU execution grid
//!   (group/local axes) or marking them sequential,
//! * *array declarations* in global, local (work-group shared), or
//!   private (register) memory,
//! * scalar-assignment *instructions* with affine array indices and an
//!   explicit dependency DAG.
//!
//! The IR is the substrate for everything else: [`crate::stats`] extracts
//! model properties from it, [`crate::schedule`] linearizes it and inserts
//! barriers, and [`crate::gpusim`] interprets it (numerically and for
//! simulated timing).

pub mod expr;
pub mod builder;

pub use expr::{Access, BinOp, DType, Expr, OpKind, RedOp, UnOp};

use crate::isl::BoxDomain;
use crate::qpoly::LinExpr;
use crate::util::intern::{Env, Sym};
use std::collections::BTreeMap;

/// How an iname maps onto the execution grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IdxTag {
    /// OpenCL work-group index along grid axis `0` or `1`
    Group(usize),
    /// OpenCL local (within-group) index along axis `0` or `1`; axis 0 is
    /// the SIMD-lane axis used for stride analysis
    Local(usize),
    /// ordinary sequential loop
    Seq,
    /// fully unrolled loop (sequential for analysis purposes)
    Unroll,
}

/// Memory space of an array.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemSpace {
    /// off-chip device memory
    Global,
    /// on-chip work-group shared memory ("local" in OpenCL terms)
    Local,
    /// per-thread registers (not modeled as memory traffic)
    Private,
}

/// Data layout of a multi-dimensional array.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    RowMajor,
    ColMajor,
}

/// An array declaration (kernel argument or temporary).
#[derive(Clone, Debug, PartialEq)]
pub struct ArrayDecl {
    pub name: Sym,
    pub dtype: DType,
    /// per-axis extents, affine in the kernel parameters
    pub shape: Vec<LinExpr>,
    pub space: MemSpace,
    pub layout: Layout,
    /// written by the kernel (outputs are validated by the simulator)
    pub is_output: bool,
}

impl ArrayDecl {
    /// Element strides (in elements) for the flattened linear index,
    /// symbolic in the parameters. Row-major: last axis has stride 1.
    pub fn elem_strides(&self) -> Vec<crate::qpoly::QPoly> {
        use crate::qpoly::QPoly;
        let d = self.shape.len();
        let mut strides = vec![QPoly::one(); d];
        match self.layout {
            Layout::RowMajor => {
                for a in (0..d.saturating_sub(1)).rev() {
                    strides[a] = strides[a + 1].mul(&QPoly::from_lin(&self.shape[a + 1]));
                }
            }
            Layout::ColMajor => {
                for a in 1..d {
                    strides[a] = strides[a - 1].mul(&QPoly::from_lin(&self.shape[a - 1]));
                }
            }
        }
        strides
    }

    /// Concrete extents at a parameter binding.
    pub fn extents_at(&self, env: &Env) -> Result<Vec<i64>, String> {
        self.shape.iter().map(|e| e.eval(env)).collect()
    }
}

/// One scalar-assignment instruction.
#[derive(Clone, Debug, PartialEq)]
pub struct Insn {
    pub id: usize,
    pub lhs: Access,
    pub rhs: Expr,
    /// inames the instruction is nested within (its execution domain is
    /// the projection of the kernel domain onto these); reduction inames
    /// inside `rhs` are *not* listed here
    pub within: Vec<Sym>,
    /// instruction dependencies (must be scheduled earlier)
    pub deps: Vec<usize>,
    /// update (`lhs op= rhs`) rather than plain assignment — used for
    /// accumulators whose reduction is expressed across a sequential loop
    pub is_update: bool,
}

/// A kernel: domain + tags + arrays + instructions (paper §3.1).
#[derive(Clone, Debug, PartialEq)]
pub struct Kernel {
    pub name: String,
    /// size parameters (`n`, `m`, ...)
    pub params: Vec<Sym>,
    pub domain: BoxDomain,
    pub tags: BTreeMap<Sym, IdxTag>,
    pub arrays: Vec<ArrayDecl>,
    pub insns: Vec<Insn>,
}

impl Kernel {
    pub fn array<S: Into<Sym>>(&self, name: S) -> Option<&ArrayDecl> {
        let sym = name.into();
        self.arrays.iter().find(|a| a.name == sym)
    }

    pub fn tag<S: Into<Sym>>(&self, iname: S) -> IdxTag {
        self.tags.get(&iname.into()).copied().unwrap_or(IdxTag::Seq)
    }

    /// inames tagged `Local(axis)`, keyed by axis.
    pub fn local_inames(&self) -> BTreeMap<usize, Sym> {
        self.tags
            .iter()
            .filter_map(|(n, t)| match t {
                IdxTag::Local(a) => Some((*a, *n)),
                _ => None,
            })
            .collect()
    }

    /// inames tagged `Group(axis)`, keyed by axis.
    pub fn group_inames(&self) -> BTreeMap<usize, Sym> {
        self.tags
            .iter()
            .filter_map(|(n, t)| match t {
                IdxTag::Group(a) => Some((*a, *n)),
                _ => None,
            })
            .collect()
    }

    /// Work-group size `(local0, local1)` at a parameter binding. Axes
    /// without a local iname have extent 1.
    pub fn group_size_at(&self, env: &Env) -> Result<(i64, i64), String> {
        let locals = self.local_inames();
        let mut out = [1i64, 1];
        for (axis, iname) in locals {
            let dim = self
                .domain
                .dim(iname)
                .ok_or_else(|| format!("local iname '{iname}' not in domain"))?;
            out[axis.min(1)] = dim.trip_count_at(env)?;
        }
        Ok((out[0], out[1]))
    }

    /// Number of work groups launched at a parameter binding.
    pub fn group_count_at(&self, env: &Env) -> Result<i64, String> {
        let groups = self.group_inames();
        let mut n = 1i64;
        for (_, iname) in groups {
            let dim = self
                .domain
                .dim(iname)
                .ok_or_else(|| format!("group iname '{iname}' not in domain"))?;
            n *= dim.trip_count_at(env)?;
        }
        Ok(n)
    }

    /// Symbolic work-group count (the launch-overhead property, §2.4).
    pub fn group_count(&self) -> crate::qpoly::PwQPoly {
        use crate::qpoly::{PwQPoly, QPoly};
        let mut q = QPoly::one();
        let mut guards = Vec::new();
        for (_, iname) in self.group_inames() {
            if let Some(dim) = self.domain.dim(iname) {
                q = q.mul(&dim.trip_count());
                let g = dim.nonempty_guard();
                if !g.0.is_constant() {
                    guards.push(g);
                }
            }
        }
        PwQPoly { pieces: vec![(guards, q)] }
    }

    /// The execution domain of an instruction: projection of the kernel
    /// domain onto `within` plus any reduction inames in its RHS
    /// (Algorithm 1 of the paper takes the projection onto the "relevant
    /// set of loop indices").
    pub fn insn_domain(&self, insn: &Insn, include_reductions: bool) -> BoxDomain {
        let mut names: Vec<Sym> = insn.within.clone();
        if include_reductions {
            for r in insn.rhs.reduction_inames() {
                if !names.contains(&r) {
                    names.push(r);
                }
            }
        }
        self.domain.project_onto(&names)
    }

    /// Structural validation: every iname referenced exists in the
    /// domain, every accessed array is declared, every dep id exists,
    /// and index arities match array ranks.
    pub fn validate(&self) -> Result<(), String> {
        let ids: Vec<usize> = self.insns.iter().map(|i| i.id).collect();
        for insn in &self.insns {
            for w in &insn.within {
                if self.domain.dim(*w).is_none() {
                    return Err(format!(
                        "insn {} references unknown iname '{w}'",
                        insn.id
                    ));
                }
            }
            for d in &insn.deps {
                if !ids.contains(d) {
                    return Err(format!("insn {} depends on unknown insn {d}", insn.id));
                }
            }
            let check_access = |a: &Access| -> Result<(), String> {
                let arr = self
                    .array(a.array)
                    .ok_or_else(|| format!("unknown array '{}'", a.array))?;
                if arr.shape.len() != a.idx.len() {
                    return Err(format!(
                        "access {} has {} indices, array has rank {}",
                        a,
                        a.idx.len(),
                        arr.shape.len()
                    ));
                }
                Ok(())
            };
            check_access(&insn.lhs)?;
            let mut err = None;
            insn.rhs.visit_loads(&mut |a, _| {
                if err.is_none() {
                    err = check_access(a).err();
                }
            });
            if let Some(e) = err {
                return Err(e);
            }
            for r in insn.rhs.reduction_inames() {
                if self.domain.dim(r).is_none() {
                    return Err(format!(
                        "insn {} reduces over unknown iname '{r}'",
                        insn.id
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isl::Dim;
    use crate::qpoly::{env, LinExpr};

    /// out[i] = 2*a[i], the paper's §3.1 example kernel.
    fn double_kernel() -> Kernel {
        builder::KernelBuilder::new("double", &["n"])
            .group_dims_1d(LinExpr::var("n"), 256)
            .global_array("a", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, false)
            .global_array("out", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("out", vec![builder::gid_lin_1d(256)]),
                Expr::mul(Expr::lit(2.0), Expr::load("a", vec![builder::gid_lin_1d(256)])),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap()
    }

    #[test]
    fn double_kernel_validates() {
        let k = double_kernel();
        assert_eq!(k.params, vec![crate::util::intern::Sym::intern("n")]);
        assert!(k.validate().is_ok());
    }

    #[test]
    fn group_size_and_count() {
        let k = double_kernel();
        let e = env(&[("n", 1024)]);
        assert_eq!(k.group_size_at(&e).unwrap(), (256, 1));
        assert_eq!(k.group_count_at(&e).unwrap(), 4);
        assert_eq!(k.group_count().eval(&e).unwrap(), 4.0);
    }

    #[test]
    fn insn_domain_projection() {
        let k = double_kernel();
        let d = k.insn_domain(&k.insns[0], true);
        assert_eq!(d.count().eval(&env(&[("n", 1024)])).unwrap(), 1024.0);
    }

    #[test]
    fn validate_catches_bad_array() {
        let mut k = double_kernel();
        k.insns[0].rhs = Expr::load("nope", vec![LinExpr::var("l0")]);
        assert!(k.validate().is_err());
    }

    #[test]
    fn validate_catches_bad_arity() {
        let mut k = double_kernel();
        k.insns[0].rhs = Expr::load("a", vec![LinExpr::var("l0"), LinExpr::var("g0")]);
        assert!(k.validate().is_err());
    }

    #[test]
    fn validate_catches_bad_dep() {
        let mut k = double_kernel();
        k.insns[0].deps = vec![99];
        assert!(k.validate().is_err());
    }

    #[test]
    fn elem_strides_row_vs_col() {
        let arr = ArrayDecl {
            name: "a".into(),
            dtype: DType::F32,
            shape: vec![LinExpr::var("n"), LinExpr::var("m")],
            space: MemSpace::Global,
            layout: Layout::RowMajor,
            is_output: false,
        };
        let s = arr.elem_strides();
        let e = env(&[("n", 4), ("m", 8)]);
        assert_eq!(s[0].eval(&e).unwrap(), 8.0);
        assert_eq!(s[1].eval(&e).unwrap(), 1.0);
        let col = ArrayDecl { layout: Layout::ColMajor, ..arr };
        let s = col.elem_strides();
        assert_eq!(s[0].eval(&e).unwrap(), 1.0);
        assert_eq!(s[1].eval(&e).unwrap(), 4.0);
    }

    #[test]
    fn kernel_dim_lookup_and_tags() {
        let k = double_kernel();
        assert_eq!(k.tag("g0"), IdxTag::Group(0));
        assert_eq!(k.tag("l0"), IdxTag::Local(0));
        assert_eq!(k.tag("unknown"), IdxTag::Seq);
        assert!(k.domain.dim("l0").is_some());
        assert_eq!(
            k.domain.dim("l0").unwrap(),
            &Dim::simple("l0", LinExpr::constant(256))
        );
    }
}
