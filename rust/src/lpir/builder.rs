//! Fluent construction of [`Kernel`]s.
//!
//! Mirrors the role of `loopy.make_kernel` + tagging transformations: the
//! builder creates grid inames (`g0`/`g1` group axes, `l0`/`l1` local
//! axes), sequential and reduction loops, array declarations, and
//! instructions, then validates the result.

use super::expr::{Access, DType, Expr};
use super::{ArrayDecl, IdxTag, Insn, Kernel, Layout, MemSpace};
use crate::isl::{BoxDomain, Dim};
use crate::qpoly::LinExpr;
use crate::util::intern::Sym;
use std::collections::BTreeMap;

/// Global-index expression `lsize * g<axis> + l<axis>`.
pub fn gid(axis: usize, lsize: i64) -> LinExpr {
    LinExpr::scaled_var(&format!("g{axis}"), lsize).add(&LinExpr::var(&format!("l{axis}")))
}

/// 1-D shorthand for [`gid`] on axis 0.
pub fn gid_lin_1d(lsize: i64) -> LinExpr {
    gid(0, lsize)
}

/// Builder for [`Kernel`].
pub struct KernelBuilder {
    name: String,
    params: Vec<Sym>,
    dims: Vec<Dim>,
    tags: BTreeMap<Sym, IdxTag>,
    arrays: Vec<ArrayDecl>,
    insns: Vec<Insn>,
}

impl KernelBuilder {
    pub fn new(name: &str, params: &[&str]) -> KernelBuilder {
        KernelBuilder {
            name: name.into(),
            params: params.iter().map(|s| Sym::intern(s)).collect(),
            dims: Vec::new(),
            tags: BTreeMap::new(),
            arrays: Vec::new(),
            insns: Vec::new(),
        }
    }

    /// 1-D grid: `g0` ranges over `ceil(total/lsize)` groups, `l0` over
    /// `lsize` lanes. Global index is [`gid_lin_1d`]`(lsize)`.
    pub fn group_dims_1d(mut self, total: LinExpr, lsize: i64) -> Self {
        self.dims.push(Dim::tiles("g0", total, lsize));
        self.dims.push(Dim::simple("l0", LinExpr::constant(lsize)));
        self.tags.insert("g0".into(), IdxTag::Group(0));
        self.tags.insert("l0".into(), IdxTag::Local(0));
        self
    }

    /// 2-D grid: axis 0 is the SIMD-lane (fastest-varying) axis.
    pub fn group_dims_2d(
        mut self,
        total0: LinExpr,
        lsize0: i64,
        total1: LinExpr,
        lsize1: i64,
    ) -> Self {
        self.dims.push(Dim::tiles("g0", total0, lsize0));
        self.dims.push(Dim::tiles("g1", total1, lsize1));
        self.dims.push(Dim::simple("l0", LinExpr::constant(lsize0)));
        self.dims.push(Dim::simple("l1", LinExpr::constant(lsize1)));
        self.tags.insert("g0".into(), IdxTag::Group(0));
        self.tags.insert("g1".into(), IdxTag::Group(1));
        self.tags.insert("l0".into(), IdxTag::Local(0));
        self.tags.insert("l1".into(), IdxTag::Local(1));
        self
    }

    /// 2-D grid with independent tile and lane extents per axis: group
    /// axis `i` has `ceil(total_i / tile_i)` groups and `lsize_i` lanes.
    /// Used when a kernel's tile shape differs from its work-group shape
    /// (e.g. square transpose tiles staged by a non-square group).
    pub fn custom_grid_2d(
        mut self,
        total0: LinExpr,
        tile0: i64,
        lsize0: i64,
        total1: LinExpr,
        tile1: i64,
        lsize1: i64,
    ) -> Self {
        self.dims.push(Dim::tiles("g0", total0, tile0));
        self.dims.push(Dim::tiles("g1", total1, tile1));
        self.dims.push(Dim::simple("l0", LinExpr::constant(lsize0)));
        self.dims.push(Dim::simple("l1", LinExpr::constant(lsize1)));
        self.tags.insert("g0".into(), IdxTag::Group(0));
        self.tags.insert("g1".into(), IdxTag::Group(1));
        self.tags.insert("l0".into(), IdxTag::Local(0));
        self.tags.insert("l1".into(), IdxTag::Local(1));
        self
    }

    /// Plain sequential loop `0 <= name < hi`.
    pub fn seq_dim(mut self, name: &str, hi: LinExpr) -> Self {
        self.dims.push(Dim::simple(name, hi));
        self.tags.insert(name.into(), IdxTag::Seq);
        self
    }

    /// Sequential tile loop `0 <= name < ceil(num/den)`.
    pub fn seq_tiles(mut self, name: &str, num: LinExpr, den: i64) -> Self {
        self.dims.push(Dim::tiles(name, num, den));
        self.tags.insert(name.into(), IdxTag::Seq);
        self
    }

    /// Strided sequential loop over every `step`-th point of `[0, hi)`.
    pub fn seq_strided(mut self, name: &str, hi: LinExpr, step: i64) -> Self {
        self.dims.push(Dim::strided(name, hi, step));
        self.tags.insert(name.into(), IdxTag::Seq);
        self
    }

    /// Unrolled loop (sequential semantics, no loop overhead modeled).
    pub fn unroll_dim(mut self, name: &str, hi: i64) -> Self {
        self.dims.push(Dim::simple(name, LinExpr::constant(hi)));
        self.tags.insert(name.into(), IdxTag::Unroll);
        self
    }

    /// Reduction iname: a domain dim not tagged onto the grid; referenced
    /// by `Expr::Reduce`.
    pub fn red_dim(mut self, name: &str, hi: LinExpr) -> Self {
        self.dims.push(Dim::simple(name, hi));
        self.tags.insert(name.into(), IdxTag::Seq);
        self
    }

    pub fn global_array(
        mut self,
        name: &str,
        dtype: DType,
        shape: Vec<LinExpr>,
        layout: Layout,
        is_output: bool,
    ) -> Self {
        self.arrays.push(ArrayDecl {
            name: name.into(),
            dtype,
            shape,
            space: MemSpace::Global,
            layout,
            is_output,
        });
        self
    }

    /// Work-group shared ("local") scratch array with constant shape.
    pub fn local_array(mut self, name: &str, dtype: DType, shape: &[i64]) -> Self {
        self.arrays.push(ArrayDecl {
            name: name.into(),
            dtype,
            shape: shape.iter().map(|&s| LinExpr::constant(s)).collect(),
            space: MemSpace::Local,
            layout: Layout::RowMajor,
            is_output: false,
        });
        self
    }

    /// Per-thread register array (usually a scalar accumulator: shape [1]).
    pub fn private_array(mut self, name: &str, dtype: DType, shape: &[i64]) -> Self {
        self.arrays.push(ArrayDecl {
            name: name.into(),
            dtype,
            shape: shape.iter().map(|&s| LinExpr::constant(s)).collect(),
            space: MemSpace::Private,
            layout: Layout::RowMajor,
            is_output: false,
        });
        self
    }

    /// Append an instruction; returns the builder (ids are sequential).
    pub fn insn(mut self, lhs: Access, rhs: Expr, within: &[&str], deps: &[usize]) -> Self {
        let id = self.insns.len();
        self.insns.push(Insn {
            id,
            lhs,
            rhs,
            within: within.iter().map(|s| Sym::intern(s)).collect(),
            deps: deps.to_vec(),
            is_update: false,
        });
        self
    }

    /// Append an update instruction (`lhs += rhs` for sum accumulators).
    pub fn update_insn(
        mut self,
        lhs: Access,
        rhs: Expr,
        within: &[&str],
        deps: &[usize],
    ) -> Self {
        let id = self.insns.len();
        self.insns.push(Insn {
            id,
            lhs,
            rhs,
            within: within.iter().map(|s| Sym::intern(s)).collect(),
            deps: deps.to_vec(),
            is_update: true,
        });
        self
    }

    /// Number of instructions appended so far (for dependency wiring).
    pub fn insn_count(&self) -> usize {
        self.insns.len()
    }

    /// Finish and validate.
    pub fn build(self) -> Result<Kernel, String> {
        let k = Kernel {
            name: self.name,
            params: self.params,
            domain: BoxDomain::new(self.dims),
            tags: self.tags,
            arrays: self.arrays,
            insns: self.insns,
        };
        k.validate()?;
        Ok(k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qpoly::env;

    #[test]
    fn gid_expression() {
        let e = gid(1, 16);
        assert_eq!(e.eval(&env(&[("g1", 3), ("l1", 5)])).unwrap(), 53);
    }

    #[test]
    fn two_d_grid_counts() {
        let k = KernelBuilder::new("t", &["n"])
            .group_dims_2d(LinExpr::var("n"), 16, LinExpr::var("n"), 16)
            .global_array(
                "out",
                DType::F32,
                vec![LinExpr::var("n"), LinExpr::var("n")],
                Layout::RowMajor,
                true,
            )
            .insn(
                Access::new("out", vec![gid(1, 16), gid(0, 16)]),
                Expr::lit(0.0),
                &["g0", "g1", "l0", "l1"],
                &[],
            )
            .build()
            .unwrap();
        let e = env(&[("n", 64)]);
        assert_eq!(k.group_count_at(&e).unwrap(), 16);
        assert_eq!(k.group_size_at(&e).unwrap(), (16, 16));
    }

    #[test]
    fn build_rejects_invalid() {
        let r = KernelBuilder::new("bad", &["n"])
            .group_dims_1d(LinExpr::var("n"), 64)
            .insn(
                Access::new("missing", vec![LinExpr::var("l0")]),
                Expr::lit(1.0),
                &["g0", "l0"],
                &[],
            )
            .build();
        assert!(r.is_err());
    }
}
