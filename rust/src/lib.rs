//! # uniperf
//!
//! Reproduction of *“A Unified, Hardware-Fitted, Cross-GPU Performance
//! Model”* (Stevens & Klöckner, 2016).
//!
//! The library models the wall time of a GPU compute kernel as a linear
//! combination of symbolically-extracted, hardware-independent *kernel
//! properties* with hardware-fitted weights:
//!
//! ```text
//! T_wall(n) ≈ Σ_i α_i · p_i(n)
//! ```
//!
//! Pipeline (the paper's Figure 1):
//!
//! 1. Express kernels in the polyhedral IR ([`lpir`]).
//! 2. Count operations symbolically ([`isl`], [`qpoly`]) and classify them
//!    into model properties ([`stats`]).
//! 3. Time a library of measurement kernels ([`kernels`]) on a device
//!    ([`gpusim`] — a simulated-GPU substrate standing in for the paper's
//!    four physical GPUs) using the paper's timing protocol ([`harness`]).
//! 4. Fit the per-device weights by relative-error least squares
//!    ([`perfmodel`]; the numerical hot path is AOT-compiled JAX/Pallas
//!    loaded through [`runtime`]).
//! 5. Predict test-kernel run times and report the paper's tables
//!    ([`report`], [`coordinator`]).
//! 6. Evaluate the model on *held-out* kernels, size cases and devices
//!    over the expanded evaluation-kernel zoo ([`crossval`]) — the
//!    device split transfers weights across the registry's widened
//!    hardware axis ([`gpusim::registry`]).
//! 7. Persist fitted weight tables as fingerprinted artifacts and serve
//!    predictions from them — batched, structurally cached, without
//!    re-running a measurement campaign ([`service`]).
//!
//! Every entry point — the batch pipeline, cross-validation, and the
//! threaded prediction server — shares one
//! measurement→extraction→fit→predict core ([`engine`]): the device
//! registry, the eviction-bounded props cache, capability-derived
//! suite construction, the solver factory and an atomically
//! hot-swappable model store live there.
//!
//! Cross-cutting observability — the typed metrics registry, the
//! structured-span recorder behind `{"cmd": "trace"}`/`--profile`, and
//! the leveled logger — lives in [`obs`].
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured record.
pub mod util;
pub mod obs;
pub mod qpoly;
pub mod isl;
pub mod lpir;
pub mod schedule;
pub mod stats;
pub mod gpusim;
pub mod kernels;
pub mod perfmodel;
pub mod harness;
pub mod runtime;
pub mod engine;
pub mod coordinator;
pub mod crossval;
pub mod report;
pub mod service;

/// Library version (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
