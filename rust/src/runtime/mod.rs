//! PJRT runtime: loads and executes the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) from the Rust hot path.
//!
//! Python runs only at build time (`make artifacts`): `python/compile/aot.py`
//! lowers the L2 fit/predict computations (whose inner Gram/matvec hot
//! spots are L1 Pallas kernels) to HLO *text*, which this module parses
//! with `HloModuleProto::from_text_file`, compiles on the PJRT CPU client
//! and executes. HLO text — not serialized protos — is the interchange
//! format because jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! Fixed artifact shapes (padding + masking on the Rust side):
//! * `fit.hlo.txt`:     B (MAX_CASES × MAX_PROPS) f64, rowmask (MAX_CASES)
//!   → weights (MAX_PROPS)
//! * `predict.hlo.txt`: P (MAX_BATCH × MAX_PROPS) f64, w (MAX_PROPS)
//!   → times (MAX_BATCH)

mod xla;

use crate::perfmodel::Solver;
use crate::util::linalg::Mat;
use std::path::{Path, PathBuf};

/// Maximum measurement cases the fit artifact accepts (the full §4.1
/// suite is 390 cases; padded rows are masked out).
pub const MAX_CASES: usize = 512;
/// Property-vector length baked into the artifacts (= `Schema::full().len()`).
pub const MAX_PROPS: usize = 160;
/// Maximum prediction batch of the predict artifact.
pub const MAX_BATCH: usize = 64;

/// Default artifact directory: `$UNIPERF_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("UNIPERF_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

fn err<E: std::fmt::Display>(e: E) -> String {
    format!("xla runtime: {e}")
}

/// A compiled HLO executable on the PJRT CPU client.
pub struct XlaExecutable {
    exe: xla::PjRtLoadedExecutable,
}

impl XlaExecutable {
    /// Load HLO text from `path`, compile on a fresh CPU client.
    pub fn load(path: &Path) -> Result<XlaExecutable, String> {
        if !path.exists() {
            return Err(format!(
                "artifact {} not found — run `make artifacts` first",
                path.display()
            ));
        }
        let client = xla::PjRtClient::cpu().map_err(err)?;
        let proto = xla::HloModuleProto::from_text_file(path).map_err(err)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(err)?;
        Ok(XlaExecutable { exe })
    }

    /// Execute with f64 inputs; returns the flattened f64 outputs of the
    /// result tuple, in order.
    pub fn run_f64(
        &self,
        inputs: &[(&[f64], &[i64])],
    ) -> Result<Vec<Vec<f64>>, String> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                xla::Literal::vec1(data).reshape(dims).map_err(err)
            })
            .collect::<Result<_, _>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals).map_err(err)?[0][0]
            .to_literal_sync()
            .map_err(err)?;
        // jax lowers with return_tuple=True: decompose the result tuple
        let elems = result.to_tuple().map_err(err)?;
        elems
            .iter()
            .map(|e| e.to_vec::<f64>().map_err(err))
            .collect()
    }
}

/// The production fit path: the relative-error least-squares solve as an
/// AOT-compiled JAX computation whose Gram/matvec hot spot is a Pallas
/// kernel (see `python/compile/kernels/gram.py`).
pub struct XlaSolver {
    exe: XlaExecutable,
}

impl XlaSolver {
    /// Load `fit.hlo.txt` from the artifact directory.
    pub fn from_artifacts() -> Result<XlaSolver, String> {
        Self::from_path(&artifacts_dir().join("fit.hlo.txt"))
    }

    pub fn from_path(path: &Path) -> Result<XlaSolver, String> {
        Ok(XlaSolver { exe: XlaExecutable::load(path)? })
    }
}

impl Solver for XlaSolver {
    fn solve(&self, b: &Mat) -> Result<Vec<f64>, String> {
        if b.rows > MAX_CASES {
            return Err(format!("{} cases exceed artifact capacity {MAX_CASES}", b.rows));
        }
        if b.cols > MAX_PROPS {
            return Err(format!("{} props exceed artifact capacity {MAX_PROPS}", b.cols));
        }
        if b.rows < b.cols {
            return Err(format!("underdetermined fit: {} cases < {} properties", b.rows, b.cols));
        }
        // pad B into (MAX_CASES, MAX_PROPS)
        let mut bp = vec![0.0f64; MAX_CASES * MAX_PROPS];
        for i in 0..b.rows {
            for j in 0..b.cols {
                bp[i * MAX_PROPS + j] = b.at(i, j);
            }
        }
        let mut rowmask = vec![0.0f64; MAX_CASES];
        for r in rowmask.iter_mut().take(b.rows) {
            *r = 1.0;
        }
        let outs = self.exe.run_f64(&[
            (&bp, &[MAX_CASES as i64, MAX_PROPS as i64]),
            (&rowmask, &[MAX_CASES as i64]),
        ])?;
        let w = outs
            .first()
            .ok_or("fit artifact returned no outputs")?;
        if w.len() < b.cols {
            return Err(format!("fit artifact returned {} weights, expected >= {}", w.len(), b.cols));
        }
        Ok(w[..b.cols].to_vec())
    }

    fn name(&self) -> &'static str {
        "xla-pallas-aot"
    }
}

/// Batched predictor: `times = P · w` through the predict artifact.
pub struct XlaPredictor {
    exe: XlaExecutable,
}

impl XlaPredictor {
    pub fn from_artifacts() -> Result<XlaPredictor, String> {
        Self::from_path(&artifacts_dir().join("predict.hlo.txt"))
    }

    pub fn from_path(path: &Path) -> Result<XlaPredictor, String> {
        Ok(XlaPredictor { exe: XlaExecutable::load(path)? })
    }

    /// Predict times for up to [`MAX_BATCH`] property vectors.
    pub fn predict(&self, props: &[Vec<f64>], weights: &[f64]) -> Result<Vec<f64>, String> {
        if props.len() > MAX_BATCH {
            return Err(format!("batch {} exceeds artifact capacity {MAX_BATCH}", props.len()));
        }
        let mut p = vec![0.0f64; MAX_BATCH * MAX_PROPS];
        for (i, row) in props.iter().enumerate() {
            if row.len() > MAX_PROPS {
                return Err(format!("property vector {} too long: {}", i, row.len()));
            }
            p[i * MAX_PROPS..i * MAX_PROPS + row.len()].copy_from_slice(row);
        }
        let mut w = vec![0.0f64; MAX_PROPS];
        if weights.len() > MAX_PROPS {
            return Err(format!("weight vector too long: {}", weights.len()));
        }
        w[..weights.len()].copy_from_slice(weights);
        let outs = self.exe.run_f64(&[
            (&p, &[MAX_BATCH as i64, MAX_PROPS as i64]),
            (&w, &[MAX_PROPS as i64]),
        ])?;
        Ok(outs.first().ok_or("predict artifact returned no outputs")?[..props.len()].to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schema_fits_artifact_capacity() {
        // the python side bakes MAX_PROPS into the artifacts; the schema
        // must fit or padding silently misaligns (the solver also packs
        // only the *active* columns, which is fewer still)
        assert!(crate::stats::Schema::full().len() <= MAX_PROPS);
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let r = XlaSolver::from_path(Path::new("/nonexistent/fit.hlo.txt"));
        assert!(r.is_err());
        assert!(format!("{}", r.err().unwrap()).contains("make artifacts"));
    }

    #[test]
    fn artifacts_dir_env_override() {
        // don't mutate the process env (tests run in parallel); just check
        // the default
        if std::env::var_os("UNIPERF_ARTIFACTS").is_none() {
            assert_eq!(artifacts_dir(), PathBuf::from("artifacts"));
        }
    }
}
