//! Minimal in-tree stand-in for the vendored `xla`/PJRT bindings.
//!
//! The build environment is offline and the vendored xla closure is not
//! present in this tree, so this module presents exactly the API surface
//! [`super`] (the PJRT loader) consumes and reports unavailability from
//! every entry point that would need the real runtime. The error string
//! is surfaced through `XlaSolver::from_artifacts`, where
//! `FitBackend::Auto` (and the integration tests) already treat it as
//! "artifacts not built" and fall back to the native solver. Dropping
//! the vendored closure into the tree and re-pointing this `mod` at it
//! restores the production path without touching the loader.

use std::path::Path;

const UNAVAILABLE: &str =
    "vendored xla/PJRT closure not present in this tree (native solver fallback applies)";

/// Error type mirroring the vendored bindings' (only `Display` is
/// consumed by the loader).
pub struct Error(String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

/// Host literal (flat f64 buffer + shape).
pub struct Literal {
    data: Vec<f64>,
}

impl Literal {
    pub fn vec1(data: &[f64]) -> Literal {
        Literal { data: data.to_vec() }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "cannot reshape {} elements to {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone() })
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(Error(UNAVAILABLE.into()))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// Parsed HLO module (text form).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto, Error> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error(UNAVAILABLE.into()))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error(UNAVAILABLE.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailability() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file(Path::new("fit.hlo.txt")).is_err());
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert!(lit.reshape(&[2, 2]).is_ok());
        assert!(lit.reshape(&[3, 2]).is_err());
        assert!(lit.to_tuple().is_err());
        assert!(lit.to_vec::<f64>().is_err());
        let e = PjRtClient::cpu().err().unwrap();
        assert!(format!("{e}").contains("native solver fallback"));
    }
}
