//! The hidden timing engine of the simulated GPUs.
//!
//! Produces a wall time for (kernel, parameter binding, device) from a
//! transaction-level cost analysis that is *strictly richer* than the
//! paper's linear model:
//!
//! * per-warp memory-transaction counting from concrete addresses
//!   (coalescing over cache lines),
//! * L2 smoothing of re-walked footprints,
//! * memory/arithmetic overlap,
//! * occupancy wave quantization and per-wave latency floors,
//! * per-device launch overhead (base + per-group),
//! * a deterministic size-dependent bandwidth ripple on "irregular"
//!   devices (the R9 Fury profile).
//!
//! None of these effects are linear in the model's properties, so the fit
//! against this engine exhibits the paper's error structure rather than
//! being a change of basis.
//!
//! ## Compile-once evaluation
//!
//! The structural part of the analysis — access-index tapes, per-insn
//! op-count polynomials, projected iteration domains, the barrier
//! schedule, the noise-stream name prefix — depends only on the kernel
//! *structure*, not on the size binding. [`CompiledTiming`] lowers it
//! once per (device, kernel) and re-evaluates per env, so a campaign's
//! ~10 size cases per kernel class (and every retry attempt) stop
//! recompiling the kernel. The free [`base_time`] / [`run_times`]
//! functions are thin wrappers over a process-wide compiled cache and
//! are pinned bit-identical to the historical per-call computation.

use super::device::DeviceProfile;
use crate::isl::BoxDomain;
use crate::lpir::{Kernel, MemSpace, OpKind};
use crate::qpoly::tape::LinTape;
use crate::qpoly::{LinExpr, PwQPoly, QPoly};
use crate::util::intern::{Env, Sym};
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Cost breakdown for one kernel launch (seconds unless noted).
#[derive(Clone, Debug, Default)]
pub struct Breakdown {
    pub launch: f64,
    pub mem: f64,
    pub alu: f64,
    pub local: f64,
    pub barrier: f64,
    /// occupancy waves the launch is quantized into
    pub waves: i64,
    pub total: f64,
}

/// sample positions (fractions of a loop range) for warp address sampling
const SAMPLE_FRACS: [f64; 4] = [0.0, 0.37, 0.71, 0.93];

struct AccessCost {
    /// estimated DRAM traffic for this access over the whole launch
    dram_bytes: f64,
}

/// Bandwidth multiplier for warp-uniform (broadcast) loads: all lanes hit
/// one line, which the constant-cache / broadcast path serves without
/// repeated line fetches.
const BROADCAST_MULT: f64 = 12.0;

/// One global-memory access, pre-lowered for per-env evaluation.
struct GlobalAccess {
    array: Sym,
    /// original index expressions (footprint flattening needs them)
    idx: Vec<LinExpr>,
    /// index expressions compiled to slot tapes (the per-call
    /// `LinTape::compile` this artifact exists to hoist)
    tapes: Vec<LinTape>,
    /// per-axis element-stride polynomials of the array
    strides: Vec<QPoly>,
    elem_bytes: i64,
    /// inames the access ranges over (instruction inames + reduction scope)
    names: Vec<Sym>,
    /// iteration domain projected onto `names` (for exec counts)
    domain: BoxDomain,
}

/// One local-memory access: count domain + bank-conflict inputs.
struct LocalAccess {
    domain: BoxDomain,
    elem_bytes: f64,
    /// per-axis (lane-0 coefficient of the index expr, element stride)
    lane: Vec<(i64, QPoly)>,
}

/// Per-(device, kernel-structure) timing artifact: everything in the
/// cost analysis that does not depend on the size binding, lowered once
/// and re-evaluated per env (tentpole of the compile-once measurement
/// plane). Obtain via [`compiled_for`].
pub struct CompiledTiming {
    /// device-name + kernel-name bytes: the seed-independent input of
    /// the noise-stream hash prefix (see [`CompiledTiming::stream_hash`])
    name_bytes: Vec<u8>,
    l0: Option<Sym>,
    l1: Option<Sym>,
    /// group inames (pinned to group 0 for the per-group footprint)
    gnames: Vec<Sym>,
    globals: Vec<GlobalAccess>,
    locals: Vec<LocalAccess>,
    /// flattened (kind, bits, count-poly) op table in the historical
    /// insn-order / key-order walk
    ops: Vec<(OpKind, u32, PwQPoly)>,
    /// barrier count per group; scheduling errors are deferred so they
    /// surface at the same point of `base_time` as before
    barriers: Result<PwQPoly, String>,
}

impl CompiledTiming {
    /// Lower the structural part of the cost analysis. Infallible: the
    /// only fallible structural step (the barrier schedule) is stored as
    /// a deferred `Result` so error ordering matches the historical
    /// per-call path.
    fn compile(profile: &DeviceProfile, kernel: &Kernel) -> CompiledTiming {
        let locals_map = kernel.local_inames();
        let l0 = locals_map.get(&0).copied();
        let l1 = locals_map.get(&1).copied();
        let gnames: Vec<Sym> =
            kernel.group_inames().into_iter().map(|(_, g)| g).collect();

        // global accesses, in the exact historical walk order:
        // lhs, (lhs again on updates), rhs loads in visit order
        let mut globals = Vec::new();
        for insn in &kernel.insns {
            let mut handle = |idx: &[LinExpr], array: Sym, red: &[Sym]| {
                let arr = match kernel.array(array) {
                    Some(a) => a,
                    None => return,
                };
                if arr.space != MemSpace::Global {
                    return;
                }
                let mut names: Vec<Sym> = insn.within.clone();
                for r in red {
                    if !names.contains(r) {
                        names.push(*r);
                    }
                }
                globals.push(GlobalAccess {
                    array,
                    idx: idx.to_vec(),
                    tapes: idx.iter().map(LinTape::compile).collect(),
                    strides: arr.elem_strides(),
                    elem_bytes: arr.dtype.size_bytes() as i64,
                    domain: kernel.domain.project_onto(&names),
                    names,
                });
            };
            handle(&insn.lhs.idx, insn.lhs.array, &[]);
            if insn.is_update {
                handle(&insn.lhs.idx, insn.lhs.array, &[]);
            }
            insn.rhs.visit_loads(&mut |a, red| handle(&a.idx, a.array, red));
        }

        // local accesses: store first, then rhs loads, per insn
        let mut locals = Vec::new();
        let lane_pairs = |idx: &[LinExpr], strides: Vec<QPoly>| -> Vec<(i64, QPoly)> {
            idx.iter()
                .zip(strides)
                .map(|(e, st)| (l0.map(|lane| e.coeff(lane)).unwrap_or(0), st))
                .collect()
        };
        for insn in &kernel.insns {
            if let Some(arr) = kernel.array(insn.lhs.array) {
                if arr.space == MemSpace::Local {
                    locals.push(LocalAccess {
                        domain: kernel.insn_domain(insn, false),
                        elem_bytes: arr.dtype.size_bytes() as f64,
                        lane: lane_pairs(&insn.lhs.idx, arr.elem_strides()),
                    });
                }
            }
            insn.rhs.visit_loads(&mut |a, red| {
                if let Some(arr) = kernel.array(a.array) {
                    if arr.space == MemSpace::Local {
                        let mut names: Vec<Sym> = insn.within.clone();
                        for r in red {
                            if !names.contains(r) {
                                names.push(*r);
                            }
                        }
                        locals.push(LocalAccess {
                            domain: kernel.domain.project_onto(&names),
                            elem_bytes: arr.dtype.size_bytes() as f64,
                            lane: lane_pairs(&a.idx, arr.elem_strides()),
                        });
                    }
                }
            });
        }

        let mut ops = Vec::new();
        for insn in &kernel.insns {
            for ((kind, bits), q) in crate::stats::ops::count_insn_ops(kernel, insn) {
                ops.push((kind, bits, q));
            }
        }

        let barriers = crate::schedule::schedule(kernel)
            .map(|s| s.barriers_per_group(kernel));

        let mut name_bytes: Vec<u8> = profile.name.as_bytes().to_vec();
        name_bytes.extend_from_slice(kernel.name.as_bytes());

        CompiledTiming { name_bytes, l0, l1, gnames, globals, locals, ops, barriers }
    }

    fn l01_extents(&self, kernel: &Kernel, env: &Env) -> Result<(i64, i64), String> {
        let ext = |n: Option<Sym>| -> Result<i64, String> {
            Ok(match n {
                Some(n) => kernel
                    .domain
                    .dim(n)
                    .map(|d| d.trip_count_at(env))
                    .transpose()?
                    .unwrap_or(1),
                None => 1,
            })
        };
        Ok((ext(self.l0)?, ext(self.l1)?))
    }

    /// Count distinct cache lines a warp touches for one access, averaged
    /// over a few sampled warp instances.
    fn warp_lines(
        &self,
        acc: &GlobalAccess,
        axis_strides: &[i64],
        kernel: &Kernel,
        env: &Env,
        profile: &DeviceProfile,
        l0_ext: i64,
        l1_ext: i64,
    ) -> Result<(f64, bool), String> {
        let threads = (l0_ext * l1_ext).max(1);
        let warp = (profile.warp_size as i64).min(threads);

        let mut total_lines = 0.0;
        let mut samples = 0usize;
        let mut all_broadcast = true;
        // one reusable slot-frame environment for the whole sampling loop
        let mut ienv = env.clone();
        let mut addrs: Vec<i64> = Vec::with_capacity(warp as usize);
        for (si, frac) in SAMPLE_FRACS.iter().enumerate() {
            // fix non-lane inames at a sampled position in their range
            for name in &acc.names {
                if Some(*name) == self.l0 || Some(*name) == self.l1 {
                    continue;
                }
                let dim = match kernel.domain.dim(*name) {
                    Some(d) => d,
                    None => continue,
                };
                let trip = dim.trip_count_at(env)?;
                let lo = dim.lo.eval(env)?;
                let t = ((frac * (trip - 1).max(0) as f64).floor() as i64)
                    .clamp(0, (trip - 1).max(0));
                ienv.bind(*name, lo + dim.step * t);
            }
            // one warp: linear local ids [w0, w0 + warp)
            let w0 = if si % 2 == 0 { 0 } else { ((threads / warp).max(1) - 1) * warp };
            addrs.clear();
            for lid in w0..(w0 + warp) {
                if let Some(n0) = self.l0 {
                    ienv.bind(n0, lid % l0_ext);
                }
                if let Some(n1) = self.l1 {
                    ienv.bind(n1, (lid / l0_ext) % l1_ext.max(1));
                }
                let mut flat: i64 = 0;
                for (tape, &st) in acc.tapes.iter().zip(axis_strides) {
                    flat += tape.eval(&ienv)? * st;
                }
                addrs.push(flat * acc.elem_bytes);
            }
            addrs.sort_unstable();
            let uniform = addrs.first() == addrs.last() && !addrs.is_empty();
            let mut lines = 0usize;
            let mut prev = i64::MIN;
            for &a in &addrs {
                let line = a.div_euclid(profile.line_bytes as i64);
                if line != prev {
                    lines += 1;
                    prev = line;
                }
            }
            total_lines += lines as f64;
            all_broadcast &= uniform;
            samples += 1;
        }
        Ok((total_lines / samples as f64, all_broadcast))
    }

    /// Analyze all global accesses into DRAM traffic estimates.
    fn access_costs(
        &self,
        kernel: &Kernel,
        env: &Env,
        profile: &DeviceProfile,
    ) -> Result<Vec<AccessCost>, String> {
        let mut costs = Vec::new();
        // per-array total requested bytes, for cache smoothing
        let mut requested: BTreeMap<Sym, f64> = BTreeMap::new();
        let mut raw: Vec<(Sym, f64, bool)> = Vec::new(); // (array, line-bytes, uncoalesced)
        // per-array flattened accesses with group inames pinned (for the
        // per-group unique-working-set estimate)
        let mut group_flats: BTreeMap<Sym, Vec<crate::stats::footprint::FlatAccess>> =
            BTreeMap::new();

        let (l0_ext, l1_ext) = self.l01_extents(kernel, env)?;
        let threads = (l0_ext * l1_ext).max(1);
        let warp = (profile.warp_size as i64).min(threads) as f64;

        for acc in &self.globals {
            let axis_strides: Vec<i64> = acc
                .strides
                .iter()
                .map(|q| q.eval(env).map(|x| x as i64))
                .collect::<Result<_, _>>()?;
            let execs = acc.domain.count_at(env)? as f64;
            let (lines_per_warp, broadcast) =
                self.warp_lines(acc, &axis_strides, kernel, env, profile, l0_ext, l1_ext)?;
            let n_warps = execs / warp;
            let mut bytes = lines_per_warp * n_warps * profile.line_bytes as f64;
            if broadcast {
                // warp-uniform load: served by the broadcast/constant path
                bytes /= BROADCAST_MULT;
            }
            // ideal fully-coalesced line count for this access width
            let ideal = (warp * acc.elem_bytes as f64 / profile.line_bytes as f64).max(1.0);
            let uncoalesced = lines_per_warp > 2.5 * ideal;
            *requested.entry(acc.array).or_insert(0.0) += bytes;
            raw.push((acc.array, bytes, uncoalesced));
            // flattened access with group inames pinned to group 0
            let mut flat =
                crate::stats::footprint::flatten_access(kernel, &acc.idx, &axis_strides, env)?;
            for gname in &self.gnames {
                flat.coeffs.remove(gname);
                flat.ranges.remove(gname);
            }
            group_flats.entry(acc.array).or_default().push(flat);
        }

        // Cache smoothing: traffic beyond an array's compulsory footprint is
        // served from cache when one of these working sets fits —
        // * the whole array is L2-resident, or
        // * the *unique* cells one work group touches fit its SM's L1
        //   (temporal reuse inside a tile region, e.g. convolution windows),
        //   estimated by enumerating the access pattern with the group
        //   inames pinned, or
        // * the concurrently-resident groups' unique slices fit L2.
        let groups = kernel.group_count_at(env)?.max(1) as f64;
        let (gs0, gs1) = kernel.group_size_at(env)?;
        let concurrent = profile.concurrent_groups(gs0 * gs1) as f64;
        // per-array unique bytes one group touches
        let mut group_unique: BTreeMap<Sym, f64> = BTreeMap::new();
        for (array, flats) in &group_flats {
            let arr = kernel.array(*array).unwrap();
            let cells = crate::stats::footprint::unique_cells(flats) as f64;
            group_unique.insert(*array, cells * arr.dtype.size_bytes() as f64);
        }
        for (array, bytes, uncoalesced) in raw {
            let arr = kernel.array(array).unwrap();
            let footprint: f64 = arr
                .extents_at(env)?
                .iter()
                .map(|&e| e as f64)
                .product::<f64>()
                * arr.dtype.size_bytes() as f64;
            let total_req = requested[&array];
            let per_group = group_unique.get(&array).copied().unwrap_or(footprint);
            let cached = footprint <= profile.l2_bytes as f64
                || per_group <= profile.l1_bytes as f64
                || per_group * concurrent.min(groups) <= profile.l2_bytes as f64;
            let dram = if cached && total_req > footprint {
                // this access's share of the compulsory traffic + cache-rate rest
                let share = bytes / total_req;
                footprint * share + (bytes - footprint * share) / profile.l2_bw_mult
            } else {
                bytes
            };
            let dram = if uncoalesced { dram * profile.uncoalesced_penalty } else { dram };
            costs.push(AccessCost { dram_bytes: dram });
        }
        Ok(costs)
    }

    /// Compute the noise-free cost breakdown of one launch at one env.
    pub fn base_time(
        &self,
        profile: &DeviceProfile,
        kernel: &Kernel,
        env: &Env,
    ) -> Result<Breakdown, String> {
        let (gs0, gs1) = kernel.group_size_at(env)?;
        let group_size = gs0 * gs1;
        if group_size > profile.max_group_size as i64 {
            return Err(format!(
                "group size {group_size} exceeds device limit {} on {}",
                profile.max_group_size, profile.name
            ));
        }
        let groups = kernel.group_count_at(env)?.max(1);

        // --- memory ---------------------------------------------------------
        let costs = self.access_costs(kernel, env, profile)?;
        let dram_bytes: f64 = costs.iter().map(|c| c.dram_bytes).sum();
        let mem = dram_bytes * ripple(profile, dram_bytes) / profile.dram_bw;

        // --- arithmetic -------------------------------------------------------
        let mut alu_cycles = 0.0;
        for (kind, bits, q) in &self.ops {
            let count = q.eval(env)?;
            alu_cycles += count * profile.cycles_for(*kind, *bits);
        }
        let alu =
            alu_cycles / (profile.sms as f64 * profile.cores_per_sm as f64 * profile.clock_hz);

        // --- local (shared) memory traffic ------------------------------------
        // Bank conflicts (32 banks, 4-byte words): a lane stride of s
        // serializes a warp's access gcd(s, 32)-fold; strides 0 (broadcast)
        // and 1 are conflict-free. The linear model can optionally bin local
        // loads by this stride (paper §6.2 future work; ExtractOpts).
        let mut local_bytes = 0.0;
        for acc in &self.locals {
            let factor = if self.l0.is_none() {
                1.0
            } else {
                let mut s: i64 = 0;
                for (c, st) in &acc.lane {
                    s += c * st.eval(env)? as i64;
                }
                let s = s.abs();
                // worst-case serialization is gcd(s, banks); real parts
                // mitigate via line multicast, so cap the effective degree
                if s <= 1 { 1.0 } else { (gcd_i64(s, 32) as f64).min(4.0) }
            };
            let execs = acc.domain.count_at(env)? as f64;
            local_bytes += execs * acc.elem_bytes * factor;
        }
        let local = local_bytes / profile.local_bw;

        // --- barriers -----------------------------------------------------------
        let per_group = match &self.barriers {
            Ok(p) => p.eval(env)?,
            Err(e) => return Err(e.clone()),
        };
        let warps_per_group =
            ((group_size as f64) / profile.warp_size as f64).ceil().max(1.0);
        let barrier = per_group * groups as f64 * warps_per_group * profile.cyc_barrier
            / (profile.clock_hz * profile.sms as f64);

        // --- overlap + occupancy -------------------------------------------------
        let busy = mem.max(alu).max(local);
        let hidden = mem + alu + local - busy;
        let mut exec = busy + (1.0 - profile.overlap) * hidden + barrier;

        let concurrent = profile.concurrent_groups(group_size);
        let waves = (groups + concurrent - 1) / concurrent;
        // wave quantization: partially-filled final waves waste throughput.
        // Only a fraction of the workload is latency/occupancy sensitive.
        let quant = (waves * concurrent) as f64 / groups as f64;
        const LAT_SENSITIVITY: f64 = 0.25;
        exec *= 1.0 + LAT_SENSITIVITY * (quant - 1.0);
        // pipeline-latency floor: one full traversal plus a small per-wave
        // scheduling cost (waves pipeline, they do not serialize the latency)
        exec += profile.wave_latency + (waves - 1) as f64 * 120e-9;

        let launch = profile.launch_base + profile.launch_per_group * groups as f64;
        Ok(Breakdown {
            launch,
            mem,
            alu,
            local,
            barrier,
            waves,
            total: launch + exec,
        })
    }

    /// The per-(device, kernel, env, seed) noise-stream hash, bit-identical
    /// to the historical inline computation: the device/kernel name prefix
    /// is folded from the precomputed byte string, then bindings are hashed
    /// in name order so the stream matches the historical string-keyed maps.
    pub fn stream_hash(&self, env: &Env, seed: u64) -> u64 {
        let mut h: u64 = seed ^ 0x9E37_79B9_97F4_A7C1;
        for &b in &self.name_bytes {
            h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
        let mut pairs: Vec<(&'static str, i64)> =
            env.iter().map(|(s, v)| (s.as_str(), v)).collect();
        pairs.sort();
        for (k, v) in pairs {
            for b in k.bytes() {
                h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
            }
            h = (h ^ v as u64).wrapping_mul(0x100_0000_01b3);
        }
        h
    }

    /// Evaluate this artifact at one size case: noise-free base time plus
    /// the stream hash, computed once so retry attempts (and repeated
    /// sampling) stop re-paying `base_time` and the env re-sort.
    pub fn case(
        &self,
        profile: &DeviceProfile,
        kernel: &Kernel,
        env: &Env,
        seed: u64,
    ) -> Result<CaseTiming, String> {
        let base = self.base_time(profile, kernel, env)?;
        Ok(CaseTiming {
            base_total: base.total,
            first_touch_factor: profile.first_touch_factor,
            second_run_sigma: profile.second_run_sigma,
            noise_sigma: profile.noise_sigma,
            hash: self.stream_hash(env, seed),
        })
    }
}

/// One fully-evaluated (device, kernel, env, seed) timing case: drawing
/// samples from it is pure noise generation (no recompilation, no
/// re-evaluation, no re-hash).
#[derive(Clone, Debug)]
pub struct CaseTiming {
    base_total: f64,
    first_touch_factor: f64,
    second_run_sigma: f64,
    noise_sigma: f64,
    hash: u64,
}

impl CaseTiming {
    /// Simulated per-run wall times implementing the paper's §4.2 timing
    /// artifacts: first-touch slowdown on run 0, extra variance on run 1,
    /// log-normal noise on every run.
    pub fn sample(&self, runs: usize) -> Vec<f64> {
        SIM_DRAWS.fetch_add(1, Ordering::Relaxed);
        let mut rng = crate::util::rng::Rng::new(self.hash);
        let mut out = Vec::with_capacity(runs);
        for r in 0..runs {
            let mut t = self.base_total;
            if r == 0 {
                t *= self.first_touch_factor;
            }
            let sigma = if r == 1 {
                self.second_run_sigma
            } else {
                self.noise_sigma
            };
            t *= rng.lognormal(sigma);
            out.push(t);
        }
        out
    }
}

/// Count of simulated timing draws since process start. A warm
/// measurement cache must replay campaigns with this counter unchanged —
/// the meascache tests and `benches/campaign.rs` pin exactly that.
static SIM_DRAWS: AtomicU64 = AtomicU64::new(0);

pub fn sim_draws() -> u64 {
    SIM_DRAWS.load(Ordering::Relaxed)
}

/// Process-wide compiled-artifact cache, keyed by (device name,
/// rename-invariant structural hash, symbol fingerprint). The symbol
/// fingerprint covers the concrete spellings the tapes were compiled
/// against, so two kernels that are structurally identical but use
/// different interned names never share an artifact.
type CompiledKey = (String, u64, u64);

static COMPILED: OnceLock<Mutex<HashMap<CompiledKey, Arc<CompiledTiming>>>> =
    OnceLock::new();

/// runaway backstop: campaigns see dozens of kernel structures, not thousands
const COMPILED_CAP: usize = 4096;

fn sym_fingerprint(kernel: &Kernel) -> u64 {
    let mut f = crate::util::fnv::Fnv64::new();
    f.write_str(&format!("{kernel:?}"));
    f.finish()
}

/// Fetch (or build) the compiled timing artifact for a (device, kernel).
pub fn compiled_for(profile: &DeviceProfile, kernel: &Kernel) -> Arc<CompiledTiming> {
    let key = (
        profile.name.clone(),
        crate::service::hash::structural_hash(kernel),
        sym_fingerprint(kernel),
    );
    let map = COMPILED.get_or_init(|| Mutex::new(HashMap::new()));
    let mut m = match map.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    if let Some(ct) = m.get(&key) {
        return ct.clone();
    }
    if m.len() >= COMPILED_CAP {
        m.clear();
    }
    let ct = Arc::new(CompiledTiming::compile(profile, kernel));
    m.insert(key, ct.clone());
    ct
}

/// Deterministic device-irregularity ripple (R9 Fury): effective
/// bandwidth oscillates with the footprint size.
fn ripple(profile: &DeviceProfile, dram_bytes: f64) -> f64 {
    if profile.irregularity == 0.0 {
        return 1.0;
    }
    let x = (dram_bytes.max(1.0)).ln();
    1.0 + profile.irregularity * 0.5 * (1.0 + (4.7 * x).sin()) * 0.5
}

/// Compute the noise-free cost breakdown of one launch (compiled-cache
/// wrapper; bit-identical to the historical per-call analysis).
pub fn base_time(
    profile: &DeviceProfile,
    kernel: &Kernel,
    env: &Env,
) -> Result<Breakdown, String> {
    compiled_for(profile, kernel).base_time(profile, kernel, env)
}

/// Simulated per-run wall times (compiled-cache wrapper over
/// [`CompiledTiming::case`] + [`CaseTiming::sample`]).
pub fn run_times(
    profile: &DeviceProfile,
    kernel: &Kernel,
    env: &Env,
    runs: usize,
    seed: u64,
) -> Result<Vec<f64>, String> {
    let ct = compiled_for(profile, kernel);
    Ok(ct.case(profile, kernel, env, seed)?.sample(runs))
}

/// Apply measurement-channel fault sites to a completed timing run.
///
/// `measure.fail` aborts the whole run (the caller's retry budget deals
/// with it); `measure.outlier` makes one deterministic sample spuriously
/// *fast* (×0.04). Fast, not slow, is the adversarial direction here:
/// the protocol reduces by min-of-runs, which is immune to slow
/// outliers but poisoned by fast ones — exactly what the MAD rejection
/// in [`crate::harness::Protocol`] exists to catch.
pub fn apply_measurement_faults(
    plan: &crate::util::fault::FaultPlan,
    kernel_name: &str,
    times: &mut [f64],
) -> Result<(), String> {
    if plan.should_inject("measure.fail") {
        return Err(format!(
            "injected measurement failure for '{kernel_name}' (fault site measure.fail)"
        ));
    }
    if !times.is_empty() && plan.should_inject("measure.outlier") {
        let i = (plan.draw("measure.outlier") % times.len() as u64) as usize;
        times[i] *= 0.04;
    }
    Ok(())
}

fn gcd_i64(a: i64, b: i64) -> i64 {
    if b == 0 {
        a
    } else {
        gcd_i64(b, a % b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::{all_devices, r9_fury, titan_x};
    use crate::lpir::builder::{gid_lin_1d, KernelBuilder};
    use crate::lpir::{Access, DType, Expr, Layout};
    use crate::qpoly::env;

    fn copy_kernel(lsize: i64) -> Kernel {
        KernelBuilder::new("copy", &["n"])
            .group_dims_1d(LinExpr::var("n"), lsize)
            .global_array("a", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, false)
            .global_array("b", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("b", vec![gid_lin_1d(lsize)]),
                Expr::load("a", vec![gid_lin_1d(lsize)]),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap()
    }

    #[test]
    fn copy_is_bandwidth_bound_and_sane() {
        let d = titan_x();
        let e = env(&[("n", 1 << 24)]);
        let b = base_time(&d, &copy_kernel(256), &e).unwrap();
        // 2 * 64 MiB over ~252 GB/s ≈ 0.53 ms
        assert!(b.mem > b.alu);
        assert!(b.total > 0.3e-3 && b.total < 2.0e-3, "total {}", b.total);
    }

    #[test]
    fn bigger_problems_take_longer() {
        let d = titan_x();
        let k = copy_kernel(256);
        let t1 = base_time(&d, &k, &env(&[("n", 1 << 20)])).unwrap().total;
        let t2 = base_time(&d, &k, &env(&[("n", 1 << 22)])).unwrap().total;
        assert!(t2 > 2.0 * t1, "t1={t1} t2={t2}");
    }

    #[test]
    fn slower_device_is_slower() {
        let e = env(&[("n", 1 << 24)]);
        let k = copy_kernel(256);
        let fast = base_time(&titan_x(), &k, &e).unwrap().total;
        let slow = base_time(&crate::gpusim::device::c2070(), &k, &e).unwrap().total;
        assert!(slow > 1.5 * fast, "fast={fast} slow={slow}");
    }

    #[test]
    fn strided_reads_cost_more() {
        let lsize = 256;
        let strided = KernelBuilder::new("s4", &["n"])
            .group_dims_1d(LinExpr::var("n"), lsize)
            .global_array(
                "a",
                DType::F32,
                vec![LinExpr::var("n").scale(4)],
                Layout::RowMajor,
                false,
            )
            .global_array("b", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("b", vec![gid_lin_1d(lsize)]),
                Expr::load("a", vec![gid_lin_1d(lsize).scale(4)]),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap();
        let e = env(&[("n", 1 << 22)]);
        for d in all_devices() {
            let dense = base_time(&d, &copy_kernel(lsize), &e).unwrap().total;
            let strid = base_time(&d, &strided, &e).unwrap().total;
            assert!(strid > 1.5 * dense, "{}: dense={dense} strided={strid}", d.name);
        }
    }

    #[test]
    fn group_size_limit_enforced() {
        let k = copy_kernel(512);
        let e = env(&[("n", 1 << 20)]);
        assert!(base_time(&r9_fury(), &k, &e).is_err()); // Fury caps at 256
        assert!(base_time(&titan_x(), &k, &e).is_ok());
    }

    #[test]
    fn run_protocol_artifacts() {
        let d = titan_x();
        let k = copy_kernel(256);
        let e = env(&[("n", 1 << 22)]);
        let times = run_times(&d, &k, &e, 30, 1).unwrap();
        assert_eq!(times.len(), 30);
        // first run is slower than the rest (first-touch)
        let min_rest = times[2..].iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(times[0] > 1.4 * min_rest, "t0={} min={}", times[0], min_rest);
        // deterministic for same seed
        assert_eq!(times, run_times(&d, &k, &e, 30, 1).unwrap());
        // different for different seed
        assert_ne!(times, run_times(&d, &k, &e, 30, 2).unwrap());
    }

    #[test]
    fn compiled_artifact_is_cached_and_reused() {
        let d = titan_x();
        let k = copy_kernel(256);
        let a = compiled_for(&d, &k);
        let b = compiled_for(&d, &k);
        assert!(Arc::ptr_eq(&a, &b), "same (device, kernel) must share one artifact");
        // a different device gets its own artifact (the noise prefix differs)
        let c = compiled_for(&r9_fury(), &k);
        assert!(!Arc::ptr_eq(&a, &c));
    }

    /// Satellite regression: the hoisted stream hash must reproduce the
    /// historical inline FNV fold byte-for-byte — device+kernel name
    /// prefix, then bindings sorted by name, keys as raw bytes, values
    /// folded as u64 — and the sampled stream must match `run_times`.
    #[test]
    fn stream_hash_matches_legacy_inline_fold() {
        let d = titan_x();
        let k = copy_kernel(256);
        let e = env(&[("n", 1 << 20)]);
        for seed in [0u64, 1, 0xD15C_0, 0xDEAD_BEEF] {
            let mut h: u64 = seed ^ 0x9E37_79B9_97F4_A7C1;
            for b in d.name.bytes().chain(k.name.bytes()) {
                h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
            }
            let mut pairs: Vec<(&'static str, i64)> =
                e.iter().map(|(s, v)| (s.as_str(), v)).collect();
            pairs.sort();
            for (key, v) in pairs {
                for b in key.bytes() {
                    h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
                }
                h = (h ^ v as u64).wrapping_mul(0x100_0000_01b3);
            }
            let ct = compiled_for(&d, &k);
            assert_eq!(ct.stream_hash(&e, seed), h, "seed {seed}");
            // the full legacy stream: base total × first-touch/sigma noise
            let base = ct.base_time(&d, &k, &e).unwrap();
            let mut rng = crate::util::rng::Rng::new(h);
            let mut legacy = Vec::with_capacity(8);
            for r in 0..8 {
                let mut t = base.total;
                if r == 0 {
                    t *= d.first_touch_factor;
                }
                let sigma = if r == 1 { d.second_run_sigma } else { d.noise_sigma };
                t *= rng.lognormal(sigma);
                legacy.push(t);
            }
            assert_eq!(legacy, run_times(&d, &k, &e, 8, seed).unwrap());
        }
    }

    #[test]
    fn case_sampling_counts_sim_draws() {
        let d = titan_x();
        let k = copy_kernel(256);
        let e = env(&[("n", 1 << 20)]);
        let before = sim_draws();
        let _ = run_times(&d, &k, &e, 4, 1).unwrap();
        assert!(sim_draws() > before, "run_times must count as a simulation draw");
    }

    #[test]
    fn empty_kernel_dominated_by_launch_overhead() {
        // launch-grid-only kernel: writes nothing, does nothing
        let k = KernelBuilder::new("empty", &["n"])
            .group_dims_1d(LinExpr::var("n"), 256)
            .global_array("sink", DType::F32, vec![LinExpr::constant(1)], Layout::RowMajor, true)
            .insn(
                Access::new("sink", vec![LinExpr::constant(0)]),
                Expr::lit(0.0),
                &["g0"],
                &[],
            )
            .build()
            .unwrap();
        let d = r9_fury();
        let small = base_time(&d, &k, &env(&[("n", 1 << 16)])).unwrap();
        assert!(small.launch > 0.5 * small.total, "{small:?}");
        // overhead grows with group count
        let big = base_time(&d, &k, &env(&[("n", 1 << 22)])).unwrap();
        assert!(big.launch > small.launch);
    }
}
