//! The data-driven device registry: every simulated GPU the pipeline
//! can target, addressable by name and extensible at runtime.
//!
//! The built-in catalogue holds the paper's four evaluation devices
//! ([`super::device`]) plus four synthetic profiles spanning
//! generations and vendors — a Pascal-class HBM part, a Vega-class
//! part, a low-power integrated part and a modern wide-bus part — so
//! the cross-GPU axis is wider than the paper's and the
//! leave-one-device-out transfer split ([`crate::crossval`]) has a
//! meaningful spread to work with. User profiles load from JSON (the
//! `--devices <profiles.json>` CLI flag) through
//! [`DeviceRegistry::extend_from_json`]; because every kernel suite is
//! capability-derived from the profile ([`crate::kernels`]), a loaded
//! profile runs the full pipeline with no further configuration.

use super::device::{all_devices, DeviceProfile};
use crate::util::json::Json;
use std::sync::OnceLock;

/// An ordered, name-addressed collection of device profiles.
#[derive(Clone, Debug, Default)]
pub struct DeviceRegistry {
    profiles: Vec<DeviceProfile>,
}

impl DeviceRegistry {
    /// An empty registry.
    pub fn empty() -> DeviceRegistry {
        DeviceRegistry::default()
    }

    /// The built-in catalogue: the four paper devices followed by the
    /// four synthetic cross-generation profiles.
    pub fn with_builtins() -> DeviceRegistry {
        let mut r = DeviceRegistry::empty();
        for p in all_devices()
            .into_iter()
            .chain([p100(), vega64(), igp620(), rtx4090()])
        {
            r.register(p).expect("built-in profiles validate");
        }
        r
    }

    /// Look up a profile by short name.
    pub fn get(&self, name: &str) -> Option<&DeviceProfile> {
        self.profiles.iter().find(|p| p.name == name)
    }

    /// Registry order (insertion order; built-ins first).
    pub fn names(&self) -> Vec<String> {
        self.profiles.iter().map(|p| p.name.clone()).collect()
    }

    /// Iterate profiles in registry order.
    pub fn iter(&self) -> impl Iterator<Item = &DeviceProfile> {
        self.profiles.iter()
    }

    pub fn len(&self) -> usize {
        self.profiles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.profiles.is_empty()
    }

    /// Add a profile after validating it. A profile with an existing
    /// name *replaces* the old entry (in place, keeping its position),
    /// so a JSON file can override a built-in for what-if studies.
    pub fn register(&mut self, profile: DeviceProfile) -> Result<(), String> {
        profile.validate()?;
        match self.profiles.iter_mut().find(|p| p.name == profile.name) {
            Some(slot) => *slot = profile,
            None => self.profiles.push(profile),
        }
        Ok(())
    }

    /// Extend the registry from a JSON document: either a top-level
    /// array of profile objects or an object with a `"devices"` array.
    /// Returns the names of the loaded profiles in document order.
    pub fn extend_from_json(&mut self, j: &Json) -> Result<Vec<String>, String> {
        let arr = match (j.as_arr(), j.get("devices").and_then(Json::as_arr)) {
            (Some(a), _) => a,
            (None, Some(a)) => a,
            (None, None) => {
                return Err(
                    "device file must be a JSON array of profiles or {\"devices\": [...]}"
                        .into(),
                )
            }
        };
        let mut names = Vec::with_capacity(arr.len());
        for entry in arr {
            let p = DeviceProfile::from_json(entry)?;
            names.push(p.name.clone());
            self.register(p)?;
        }
        Ok(names)
    }

    /// Serialize the whole registry (the `--devices` file format).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "devices",
            Json::Arr(self.profiles.iter().map(DeviceProfile::to_json).collect()),
        )])
    }
}

/// A commented, directly loadable `--devices` template (the `devices
/// --export` subcommand): one complete built-in profile to copy from,
/// plus a skeleton carrying only the required hardware fields. JSON has
/// no comment syntax, so guidance rides in `_comment` keys, which the
/// profile loader ignores like any unknown field — the emitted file
/// round-trips through [`DeviceRegistry::extend_from_json`] unchanged.
pub fn export_template() -> Json {
    let mut full = match builtins().get("k40c").expect("built-in").to_json() {
        Json::Obj(m) => m,
        _ => unreachable!("profiles serialize to objects"),
    };
    full.insert(
        "_comment".into(),
        Json::Str(
            "complete profile (the built-in k40c): every field the simulator reads. \
             Loading a profile under an existing name overrides the built-in."
                .into(),
        ),
    );
    let skeleton = Json::obj(vec![
        (
            "_comment",
            Json::Str(
                "minimal profile: only the required hardware fields. Omitted \
                 measurement-artifact fields (noise_sigma, first_touch_factor, \
                 second_run_sigma, irregularity, ...) default to a well-behaved \
                 device; 'size_exp' optionally overrides per-class base size \
                 exponents layered over the capability-derived solver."
                    .into(),
            ),
        ),
        ("name", Json::Str("my_device".into())),
        ("full_name", Json::Str("My Custom GPU".into())),
        ("sms", Json::Num(16.0)),
        ("clock_hz", Json::Num(1.2e9)),
        ("cores_per_sm", Json::Num(64.0)),
        ("warp_size", Json::Num(32.0)),
        ("dram_bw", Json::Num(2.0e11)),
        ("line_bytes", Json::Num(128.0)),
        ("l2_bytes", Json::Num((2u64 << 20) as f64)),
        ("l1_bytes", Json::Num((32u64 << 10) as f64)),
        ("local_bw", Json::Num(1.0e12)),
        ("launch_base", Json::Num(8.0e-6)),
        ("threads_per_sm", Json::Num(2048.0)),
        ("max_groups_per_sm", Json::Num(16.0)),
        ("max_group_size", Json::Num(512.0)),
        ("size_exp", Json::obj(vec![("mm_tiled", Json::Num(8.0))])),
    ]);
    Json::obj(vec![
        (
            "_comment",
            Json::Str(
                "uniperf --devices template: {\"devices\": [...]} or a bare JSON \
                 array of profile objects (see DeviceProfile::from_json for the \
                 field set); '_comment' keys are ignored."
                    .into(),
            ),
        ),
        ("devices", Json::Arr(vec![Json::Obj(full), skeleton])),
    ])
}

/// The process-wide built-in catalogue, constructed once. Name lookups
/// (`gpusim::device`, `SimGpu::named`) go through this instead of
/// rebuilding the profile vector per call.
pub fn builtins() -> &'static DeviceRegistry {
    static REGISTRY: OnceLock<DeviceRegistry> = OnceLock::new();
    REGISTRY.get_or_init(DeviceRegistry::with_builtins)
}

// ---------------------------------------------------------------------------
// Synthetic cross-generation profiles
// ---------------------------------------------------------------------------

/// Nvidia Tesla P100 (Pascal, GP100): the HBM2 datacenter part — high
/// sustained bandwidth, full-rate f64, small per-SM lane count.
pub fn p100() -> DeviceProfile {
    DeviceProfile {
        name: "p100".into(),
        full_name: "Nvidia Tesla P100".into(),
        sms: 56,
        clock_hz: 1.3e9,
        cores_per_sm: 64,
        warp_size: 32,
        dram_bw: 0.75 * 732.0e9,
        line_bytes: 128,
        l2_bytes: 4 << 20,
        l1_bytes: 24 << 10,
        l2_bw_mult: 3.0,
        local_bw: 56.0 * 128.0 * 1.3e9,
        cyc_mad: 1.0,
        cyc_div: 10.0,
        cyc_exp: 16.0,
        cyc_special: 4.0,
        f64_ratio: 2.0, // 1:2 f64 — the datacenter configuration
        cyc_barrier: 32.0,
        launch_base: 5.0e-6,
        launch_per_group: 1.5e-9,
        threads_per_sm: 2048,
        max_groups_per_sm: 32,
        max_group_size: 1024,
        wave_latency: 2.2e-6,
        overlap: 0.72,
        noise_sigma: 0.013,
        first_touch_factor: 1.8,
        second_run_sigma: 0.05,
        irregularity: 0.0,
        uncoalesced_penalty: 1.0,
        size_exp: std::collections::BTreeMap::new(),
    }
}

/// AMD Radeon RX Vega 64 (Vega 10): HBM2, 64-lane wavefronts, the
/// 256-thread group cap and a milder version of the Fury's launch
/// overhead and bandwidth ripple.
pub fn vega64() -> DeviceProfile {
    DeviceProfile {
        name: "vega64".into(),
        full_name: "AMD Radeon RX Vega 64".into(),
        sms: 64,
        clock_hz: 1.4e9,
        cores_per_sm: 64,
        warp_size: 64,
        dram_bw: 0.65 * 484.0e9,
        line_bytes: 64,
        l2_bytes: 4 << 20,
        l1_bytes: 16 << 10,
        l2_bw_mult: 2.2,
        local_bw: 64.0 * 128.0 * 1.4e9,
        cyc_mad: 1.0,
        cyc_div: 10.0,
        cyc_exp: 16.0,
        cyc_special: 4.0,
        f64_ratio: 16.0,
        cyc_barrier: 40.0,
        launch_base: 30.0e-6,
        launch_per_group: 5.0e-9,
        threads_per_sm: 2560,
        max_groups_per_sm: 40,
        max_group_size: 256,
        wave_latency: 4.0e-6,
        overlap: 0.60,
        noise_sigma: 0.018,
        first_touch_factor: 2.0,
        second_run_sigma: 0.08,
        irregularity: 0.25,
        uncoalesced_penalty: 1.5,
        size_exp: std::collections::BTreeMap::new(),
    }
}

/// A low-power integrated GPU (Gen9-class, UHD-620-like): shared DDR4
/// bandwidth, SIMD-16 scheduling, driver-heavy launches, noisy timing —
/// the opposite corner of the hardware space from the discrete parts.
pub fn igp620() -> DeviceProfile {
    DeviceProfile {
        name: "igp620".into(),
        full_name: "Integrated Gen9 GT2 (UHD 620 class)".into(),
        sms: 3, // subslices
        clock_hz: 1.0e9,
        cores_per_sm: 64, // 8 EUs x SIMD-8 FPUs per subslice
        warp_size: 16,
        dram_bw: 0.60 * 34.1e9, // dual-channel DDR4-2133, shared with the CPU
        line_bytes: 64,
        l2_bytes: 512 << 10,
        l1_bytes: 32 << 10,
        l2_bw_mult: 2.0,
        local_bw: 3.0 * 64.0 * 1.0e9, // SLM lives next to L3 — slow
        cyc_mad: 1.0,
        cyc_div: 14.0,
        cyc_exp: 22.0,
        cyc_special: 8.0,
        f64_ratio: 4.0,
        cyc_barrier: 48.0,
        launch_base: 25.0e-6, // driver-dominated submission path
        launch_per_group: 8.0e-9,
        threads_per_sm: 512,
        max_groups_per_sm: 16,
        max_group_size: 256,
        wave_latency: 8.0e-6,
        overlap: 0.50,
        noise_sigma: 0.030, // shares memory and power budget with the CPU
        first_touch_factor: 2.5,
        second_run_sigma: 0.12,
        irregularity: 0.15,
        uncoalesced_penalty: 1.4,
        size_exp: std::collections::BTreeMap::new(),
    }
}

/// A modern wide-bus consumer flagship (Ada-class, RTX-4090-like):
/// ~1 TB/s GDDR6X, a huge L2 that smooths most re-walked footprints,
/// tiny launch overheads and strong overlap.
pub fn rtx4090() -> DeviceProfile {
    DeviceProfile {
        name: "rtx4090".into(),
        full_name: "Nvidia GeForce RTX 4090".into(),
        sms: 128,
        clock_hz: 2.2e9,
        cores_per_sm: 128,
        warp_size: 32,
        dram_bw: 0.78 * 1008.0e9,
        line_bytes: 128,
        l2_bytes: 72 << 20,
        l1_bytes: 128 << 10,
        l2_bw_mult: 4.0,
        local_bw: 128.0 * 128.0 * 2.2e9,
        cyc_mad: 1.0,
        cyc_div: 8.0,
        cyc_exp: 14.0,
        cyc_special: 4.0,
        f64_ratio: 64.0, // consumer f64 rate
        cyc_barrier: 24.0,
        launch_base: 4.0e-6,
        launch_per_group: 1.0e-9,
        threads_per_sm: 1536,
        max_groups_per_sm: 24,
        max_group_size: 1024,
        wave_latency: 1.8e-6,
        overlap: 0.80,
        noise_sigma: 0.012,
        first_touch_factor: 1.7,
        second_run_sigma: 0.04,
        irregularity: 0.0,
        uncoalesced_penalty: 1.0,
        size_exp: std::collections::BTreeMap::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_catalogue_spans_eight_devices() {
        let r = builtins();
        assert!(r.len() >= 8, "registry has {} devices", r.len());
        for name in [
            "titan_x", "k40c", "c2070", "r9_fury", "p100", "vega64", "igp620", "rtx4090",
        ] {
            assert!(r.get(name).is_some(), "missing built-in '{name}'");
        }
        // paper devices come first, in the paper's order
        assert_eq!(&r.names()[..4], &["titan_x", "k40c", "c2070", "r9_fury"]);
        for p in r.iter() {
            p.validate().unwrap();
        }
    }

    #[test]
    fn synthetic_profiles_span_the_axes() {
        // generations/vendors: HBM datacenter, Vega, integrated, wide-bus
        assert!(p100().f64_ratio < titan_x_ratio());
        assert_eq!(vega64().warp_size, 64);
        assert_eq!(vega64().max_group_size, 256);
        let igp = igp620();
        let wide = rtx4090();
        // the integrated part is the slowest by an order of magnitude,
        // the wide-bus part the fastest
        for other in builtins().iter() {
            if other.name != igp.name {
                assert!(other.dram_bw > 2.0 * igp.dram_bw, "{}", other.name);
            }
            if other.name != wide.name {
                assert!(wide.dram_bw > other.dram_bw, "{}", other.name);
            }
        }
    }

    fn titan_x_ratio() -> f64 {
        super::super::device::titan_x().f64_ratio
    }

    #[test]
    fn register_replaces_by_name_and_validates() {
        let mut r = DeviceRegistry::with_builtins();
        let n = r.len();
        let mut p = p100();
        p.sms = 60;
        r.register(p).unwrap();
        assert_eq!(r.len(), n, "replacement must not grow the registry");
        assert_eq!(r.get("p100").unwrap().sms, 60);
        let mut bad = igp620();
        bad.max_group_size = 40;
        assert!(r.register(bad).is_err());
    }

    #[test]
    fn export_template_is_commented_and_loadable() {
        let t = export_template();
        let text = t.pretty();
        // the template parses back and loads as a --devices file as-is
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        let mut r = DeviceRegistry::empty();
        let names = r.extend_from_json(&parsed).unwrap();
        assert_eq!(names, vec!["k40c".to_string(), "my_device".to_string()]);
        // the full profile matches the built-in exactly
        assert_eq!(r.get("k40c"), builtins().get("k40c"));
        // the skeleton validates, takes artifact defaults, and carries
        // a legal size_exp override example
        let sk = r.get("my_device").unwrap();
        sk.validate().unwrap();
        assert!(sk.noise_sigma > 0.0);
        assert_eq!(sk.class_size_exp("mm_tiled", 11), 8);
        // guidance is present for humans
        assert!(text.contains("_comment"));
        assert!(text.contains("size_exp"));
    }

    #[test]
    fn registry_json_roundtrip_and_extension() {
        let r = DeviceRegistry::with_builtins();
        let j = r.to_json().pretty();
        let mut r2 = DeviceRegistry::empty();
        let names = r2
            .extend_from_json(&crate::util::json::Json::parse(&j).unwrap())
            .unwrap();
        assert_eq!(names, r.names());
        for p in r.iter() {
            assert_eq!(r2.get(&p.name), Some(p));
        }
        // a bare array works too
        let arr = crate::util::json::Json::Arr(vec![p100().to_json()]);
        let mut r3 = DeviceRegistry::empty();
        assert_eq!(r3.extend_from_json(&arr).unwrap(), vec!["p100".to_string()]);
        // scalars are rejected
        assert!(r3
            .extend_from_json(&crate::util::json::Json::Num(3.0))
            .is_err());
    }
}
