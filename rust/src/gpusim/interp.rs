//! Numeric kernel interpreter — the simulated device's execution engine.
//!
//! Executes a [`Kernel`] for a concrete parameter binding, following the
//! schedule (instruction order, loop nesting; barriers are memory-order
//! no-ops because lanes are executed instruction-synchronously, which is
//! exactly the semantics barriers guarantee for race-free kernels).
//!
//! Used to *validate* every kernel in the library against a plain
//! reference implementation — the simulator must run the same computation
//! the paper's OpenCL kernels ran, not just time a description of it.
//!
//! Execution is two-phase: the kernel is first *compiled* against the
//! parameter binding — array names resolve to dense indices, affine
//! index expressions become [`LinTape`]s over symbol slots, loop bounds
//! fold to concrete integers — and the per-lane inner loop then runs
//! against a flat [`Env`] slot frame with no string-keyed map lookups.

use crate::lpir::{BinOp, DType, Expr, IdxTag, Kernel, MemSpace, RedOp, UnOp};
use crate::qpoly::tape::LinTape;
use crate::schedule::{schedule, SchedItem, Schedule};
use crate::util::intern::{Env, Sym};
use std::collections::BTreeMap;

/// Global-array storage after execution.
#[derive(Clone, Debug, Default)]
pub struct Storage {
    pub arrays: BTreeMap<String, Vec<f64>>,
}

impl Storage {
    pub fn get(&self, name: &str) -> Option<&[f64]> {
        self.arrays.get(name).map(|v| v.as_slice())
    }
}

/// Deterministic input seeding: a cheap hash of (array, flat index) mapped
/// into [-1, 1). Kernel reference implementations use the same function.
pub fn seed_value(array: &str, flat: usize) -> f64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in array.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h ^= flat as u64;
    h = h.wrapping_mul(0x100_0000_01b3);
    h ^= h >> 33;
    // map to [-1, 1) with 20 bits of resolution
    ((h >> 44) as i64 - (1 << 19)) as f64 / (1 << 19) as f64
}

/// Compiled array access: dense array index + slot-indexed affine tapes.
struct CAccess {
    array: usize,
    idx: Vec<LinTape>,
}

/// Compiled right-hand-side expression.
enum CExpr {
    Lit(f64),
    Idx(LinTape),
    Load(CAccess),
    Cast(DType, Box<CExpr>),
    Un(UnOp, Box<CExpr>),
    Bin(BinOp, Box<CExpr>, Box<CExpr>),
    Reduce {
        op: RedOp,
        iname: Sym,
        lo: i64,
        hi: i64,
        step: i64,
        body: Box<CExpr>,
    },
}

struct CInsn {
    lhs: CAccess,
    rhs: CExpr,
    is_update: bool,
}

/// Tree form of a schedule with concrete loop bounds.
enum Node {
    Loop { iname: Sym, lo: i64, hi: i64, step: i64, body: Vec<Node> },
    Run(usize),
    Barrier,
}

/// Per-array static info at the compiled binding.
struct ArrInfo {
    name: Sym,
    space: MemSpace,
    extents: Vec<i64>,
    strides: Vec<i64>,
    total: usize,
    is_output: bool,
}

/// A kernel compiled against one parameter binding.
struct Compiled {
    kernel_name: String,
    arrays: Vec<ArrInfo>,
    insns: Vec<CInsn>,
    tree: Vec<Node>,
    /// lane local-id tuples, l0-major
    lanes: Vec<(i64, i64)>,
    l0: Option<Sym>,
    l1: Option<Sym>,
    g0: Option<Sym>,
    g1: Option<Sym>,
    g0_extent: i64,
    g1_extent: i64,
}

/// Mutable array storage, indexed like `Compiled::arrays`.
struct MachineState {
    /// global arrays (empty Vec for non-global slots)
    global: Vec<Vec<f64>>,
    /// local arrays, re-zeroed per group
    local: Vec<Vec<f64>>,
    /// private arrays: lane-major [lane][elem]
    private: Vec<Vec<Vec<f64>>>,
}

fn compile_access(
    acc: &crate::lpir::Access,
    index: &BTreeMap<Sym, usize>,
) -> Result<CAccess, String> {
    let array = *index
        .get(&acc.array)
        .ok_or_else(|| format!("unknown array '{}'", acc.array))?;
    Ok(CAccess { array, idx: acc.idx.iter().map(LinTape::compile).collect() })
}

fn compile_expr(
    kernel: &Kernel,
    env: &Env,
    index: &BTreeMap<Sym, usize>,
    e: &Expr,
) -> Result<CExpr, String> {
    Ok(match e {
        Expr::Lit(x) => CExpr::Lit(*x),
        Expr::Idx(le) => CExpr::Idx(LinTape::compile(le)),
        Expr::Load(a) => CExpr::Load(compile_access(a, index)?),
        Expr::Cast(dt, x) => CExpr::Cast(*dt, Box::new(compile_expr(kernel, env, index, x)?)),
        Expr::Un(op, x) => CExpr::Un(*op, Box::new(compile_expr(kernel, env, index, x)?)),
        Expr::Bin(op, a, b) => CExpr::Bin(
            *op,
            Box::new(compile_expr(kernel, env, index, a)?),
            Box::new(compile_expr(kernel, env, index, b)?),
        ),
        Expr::Reduce(op, iname, body) => {
            let dim = kernel
                .domain
                .dim(*iname)
                .ok_or_else(|| format!("unknown reduction iname '{iname}'"))?;
            CExpr::Reduce {
                op: *op,
                iname: *iname,
                lo: dim.lo.eval(env)?,
                hi: dim.hi.eval(env)?,
                step: dim.step,
                body: Box::new(compile_expr(kernel, env, index, body)?),
            }
        }
    })
}

fn build_tree(kernel: &Kernel, env: &Env, sched: &Schedule) -> Result<Vec<Node>, String> {
    fn go(
        kernel: &Kernel,
        env: &Env,
        items: &[SchedItem],
        pos: &mut usize,
    ) -> Result<Vec<Node>, String> {
        let mut out = Vec::new();
        while *pos < items.len() {
            match &items[*pos] {
                SchedItem::OpenLoop(name) => {
                    *pos += 1;
                    let body = go(kernel, env, items, pos)?;
                    let dim = kernel
                        .domain
                        .dim(*name)
                        .ok_or_else(|| format!("unknown loop iname '{name}'"))?;
                    out.push(Node::Loop {
                        iname: *name,
                        lo: dim.lo.eval(env)?,
                        hi: dim.hi.eval(env)?,
                        step: dim.step,
                        body,
                    });
                }
                SchedItem::CloseLoop(_) => {
                    *pos += 1;
                    return Ok(out);
                }
                SchedItem::RunInsn(id) => {
                    out.push(Node::Run(*id));
                    *pos += 1;
                }
                SchedItem::Barrier => {
                    out.push(Node::Barrier);
                    *pos += 1;
                }
            }
        }
        Ok(out)
    }
    let mut pos = 0;
    go(kernel, env, &sched.items, &mut pos)
}

fn compile(kernel: &Kernel, env: &Env) -> Result<Compiled, String> {
    let sched = schedule(kernel)?;

    // arrays: dense indices in declaration order
    let mut index: BTreeMap<Sym, usize> = BTreeMap::new();
    let mut arrays = Vec::with_capacity(kernel.arrays.len());
    for (i, arr) in kernel.arrays.iter().enumerate() {
        index.insert(arr.name, i);
        let extents = arr.extents_at(env)?;
        let total: i64 = extents.iter().product::<i64>().max(0);
        let strides: Vec<i64> = arr
            .elem_strides()
            .iter()
            .map(|q| q.eval(env).map(|x| x as i64))
            .collect::<Result<_, _>>()?;
        arrays.push(ArrInfo {
            name: arr.name,
            space: arr.space,
            extents,
            strides,
            total: total as usize,
            is_output: arr.is_output,
        });
    }

    let insns = kernel
        .insns
        .iter()
        .map(|insn| {
            Ok(CInsn {
                lhs: compile_access(&insn.lhs, &index)?,
                rhs: compile_expr(kernel, env, &index, &insn.rhs)?,
                is_update: insn.is_update,
            })
        })
        .collect::<Result<Vec<_>, String>>()?;

    let tree = build_tree(kernel, env, &sched)?;

    // grid setup
    let locals = kernel.local_inames();
    let groups_map = kernel.group_inames();
    let l0 = locals.get(&0).copied();
    let l1 = locals.get(&1).copied();
    let trip = |name: Option<Sym>| -> Result<i64, String> {
        match name {
            Some(n) => kernel.domain.dim(n).unwrap().trip_count_at(env),
            None => Ok(1),
        }
    };
    let l0_extent = trip(l0)?;
    let l1_extent = trip(l1)?;
    let mut lanes = Vec::with_capacity((l0_extent * l1_extent) as usize);
    for v1 in 0..l1_extent {
        for v0 in 0..l0_extent {
            lanes.push((v0, v1));
        }
    }
    let g0 = groups_map.get(&0).copied();
    let g1 = groups_map.get(&1).copied();
    let g0_extent = trip(g0)?;
    let g1_extent = trip(g1)?;

    Ok(Compiled {
        kernel_name: kernel.name.clone(),
        arrays,
        insns,
        tree,
        lanes,
        l0,
        l1,
        g0,
        g1,
        g0_extent,
        g1_extent,
    })
}

#[inline]
fn flat_index(c: &Compiled, acc: &CAccess, ienv: &Env) -> Result<usize, String> {
    let info = &c.arrays[acc.array];
    let mut flat: i64 = 0;
    for ((tape, &st), &ext) in acc.idx.iter().zip(&info.strides).zip(&info.extents) {
        let v = tape.eval(ienv)?;
        if v < 0 || v >= ext {
            return Err(format!(
                "out-of-bounds access {}[..{v}..] (extent {ext}) in kernel '{}'",
                info.name, c.kernel_name
            ));
        }
        flat += v * st;
    }
    Ok(flat as usize)
}

fn read(
    c: &Compiled,
    st: &MachineState,
    acc: &CAccess,
    lane: usize,
    ienv: &Env,
) -> Result<f64, String> {
    let flat = flat_index(c, acc, ienv)?;
    Ok(match c.arrays[acc.array].space {
        MemSpace::Global => st.global[acc.array][flat],
        MemSpace::Local => st.local[acc.array][flat],
        MemSpace::Private => st.private[acc.array][lane][flat],
    })
}

fn write(
    c: &Compiled,
    st: &mut MachineState,
    acc: &CAccess,
    lane: usize,
    ienv: &Env,
    value: f64,
    is_update: bool,
) -> Result<(), String> {
    let flat = flat_index(c, acc, ienv)?;
    let slot = match c.arrays[acc.array].space {
        MemSpace::Global => &mut st.global[acc.array][flat],
        MemSpace::Local => &mut st.local[acc.array][flat],
        MemSpace::Private => &mut st.private[acc.array][lane][flat],
    };
    if is_update {
        *slot += value;
    } else {
        *slot = value;
    }
    Ok(())
}

fn eval(
    c: &Compiled,
    st: &MachineState,
    e: &CExpr,
    lane: usize,
    ienv: &mut Env,
) -> Result<f64, String> {
    Ok(match e {
        CExpr::Lit(x) => *x,
        CExpr::Idx(tape) => tape.eval(ienv)? as f64,
        CExpr::Load(a) => read(c, st, a, lane, ienv)?,
        CExpr::Cast(dt, x) => {
            let v = eval(c, st, x, lane, ienv)?;
            match dt {
                DType::F32 | DType::F32x4 => v as f32 as f64,
                _ => v,
            }
        }
        CExpr::Un(op, x) => {
            let v = eval(c, st, x, lane, ienv)?;
            match op {
                UnOp::Neg => -v,
                UnOp::Sqrt => v.sqrt(),
                UnOp::Rsqrt => 1.0 / v.sqrt(),
                UnOp::Exp => v.exp(),
                UnOp::Sin => v.sin(),
                UnOp::Cos => v.cos(),
                UnOp::Abs => v.abs(),
            }
        }
        CExpr::Bin(op, a, b) => {
            let x = eval(c, st, a, lane, ienv)?;
            let y = eval(c, st, b, lane, ienv)?;
            match op {
                BinOp::Add => x + y,
                BinOp::Sub => x - y,
                BinOp::Mul => x * y,
                BinOp::Div => x / y,
                BinOp::Pow => x.powf(y),
                BinOp::Min => x.min(y),
                BinOp::Max => x.max(y),
            }
        }
        CExpr::Reduce { op, iname, lo, hi, step, body } => {
            let prev = ienv.get(*iname);
            let mut acc = match op {
                RedOp::Sum => 0.0,
                RedOp::Max => f64::NEG_INFINITY,
            };
            let mut v = *lo;
            while v < *hi {
                ienv.bind(*iname, v);
                let x = eval(c, st, body, lane, ienv)?;
                match op {
                    RedOp::Sum => acc += x,
                    RedOp::Max => acc = acc.max(x),
                }
                v += step;
            }
            match prev {
                Some(p) => ienv.bind(*iname, p),
                None => ienv.unbind(*iname),
            }
            acc
        }
    })
}

fn run_nodes(
    c: &Compiled,
    st: &mut MachineState,
    nodes: &[Node],
    ienv: &mut Env,
) -> Result<(), String> {
    for node in nodes {
        match node {
            Node::Barrier => {}
            Node::Run(id) => {
                let insn = &c.insns[*id];
                // lanes not listed in `within` still execute the
                // instruction redundantly on real hardware; values are
                // identical, so executing all lanes is equivalent.
                for (lane, &(v0, v1)) in c.lanes.iter().enumerate() {
                    if let Some(n0) = c.l0 {
                        ienv.bind(n0, v0);
                    }
                    if let Some(n1) = c.l1 {
                        ienv.bind(n1, v1);
                    }
                    let value = eval(c, st, &insn.rhs, lane, ienv)?;
                    write(c, st, &insn.lhs, lane, ienv, value, insn.is_update)?;
                }
            }
            Node::Loop { iname, lo, hi, step, body } => {
                let mut v = *lo;
                while v < *hi {
                    ienv.bind(*iname, v);
                    run_nodes(c, st, body, ienv)?;
                    v += step;
                }
                ienv.unbind(*iname);
            }
        }
    }
    Ok(())
}

/// Execute a kernel, returning final global-array storage. Inputs are
/// seeded with [`seed_value`]; outputs (and local/private scratch) start
/// at zero.
pub fn execute(kernel: &Kernel, env: &Env) -> Result<Storage, String> {
    kernel.validate()?;
    let c = compile(kernel, env)?;
    let n_lanes = c.lanes.len();

    let mut st = MachineState {
        global: Vec::with_capacity(c.arrays.len()),
        local: Vec::with_capacity(c.arrays.len()),
        private: Vec::with_capacity(c.arrays.len()),
    };
    for info in &c.arrays {
        let mut global = Vec::new();
        let mut local = Vec::new();
        let mut private = Vec::new();
        match info.space {
            MemSpace::Global => {
                let mut data = vec![0.0; info.total];
                if !info.is_output {
                    let name = info.name.as_str();
                    for (i, d) in data.iter_mut().enumerate() {
                        *d = seed_value(name, i);
                    }
                }
                global = data;
            }
            MemSpace::Local => local = vec![0.0; info.total],
            MemSpace::Private => private = vec![vec![0.0; info.total]; n_lanes],
        }
        st.global.push(global);
        st.local.push(local);
        st.private.push(private);
    }

    // iterate groups
    for gv1 in 0..c.g1_extent {
        for gv0 in 0..c.g0_extent {
            // fresh local/private storage per group
            for v in st.local.iter_mut() {
                v.fill(0.0);
            }
            for lanes in st.private.iter_mut() {
                for v in lanes.iter_mut() {
                    v.fill(0.0);
                }
            }
            let mut ienv = env.clone();
            if let Some(n) = c.g0 {
                ienv.bind(n, gv0);
            }
            if let Some(n) = c.g1 {
                ienv.bind(n, gv1);
            }
            run_nodes(&c, &mut st, &c.tree, &mut ienv)?;
        }
    }

    let mut arrays = BTreeMap::new();
    for (info, data) in c.arrays.iter().zip(st.global.into_iter()) {
        if info.space == MemSpace::Global {
            arrays.insert(info.name.as_str().to_string(), data);
        }
    }
    Ok(Storage { arrays })
}

/// `IdxTag` re-export guard: interpreting a kernel whose sequential dims
/// carry grid tags would double-count; assert the invariant here.
pub fn check_grid_tags(kernel: &Kernel) -> Result<(), String> {
    for d in &kernel.domain.dims {
        if matches!(kernel.tag(d.name), IdxTag::Group(a) | IdxTag::Local(a) if a > 1) {
            return Err(format!("iname '{}' uses unsupported grid axis > 1", d.name));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lpir::builder::{gid, gid_lin_1d, KernelBuilder};
    use crate::lpir::{Access, Layout};
    use crate::qpoly::{env, LinExpr};

    #[test]
    fn seed_value_is_deterministic_and_bounded() {
        for i in 0..1000 {
            let v = seed_value("a", i);
            assert_eq!(v, seed_value("a", i));
            assert!((-1.0..1.0).contains(&v), "{v}");
        }
        assert_ne!(seed_value("a", 3), seed_value("b", 3));
    }

    #[test]
    fn executes_double_kernel() {
        let k = KernelBuilder::new("double", &["n"])
            .group_dims_1d(LinExpr::var("n"), 64)
            .global_array("a", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, false)
            .global_array("out", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("out", vec![gid_lin_1d(64)]),
                Expr::mul(Expr::lit(2.0), Expr::load("a", vec![gid_lin_1d(64)])),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap();
        let e = env(&[("n", 256)]);
        let st = execute(&k, &e).unwrap();
        let out = st.get("out").unwrap();
        for i in 0..256 {
            assert_eq!(out[i], 2.0 * seed_value("a", i));
        }
    }

    #[test]
    fn executes_tiled_transpose_with_barrier() {
        // out[j, i] = a[i, j] via a local tile
        let n = LinExpr::var("n");
        let k = KernelBuilder::new("tr", &["n"])
            .group_dims_2d(n.clone(), 8, n.clone(), 8)
            .global_array("a", DType::F32, vec![n.clone(), n.clone()], Layout::RowMajor, false)
            .global_array("out", DType::F32, vec![n.clone(), n.clone()], Layout::RowMajor, true)
            .local_array("tile", DType::F32, &[8, 8])
            .insn(
                Access::new("tile", vec![LinExpr::var("l1"), LinExpr::var("l0")]),
                Expr::load("a", vec![gid(1, 8), gid(0, 8)]),
                &["g0", "g1", "l0", "l1"],
                &[],
            )
            .insn(
                Access::new(
                    "out",
                    vec![
                        LinExpr::scaled_var("g0", 8).add(&LinExpr::var("l1")),
                        LinExpr::scaled_var("g1", 8).add(&LinExpr::var("l0")),
                    ],
                ),
                Expr::load("tile", vec![LinExpr::var("l0"), LinExpr::var("l1")]),
                &["g0", "g1", "l0", "l1"],
                &[0],
            )
            .build()
            .unwrap();
        let e = env(&[("n", 16)]);
        let st = execute(&k, &e).unwrap();
        let out = st.get("out").unwrap();
        for i in 0..16usize {
            for j in 0..16usize {
                assert_eq!(out[j * 16 + i], seed_value("a", i * 16 + j), "({i},{j})");
            }
        }
    }

    #[test]
    fn executes_tiled_mm_with_accumulator() {
        // c = a @ b via 4x4 tiles with private accumulator
        let n = LinExpr::var("n");
        let k = KernelBuilder::new("mm", &["n"])
            .group_dims_2d(n.clone(), 4, n.clone(), 4)
            .seq_tiles("kt", n.clone(), 4)
            .red_dim("ki", LinExpr::constant(4))
            .global_array("a", DType::F32, vec![n.clone(), n.clone()], Layout::RowMajor, false)
            .global_array("b", DType::F32, vec![n.clone(), n.clone()], Layout::RowMajor, false)
            .global_array("c", DType::F32, vec![n.clone(), n.clone()], Layout::RowMajor, true)
            .local_array("at", DType::F32, &[4, 4])
            .local_array("bt", DType::F32, &[4, 4])
            .private_array("acc", DType::F32, &[1])
            .insn(
                Access::new("at", vec![LinExpr::var("l1"), LinExpr::var("l0")]),
                Expr::load(
                    "a",
                    vec![gid(1, 4), LinExpr::scaled_var("kt", 4).add(&LinExpr::var("l0"))],
                ),
                &["g0", "g1", "l0", "l1", "kt"],
                &[],
            )
            .insn(
                Access::new("bt", vec![LinExpr::var("l1"), LinExpr::var("l0")]),
                Expr::load(
                    "b",
                    vec![LinExpr::scaled_var("kt", 4).add(&LinExpr::var("l1")), gid(0, 4)],
                ),
                &["g0", "g1", "l0", "l1", "kt"],
                &[],
            )
            .update_insn(
                Access::new("acc", vec![LinExpr::constant(0)]),
                Expr::sum(
                    "ki",
                    Expr::mul(
                        Expr::load("at", vec![LinExpr::var("l1"), LinExpr::var("ki")]),
                        Expr::load("bt", vec![LinExpr::var("ki"), LinExpr::var("l0")]),
                    ),
                ),
                &["g0", "g1", "l0", "l1", "kt"],
                &[0, 1],
            )
            .insn(
                Access::new("c", vec![gid(1, 4), gid(0, 4)]),
                Expr::load("acc", vec![LinExpr::constant(0)]),
                &["g0", "g1", "l0", "l1"],
                &[2],
            )
            .build()
            .unwrap();
        let e = env(&[("n", 8)]);
        let st = execute(&k, &e).unwrap();
        let c = st.get("c").unwrap();
        let n = 8usize;
        for i in 0..n {
            for j in 0..n {
                let want: f64 = (0..n)
                    .map(|kk| seed_value("a", i * n + kk) * seed_value("b", kk * n + j))
                    .sum();
                assert!(
                    (c[i * n + j] - want).abs() < 1e-12,
                    "c[{i},{j}] = {} want {want}",
                    c[i * n + j]
                );
            }
        }
    }

    #[test]
    fn out_of_bounds_detected() {
        let k = KernelBuilder::new("oob", &["n"])
            .group_dims_1d(LinExpr::var("n"), 64)
            .global_array("a", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, false)
            .global_array("out", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("out", vec![gid_lin_1d(64)]),
                Expr::load("a", vec![gid_lin_1d(64).add(&LinExpr::constant(1))]),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap();
        assert!(execute(&k, &env(&[("n", 64)])).is_err());
    }

    #[test]
    fn strided_seq_loop_executes_correct_subset() {
        // out[i] = a[3i] for i in the strided global pattern (stride-3 read)
        let k = KernelBuilder::new("s3", &["n"])
            .group_dims_1d(LinExpr::var("n"), 64)
            .global_array(
                "a",
                DType::F32,
                vec![LinExpr::var("n").scale(3)],
                Layout::RowMajor,
                false,
            )
            .global_array("out", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("out", vec![gid_lin_1d(64)]),
                Expr::load("a", vec![gid_lin_1d(64).scale(3)]),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap();
        let e = env(&[("n", 128)]);
        let st = execute(&k, &e).unwrap();
        let out = st.get("out").unwrap();
        for i in 0..128 {
            assert_eq!(out[i], seed_value("a", 3 * i));
        }
    }
}
