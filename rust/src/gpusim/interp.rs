//! Numeric kernel interpreter — the simulated device's execution engine.
//!
//! Executes a [`Kernel`] for a concrete parameter binding, following the
//! schedule (instruction order, loop nesting; barriers are memory-order
//! no-ops because lanes are executed instruction-synchronously, which is
//! exactly the semantics barriers guarantee for race-free kernels).
//!
//! Used to *validate* every kernel in the library against a plain
//! reference implementation — the simulator must run the same computation
//! the paper's OpenCL kernels ran, not just time a description of it.

use crate::lpir::{Access, DType, Expr, IdxTag, Kernel, MemSpace, RedOp, UnOp};
#[cfg(test)]
use crate::qpoly::LinExpr;
use crate::schedule::{schedule, SchedItem, Schedule};
use std::collections::BTreeMap;

/// Global-array storage after execution.
#[derive(Clone, Debug, Default)]
pub struct Storage {
    pub arrays: BTreeMap<String, Vec<f64>>,
}

impl Storage {
    pub fn get(&self, name: &str) -> Option<&[f64]> {
        self.arrays.get(name).map(|v| v.as_slice())
    }
}

/// Deterministic input seeding: a cheap hash of (array, flat index) mapped
/// into [-1, 1). Kernel reference implementations use the same function.
pub fn seed_value(array: &str, flat: usize) -> f64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in array.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h ^= flat as u64;
    h = h.wrapping_mul(0x100_0000_01b3);
    h ^= h >> 33;
    // map to [-1, 1) with 20 bits of resolution
    ((h >> 44) as i64 - (1 << 19)) as f64 / (1 << 19) as f64
}

/// Tree form of a schedule (loops re-nested for recursive execution).
enum Node {
    Loop(String, Vec<Node>),
    Run(usize),
    Barrier,
}

fn build_tree(sched: &Schedule) -> Vec<Node> {
    fn go(items: &[SchedItem], pos: &mut usize) -> Vec<Node> {
        let mut out = Vec::new();
        while *pos < items.len() {
            match &items[*pos] {
                SchedItem::OpenLoop(name) => {
                    *pos += 1;
                    let body = go(items, pos);
                    out.push(Node::Loop(name.clone(), body));
                }
                SchedItem::CloseLoop(_) => {
                    *pos += 1;
                    return out;
                }
                SchedItem::RunInsn(id) => {
                    out.push(Node::Run(*id));
                    *pos += 1;
                }
                SchedItem::Barrier => {
                    out.push(Node::Barrier);
                    *pos += 1;
                }
            }
        }
        out
    }
    let mut pos = 0;
    go(&sched.items, &mut pos)
}

struct Machine<'a> {
    kernel: &'a Kernel,
    env: &'a BTreeMap<String, i64>,
    /// concrete extents and element strides per array
    extents: BTreeMap<String, Vec<i64>>,
    strides: BTreeMap<String, Vec<i64>>,
    global: BTreeMap<String, Vec<f64>>,
    /// local arrays, re-zeroed per group
    local: BTreeMap<String, Vec<f64>>,
    /// private arrays: lane-major [lane][elem]
    private: BTreeMap<String, Vec<Vec<f64>>>,
    lanes: Vec<(i64, i64)>,
    l0_name: Option<String>,
    l1_name: Option<String>,
}

impl<'a> Machine<'a> {
    fn flat_index(&self, acc: &Access, ienv: &BTreeMap<String, i64>) -> Result<usize, String> {
        let strides = &self.strides[&acc.array];
        let extents = &self.extents[&acc.array];
        let mut flat: i64 = 0;
        for ((e, &st), &ext) in acc.idx.iter().zip(strides).zip(extents) {
            let v = e.eval(ienv)?;
            if v < 0 || v >= ext {
                return Err(format!(
                    "out-of-bounds access {}[..{v}..] (extent {ext}) in kernel '{}'",
                    acc.array, self.kernel.name
                ));
            }
            flat += v * st;
        }
        Ok(flat as usize)
    }

    fn read(&self, acc: &Access, lane: usize, ienv: &BTreeMap<String, i64>) -> Result<f64, String> {
        let arr = self.kernel.array(&acc.array).unwrap();
        let flat = self.flat_index(acc, ienv)?;
        Ok(match arr.space {
            MemSpace::Global => self.global[&acc.array][flat],
            MemSpace::Local => self.local[&acc.array][flat],
            MemSpace::Private => self.private[&acc.array][lane][flat],
        })
    }

    fn write(
        &mut self,
        acc: &Access,
        lane: usize,
        ienv: &BTreeMap<String, i64>,
        value: f64,
        is_update: bool,
    ) -> Result<(), String> {
        let arr = self.kernel.array(&acc.array).unwrap();
        let space = arr.space;
        let flat = self.flat_index(acc, ienv)?;
        let slot = match space {
            MemSpace::Global => &mut self.global.get_mut(&acc.array).unwrap()[flat],
            MemSpace::Local => &mut self.local.get_mut(&acc.array).unwrap()[flat],
            MemSpace::Private => &mut self.private.get_mut(&acc.array).unwrap()[lane][flat],
        };
        if is_update {
            *slot += value;
        } else {
            *slot = value;
        }
        Ok(())
    }

    fn eval(
        &self,
        e: &Expr,
        lane: usize,
        ienv: &mut BTreeMap<String, i64>,
    ) -> Result<f64, String> {
        Ok(match e {
            Expr::Lit(x) => *x,
            Expr::Idx(le) => le.eval(ienv)? as f64,
            Expr::Load(a) => self.read(a, lane, ienv)?,
            Expr::Cast(dt, x) => {
                let v = self.eval(x, lane, ienv)?;
                match dt {
                    DType::F32 | DType::F32x4 => v as f32 as f64,
                    _ => v,
                }
            }
            Expr::Un(op, x) => {
                let v = self.eval(x, lane, ienv)?;
                match op {
                    UnOp::Neg => -v,
                    UnOp::Sqrt => v.sqrt(),
                    UnOp::Rsqrt => 1.0 / v.sqrt(),
                    UnOp::Exp => v.exp(),
                    UnOp::Sin => v.sin(),
                    UnOp::Cos => v.cos(),
                    UnOp::Abs => v.abs(),
                }
            }
            Expr::Bin(op, a, b) => {
                use crate::lpir::BinOp::*;
                let x = self.eval(a, lane, ienv)?;
                let y = self.eval(b, lane, ienv)?;
                match op {
                    Add => x + y,
                    Sub => x - y,
                    Mul => x * y,
                    Div => x / y,
                    Pow => x.powf(y),
                    Min => x.min(y),
                    Max => x.max(y),
                }
            }
            Expr::Reduce(op, iname, body) => {
                let dim = self
                    .kernel
                    .domain
                    .dim(iname)
                    .ok_or_else(|| format!("unknown reduction iname '{iname}'"))?;
                let lo = dim.lo.eval(self.env)?;
                let hi = dim.hi.eval(self.env)?;
                let mut acc = match op {
                    RedOp::Sum => 0.0,
                    RedOp::Max => f64::NEG_INFINITY,
                };
                let mut v = lo;
                while v < hi {
                    let prev = ienv.insert(iname.clone(), v);
                    let x = self.eval(body, lane, ienv)?;
                    match prev {
                        Some(p) => {
                            ienv.insert(iname.clone(), p);
                        }
                        None => {
                            ienv.remove(iname);
                        }
                    }
                    match op {
                        RedOp::Sum => acc += x,
                        RedOp::Max => acc = acc.max(x),
                    }
                    v += dim.step;
                }
                acc
            }
        })
    }

    fn run_nodes(
        &mut self,
        nodes: &[Node],
        ienv: &mut BTreeMap<String, i64>,
    ) -> Result<(), String> {
        for node in nodes {
            match node {
                Node::Barrier => {}
                Node::Run(id) => {
                    let insn = self.kernel.insns[*id].clone();
                    // lanes not listed in `within` still execute the
                    // instruction redundantly on real hardware; values are
                    // identical, so executing all lanes is equivalent.
                    for (lane, &(v0, v1)) in self.lanes.clone().iter().enumerate() {
                        if let Some(n0) = &self.l0_name {
                            ienv.insert(n0.clone(), v0);
                        }
                        if let Some(n1) = &self.l1_name {
                            ienv.insert(n1.clone(), v1);
                        }
                        let value = self.eval(&insn.rhs, lane, ienv)?;
                        self.write(&insn.lhs, lane, ienv, value, insn.is_update)?;
                    }
                }
                Node::Loop(name, body) => {
                    let dim = self
                        .kernel
                        .domain
                        .dim(name)
                        .ok_or_else(|| format!("unknown loop iname '{name}'"))?;
                    let lo = dim.lo.eval(self.env)?;
                    let hi = dim.hi.eval(self.env)?;
                    let mut v = lo;
                    while v < hi {
                        ienv.insert(name.clone(), v);
                        self.run_nodes(body, ienv)?;
                        v += dim.step;
                    }
                    ienv.remove(name);
                }
            }
        }
        Ok(())
    }
}

/// Execute a kernel, returning final global-array storage. Inputs are
/// seeded with [`seed_value`]; outputs (and local/private scratch) start
/// at zero.
pub fn execute(kernel: &Kernel, env: &BTreeMap<String, i64>) -> Result<Storage, String> {
    kernel.validate()?;
    let sched = schedule(kernel)?;
    let tree = build_tree(&sched);

    // allocate arrays
    let mut extents = BTreeMap::new();
    let mut strides = BTreeMap::new();
    let mut global = BTreeMap::new();
    for arr in &kernel.arrays {
        let ext = arr.extents_at(env)?;
        let total: i64 = ext.iter().product::<i64>().max(0);
        let st: Vec<i64> = arr
            .elem_strides()
            .iter()
            .map(|q| q.eval(env).map(|x| x as i64))
            .collect::<Result<_, _>>()?;
        if arr.space == MemSpace::Global {
            let mut data = vec![0.0; total as usize];
            if !arr.is_output {
                for (i, d) in data.iter_mut().enumerate() {
                    *d = seed_value(&arr.name, i);
                }
            }
            global.insert(arr.name.clone(), data);
        }
        extents.insert(arr.name.clone(), ext);
        strides.insert(arr.name.clone(), st);
    }

    // grid setup
    let locals = kernel.local_inames();
    let groups_map = kernel.group_inames();
    let l0 = locals.get(&0).cloned();
    let l1 = locals.get(&1).cloned();
    let l0_extent = match &l0 {
        Some(n) => kernel.domain.dim(n).unwrap().trip_count_at(env)?,
        None => 1,
    };
    let l1_extent = match &l1 {
        Some(n) => kernel.domain.dim(n).unwrap().trip_count_at(env)?,
        None => 1,
    };
    let mut lanes = Vec::with_capacity((l0_extent * l1_extent) as usize);
    for v1 in 0..l1_extent {
        for v0 in 0..l0_extent {
            lanes.push((v0, v1));
        }
    }

    let mut machine = Machine {
        kernel,
        env,
        extents,
        strides,
        global,
        local: BTreeMap::new(),
        private: BTreeMap::new(),
        lanes,
        l0_name: l0,
        l1_name: l1,
    };

    // iterate groups
    let g0 = groups_map.get(&0).cloned();
    let g1 = groups_map.get(&1).cloned();
    let g0_extent = match &g0 {
        Some(n) => kernel.domain.dim(n).unwrap().trip_count_at(env)?,
        None => 1,
    };
    let g1_extent = match &g1 {
        Some(n) => kernel.domain.dim(n).unwrap().trip_count_at(env)?,
        None => 1,
    };

    let n_lanes = machine.lanes.len();
    for gv1 in 0..g1_extent {
        for gv0 in 0..g0_extent {
            // fresh local/private storage per group
            machine.local.clear();
            machine.private.clear();
            for arr in &kernel.arrays {
                let total: i64 = machine.extents[&arr.name].iter().product();
                match arr.space {
                    MemSpace::Local => {
                        machine.local.insert(arr.name.clone(), vec![0.0; total as usize]);
                    }
                    MemSpace::Private => {
                        machine
                            .private
                            .insert(arr.name.clone(), vec![vec![0.0; total as usize]; n_lanes]);
                    }
                    MemSpace::Global => {}
                }
            }
            let mut ienv: BTreeMap<String, i64> = env.clone();
            if let Some(n) = &g0 {
                ienv.insert(n.clone(), gv0);
            }
            if let Some(n) = &g1 {
                ienv.insert(n.clone(), gv1);
            }
            machine.run_nodes(&tree, &mut ienv)?;
        }
    }
    Ok(Storage { arrays: machine.global })
}

/// `IdxTag` re-export guard: interpreting a kernel whose sequential dims
/// carry grid tags would double-count; assert the invariant here.
pub fn check_grid_tags(kernel: &Kernel) -> Result<(), String> {
    for d in &kernel.domain.dims {
        if matches!(kernel.tag(&d.name), IdxTag::Group(a) | IdxTag::Local(a) if a > 1) {
            return Err(format!("iname '{}' uses unsupported grid axis > 1", d.name));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lpir::builder::{gid, gid_lin_1d, KernelBuilder};
    use crate::lpir::Layout;
    use crate::qpoly::env;

    #[test]
    fn seed_value_is_deterministic_and_bounded() {
        for i in 0..1000 {
            let v = seed_value("a", i);
            assert_eq!(v, seed_value("a", i));
            assert!((-1.0..1.0).contains(&v), "{v}");
        }
        assert_ne!(seed_value("a", 3), seed_value("b", 3));
    }

    #[test]
    fn executes_double_kernel() {
        let k = KernelBuilder::new("double", &["n"])
            .group_dims_1d(LinExpr::var("n"), 64)
            .global_array("a", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, false)
            .global_array("out", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("out", vec![gid_lin_1d(64)]),
                Expr::mul(Expr::lit(2.0), Expr::load("a", vec![gid_lin_1d(64)])),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap();
        let e = env(&[("n", 256)]);
        let st = execute(&k, &e).unwrap();
        let out = st.get("out").unwrap();
        for i in 0..256 {
            assert_eq!(out[i], 2.0 * seed_value("a", i));
        }
    }

    #[test]
    fn executes_tiled_transpose_with_barrier() {
        // out[j, i] = a[i, j] via a local tile
        let n = LinExpr::var("n");
        let k = KernelBuilder::new("tr", &["n"])
            .group_dims_2d(n.clone(), 8, n.clone(), 8)
            .global_array("a", DType::F32, vec![n.clone(), n.clone()], Layout::RowMajor, false)
            .global_array("out", DType::F32, vec![n.clone(), n.clone()], Layout::RowMajor, true)
            .local_array("tile", DType::F32, &[8, 8])
            .insn(
                Access::new("tile", vec![LinExpr::var("l1"), LinExpr::var("l0")]),
                Expr::load("a", vec![gid(1, 8), gid(0, 8)]),
                &["g0", "g1", "l0", "l1"],
                &[],
            )
            .insn(
                Access::new(
                    "out",
                    vec![
                        LinExpr::scaled_var("g0", 8).add(&LinExpr::var("l1")),
                        LinExpr::scaled_var("g1", 8).add(&LinExpr::var("l0")),
                    ],
                ),
                Expr::load("tile", vec![LinExpr::var("l0"), LinExpr::var("l1")]),
                &["g0", "g1", "l0", "l1"],
                &[0],
            )
            .build()
            .unwrap();
        let e = env(&[("n", 16)]);
        let st = execute(&k, &e).unwrap();
        let out = st.get("out").unwrap();
        for i in 0..16usize {
            for j in 0..16usize {
                assert_eq!(out[j * 16 + i], seed_value("a", i * 16 + j), "({i},{j})");
            }
        }
    }

    #[test]
    fn executes_tiled_mm_with_accumulator() {
        // c = a @ b via 4x4 tiles with private accumulator
        let n = LinExpr::var("n");
        let k = KernelBuilder::new("mm", &["n"])
            .group_dims_2d(n.clone(), 4, n.clone(), 4)
            .seq_tiles("kt", n.clone(), 4)
            .red_dim("ki", LinExpr::constant(4))
            .global_array("a", DType::F32, vec![n.clone(), n.clone()], Layout::RowMajor, false)
            .global_array("b", DType::F32, vec![n.clone(), n.clone()], Layout::RowMajor, false)
            .global_array("c", DType::F32, vec![n.clone(), n.clone()], Layout::RowMajor, true)
            .local_array("at", DType::F32, &[4, 4])
            .local_array("bt", DType::F32, &[4, 4])
            .private_array("acc", DType::F32, &[1])
            .insn(
                Access::new("at", vec![LinExpr::var("l1"), LinExpr::var("l0")]),
                Expr::load(
                    "a",
                    vec![gid(1, 4), LinExpr::scaled_var("kt", 4).add(&LinExpr::var("l0"))],
                ),
                &["g0", "g1", "l0", "l1", "kt"],
                &[],
            )
            .insn(
                Access::new("bt", vec![LinExpr::var("l1"), LinExpr::var("l0")]),
                Expr::load(
                    "b",
                    vec![LinExpr::scaled_var("kt", 4).add(&LinExpr::var("l1")), gid(0, 4)],
                ),
                &["g0", "g1", "l0", "l1", "kt"],
                &[],
            )
            .update_insn(
                Access::new("acc", vec![LinExpr::constant(0)]),
                Expr::sum(
                    "ki",
                    Expr::mul(
                        Expr::load("at", vec![LinExpr::var("l1"), LinExpr::var("ki")]),
                        Expr::load("bt", vec![LinExpr::var("ki"), LinExpr::var("l0")]),
                    ),
                ),
                &["g0", "g1", "l0", "l1", "kt"],
                &[0, 1],
            )
            .insn(
                Access::new("c", vec![gid(1, 4), gid(0, 4)]),
                Expr::load("acc", vec![LinExpr::constant(0)]),
                &["g0", "g1", "l0", "l1"],
                &[2],
            )
            .build()
            .unwrap();
        let e = env(&[("n", 8)]);
        let st = execute(&k, &e).unwrap();
        let c = st.get("c").unwrap();
        let n = 8usize;
        for i in 0..n {
            for j in 0..n {
                let want: f64 = (0..n)
                    .map(|kk| seed_value("a", i * n + kk) * seed_value("b", kk * n + j))
                    .sum();
                assert!(
                    (c[i * n + j] - want).abs() < 1e-12,
                    "c[{i},{j}] = {} want {want}",
                    c[i * n + j]
                );
            }
        }
    }

    #[test]
    fn out_of_bounds_detected() {
        let k = KernelBuilder::new("oob", &["n"])
            .group_dims_1d(LinExpr::var("n"), 64)
            .global_array("a", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, false)
            .global_array("out", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("out", vec![gid_lin_1d(64)]),
                Expr::load("a", vec![gid_lin_1d(64).add(&LinExpr::constant(1))]),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap();
        assert!(execute(&k, &env(&[("n", 64)])).is_err());
    }

    #[test]
    fn strided_seq_loop_executes_correct_subset() {
        // out[i] = a[3i] for i in the strided global pattern (stride-3 read)
        let k = KernelBuilder::new("s3", &["n"])
            .group_dims_1d(LinExpr::var("n"), 64)
            .global_array(
                "a",
                DType::F32,
                vec![LinExpr::var("n").scale(3)],
                Layout::RowMajor,
                false,
            )
            .global_array("out", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("out", vec![gid_lin_1d(64)]),
                Expr::load("a", vec![gid_lin_1d(64).scale(3)]),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap();
        let e = env(&[("n", 128)]);
        let st = execute(&k, &e).unwrap();
        let out = st.get("out").unwrap();
        for i in 0..128 {
            assert_eq!(out[i], seed_value("a", 3 * i));
        }
    }
}
