//! `gpusim` — the simulated-GPU substrate.
//!
//! The paper's evaluation requires four physical GPUs (Titan X, K40,
//! C2070, R9 Fury) driven over OpenCL. This module replaces that
//! hardware with simulated devices that
//!
//! 1. **execute** kernels numerically ([`interp`]) so the kernel library
//!    is correctness-checked against reference implementations, and
//! 2. **time** kernels through a hidden, non-linear, transaction-level
//!    cost engine ([`timing`]) with per-device profiles ([`device`]),
//!    reproducing the paper's measurement artifacts (first-touch
//!    slowdown, second-run variance, run-to-run noise, launch overhead).
//!
//! The linear model never sees the engine's internals — only (kernel,
//! wall-time) pairs — so fitting remains a genuine approximation problem.

pub mod device;
pub mod interp;
pub mod registry;
pub mod timing;

pub use device::{all_devices, device, DeviceProfile};
pub use interp::{execute, seed_value, Storage};
pub use registry::DeviceRegistry;
pub use timing::{
    base_time, compiled_for, run_times, sim_draws, Breakdown, CaseTiming, CompiledTiming,
};

use std::sync::Arc;

use crate::lpir::Kernel;
use crate::util::fault::FaultPlan;
use crate::util::intern::Env;

/// The noise seed every [`SimGpu::new`] starts from — the one seed the
/// whole repo's measurement artifacts are pinned against. Callers that
/// persist raw timing streams (the harness measurement cache) record
/// it so a replay can refuse a file drawn under a different stream.
pub const DEFAULT_SEED: u64 = 0xD15C_0;

/// A store of raw timing streams consulted *instead of* simulation —
/// the hook the harness measurement cache
/// ([`crate::harness::meascache::MeasCacheFile`]) plugs into a
/// [`SimGpu`]. Implementations must only answer when every input that
/// shapes the stream matches what they recorded: the device profile,
/// the kernel (structure *and* name — the noise hash folds the literal
/// name), the env, the run count and the seed. Answering with the
/// wrong stream silently corrupts a fit, so when in doubt return
/// `None` and let the simulation run.
pub trait TimingCache: Send + Sync + std::fmt::Debug {
    /// A previously recorded raw stream for this exact case, or `None`.
    fn lookup(
        &self,
        profile: &DeviceProfile,
        kernel: &Kernel,
        env: &Env,
        runs: usize,
        seed: u64,
    ) -> Option<Vec<f64>>;

    /// Record a freshly simulated raw stream (best-effort; never fails
    /// the measurement).
    fn store(
        &self,
        profile: &DeviceProfile,
        kernel: &Kernel,
        env: &Env,
        runs: usize,
        seed: u64,
        times: &[f64],
    );
}

/// A simulated GPU: a profile plus a noise seed, and optionally a fault
/// plan whose `measure.*` sites corrupt the measurement channel.
#[derive(Clone, Debug)]
pub struct SimGpu {
    pub profile: DeviceProfile,
    pub seed: u64,
    /// When set, `measure.fail` / `measure.outlier` faults apply to
    /// every [`SimGpu::time`] call (see [`crate::util::fault`]). `None`
    /// leaves timing byte-identical to the pre-fault-plane behavior.
    pub faults: Option<Arc<FaultPlan>>,
    /// When set, the harness retry loop replays raw streams from this
    /// cache instead of simulating, and records fresh streams into it.
    /// Ignored whenever `faults` is armed: fault draws are counter-based
    /// and must advance exactly as they would live, and corrupted
    /// streams must never be recorded.
    pub meas: Option<Arc<dyn TimingCache>>,
}

impl SimGpu {
    pub fn new(profile: DeviceProfile) -> SimGpu {
        SimGpu { profile, seed: DEFAULT_SEED, faults: None, meas: None }
    }

    pub fn named(name: &str) -> Option<SimGpu> {
        device(name).map(SimGpu::new)
    }

    /// Attach a fault plan (builder-style; `None` detaches).
    pub fn with_faults(mut self, faults: Option<Arc<FaultPlan>>) -> SimGpu {
        self.faults = faults;
        self
    }

    /// Attach a measurement cache (builder-style; `None` detaches).
    pub fn with_meas_cache(mut self, meas: Option<Arc<dyn TimingCache>>) -> SimGpu {
        self.meas = meas;
        self
    }

    /// Time `runs` launches of a kernel (seconds per run), with the
    /// §4.2 measurement artifacts. Fault sites apply *after* the noise
    /// stream is drawn, so an installed plan never shifts the baseline
    /// samples — it only fails the call or corrupts one sample.
    pub fn time(
        &self,
        kernel: &Kernel,
        env: &Env,
        runs: usize,
    ) -> Result<Vec<f64>, String> {
        let mut times = run_times(&self.profile, kernel, env, runs, self.seed)?;
        if let Some(plan) = &self.faults {
            timing::apply_measurement_faults(plan, &kernel.name, &mut times)?;
        }
        Ok(times)
    }

    /// Pre-lower one (kernel, env) case against this GPU: the compiled
    /// timing artifact is fetched (or built) once, the noise-free base
    /// time and the stream hash are evaluated once, and every
    /// [`PreparedCase::time`] call afterwards is pure noise sampling
    /// plus the fault plan. Retry loops use this so noise-only reruns
    /// stop re-paying `base_time`.
    pub fn prepare(&self, kernel: &Kernel, env: &Env) -> Result<PreparedCase, String> {
        let ct = timing::compiled_for(&self.profile, kernel);
        Ok(PreparedCase {
            case: ct.case(&self.profile, kernel, env, self.seed)?,
            kernel_name: kernel.name.clone(),
            faults: self.faults.clone(),
        })
    }

    /// Noise-free cost breakdown (for diagnostics and tests; the
    /// modeling pipeline must not use this).
    pub fn breakdown(
        &self,
        kernel: &Kernel,
        env: &Env,
    ) -> Result<Breakdown, String> {
        base_time(&self.profile, kernel, env)
    }

    /// Execute the kernel numerically (validation path).
    pub fn execute(
        &self,
        kernel: &Kernel,
        env: &Env,
    ) -> Result<Storage, String> {
        execute(kernel, env)
    }
}

/// One (kernel, env) case pre-lowered against a [`SimGpu`]: base time
/// and noise-stream hash computed once, fault plan captured. See
/// [`SimGpu::prepare`].
#[derive(Clone, Debug)]
pub struct PreparedCase {
    case: CaseTiming,
    kernel_name: String,
    faults: Option<Arc<FaultPlan>>,
}

impl PreparedCase {
    /// Time `runs` launches (bit-identical to [`SimGpu::time`] on the
    /// same case: same stream hash, same fault-application order).
    pub fn time(&self, runs: usize) -> Result<Vec<f64>, String> {
        let mut times = self.case.sample(runs);
        if let Some(plan) = &self.faults {
            timing::apply_measurement_faults(plan, &self.kernel_name, &mut times)?;
        }
        Ok(times)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lpir::builder::{gid_lin_1d, KernelBuilder};
    use crate::lpir::{Access, DType, Expr, Layout};
    use crate::qpoly::{env, LinExpr};

    #[test]
    fn sim_gpu_end_to_end() {
        let gpu = SimGpu::named("k40c").unwrap();
        let k = KernelBuilder::new("scale", &["n"])
            .group_dims_1d(LinExpr::var("n"), 128)
            .global_array("a", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, false)
            .global_array("b", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("b", vec![gid_lin_1d(128)]),
                Expr::mul(Expr::lit(3.0), Expr::load("a", vec![gid_lin_1d(128)])),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap();
        // numeric validation at a small size
        let st = gpu.execute(&k, &env(&[("n", 256)])).unwrap();
        for i in 0..256 {
            assert_eq!(st.get("b").unwrap()[i], 3.0 * seed_value("a", i));
        }
        // timing at a large size
        let times = gpu.time(&k, &env(&[("n", 1 << 22)]), 30).unwrap();
        assert_eq!(times.len(), 30);
        assert!(times.iter().all(|&t| t > 0.0));
    }

    #[test]
    fn unknown_device_rejected() {
        assert!(SimGpu::named("quadro_9000").is_none());
    }

    #[test]
    fn prepared_case_matches_direct_timing_bit_for_bit() {
        let gpu = SimGpu::named("titan_x").unwrap();
        let k = KernelBuilder::new("copy_p", &["n"])
            .group_dims_1d(LinExpr::var("n"), 256)
            .global_array("a", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, false)
            .global_array("b", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("b", vec![gid_lin_1d(256)]),
                Expr::load("a", vec![gid_lin_1d(256)]),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap();
        let e = env(&[("n", 1 << 22)]);
        let prepared = gpu.prepare(&k, &e).unwrap();
        let direct = gpu.time(&k, &e, 30).unwrap();
        assert_eq!(prepared.time(30).unwrap(), direct);
        // re-timing a prepared case re-draws the same deterministic stream
        assert_eq!(prepared.time(30).unwrap(), direct);
    }
}
