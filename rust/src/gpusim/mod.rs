//! `gpusim` — the simulated-GPU substrate.
//!
//! The paper's evaluation requires four physical GPUs (Titan X, K40,
//! C2070, R9 Fury) driven over OpenCL. This module replaces that
//! hardware with simulated devices that
//!
//! 1. **execute** kernels numerically ([`interp`]) so the kernel library
//!    is correctness-checked against reference implementations, and
//! 2. **time** kernels through a hidden, non-linear, transaction-level
//!    cost engine ([`timing`]) with per-device profiles ([`device`]),
//!    reproducing the paper's measurement artifacts (first-touch
//!    slowdown, second-run variance, run-to-run noise, launch overhead).
//!
//! The linear model never sees the engine's internals — only (kernel,
//! wall-time) pairs — so fitting remains a genuine approximation problem.

pub mod device;
pub mod interp;
pub mod registry;
pub mod timing;

pub use device::{all_devices, device, DeviceProfile};
pub use interp::{execute, seed_value, Storage};
pub use registry::DeviceRegistry;
pub use timing::{base_time, run_times, Breakdown};

use std::sync::Arc;

use crate::lpir::Kernel;
use crate::util::fault::FaultPlan;
use crate::util::intern::Env;

/// A simulated GPU: a profile plus a noise seed, and optionally a fault
/// plan whose `measure.*` sites corrupt the measurement channel.
#[derive(Clone, Debug)]
pub struct SimGpu {
    pub profile: DeviceProfile,
    pub seed: u64,
    /// When set, `measure.fail` / `measure.outlier` faults apply to
    /// every [`SimGpu::time`] call (see [`crate::util::fault`]). `None`
    /// leaves timing byte-identical to the pre-fault-plane behavior.
    pub faults: Option<Arc<FaultPlan>>,
}

impl SimGpu {
    pub fn new(profile: DeviceProfile) -> SimGpu {
        SimGpu { profile, seed: 0xD15C_0, faults: None }
    }

    pub fn named(name: &str) -> Option<SimGpu> {
        device(name).map(SimGpu::new)
    }

    /// Attach a fault plan (builder-style; `None` detaches).
    pub fn with_faults(mut self, faults: Option<Arc<FaultPlan>>) -> SimGpu {
        self.faults = faults;
        self
    }

    /// Time `runs` launches of a kernel (seconds per run), with the
    /// §4.2 measurement artifacts. Fault sites apply *after* the noise
    /// stream is drawn, so an installed plan never shifts the baseline
    /// samples — it only fails the call or corrupts one sample.
    pub fn time(
        &self,
        kernel: &Kernel,
        env: &Env,
        runs: usize,
    ) -> Result<Vec<f64>, String> {
        let mut times = run_times(&self.profile, kernel, env, runs, self.seed)?;
        if let Some(plan) = &self.faults {
            timing::apply_measurement_faults(plan, &kernel.name, &mut times)?;
        }
        Ok(times)
    }

    /// Noise-free cost breakdown (for diagnostics and tests; the
    /// modeling pipeline must not use this).
    pub fn breakdown(
        &self,
        kernel: &Kernel,
        env: &Env,
    ) -> Result<Breakdown, String> {
        base_time(&self.profile, kernel, env)
    }

    /// Execute the kernel numerically (validation path).
    pub fn execute(
        &self,
        kernel: &Kernel,
        env: &Env,
    ) -> Result<Storage, String> {
        execute(kernel, env)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lpir::builder::{gid_lin_1d, KernelBuilder};
    use crate::lpir::{Access, DType, Expr, Layout};
    use crate::qpoly::{env, LinExpr};

    #[test]
    fn sim_gpu_end_to_end() {
        let gpu = SimGpu::named("k40c").unwrap();
        let k = KernelBuilder::new("scale", &["n"])
            .group_dims_1d(LinExpr::var("n"), 128)
            .global_array("a", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, false)
            .global_array("b", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("b", vec![gid_lin_1d(128)]),
                Expr::mul(Expr::lit(3.0), Expr::load("a", vec![gid_lin_1d(128)])),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap();
        // numeric validation at a small size
        let st = gpu.execute(&k, &env(&[("n", 256)])).unwrap();
        for i in 0..256 {
            assert_eq!(st.get("b").unwrap()[i], 3.0 * seed_value("a", i));
        }
        // timing at a large size
        let times = gpu.time(&k, &env(&[("n", 1 << 22)]), 30).unwrap();
        assert_eq!(times.len(), 30);
        assert!(times.iter().all(|&t| t > 0.0));
    }

    #[test]
    fn unknown_device_rejected() {
        assert!(SimGpu::named("quadro_9000").is_none());
    }
}
