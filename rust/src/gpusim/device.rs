//! Simulated device profiles.
//!
//! Stand-ins for the paper's four GPUs (§5): Nvidia GTX Titan X
//! (Maxwell), Tesla K40 (Kepler), Tesla C2070 (Fermi) and AMD Radeon R9
//! Fury. Each profile parameterizes the *hidden* cost engine in
//! [`super::timing`] — deliberately richer than the linear model
//! (transactions, caches, overlap, occupancy waves, latency floors), so
//! that fitting the model against the simulator remains a non-trivial
//! approximation problem with the paper's error structure.
//!
//! The constants are drawn from the public spec sheets of the real parts
//! (bandwidth, SM/CU counts, clocks, FP64 ratios) so that simulated times
//! land in the same millisecond ranges as the paper's Table 1.
//!
//! Profiles are plain owned values that round-trip through
//! [`crate::util::json`]; the full device catalogue (the four paper
//! parts plus the synthetic generation/vendor spread, user-extensible
//! from JSON) lives in [`super::registry`].

use crate::util::json::Json;
use std::collections::BTreeMap;

/// The measurement/evaluation kernel classes whose base size exponent a
/// profile may override via its `size_exp` table (JSON key `"size_exp"`:
/// `{"<class>": <exponent>}`). Unknown class names are a validation
/// error — a typo must not silently leave the capability-derived value
/// in place.
pub const SIZE_EXP_CLASSES: &[&str] = &[
    // §4.1 measurement classes
    "mm_tiled", "mm_naive", "vsadd", "transpose", "sg", "sg_filled", "arith", "empty",
    // §5 test kernels
    "fd5", "mm_skinny", "conv7", "nbody",
    // evaluation-zoo expansion
    "reduce_tree", "scan_hs", "st3d7", "bmm8", "gather_s2",
];

/// Override exponents outside this range would create degenerate or
/// absurdly large sweeps (sizes are `2^p`-based with up to +8 octaves).
pub const SIZE_EXP_RANGE: (i64, i64) = (1, 26);

/// A simulated GPU.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceProfile {
    pub name: String,
    /// marketing name for reports
    pub full_name: String,
    /// streaming multiprocessors (Nvidia) / compute units (AMD)
    pub sms: u32,
    /// shader clock in Hz
    pub clock_hz: f64,
    /// f32 lanes per SM
    pub cores_per_sm: u32,
    /// SIMD width of a scheduling unit (warp 32 / wavefront 64)
    pub warp_size: u32,
    /// sustained DRAM bandwidth, bytes/s (≈75% of peak spec)
    pub dram_bw: f64,
    /// memory-transaction (cache-line) size in bytes
    pub line_bytes: u32,
    /// L2 cache size in bytes (smooths re-walked footprints)
    pub l2_bytes: u64,
    /// per-SM L1/texture cache in bytes (absorbs within-group reuse)
    pub l1_bytes: u64,
    /// L2-hit bandwidth multiplier over DRAM
    pub l2_bw_mult: f64,
    /// aggregate local/shared-memory bandwidth, bytes/s
    pub local_bw: f64,
    /// cycles per f32 op: add/sub & mul
    pub cyc_mad: f64,
    /// cycles per f32 division
    pub cyc_div: f64,
    /// cycles per f32 exponentiation (pow/exp)
    pub cyc_exp: f64,
    /// cycles per f32 special function (rsqrt, sqrt, trig)
    pub cyc_special: f64,
    /// f64 throughput ratio (f32 rate / f64 rate), e.g. 32 on Maxwell
    pub f64_ratio: f64,
    /// barrier cost in cycles per warp that crosses it
    pub cyc_barrier: f64,
    /// kernel-launch fixed overhead, seconds
    pub launch_base: f64,
    /// additional launch overhead per work group, seconds
    pub launch_per_group: f64,
    /// resident thread limit per SM (occupancy)
    pub threads_per_sm: u32,
    /// maximum resident groups per SM
    pub max_groups_per_sm: u32,
    /// maximum work-group size the device accepts
    pub max_group_size: u32,
    /// per-wave pipeline/latency floor, seconds (exposed when few waves)
    pub wave_latency: f64,
    /// fraction of min(mem, alu) hidden by overlap, in [0, 1]
    pub overlap: f64,
    /// run-to-run multiplicative noise sigma (log-normal)
    pub noise_sigma: f64,
    /// first-run (first-touch allocation) slowdown factor
    pub first_touch_factor: f64,
    /// extra noise sigma on the second run (paper §4.2 observes this)
    pub second_run_sigma: f64,
    /// "irregularity": amplitude of a deterministic size-dependent ripple
    /// in effective bandwidth (0 = regular device)
    pub irregularity: f64,
    /// extra penalty multiplier on uncoalesced (large-stride) traffic
    pub uncoalesced_penalty: f64,
    /// per-class base size-exponent overrides, layered over the
    /// capability-derived solver ([`crate::kernels::size_exp`]): class
    /// name ([`SIZE_EXP_CLASSES`]) -> exponent. Empty for every
    /// built-in; user profiles opt in via the JSON `"size_exp"` object.
    pub size_exp: BTreeMap<String, i64>,
}

/// The four devices of the paper's evaluation (§5). The widened
/// catalogue — these four plus the synthetic cross-generation parts —
/// is served by [`super::registry::builtins`].
pub fn all_devices() -> Vec<DeviceProfile> {
    vec![titan_x(), k40c(), c2070(), r9_fury()]
}

/// Look up a device profile by short name, through the cached built-in
/// registry (the catalogue is constructed once per process, not
/// rebuilt per lookup).
pub fn device(name: &str) -> Option<DeviceProfile> {
    super::registry::builtins().get(name).cloned()
}

/// Nvidia GTX Titan X (Maxwell, GM200).
pub fn titan_x() -> DeviceProfile {
    DeviceProfile {
        name: "titan_x".into(),
        full_name: "Nvidia GTX Titan X".into(),
        sms: 24,
        clock_hz: 1.0e9,
        cores_per_sm: 128,
        warp_size: 32,
        dram_bw: 0.75 * 336.5e9,
        line_bytes: 128,
        l2_bytes: 3 << 20,
        l1_bytes: 48 << 10,
        l2_bw_mult: 3.5,
        local_bw: 24.0 * 128.0 * 1.0e9, // 128 B/cycle/SM
        cyc_mad: 1.0,
        cyc_div: 8.0,
        cyc_exp: 16.0,
        cyc_special: 4.0,
        f64_ratio: 32.0,
        cyc_barrier: 32.0,
        launch_base: 6.0e-6,
        launch_per_group: 1.5e-9,
        threads_per_sm: 2048,
        max_groups_per_sm: 32,
        max_group_size: 1024,
        wave_latency: 2.5e-6,
        overlap: 0.70,
        noise_sigma: 0.015,
        first_touch_factor: 1.9,
        second_run_sigma: 0.06,
        irregularity: 0.0,
        uncoalesced_penalty: 1.0,
        size_exp: BTreeMap::new(),
    }
}

/// Nvidia Tesla K40c (Kepler, GK110B).
pub fn k40c() -> DeviceProfile {
    DeviceProfile {
        name: "k40c".into(),
        full_name: "Nvidia Tesla K40".into(),
        sms: 15,
        clock_hz: 745.0e6,
        cores_per_sm: 192,
        warp_size: 32,
        dram_bw: 0.72 * 288.4e9,
        line_bytes: 128,
        l2_bytes: 1536 << 10,
        l1_bytes: 48 << 10,
        l2_bw_mult: 3.0,
        local_bw: 15.0 * 128.0 * 745.0e6,
        cyc_mad: 1.0,
        cyc_div: 10.0,
        cyc_exp: 18.0,
        cyc_special: 6.0,
        f64_ratio: 3.0,
        cyc_barrier: 40.0,
        launch_base: 8.0e-6,
        launch_per_group: 2.5e-9,
        threads_per_sm: 2048,
        max_groups_per_sm: 16,
        max_group_size: 1024,
        wave_latency: 3.5e-6,
        overlap: 0.75, // Kepler's dual issue hides arithmetic well
        noise_sigma: 0.012,
        first_touch_factor: 1.8,
        second_run_sigma: 0.05,
        irregularity: 0.0,
        uncoalesced_penalty: 1.1,
        size_exp: BTreeMap::new(),
    }
}

/// Nvidia Tesla C2070 (Fermi, GF100).
pub fn c2070() -> DeviceProfile {
    DeviceProfile {
        name: "c2070".into(),
        full_name: "Nvidia Tesla C2070".into(),
        sms: 14,
        clock_hz: 1.15e9,
        cores_per_sm: 32,
        warp_size: 32,
        dram_bw: 0.70 * 144.0e9,
        line_bytes: 128,
        l2_bytes: 768 << 10,
        l1_bytes: 48 << 10,
        l2_bw_mult: 2.5,
        local_bw: 14.0 * 64.0 * 1.15e9,
        cyc_mad: 1.0,
        cyc_div: 12.0,
        cyc_exp: 20.0,
        cyc_special: 8.0,
        f64_ratio: 2.0,
        cyc_barrier: 48.0,
        launch_base: 10.0e-6,
        launch_per_group: 3.5e-9,
        threads_per_sm: 1536,
        max_groups_per_sm: 8,
        max_group_size: 1024,
        wave_latency: 4.5e-6,
        overlap: 0.60, // Fermi overlaps less
        noise_sigma: 0.016,
        first_touch_factor: 1.7,
        second_run_sigma: 0.07,
        irregularity: 0.0,
        uncoalesced_penalty: 1.3, // weaker coalescing hardware
        size_exp: BTreeMap::new(),
    }
}

/// AMD Radeon R9 Fury (Fiji). The paper found this device "irregular and
/// ... less amenable to being captured by our model", with the highest
/// launch overhead; the profile reflects that with a large launch cost, a
/// 64-lane wavefront, a deterministic bandwidth ripple and heavier
/// uncoalesced-access penalties.
pub fn r9_fury() -> DeviceProfile {
    DeviceProfile {
        name: "r9_fury".into(),
        full_name: "AMD Radeon R9 Fury".into(),
        sms: 56,
        clock_hz: 1.0e9,
        cores_per_sm: 64,
        warp_size: 64,
        dram_bw: 0.65 * 512.0e9,
        line_bytes: 64,
        l2_bytes: 2 << 20,
        l1_bytes: 16 << 10,
        l2_bw_mult: 2.0,
        local_bw: 56.0 * 128.0 * 1.0e9,
        cyc_mad: 1.0,
        cyc_div: 10.0,
        cyc_exp: 16.0,
        cyc_special: 4.0,
        f64_ratio: 16.0,
        cyc_barrier: 40.0,
        launch_base: 45.0e-6, // highest launch overhead (paper §4.2)
        launch_per_group: 6.0e-9,
        threads_per_sm: 2560,
        max_groups_per_sm: 40,
        max_group_size: 256, // paper: "the Radeon R9 Fury limits group sizes to 256"
        wave_latency: 5.0e-6,
        overlap: 0.55,
        noise_sigma: 0.02,
        first_touch_factor: 2.2,
        second_run_sigma: 0.10,
        irregularity: 0.35,
        uncoalesced_penalty: 1.6,
        size_exp: BTreeMap::new(),
    }
}

impl DeviceProfile {
    /// Peak f32 rate in ops/s.
    pub fn peak_f32(&self) -> f64 {
        self.sms as f64 * self.cores_per_sm as f64 * self.clock_hz
    }

    /// Cycles per op for a model operation kind.
    pub fn cycles_for(&self, kind: crate::lpir::OpKind, bits: u32) -> f64 {
        use crate::lpir::OpKind::*;
        let base = match kind {
            AddSub | Mul => self.cyc_mad,
            Div => self.cyc_div,
            Exp => self.cyc_exp,
            Special => self.cyc_special,
        };
        if bits == 64 {
            base * self.f64_ratio
        } else {
            base
        }
    }

    /// Resident groups machine-wide for a given group size (occupancy).
    pub fn concurrent_groups(&self, group_size: i64) -> i64 {
        let by_threads = (self.threads_per_sm as i64 / group_size.max(1)).max(1);
        let per_sm = by_threads.min(self.max_groups_per_sm as i64);
        per_sm * self.sms as i64
    }

    /// The launch-overhead floor: the fixed per-launch cost (launch base
    /// plus the pipeline-latency floor) that the §4.2 timing protocol
    /// must comfortably exceed. The capability-derived suite
    /// configuration ([`crate::kernels`]) sizes every case against this.
    pub fn launch_floor_s(&self) -> f64 {
        self.launch_base + self.wave_latency
    }

    /// The base size exponent for a kernel class: the profile's
    /// `size_exp` override when present, the capability-`derived` value
    /// otherwise. Class names are validated at profile load/registration
    /// time ([`SIZE_EXP_CLASSES`]), so a present key is authoritative.
    pub fn class_size_exp(&self, class: &str, derived: i64) -> i64 {
        self.size_exp.get(class).copied().unwrap_or(derived)
    }

    /// Sanity-check a profile (used when loading user-supplied JSON):
    /// positive rates/counts and a group-size cap the capability
    /// derivation can work with (≥ 64, multiple of 16, within the
    /// per-SM thread budget).
    pub fn validate(&self) -> Result<(), String> {
        let err = |m: &str| Err(format!("device '{}': {m}", self.name));
        if self.name.is_empty() {
            return Err("device profile with empty name".into());
        }
        if self.sms == 0 || self.cores_per_sm == 0 || self.warp_size == 0 {
            return err("sms, cores_per_sm and warp_size must be positive");
        }
        if !(self.clock_hz > 0.0 && self.dram_bw > 0.0 && self.local_bw > 0.0) {
            return err("clock_hz, dram_bw and local_bw must be positive");
        }
        if self.line_bytes < 4 {
            return err("line_bytes must be at least one f32");
        }
        if self.max_group_size < 64 || self.max_group_size % 16 != 0 {
            return err("max_group_size must be a multiple of 16, at least 64");
        }
        if self.threads_per_sm < self.max_group_size {
            return err("threads_per_sm must admit at least one maximal group");
        }
        if self.max_groups_per_sm == 0 {
            return err("max_groups_per_sm must be positive");
        }
        if !(self.launch_base >= 0.0 && self.launch_per_group >= 0.0 && self.wave_latency >= 0.0)
        {
            return err("launch overheads must be non-negative");
        }
        if !(0.0..=1.0).contains(&self.overlap) {
            return err("overlap must be in [0, 1]");
        }
        for (class, &p) in &self.size_exp {
            if !SIZE_EXP_CLASSES.contains(&class.as_str()) {
                return Err(format!(
                    "device '{}': size_exp override for unknown class '{class}' \
                     (known: {})",
                    self.name,
                    SIZE_EXP_CLASSES.join(", ")
                ));
            }
            let (lo, hi) = SIZE_EXP_RANGE;
            if !(lo..=hi).contains(&p) {
                return Err(format!(
                    "device '{}': size_exp override for '{class}' is {p}, \
                     outside [{lo}, {hi}]",
                    self.name
                ));
            }
        }
        Ok(())
    }

    /// Serialize to JSON (one object per profile; field names match the
    /// struct). Emits every field, so [`DeviceProfile::from_json`]
    /// round-trips exactly.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::Str(self.name.clone())),
            ("full_name", Json::Str(self.full_name.clone())),
            ("sms", Json::Num(self.sms as f64)),
            ("clock_hz", Json::Num(self.clock_hz)),
            ("cores_per_sm", Json::Num(self.cores_per_sm as f64)),
            ("warp_size", Json::Num(self.warp_size as f64)),
            ("dram_bw", Json::Num(self.dram_bw)),
            ("line_bytes", Json::Num(self.line_bytes as f64)),
            ("l2_bytes", Json::Num(self.l2_bytes as f64)),
            ("l1_bytes", Json::Num(self.l1_bytes as f64)),
            ("l2_bw_mult", Json::Num(self.l2_bw_mult)),
            ("local_bw", Json::Num(self.local_bw)),
            ("cyc_mad", Json::Num(self.cyc_mad)),
            ("cyc_div", Json::Num(self.cyc_div)),
            ("cyc_exp", Json::Num(self.cyc_exp)),
            ("cyc_special", Json::Num(self.cyc_special)),
            ("f64_ratio", Json::Num(self.f64_ratio)),
            ("cyc_barrier", Json::Num(self.cyc_barrier)),
            ("launch_base", Json::Num(self.launch_base)),
            ("launch_per_group", Json::Num(self.launch_per_group)),
            ("threads_per_sm", Json::Num(self.threads_per_sm as f64)),
            ("max_groups_per_sm", Json::Num(self.max_groups_per_sm as f64)),
            ("max_group_size", Json::Num(self.max_group_size as f64)),
            ("wave_latency", Json::Num(self.wave_latency)),
            ("overlap", Json::Num(self.overlap)),
            ("noise_sigma", Json::Num(self.noise_sigma)),
            ("first_touch_factor", Json::Num(self.first_touch_factor)),
            ("second_run_sigma", Json::Num(self.second_run_sigma)),
            ("irregularity", Json::Num(self.irregularity)),
            ("uncoalesced_penalty", Json::Num(self.uncoalesced_penalty)),
        ];
        if !self.size_exp.is_empty() {
            pairs.push((
                "size_exp",
                Json::Obj(
                    self.size_exp
                        .iter()
                        .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
                        .collect(),
                ),
            ));
        }
        Json::obj(pairs)
    }

    /// Deserialize from JSON produced by [`DeviceProfile::to_json`] or
    /// hand-written for `--devices`. Hardware fields are required; the
    /// measurement-artifact fields (noise, first-touch, ripple) default
    /// to a well-behaved device when omitted. The result is
    /// [`DeviceProfile::validate`]d.
    pub fn from_json(j: &Json) -> Result<DeviceProfile, String> {
        let name = j
            .get_str("name")
            .ok_or("device profile: missing 'name'")?
            .to_string();
        let req = |key: &str| -> Result<f64, String> {
            j.get_f64(key)
                .ok_or_else(|| format!("device '{name}': missing numeric field '{key}'"))
        };
        // integer counts load strictly: fractional or out-of-range
        // values would otherwise truncate/saturate silently through
        // `as` casts and defeat validation
        let req_u32 = |key: &str| -> Result<u32, String> {
            let v = req(key)?;
            if v.fract() != 0.0 || !(0.0..=u32::MAX as f64).contains(&v) {
                return Err(format!("device '{name}': field '{key}' must be a u32 integer"));
            }
            Ok(v as u32)
        };
        let req_u64 = |key: &str| -> Result<u64, String> {
            let v = req(key)?;
            if v.fract() != 0.0 || v < 0.0 || v >= 9_007_199_254_740_992.0 {
                return Err(format!(
                    "device '{name}': field '{key}' must be an exactly-representable integer"
                ));
            }
            Ok(v as u64)
        };
        let opt = |key: &str, default: f64| -> f64 { j.get_f64(key).unwrap_or(default) };
        let size_exp = match j.get("size_exp") {
            None => BTreeMap::new(),
            Some(Json::Obj(m)) => {
                let mut out = BTreeMap::new();
                for (class, v) in m {
                    match v.as_i64() {
                        Some(n) => {
                            out.insert(class.clone(), n);
                        }
                        None => {
                            return Err(format!(
                                "device '{name}': size_exp entry '{class}' must be an \
                                 integer exponent"
                            ))
                        }
                    }
                }
                out
            }
            Some(_) => {
                return Err(format!(
                    "device '{name}': 'size_exp' must be an object of class -> exponent"
                ))
            }
        };
        let p = DeviceProfile {
            full_name: j.get_str("full_name").unwrap_or(&name).to_string(),
            sms: req_u32("sms")?,
            clock_hz: req("clock_hz")?,
            cores_per_sm: req_u32("cores_per_sm")?,
            warp_size: req_u32("warp_size")?,
            dram_bw: req("dram_bw")?,
            line_bytes: req_u32("line_bytes")?,
            l2_bytes: req_u64("l2_bytes")?,
            l1_bytes: req_u64("l1_bytes")?,
            l2_bw_mult: opt("l2_bw_mult", 2.5),
            local_bw: req("local_bw")?,
            cyc_mad: opt("cyc_mad", 1.0),
            cyc_div: opt("cyc_div", 10.0),
            cyc_exp: opt("cyc_exp", 16.0),
            cyc_special: opt("cyc_special", 4.0),
            f64_ratio: opt("f64_ratio", 16.0),
            cyc_barrier: opt("cyc_barrier", 40.0),
            launch_base: req("launch_base")?,
            launch_per_group: opt("launch_per_group", 2.0e-9),
            threads_per_sm: req_u32("threads_per_sm")?,
            max_groups_per_sm: req_u32("max_groups_per_sm")?,
            max_group_size: req_u32("max_group_size")?,
            wave_latency: opt("wave_latency", 3.0e-6),
            overlap: opt("overlap", 0.65),
            noise_sigma: opt("noise_sigma", 0.015),
            first_touch_factor: opt("first_touch_factor", 1.8),
            second_run_sigma: opt("second_run_sigma", 0.05),
            irregularity: opt("irregularity", 0.0),
            uncoalesced_penalty: opt("uncoalesced_penalty", 1.0),
            size_exp,
            name,
        };
        p.validate()?;
        Ok(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_paper_devices_and_registry_lookup() {
        let names: Vec<&str> = all_devices().iter().map(|d| d.name.as_str()).collect();
        assert_eq!(names, vec!["titan_x", "k40c", "c2070", "r9_fury"]);
        assert!(device("k40c").is_some());
        assert!(device("gtx480").is_none());
    }

    #[test]
    fn profile_json_roundtrip_exact() {
        for d in all_devices() {
            let j = d.to_json().pretty();
            let parsed = Json::parse(&j).unwrap();
            let back = DeviceProfile::from_json(&parsed).unwrap();
            assert_eq!(back, d, "{} did not round-trip", d.name);
        }
    }

    #[test]
    fn from_json_defaults_and_validation() {
        // minimal hardware-only profile: artifact fields take defaults
        let text = r#"{
            "name": "toy", "sms": 4, "clock_hz": 1e9, "cores_per_sm": 32,
            "warp_size": 32, "dram_bw": 5e10, "line_bytes": 64,
            "l2_bytes": 524288, "l1_bytes": 16384, "local_bw": 1e11,
            "launch_base": 1e-5, "threads_per_sm": 1024,
            "max_groups_per_sm": 8, "max_group_size": 256
        }"#;
        let p = DeviceProfile::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(p.full_name, "toy");
        assert_eq!(p.irregularity, 0.0);
        assert!(p.noise_sigma > 0.0);
        assert!(p.validate().is_ok());
        // an undersized group cap is rejected
        let bad = text.replace("\"max_group_size\": 256", "\"max_group_size\": 48");
        assert!(DeviceProfile::from_json(&Json::parse(&bad).unwrap()).is_err());
        // a missing hardware field is rejected with the field name
        let missing = text.replace("\"dram_bw\": 5e10,", "");
        let e = DeviceProfile::from_json(&Json::parse(&missing).unwrap()).unwrap_err();
        assert!(e.contains("dram_bw"), "{e}");
        // fractional and oversized integer counts are rejected, not
        // silently truncated/saturated
        let frac = text.replace("\"sms\": 4,", "\"sms\": 2.7,");
        let e = DeviceProfile::from_json(&Json::parse(&frac).unwrap()).unwrap_err();
        assert!(e.contains("sms"), "{e}");
        let huge = text.replace("\"threads_per_sm\": 1024,", "\"threads_per_sm\": 1e19,");
        let e = DeviceProfile::from_json(&Json::parse(&huge).unwrap()).unwrap_err();
        assert!(e.contains("threads_per_sm"), "{e}");
    }

    #[test]
    fn size_exp_overrides_roundtrip_and_validate() {
        // a profile with overrides round-trips exactly
        let mut p = k40c();
        p.size_exp.insert("mm_tiled".into(), 7);
        p.size_exp.insert("fd5".into(), 9);
        p.validate().unwrap();
        let back = DeviceProfile::from_json(&Json::parse(&p.to_json().pretty()).unwrap())
            .unwrap();
        assert_eq!(back, p);
        assert_eq!(back.class_size_exp("mm_tiled", 11), 7);
        assert_eq!(back.class_size_exp("vsadd", 20), 20, "no override -> derived");
        // built-ins emit no size_exp key at all
        assert!(k40c().to_json().get("size_exp").is_none());

        // unknown class names are a validation error, not a silent no-op
        let mut bad = k40c();
        bad.size_exp.insert("mm_tyled".into(), 7);
        let e = bad.validate().unwrap_err();
        assert!(e.contains("mm_tyled") && e.contains("known:"), "{e}");

        // out-of-range exponents are rejected
        let mut bad = k40c();
        bad.size_exp.insert("fd5".into(), 40);
        assert!(bad.validate().unwrap_err().contains("outside"), "{}",
            bad.validate().unwrap_err());

        // JSON-side: non-integer exponents and non-object tables
        let text = r#"{
            "name": "toy", "sms": 4, "clock_hz": 1e9, "cores_per_sm": 32,
            "warp_size": 32, "dram_bw": 5e10, "line_bytes": 64,
            "l2_bytes": 524288, "l1_bytes": 16384, "local_bw": 1e11,
            "launch_base": 1e-5, "threads_per_sm": 1024,
            "max_groups_per_sm": 8, "max_group_size": 256,
            "size_exp": {"nbody": 10}
        }"#;
        let p = DeviceProfile::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(p.class_size_exp("nbody", 12), 10);
        let frac = text.replace("\"nbody\": 10", "\"nbody\": 10.5");
        assert!(DeviceProfile::from_json(&Json::parse(&frac).unwrap()).is_err());
        let scalar = text.replace("{\"nbody\": 10}", "7");
        assert!(DeviceProfile::from_json(&Json::parse(&scalar).unwrap()).is_err());
        let unknown = text.replace("\"nbody\"", "\"warpshuffle\"");
        let e = DeviceProfile::from_json(&Json::parse(&unknown).unwrap()).unwrap_err();
        assert!(e.contains("warpshuffle"), "{e}");
    }

    #[test]
    fn fury_is_the_irregular_device() {
        let f = r9_fury();
        for d in [titan_x(), k40c(), c2070()] {
            assert!(f.launch_base > d.launch_base);
            assert!(f.irregularity > d.irregularity);
        }
        assert_eq!(f.max_group_size, 256);
        assert_eq!(f.warp_size, 64);
    }

    #[test]
    fn peak_rates_ordering() {
        // Titan X > Fury-f32? Fury peak: 56*64*1e9 = 3.58 Tops; TitanX 3.07
        // — Fury has higher f32 peak; what must hold is Fermi being lowest.
        let peaks: Vec<f64> = all_devices().iter().map(|d| d.peak_f32()).collect();
        let fermi = c2070().peak_f32();
        assert!(peaks.iter().all(|&p| p >= fermi));
    }

    #[test]
    fn occupancy_limits() {
        let d = titan_x();
        assert_eq!(d.concurrent_groups(256), 8 * 24);
        assert_eq!(d.concurrent_groups(1024), 2 * 24);
        // tiny groups run into the max-groups cap
        assert_eq!(d.concurrent_groups(32), 32 * 24);
    }

    #[test]
    fn f64_costs_more() {
        use crate::lpir::OpKind;
        for d in all_devices() {
            assert!(d.cycles_for(OpKind::Mul, 64) > d.cycles_for(OpKind::Mul, 32));
            assert!(d.cycles_for(OpKind::Div, 32) > d.cycles_for(OpKind::AddSub, 32));
        }
    }
}
