//! `uniperf` CLI — drive the unified, hardware-fitted, cross-GPU
//! performance model end to end.
//!
//! Subcommands:
//! * `pipeline` — full Figure-1 pipeline over all devices (Table 1 + 2);
//!   `--zoo` evaluates the full 9-class kernel zoo instead of the §5 four
//! * `crossval` — held-out cross-validation over the evaluation-kernel
//!   zoo (`--split kernel|case|device`, `--quick` for the smoke
//!   campaign; the `device` split reports a device×device
//!   transfer-error matrix)
//! * `fit`      — calibrate one device and print its weight table;
//!   `--save <models.json>` instead fits *all* configured devices and
//!   persists their weight tables as a fingerprinted artifact
//! * `predict`  — with `--models <models.json>`: answer predictions
//!   from a saved artifact (one-shot via `--kernel`/`--case`/`--env`,
//!   or a whole `--requests` file of line-delimited JSON); without
//!   `--models`: legacy predict + measure of the §5 test kernels
//! * `serve`    — the prediction server: line-delimited JSON requests
//!   on stdin (responses on stdout, summary on stderr), or a TCP
//!   listener with `--port`. The listener transport is selected by
//!   `--transport auto|reactor|threaded`: on Linux the default is the
//!   epoll reactor (one readiness loop, nonblocking sockets, a fixed
//!   worker pool, and cross-connection batch formation under the
//!   `--batch-ms` window); elsewhere, or on request, one thread per
//!   connection. Both share the cache, the `--max-conns` connection
//!   guard and the `--queue-cap` bound, and drain on a
//!   `{"cmd": "shutdown"}` request; requires `--models`. `--watch`
//!   hot-reloads the artifact when the file changes (a bad rewrite
//!   keeps the old models serving). Requests may also be batched
//!   device×kernel matrices (`{"cmd": "matrix", ...}`)
//! * `devices`  — list the device registry (built-ins + `--devices`
//!   file); `--export <path>` writes a commented, loadable
//!   `profiles.json` template instead
//! * `props`    — show extracted properties for one evaluation kernel
//!
//! `--devices <profiles.json>` extends the device registry with
//! user-defined profiles (a JSON array of profile objects, or
//! `{"devices": [...]}`; see `DeviceProfile::to_json` for the field
//! set) and adds them to the run — every kernel suite is derived from
//! profile capabilities, so a loaded device runs the full pipeline
//! end to end.

use std::path::Path;
use uniperf::coordinator::{fit_models, run_device, run_pipeline, Config, FitBackend};
use uniperf::obs::log::Level;
use uniperf::obs::{log as olog_mod, span};
use uniperf::olog;
use uniperf::crossval::{run_crossval, CrossvalOpts, Split};
use uniperf::gpusim::registry;
use uniperf::harness::Protocol;
use uniperf::report::{render_service, render_table2};
use uniperf::service::{reactor, tcp, ModelStore, Service, ServiceConfig};
use uniperf::stats::{extract, ExtractOpts, Schema};
use uniperf::util::cli::{parse, usage, Args, OptSpec};
use uniperf::util::json::Json;

fn specs() -> Vec<OptSpec> {
    vec![
        // no parser-level default: `fit --save`/`pipeline` treat an
        // explicit --device differently from its absence; single-device
        // subcommands default to k40c at their use sites via get_or
        OptSpec { name: "device", help: "device name, default k40c (see the 'devices' subcommand)", is_flag: false, default: None },
        OptSpec { name: "devices", help: "JSON file of extra device profiles to register and run", is_flag: false, default: None },
        OptSpec { name: "backend", help: "fit backend: native|xla|auto", is_flag: false, default: Some("auto") },
        OptSpec { name: "runs", help: "timing runs per case", is_flag: false, default: Some("30") },
        OptSpec { name: "out", help: "results directory", is_flag: false, default: None },
        OptSpec { name: "workers", help: "worker threads", is_flag: false, default: None },
        OptSpec { name: "kernel", help: "evaluation kernel (default fd5): fd5|mm_skinny|conv7|nbody|reduce_tree|scan_hs|st3d7|bmm8|gather_s2", is_flag: false, default: None },
        OptSpec { name: "collapse-utilization", help: "ablation: ignore utilization ratios", is_flag: true, default: None },
        OptSpec { name: "bin-local-strides", help: "extension (§6.2): bin local loads by bank-conflict stride", is_flag: true, default: None },
        OptSpec { name: "zoo", help: "pipeline: evaluate the full 9-class kernel zoo", is_flag: true, default: None },
        OptSpec { name: "split", help: "crossval split: kernel|case|device", is_flag: false, default: Some("kernel") },
        OptSpec { name: "quick", help: "crossval: cut-down smoke campaign", is_flag: true, default: None },
        OptSpec { name: "save", help: "fit: persist weight tables (all configured devices, or just --device) to this artifact", is_flag: false, default: None },
        OptSpec { name: "models", help: "serve/predict: model artifact written by 'fit --save'", is_flag: false, default: None },
        OptSpec { name: "case", help: "predict: size-case letter (a-d)", is_flag: false, default: None },
        OptSpec { name: "env", help: "predict: size bindings, e.g. n=4096 or n=512,m=64", is_flag: false, default: None },
        OptSpec { name: "requests", help: "predict: answer a file of line-delimited JSON requests", is_flag: false, default: None },
        OptSpec { name: "port", help: "serve: listen on 127.0.0.1:<port> instead of stdin/stdout (threaded, one connection per thread)", is_flag: false, default: None },
        OptSpec { name: "batch", help: "serve: requests per executor batch", is_flag: false, default: Some("64") },
        OptSpec { name: "watch", help: "serve: hot-reload --models when the file changes (polled between batches/connections)", is_flag: true, default: None },
        OptSpec { name: "max-conn", help: "serve --port: concurrent-connection guard", is_flag: false, default: Some("256") },
        OptSpec { name: "max-conns", help: "serve --port: alias for --max-conn (takes precedence when both are given)", is_flag: false, default: None },
        OptSpec { name: "transport", help: "serve --port: auto|reactor|threaded (auto picks the epoll reactor where supported)", is_flag: false, default: Some("auto") },
        OptSpec { name: "batch-ms", help: "serve --port (reactor): cross-connection batch-formation window in milliseconds", is_flag: false, default: Some("2") },
        OptSpec { name: "queue-cap", help: "serve: pending-request queue bound; beyond it requests shed with reason \"overloaded\"", is_flag: false, default: None },
        OptSpec { name: "export", help: "devices: write a commented profiles.json template to this path", is_flag: false, default: None },
        OptSpec { name: "faults", help: "chaos: deterministic fault-injection plan (JSON: {\"seed\", \"sites\": {\"<site>\": {\"rate\", \"max\"?}}})", is_flag: false, default: None },
        OptSpec { name: "degraded", help: "serve/predict: answer for devices the artifact lacks from the nearest-capability fitted device (responses flagged \"degraded\")", is_flag: true, default: None },
        OptSpec { name: "props-cache", help: "serve/predict: persistent extraction-cache file (append-only JSON lines, created if missing; a restarted server preloads it and warm-starts, an incompatible file is ignored with a warning)", is_flag: false, default: None },
        OptSpec { name: "meas-cache", help: "fit/crossval/pipeline: persistent campaign measurement cache (append-only JSON lines, created if missing; a repeated run replays its raw timing streams bit-identically with zero simulation, an incompatible file is ignored with a warning)", is_flag: false, default: None },
        OptSpec { name: "log-level", help: "stderr verbosity: error|warn|info|debug|off", is_flag: false, default: Some("info") },
        OptSpec { name: "trace", help: "record structured spans (serve exposes them via {\"cmd\": \"trace\"}; slow roots land in a separate ring)", is_flag: true, default: None },
        OptSpec { name: "slow-ms", help: "with --trace/--profile: root spans at least this many ms are kept in the slow ring", is_flag: false, default: Some("500") },
        OptSpec { name: "profile", help: "write recorded spans as Chrome trace-event JSON (chrome://tracing, Perfetto) to this path at exit; implies --trace", is_flag: false, default: None },
    ]
}

fn backend_of(s: &str) -> Result<FitBackend, String> {
    match s {
        "native" => Ok(FitBackend::Native),
        "xla" => Ok(FitBackend::Xla),
        "auto" => Ok(FitBackend::Auto),
        other => Err(format!("unknown backend '{other}'")),
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            print_help();
            return;
        }
    };
    if let Err(e) = dispatch(cmd, &rest) {
        olog!(Level::Error, "error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "uniperf {} — unified, hardware-fitted, cross-GPU performance model",
        uniperf::VERSION
    );
    println!();
    println!("subcommands: pipeline | crossval | fit | predict | serve | devices | props");
    println!();
    println!("{}", usage("uniperf <subcommand>", "options", &specs()));
}

fn make_config(args: &uniperf::util::cli::Args) -> Result<Config, String> {
    let mut cfg = Config::default();
    cfg.backend = backend_of(args.get_or("backend", "auto"))?;
    cfg.protocol = Protocol { runs: args.get_usize("runs", 30)?, ..Protocol::default() };
    cfg.extract = ExtractOpts {
        collapse_utilization: args.has_flag("collapse-utilization"),
        bin_local_strides: args.has_flag("bin-local-strides"),
    };
    if let Some(out) = args.get("out") {
        cfg.out_dir = Some(out.into());
    }
    if let Some(w) = args.get("workers") {
        cfg.workers = w.parse().map_err(|_| "bad --workers")?;
    }
    cfg.eval_zoo = args.has_flag("zoo");
    cfg.degraded = args.has_flag("degraded");
    if let Some(path) = args.get("props-cache") {
        cfg.props_cache = Some(path.into());
    }
    if let Some(path) = args.get("meas-cache") {
        cfg.meas_cache = Some(path.into());
    }
    if let Some(path) = args.get("faults") {
        let plan = uniperf::util::fault::FaultPlan::load(Path::new(path))?;
        olog!(Level::Info, "uniperf: fault injection armed (--faults {path}, seed {})", plan.seed());
        cfg.faults = Some(std::sync::Arc::new(plan));
    }
    if let Some(path) = args.get("devices") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("--devices {path}: {e}"))?;
        let doc = Json::parse(&text).map_err(|e| format!("--devices {path}: {e}"))?;
        let loaded = cfg
            .registry
            .extend_from_json(&doc)
            .map_err(|e| format!("--devices {path}: {e}"))?;
        // loaded profiles join the run (deduplicated against defaults)
        for name in loaded {
            if !cfg.devices.contains(&name) {
                cfg.devices.push(name);
            }
        }
    }
    Ok(cfg)
}

/// Load a model artifact and stand up a validated [`Service`] over the
/// run's registry (including any `--devices` extensions).
fn load_service(models: &str, cfg: &Config, args: &Args) -> Result<Service, String> {
    let schema = Schema::full();
    let store = ModelStore::load(Path::new(models), &schema)?;
    let defaults = ServiceConfig::default();
    let svc_cfg = ServiceConfig {
        batch: args.get_usize("batch", 64)?,
        workers: cfg.workers,
        extract: cfg.extract,
        queue_cap: args.get_usize("queue-cap", defaults.queue_cap)?,
        ..defaults
    };
    // the serving engine is built here (not through `Service::new`) so
    // it carries the run's fault plan and degraded-mode setting along
    // with the registry — `ServiceConfig` is plain-`Copy` and cannot
    // hold the `Arc`'d plan
    let engine = uniperf::engine::Engine::with_cache_capacity(
        Config {
            registry: cfg.registry.clone(),
            extract: cfg.extract,
            workers: cfg.workers,
            faults: cfg.faults.clone(),
            degraded: cfg.degraded,
            props_cache: cfg.props_cache.clone(),
            ..Config::default()
        },
        svc_cfg.cache_capacity,
    );
    engine.install_store(store)?;
    Service::over(std::sync::Arc::new(engine), svc_cfg)
}

/// One-line campaign-plane summary from the process-global campaign
/// registry: total measured cases across devices plus measurement-cache
/// traffic. `None` when nothing was measured (e.g. artifact-backed
/// predict), so non-campaign commands stay silent.
fn campaign_summary() -> Option<String> {
    use uniperf::obs::metrics::{campaign, MetricValue};
    let snap = campaign().snapshot();
    let cases: u64 = snap
        .iter()
        .filter(|(name, _)| name.starts_with("campaign_cases_total"))
        .map(|(_, v)| match v {
            MetricValue::Counter(c) => *c,
            _ => 0,
        })
        .sum();
    let hits = snap.counter("meascache_hits_total");
    let misses = snap.counter("meascache_misses_total");
    let refused = snap.counter("meascache_refused_total");
    if cases == 0 && hits + misses + refused == 0 {
        return None;
    }
    Some(format!(
        "campaign: {cases} cases measured; meas cache: {hits} replayed, \
         {misses} simulated, {refused} file(s) refused"
    ))
}

/// Emit the campaign-plane summary on stderr after a measuring command.
fn log_campaign_summary() {
    if let Some(s) = campaign_summary() {
        olog!(Level::Info, "uniperf: {s}");
    }
}

/// Assemble the one-shot `predict` request line from CLI flags.
fn one_shot_request(args: &Args) -> Result<String, String> {
    let mut pairs = vec![
        ("device", Json::Str(args.get_or("device", "k40c").to_string())),
        ("kernel", Json::Str(args.get_or("kernel", "fd5").to_string())),
    ];
    if let Some(case) = args.get("case") {
        pairs.push(("case", Json::Str(case.to_string())));
    }
    if let Some(env) = args.get("env") {
        let mut bindings = Vec::new();
        for part in env.split(',') {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| format!("--env expects k=v pairs, got '{part}'"))?;
            let n: i64 = v
                .trim()
                .parse()
                .map_err(|_| format!("--env {k}: integer expected, got '{v}'"))?;
            bindings.push((k.trim().to_string(), Json::Num(n as f64)));
        }
        pairs.push((
            "env",
            Json::Obj(bindings.into_iter().collect()),
        ));
    }
    Ok(Json::obj(pairs).compact())
}

fn dispatch(cmd: &str, rest: &[String]) -> Result<(), String> {
    let args = parse(rest, &specs())?;
    olog_mod::set_level_str(args.get_or("log-level", "info"))?;
    let profile = args.get("profile").map(String::from);
    if args.has_flag("trace") || profile.is_some() {
        span::enable(args.get_f64("slow-ms", 500.0)?);
    }
    let result = run_cmd(cmd, &args);
    // written even when the command failed: a trace of the failing run
    // is exactly what the flag is for
    if let Some(path) = profile {
        match span::write_chrome_trace(Path::new(&path)) {
            Ok(()) => olog!(Level::Info, "uniperf: wrote trace profile to {path}"),
            Err(e) => olog!(Level::Warn, "uniperf: could not write --profile: {e}"),
        }
    }
    result
}

fn run_cmd(cmd: &str, args: &Args) -> Result<(), String> {
    match cmd {
        "pipeline" => {
            let cfg = make_config(args)?;
            let t0 = std::time::Instant::now();
            let result = run_pipeline(&cfg)?;
            println!("{}", result.table1.render());
            for dr in &result.per_device {
                println!(
                    "{}: {} cases, launch overhead {:.1} µs, train geomean {:.1}%",
                    dr.device,
                    dr.n_measurement_cases,
                    dr.launch_overhead_s * 1e6,
                    100.0 * dr.model.train_rel_err_geomean
                );
                for w in &dr.warnings {
                    olog!(Level::Warn, "  warning [{}]: {w}", dr.device);
                }
                for (label, reason) in &dr.quarantined {
                    olog!(Level::Warn, "  quarantined [{}]: {label}: {reason}", dr.device);
                }
            }
            println!("pipeline completed in {:.1}s", t0.elapsed().as_secs_f64());
            log_campaign_summary();
            Ok(())
        }
        "crossval" => {
            let cfg = make_config(args)?;
            let split = match args.get_or("split", "kernel") {
                "kernel" => Split::LeaveOneKernelOut,
                "case" => Split::LeaveOneSizeCaseOut,
                "device" => Split::LeaveOneDeviceOut,
                other => return Err(format!("unknown split '{other}' (kernel|case|device)")),
            };
            let opts = CrossvalOpts { base: cfg, split, quick: args.has_flag("quick") };
            let t0 = std::time::Instant::now();
            let result = run_crossval(&opts)?;
            println!("{}", result.render());
            println!("crossval completed in {:.1}s", t0.elapsed().as_secs_f64());
            log_campaign_summary();
            Ok(())
        }
        "fit" => {
            let cfg = make_config(args)?;
            if let Some(path) = args.get("save") {
                // fit --save: all configured devices -> persisted
                // artifact; an explicit --device narrows the fit to
                // that one device instead of being silently ignored
                let mut cfg = cfg;
                if let Some(device) = args.get("device") {
                    cfg.devices = vec![device.to_string()];
                }
                let t0 = std::time::Instant::now();
                let store = fit_models(&cfg)?;
                let schema = Schema::full();
                store.save(Path::new(path), &schema)?;
                for d in store.devices() {
                    let sm = store.get(&d).unwrap();
                    println!(
                        "{d}: {} cases, train geomean {:.1}%, profile fp {}, suite fp {}",
                        sm.n_measurement_cases,
                        100.0 * sm.model.train_rel_err_geomean,
                        sm.profile_fp,
                        sm.suite_fp
                    );
                }
                println!(
                    "saved {} fitted device models to {path} in {:.1}s",
                    store.len(),
                    t0.elapsed().as_secs_f64()
                );
                log_campaign_summary();
                return Ok(());
            }
            let device = args.get_or("device", "k40c").to_string();
            let schema = Schema::full();
            let dr = run_device(&device, &schema, &cfg)?;
            println!("{}", render_table2(&dr.model, &schema));
            for w in &dr.warnings {
                olog!(Level::Warn, "warning: {w}");
            }
            for (label, reason) in &dr.quarantined {
                olog!(Level::Warn, "quarantined: {label}: {reason}");
            }
            log_campaign_summary();
            Ok(())
        }
        "predict" => {
            let cfg = make_config(args)?;
            if args.get("models").is_none() {
                // the artifact-backed flags must not be silently dropped
                // by the legacy measure-everything path
                for flag in ["requests", "case", "env"] {
                    if args.get(flag).is_some() {
                        return Err(format!(
                            "--{flag} requires --models <models.json> (create one \
                             with 'fit --save')"
                        ));
                    }
                }
            }
            if let Some(models) = args.get("models") {
                // artifact-backed predict: no measurement, no refit
                let svc = load_service(models, &cfg, args)?;
                if let Some(reqfile) = args.get("requests") {
                    // a requests file carries its own device/kernel/case
                    // per line; one-shot flags cannot be honored and
                    // must not be silently dropped
                    for flag in ["device", "kernel", "case", "env"] {
                        if args.get(flag).is_some() {
                            return Err(format!(
                                "--{flag} does not combine with --requests (each \
                                 request line names its own device/kernel)"
                            ));
                        }
                    }
                    let text = std::fs::read_to_string(reqfile)
                        .map_err(|e| format!("--requests {reqfile}: {e}"))?;
                    let out = std::io::stdout();
                    let summary = svc.serve(text.as_bytes(), out.lock())?;
                    eprint!("{}", render_service(&summary));
                } else {
                    let line = one_shot_request(args)?;
                    let resp = svc.respond(&line);
                    println!("{}", resp.compact());
                    // scripted callers rely on the exit status: a failed
                    // one-shot prediction is a CLI error, not a 0-exit
                    if let Some(e) = resp.get_str("error") {
                        return Err(format!("prediction failed: {e}"));
                    }
                }
                return Ok(());
            }
            let device = args.get_or("device", "k40c").to_string();
            let schema = Schema::full();
            let dr = run_device(&device, &schema, &cfg)?;
            println!(
                "{:<12} {:>6} {:>12} {:>12} {:>8}",
                "kernel", "case", "pred (ms)", "actual (ms)", "relerr"
            );
            for (k, c, pred, act) in &dr.tests {
                println!(
                    "{:<12} {:>6} {:>12.3} {:>12.3} {:>7.1}%",
                    k,
                    c,
                    pred * 1e3,
                    act * 1e3,
                    100.0 * (pred - act).abs() / act
                );
            }
            Ok(())
        }
        "serve" => {
            let cfg = make_config(args)?;
            let models = args.get("models").ok_or(
                "serve requires --models <models.json> (create one with 'fit --save')",
            )?;
            let mut svc = load_service(models, &cfg, args)?;
            if args.has_flag("watch") {
                // hot artifact reload: polled between batches (stdin
                // loop) / before each connection (TCP); a bad rewrite
                // keeps the old store serving
                svc.watch(Path::new(models));
            }
            match args.get("port") {
                None => {
                    let stdin = std::io::stdin();
                    let out = std::io::stdout();
                    let summary = svc.serve(stdin.lock(), out.lock())?;
                    eprint!("{}", render_service(&summary));
                }
                Some(p) => {
                    let port: u16 =
                        p.parse().map_err(|_| format!("bad --port '{p}'"))?;
                    let max_conn = if args.get("max-conns").is_some() {
                        args.get_usize("max-conns", tcp::DEFAULT_MAX_CONNECTIONS)?
                    } else {
                        args.get_usize("max-conn", tcp::DEFAULT_MAX_CONNECTIONS)?
                    };
                    let transport = match args.get_or("transport", "auto") {
                        "threaded" => "threaded",
                        "reactor" => {
                            if !reactor::supported() {
                                return Err(
                                    "--transport reactor: the epoll reactor requires \
                                     Linux on x86_64/aarch64 (use --transport threaded)"
                                        .into(),
                                );
                            }
                            "reactor"
                        }
                        "auto" => {
                            if reactor::supported() {
                                "reactor"
                            } else {
                                "threaded"
                            }
                        }
                        other => {
                            return Err(format!(
                                "unknown transport '{other}' (auto|reactor|threaded)"
                            ))
                        }
                    };
                    let listener = std::net::TcpListener::bind(("127.0.0.1", port))
                        .map_err(|e| format!("bind 127.0.0.1:{port}: {e}"))?;
                    olog!(
                        Level::Info,
                        "uniperf serve: listening on 127.0.0.1:{port} \
                         (line-delimited JSON requests, one response line each; \
                         {transport} transport, up to {max_conn} connections; send \
                         {{\"cmd\": \"shutdown\"}} to drain)"
                    );
                    // one shared service either way; both transports
                    // return once a shutdown request drained everything
                    let svc = std::sync::Arc::new(svc);
                    let summary = if transport == "reactor" {
                        let rcfg = reactor::ReactorConfig {
                            max_conns: max_conn,
                            batch_ms: args.get_f64("batch-ms", reactor::DEFAULT_BATCH_MS)?,
                            batch_cap: svc.config().batch,
                            workers: svc.config().workers,
                            ..reactor::ReactorConfig::default()
                        };
                        reactor::serve_reactor(&svc, listener, rcfg)?
                    } else {
                        tcp::serve_threaded(&svc, listener, max_conn)?
                    };
                    eprint!("{}", render_service(&summary));
                }
            }
            Ok(())
        }
        "devices" => {
            let cfg = make_config(args)?;
            if let Some(path) = args.get("export") {
                std::fs::write(path, registry::export_template().pretty())
                    .map_err(|e| format!("write {path}: {e}"))?;
                println!(
                    "wrote device-profile template to {path} \
                     (edit it, then load with --devices {path})"
                );
                return Ok(());
            }
            println!(
                "{:<10} {:<36} {:>5} {:>10} {:>10} {:>5} {:>6} {:>10}",
                "name", "full name", "SMs", "clock", "BW (GB/s)", "warp", "maxg", "launch"
            );
            for d in cfg.registry.iter() {
                println!(
                    "{:<10} {:<36} {:>5} {:>7.2}GHz {:>10.0} {:>5} {:>6} {:>8.1}µs",
                    d.name,
                    d.full_name,
                    d.sms,
                    d.clock_hz / 1e9,
                    d.dram_bw / 1e9,
                    d.warp_size,
                    d.max_group_size,
                    d.launch_base * 1e6
                );
            }
            Ok(())
        }
        "props" => {
            let cfg = make_config(args)?;
            let device = args.get_or("device", "k40c").to_string();
            let kernel_name = args.get_or("kernel", "fd5");
            let profile = cfg
                .registry
                .get(&device)
                .ok_or_else(|| format!("unknown device '{device}'"))?;
            let suite = uniperf::kernels::eval_suite(profile);
            let case = suite
                .iter()
                .find(|c| c.kernel.name == kernel_name)
                .ok_or_else(|| format!("unknown test kernel '{kernel_name}'"))?;
            let props = extract(&case.kernel, &case.env, ExtractOpts::default())?;
            println!("symbolic properties of {kernel_name} (polynomials in the size parameters):");
            for (label, q) in props.nonzero() {
                println!("  {:<42} {}", label, q);
            }
            println!("\nat {:?}:", case.env);
            let schema = Schema::full();
            let v = props.eval(&schema, &case.env)?;
            for (i, p) in schema.props().iter().enumerate() {
                if v[i] != 0.0 {
                    println!("  {:<42} {:e}", p.label(), v[i]);
                }
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(format!("unknown subcommand '{other}' (try 'help')")),
    }
}
