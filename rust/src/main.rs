//! `uniperf` CLI — drive the unified, hardware-fitted, cross-GPU
//! performance model end to end.
//!
//! Subcommands:
//! * `pipeline` — full Figure-1 pipeline over all devices (Table 1 + 2);
//!   `--zoo` evaluates the full 9-class kernel zoo instead of the §5 four
//! * `crossval` — held-out cross-validation over the evaluation-kernel
//!   zoo (`--split kernel|case|device`, `--quick` for the smoke
//!   campaign; the `device` split reports a device×device
//!   transfer-error matrix)
//! * `fit`      — calibrate one device and print its weight table
//! * `predict`  — predict + measure the §5 test kernels on one device
//! * `devices`  — list the device registry (built-ins + `--devices` file)
//! * `props`    — show extracted properties for one evaluation kernel
//!
//! `--devices <profiles.json>` extends the device registry with
//! user-defined profiles (a JSON array of profile objects, or
//! `{"devices": [...]}`; see `DeviceProfile::to_json` for the field
//! set) and adds them to the run — every kernel suite is derived from
//! profile capabilities, so a loaded device runs the full pipeline
//! end to end.

use uniperf::coordinator::{run_device, run_pipeline, Config, FitBackend};
use uniperf::crossval::{run_crossval, CrossvalOpts, Split};
use uniperf::util::json::Json;
use uniperf::harness::Protocol;
use uniperf::report::render_table2;
use uniperf::stats::{extract, ExtractOpts, Schema};
use uniperf::util::cli::{parse, usage, OptSpec};

fn specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "device", help: "device name (see the 'devices' subcommand)", is_flag: false, default: Some("k40c") },
        OptSpec { name: "devices", help: "JSON file of extra device profiles to register and run", is_flag: false, default: None },
        OptSpec { name: "backend", help: "fit backend: native|xla|auto", is_flag: false, default: Some("auto") },
        OptSpec { name: "runs", help: "timing runs per case", is_flag: false, default: Some("30") },
        OptSpec { name: "out", help: "results directory", is_flag: false, default: None },
        OptSpec { name: "workers", help: "worker threads", is_flag: false, default: None },
        OptSpec { name: "kernel", help: "evaluation kernel: fd5|mm_skinny|conv7|nbody|reduce_tree|scan_hs|st3d7|bmm8|gather_s2", is_flag: false, default: Some("fd5") },
        OptSpec { name: "collapse-utilization", help: "ablation: ignore utilization ratios", is_flag: true, default: None },
        OptSpec { name: "bin-local-strides", help: "extension (§6.2): bin local loads by bank-conflict stride", is_flag: true, default: None },
        OptSpec { name: "zoo", help: "pipeline: evaluate the full 9-class kernel zoo", is_flag: true, default: None },
        OptSpec { name: "split", help: "crossval split: kernel|case|device", is_flag: false, default: Some("kernel") },
        OptSpec { name: "quick", help: "crossval: cut-down smoke campaign", is_flag: true, default: None },
    ]
}

fn backend_of(s: &str) -> Result<FitBackend, String> {
    match s {
        "native" => Ok(FitBackend::Native),
        "xla" => Ok(FitBackend::Xla),
        "auto" => Ok(FitBackend::Auto),
        other => Err(format!("unknown backend '{other}'")),
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            print_help();
            return;
        }
    };
    if let Err(e) = dispatch(cmd, &rest) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "uniperf {} — unified, hardware-fitted, cross-GPU performance model",
        uniperf::VERSION
    );
    println!();
    println!("subcommands: pipeline | crossval | fit | predict | devices | props");
    println!();
    println!("{}", usage("uniperf <subcommand>", "options", &specs()));
}

fn make_config(args: &uniperf::util::cli::Args) -> Result<Config, String> {
    let mut cfg = Config::default();
    cfg.backend = backend_of(args.get_or("backend", "auto"))?;
    cfg.protocol = Protocol { runs: args.get_usize("runs", 30)?, ..Protocol::default() };
    cfg.extract = ExtractOpts {
        collapse_utilization: args.has_flag("collapse-utilization"),
        bin_local_strides: args.has_flag("bin-local-strides"),
    };
    if let Some(out) = args.get("out") {
        cfg.out_dir = Some(out.into());
    }
    if let Some(w) = args.get("workers") {
        cfg.workers = w.parse().map_err(|_| "bad --workers")?;
    }
    cfg.eval_zoo = args.has_flag("zoo");
    if let Some(path) = args.get("devices") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("--devices {path}: {e}"))?;
        let doc = Json::parse(&text).map_err(|e| format!("--devices {path}: {e}"))?;
        let loaded = cfg
            .registry
            .extend_from_json(&doc)
            .map_err(|e| format!("--devices {path}: {e}"))?;
        // loaded profiles join the run (deduplicated against defaults)
        for name in loaded {
            if !cfg.devices.contains(&name) {
                cfg.devices.push(name);
            }
        }
    }
    Ok(cfg)
}

fn dispatch(cmd: &str, rest: &[String]) -> Result<(), String> {
    let args = parse(rest, &specs())?;
    match cmd {
        "pipeline" => {
            let cfg = make_config(&args)?;
            let t0 = std::time::Instant::now();
            let result = run_pipeline(&cfg)?;
            println!("{}", result.table1.render());
            for dr in &result.per_device {
                println!(
                    "{}: {} cases, launch overhead {:.1} µs, train geomean {:.1}%",
                    dr.device,
                    dr.n_measurement_cases,
                    dr.launch_overhead_s * 1e6,
                    100.0 * dr.model.train_rel_err_geomean
                );
            }
            println!("pipeline completed in {:.1}s", t0.elapsed().as_secs_f64());
            Ok(())
        }
        "crossval" => {
            let cfg = make_config(&args)?;
            let split = match args.get_or("split", "kernel") {
                "kernel" => Split::LeaveOneKernelOut,
                "case" => Split::LeaveOneSizeCaseOut,
                "device" => Split::LeaveOneDeviceOut,
                other => return Err(format!("unknown split '{other}' (kernel|case|device)")),
            };
            let opts = CrossvalOpts { base: cfg, split, quick: args.has_flag("quick") };
            let t0 = std::time::Instant::now();
            let result = run_crossval(&opts)?;
            println!("{}", result.render());
            println!("crossval completed in {:.1}s", t0.elapsed().as_secs_f64());
            Ok(())
        }
        "fit" => {
            let cfg = make_config(&args)?;
            let device = args.get_or("device", "k40c").to_string();
            let schema = Schema::full();
            let dr = run_device(&device, &schema, &cfg)?;
            println!("{}", render_table2(&dr.model, &schema));
            Ok(())
        }
        "predict" => {
            let cfg = make_config(&args)?;
            let device = args.get_or("device", "k40c").to_string();
            let schema = Schema::full();
            let dr = run_device(&device, &schema, &cfg)?;
            println!(
                "{:<12} {:>6} {:>12} {:>12} {:>8}",
                "kernel", "case", "pred (ms)", "actual (ms)", "relerr"
            );
            for (k, c, pred, act) in &dr.tests {
                println!(
                    "{:<12} {:>6} {:>12.3} {:>12.3} {:>7.1}%",
                    k,
                    c,
                    pred * 1e3,
                    act * 1e3,
                    100.0 * (pred - act).abs() / act
                );
            }
            Ok(())
        }
        "devices" => {
            let cfg = make_config(&args)?;
            println!(
                "{:<10} {:<36} {:>5} {:>10} {:>10} {:>5} {:>6} {:>10}",
                "name", "full name", "SMs", "clock", "BW (GB/s)", "warp", "maxg", "launch"
            );
            for d in cfg.registry.iter() {
                println!(
                    "{:<10} {:<36} {:>5} {:>7.2}GHz {:>10.0} {:>5} {:>6} {:>8.1}µs",
                    d.name,
                    d.full_name,
                    d.sms,
                    d.clock_hz / 1e9,
                    d.dram_bw / 1e9,
                    d.warp_size,
                    d.max_group_size,
                    d.launch_base * 1e6
                );
            }
            Ok(())
        }
        "props" => {
            let cfg = make_config(&args)?;
            let device = args.get_or("device", "k40c").to_string();
            let kernel_name = args.get_or("kernel", "fd5");
            let profile = cfg
                .registry
                .get(&device)
                .ok_or_else(|| format!("unknown device '{device}'"))?;
            let suite = uniperf::kernels::eval_suite(profile);
            let case = suite
                .iter()
                .find(|c| c.kernel.name == kernel_name)
                .ok_or_else(|| format!("unknown test kernel '{kernel_name}'"))?;
            let props = extract(&case.kernel, &case.env, ExtractOpts::default())?;
            println!("symbolic properties of {kernel_name} (polynomials in the size parameters):");
            for (label, q) in props.nonzero() {
                println!("  {:<42} {}", label, q);
            }
            println!("\nat {:?}:", case.env);
            let schema = Schema::full();
            let v = props.eval(&schema, &case.env)?;
            for (i, p) in schema.props().iter().enumerate() {
                if v[i] != 0.0 {
                    println!("  {:<42} {:e}", p.label(), v[i]);
                }
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(format!("unknown subcommand '{other}' (try 'help')")),
    }
}
