//! The four test kernels of §5: finite-difference stencil, skinny matrix
//! multiplication, 7×7×3 convolution, and n-body. Results for these are
//! what Table 1 reports.

use super::{measure::mm_tiled, snap, GroupSet, KernelCase};
use crate::lpir::builder::{gid, KernelBuilder};
use crate::lpir::{Access, DType, Expr, Kernel, Layout, UnOp};
use crate::qpoly::{env, LinExpr};

fn v(name: &str) -> LinExpr {
    LinExpr::var(name)
}

fn c(x: i64) -> LinExpr {
    LinExpr::constant(x)
}

/// Small epsilon added to squared distances in the n-body kernel (the
/// self-interaction term becomes a constant instead of a singularity).
pub const NBODY_EPS: f64 = 1.0e-4;

// ---------------------------------------------------------------------------
// Finite differences
// ---------------------------------------------------------------------------

/// 5-point stencil with a quadratic source term on an `n×n` grid
/// (row-major), prefetching `(gy+2)×(gx+2)` halo tiles into local memory.
/// The input is halo-padded to `(n+2)×(n+2)`, so the kernel is guard-free;
/// each thread performs four shifted loads that jointly cover the tile
/// plus halo.
pub fn fd_stencil(gx: i64, gy: i64) -> Kernel {
    let np2 = v("n").add(&c(2));
    let mut b = KernelBuilder::new("fd5", &["n"])
        .group_dims_2d(v("n"), gx, v("n"), gy)
        .global_array("u", DType::F32, vec![np2.clone(), np2], Layout::RowMajor, false)
        .global_array("out", DType::F32, vec![v("n"), v("n")], Layout::RowMajor, true)
        .local_array("t", DType::F32, &[gy + 2, gx + 2]);
    // four shifted cooperative loads cover [0, gy+2) x [0, gx+2)
    let mut deps = Vec::new();
    for (dy, dx) in [(0i64, 0i64), (0, 2), (2, 0), (2, 2)] {
        b = b.insn(
            Access::new("t", vec![v("l1").add(&c(dy)), v("l0").add(&c(dx))]),
            Expr::load(
                "u",
                vec![gid(1, gy).add(&c(dy)), gid(0, gx).add(&c(dx))],
            ),
            &["g0", "g1", "l0", "l1"],
            &[],
        );
        deps.push(b_len(&b) - 1);
    }
    // out[y, x] = 0.25*(N + S + E + W - 4*C) + C*C
    let center = Expr::load("t", vec![v("l1").add(&c(1)), v("l0").add(&c(1))]);
    let north = Expr::load("t", vec![v("l1"), v("l0").add(&c(1))]);
    let south = Expr::load("t", vec![v("l1").add(&c(2)), v("l0").add(&c(1))]);
    let west = Expr::load("t", vec![v("l1").add(&c(1)), v("l0")]);
    let east = Expr::load("t", vec![v("l1").add(&c(1)), v("l0").add(&c(2))]);
    let laplace = Expr::sub(
        Expr::add(Expr::add(north, south), Expr::add(west, east)),
        Expr::mul(Expr::lit(4.0), center.clone()),
    );
    let rhs = Expr::add(
        Expr::mul(Expr::lit(0.25), laplace),
        Expr::mul(center.clone(), center),
    );
    b.insn(
        Access::new("out", vec![gid(1, gy), gid(0, gx)]),
        rhs,
        &["g0", "g1", "l0", "l1"],
        &deps,
    )
    .build()
    .expect("fd5 builds")
}

fn b_len(b: &KernelBuilder) -> usize {
    b.insn_count()
}

/// Reference implementation of [`fd_stencil`] against seeded inputs.
pub fn fd_reference(n: usize) -> Vec<f64> {
    use crate::gpusim::seed_value;
    let np2 = n + 2;
    let u = |y: usize, x: usize| seed_value("u", y * np2 + x);
    let mut out = vec![0.0; n * n];
    for y in 0..n {
        for x in 0..n {
            let cpt = u(y + 1, x + 1);
            let lap = u(y, x + 1) + u(y + 2, x + 1) + u(y + 1, x) + u(y + 1, x + 2) - 4.0 * cpt;
            out[y * n + x] = 0.25 * lap + cpt * cpt;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// 'Skinny' matrix multiplication
// ---------------------------------------------------------------------------

/// Tiled MM with `n = l = m/8` (§5): reuses the measurement tiled-MM
/// kernel with the skinny shape.
pub fn skinny_mm(gx: i64, gy: i64) -> Kernel {
    let mut k = mm_tiled(gx, gy);
    k.name = "mm_skinny".into();
    k
}

/// Parameter binding for the skinny shape at base size `n`.
pub fn skinny_env(n: i64, gx: i64, gy: i64) -> crate::util::intern::Env {
    let n_ = snap(n, gy);
    let m_ = snap(8 * n, gx);
    let l_ = snap(n, gx);
    env(&[("n", n_), ("m", m_), ("l", l_)])
}

// ---------------------------------------------------------------------------
// Convolution
// ---------------------------------------------------------------------------

/// 7×7 convolution: three filters applied to three RGB images (§5).
///
/// `out[i, j, y, x] = Σ_{η,ξ,c} m[i, y+η, x+ξ, c] · f[j, η, ξ, c]`
///
/// with `m` halo-padded to `(3, n+6, n+6, 3)` (interleaved RGB — the
/// innermost channel axis gives the image loads the lane stride 3 / 3-of-3
/// utilization class) and `f` of shape `(3, 7, 7, 3)` read uniformly.
pub fn convolution(gx: i64, gy: i64) -> Kernel {
    let np6 = v("n").add(&c(6));
    KernelBuilder::new("conv7", &["n"])
        .group_dims_2d(v("n"), gx, v("n"), gy)
        .seq_dim("i", c(3))
        .seq_dim("j", c(3))
        .red_dim("eta", c(7))
        .red_dim("xi", c(7))
        .red_dim("ch", c(3))
        .global_array(
            "m",
            DType::F32,
            vec![c(3), np6.clone(), np6, c(3)],
            Layout::RowMajor,
            false,
        )
        .global_array("f", DType::F32, vec![c(3), c(7), c(7), c(3)], Layout::RowMajor, false)
        .global_array(
            "out",
            DType::F32,
            vec![c(3), c(3), v("n"), v("n")],
            Layout::RowMajor,
            true,
        )
        .insn(
            Access::new("out", vec![v("i"), v("j"), gid(1, gy), gid(0, gx)]),
            Expr::sum(
                "eta",
                Expr::sum(
                    "xi",
                    Expr::sum(
                        "ch",
                        Expr::mul(
                            Expr::load(
                                "m",
                                vec![
                                    v("i"),
                                    gid(1, gy).add(&v("eta")),
                                    gid(0, gx).add(&v("xi")),
                                    v("ch"),
                                ],
                            ),
                            Expr::load("f", vec![v("j"), v("eta"), v("xi"), v("ch")]),
                        ),
                    ),
                ),
            ),
            &["g0", "g1", "l0", "l1", "i", "j"],
            &[],
        )
        .build()
        .expect("conv7 builds")
}

/// Reference implementation of [`convolution`].
pub fn conv_reference(n: usize) -> Vec<f64> {
    use crate::gpusim::seed_value;
    let np6 = n + 6;
    let m = |i: usize, y: usize, x: usize, ch: usize| {
        seed_value("m", ((i * np6 + y) * np6 + x) * 3 + ch)
    };
    let f = |j: usize, e: usize, x: usize, ch: usize| {
        seed_value("f", ((j * 7 + e) * 7 + x) * 3 + ch)
    };
    let mut out = vec![0.0; 3 * 3 * n * n];
    for i in 0..3 {
        for j in 0..3 {
            for y in 0..n {
                for x in 0..n {
                    let mut acc = 0.0;
                    for eta in 0..7 {
                        for xi in 0..7 {
                            for ch in 0..3 {
                                acc += m(i, y + eta, x + xi, ch) * f(j, eta, xi, ch);
                            }
                        }
                    }
                    out[((i * 3 + j) * n + y) * n + x] = acc;
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// N-body
// ---------------------------------------------------------------------------

/// N-body inverse-distance summation (§5): positions in a column-major
/// `3×n` array, prefetched in `3×gsize` blocks into local memory; each
/// thread sums `1/√(|p_i - p_j|² + ε)` over all j.
pub fn nbody(lsize: i64) -> Kernel {
    let i = gid(0, lsize);
    KernelBuilder::new("nbody", &["n"])
        .group_dims_1d(v("n"), lsize)
        .seq_tiles("jt", v("n"), lsize)
        .unroll_dim("cload", 3)
        .red_dim("jl", c(lsize))
        // column-major [3, n]: element (cp, j) at flat cp + 3j
        .global_array("pos", DType::F32, vec![c(3), v("n")], Layout::ColMajor, false)
        .global_array("out", DType::F32, vec![v("n")], Layout::RowMajor, true)
        .local_array("tile", DType::F32, &[3, lsize])
        .private_array("pp", DType::F32, &[3])
        .private_array("acc", DType::F32, &[1])
        // 0: own position into registers (outside the jt loop)
        .insn(
            Access::new("pp", vec![v("cload")]),
            Expr::load("pos", vec![v("cload"), i.clone()]),
            &["g0", "l0", "cload"],
            &[],
        )
        // 1: prefetch a 3×gsize block of positions
        .insn(
            Access::new("tile", vec![v("cload"), v("l0")]),
            Expr::load(
                "pos",
                vec![v("cload"), LinExpr::scaled_var("jt", lsize).add(&v("l0"))],
            ),
            &["g0", "l0", "jt", "cload"],
            &[0],
        )
        // 2: accumulate inverse distances over the tile
        .update_insn(
            Access::new("acc", vec![c(0)]),
            Expr::sum("jl", {
                let d = |cp: i64| {
                    Expr::sub(
                        Expr::load("pp", vec![c(cp)]),
                        Expr::load("tile", vec![c(cp), v("jl")]),
                    )
                };
                let sq = |e: Expr| Expr::mul(e.clone(), e);
                Expr::un(
                    UnOp::Rsqrt,
                    Expr::add(
                        Expr::add(sq(d(0)), sq(d(1))),
                        Expr::add(sq(d(2)), Expr::lit(NBODY_EPS)),
                    ),
                )
            }),
            &["g0", "l0", "jt"],
            &[1],
        )
        // 3: write the sum
        .insn(
            Access::new("out", vec![i]),
            Expr::load("acc", vec![c(0)]),
            &["g0", "l0"],
            &[2],
        )
        .build()
        .expect("nbody builds")
}

/// Reference implementation of [`nbody`].
pub fn nbody_reference(n: usize) -> Vec<f64> {
    use crate::gpusim::seed_value;
    let p = |cp: usize, j: usize| seed_value("pos", cp + 3 * j);
    let mut out = vec![0.0; n];
    for i in 0..n {
        let mut acc = 0.0;
        for j in 0..n {
            let dx = p(0, i) - p(0, j);
            let dy = p(1, i) - p(1, j);
            let dz = p(2, i) - p(2, j);
            acc += 1.0 / (dx * dx + dy * dy + dz * dz + NBODY_EPS).sqrt();
        }
        out[i] = acc;
    }
    out
}

// ---------------------------------------------------------------------------
// Per-device test suite (§5)
// ---------------------------------------------------------------------------

/// §5 per-device configuration: (group set, p) for each test kernel.
fn cfg(device: &str) -> [(GroupSet, i64); 4] {
    // order: fd, skinny_mm, conv, nbody
    match device {
        "r9_fury" => [
            (GroupSet::TwoDSmall, 10),
            (GroupSet::TwoDSmall, 9),
            (GroupSet::TwoDSmall, 7),
            (GroupSet::OneDSmall, 10),
        ],
        "c2070" => [
            (GroupSet::TwoDMed, 10),
            (GroupSet::TwoDMed, 9),
            (GroupSet::TwoDMed, 6),
            (GroupSet::OneDMed, 11),
        ],
        "k40c" => [
            (GroupSet::TwoDMed, 11),
            (GroupSet::TwoDMed, 9),
            (GroupSet::TwoDMed, 7),
            (GroupSet::OneDMed, 11),
        ],
        _ => [
            (GroupSet::TwoDLarge, 11),
            (GroupSet::TwoDLarge, 10),
            (GroupSet::TwoDLarge, 8),
            (GroupSet::OneDLarge, 11),
        ],
    }
}

/// The four §5 test kernels with their 256-thread group configuration and
/// four size cases (`a.`–`d.`, i.e. t = 0..4) each.
pub fn suite(device: &str) -> Vec<KernelCase> {
    let [fd_c, mm_c, cv_c, nb_c] = cfg(device);
    let mut out = Vec::new();

    let (gx, gy) = fd_c.0.g256();
    let k = fd_stencil(gx, gy);
    for t in 0..4 {
        let n = snap(1i64 << (fd_c.1 + t), lcm(gx, gy));
        out.push(KernelCase {
            kernel: k.clone(),
            env: env(&[("n", n)]),
            label: format!("fd5/{}/n={n}", case_letter(t)),
            group: (gx, gy),
        });
    }

    let (gx, gy) = mm_c.0.g256();
    let k = skinny_mm(gx, gy);
    for t in 0..4 {
        let n = 1i64 << (mm_c.1 + t);
        out.push(KernelCase {
            kernel: k.clone(),
            env: skinny_env(n, gx, gy),
            label: format!("mm_skinny/{}/n={n}", case_letter(t)),
            group: (gx, gy),
        });
    }

    let (gx, gy) = cv_c.0.g256();
    let k = convolution(gx, gy);
    for t in 0..4 {
        let n = snap(1i64 << (cv_c.1 + t), lcm(gx, gy));
        out.push(KernelCase {
            kernel: k.clone(),
            env: env(&[("n", n)]),
            label: format!("conv7/{}/n={n}", case_letter(t)),
            group: (gx, gy),
        });
    }

    let (lsize, _) = nb_c.0.g256();
    let k = nbody(lsize);
    for t in 0..4 {
        let n = snap(1i64 << (nb_c.1 + t), lsize);
        out.push(KernelCase {
            kernel: k.clone(),
            env: env(&[("n", n)]),
            label: format!("nbody/{}/n={n}", case_letter(t)),
            group: (lsize, 1),
        });
    }
    out
}

/// Table-1 row letters for the four size cases.
pub fn case_letter(t: i64) -> &'static str {
    ["a", "b", "c", "d"][t as usize]
}

fn gcd(a: i64, b: i64) -> i64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

fn lcm(a: i64, b: i64) -> i64 {
    a / gcd(a, b) * b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{execute, seed_value};

    #[test]
    fn fd_stencil_matches_reference() {
        let k = fd_stencil(8, 8);
        let n = 16usize;
        let st = execute(&k, &env(&[("n", n as i64)])).unwrap();
        let out = st.get("out").unwrap();
        let want = fd_reference(n);
        for i in 0..n * n {
            assert!((out[i] - want[i]).abs() < 1e-10, "i={i}");
        }
    }

    #[test]
    fn skinny_mm_matches_reference() {
        let k = skinny_mm(8, 8);
        let e = skinny_env(8, 8, 8);
        let (n, m, l) = (e["n"] as usize, e["m"] as usize, e["l"] as usize);
        let st = execute(&k, &e).unwrap();
        let cc = st.get("cc").unwrap();
        for i in 0..n {
            for j in 0..l {
                let want: f64 = (0..m)
                    .map(|kk| seed_value("a", i * m + kk) * seed_value("b", kk * l + j))
                    .sum();
                assert!((cc[i * l + j] - want).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn convolution_matches_reference() {
        let k = convolution(8, 4);
        let n = 8usize;
        let st = execute(&k, &env(&[("n", n as i64)])).unwrap();
        let out = st.get("out").unwrap();
        let want = conv_reference(n);
        for i in 0..want.len() {
            assert!((out[i] - want[i]).abs() < 1e-9, "i={i}");
        }
    }

    #[test]
    fn nbody_matches_reference() {
        let k = nbody(16);
        let n = 32usize;
        let st = execute(&k, &env(&[("n", n as i64)])).unwrap();
        let out = st.get("out").unwrap();
        let want = nbody_reference(n);
        for i in 0..n {
            assert!(
                (out[i] - want[i]).abs() / want[i].abs() < 1e-10,
                "i={i}: {} vs {}",
                out[i],
                want[i]
            );
        }
    }

    #[test]
    fn test_suite_has_16_cases_per_device() {
        for dev in ["titan_x", "k40c", "c2070", "r9_fury"] {
            let s = suite(dev);
            assert_eq!(s.len(), 16, "{dev}");
            // 4 kernels x 4 size cases with 256-thread groups
            for case in &s {
                assert_eq!(case.group.0 * case.group.1, 256, "{}", case.label);
            }
        }
    }

    #[test]
    fn nbody_exercises_rsqrt_and_local_loads() {
        use crate::lpir::OpKind;
        use crate::stats::{extract, ExtractOpts, Prop, Schema};
        let k = nbody(16);
        let e = env(&[("n", 64)]);
        let props = extract(&k, &e, ExtractOpts::default()).unwrap();
        let schema = Schema::full();
        let v = props.eval(&schema, &e).unwrap();
        let rsqrt_like =
            v[schema.index_of(&Prop::Op { kind: OpKind::Special, bits: 32 }).unwrap()];
        assert_eq!(rsqrt_like, 64.0 * 64.0); // one rsqrt per pair
        assert!(v[schema.index_of(&Prop::LocalLoad { bits: 32 }).unwrap()] > 0.0);
        assert!(v[schema.index_of(&Prop::Barriers).unwrap()] > 0.0);
    }

    #[test]
    fn conv_filter_reads_are_uniform() {
        use crate::stats::{extract, ExtractOpts, Prop, Schema, Dir};
        use crate::isl::progression::StrideClass;
        let k = convolution(16, 16);
        let e = env(&[("n", 32)]);
        let props = extract(&k, &e, ExtractOpts::default()).unwrap();
        let schema = Schema::full();
        let v = props.eval(&schema, &e).unwrap();
        let uni = v[schema
            .index_of(&Prop::MemGlobal {
                bits: 32,
                dir: Dir::Load,
                class: StrideClass::Uniform,
            })
            .unwrap()];
        assert!(uni > 0.0, "filter loads should be uniform");
        // image loads have lane stride 3, full utilization -> 3/3
        let s3 = v[schema
            .index_of(&Prop::MemGlobal {
                bits: 32,
                dir: Dir::Load,
                class: StrideClass::Frac { numer: 3, denom: 3 },
            })
            .unwrap()];
        assert!(s3 > 0.0, "image loads should be stride-3 full-utilization");
    }
}
