//! The evaluation kernels: the four test kernels of §5 (finite-difference
//! stencil, skinny matrix multiplication, 7×7×3 convolution, n-body) whose
//! results Table 1 reports, plus the expanded evaluation-kernel *zoo*
//! (work-group tree reduction, Hillis–Steele inclusive scan, 7-point 3-D
//! stencil, batched small matrix multiplication, and an ELL/"spmv-like"
//! strided gather) used by the cross-validation subsystem
//! ([`crate::crossval`]) and, behind `Config::eval_zoo`, by the pipeline.
//!
//! Every kernel has a scalar reference implementation. Per-device
//! (group set, size exponent) configuration is **derived from the
//! device profile's capabilities** — no name-matched tables — so any
//! registry device, including profiles loaded from JSON, gets a valid
//! evaluation suite (see [`crate::kernels`]).

use super::{lcm, measure::mm_tiled, one_d_groups, size_exp, snap, t_case, two_d_groups,
    GroupSet, KernelCase};
use crate::gpusim::DeviceProfile;
use crate::lpir::builder::{gid, KernelBuilder};
use crate::lpir::{Access, DType, Expr, Kernel, Layout, UnOp};
use crate::qpoly::{env, LinExpr};

fn v(name: &str) -> LinExpr {
    LinExpr::var(name)
}

fn c(x: i64) -> LinExpr {
    LinExpr::constant(x)
}

/// Small epsilon added to squared distances in the n-body kernel (the
/// self-interaction term becomes a constant instead of a singularity).
pub const NBODY_EPS: f64 = 1.0e-4;

// ---------------------------------------------------------------------------
// Finite differences
// ---------------------------------------------------------------------------

/// 5-point stencil with a quadratic source term on an `n×n` grid
/// (row-major), prefetching `(gy+2)×(gx+2)` halo tiles into local memory.
/// The input is halo-padded to `(n+2)×(n+2)`, so the kernel is guard-free;
/// each thread performs four shifted loads that jointly cover the tile
/// plus halo.
pub fn fd_stencil(gx: i64, gy: i64) -> Kernel {
    let np2 = v("n").add(&c(2));
    let mut b = KernelBuilder::new("fd5", &["n"])
        .group_dims_2d(v("n"), gx, v("n"), gy)
        .global_array("u", DType::F32, vec![np2.clone(), np2], Layout::RowMajor, false)
        .global_array("out", DType::F32, vec![v("n"), v("n")], Layout::RowMajor, true)
        .local_array("t", DType::F32, &[gy + 2, gx + 2]);
    // four shifted cooperative loads cover [0, gy+2) x [0, gx+2)
    let mut deps = Vec::new();
    for (dy, dx) in [(0i64, 0i64), (0, 2), (2, 0), (2, 2)] {
        b = b.insn(
            Access::new("t", vec![v("l1").add(&c(dy)), v("l0").add(&c(dx))]),
            Expr::load(
                "u",
                vec![gid(1, gy).add(&c(dy)), gid(0, gx).add(&c(dx))],
            ),
            &["g0", "g1", "l0", "l1"],
            &[],
        );
        deps.push(b_len(&b) - 1);
    }
    // out[y, x] = 0.25*(N + S + E + W - 4*C) + C*C
    let center = Expr::load("t", vec![v("l1").add(&c(1)), v("l0").add(&c(1))]);
    let north = Expr::load("t", vec![v("l1"), v("l0").add(&c(1))]);
    let south = Expr::load("t", vec![v("l1").add(&c(2)), v("l0").add(&c(1))]);
    let west = Expr::load("t", vec![v("l1").add(&c(1)), v("l0")]);
    let east = Expr::load("t", vec![v("l1").add(&c(1)), v("l0").add(&c(2))]);
    let laplace = Expr::sub(
        Expr::add(Expr::add(north, south), Expr::add(west, east)),
        Expr::mul(Expr::lit(4.0), center.clone()),
    );
    let rhs = Expr::add(
        Expr::mul(Expr::lit(0.25), laplace),
        Expr::mul(center.clone(), center),
    );
    b.insn(
        Access::new("out", vec![gid(1, gy), gid(0, gx)]),
        rhs,
        &["g0", "g1", "l0", "l1"],
        &deps,
    )
    .build()
    .expect("fd5 builds")
}

fn b_len(b: &KernelBuilder) -> usize {
    b.insn_count()
}

/// Reference implementation of [`fd_stencil`] against seeded inputs.
pub fn fd_reference(n: usize) -> Vec<f64> {
    use crate::gpusim::seed_value;
    let np2 = n + 2;
    let u = |y: usize, x: usize| seed_value("u", y * np2 + x);
    let mut out = vec![0.0; n * n];
    for y in 0..n {
        for x in 0..n {
            let cpt = u(y + 1, x + 1);
            let lap = u(y, x + 1) + u(y + 2, x + 1) + u(y + 1, x) + u(y + 1, x + 2) - 4.0 * cpt;
            out[y * n + x] = 0.25 * lap + cpt * cpt;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// 'Skinny' matrix multiplication
// ---------------------------------------------------------------------------

/// Tiled MM with `n = l = m/8` (§5): reuses the measurement tiled-MM
/// kernel with the skinny shape.
pub fn skinny_mm(gx: i64, gy: i64) -> Kernel {
    let mut k = mm_tiled(gx, gy);
    k.name = "mm_skinny".into();
    k
}

/// Parameter binding for the skinny shape at base size `n`.
pub fn skinny_env(n: i64, gx: i64, gy: i64) -> crate::util::intern::Env {
    let n_ = snap(n, gy);
    let m_ = snap(8 * n, gx);
    let l_ = snap(n, gx);
    env(&[("n", n_), ("m", m_), ("l", l_)])
}

// ---------------------------------------------------------------------------
// Convolution
// ---------------------------------------------------------------------------

/// 7×7 convolution: three filters applied to three RGB images (§5).
///
/// `out[i, j, y, x] = Σ_{η,ξ,c} m[i, y+η, x+ξ, c] · f[j, η, ξ, c]`
///
/// with `m` halo-padded to `(3, n+6, n+6, 3)` (interleaved RGB — the
/// innermost channel axis gives the image loads the lane stride 3 / 3-of-3
/// utilization class) and `f` of shape `(3, 7, 7, 3)` read uniformly.
pub fn convolution(gx: i64, gy: i64) -> Kernel {
    let np6 = v("n").add(&c(6));
    KernelBuilder::new("conv7", &["n"])
        .group_dims_2d(v("n"), gx, v("n"), gy)
        .seq_dim("i", c(3))
        .seq_dim("j", c(3))
        .red_dim("eta", c(7))
        .red_dim("xi", c(7))
        .red_dim("ch", c(3))
        .global_array(
            "m",
            DType::F32,
            vec![c(3), np6.clone(), np6, c(3)],
            Layout::RowMajor,
            false,
        )
        .global_array("f", DType::F32, vec![c(3), c(7), c(7), c(3)], Layout::RowMajor, false)
        .global_array(
            "out",
            DType::F32,
            vec![c(3), c(3), v("n"), v("n")],
            Layout::RowMajor,
            true,
        )
        .insn(
            Access::new("out", vec![v("i"), v("j"), gid(1, gy), gid(0, gx)]),
            Expr::sum(
                "eta",
                Expr::sum(
                    "xi",
                    Expr::sum(
                        "ch",
                        Expr::mul(
                            Expr::load(
                                "m",
                                vec![
                                    v("i"),
                                    gid(1, gy).add(&v("eta")),
                                    gid(0, gx).add(&v("xi")),
                                    v("ch"),
                                ],
                            ),
                            Expr::load("f", vec![v("j"), v("eta"), v("xi"), v("ch")]),
                        ),
                    ),
                ),
            ),
            &["g0", "g1", "l0", "l1", "i", "j"],
            &[],
        )
        .build()
        .expect("conv7 builds")
}

/// Reference implementation of [`convolution`].
pub fn conv_reference(n: usize) -> Vec<f64> {
    use crate::gpusim::seed_value;
    let np6 = n + 6;
    let m = |i: usize, y: usize, x: usize, ch: usize| {
        seed_value("m", ((i * np6 + y) * np6 + x) * 3 + ch)
    };
    let f = |j: usize, e: usize, x: usize, ch: usize| {
        seed_value("f", ((j * 7 + e) * 7 + x) * 3 + ch)
    };
    let mut out = vec![0.0; 3 * 3 * n * n];
    for i in 0..3 {
        for j in 0..3 {
            for y in 0..n {
                for x in 0..n {
                    let mut acc = 0.0;
                    for eta in 0..7 {
                        for xi in 0..7 {
                            for ch in 0..3 {
                                acc += m(i, y + eta, x + xi, ch) * f(j, eta, xi, ch);
                            }
                        }
                    }
                    out[((i * 3 + j) * n + y) * n + x] = acc;
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// N-body
// ---------------------------------------------------------------------------

/// N-body inverse-distance summation (§5): positions in a column-major
/// `3×n` array, prefetched in `3×gsize` blocks into local memory; each
/// thread sums `1/√(|p_i - p_j|² + ε)` over all j.
pub fn nbody(lsize: i64) -> Kernel {
    let i = gid(0, lsize);
    KernelBuilder::new("nbody", &["n"])
        .group_dims_1d(v("n"), lsize)
        .seq_tiles("jt", v("n"), lsize)
        .unroll_dim("cload", 3)
        .red_dim("jl", c(lsize))
        // column-major [3, n]: element (cp, j) at flat cp + 3j
        .global_array("pos", DType::F32, vec![c(3), v("n")], Layout::ColMajor, false)
        .global_array("out", DType::F32, vec![v("n")], Layout::RowMajor, true)
        .local_array("tile", DType::F32, &[3, lsize])
        .private_array("pp", DType::F32, &[3])
        .private_array("acc", DType::F32, &[1])
        // 0: own position into registers (outside the jt loop)
        .insn(
            Access::new("pp", vec![v("cload")]),
            Expr::load("pos", vec![v("cload"), i.clone()]),
            &["g0", "l0", "cload"],
            &[],
        )
        // 1: prefetch a 3×gsize block of positions
        .insn(
            Access::new("tile", vec![v("cload"), v("l0")]),
            Expr::load(
                "pos",
                vec![v("cload"), LinExpr::scaled_var("jt", lsize).add(&v("l0"))],
            ),
            &["g0", "l0", "jt", "cload"],
            &[0],
        )
        // 2: accumulate inverse distances over the tile
        .update_insn(
            Access::new("acc", vec![c(0)]),
            Expr::sum("jl", {
                let d = |cp: i64| {
                    Expr::sub(
                        Expr::load("pp", vec![c(cp)]),
                        Expr::load("tile", vec![c(cp), v("jl")]),
                    )
                };
                let sq = |e: Expr| Expr::mul(e.clone(), e);
                Expr::un(
                    UnOp::Rsqrt,
                    Expr::add(
                        Expr::add(sq(d(0)), sq(d(1))),
                        Expr::add(sq(d(2)), Expr::lit(NBODY_EPS)),
                    ),
                )
            }),
            &["g0", "l0", "jt"],
            &[1],
        )
        // 3: write the sum
        .insn(
            Access::new("out", vec![i]),
            Expr::load("acc", vec![c(0)]),
            &["g0", "l0"],
            &[2],
        )
        .build()
        .expect("nbody builds")
}

/// Reference implementation of [`nbody`].
pub fn nbody_reference(n: usize) -> Vec<f64> {
    use crate::gpusim::seed_value;
    let p = |cp: usize, j: usize| seed_value("pos", cp + 3 * j);
    let mut out = vec![0.0; n];
    for i in 0..n {
        let mut acc = 0.0;
        for j in 0..n {
            let dx = p(0, i) - p(0, j);
            let dy = p(1, i) - p(1, j);
            let dz = p(2, i) - p(2, j);
            acc += 1.0 / (dx * dx + dy * dy + dz * dz + NBODY_EPS).sqrt();
        }
        out[i] = acc;
    }
    out
}

// ---------------------------------------------------------------------------
// Zoo kernel 1: work-group tree reduction
// ---------------------------------------------------------------------------

/// Number of halving steps a work-group tree reduction or scan over
/// `lsize` lanes needs: the smallest `k` with `2^k >= lsize`.
pub fn reduce_steps(lsize: i64) -> i64 {
    let mut k = 0;
    while (1i64 << k) < lsize {
        k += 1;
    }
    k
}

/// Work-group tree reduction: each group stages `lsize` elements of `rin`
/// into local memory and halves pairwise (`dst[i] = src[2i] + src[2i+1]`)
/// for [`reduce_steps`] ping-pong steps, then writes the group sum to
/// `rout[g0]`.
///
/// Guard-free trick: both ping-pong buffers are `2·lsize` cells with a
/// zero upper half, so inactive lanes sum zeros into cells that stay
/// zero — no boundary control flow, and the polyhedral analyses remain
/// exact. Every step reads its source under a different lane mapping, so
/// the schedule places one barrier per step (plus one before the final
/// cross-lane read of cell 0).
pub fn reduce_tree(lsize: i64) -> Kernel {
    let steps = reduce_steps(lsize);
    let i = gid(0, lsize);
    let mut b = KernelBuilder::new("reduce_tree", &["n"])
        .group_dims_1d(v("n"), lsize)
        .global_array("rin", DType::F32, vec![v("n")], Layout::RowMajor, false)
        .global_array("rout", DType::F32, vec![v("n")], Layout::RowMajor, true)
        .local_array("ra", DType::F32, &[2 * lsize])
        .local_array("rb", DType::F32, &[2 * lsize])
        .insn(
            Access::new("ra", vec![v("l0")]),
            Expr::load("rin", vec![i]),
            &["g0", "l0"],
            &[],
        );
    let (mut src, mut dst) = ("ra", "rb");
    for _ in 0..steps {
        let prev = b_len(&b) - 1;
        b = b.insn(
            Access::new(dst, vec![v("l0")]),
            Expr::add(
                Expr::load(src, vec![v("l0").scale(2)]),
                Expr::load(src, vec![v("l0").scale(2).add(&c(1))]),
            ),
            &["g0", "l0"],
            &[prev],
        );
        std::mem::swap(&mut src, &mut dst);
    }
    let prev = b_len(&b) - 1;
    b.insn(
        Access::new("rout", vec![v("g0")]),
        Expr::load(src, vec![c(0)]),
        &["g0", "l0"],
        &[prev],
    )
    .build()
    .expect("reduce_tree builds")
}

/// Reference implementation of [`reduce_tree`]: one sum per work group
/// (`n` must be a multiple of `lsize`).
pub fn reduce_reference(n: usize, lsize: usize) -> Vec<f64> {
    use crate::gpusim::seed_value;
    (0..n / lsize)
        .map(|g| (0..lsize).map(|i| seed_value("rin", g * lsize + i)).sum())
        .collect()
}

// ---------------------------------------------------------------------------
// Zoo kernel 2: Hillis–Steele inclusive scan
// ---------------------------------------------------------------------------

/// Work-group inclusive prefix sum (Hillis–Steele): each group stages
/// `lsize` elements of `sin` into the upper window `[lsize, 2·lsize)` of
/// a local buffer and runs [`reduce_steps`] doubling-offset steps
/// (`dst[w+i] = src[w+i] + src[w+i−2^s]`), ping-ponging between two
/// buffers; lanes whose shifted read falls below the window read the
/// zeroed pad (the scan identity), so no guards are needed. The scanned
/// window is written to `sout`.
pub fn scan_hs(lsize: i64) -> Kernel {
    let steps = reduce_steps(lsize);
    let i = gid(0, lsize);
    let w = lsize;
    let mut b = KernelBuilder::new("scan_hs", &["n"])
        .group_dims_1d(v("n"), lsize)
        .global_array("sin", DType::F32, vec![v("n")], Layout::RowMajor, false)
        .global_array("sout", DType::F32, vec![v("n")], Layout::RowMajor, true)
        .local_array("sa", DType::F32, &[2 * lsize])
        .local_array("sb", DType::F32, &[2 * lsize])
        .insn(
            Access::new("sa", vec![v("l0").add(&c(w))]),
            Expr::load("sin", vec![i.clone()]),
            &["g0", "l0"],
            &[],
        );
    let (mut src, mut dst) = ("sa", "sb");
    for s in 0..steps {
        let prev = b_len(&b) - 1;
        let off = 1i64 << s;
        b = b.insn(
            Access::new(dst, vec![v("l0").add(&c(w))]),
            Expr::add(
                Expr::load(src, vec![v("l0").add(&c(w))]),
                Expr::load(src, vec![v("l0").add(&c(w - off))]),
            ),
            &["g0", "l0"],
            &[prev],
        );
        std::mem::swap(&mut src, &mut dst);
    }
    let prev = b_len(&b) - 1;
    b.insn(
        Access::new("sout", vec![i]),
        Expr::load(src, vec![v("l0").add(&c(w))]),
        &["g0", "l0"],
        &[prev],
    )
    .build()
    .expect("scan_hs builds")
}

/// Reference implementation of [`scan_hs`]: per-group inclusive prefix
/// sums (`n` must be a multiple of `lsize`).
pub fn scan_reference(n: usize, lsize: usize) -> Vec<f64> {
    use crate::gpusim::seed_value;
    let mut out = vec![0.0; n];
    for g in 0..n / lsize {
        let mut acc = 0.0;
        for i in 0..lsize {
            acc += seed_value("sin", g * lsize + i);
            out[g * lsize + i] = acc;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Zoo kernel 3: 7-point 3-D stencil
// ---------------------------------------------------------------------------

/// Neighbor weight of the 3-D stencil.
pub const ST3D_W: f64 = 0.125;

/// 7-point stencil on an `n×n×n` grid: the 2-D grid covers an x/y tile,
/// a sequential loop walks z. The input is halo-padded to `(n+2)³`, so
/// the kernel is guard-free; all seven loads (six neighbors + one
/// center) are lane-stride-1.
///
/// `o3[z,y,x] = (1 − 6w)·c + w·Σ_6 neighbors` with `c` the center value
/// (the usual `c + w·(Σ_6 − 6c)` form refactored to load `c` once).
pub fn stencil3d(gx: i64, gy: i64) -> Kernel {
    let np2 = v("n").add(&c(2));
    let u3 = |dz: i64, dy: i64, dx: i64| {
        Expr::load(
            "u3",
            vec![
                v("z").add(&c(1 + dz)),
                gid(1, gy).add(&c(1 + dy)),
                gid(0, gx).add(&c(1 + dx)),
            ],
        )
    };
    let sum6 = Expr::add(
        Expr::add(
            Expr::add(u3(0, 0, 1), u3(0, 0, -1)),
            Expr::add(u3(0, 1, 0), u3(0, -1, 0)),
        ),
        Expr::add(u3(1, 0, 0), u3(-1, 0, 0)),
    );
    let rhs = Expr::add(
        Expr::mul(Expr::lit(1.0 - 6.0 * ST3D_W), u3(0, 0, 0)),
        Expr::mul(Expr::lit(ST3D_W), sum6),
    );
    KernelBuilder::new("st3d7", &["n"])
        .group_dims_2d(v("n"), gx, v("n"), gy)
        .seq_dim("z", v("n"))
        .global_array(
            "u3",
            DType::F32,
            vec![np2.clone(), np2.clone(), np2],
            Layout::RowMajor,
            false,
        )
        .global_array("o3", DType::F32, vec![v("n"), v("n"), v("n")], Layout::RowMajor, true)
        .insn(
            Access::new("o3", vec![v("z"), gid(1, gy), gid(0, gx)]),
            rhs,
            &["g0", "g1", "l0", "l1", "z"],
            &[],
        )
        .build()
        .expect("st3d7 builds")
}

/// Reference implementation of [`stencil3d`].
pub fn stencil3d_reference(n: usize) -> Vec<f64> {
    use crate::gpusim::seed_value;
    let np2 = n + 2;
    let u = |z: usize, y: usize, x: usize| seed_value("u3", (z * np2 + y) * np2 + x);
    let mut out = vec![0.0; n * n * n];
    for z in 0..n {
        for y in 0..n {
            for x in 0..n {
                let ctr = u(z + 1, y + 1, x + 1);
                let sum6 = u(z + 1, y + 1, x + 2)
                    + u(z + 1, y + 1, x)
                    + u(z + 1, y + 2, x + 1)
                    + u(z + 1, y, x + 1)
                    + u(z + 2, y + 1, x + 1)
                    + u(z, y + 1, x + 1);
                out[(z * n + y) * n + x] = (1.0 - 6.0 * ST3D_W) * ctr + ST3D_W * sum6;
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Zoo kernel 4: batched small matrix multiplication
// ---------------------------------------------------------------------------

/// Matrix dimension of the batched small matmul.
pub const BMM_D: i64 = 8;

/// Batched small matmul: `nb` independent 8×8 products, one whole
/// product per thread. Arrays are batch-innermost (`[8, 8, nb]`
/// row-major), so every load and store is lane-stride-1 — the classic
/// "struct of arrays" batched-BLAS layout.
pub fn bmm(lsize: i64) -> Kernel {
    let bi = gid(0, lsize);
    KernelBuilder::new("bmm8", &["nb"])
        .group_dims_1d(v("nb"), lsize)
        .seq_dim("i", c(BMM_D))
        .seq_dim("j", c(BMM_D))
        .red_dim("kk", c(BMM_D))
        .global_array(
            "ba",
            DType::F32,
            vec![c(BMM_D), c(BMM_D), v("nb")],
            Layout::RowMajor,
            false,
        )
        .global_array(
            "bb",
            DType::F32,
            vec![c(BMM_D), c(BMM_D), v("nb")],
            Layout::RowMajor,
            false,
        )
        .global_array(
            "bc",
            DType::F32,
            vec![c(BMM_D), c(BMM_D), v("nb")],
            Layout::RowMajor,
            true,
        )
        .insn(
            Access::new("bc", vec![v("i"), v("j"), bi.clone()]),
            Expr::sum(
                "kk",
                Expr::mul(
                    Expr::load("ba", vec![v("i"), v("kk"), bi.clone()]),
                    Expr::load("bb", vec![v("kk"), v("j"), bi]),
                ),
            ),
            &["g0", "l0", "i", "j"],
            &[],
        )
        .build()
        .expect("bmm8 builds")
}

/// Reference implementation of [`bmm`].
pub fn bmm_reference(nb: usize) -> Vec<f64> {
    use crate::gpusim::seed_value;
    let d = BMM_D as usize;
    let a = |i: usize, kk: usize, b: usize| seed_value("ba", (i * d + kk) * nb + b);
    let bb = |kk: usize, j: usize, b: usize| seed_value("bb", (kk * d + j) * nb + b);
    let mut out = vec![0.0; d * d * nb];
    for i in 0..d {
        for j in 0..d {
            for b in 0..nb {
                let acc: f64 = (0..d).map(|kk| a(i, kk, b) * bb(kk, j, b)).sum();
                out[(i * d + j) * nb + b] = acc;
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Zoo kernel 5: strided gather ("spmv-like" ELL band)
// ---------------------------------------------------------------------------

/// Number of ELL diagonals of the strided gather.
pub const GATHER_DIAGS: i64 = 8;
/// Flat-index offset between consecutive diagonals.
pub const GATHER_OFF: i64 = 32;

/// ELL-style banded "spmv": `ey[i] = Σ_j ev[j, i] · ex[2i + j·32]`.
/// Coefficient loads are lane-stride-1; the gather reads `ex` at lane
/// stride 2 across eight shifted diagonals — since both the lane stride
/// and the diagonal offsets are even, only every other cell is ever
/// touched, exercising the model's half-utilization stride class.
pub fn gather_strided(lsize: i64) -> Kernel {
    let i = gid(0, lsize);
    KernelBuilder::new("gather_s2", &["n"])
        .group_dims_1d(v("n"), lsize)
        .red_dim("jd", c(GATHER_DIAGS))
        .global_array(
            "ev",
            DType::F32,
            vec![c(GATHER_DIAGS), v("n")],
            Layout::RowMajor,
            false,
        )
        .global_array(
            "ex",
            DType::F32,
            vec![v("n").scale(2).add(&c(GATHER_DIAGS * GATHER_OFF))],
            Layout::RowMajor,
            false,
        )
        .global_array("ey", DType::F32, vec![v("n")], Layout::RowMajor, true)
        .insn(
            Access::new("ey", vec![i.clone()]),
            Expr::sum(
                "jd",
                Expr::mul(
                    Expr::load("ev", vec![v("jd"), i.clone()]),
                    Expr::load(
                        "ex",
                        vec![i.scale(2).add(&LinExpr::scaled_var("jd", GATHER_OFF))],
                    ),
                ),
            ),
            &["g0", "l0"],
            &[],
        )
        .build()
        .expect("gather_s2 builds")
}

/// Reference implementation of [`gather_strided`].
pub fn gather_reference(n: usize) -> Vec<f64> {
    use crate::gpusim::seed_value;
    let kd = GATHER_DIAGS as usize;
    let off = GATHER_OFF as usize;
    (0..n)
        .map(|i| {
            (0..kd)
                .map(|j| seed_value("ev", j * n + i) * seed_value("ex", 2 * i + j * off))
                .sum()
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Per-device test suite (§5)
// ---------------------------------------------------------------------------

/// §5 per-device configuration: (group set, base size exponent) for
/// each test kernel, derived from the profile's capabilities. Cost
/// sketches per class: fd5 streams ~8 bytes per grid cell (n²);
/// mm_skinny executes 16·n³ flops; conv7 executes ~2646 flops per n²
/// grid point; n-body ~10 flops per n² pair. Exponents are solved
/// against the launch-overhead floor so the smallest (`a.`) case is
/// still comfortably measurable.
fn cfg(d: &DeviceProfile) -> [(GroupSet, i64); 4] {
    let t = t_case(d);
    // order: fd, skinny_mm, conv, nbody
    [
        (two_d_groups(d), d.class_size_exp("fd5", size_exp(d.dram_bw, 8.0, 2, t, 8, 12))),
        (two_d_groups(d), d.class_size_exp("mm_skinny", size_exp(d.peak_f32(), 16.0, 3, t, 8, 11))),
        (two_d_groups(d), d.class_size_exp("conv7", size_exp(d.peak_f32(), 2646.0, 2, t, 5, 9))),
        (one_d_groups(d), d.class_size_exp("nbody", size_exp(d.peak_f32(), 10.0, 2, t, 9, 12))),
    ]
}

/// The four §5 test kernels with their standard-size group configuration
/// and four size cases (`a.`–`d.`, i.e. t = 0..4) each.
pub fn suite(device: &DeviceProfile) -> Vec<KernelCase> {
    let [fd_c, mm_c, cv_c, nb_c] = cfg(device);
    let mut out = Vec::new();

    let (gx, gy) = fd_c.0.standard();
    let k = fd_stencil(gx, gy);
    for t in 0..4 {
        let n = snap(1i64 << (fd_c.1 + t), lcm(gx, gy));
        out.push(KernelCase {
            kernel: k.clone(),
            env: env(&[("n", n)]),
            label: format!("fd5/{}/n={n}", case_letter(t)),
            group: (gx, gy),
        });
    }

    let (gx, gy) = mm_c.0.standard();
    let k = skinny_mm(gx, gy);
    for t in 0..4 {
        let n = 1i64 << (mm_c.1 + t);
        out.push(KernelCase {
            kernel: k.clone(),
            env: skinny_env(n, gx, gy),
            label: format!("mm_skinny/{}/n={n}", case_letter(t)),
            group: (gx, gy),
        });
    }

    let (gx, gy) = cv_c.0.standard();
    let k = convolution(gx, gy);
    for t in 0..4 {
        let n = snap(1i64 << (cv_c.1 + t), lcm(gx, gy));
        out.push(KernelCase {
            kernel: k.clone(),
            env: env(&[("n", n)]),
            label: format!("conv7/{}/n={n}", case_letter(t)),
            group: (gx, gy),
        });
    }

    let (lsize, _) = nb_c.0.standard();
    let k = nbody(lsize);
    for t in 0..4 {
        let n = snap(1i64 << (nb_c.1 + t), lsize);
        out.push(KernelCase {
            kernel: k.clone(),
            env: env(&[("n", n)]),
            label: format!("nbody/{}/n={n}", case_letter(t)),
            group: (lsize, 1),
        });
    }
    out
}

/// Per-device configuration of the five zoo kernels, in order:
/// reduce_tree, scan_hs, st3d7, bmm8, gather_s2 — derived from the
/// profile like [`cfg`]. Cost sketches: the reduction and scan stream
/// ~4 bytes per element; the 3-D stencil ~8 bytes per n³ cell; bmm8
/// touches ~3 KB per batch (the 8×8×8 reduction re-reads its operands
/// lane-coalesced, well beyond the 768-byte footprint); the gather
/// touches ~100 bytes per row across its half-utilized diagonals.
/// Exponents are solved against the launch floor so even the smallest
/// case is well above it.
fn zoo_cfg(d: &DeviceProfile) -> [(GroupSet, i64); 5] {
    let t = t_case(d);
    [
        (one_d_groups(d), d.class_size_exp("reduce_tree", size_exp(d.dram_bw, 4.0, 1, t, 18, 23))),
        (one_d_groups(d), d.class_size_exp("scan_hs", size_exp(d.dram_bw, 4.0, 1, t, 18, 23))),
        (two_d_groups(d), d.class_size_exp("st3d7", size_exp(d.dram_bw, 8.0, 3, t, 4, 8))),
        (one_d_groups(d), d.class_size_exp("bmm8", size_exp(d.dram_bw, 3072.0, 1, t, 12, 16))),
        (one_d_groups(d), d.class_size_exp("gather_s2", size_exp(d.dram_bw, 100.0, 1, t, 16, 21))),
    ]
}

/// The five zoo kernels with their standard-size group configuration and
/// four size cases (`a.`–`d.`) each — the expansion half of the
/// evaluation-kernel zoo.
pub fn zoo_suite(device: &DeviceProfile) -> Vec<KernelCase> {
    let [rd_c, sc_c, st_c, bm_c, ga_c] = zoo_cfg(device);
    let mut out = Vec::new();

    let (lsize, _) = rd_c.0.standard();
    let k = reduce_tree(lsize);
    for t in 0..4 {
        let n = snap(1i64 << (rd_c.1 + t), lsize);
        out.push(KernelCase {
            kernel: k.clone(),
            env: env(&[("n", n)]),
            label: format!("reduce_tree/{}/n={n}", case_letter(t)),
            group: (lsize, 1),
        });
    }

    let (lsize, _) = sc_c.0.standard();
    let k = scan_hs(lsize);
    for t in 0..4 {
        let n = snap(1i64 << (sc_c.1 + t), lsize);
        out.push(KernelCase {
            kernel: k.clone(),
            env: env(&[("n", n)]),
            label: format!("scan_hs/{}/n={n}", case_letter(t)),
            group: (lsize, 1),
        });
    }

    let (gx, gy) = st_c.0.standard();
    let k = stencil3d(gx, gy);
    for t in 0..4 {
        let n = snap(1i64 << (st_c.1 + t), lcm(gx, gy));
        out.push(KernelCase {
            kernel: k.clone(),
            env: env(&[("n", n)]),
            label: format!("st3d7/{}/n={n}", case_letter(t)),
            group: (gx, gy),
        });
    }

    let (lsize, _) = bm_c.0.standard();
    let k = bmm(lsize);
    for t in 0..4 {
        let nb = snap(1i64 << (bm_c.1 + t), lsize);
        out.push(KernelCase {
            kernel: k.clone(),
            env: env(&[("nb", nb)]),
            label: format!("bmm8/{}/nb={nb}", case_letter(t)),
            group: (lsize, 1),
        });
    }

    let (lsize, _) = ga_c.0.standard();
    let k = gather_strided(lsize);
    for t in 0..4 {
        let n = snap(1i64 << (ga_c.1 + t), lsize);
        out.push(KernelCase {
            kernel: k.clone(),
            env: env(&[("n", n)]),
            label: format!("gather_s2/{}/n={n}", case_letter(t)),
            group: (lsize, 1),
        });
    }
    out
}

/// The full evaluation-kernel zoo for a device: the four §5 test kernels
/// plus the five zoo kernels — 9 classes × 4 size cases.
pub fn eval_suite(device: &DeviceProfile) -> Vec<KernelCase> {
    let mut out = suite(device);
    out.extend(zoo_suite(device));
    out
}

/// Table-1 row letters for the four size cases.
pub fn case_letter(t: i64) -> &'static str {
    ["a", "b", "c", "d"][t as usize]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{execute, seed_value};

    #[test]
    fn fd_stencil_matches_reference() {
        let k = fd_stencil(8, 8);
        let n = 16usize;
        let st = execute(&k, &env(&[("n", n as i64)])).unwrap();
        let out = st.get("out").unwrap();
        let want = fd_reference(n);
        for i in 0..n * n {
            assert!((out[i] - want[i]).abs() < 1e-10, "i={i}");
        }
    }

    #[test]
    fn skinny_mm_matches_reference() {
        let k = skinny_mm(8, 8);
        let e = skinny_env(8, 8, 8);
        let (n, m, l) = (e["n"] as usize, e["m"] as usize, e["l"] as usize);
        let st = execute(&k, &e).unwrap();
        let cc = st.get("cc").unwrap();
        for i in 0..n {
            for j in 0..l {
                let want: f64 = (0..m)
                    .map(|kk| seed_value("a", i * m + kk) * seed_value("b", kk * l + j))
                    .sum();
                assert!((cc[i * l + j] - want).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn convolution_matches_reference() {
        let k = convolution(8, 4);
        let n = 8usize;
        let st = execute(&k, &env(&[("n", n as i64)])).unwrap();
        let out = st.get("out").unwrap();
        let want = conv_reference(n);
        for i in 0..want.len() {
            assert!((out[i] - want[i]).abs() < 1e-9, "i={i}");
        }
    }

    #[test]
    fn nbody_matches_reference() {
        let k = nbody(16);
        let n = 32usize;
        let st = execute(&k, &env(&[("n", n as i64)])).unwrap();
        let out = st.get("out").unwrap();
        let want = nbody_reference(n);
        for i in 0..n {
            assert!(
                (out[i] - want[i]).abs() / want[i].abs() < 1e-10,
                "i={i}: {} vs {}",
                out[i],
                want[i]
            );
        }
    }

    #[test]
    fn test_suite_has_16_cases_per_device() {
        for dev in crate::gpusim::registry::builtins().iter() {
            let s = suite(dev);
            assert_eq!(s.len(), 16, "{}", dev.name);
            // 4 kernels x 4 size cases with 256-thread (standard) groups
            for case in &s {
                assert_eq!(case.group.0 * case.group.1, 256, "{}: {}", dev.name, case.label);
            }
        }
    }

    #[test]
    fn eval_suite_has_36_cases_over_9_classes() {
        for dev in crate::gpusim::registry::builtins().iter() {
            let s = eval_suite(dev);
            assert_eq!(s.len(), 36, "{}", dev.name);
            let mut classes: Vec<&str> =
                s.iter().map(|c| c.label.split('/').next().unwrap()).collect();
            classes.sort();
            classes.dedup();
            assert_eq!(classes.len(), 9, "{}: {classes:?}", dev.name);
            for case in &s {
                assert_eq!(case.group.0 * case.group.1, 256, "{}: {}", dev.name, case.label);
            }
        }
    }

    #[test]
    fn reduce_steps_covers_all_group_sizes() {
        for (lsize, k) in [(128i64, 7i64), (192, 8), (224, 8), (256, 8), (384, 9), (512, 9)] {
            assert_eq!(reduce_steps(lsize), k, "lsize={lsize}");
        }
    }

    #[test]
    fn reduce_tree_matches_reference() {
        let lsize = 16i64;
        let k = reduce_tree(lsize);
        let n = 4 * lsize;
        let st = execute(&k, &env(&[("n", n)])).unwrap();
        let out = st.get("rout").unwrap();
        let want = reduce_reference(n as usize, lsize as usize);
        for (g, w) in want.iter().enumerate() {
            assert!((out[g] - w).abs() < 1e-9, "group {g}: {} vs {w}", out[g]);
        }
    }

    #[test]
    fn scan_matches_reference() {
        let lsize = 16i64;
        let k = scan_hs(lsize);
        let n = 3 * lsize;
        let st = execute(&k, &env(&[("n", n)])).unwrap();
        let out = st.get("sout").unwrap();
        let want = scan_reference(n as usize, lsize as usize);
        for i in 0..n as usize {
            assert!((out[i] - want[i]).abs() < 1e-9, "i={i}: {} vs {}", out[i], want[i]);
        }
    }

    #[test]
    fn stencil3d_matches_reference() {
        let k = stencil3d(8, 4);
        let n = 8usize;
        let st = execute(&k, &env(&[("n", n as i64)])).unwrap();
        let out = st.get("o3").unwrap();
        let want = stencil3d_reference(n);
        for i in 0..want.len() {
            assert!((out[i] - want[i]).abs() < 1e-9, "i={i}");
        }
    }

    #[test]
    fn bmm_matches_reference() {
        let k = bmm(16);
        let nb = 32usize;
        let st = execute(&k, &env(&[("nb", nb as i64)])).unwrap();
        let out = st.get("bc").unwrap();
        let want = bmm_reference(nb);
        for i in 0..want.len() {
            assert!((out[i] - want[i]).abs() < 1e-9, "i={i}");
        }
    }

    #[test]
    fn gather_matches_reference() {
        let k = gather_strided(16);
        let n = 48usize;
        let st = execute(&k, &env(&[("n", n as i64)])).unwrap();
        let out = st.get("ey").unwrap();
        let want = gather_reference(n);
        for i in 0..n {
            assert!((out[i] - want[i]).abs() < 1e-9, "i={i}");
        }
    }

    #[test]
    fn reduce_and_scan_insert_per_step_barriers() {
        use crate::schedule::schedule;
        let lsize = 256i64;
        let k = reduce_steps(lsize) as usize;
        // reduce: one barrier per halving step + one before the final
        // cross-lane read of cell 0
        let s = schedule(&reduce_tree(lsize)).unwrap();
        assert_eq!(s.barrier_sites(), k + 1);
        // scan: one barrier per doubling step; the final read is under
        // the same lane mapping as the last write
        let s = schedule(&scan_hs(lsize)).unwrap();
        assert_eq!(s.barrier_sites(), k);
    }

    #[test]
    fn nbody_exercises_rsqrt_and_local_loads() {
        use crate::lpir::OpKind;
        use crate::stats::{extract, ExtractOpts, Prop, Schema};
        let k = nbody(16);
        let e = env(&[("n", 64)]);
        let props = extract(&k, &e, ExtractOpts::default()).unwrap();
        let schema = Schema::full();
        let v = props.eval(&schema, &e).unwrap();
        let rsqrt_like =
            v[schema.index_of(&Prop::Op { kind: OpKind::Special, bits: 32 }).unwrap()];
        assert_eq!(rsqrt_like, 64.0 * 64.0); // one rsqrt per pair
        assert!(v[schema.index_of(&Prop::LocalLoad { bits: 32 }).unwrap()] > 0.0);
        assert!(v[schema.index_of(&Prop::Barriers).unwrap()] > 0.0);
    }

    #[test]
    fn conv_filter_reads_are_uniform() {
        use crate::stats::{extract, ExtractOpts, Prop, Schema, Dir};
        use crate::isl::progression::StrideClass;
        let k = convolution(16, 16);
        let e = env(&[("n", 32)]);
        let props = extract(&k, &e, ExtractOpts::default()).unwrap();
        let schema = Schema::full();
        let v = props.eval(&schema, &e).unwrap();
        let uni = v[schema
            .index_of(&Prop::MemGlobal {
                bits: 32,
                dir: Dir::Load,
                class: StrideClass::Uniform,
            })
            .unwrap()];
        assert!(uni > 0.0, "filter loads should be uniform");
        // image loads have lane stride 3, full utilization -> 3/3
        let s3 = v[schema
            .index_of(&Prop::MemGlobal {
                bits: 32,
                dir: Dir::Load,
                class: StrideClass::Frac { numer: 3, denom: 3 },
            })
            .unwrap()];
        assert!(s3 > 0.0, "image loads should be stride-3 full-utilization");
    }
}
