//! The measurement-kernel classes of §4.1 (plus the uniform-class
//! global-store kernel that closes the suite's coverage gap).
//!
//! Every class is a parameterized [`Kernel`] builder plus a per-device
//! sweep (size exponents, shape cases, work-group sets) **derived from
//! the device profile's capabilities** — group sets from the group-size
//! cap and occupancy headroom, size exponents from a per-class cost
//! sketch against the launch-overhead floor (see [`crate::kernels`]).
//! The builders avoid data-dependent control flow — boundary coverage
//! uses unrolled cooperative loads into padded arrays instead of guards,
//! which keeps the polyhedral analyses exact.

use super::{lcm, one_d_groups, size_exp, snap, t_case, t_sweep, two_d_groups, GroupSet,
    KernelCase};
use crate::gpusim::DeviceProfile;
use crate::lpir::builder::{gid, KernelBuilder};
use crate::lpir::{Access, DType, Expr, Kernel, Layout, UnOp};
use crate::qpoly::{env, LinExpr};

fn v(name: &str) -> LinExpr {
    LinExpr::var(name)
}

fn c(x: i64) -> LinExpr {
    LinExpr::constant(x)
}

/// ceil(a/b) for small constants.
fn ceil_div(a: i64, b: i64) -> i64 {
    (a + b - 1) / b
}

// ---------------------------------------------------------------------------
// 1. Tiled matrix multiplication
// ---------------------------------------------------------------------------

/// Tiled MM: `C (n×l) = A (n×m) · B (m×l)`, row-major, prefetching tiles
/// into local memory. The reduction is tiled by the lane extent `gx`;
/// when the group is non-square (`gy < gx`) the B tile is staged with an
/// unrolled cooperative load into a row-padded B (`bpad` extra rows), so
/// no control flow is needed.
pub fn mm_tiled(gx: i64, gy: i64) -> Kernel {
    let ru = ceil_div(gx, gy);
    let bpad = ru * gy - gx;
    let mut b = KernelBuilder::new("mm_tiled", &["n", "m", "l"])
        .group_dims_2d(v("l"), gx, v("n"), gy)
        .seq_tiles("kt", v("m"), gx)
        .red_dim("ki", c(gx))
        .global_array("a", DType::F32, vec![v("n"), v("m")], Layout::RowMajor, false)
        .global_array(
            "b",
            DType::F32,
            vec![v("m").add(&c(bpad)), v("l")],
            Layout::RowMajor,
            false,
        )
        .global_array("cc", DType::F32, vec![v("n"), v("l")], Layout::RowMajor, true)
        .local_array("at", DType::F32, &[gy, gx])
        .local_array("bt", DType::F32, &[ru * gy, gx])
        .private_array("acc", DType::F32, &[1]);
    if ru > 1 {
        b = b.unroll_dim("u", ru);
    }
    // at[l1, l0] = a[g1*gy + l1, kt*gx + l0]
    b = b.insn(
        Access::new("at", vec![v("l1"), v("l0")]),
        Expr::load("a", vec![gid(1, gy), LinExpr::scaled_var("kt", gx).add(&v("l0"))]),
        &["g0", "g1", "l0", "l1", "kt"],
        &[],
    );
    // bt[l1 + gy*u, l0] = b[kt*gx + l1 + gy*u, g0*gx + l0]
    if ru > 1 {
        b = b.insn(
            Access::new("bt", vec![v("l1").add(&LinExpr::scaled_var("u", gy)), v("l0")]),
            Expr::load(
                "b",
                vec![
                    LinExpr::scaled_var("kt", gx)
                        .add(&v("l1"))
                        .add(&LinExpr::scaled_var("u", gy)),
                    gid(0, gx),
                ],
            ),
            &["g0", "g1", "l0", "l1", "kt", "u"],
            &[],
        );
    } else {
        b = b.insn(
            Access::new("bt", vec![v("l1"), v("l0")]),
            Expr::load("b", vec![LinExpr::scaled_var("kt", gx).add(&v("l1")), gid(0, gx)]),
            &["g0", "g1", "l0", "l1", "kt"],
            &[],
        );
    }
    b.update_insn(
        Access::new("acc", vec![c(0)]),
        Expr::sum(
            "ki",
            Expr::mul(
                Expr::load("at", vec![v("l1"), v("ki")]),
                Expr::load("bt", vec![v("ki"), v("l0")]),
            ),
        ),
        &["g0", "g1", "l0", "l1", "kt"],
        &[0, 1],
    )
    .insn(
        Access::new("cc", vec![gid(1, gy), gid(0, gx)]),
        Expr::load("acc", vec![c(0)]),
        &["g0", "g1", "l0", "l1"],
        &[2],
    )
    .build()
    .expect("mm_tiled builds")
}

/// The four MM shape cases of §4.1: (n, m, l) from a base size.
pub fn mm_shapes(base: i64) -> Vec<(&'static str, i64, i64, i64)> {
    vec![
        ("square", base, base, base),
        ("l_half", base, base, base / 2),
        ("m_half", base, base / 2, base),
        ("n_half", base / 2, base, base),
    ]
}

// ---------------------------------------------------------------------------
// 2. Naive matrix multiplication
// ---------------------------------------------------------------------------

/// Naive MM on square `n×n` matrices: each thread computes one output
/// element as a full inner product (uniform A reads, stride-1 B reads).
pub fn mm_naive(gx: i64, gy: i64) -> Kernel {
    KernelBuilder::new("mm_naive", &["n"])
        .group_dims_2d(v("n"), gx, v("n"), gy)
        .red_dim("k", v("n"))
        .global_array("a", DType::F32, vec![v("n"), v("n")], Layout::RowMajor, false)
        .global_array("b", DType::F32, vec![v("n"), v("n")], Layout::RowMajor, false)
        .global_array("cc", DType::F32, vec![v("n"), v("n")], Layout::RowMajor, true)
        .insn(
            Access::new("cc", vec![gid(1, gy), gid(0, gx)]),
            Expr::sum(
                "k",
                Expr::mul(
                    Expr::load("a", vec![gid(1, gy), v("k")]),
                    Expr::load("b", vec![v("k"), gid(0, gx)]),
                ),
            ),
            &["g0", "g1", "l0", "l1"],
            &[],
        )
        .build()
        .expect("mm_naive builds")
}

// ---------------------------------------------------------------------------
// 3. Vector scale-and-add (strides 1, 2, 3)
// ---------------------------------------------------------------------------

/// `out[s·i] = s1·x[s·i] + s2·y[s·i]` over `nt` threads; arrays have
/// `s·nt` elements. The scalars live in 1-element arrays, producing the
/// model's uniform (stride-0) load class.
pub fn vsadd(stride: i64, lsize: i64) -> Kernel {
    let idx = gid(0, lsize).scale(stride);
    KernelBuilder::new(&format!("vsadd_s{stride}"), &["nt"])
        .group_dims_1d(v("nt"), lsize)
        .global_array("x", DType::F32, vec![v("nt").scale(stride)], Layout::RowMajor, false)
        .global_array("y", DType::F32, vec![v("nt").scale(stride)], Layout::RowMajor, false)
        .global_array("s1", DType::F32, vec![c(1)], Layout::RowMajor, false)
        .global_array("s2", DType::F32, vec![c(1)], Layout::RowMajor, false)
        .global_array("out", DType::F32, vec![v("nt").scale(stride)], Layout::RowMajor, true)
        .insn(
            Access::new("out", vec![idx.clone()]),
            Expr::add(
                Expr::mul(Expr::load("s1", vec![c(0)]), Expr::load("x", vec![idx.clone()])),
                Expr::mul(Expr::load("s2", vec![c(0)]), Expr::load("y", vec![idx])),
            ),
            &["g0", "l0"],
            &[],
        )
        .build()
        .expect("vsadd builds")
}

// ---------------------------------------------------------------------------
// 4. Transpose (three prefetch/stride configurations)
// ---------------------------------------------------------------------------

/// Which transpose variant to build.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransposeVariant {
    /// prefetch tiles into local memory: stride-1 reads *and* writes
    Tiled,
    /// no prefetch, stride-1 writes, uncoalesced reads
    CoalescedWrite,
    /// no prefetch, stride-1 reads, uncoalesced writes
    CoalescedRead,
}

/// Square-matrix transpose `out = aᵀ`, three variants. The tiled variant
/// uses square `gx×gx` tiles staged with an unrolled cooperative load
/// (row-padded global arrays when `gy < gx`).
pub fn transpose(variant: TransposeVariant, gx: i64, gy: i64) -> Kernel {
    match variant {
        TransposeVariant::Tiled => {
            let ru = ceil_div(gx, gy);
            assert!(2 * gy >= gx, "tiled transpose needs 2*gy >= gx (got {gx}x{gy})");
            // overlapping cooperative loads: iteration u covers tile rows
            // [u*(gx-gy), u*(gx-gy)+gy); for ru = 2 that is [0,gy) and
            // [gx-gy, gx) which exactly cover [0, gx) with a benign
            // same-value overlap — no guards, no padding
            let off = gx - gy;
            let mut b = KernelBuilder::new("transpose_tiled", &["n"])
                // both grid axes tile n by gx (square tiles); lanes (gx, gy)
                .custom_grid_2d(v("n"), gx, gx, v("n"), gx, gy)
                .global_array("a", DType::F32, vec![v("n"), v("n")], Layout::RowMajor, false)
                .global_array("out", DType::F32, vec![v("n"), v("n")], Layout::RowMajor, true)
                .local_array("t", DType::F32, &[gx, gx]);
            if ru > 1 {
                // separate unroll inames: all load iterations must finish
                // before any (transposed) read of the tile
                b = b.unroll_dim("u", ru).unroll_dim("w", ru);
                b = b
                    .insn(
                        Access::new(
                            "t",
                            vec![v("l1").add(&LinExpr::scaled_var("u", off)), v("l0")],
                        ),
                        Expr::load(
                            "a",
                            vec![
                                LinExpr::scaled_var("g1", gx)
                                    .add(&v("l1"))
                                    .add(&LinExpr::scaled_var("u", off)),
                                gid(0, gx),
                            ],
                        ),
                        &["g0", "g1", "l0", "l1", "u"],
                        &[],
                    )
                    .insn(
                        Access::new(
                            "out",
                            vec![
                                LinExpr::scaled_var("g0", gx)
                                    .add(&v("l1"))
                                    .add(&LinExpr::scaled_var("w", off)),
                                LinExpr::scaled_var("g1", gx).add(&v("l0")),
                            ],
                        ),
                        Expr::load(
                            "t",
                            vec![v("l0"), v("l1").add(&LinExpr::scaled_var("w", off))],
                        ),
                        &["g0", "g1", "l0", "l1", "w"],
                        &[0],
                    );
            } else {
                b = b
                    .insn(
                        Access::new("t", vec![v("l1"), v("l0")]),
                        Expr::load("a", vec![gid(1, gx), gid(0, gx)]),
                        &["g0", "g1", "l0", "l1"],
                        &[],
                    )
                    .insn(
                        Access::new(
                            "out",
                            vec![
                                LinExpr::scaled_var("g0", gx).add(&v("l1")),
                                LinExpr::scaled_var("g1", gx).add(&v("l0")),
                            ],
                        ),
                        Expr::load("t", vec![v("l0"), v("l1")]),
                        &["g0", "g1", "l0", "l1"],
                        &[0],
                    );
            }
            b.build().expect("transpose_tiled builds")
        }
        TransposeVariant::CoalescedWrite => KernelBuilder::new("transpose_cw", &["n"])
            .group_dims_2d(v("n"), gx, v("n"), gy)
            .global_array("a", DType::F32, vec![v("n"), v("n")], Layout::RowMajor, false)
            .global_array("out", DType::F32, vec![v("n"), v("n")], Layout::RowMajor, true)
            .insn(
                // out[y, x] = a[x, y]: write stride-1 (x = lane), read stride-n
                Access::new("out", vec![gid(1, gy), gid(0, gx)]),
                Expr::load("a", vec![gid(0, gx), gid(1, gy)]),
                &["g0", "g1", "l0", "l1"],
                &[],
            )
            .build()
            .expect("transpose_cw builds"),
        TransposeVariant::CoalescedRead => KernelBuilder::new("transpose_cr", &["n"])
            .group_dims_2d(v("n"), gx, v("n"), gy)
            .global_array("a", DType::F32, vec![v("n"), v("n")], Layout::RowMajor, false)
            .global_array("out", DType::F32, vec![v("n"), v("n")], Layout::RowMajor, true)
            .insn(
                // out[x, y] = a[y, x]: read stride-1, write stride-n
                Access::new("out", vec![gid(0, gx), gid(1, gy)]),
                Expr::load("a", vec![gid(1, gy), gid(0, gx)]),
                &["g0", "g1", "l0", "l1"],
                &[],
            )
            .build()
            .expect("transpose_cr builds"),
    }
}

// ---------------------------------------------------------------------------
// 5. Stride-1 global access (copy / add-4 / index-store)
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GlobalAccessConfig {
    /// 1 load, 1 store
    Copy,
    /// 4 loads, 1 store
    Add4,
    /// 0 loads, 1 store
    StoreIndex,
    /// 0 loads, 1 *uniform-class* store: every lane of a group writes
    /// the group's cell `out[g0]`. This is the §4.1 coverage gap the
    /// ROADMAP names — without it no measurement kernel emits
    /// uniform-class global stores, so the per-group result store of
    /// `reduce_tree` fits to weight zero in its own hold-out fold.
    StoreUniform,
}

/// Stride-1 global-access kernels over `n`-element arrays.
pub fn global_access(cfg: GlobalAccessConfig, lsize: i64) -> Kernel {
    let idx = gid(0, lsize);
    let b = KernelBuilder::new(
        match cfg {
            GlobalAccessConfig::Copy => "sg_copy",
            GlobalAccessConfig::Add4 => "sg_add4",
            GlobalAccessConfig::StoreIndex => "sg_storeidx",
            GlobalAccessConfig::StoreUniform => "sg_storeuni",
        },
        &["n"],
    )
    .group_dims_1d(v("n"), lsize);
    match cfg {
        GlobalAccessConfig::Copy => b
            .global_array("a", DType::F32, vec![v("n")], Layout::RowMajor, false)
            .global_array("out", DType::F32, vec![v("n")], Layout::RowMajor, true)
            .insn(
                Access::new("out", vec![idx.clone()]),
                Expr::load("a", vec![idx]),
                &["g0", "l0"],
                &[],
            ),
        GlobalAccessConfig::Add4 => {
            let mut b = b;
            for name in ["a1", "a2", "a3", "a4"] {
                b = b.global_array(name, DType::F32, vec![v("n")], Layout::RowMajor, false);
            }
            b.global_array("out", DType::F32, vec![v("n")], Layout::RowMajor, true).insn(
                Access::new("out", vec![idx.clone()]),
                Expr::add(
                    Expr::add(
                        Expr::load("a1", vec![idx.clone()]),
                        Expr::load("a2", vec![idx.clone()]),
                    ),
                    Expr::add(
                        Expr::load("a3", vec![idx.clone()]),
                        Expr::load("a4", vec![idx]),
                    ),
                ),
                &["g0", "l0"],
                &[],
            )
        }
        GlobalAccessConfig::StoreIndex => b
            .global_array("out", DType::F32, vec![v("n")], Layout::RowMajor, true)
            .insn(Access::new("out", vec![idx.clone()]), Expr::Idx(idx), &["g0", "l0"], &[]),
        GlobalAccessConfig::StoreUniform => b
            // the array is over-allocated to n cells; only the n/lsize
            // per-group cells are written (all lanes store one value)
            .global_array("out", DType::F32, vec![v("n")], Layout::RowMajor, true)
            .insn(
                Access::new("out", vec![v("g0")]),
                Expr::Idx(v("g0")),
                &["g0", "l0"],
                &[],
            ),
    }
    .build()
    .expect("global_access builds")
}

// ---------------------------------------------------------------------------
// 6/7. Stride-2 / stride-3 filled access
// ---------------------------------------------------------------------------

/// Filled strided access: a `s×nt` column-major array is read in a
/// stride-`s` pattern covering all residues; each of `nt` threads sums
/// its `s`-tuple 256 times (paper: "a summation over 256 of these
/// pairwise sums") into a `1×nt` output.
pub fn filled(s: i64, lsize: i64) -> Kernel {
    let mut b = KernelBuilder::new(&format!("filled_s{s}"), &["nt"])
        .group_dims_1d(v("nt"), lsize)
        .red_dim("q", c(256))
        // column-major [s, nt]: element (c, col) at flat c + s*col
        .global_array("x", DType::F32, vec![c(s), v("nt")], Layout::ColMajor, false)
        .global_array("out", DType::F32, vec![v("nt")], Layout::RowMajor, true);
    // sum over q of (x[0, i] + x[1, i] (+ x[2, i]))
    let col = gid(0, lsize);
    let mut body = Expr::load("x", vec![c(0), col.clone()]);
    for ci in 1..s {
        body = Expr::add(body, Expr::load("x", vec![c(ci), col.clone()]));
    }
    b = b.insn(
        Access::new("out", vec![col]),
        Expr::sum("q", body),
        &["g0", "l0"],
        &[],
    );
    b.build().expect("filled builds")
}

// ---------------------------------------------------------------------------
// 8. Arithmetic-operation kernels
// ---------------------------------------------------------------------------

/// Which arithmetic type a kernel exercises (§4.1: separate kernel each).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArithType {
    AddSub,
    Mul,
    Div,
    Exp,
    Rsqrt,
}

impl ArithType {
    pub fn all() -> [ArithType; 5] {
        [ArithType::AddSub, ArithType::Mul, ArithType::Div, ArithType::Exp, ArithType::Rsqrt]
    }

    pub fn label(&self) -> &'static str {
        match self {
            ArithType::AddSub => "addsub",
            ArithType::Mul => "mul",
            ArithType::Div => "div",
            ArithType::Exp => "exp",
            ArithType::Rsqrt => "rsqrt",
        }
    }
}

/// `out[y, x] = Σ_{q<k} chain(q)` where the chain applies 6–10 operations
/// of one type to the (float-converted) reduction index. No global reads.
pub fn arith(ty: ArithType, gx: i64, gy: i64) -> Kernel {
    let iv = Expr::Idx(v("q"));
    let chain = match ty {
        ArithType::AddSub => {
            // 8 add/sub ops
            let mut e = iv.clone();
            for (i, lit) in [1.1, 2.2, 3.3, 4.4].iter().enumerate() {
                e = Expr::add(e, Expr::lit(*lit));
                if i % 2 == 0 {
                    e = Expr::sub(e, iv.clone());
                } else {
                    e = Expr::add(e, iv.clone());
                }
            }
            e
        }
        ArithType::Mul => {
            // 8 multiplications
            let mut e = iv.clone();
            for lit in [1.0001, 0.9999, 1.0002, 0.9998, 1.0001, 0.9999, 1.0002, 0.9998] {
                e = Expr::mul(e, Expr::lit(lit));
            }
            e
        }
        ArithType::Div => {
            // 7 divisions
            let mut e = Expr::add(iv.clone(), Expr::lit(1.5));
            for lit in [1.1, 0.9, 1.2, 0.8, 1.3, 0.7, 1.05] {
                e = Expr::div(e, Expr::lit(lit));
            }
            e
        }
        ArithType::Exp => {
            // 6 exponentiations
            let mut e = Expr::add(iv.clone(), Expr::lit(1.5));
            for _ in 0..6 {
                e = Expr::bin(crate::lpir::BinOp::Pow, e, Expr::lit(1.01));
            }
            e
        }
        ArithType::Rsqrt => {
            // 6 rsqrt applications
            let mut e = Expr::add(iv.clone(), Expr::lit(1.5));
            for _ in 0..6 {
                e = Expr::un(UnOp::Rsqrt, e);
            }
            e
        }
    };
    KernelBuilder::new(&format!("arith_{}", ty.label()), &["n", "k"])
        .group_dims_2d(v("n"), gx, v("n"), gy)
        .red_dim("q", v("k"))
        .global_array("out", DType::F32, vec![v("n"), v("n")], Layout::RowMajor, true)
        .insn(
            Access::new("out", vec![gid(1, gy), gid(0, gx)]),
            Expr::sum("q", chain),
            &["g0", "g1", "l0", "l1"],
            &[],
        )
        .build()
        .expect("arith builds")
}

// ---------------------------------------------------------------------------
// 9. Empty kernel
// ---------------------------------------------------------------------------

/// Launches the grid of an `n×n` element-per-thread kernel but performs
/// no operations or memory accesses (launch-overhead calibration, §2.4).
pub fn empty(gx: i64, gy: i64) -> Kernel {
    KernelBuilder::new("empty", &["n"])
        .group_dims_2d(v("n"), gx, v("n"), gy)
        .build()
        .expect("empty builds")
}

// ---------------------------------------------------------------------------
// Per-device sweeps (§4.1)
// ---------------------------------------------------------------------------

/// Configuration of one measurement class: a capability-derived group
/// set and a base size exponent solved from the class's cost sketch.
struct ClassCfg {
    group_set: GroupSet,
    p: i64,
}

/// Tiled MM moves `2·b³` flops per base size `b`.
fn mm_cfg(d: &DeviceProfile) -> ClassCfg {
    ClassCfg {
        group_set: two_d_groups(d),
        p: d.class_size_exp("mm_tiled", size_exp(d.peak_f32(), 2.0, 3, t_case(d), 6, 11)),
    }
}

fn mm_naive_cfg(d: &DeviceProfile) -> ClassCfg {
    ClassCfg {
        group_set: two_d_groups(d),
        p: d.class_size_exp("mm_naive", size_exp(d.peak_f32(), 2.0, 3, t_case(d), 6, 10)),
    }
}

/// vsadd streams 3 arrays × 4 bytes per thread.
fn vsadd_cfg(d: &DeviceProfile) -> ClassCfg {
    ClassCfg {
        group_set: one_d_groups(d),
        p: d.class_size_exp("vsadd", size_exp(d.dram_bw, 12.0, 1, t_sweep(d), 16, 24)),
    }
}

/// Transpose moves 8 bytes per cell of an `n×n` matrix.
fn transpose_cfg(d: &DeviceProfile) -> ClassCfg {
    ClassCfg {
        group_set: two_d_groups(d),
        p: d.class_size_exp("transpose", size_exp(d.dram_bw, 8.0, 2, t_case(d), 8, 12)),
    }
}

/// Stride-1 global access moves ~8 bytes per thread (copy).
fn global_cfg(d: &DeviceProfile) -> ClassCfg {
    ClassCfg {
        group_set: one_d_groups(d),
        p: d.class_size_exp("sg", size_exp(d.dram_bw, 8.0, 1, t_sweep(d), 14, 22)),
    }
}

/// Filled strided access re-reads its tuples 256×, mostly from cache —
/// start two octaves under the stride-1 class.
fn filled_cfg(d: &DeviceProfile) -> ClassCfg {
    ClassCfg {
        group_set: one_d_groups(d),
        p: d.class_size_exp("sg_filled", (global_cfg(d).p - 2).clamp(12, 20)),
    }
}

/// Arithmetic chains execute ~8·k ≈ 4096 flops per grid point at the
/// middle reduction depth.
fn arith_cfg(d: &DeviceProfile) -> ClassCfg {
    ClassCfg {
        group_set: two_d_groups(d),
        p: d.class_size_exp("arith", size_exp(d.peak_f32(), 4096.0, 2, t_case(d), 6, 10)),
    }
}

/// The empty kernel sweeps group counts around the point where the
/// per-group launch term matches the fixed launch base, so the fit can
/// separate the two overhead columns.
fn empty_cfg(d: &DeviceProfile) -> ClassCfg {
    let group_set = two_d_groups(d);
    let (gx, gy) = group_set.standard();
    let ratio = (gx * gy) as f64 * d.launch_base / d.launch_per_group.max(1e-12);
    let p = ((ratio.max(1.0).log2() / 2.0).ceil() as i64).clamp(7, 11);
    ClassCfg { group_set, p: d.class_size_exp("empty", p) }
}

/// Assemble the full §4.1 measurement suite for a device.
pub fn suite(device: &DeviceProfile) -> Vec<KernelCase> {
    let mut out = Vec::new();

    // 1. tiled MM: 4 shapes x 4 sizes x 3 groups
    let cfg = mm_cfg(device);
    for (gx, gy) in cfg.group_set.sizes() {
        let k = mm_tiled(gx, gy);
        for t in 0..4 {
            let base = 1i64 << (cfg.p + t);
            for (shape, n, m, l) in mm_shapes(base) {
                let (n, m, l) = (snap(n, gy), snap(m, gx), snap(l, gx));
                out.push(KernelCase {
                    kernel: k.clone(),
                    env: env(&[("n", n), ("m", m), ("l", l)]),
                    label: format!("mm_tiled/{shape}/b={base}/g={gx}x{gy}"),
                    group: (gx, gy),
                });
            }
        }
    }

    // 2. naive MM: 4 sizes x 3 groups
    let cfg = mm_naive_cfg(device);
    for (gx, gy) in cfg.group_set.sizes() {
        let k = mm_naive(gx, gy);
        for t in 0..4 {
            let n = snap(1i64 << (cfg.p + t), lcm(gx, gy));
            out.push(KernelCase {
                kernel: k.clone(),
                env: env(&[("n", n)]),
                label: format!("mm_naive/n={n}/g={gx}x{gy}"),
                group: (gx, gy),
            });
        }
    }

    // 3. vector scale-and-add: 3 strides x 4 sizes x 3 groups
    let cfg = vsadd_cfg(device);
    for (lsize, _) in cfg.group_set.sizes() {
        for stride in 1..=3i64 {
            let k = vsadd(stride, lsize);
            for t in 0..4 {
                let n = 1i64 << (cfg.p + 2 * t).min(26);
                let nt = snap(n / stride, lsize);
                out.push(KernelCase {
                    kernel: k.clone(),
                    env: env(&[("nt", nt)]),
                    label: format!("vsadd/s={stride}/t={t}/n={n}/g={lsize}"),
                    group: (lsize, 1),
                });
            }
        }
    }

    // 4. transpose: 3 variants x 4 sizes x 3 groups
    let cfg = transpose_cfg(device);
    for (gx, gy) in cfg.group_set.sizes() {
        for variant in [
            TransposeVariant::Tiled,
            TransposeVariant::CoalescedWrite,
            TransposeVariant::CoalescedRead,
        ] {
            let k = transpose(variant, gx, gy);
            for t in 0..4 {
                let n = snap(1i64 << (cfg.p + t), lcm(gx, gy).max(gx));
                out.push(KernelCase {
                    kernel: k.clone(),
                    env: env(&[("n", n)]),
                    label: format!("{}/n={n}/g={gx}x{gy}", k.name),
                    group: (gx, gy),
                });
            }
        }
    }

    // 5. stride-1 global access (+ the uniform-class store):
    //    4 configs x 9 sizes x 3 groups
    let cfg = global_cfg(device);
    for (lsize, _) in cfg.group_set.sizes() {
        for gac in [
            GlobalAccessConfig::Copy,
            GlobalAccessConfig::Add4,
            GlobalAccessConfig::StoreIndex,
            GlobalAccessConfig::StoreUniform,
        ] {
            let k = global_access(gac, lsize);
            for t in 0..9 {
                let n = snap(1i64 << (cfg.p + t).min(26), lsize);
                out.push(KernelCase {
                    kernel: k.clone(),
                    env: env(&[("n", n)]),
                    label: format!("{}/t={t}/n={n}/g={lsize}", k.name),
                    group: (lsize, 1),
                });
            }
        }
    }

    // 6/7. filled stride-2 and stride-3: 4 sizes x 3 groups each
    let cfg = filled_cfg(device);
    for (lsize, _) in cfg.group_set.sizes() {
        for s in [2i64, 3] {
            let k = filled(s, lsize);
            for t in 0..4 {
                let nt = snap(1i64 << (cfg.p + 3 * t).min(24), lsize);
                out.push(KernelCase {
                    kernel: k.clone(),
                    env: env(&[("nt", nt)]),
                    label: format!("{}/t={t}/nt={nt}/g={lsize}", k.name),
                    group: (lsize, 1),
                });
            }
        }
    }

    // 8. arithmetic: 5 types x (3 k-values x 3 sizes) x 3 groups
    let cfg = arith_cfg(device);
    for (gx, gy) in cfg.group_set.sizes() {
        for ty in ArithType::all() {
            let k = arith(ty, gx, gy);
            for kk in [256i64, 512, 728] {
                for t in 0..3 {
                    let n = snap(1i64 << (cfg.p + t), lcm(gx, gy));
                    out.push(KernelCase {
                        kernel: k.clone(),
                        env: env(&[("n", n), ("k", kk)]),
                        label: format!("{}/n={n}/k={kk}/g={gx}x{gy}", k.name),
                        group: (gx, gy),
                    });
                }
            }
        }
    }

    // 9. empty kernel: 6 sizes x 3 groups
    let cfg = empty_cfg(device);
    for (gx, gy) in cfg.group_set.sizes() {
        let k = empty(gx, gy);
        for t in 0..6 {
            let n = snap(1i64 << (cfg.p + t), lcm(gx, gy));
            out.push(KernelCase {
                kernel: k.clone(),
                env: env(&[("n", n)]),
                label: format!("empty/n={n}/g={gx}x{gy}"),
                group: (gx, gy),
            });
        }
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{execute, seed_value};
    use crate::qpoly::env;

    #[test]
    fn mm_tiled_square_group_correct() {
        let k = mm_tiled(8, 8);
        let e = env(&[("n", 16), ("m", 16), ("l", 16)]);
        let st = execute(&k, &e).unwrap();
        let cc = st.get("cc").unwrap();
        for i in 0..16usize {
            for j in 0..16usize {
                let want: f64 = (0..16)
                    .map(|kk| seed_value("a", i * 16 + kk) * seed_value("b", kk * 16 + j))
                    .sum();
                assert!((cc[i * 16 + j] - want).abs() < 1e-10, "({i},{j})");
            }
        }
    }

    #[test]
    fn mm_tiled_nonsquare_group_correct() {
        // (gx, gy) = (8, 4): ru = 2, B padded by 0 rows (2*4 = 8 = gx)
        let k = mm_tiled(8, 4);
        let e = env(&[("n", 8), ("m", 16), ("l", 8)]);
        let st = execute(&k, &e).unwrap();
        let cc = st.get("cc").unwrap();
        for i in 0..8usize {
            for j in 0..8usize {
                let want: f64 = (0..16)
                    .map(|kk| seed_value("a", i * 16 + kk) * seed_value("b", kk * 8 + j))
                    .sum();
                assert!((cc[i * 8 + j] - want).abs() < 1e-10, "({i},{j})");
            }
        }
    }

    #[test]
    fn mm_tiled_padded_b_group_correct() {
        // (gx, gy) = (16, 12): ru = 2, bpad = 8 -> padded B rows unused
        let k = mm_tiled(16, 12);
        let e = env(&[("n", 24), ("m", 32), ("l", 16)]);
        let st = execute(&k, &e).unwrap();
        let cc = st.get("cc").unwrap();
        for i in 0..24usize {
            for j in 0..16usize {
                let want: f64 = (0..32)
                    .map(|kk| seed_value("a", i * 32 + kk) * seed_value("b", kk * 16 + j))
                    .sum();
                assert!((cc[i * 16 + j] - want).abs() < 1e-10, "({i},{j})");
            }
        }
    }

    #[test]
    fn mm_naive_correct() {
        let k = mm_naive(8, 4);
        let e = env(&[("n", 8)]);
        let st = execute(&k, &e).unwrap();
        let cc = st.get("cc").unwrap();
        for i in 0..8usize {
            for j in 0..8usize {
                let want: f64 = (0..8)
                    .map(|kk| seed_value("a", i * 8 + kk) * seed_value("b", kk * 8 + j))
                    .sum();
                assert!((cc[i * 8 + j] - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn vsadd_strides_correct() {
        for s in 1..=3i64 {
            let k = vsadd(s, 32);
            let e = env(&[("nt", 64)]);
            let st = execute(&k, &e).unwrap();
            let out = st.get("out").unwrap();
            let (s1, s2) = (seed_value("s1", 0), seed_value("s2", 0));
            for i in 0..64usize {
                let idx = s as usize * i;
                let want = s1 * seed_value("x", idx) + s2 * seed_value("y", idx);
                assert!((out[idx] - want).abs() < 1e-12, "s={s} i={i}");
            }
        }
    }

    #[test]
    fn transpose_variants_correct() {
        // tiled with square group
        for (variant, gx, gy) in [
            (TransposeVariant::Tiled, 8, 8),
            (TransposeVariant::Tiled, 8, 4),
            (TransposeVariant::CoalescedWrite, 8, 4),
            (TransposeVariant::CoalescedRead, 8, 4),
        ] {
            let k = transpose(variant, gx, gy);
            let n = 16usize;
            let e = env(&[("n", n as i64)]);
            let st = execute(&k, &e).unwrap();
            let out = st.get("out").unwrap();
            // row pitch may include padding for the tiled variant
            let pitch = n;
            for i in 0..n {
                for j in 0..n {
                    assert_eq!(
                        out[j * pitch + i],
                        seed_value("a", i * pitch + j),
                        "{variant:?} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn global_access_configs_correct() {
        let e = env(&[("n", 128)]);
        let st = execute(&global_access(GlobalAccessConfig::Copy, 64), &e).unwrap();
        assert_eq!(st.get("out").unwrap()[7], seed_value("a", 7));
        let st = execute(&global_access(GlobalAccessConfig::Add4, 64), &e).unwrap();
        let want: f64 = ["a1", "a2", "a3", "a4"].iter().map(|a| seed_value(a, 9)).sum();
        assert!((st.get("out").unwrap()[9] - want).abs() < 1e-12);
        let st = execute(&global_access(GlobalAccessConfig::StoreIndex, 64), &e).unwrap();
        assert_eq!(st.get("out").unwrap()[100], 100.0);
        // uniform store: one cell per group, holding the group id
        let st = execute(&global_access(GlobalAccessConfig::StoreUniform, 64), &e).unwrap();
        let out = st.get("out").unwrap();
        assert_eq!(out[0], 0.0);
        assert_eq!(out[1], 1.0);
    }

    #[test]
    fn store_uniform_emits_uniform_class_global_stores() {
        use crate::isl::progression::StrideClass;
        use crate::stats::{extract, Dir, ExtractOpts, Prop, Schema};
        let k = global_access(GlobalAccessConfig::StoreUniform, 256);
        let e = env(&[("n", 4096)]);
        let props = extract(&k, &e, ExtractOpts::default()).unwrap();
        let schema = Schema::full();
        let v = props.eval(&schema, &e).unwrap();
        let uni_store = v[schema
            .index_of(&Prop::MemGlobal {
                bits: 32,
                dir: Dir::Store,
                class: StrideClass::Uniform,
            })
            .unwrap()];
        assert!(uni_store > 0.0, "sg_storeuni must exercise the uniform-store class");
    }

    #[test]
    fn filled_kernels_correct() {
        for s in [2i64, 3] {
            let k = filled(s, 32);
            let e = env(&[("nt", 32)]);
            let st = execute(&k, &e).unwrap();
            let out = st.get("out").unwrap();
            for i in 0..32usize {
                let pair: f64 =
                    (0..s as usize).map(|ci| seed_value("x", ci + s as usize * i)).sum();
                assert!((out[i] - 256.0 * pair).abs() < 1e-9, "s={s} i={i}");
            }
        }
    }

    #[test]
    fn arith_kernels_run_and_are_finite() {
        for ty in ArithType::all() {
            let k = arith(ty, 8, 4);
            let e = env(&[("n", 8), ("k", 16)]);
            let st = execute(&k, &e).unwrap();
            for &x in st.get("out").unwrap() {
                assert!(x.is_finite(), "{ty:?}");
            }
        }
    }

    #[test]
    fn arith_op_counts_match_design() {
        use crate::lpir::OpKind;
        use crate::stats::{extract, ExtractOpts, Prop, Schema};
        // mul kernel: 8 muls per reduction point
        let k = arith(ArithType::Mul, 16, 16);
        let e = env(&[("n", 32), ("k", 16)]);
        let props = extract(&k, &e, ExtractOpts::default()).unwrap();
        let schema = Schema::full();
        let v = props.eval(&schema, &e).unwrap();
        let muls = v[schema.index_of(&Prop::Op { kind: OpKind::Mul, bits: 32 }).unwrap()];
        assert_eq!(muls, 8.0 * 32.0 * 32.0 * 16.0);
    }

    #[test]
    fn empty_kernel_has_no_work() {
        use crate::stats::{extract, ExtractOpts, Prop, Schema};
        let k = empty(16, 16);
        let e = env(&[("n", 64)]);
        let props = extract(&k, &e, ExtractOpts::default()).unwrap();
        let schema = Schema::full();
        let v = props.eval(&schema, &e).unwrap();
        let nonzero: Vec<usize> = (0..v.len()).filter(|&i| v[i] != 0.0).collect();
        // only WorkGroups and Const
        assert_eq!(nonzero.len(), 2);
        assert_eq!(v[schema.index_of(&Prop::WorkGroups).unwrap()], 16.0);
        assert_eq!(v[schema.index_of(&Prop::Const).unwrap()], 1.0);
    }

    #[test]
    fn suite_sizes_per_device() {
        for dev in crate::gpusim::registry::builtins().iter() {
            let suite = suite(dev);
            // 48 mm + 12 naive + 36 vsadd + 36 transpose + 108 global
            // + 24 filled + 135 arith + 18 empty = 417
            assert_eq!(suite.len(), 417, "{}", dev.name);
            // labels unique
            let mut labels: Vec<&String> = suite.iter().map(|c| &c.label).collect();
            labels.sort();
            labels.dedup();
            assert_eq!(labels.len(), 417, "{}: duplicate labels", dev.name);
            // every case respects the device's group-size cap
            for case in &suite {
                assert!(
                    case.group.0 * case.group.1 <= dev.max_group_size as i64,
                    "{}: {}",
                    dev.name,
                    case.label
                );
            }
        }
    }
}
