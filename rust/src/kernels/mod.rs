//! The kernel library: the paper's measurement-kernel classes (§4.1) and
//! test kernels (§5), expressed as [`crate::lpir`] builders.
//!
//! * [`measure`] — the nine measurement classes (tiled & naive matrix
//!   multiplication, vector scale-and-add at strides 1–3, three transpose
//!   variants, stride-1 global access, stride-2/3 filled access, five
//!   arithmetic-operation kernels, and the empty kernel), each swept over
//!   the paper's size and work-group-size cases per device.
//! * [`testks`] — the evaluation-kernel zoo: the four §5 test kernels
//!   (finite-difference stencil, skinny matrix multiplication, 7×7×3
//!   convolution, n-body) with the per-device problem/group sizes of §5,
//!   plus five zoo kernels (tree reduction, inclusive scan, 3-D stencil,
//!   batched small matmul, strided gather) used for held-out
//!   cross-validation ([`crate::crossval`]).
//!
//! Sizes are *snapped* to the nearest multiple of the work-group tile so
//! kernels stay guard-free (the paper's OpenCL emits boundary guards
//! instead; both choices keep model and device consistent, which is all
//! the fit requires).

pub mod measure;
pub mod testks;

use crate::lpir::Kernel;
use crate::util::intern::Env;

/// A concrete benchmarkable case: kernel + parameter binding.
#[derive(Clone, Debug)]
pub struct KernelCase {
    pub kernel: Kernel,
    pub env: Env,
    /// e.g. `mm_square/p=9/t=1/g=16x16`
    pub label: String,
    /// work-group shape used to build the kernel
    pub group: (i64, i64),
}

/// The paper's six work-group-size sets (§4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GroupSet {
    OneDSmall,
    OneDMed,
    OneDLarge,
    TwoDSmall,
    TwoDMed,
    TwoDLarge,
}

impl GroupSet {
    /// The three work-group shapes of the set.
    pub fn sizes(&self) -> Vec<(i64, i64)> {
        match self {
            GroupSet::OneDSmall => vec![(192, 1), (224, 1), (256, 1)],
            GroupSet::OneDMed => vec![(128, 1), (256, 1), (384, 1)],
            GroupSet::OneDLarge => vec![(256, 1), (384, 1), (512, 1)],
            GroupSet::TwoDSmall => vec![(16, 12), (16, 14), (16, 16)],
            GroupSet::TwoDMed => vec![(16, 12), (16, 16), (32, 16)],
            GroupSet::TwoDLarge => vec![(16, 16), (24, 16), (32, 16)],
        }
    }

    /// The 256-thread member of the set (the configuration the paper
    /// reports test-kernel results for).
    pub fn g256(&self) -> (i64, i64) {
        self.sizes()
            .into_iter()
            .find(|(a, b)| a * b == 256)
            .expect("every group set contains a 256-thread shape")
    }
}

/// Snap `n` to the nearest positive multiple of `q`.
pub fn snap(n: i64, q: i64) -> i64 {
    (((n + q / 2) / q).max(1)) * q
}

/// Full measurement suite for a device (§4.1): all nine classes with the
/// paper's per-device group sets and size exponents.
pub fn measurement_suite(device: &str) -> Vec<KernelCase> {
    measure::suite(device)
}

/// The four test kernels for a device (§5), 256-thread groups, four size
/// cases (`a.`–`d.`) each.
pub fn test_suite(device: &str) -> Vec<KernelCase> {
    testks::suite(device)
}

/// The full evaluation-kernel zoo for a device: the four §5 test kernels
/// plus the five expansion kernels (9 classes × 4 size cases).
pub fn eval_suite(device: &str) -> Vec<KernelCase> {
    testks::eval_suite(device)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_sets_have_three_shapes_and_a_256(){
        for gs in [
            GroupSet::OneDSmall,
            GroupSet::OneDMed,
            GroupSet::OneDLarge,
            GroupSet::TwoDSmall,
            GroupSet::TwoDMed,
            GroupSet::TwoDLarge,
        ] {
            assert_eq!(gs.sizes().len(), 3);
            let (a, b) = gs.g256();
            assert_eq!(a * b, 256);
        }
    }

    #[test]
    fn snap_behaviour() {
        assert_eq!(snap(128, 16), 128);
        assert_eq!(snap(128, 12), 132);
        assert_eq!(snap(5, 16), 16);
        assert_eq!(snap(1024, 48), 1008);
    }
}
