//! The kernel library: the paper's measurement-kernel classes (§4.1) and
//! test kernels (§5), expressed as [`crate::lpir`] builders.
//!
//! * [`measure`] — the measurement classes (tiled & naive matrix
//!   multiplication, vector scale-and-add at strides 1–3, three transpose
//!   variants, stride-1 global access including the uniform-class store,
//!   stride-2/3 filled access, five arithmetic-operation kernels, and the
//!   empty kernel), each swept over size and work-group-size cases.
//! * [`testks`] — the evaluation-kernel zoo: the four §5 test kernels
//!   (finite-difference stencil, skinny matrix multiplication, 7×7×3
//!   convolution, n-body) plus five zoo kernels (tree reduction,
//!   inclusive scan, 3-D stencil, batched small matmul, strided gather)
//!   used for held-out cross-validation ([`crate::crossval`]).
//!
//! Per-device configuration is **capability-derived**: work-group sets
//! come from the profile's group-size cap, warp width and occupancy
//! headroom ([`one_d_groups`]/[`two_d_groups`]), and size exponents are
//! solved from a per-class cost sketch against the profile's
//! launch-overhead floor ([`size_exp`]) — so *any* profile served by the
//! device registry ([`crate::gpusim::registry`]), including ones loaded
//! from JSON, automatically gets a valid measurement campaign and zoo
//! suite. The paper's four devices land on exactly the six group sets
//! the paper tabulates.
//!
//! Sizes are *snapped* to the nearest multiple of the work-group tile so
//! kernels stay guard-free (the paper's OpenCL emits boundary guards
//! instead; both choices keep model and device consistent, which is all
//! the fit requires).

pub mod measure;
pub mod testks;

use crate::gpusim::DeviceProfile;
use crate::lpir::Kernel;
use crate::util::intern::Env;

/// A concrete benchmarkable case: kernel + parameter binding.
#[derive(Clone, Debug)]
pub struct KernelCase {
    pub kernel: Kernel,
    pub env: Env,
    /// e.g. `mm_square/p=9/t=1/g=16x16`
    pub label: String,
    /// work-group shape used to build the kernel
    pub group: (i64, i64),
}

/// A set of work-group shapes for one device, derived from its
/// capabilities (replaces the paper's six hand-tabulated sets).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GroupSet {
    shapes: Vec<(i64, i64)>,
}

impl GroupSet {
    pub fn new(shapes: Vec<(i64, i64)>) -> GroupSet {
        assert!(!shapes.is_empty(), "a group set needs at least one shape");
        GroupSet { shapes }
    }

    /// The work-group shapes of the set.
    pub fn sizes(&self) -> Vec<(i64, i64)> {
        self.shapes.clone()
    }

    /// The *standard* member: the largest shape of at most 256 threads
    /// — the 256-thread configuration the paper reports test-kernel
    /// results for on every device that admits 256-thread groups, and
    /// the device's largest shape on smaller parts.
    pub fn standard(&self) -> (i64, i64) {
        self.shapes
            .iter()
            .copied()
            .filter(|(a, b)| a * b <= 256)
            .max_by_key(|(a, b)| a * b)
            .or_else(|| self.shapes.first().copied())
            .expect("non-empty group set")
    }
}

/// The 1-D work-group set for a profile. Parts capped at 256 threads or
/// fewer pack three shapes up against the cap (the Fury's published
/// `{192, 224, 256}`); caps between 256 and 512 anchor the 256-thread
/// standard and reach up to the cap; larger parts get the paper's
/// medium or large set depending on resident-group headroom. Every set
/// contains a 256-thread shape whenever the cap admits one, so
/// [`GroupSet::standard`] is well-defined on any valid profile.
pub fn one_d_groups(p: &DeviceProfile) -> GroupSet {
    let cap = p.max_group_size as i64;
    if cap <= 256 {
        let step = (cap / 8).min(32).max(1);
        GroupSet::new(vec![(cap - 2 * step, 1), (cap - step, 1), (cap, 1)])
    } else if cap < 512 {
        GroupSet::new(vec![(128, 1), (256, 1), (cap.min(384), 1)])
    } else if p.max_groups_per_sm >= 24 {
        GroupSet::new(vec![(256, 1), (384, 1), (512, 1)])
    } else {
        GroupSet::new(vec![(128, 1), (256, 1), (384, 1)])
    }
}

/// The 2-D work-group set for a profile. Derived shapes keep lane
/// (x) extent at 16 (8 on sub-192 parts) so tiled kernels' cooperative
/// loads stay legal (`2·gy ≥ gx`), and always include the standard
/// shape of [`GroupSet::standard`] (the 256-thread `(16, 16)` whenever
/// the cap admits it).
pub fn two_d_groups(p: &DeviceProfile) -> GroupSet {
    let cap = p.max_group_size as i64;
    if cap < 192 {
        let c = cap / 8;
        GroupSet::new(vec![(8, c - 2), (8, c - 1), (8, c)])
    } else if cap <= 256 {
        let c = cap / 16;
        GroupSet::new(vec![(16, c - 4), (16, c - 2), (16, c)])
    } else if cap < 512 {
        GroupSet::new(vec![(16, 12), (16, 16), (16, cap / 16)])
    } else if p.max_groups_per_sm >= 24 {
        GroupSet::new(vec![(16, 16), (24, 16), (32, 16)])
    } else {
        GroupSet::new(vec![(16, 12), (16, 16), (32, 16)])
    }
}

/// Target wall time for classes that sweep a wide size range (the small
/// end may fall under the harness's reliable-timing filter; that is the
/// sweep's job).
pub(crate) fn t_sweep(p: &DeviceProfile) -> f64 {
    (2.5 * p.launch_floor_s()).max(25e-6)
}

/// Target wall time for the evaluation classes whose *smallest* case
/// must itself clear the launch floor comfortably.
pub(crate) fn t_case(p: &DeviceProfile) -> f64 {
    (10.0 * p.launch_floor_s()).max(150e-6)
}

/// Solve a per-class cost sketch for the base size exponent: the
/// smallest `e` (clamped to `[lo, hi]`) such that a problem of
/// `2^(dims·e)` cost units of `unit` each, executed at `rate` units/s,
/// runs for at least `t_min` seconds. `rate` is the profile's DRAM
/// bandwidth for memory-bound classes (unit = bytes) or its peak f32
/// rate for compute-bound ones (unit = flops).
pub(crate) fn size_exp(rate: f64, unit: f64, dims: i64, t_min: f64, lo: i64, hi: i64) -> i64 {
    let target = (t_min * rate / unit).max(1.0);
    ((target.log2() / dims as f64).ceil() as i64).clamp(lo, hi)
}

/// Snap `n` to the nearest positive multiple of `q`.
pub fn snap(n: i64, q: i64) -> i64 {
    (((n + q / 2) / q).max(1)) * q
}

pub(crate) fn gcd(a: i64, b: i64) -> i64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Least common multiple (used to snap sizes to 2-D tile shapes).
pub(crate) fn lcm(a: i64, b: i64) -> i64 {
    a / gcd(a, b) * b
}

/// Full measurement suite for a device (§4.1): all classes with
/// capability-derived group sets and size exponents.
pub fn measurement_suite(device: &DeviceProfile) -> Vec<KernelCase> {
    measure::suite(device)
}

/// The four test kernels for a device (§5), standard-size groups, four
/// size cases (`a.`–`d.`) each.
pub fn test_suite(device: &DeviceProfile) -> Vec<KernelCase> {
    testks::suite(device)
}

/// The full evaluation-kernel zoo for a device: the four §5 test kernels
/// plus the five expansion kernels (9 classes × 4 size cases).
pub fn eval_suite(device: &DeviceProfile) -> Vec<KernelCase> {
    testks::eval_suite(device)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::registry::builtins;

    #[test]
    fn paper_devices_derive_the_published_group_sets() {
        let one = |n: &str| one_d_groups(builtins().get(n).unwrap()).sizes();
        let two = |n: &str| two_d_groups(builtins().get(n).unwrap()).sizes();
        // the derivation reproduces the paper's six tabulated sets
        assert_eq!(one("r9_fury"), vec![(192, 1), (224, 1), (256, 1)]);
        assert_eq!(one("k40c"), vec![(128, 1), (256, 1), (384, 1)]);
        assert_eq!(one("c2070"), vec![(128, 1), (256, 1), (384, 1)]);
        assert_eq!(one("titan_x"), vec![(256, 1), (384, 1), (512, 1)]);
        assert_eq!(two("r9_fury"), vec![(16, 12), (16, 14), (16, 16)]);
        assert_eq!(two("k40c"), vec![(16, 12), (16, 16), (32, 16)]);
        assert_eq!(two("titan_x"), vec![(16, 16), (24, 16), (32, 16)]);
    }

    #[test]
    fn derived_sets_valid_on_every_builtin() {
        for p in builtins().iter() {
            for gs in [one_d_groups(p), two_d_groups(p)] {
                assert_eq!(gs.sizes().len(), 3, "{}", p.name);
                for (a, b) in gs.sizes() {
                    assert!(a > 0 && b > 0, "{}", p.name);
                    assert!(a * b <= p.max_group_size as i64, "{}: {a}x{b}", p.name);
                }
                // every built-in admits 256-thread groups
                let (a, b) = gs.standard();
                assert_eq!(a * b, 256, "{}", p.name);
            }
        }
    }

    #[test]
    fn mid_caps_keep_the_256_thread_standard() {
        // caps strictly between 256 and 512 must still anchor a
        // 256-thread standard shape while reaching up to the cap
        for cap in [272u32, 336, 384, 496] {
            let mut p = builtins().get("r9_fury").unwrap().clone();
            p.max_group_size = cap;
            p.threads_per_sm = 2048;
            for gs in [one_d_groups(&p), two_d_groups(&p)] {
                let (a, b) = gs.standard();
                assert_eq!(a * b, 256, "cap={cap}: {:?}", gs.sizes());
                for (x, y) in gs.sizes() {
                    assert!(x * y <= cap as i64, "cap={cap}: {x}x{y}");
                }
            }
            assert!(one_d_groups(&p).sizes().iter().any(|&(x, _)| x > 256), "cap={cap}");
        }
    }

    #[test]
    fn standard_shape_of_small_caps() {
        // a hypothetical 128-thread-capped part still gets a usable set
        let mut p = builtins().get("igp620").unwrap().clone();
        p.max_group_size = 128;
        let one = one_d_groups(&p);
        assert_eq!(one.sizes(), vec![(96, 1), (112, 1), (128, 1)]);
        assert_eq!(one.standard(), (128, 1));
        let two = two_d_groups(&p);
        assert_eq!(two.sizes(), vec![(8, 14), (8, 15), (8, 16)]);
        assert_eq!(two.standard(), (8, 16));
        // tiled transpose's cooperative-load precondition holds
        for (gx, gy) in two.sizes() {
            assert!(2 * gy >= gx);
        }
    }

    #[test]
    fn size_exp_solves_and_clamps() {
        // 100 µs at 100 GB/s over 12-byte elements -> 2^20
        assert_eq!(size_exp(100e9, 12.0, 1, 100e-6, 1, 63), 20);
        // cubic classes take the exponent per axis
        assert_eq!(size_exp(1e12, 2.0, 3, 100e-6, 1, 63), 9);
        // clamps apply
        assert_eq!(size_exp(100e9, 12.0, 1, 100e-6, 1, 15), 15);
        assert_eq!(size_exp(100e9, 12.0, 1, 100e-6, 22, 63), 22);
    }

    #[test]
    fn size_exp_overrides_reshape_the_suites() {
        let base = builtins().get("k40c").unwrap().clone();
        let mut tuned = base.clone();
        tuned.size_exp.insert("fd5".into(), 9);
        tuned.size_exp.insert("sg".into(), 15);
        tuned.validate().unwrap();
        let labels = |cases: &[KernelCase], prefix: &str| -> Vec<String> {
            cases.iter().filter(|c| c.label.starts_with(prefix)).map(|c| c.label.clone()).collect()
        };
        // the overridden evaluation class moves, untouched classes don't
        let (tb, tt) = (test_suite(&base), test_suite(&tuned));
        assert_ne!(labels(&tb, "fd5/"), labels(&tt, "fd5/"));
        assert_eq!(labels(&tb, "nbody/"), labels(&tt, "nbody/"));
        // same for the measurement campaign's stride-1 global class
        let (mb, mt) = (measurement_suite(&base), measurement_suite(&tuned));
        assert_ne!(labels(&mb, "sg_copy/"), labels(&mt, "sg_copy/"));
        assert_eq!(labels(&mb, "mm_tiled/"), labels(&mt, "mm_tiled/"));
        assert_eq!(mb.len(), mt.len(), "overrides move sizes, not case counts");
    }

    #[test]
    fn snap_behaviour() {
        assert_eq!(snap(128, 16), 128);
        assert_eq!(snap(128, 12), 132);
        assert_eq!(snap(5, 16), 16);
        assert_eq!(snap(1024, 48), 1008);
    }
}
