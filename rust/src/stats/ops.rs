//! Floating-point operation counting (paper §2.2, Algorithm 1).
//!
//! Walks instruction right-hand sides, inferring operand data types (the
//! paper's "type inference pass") and attributing each float operation to
//! its execution scope: the projection of the kernel domain onto the
//! instruction's inames plus any enclosing reduction inames.

use crate::lpir::{DType, Expr, Insn, Kernel, OpKind};
use crate::qpoly::PwQPoly;
use crate::util::intern::Sym;
use std::collections::BTreeMap;

/// Infer the result dtype of an expression. `None` means "type-neutral"
/// (literals adapt to their context); integer index values are treated as
/// 32-bit floats because every use in a value context implies a
/// conversion to the surrounding float computation.
pub fn infer_dtype(kernel: &Kernel, e: &Expr) -> Option<DType> {
    match e {
        Expr::Lit(_) => None,
        Expr::Idx(_) => Some(DType::F32),
        Expr::Load(a) => kernel.array(&a.array).map(|arr| arr.dtype),
        Expr::Cast(dt, _) => Some(*dt),
        Expr::Un(_, x) => infer_dtype(kernel, x),
        Expr::Bin(_, a, b) => match (infer_dtype(kernel, a), infer_dtype(kernel, b)) {
            (Some(x), Some(y)) => Some(DType::promote(x, y)),
            (Some(x), None) | (None, Some(x)) => Some(x),
            (None, None) => None,
        },
        Expr::Reduce(_, _, body) => infer_dtype(kernel, body),
    }
}

/// Operation-size bucket (bits) and SIMD width multiplier for a dtype.
fn op_bits(dt: DType) -> (u32, f64) {
    match dt {
        DType::F32 | DType::I32 => (32, 1.0),
        DType::F64 => (64, 1.0),
        // a 4-wide vector op performs 4 scalar f32 operations
        DType::F32x4 => (32, 4.0),
    }
}

/// Count the floating-point operations of one instruction, keyed by
/// (operation kind, operand bits), as symbolic execution counts.
pub fn count_insn_ops(
    kernel: &Kernel,
    insn: &Insn,
) -> BTreeMap<(OpKind, u32), PwQPoly> {
    let mut out: BTreeMap<(OpKind, u32), PwQPoly> = BTreeMap::new();

    // scope multiplier, memoized per reduction-iname stack: every op in
    // the same scope shares one symbolic projection count (a reduce body
    // with k ops would otherwise recount the same domain k times)
    let mut memo: BTreeMap<Vec<Sym>, PwQPoly> = BTreeMap::new();
    let mut scope_count = move |red: &[Sym]| -> PwQPoly {
        if let Some(q) = memo.get(red) {
            return q.clone();
        }
        let mut names: Vec<Sym> = insn.within.clone();
        for r in red {
            if !names.contains(r) {
                names.push(*r);
            }
        }
        let q = kernel.domain.project_onto(&names).count();
        memo.insert(red.to_vec(), q.clone());
        q
    };

    fn add(
        out: &mut BTreeMap<(OpKind, u32), PwQPoly>,
        kind: OpKind,
        bits: u32,
        width: f64,
        scope: &PwQPoly,
    ) {
        let entry = out.entry((kind, bits)).or_insert_with(PwQPoly::zero);
        *entry = entry.add(&scope.scale(width));
    }

    fn walk(
        kernel: &Kernel,
        e: &Expr,
        red: &mut Vec<Sym>,
        scope_count: &mut dyn FnMut(&[Sym]) -> PwQPoly,
        out: &mut BTreeMap<(OpKind, u32), PwQPoly>,
    ) {
        match e {
            Expr::Lit(_) | Expr::Idx(_) | Expr::Load(_) => {}
            Expr::Cast(_, x) => walk(kernel, x, red, scope_count, out),
            Expr::Un(op, x) => {
                if let Some(dt) = infer_dtype(kernel, e) {
                    if dt.is_float() {
                        let (bits, width) = op_bits(dt);
                        let scope = scope_count(red);
                        add(out, op.op_kind(), bits, width, &scope);
                    }
                }
                walk(kernel, x, red, scope_count, out);
            }
            Expr::Bin(op, a, b) => {
                if let Some(dt) = infer_dtype(kernel, e) {
                    if dt.is_float() {
                        let (bits, width) = op_bits(dt);
                        let scope = scope_count(red);
                        add(out, op.op_kind(), bits, width, &scope);
                    }
                }
                walk(kernel, a, red, scope_count, out);
                walk(kernel, b, red, scope_count, out);
            }
            Expr::Reduce(_, iname, body) => {
                // the reduction combine: one add/sub per reduced element
                red.push(*iname);
                if let Some(dt) = infer_dtype(kernel, body) {
                    if dt.is_float() {
                        let (bits, width) = op_bits(dt);
                        let scope = scope_count(red);
                        add(out, OpKind::AddSub, bits, width, &scope);
                    }
                }
                walk(kernel, body, red, scope_count, out);
                red.pop();
            }
        }
    }

    walk(kernel, &insn.rhs, &mut Vec::new(), &mut scope_count, &mut out);

    // update instructions (`lhs += rhs`) perform one combine per execution
    if insn.is_update {
        if let Some(dt) = infer_dtype(kernel, &insn.rhs)
            .or_else(|| kernel.array(insn.lhs.array).map(|a| a.dtype))
        {
            if dt.is_float() {
                let (bits, width) = op_bits(dt);
                let scope = scope_count(&[]);
                add(&mut out, OpKind::AddSub, bits, width, &scope);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lpir::builder::{gid_lin_1d, KernelBuilder};
    use crate::lpir::{Access, Layout, UnOp};
    use crate::qpoly::{env, LinExpr};

    fn simple_kernel(rhs: Expr) -> Kernel {
        KernelBuilder::new("k", &["n"])
            .group_dims_1d(LinExpr::var("n"), 256)
            .red_dim("r", LinExpr::var("m"))
            .global_array("a", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, false)
            .global_array("d", DType::F64, vec![LinExpr::var("n")], Layout::RowMajor, false)
            .global_array("out", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(Access::new("out", vec![gid_lin_1d(256)]), rhs, &["g0", "l0"], &[])
            .build()
            .unwrap()
    }

    #[test]
    fn counts_simple_mul() {
        // out[i] = 2 * a[i] -> one f32 mul per point
        let k = simple_kernel(Expr::mul(Expr::lit(2.0), Expr::load("a", vec![gid_lin_1d(256)])));
        let ops = count_insn_ops(&k, &k.insns[0]);
        let e = env(&[("n", 1024), ("m", 4)]);
        assert_eq!(ops[&(OpKind::Mul, 32)].eval(&e).unwrap(), 1024.0);
        assert_eq!(ops.len(), 1);
    }

    #[test]
    fn promotes_to_f64() {
        // out[i] = a[i] + d[i] -> one f64 add
        let k = simple_kernel(Expr::add(
            Expr::load("a", vec![gid_lin_1d(256)]),
            Expr::load("d", vec![gid_lin_1d(256)]),
        ));
        let ops = count_insn_ops(&k, &k.insns[0]);
        let e = env(&[("n", 512), ("m", 4)]);
        assert_eq!(ops[&(OpKind::AddSub, 64)].eval(&e).unwrap(), 512.0);
    }

    #[test]
    fn reduction_scope_multiplies() {
        // out[i] = sum(r, a[i] * 1.5): per point, m muls + m reduction adds
        let k = simple_kernel(Expr::sum(
            "r",
            Expr::mul(Expr::load("a", vec![gid_lin_1d(256)]), Expr::lit(1.5)),
        ));
        let ops = count_insn_ops(&k, &k.insns[0]);
        let e = env(&[("n", 256), ("m", 8)]);
        assert_eq!(ops[&(OpKind::Mul, 32)].eval(&e).unwrap(), 256.0 * 8.0);
        assert_eq!(ops[&(OpKind::AddSub, 32)].eval(&e).unwrap(), 256.0 * 8.0);
    }

    #[test]
    fn special_functions_categorized() {
        let k = simple_kernel(Expr::un(UnOp::Rsqrt, Expr::load("a", vec![gid_lin_1d(256)])));
        let ops = count_insn_ops(&k, &k.insns[0]);
        let e = env(&[("n", 512), ("m", 1)]);
        assert_eq!(ops[&(OpKind::Special, 32)].eval(&e).unwrap(), 512.0);
    }

    #[test]
    fn cast_not_counted_but_typed() {
        // out[i] = cast<f64>(idx) / 3.0 -> one f64 div, no other ops
        let k = simple_kernel(Expr::div(
            Expr::cast(DType::F64, Expr::Idx(gid_lin_1d(256))),
            Expr::lit(3.0),
        ));
        let ops = count_insn_ops(&k, &k.insns[0]);
        let e = env(&[("n", 256), ("m", 1)]);
        assert_eq!(ops[&(OpKind::Div, 64)].eval(&e).unwrap(), 256.0);
        assert_eq!(ops.len(), 1);
    }

    #[test]
    fn index_store_has_no_float_ops() {
        let k = simple_kernel(Expr::Idx(gid_lin_1d(256)));
        let ops = count_insn_ops(&k, &k.insns[0]);
        assert!(ops.is_empty());
    }
}
