//! Automatic extraction of model properties from kernels (paper §2 + §3).
//!
//! A [`KernelProps`] maps each [`Prop`] to a symbolic execution count
//! ([`PwQPoly`]); [`Schema`] fixes the property ordering so that dense
//! vectors line up across kernels for the fit.
//!
//! Extraction is fully automatic for the static-control-flow kernels the
//! paper targets: memory accesses are classified by access size ×
//! direction × amortized-stride-fraction class (§2.1), floating-point
//! operations by kind × operand width (§2.2), barrier counts come from
//! the schedule (§2.3), and launch overhead from the work-group count
//! (§2.4). The non-linear `min(loads, stores)` roofline property is
//! evaluated at binding time from the retained load/store counts.

pub mod footprint;
pub mod ops;

use crate::isl::progression::StrideClass;
use crate::lpir::{Insn, Kernel, MemSpace, OpKind};
use crate::obs::span::{self, Span};
use crate::qpoly::tape::{EnvFrame, PwTape, TapeScratch};
use crate::qpoly::PwQPoly;
use crate::schedule::schedule;
use crate::util::intern::{Env, Sym};
use crate::util::json::Json;
use footprint::{flatten_access, utilization, FlatAccess};
use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock};

/// Memory-access direction.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Dir {
    Load,
    Store,
}

/// A model property (one column of the property matrix).
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Prop {
    /// float op counts by kind and operand width (32/64)
    Op { kind: OpKind, bits: u32 },
    /// loads from work-group shared memory, by access width
    LocalLoad { bits: u32 },
    /// bank-conflicted local loads (lane stride >= 2) — §6.2 extension,
    /// populated only when `ExtractOpts::bin_local_strides` is set
    LocalLoadConflict { bits: u32 },
    /// global-memory traffic by access width, direction and stride class
    MemGlobal { bits: u32, dir: Dir, class: StrideClass },
    /// `min(loads, stores)` of one access type — the roofline-style
    /// nonlinearity of §2.1 (evaluated at binding time)
    MemMin { bits: u32, class: StrideClass },
    /// total barriers encountered by all threads
    Barriers,
    /// number of work groups launched (launch overhead, linear part)
    WorkGroups,
    /// constant 1 (launch overhead, constant part)
    Const,
}

impl Prop {
    /// Human-readable name (used in Table-2-style weight reports).
    pub fn label(&self) -> String {
        match self {
            Prop::Op { kind, bits } => format!("f{bits} {}", kind.label()),
            Prop::LocalLoad { bits } => format!("local f{bits} loads"),
            Prop::LocalLoadConflict { bits } => format!("local f{bits} conflicted loads"),
            Prop::MemGlobal { bits, dir, class } => {
                let d = match dir {
                    Dir::Load => "loads",
                    Dir::Store => "stores",
                };
                format!("f{bits} {} {d}", class.label())
            }
            Prop::MemMin { bits, class } => {
                format!("min(f{bits} {} loads, stores)", class.label())
            }
            Prop::Barriers => "barriers".into(),
            Prop::WorkGroups => "thread groups".into(),
            Prop::Const => "const(1)".into(),
        }
    }
}

/// The fixed property ordering shared by all kernels.
#[derive(Clone, Debug)]
pub struct Schema {
    props: Vec<Prop>,
    index: BTreeMap<Prop, usize>,
}

impl Default for Schema {
    fn default() -> Self {
        Self::full()
    }
}

impl Schema {
    /// The full §2 property set.
    pub fn full() -> Schema {
        let mut props = Vec::new();
        for kind in OpKind::all() {
            for bits in [32u32, 64] {
                props.push(Prop::Op { kind, bits });
            }
        }
        for bits in [32u32, 64, 128] {
            props.push(Prop::LocalLoad { bits });
        }
        for bits in [32u32, 64, 128] {
            props.push(Prop::LocalLoadConflict { bits });
        }
        for bits in [32u32, 64, 128] {
            for dir in [Dir::Load, Dir::Store] {
                for class in StrideClass::all() {
                    props.push(Prop::MemGlobal { bits, dir, class });
                }
            }
        }
        for bits in [32u32, 64, 128] {
            for class in StrideClass::all() {
                props.push(Prop::MemMin { bits, class });
            }
        }
        props.push(Prop::Barriers);
        props.push(Prop::WorkGroups);
        props.push(Prop::Const);
        let index = props.iter().cloned().enumerate().map(|(i, p)| (p, i)).collect();
        Schema { props, index }
    }

    /// Ablation A2: a schema whose stride classes ignore the utilization
    /// ratio (pure stride binning — every fraction collapses onto its
    /// denominator's fully-utilized class).
    pub fn without_utilization() -> Schema {
        // Same property list; collapse happens at extraction time via
        // `collapse_utilization`. The schema itself is unchanged so that
        // vectors remain comparable.
        Self::full()
    }

    pub fn len(&self) -> usize {
        self.props.len()
    }

    pub fn is_empty(&self) -> bool {
        self.props.is_empty()
    }

    pub fn props(&self) -> &[Prop] {
        &self.props
    }

    pub fn index_of(&self, p: &Prop) -> Option<usize> {
        self.index.get(p).copied()
    }

    /// Process-independent digest of the property ordering (length +
    /// every label, in order). Persisted model artifacts
    /// ([`crate::service::store`]) record this so that weight vectors
    /// are never applied against a schema whose column layout changed.
    pub fn fingerprint(&self) -> String {
        let mut h = crate::util::fnv::Fnv64::new();
        h.write_u64(self.props.len() as u64);
        for p in &self.props {
            h.write_str(&p.label());
        }
        h.hex()
    }
}

/// Extraction options (ablations). `Eq`/`Ord` because persisted model
/// artifacts record the options they were fitted under and the serving
/// layer refuses a mismatch ([`crate::service::store`]), and the
/// service's props cache embeds the whole struct in its map key — a
/// future option field then extends the key automatically instead of
/// silently aliasing entries ([`crate::service::cache`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub struct ExtractOpts {
    /// collapse utilization-ratio classes onto the fully-utilized class
    /// of the same stride (ablation A2)
    pub collapse_utilization: bool,
    /// bin local loads by lane stride into conflict-free vs.
    /// bank-conflicted classes (the paper's §6.2 future-work extension)
    pub bin_local_strides: bool,
}

/// Symbolic property counts for one kernel.
///
/// Evaluation runs on compiled tapes ([`PwTape`]): the symbolic counts
/// are flattened once (lazily, shared across clones) into slot-indexed
/// postfix programs, so re-evaluating at a new parameter binding is a
/// single allocation-free pass per property — the paper's "cheaply
/// reevaluated for changed values of the parameter vector".
#[derive(Clone, Debug)]
pub struct KernelProps {
    pub kernel_name: String,
    /// symbolic counts; private because the compiled tape cache below
    /// is derived from it once and shared across clones — mutating the
    /// counts after compilation would silently desynchronize them
    sym: BTreeMap<Prop, PwQPoly>,
    /// lazily compiled evaluation tapes, shared across clones
    tapes: Arc<OnceLock<Vec<(Prop, PwTape)>>>,
    /// schema-resolved evaluation plan, built once alongside the tapes
    /// and shared across clones (see [`EvalPlan`])
    plan: Arc<OnceLock<EvalPlan>>,
}

/// Schema-resolved evaluation plan: which dense column each compiled
/// tape writes, and which columns feed each roofline `MemMin` entry.
/// Resolving the `BTreeMap` schema probes once per (props, schema) —
/// instead of once per evaluated environment — is what makes
/// [`KernelProps::eval_batch`] allocation- and probe-free per lane.
#[derive(Clone, Debug)]
struct EvalPlan {
    /// fingerprint of the schema the plan was resolved against
    schema_fp: String,
    /// dense column per `tapes()` entry (`None`: prop not in the schema)
    tape_idx: Vec<Option<usize>>,
    /// `(MemMin column, loads column, stores column)`
    memmin: Vec<(usize, Option<usize>, Option<usize>)>,
}

fn build_plan(schema: &Schema, tapes: &[(Prop, PwTape)]) -> EvalPlan {
    let tape_idx = tapes.iter().map(|(p, _)| schema.index_of(p)).collect();
    let mut memmin = Vec::new();
    for (i, p) in schema.props().iter().enumerate() {
        if let Prop::MemMin { bits, class } = p {
            memmin.push((
                i,
                schema.index_of(&Prop::MemGlobal { bits: *bits, dir: Dir::Load, class: *class }),
                schema.index_of(&Prop::MemGlobal { bits: *bits, dir: Dir::Store, class: *class }),
            ));
        }
    }
    EvalPlan { schema_fp: schema.fingerprint(), tape_idx, memmin }
}

/// Reusable buffers for [`KernelProps::eval_batch`]: the SoA environment
/// frame, tape scratch, and one per-tape output column. An arena serves
/// any number of batches of any size — buffers grow to the high-water
/// mark and carry no state between calls.
#[derive(Default)]
pub struct BatchArena {
    frame: EnvFrame,
    scratch: TapeScratch,
    col: Vec<f64>,
}

impl BatchArena {
    pub fn new() -> BatchArena {
        BatchArena::default()
    }
}

impl KernelProps {
    pub fn new(kernel_name: String, sym: BTreeMap<Prop, PwQPoly>) -> KernelProps {
        KernelProps {
            kernel_name,
            sym,
            tapes: Arc::new(OnceLock::new()),
            plan: Arc::new(OnceLock::new()),
        }
    }

    /// The symbolic property counts (read-only; construct a new
    /// `KernelProps` to change them).
    pub fn sym(&self) -> &BTreeMap<Prop, PwQPoly> {
        &self.sym
    }

    fn tapes(&self) -> &[(Prop, PwTape)] {
        self.tapes.get_or_init(|| {
            self.sym
                .iter()
                .map(|(p, q)| (p.clone(), PwTape::compile(q)))
                .collect()
        })
    }

    /// Dense property vector at a parameter binding, in schema order.
    /// `MemMin` entries are computed here (the min is not a polynomial).
    pub fn eval(
        &self,
        schema: &Schema,
        env: &Env,
    ) -> Result<Vec<f64>, String> {
        let mut v = vec![0.0; schema.len()];
        for (p, t) in self.tapes() {
            if let Some(i) = schema.index_of(p) {
                v[i] = t.eval(env)?;
            }
        }
        // fill the roofline min(loads, stores) entries
        for (i, p) in schema.props().iter().enumerate() {
            if let Prop::MemMin { bits, class } = p {
                let loads = schema
                    .index_of(&Prop::MemGlobal { bits: *bits, dir: Dir::Load, class: *class })
                    .map(|j| v[j])
                    .unwrap_or(0.0);
                let stores = schema
                    .index_of(&Prop::MemGlobal { bits: *bits, dir: Dir::Store, class: *class })
                    .map(|j| v[j])
                    .unwrap_or(0.0);
                v[i] = loads.min(stores);
            }
        }
        Ok(v)
    }

    /// Identity of the shared compiled-tape cache. Clones of one
    /// extraction share tapes (and evaluation plan), so requests whose
    /// props carry equal ids can be evaluated by one [`Self::eval_batch`]
    /// pass.
    pub fn tape_id(&self) -> usize {
        Arc::as_ptr(&self.tapes) as usize
    }

    /// The cached plan if it matches `schema`, else a freshly resolved
    /// one (a caller mixing schemas is rare enough not to cache).
    fn plan_for(&self, schema: &Schema) -> std::borrow::Cow<'_, EvalPlan> {
        let tapes = self.tapes();
        let cached = self.plan.get_or_init(|| build_plan(schema, tapes));
        if cached.schema_fp == schema.fingerprint() {
            std::borrow::Cow::Borrowed(cached)
        } else {
            std::borrow::Cow::Owned(build_plan(schema, tapes))
        }
    }

    /// Batched [`Self::eval`]: one schema-ordered dense row per
    /// environment, written row-major into `out`
    /// (`out[j * schema.len() + i]` is property `i` of environment `j`).
    ///
    /// Each compiled tape is walked *once* across all environments over
    /// the arena's structure-of-arrays frame, and schema indices come
    /// from a plan resolved once and cached alongside the tapes — no
    /// per-environment allocation or map probing. Results are
    /// bit-identical to per-environment [`Self::eval`]. The batch fails
    /// as a whole on the first lane error (unbound parameter or i64
    /// overflow); callers that need per-environment attribution fall
    /// back to scalar `eval`, which produces the identical diagnostic.
    pub fn eval_batch(
        &self,
        schema: &Schema,
        envs: &[&Env],
        arena: &mut BatchArena,
        out: &mut Vec<f64>,
    ) -> Result<(), String> {
        let n = envs.len();
        let m = schema.len();
        out.clear();
        out.resize(n * m, 0.0);
        if n == 0 {
            return Ok(());
        }
        // timing hook for the observability plane: one span per batched
        // tape walk, lane count in the meta. Inert when tracing is off.
        let mut sp = Span::child("tape.eval_batch");
        if span::enabled() {
            sp.set_meta(format!("lanes={n}"));
        }
        let tapes = self.tapes();
        let plan = self.plan_for(schema);
        arena.frame.load(envs);
        arena.col.clear();
        arena.col.resize(n, 0.0);
        for ((_, t), idx) in tapes.iter().zip(plan.tape_idx.iter()) {
            let Some(i) = idx else { continue };
            t.eval_many(&arena.frame, &mut arena.scratch, &mut arena.col)?;
            for (j, &v) in arena.col.iter().enumerate() {
                out[j * m + *i] = v;
            }
        }
        for &(i, loads, stores) in &plan.memmin {
            for row in out.chunks_exact_mut(m) {
                let l = loads.map(|k| row[k]).unwrap_or(0.0);
                let s = stores.map(|k| row[k]).unwrap_or(0.0);
                row[i] = l.min(s);
            }
        }
        Ok(())
    }

    /// Non-zero symbolic entries with labels (for reports / debugging).
    pub fn nonzero(&self) -> Vec<(String, &PwQPoly)> {
        self.sym
            .iter()
            .filter(|(_, q)| !q.is_zero())
            .map(|(p, q)| (p.label(), q))
            .collect()
    }

    /// Serialize the symbolic counts for the persistent extraction
    /// cache. Properties are keyed by [`Prop::label`], which is unique
    /// and invertible over the full §2 property set (see
    /// [`prop_from_label`]); extraction never produces a property
    /// outside that set.
    pub fn to_json(&self) -> Json {
        let props: BTreeMap<String, Json> =
            self.sym.iter().map(|(p, q)| (p.label(), q.to_json())).collect();
        Json::obj(vec![
            ("kernel", Json::Str(self.kernel_name.clone())),
            ("props", Json::Obj(props)),
        ])
    }

    /// Rebuild from [`Self::to_json`] output. The compiled tapes are
    /// re-derived lazily on first evaluation.
    pub fn from_json(j: &Json) -> Result<KernelProps, String> {
        let name = j.get_str("kernel").ok_or("props entry: missing 'kernel'")?;
        let Some(Json::Obj(props)) = j.get("props") else {
            return Err("props entry: missing 'props'".into());
        };
        let mut sym = BTreeMap::new();
        for (label, q) in props {
            let p = prop_from_label(label)
                .ok_or_else(|| format!("unknown property label '{label}'"))?;
            sym.insert(p, PwQPoly::from_json(q)?);
        }
        Ok(KernelProps::new(name.to_string(), sym))
    }
}

/// Inverse of [`Prop::label`] over the full §2 property set (labels are
/// unique). Used when deserializing persisted extraction-cache entries;
/// an unknown label means the entry was written by an incompatible
/// build and must be rejected.
pub fn prop_from_label(label: &str) -> Option<Prop> {
    static MAP: OnceLock<BTreeMap<String, Prop>> = OnceLock::new();
    MAP.get_or_init(|| {
        let s = Schema::full();
        s.props().iter().map(|p| (p.label(), p.clone())).collect()
    })
    .get(label)
    .cloned()
}

/// A global access together with its symbolic count and flattened form.
struct GAccess {
    bits: u32,
    dir: Dir,
    count: PwQPoly,
    flat: FlatAccess,
    lane_stride: i64,
}

/// Extract all §2 properties of a kernel.
///
/// `classify_env` is a representative parameter binding used only to
/// *classify* accesses (stride class and utilization); the returned
/// counts remain symbolic and can be evaluated at any binding. (Stride
/// classes are structural for all kernels in the paper: they do not
/// change across the size sweeps.)
pub fn extract(
    kernel: &Kernel,
    classify_env: &Env,
    opts: ExtractOpts,
) -> Result<KernelProps, String> {
    kernel.validate()?;
    let sched = schedule(kernel)?;
    let mut sym: BTreeMap<Prop, PwQPoly> = BTreeMap::new();
    fn add(sym: &mut BTreeMap<Prop, PwQPoly>, p: Prop, q: PwQPoly) {
        let entry = sym.entry(p).or_insert_with(PwQPoly::zero);
        *entry = entry.add(&q);
    }

    // lane (SIMD) iname: local axis 0
    let lane_iname = kernel.local_inames().get(&0).copied();

    // ---- global memory accesses + local loads ---------------------------
    let mut gaccesses: Vec<(Sym, GAccess)> = Vec::new(); // (array, access)
    for insn in &kernel.insns {
        collect_mem(kernel, insn, classify_env, lane_iname, &mut gaccesses)?;

        // local loads (RHS only). The base model does not track their
        // strides (§2.1 last paragraph); with `bin_local_strides` they
        // split into conflict-free vs. bank-conflicted classes (§6.2).
        insn.rhs.visit_loads(&mut |a, red| {
            if let Some(arr) = kernel.array(a.array) {
                if arr.space == MemSpace::Local {
                    let mut names: Vec<Sym> = insn.within.clone();
                    for r in red {
                        if !names.contains(r) {
                            names.push(*r);
                        }
                    }
                    let count = kernel.domain.project_onto(&names).count();
                    let conflicted = opts.bin_local_strides
                        && local_lane_stride(kernel, a, classify_env, lane_iname)
                            .map(|s| s.abs() >= 2)
                            .unwrap_or(false);
                    let p = if conflicted {
                        Prop::LocalLoadConflict { bits: arr.dtype.access_bits() }
                    } else {
                        Prop::LocalLoad { bits: arr.dtype.access_bits() }
                    };
                    let entry = sym.entry(p).or_insert_with(PwQPoly::zero);
                    *entry = entry.add(&count);
                }
            }
        });
    }

    // group accesses by (array, dir, bits, |lane stride|) and classify
    let mut groups: BTreeMap<(Sym, Dir, u32, i64), Vec<GAccess>> = BTreeMap::new();
    for (arr, acc) in gaccesses {
        groups
            .entry((arr, acc.dir, acc.bits, acc.lane_stride.abs()))
            .or_default()
            .push(acc);
    }
    // merge groups in array-name order: Sym ordering is interning order
    // (process-history-dependent), and same-Prop groups fold into one
    // f64 accumulation whose order must be reproducible across runs
    let mut merged: Vec<((Sym, Dir, u32, i64), Vec<GAccess>)> = groups.into_iter().collect();
    merged.sort_by_key(|((arr, _, _, _), _)| arr.as_str());
    for ((_, dir, bits, stride), accs) in merged {
        let class = classify_group(stride, &accs, opts);
        let mut count = PwQPoly::zero();
        for a in &accs {
            count = count.add(&a.count);
        }
        add(&mut sym, Prop::MemGlobal { bits, dir, class }, count);
    }

    // ---- floating point operations --------------------------------------
    for insn in &kernel.insns {
        for ((kind, bits), q) in ops::count_insn_ops(kernel, insn) {
            add(&mut sym, Prop::Op { kind, bits }, q);
        }
    }

    // ---- barriers: total encountered by all threads ----------------------
    let per_group = sched.barriers_per_group(kernel);
    if !per_group.is_zero() {
        let group_count = kernel.group_count();
        // threads per group (product of local trip counts; symbolic)
        let mut gsize = PwQPoly::constant(1.0);
        for (_, iname) in kernel.local_inames() {
            if let Some(dim) = kernel.domain.dim(iname) {
                gsize = gsize.mul(&PwQPoly { pieces: vec![(Vec::new(), dim.trip_count())] });
            }
        }
        add(&mut sym, Prop::Barriers, per_group.mul(&group_count).mul(&gsize));
    }

    // ---- launch overhead --------------------------------------------------
    add(&mut sym, Prop::WorkGroups, kernel.group_count());
    add(&mut sym, Prop::Const, PwQPoly::constant(1.0));

    Ok(KernelProps::new(kernel.name.clone(), sym))
}

/// Lane stride (in elements) of a local-memory access.
fn local_lane_stride(
    kernel: &Kernel,
    access: &crate::lpir::Access,
    env: &Env,
    lane_iname: Option<Sym>,
) -> Option<i64> {
    let lane = lane_iname?;
    let arr = kernel.array(access.array)?;
    let axis_strides: Vec<i64> = arr
        .elem_strides()
        .iter()
        .map(|q| q.eval(env).ok().map(|x| x as i64))
        .collect::<Option<_>>()?;
    let mut s: i64 = 0;
    for (e, &st) in access.idx.iter().zip(&axis_strides) {
        s += e.coeff(lane) * st;
    }
    Some(s)
}

/// Gather the global-memory accesses of one instruction.
fn collect_mem(
    kernel: &Kernel,
    insn: &Insn,
    env: &Env,
    lane_iname: Option<Sym>,
    out: &mut Vec<(Sym, GAccess)>,
) -> Result<(), String> {
    let mut push = |array: Sym,
                    idx: &[crate::qpoly::LinExpr],
                    dir: Dir,
                    red: &[Sym]|
     -> Result<(), String> {
        let arr = kernel.array(array).ok_or_else(|| format!("unknown array '{array}'"))?;
        if arr.space != MemSpace::Global {
            return Ok(());
        }
        let mut names: Vec<Sym> = insn.within.clone();
        for r in red {
            if !names.contains(r) {
                names.push(*r);
            }
        }
        let count = kernel.domain.project_onto(&names).count();
        // concrete element strides at the classification binding
        let axis_strides: Vec<i64> = arr
            .elem_strides()
            .iter()
            .map(|q| q.eval(env).map(|x| x as i64))
            .collect::<Result<_, _>>()?;
        let flat = flatten_access(kernel, idx, &axis_strides, env)?;
        let lane_stride = lane_iname
            .map(|l| flat.coeffs.get(&l).copied().unwrap_or(0))
            .unwrap_or(0);
        out.push((
            array,
            GAccess { bits: arr.dtype.access_bits(), dir, count, flat, lane_stride },
        ));
        Ok(())
    };

    // stores: LHS (update instructions also read their LHS)
    push(insn.lhs.array, &insn.lhs.idx, Dir::Store, &[])?;
    if insn.is_update {
        push(insn.lhs.array, &insn.lhs.idx, Dir::Load, &[])?;
    }
    // loads: RHS
    let mut err: Option<String> = None;
    insn.rhs.visit_loads(&mut |a, red| {
        if err.is_none() {
            err = push(a.array, &a.idx, Dir::Load, red).err();
        }
    });
    match err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Classify an access group into a stride class using the footprint
/// utilization (paper §2.1 quantization rules).
fn classify_group(stride: i64, accs: &[GAccess], opts: ExtractOpts) -> StrideClass {
    if stride == 0 {
        return StrideClass::Uniform;
    }
    if stride == 1 {
        return StrideClass::Unit;
    }
    if opts.collapse_utilization {
        // ablation: pure stride binning, assume full utilization
        return StrideClass::classify(stride, stride);
    }
    let flats: Vec<FlatAccess> = accs.iter().map(|a| a.flat.clone()).collect();
    let info = utilization(&flats);
    // Covered cells per stride period, quantized from the ratio. The
    // small epsilon implements the paper's "50% or less -> 1/2" rule and
    // absorbs finite-window boundary effects (a stride-2 window of N
    // cells has ratio N/(2N-1), slightly above 1/2).
    let denom = if stride > 4 { 4 } else { stride };
    let covered =
        ((info.utilization * denom as f64 - 0.02).ceil() as i64).clamp(1, denom);
    if stride > 4 {
        StrideClass::FracGt4 { numer: covered as u8 }
    } else {
        StrideClass::classify(stride, covered)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lpir::builder::{gid, gid_lin_1d, KernelBuilder};
    use crate::lpir::{Access, DType, Expr, Layout};
    use crate::qpoly::{env, LinExpr};

    fn copy_kernel() -> Kernel {
        KernelBuilder::new("copy", &["n"])
            .group_dims_1d(LinExpr::var("n"), 256)
            .global_array("a", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, false)
            .global_array("b", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("b", vec![gid_lin_1d(256)]),
                Expr::load("a", vec![gid_lin_1d(256)]),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap()
    }

    #[test]
    fn copy_properties() {
        let k = copy_kernel();
        let e = env(&[("n", 1 << 20)]);
        let props = extract(&k, &e, ExtractOpts::default()).unwrap();
        let schema = Schema::full();
        let v = props.eval(&schema, &e).unwrap();
        let n = (1u64 << 20) as f64;
        let get = |p: Prop| v[schema.index_of(&p).unwrap()];
        assert_eq!(
            get(Prop::MemGlobal { bits: 32, dir: Dir::Load, class: StrideClass::Unit }),
            n
        );
        assert_eq!(
            get(Prop::MemGlobal { bits: 32, dir: Dir::Store, class: StrideClass::Unit }),
            n
        );
        // roofline min property
        assert_eq!(get(Prop::MemMin { bits: 32, class: StrideClass::Unit }), n);
        assert_eq!(get(Prop::WorkGroups), n / 256.0);
        assert_eq!(get(Prop::Const), 1.0);
        assert_eq!(get(Prop::Barriers), 0.0);
    }

    #[test]
    fn stride2_load_classified_half() {
        // b[i] = a[2i]: loads stride 2, half utilization
        let k = KernelBuilder::new("s2", &["n"])
            .group_dims_1d(LinExpr::var("n"), 256)
            .global_array(
                "a",
                DType::F32,
                vec![LinExpr::var("n").scale(2)],
                Layout::RowMajor,
                false,
            )
            .global_array("b", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("b", vec![gid_lin_1d(256)]),
                Expr::load("a", vec![gid_lin_1d(256).scale(2)]),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap();
        let e = env(&[("n", 1 << 18)]);
        let props = extract(&k, &e, ExtractOpts::default()).unwrap();
        let has = props.sym().iter().any(|(p, q)| {
            matches!(
                p,
                Prop::MemGlobal {
                    bits: 32,
                    dir: Dir::Load,
                    class: StrideClass::Frac { numer: 1, denom: 2 }
                }
            ) && !q.is_zero()
        });
        assert!(has, "props: {:?}", props.nonzero().iter().map(|(l, _)| l).collect::<Vec<_>>());
    }

    #[test]
    fn stride2_filled_classified_full() {
        // b[i] = a[2i] + a[2i+1]: both phases -> 2/2
        let k = KernelBuilder::new("s2f", &["n"])
            .group_dims_1d(LinExpr::var("n"), 256)
            .global_array(
                "a",
                DType::F32,
                vec![LinExpr::var("n").scale(2)],
                Layout::RowMajor,
                false,
            )
            .global_array("b", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("b", vec![gid_lin_1d(256)]),
                Expr::add(
                    Expr::load("a", vec![gid_lin_1d(256).scale(2)]),
                    Expr::load(
                        "a",
                        vec![gid_lin_1d(256).scale(2).add(&LinExpr::constant(1))],
                    ),
                ),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap();
        let e = env(&[("n", 1 << 18)]);
        let props = extract(&k, &e, ExtractOpts::default()).unwrap();
        let schema = Schema::full();
        let v = props.eval(&schema, &e).unwrap();
        let idx = schema
            .index_of(&Prop::MemGlobal {
                bits: 32,
                dir: Dir::Load,
                class: StrideClass::Frac { numer: 2, denom: 2 },
            })
            .unwrap();
        assert_eq!(v[idx], 2.0 * (1 << 18) as f64);
    }

    #[test]
    fn uncoalesced_column_access() {
        // out[i] = a[gid*m] — lane stride = m (row-major): uncoalesced
        let k = KernelBuilder::new("col", &["n", "m"])
            .group_dims_1d(LinExpr::var("n"), 256)
            .global_array(
                "a",
                DType::F32,
                vec![LinExpr::var("n"), LinExpr::var("m")],
                Layout::RowMajor,
                false,
            )
            .global_array("b", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("b", vec![gid_lin_1d(256)]),
                Expr::load("a", vec![gid_lin_1d(256), LinExpr::constant(0)]),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap();
        let e = env(&[("n", 4096), ("m", 512)]);
        let props = extract(&k, &e, ExtractOpts::default()).unwrap();
        let found = props.sym().iter().any(|(p, q)| {
            matches!(
                p,
                Prop::MemGlobal { bits: 32, dir: Dir::Load, class: StrideClass::FracGt4 { numer: 1 } }
            ) && !q.is_zero()
        });
        assert!(found, "{:?}", props.nonzero().iter().map(|(l, _)| l).collect::<Vec<_>>());
    }

    #[test]
    fn uniform_access_stride0() {
        // b[i] = a[0] — lane-independent load
        let k = KernelBuilder::new("uni", &["n"])
            .group_dims_1d(LinExpr::var("n"), 256)
            .global_array("a", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, false)
            .global_array("b", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("b", vec![gid_lin_1d(256)]),
                Expr::load("a", vec![LinExpr::constant(0)]),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap();
        let e = env(&[("n", 1024)]);
        let props = extract(&k, &e, ExtractOpts::default()).unwrap();
        let schema = Schema::full();
        let v = props.eval(&schema, &e).unwrap();
        let idx = schema
            .index_of(&Prop::MemGlobal {
                bits: 32,
                dir: Dir::Load,
                class: StrideClass::Uniform,
            })
            .unwrap();
        assert_eq!(v[idx], 1024.0);
    }

    #[test]
    fn local_loads_counted() {
        let k = KernelBuilder::new("loc", &["n"])
            .group_dims_1d(LinExpr::var("n"), 64)
            .global_array("a", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, false)
            .global_array("b", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .local_array("t", DType::F32, &[64])
            .insn(
                Access::new("t", vec![LinExpr::var("l0")]),
                Expr::load("a", vec![gid_lin_1d(64)]),
                &["g0", "l0"],
                &[],
            )
            .insn(
                Access::new("b", vec![gid_lin_1d(64)]),
                Expr::load("t", vec![LinExpr::var("l0")]),
                &["g0", "l0"],
                &[0],
            )
            .build()
            .unwrap();
        let e = env(&[("n", 640)]);
        let props = extract(&k, &e, ExtractOpts::default()).unwrap();
        let schema = Schema::full();
        let v = props.eval(&schema, &e).unwrap();
        assert_eq!(v[schema.index_of(&Prop::LocalLoad { bits: 32 }).unwrap()], 640.0);
    }

    #[test]
    fn barrier_property_scales_with_threads() {
        // 2-D prefetch with cross-lane read: 1 barrier/group · 256 threads
        let k = KernelBuilder::new("pf", &["n"])
            .group_dims_2d(LinExpr::var("n"), 16, LinExpr::var("n"), 16)
            .global_array(
                "a",
                DType::F32,
                vec![LinExpr::var("n"), LinExpr::var("n")],
                Layout::RowMajor,
                false,
            )
            .global_array(
                "o",
                DType::F32,
                vec![LinExpr::var("n"), LinExpr::var("n")],
                Layout::RowMajor,
                true,
            )
            .local_array("t", DType::F32, &[16, 16])
            .insn(
                Access::new("t", vec![LinExpr::var("l1"), LinExpr::var("l0")]),
                Expr::load("a", vec![gid(1, 16), gid(0, 16)]),
                &["g0", "g1", "l0", "l1"],
                &[],
            )
            .insn(
                Access::new("o", vec![gid(1, 16), gid(0, 16)]),
                Expr::load("t", vec![LinExpr::var("l0"), LinExpr::var("l1")]),
                &["g0", "g1", "l0", "l1"],
                &[0],
            )
            .build()
            .unwrap();
        let e = env(&[("n", 64)]);
        let props = extract(&k, &e, ExtractOpts::default()).unwrap();
        let schema = Schema::full();
        let v = props.eval(&schema, &e).unwrap();
        // 16 groups (4x4) · 256 threads · 1 barrier
        assert_eq!(v[schema.index_of(&Prop::Barriers).unwrap()], 16.0 * 256.0);
    }

    #[test]
    fn symbolic_reevaluation_cheap_and_consistent() {
        let k = copy_kernel();
        let e1 = env(&[("n", 1 << 20)]);
        let props = extract(&k, &e1, ExtractOpts::default()).unwrap();
        let schema = Schema::full();
        // re-evaluate the same symbolic counts at other sizes
        for p in [1 << 18, 1 << 19, 1 << 21] {
            let e = env(&[("n", p)]);
            let v = props.eval(&schema, &e).unwrap();
            let idx = schema
                .index_of(&Prop::MemGlobal {
                    bits: 32,
                    dir: Dir::Load,
                    class: StrideClass::Unit,
                })
                .unwrap();
            assert_eq!(v[idx], p as f64);
        }
    }

    #[test]
    fn local_stride_binning_extension() {
        use crate::lpir::builder::gid;
        // transpose-style tile: read t[l0, l1] -> lane stride = gx (conflict)
        let k = KernelBuilder::new("tconf", &["n"])
            .group_dims_2d(LinExpr::var("n"), 16, LinExpr::var("n"), 16)
            .global_array(
                "a",
                DType::F32,
                vec![LinExpr::var("n"), LinExpr::var("n")],
                Layout::RowMajor,
                false,
            )
            .global_array(
                "o",
                DType::F32,
                vec![LinExpr::var("n"), LinExpr::var("n")],
                Layout::RowMajor,
                true,
            )
            .local_array("t", DType::F32, &[16, 16])
            .insn(
                Access::new("t", vec![LinExpr::var("l1"), LinExpr::var("l0")]),
                Expr::load("a", vec![gid(1, 16), gid(0, 16)]),
                &["g0", "g1", "l0", "l1"],
                &[],
            )
            .insn(
                // conflicted read: lane (l0) indexes the major axis
                Access::new("o", vec![gid(1, 16), gid(0, 16)]),
                Expr::load("t", vec![LinExpr::var("l0"), LinExpr::var("l1")]),
                &["g0", "g1", "l0", "l1"],
                &[0],
            )
            .build()
            .unwrap();
        let e = env(&[("n", 64)]);
        let schema = Schema::full();
        // default: everything lands in the plain local-load class
        let base = extract(&k, &e, ExtractOpts::default()).unwrap();
        let v = base.eval(&schema, &e).unwrap();
        assert_eq!(v[schema.index_of(&Prop::LocalLoad { bits: 32 }).unwrap()], 4096.0);
        assert_eq!(
            v[schema.index_of(&Prop::LocalLoadConflict { bits: 32 }).unwrap()],
            0.0
        );
        // extension: the strided read moves to the conflicted class
        let ext = extract(
            &k,
            &e,
            ExtractOpts { bin_local_strides: true, ..Default::default() },
        )
        .unwrap();
        let v = ext.eval(&schema, &e).unwrap();
        assert_eq!(v[schema.index_of(&Prop::LocalLoad { bits: 32 }).unwrap()], 0.0);
        assert_eq!(
            v[schema.index_of(&Prop::LocalLoadConflict { bits: 32 }).unwrap()],
            4096.0
        );
    }

    #[test]
    fn schema_fingerprint_stable_and_layout_sensitive() {
        let a = Schema::full().fingerprint();
        let b = Schema::full().fingerprint();
        assert_eq!(a, b);
        assert_eq!(a.len(), 16);
        // a schema with a different column layout fingerprints differently
        let mut props = Schema::full().props().to_vec();
        props.swap(0, 1);
        let index = props.iter().cloned().enumerate().map(|(i, p)| (p, i)).collect();
        let swapped = Schema { props, index };
        assert_ne!(a, swapped.fingerprint());
    }

    #[test]
    fn collapse_utilization_ablation() {
        let k = KernelBuilder::new("s2", &["n"])
            .group_dims_1d(LinExpr::var("n"), 256)
            .global_array(
                "a",
                DType::F32,
                vec![LinExpr::var("n").scale(2)],
                Layout::RowMajor,
                false,
            )
            .global_array("b", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("b", vec![gid_lin_1d(256)]),
                Expr::load("a", vec![gid_lin_1d(256).scale(2)]),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap();
        let e = env(&[("n", 4096)]);
        let props =
            extract(&k, &e, ExtractOpts { collapse_utilization: true, ..Default::default() }).unwrap();
        // under the ablation, the stride-2 load lands in 2/2
        let found = props.sym().iter().any(|(p, q)| {
            matches!(
                p,
                Prop::MemGlobal {
                    bits: 32,
                    dir: Dir::Load,
                    class: StrideClass::Frac { numer: 2, denom: 2 }
                }
            ) && !q.is_zero()
        });
        assert!(found);
    }

    #[test]
    fn eval_batch_rows_match_scalar_eval_bitwise() {
        let k = copy_kernel();
        let classify = env(&[("n", 1 << 20)]);
        let props = extract(&k, &classify, ExtractOpts::default()).unwrap();
        let schema = Schema::full();
        let envs: Vec<Env> =
            [256i64, 4096, 1 << 16, 1 << 20, 3 * 256].iter().map(|&n| env(&[("n", n)])).collect();
        let refs: Vec<&Env> = envs.iter().collect();
        let mut arena = BatchArena::new();
        let mut out = Vec::new();
        props.eval_batch(&schema, &refs, &mut arena, &mut out).unwrap();
        let m = schema.len();
        assert_eq!(out.len(), refs.len() * m);
        for (j, e) in envs.iter().enumerate() {
            let want = props.eval(&schema, e).unwrap();
            for i in 0..m {
                assert_eq!(
                    out[j * m + i].to_bits(),
                    want[i].to_bits(),
                    "row {j} col {i} ({})",
                    schema.props()[i].label()
                );
            }
        }
        // clones share tapes — and therefore one batch identity
        assert_eq!(props.clone().tape_id(), props.tape_id());
        // an unbound parameter fails the whole batch
        let bad = env(&[("m", 7)]);
        let refs = [&envs[0], &bad];
        assert!(props.eval_batch(&schema, &refs, &mut arena, &mut out).is_err());
    }

    #[test]
    fn props_json_round_trip_evaluates_identically() {
        let k = copy_kernel();
        let e = env(&[("n", 1 << 20)]);
        let props = extract(&k, &e, ExtractOpts::default()).unwrap();
        let wire = props.to_json().compact();
        let back = KernelProps::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.kernel_name, props.kernel_name);
        assert_eq!(back.sym(), props.sym());
        let schema = Schema::full();
        for n in [256i64, 4096, 1 << 20] {
            let b = env(&[("n", n)]);
            let a = props.eval(&schema, &b).unwrap();
            let c = back.eval(&schema, &b).unwrap();
            assert_eq!(a, c, "n={n}");
        }
        // an unknown property label is rejected, not silently dropped
        let j = Json::parse(r#"{"kernel":"x","props":{"no such prop":[]}}"#).unwrap();
        assert!(KernelProps::from_json(&j).is_err());
    }
}
