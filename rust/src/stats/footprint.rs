//! Footprint / utilization-ratio analysis (paper §2.1 and Algorithm 2).
//!
//! For a group of accesses to one (array, direction), the *utilization
//! ratio* is `|accessed cells| / |filled footprint|`, where the filled
//! footprint closes the gaps caused by axis-0 striding. The paper
//! quantizes this ratio into amortized-stride-fraction classes.
//!
//! Exact symbolic image counting (barvinok's polytope image machinery) is
//! replaced by **windowed enumeration**: access patterns of affine maps
//! over rectangular domains are periodic in each iname, so a window that
//! covers a whole number of periods of the pattern yields the exact
//! asymptotic ratio. Every kernel in the paper has a pattern period of at
//! most a few dozen cells, far below the window budget.

use crate::lpir::Kernel;
use crate::qpoly::LinExpr;
use crate::util::intern::{Env, Sym};
use std::collections::BTreeMap;

/// Maximum number of enumerated iname tuples per access group.
const WINDOW_BUDGET: usize = 1 << 14;

/// Count the distinct cells a set of accesses touches (within the
/// enumeration window). Used by the simulator's cache model to estimate
/// per-work-group unique working sets.
///
/// Single accesses with perfectly nested strides (each iname's stride at
/// least the span of the finer inames — true for every tiled/linear
/// access) are counted analytically without enumeration; overlapping
/// patterns (convolution windows) fall back to the windowed enumeration.
pub fn unique_cells(accesses: &[FlatAccess]) -> usize {
    if accesses.len() == 1 {
        if let Some(n) = analytic_unique(&accesses[0]) {
            return n;
        }
    }
    utilization(accesses).accessed_cells
}

/// Exact distinct-cell count for one access when its per-iname strides
/// nest without overlap; `None` when enumeration is required.
fn analytic_unique(acc: &FlatAccess) -> Option<usize> {
    let mut terms: Vec<(i64, i64)> = acc
        .coeffs
        .iter()
        .filter(|(_, &c)| c != 0)
        .map(|(name, &c)| {
            let (trip, step) = acc.ranges.get(name).copied().unwrap_or((1, 1));
            ((c * step).abs(), trip.max(1))
        })
        .collect();
    terms.sort_unstable();
    let mut span: i64 = 1; // extent of the sum-set built so far
    let mut count: i64 = 1;
    for (stride, trip) in terms {
        if stride < span {
            return None; // copies overlap: cannot multiply counts
        }
        count = count.checked_mul(trip)?;
        span = stride
            .checked_mul(trip - 1)
            .and_then(|x| x.checked_add(span))?;
    }
    Some(count as usize)
}

/// One flattened access pattern: the linear (cell-index) expression of an
/// access, plus the iname extents it ranges over.
#[derive(Clone, Debug)]
pub struct FlatAccess {
    /// coefficient of each iname in the flattened cell index
    pub coeffs: BTreeMap<Sym, i64>,
    /// constant offset of the flattened cell index
    pub offset: i64,
    /// iname -> (trip count, step) for inames appearing in `coeffs`
    pub ranges: BTreeMap<Sym, (i64, i64)>,
}

/// Result of the footprint analysis for one access group.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FootprintInfo {
    /// accessed cells / filled footprint, in (0, 1]
    pub utilization: f64,
    /// number of distinct accessed cells within the analysis window
    pub accessed_cells: usize,
    /// size of the filled (gap-closed) footprint within the window
    pub filled_cells: usize,
}

/// Compute the utilization ratio of a set of accesses sharing an array.
///
/// Enumerates a window of iname tuples: each iname's range is capped so
/// the total tuple count stays within budget, preferring to keep
/// small-extent inames complete (they define the pattern period) and
/// truncating large grid inames (which only repeat the pattern).
pub fn utilization(accesses: &[FlatAccess]) -> FootprintInfo {
    // flat Vec + sort + dedup beats a BTreeSet by ~2x on the enumeration
    // hot path (see EXPERIMENTS.md §Perf)
    let mut cells: Vec<i64> = Vec::new();
    for acc in accesses {
        enumerate_access(acc, &mut cells);
    }
    if cells.is_empty() {
        return FootprintInfo { utilization: 1.0, accessed_cells: 0, filled_cells: 0 };
    }
    cells.sort_unstable();
    cells.dedup();
    let lo = cells[0];
    let hi = *cells.last().unwrap();
    let filled = (hi - lo + 1) as usize;
    let accessed = cells.len();
    FootprintInfo {
        utilization: accessed as f64 / filled as f64,
        accessed_cells: accessed,
        filled_cells: filled,
    }
}

fn enumerate_access(acc: &FlatAccess, cells: &mut Vec<i64>) {
    // Order inames by |coeff| ascending: small coefficients define the
    // fine structure of the pattern and must be enumerated fully; large
    // coefficients (grid axes) merely translate the pattern and can be
    // truncated once the budget is exhausted.
    let mut inames: Vec<(Sym, i64)> =
        acc.coeffs.iter().filter(|(_, &c)| c != 0).map(|(n, &c)| (*n, c)).collect();
    // tie-break equal-|coeff| inames by name: Sym ordering is interning
    // order, which would make budget truncation process-history-dependent
    inames.sort_by_key(|(n, c)| (c.abs(), n.as_str()));

    // Decide per-iname enumeration caps within the budget.
    let mut caps: Vec<(Sym, i64, i64, i64)> = Vec::new(); // (name, coeff, cap, step)
    let mut budget = WINDOW_BUDGET as i64;
    for (name, coeff) in inames {
        let (trip, step) = acc.ranges.get(&name).copied().unwrap_or((1, 1));
        let cap = trip.min(budget.max(1));
        caps.push((name, coeff, cap, step));
        budget /= cap.max(1);
        if budget < 1 {
            budget = 1;
        }
    }

    // Recursive enumeration.
    fn rec(caps: &[(Sym, i64, i64, i64)], base: i64, cells: &mut Vec<i64>) {
        match caps.split_first() {
            None => {
                cells.push(base);
            }
            Some(((_, coeff, cap, step), rest)) => {
                for t in 0..*cap {
                    rec(rest, base + coeff * step * t, cells);
                }
            }
        }
    }
    rec(&caps, acc.offset, cells);
}

/// Build a [`FlatAccess`] from an access's index expressions given
/// concrete element strides and a concrete parameter environment.
///
/// `axis_strides` are the element strides of each array axis at the
/// classification binding; iname coefficients across axes accumulate into
/// one flat linear form. Parameter terms inside indices fold into the
/// constant offset.
pub fn flatten_access(
    kernel: &Kernel,
    idx: &[LinExpr],
    axis_strides: &[i64],
    env: &Env,
) -> Result<FlatAccess, String> {
    let mut coeffs: BTreeMap<Sym, i64> = BTreeMap::new();
    let mut offset: i64 = 0;
    for (e, &stride) in idx.iter().zip(axis_strides) {
        offset += e.c * stride;
        for (name, k) in &e.terms {
            if kernel.domain.dim(*name).is_some() {
                *coeffs.entry(*name).or_insert(0) += k * stride;
            } else {
                // a size parameter inside an index folds into the offset
                let v = env
                    .get(*name)
                    .ok_or_else(|| format!("unbound parameter '{name}' in index"))?;
                offset += k * v * stride;
            }
        }
    }
    let mut ranges = BTreeMap::new();
    for name in coeffs.keys() {
        let dim = kernel
            .domain
            .dim(*name)
            .ok_or_else(|| format!("unknown iname '{name}'"))?;
        ranges.insert(*name, (dim.trip_count_at(env)?, dim.step));
    }
    Ok(FlatAccess { coeffs, offset, ranges })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fa(coeffs: &[(&str, i64)], offset: i64, ranges: &[(&str, i64, i64)]) -> FlatAccess {
        FlatAccess {
            coeffs: coeffs.iter().map(|(n, c)| (Sym::intern(n), *c)).collect(),
            offset,
            ranges: ranges.iter().map(|(n, t, s)| (Sym::intern(n), (*t, *s))).collect(),
        }
    }

    #[test]
    fn dense_access_full_utilization() {
        // a[i], i in [0, 1000)
        let info = utilization(&[fa(&[("i", 1)], 0, &[("i", 1000, 1)])]);
        assert_eq!(info.utilization, 1.0);
        assert_eq!(info.accessed_cells, 1000);
    }

    #[test]
    fn stride2_half_utilization() {
        // a[2i], i in [0, 500)
        let info = utilization(&[fa(&[("i", 2)], 0, &[("i", 500, 1)])]);
        assert!((info.utilization - 500.0 / 999.0).abs() < 1e-9);
    }

    #[test]
    fn stride2_both_phases_full() {
        // a[2i] union a[2i+1]
        let a = fa(&[("i", 2)], 0, &[("i", 500, 1)]);
        let b = fa(&[("i", 2)], 1, &[("i", 500, 1)]);
        let info = utilization(&[a, b]);
        assert_eq!(info.utilization, 1.0);
        assert_eq!(info.accessed_cells, 1000);
    }

    #[test]
    fn strided_loop_dim() {
        // loop visits every 3rd point: i ∈ {0,3,6,...}, access a[i]
        // -> cells {0,3,...}: utilization 1/3-ish
        let info = utilization(&[fa(&[("i", 1)], 0, &[("i", 100, 3)])]);
        assert!((info.utilization - 100.0 / 298.0).abs() < 1e-9);
    }

    #[test]
    fn transpose_like_row_access_is_dense_overall() {
        // a[l0*N + l1] over l0,l1 in [0,16): the 16x16 tile is dense in
        // the window because column index fills the gaps... with N=16
        let info = utilization(&[fa(
            &[("l0", 16), ("l1", 1)],
            0,
            &[("l0", 16, 1), ("l1", 16, 1)],
        )]);
        assert_eq!(info.utilization, 1.0);
        assert_eq!(info.accessed_cells, 256);
    }

    #[test]
    fn budget_truncates_large_grids_but_keeps_ratio() {
        // a[2*(256*g + l)] — huge grid; ratio must still come out ~1/2
        let info = utilization(&[fa(
            &[("g", 512), ("l", 2)],
            0,
            &[("g", 1 << 20, 1), ("l", 256, 1)],
        )]);
        assert!((info.utilization - 0.5).abs() < 0.01, "{info:?}");
    }

    #[test]
    fn offset_only_access() {
        let info = utilization(&[fa(&[], 7, &[])]);
        assert_eq!(info.accessed_cells, 1);
        assert_eq!(info.utilization, 1.0);
    }
}

#[cfg(test)]
mod analytic_tests {
    use super::*;
    use crate::util::prop::{gen_usize, quickcheck};

    fn fa2(coeffs: &[(&str, i64)], ranges: &[(&str, i64, i64)]) -> FlatAccess {
        FlatAccess {
            coeffs: coeffs.iter().map(|(n, c)| (Sym::intern(n), *c)).collect(),
            offset: 0,
            ranges: ranges.iter().map(|(n, t, s)| (Sym::intern(n), (*t, *s))).collect(),
        }
    }

    #[test]
    fn analytic_matches_enumeration_for_nested() {
        // tiled access: l0 stride 1 x16, kt stride 16 x8, l1 stride 128 x4
        let f = fa2(
            &[("l0", 1), ("kt", 16), ("l1", 128)],
            &[("l0", 16, 1), ("kt", 8, 1), ("l1", 4, 1)],
        );
        assert_eq!(unique_cells(std::slice::from_ref(&f)), 16 * 8 * 4);
        assert_eq!(utilization(std::slice::from_ref(&f)).accessed_cells, 16 * 8 * 4);
    }

    #[test]
    fn overlapping_falls_back_to_enumeration() {
        // conv-like: two inames with stride 1 overlap
        let f = fa2(&[("x", 1), ("xi", 1)], &[("x", 16, 1), ("xi", 7, 1)]);
        // distinct values of x + xi over [0,16)x[0,7) = [0, 22) -> 22 cells
        assert_eq!(unique_cells(std::slice::from_ref(&f)), 22);
    }

    #[test]
    fn analytic_vs_enumeration_property() {
        quickcheck("analytic_unique_vs_enumeration", |rng| {
            // random nested-or-not patterns with small extents
            let k = gen_usize(rng, 1, 4);
            let mut coeffs = Vec::new();
            let mut ranges = Vec::new();
            let names = ["a", "b", "c"];
            let mut stride = 1i64;
            for name in names.iter().take(k) {
                let trip = rng.range_i64(1, 6);
                coeffs.push((*name, stride));
                ranges.push((*name, trip, 1i64));
                // sometimes nest exactly, sometimes overlap, sometimes gap
                let grow = match rng.range_i64(0, 3) {
                    0 => stride * trip,             // exact nesting
                    1 => (stride * trip) / 2 + 1,   // overlap
                    _ => stride * trip + 3,         // gaps
                };
                stride = grow.max(1);
            }
            let f = fa2(&coeffs, &ranges);
            let fast = unique_cells(std::slice::from_ref(&f));
            let slow = utilization(std::slice::from_ref(&f)).accessed_cells;
            if fast != slow {
                return Err(format!("fast {fast} != slow {slow} for {coeffs:?} {ranges:?}"));
            }
            Ok(())
        });
    }
}
