//! Cross-validation evaluation subsystem: the paper's *predictive* claim
//! tested on genuinely held-out work.
//!
//! The pipeline's Table 1 reports error on the §5 test kernels, but the
//! model is fitted on the measurement suite alone — nothing in the repo
//! tested what happens when kernels the fit *has* seen are held out
//! systematically. Following the cross-machine follow-up work (Stevens &
//! Klöckner, arXiv:1904.09538; Braun et al., arXiv:2001.07104), this
//! module treats the evaluation-kernel zoo ([`crate::kernels::eval_suite`],
//! 9 classes × 4 size cases) as data and evaluates three splits:
//!
//! * **leave-one-kernel-out** — fit on the measurement campaign plus all
//!   zoo cases except one kernel class; predict that class's cases;
//! * **leave-one-size-case-out** — fit on the campaign plus all zoo
//!   cases except one size-case letter (`a`–`d`); predict that letter;
//! * **leave-one-device-out** — fit on one *source* device's campaign
//!   plus its own zoo, then predict every **other** device's held-out
//!   zoo timings with those weights (the property vectors are
//!   hardware-independent; only the weights carry the device), yielding
//!   a device×device transfer-error matrix
//!   ([`crate::report::TransferMatrix`]).
//!
//! The measurement→fit machinery is the shared engine core
//! ([`crate::engine::Engine`]): per device the campaign and the zoo
//! measurements run **once** ([`Engine::measure_fold_ctx`], parallel
//! over devices), then every fold — (device × fold) for the per-device
//! splits, (source × target) for the transfer split — is an engine job
//! ([`Engine::fold_training_matrix`] + [`Engine::fit_fold_model`])
//! fanned out on [`crate::util::executor::par_map`]. This module only
//! owns the *split semantics* (which cases a fold holds out) and the
//! reporting. Results are collected into a [`crate::report::Table1`] of
//! held-out predictions and rendered by
//! [`crate::report::render_crossval`] / [`crate::report::render_transfer`].
//! Every fold also retains its fitted weight table, persisted in the
//! crossval JSON output for weight-drift analysis across PRs.

use crate::coordinator::Config;
use crate::engine::{Engine, FoldCtx, ZooCase};
use crate::obs::span::{self, Span};
use crate::report::{render_crossval, render_transfer, Table1, Table1Entry, TransferMatrix};
use crate::util::executor::par_map;
use crate::util::json::Json;
use crate::util::linalg::geometric_mean;
use std::fmt::Write as _;

/// Which hold-out scheme to evaluate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    /// hold out one kernel class per fold (9 folds per device)
    LeaveOneKernelOut,
    /// hold out one size-case letter per fold (4 folds per device)
    LeaveOneSizeCaseOut,
    /// one fold per *source* device: fit there, predict every other
    /// device's zoo (cross-device transfer)
    LeaveOneDeviceOut,
}

impl Split {
    /// Human-readable name for reports.
    pub fn label(&self) -> &'static str {
        match self {
            Split::LeaveOneKernelOut => "leave-one-kernel-out",
            Split::LeaveOneSizeCaseOut => "leave-one-size-case-out",
            Split::LeaveOneDeviceOut => "leave-one-device-out",
        }
    }

    /// The fold key of a zoo case under the per-device splits (the
    /// device split keys folds by device, not by case).
    fn key<'a>(&self, kernel: &'a str, case: &'a str) -> &'a str {
        match self {
            Split::LeaveOneKernelOut | Split::LeaveOneDeviceOut => kernel,
            Split::LeaveOneSizeCaseOut => case,
        }
    }
}

/// Cross-validation options on top of the pipeline [`Config`] (devices,
/// protocol, fit backend, extraction options, worker count).
#[derive(Clone, Debug)]
pub struct CrossvalOpts {
    pub base: Config,
    pub split: Split,
    /// smoke mode: cut the campaign down to the classes that still cover
    /// every property family the zoo exercises, and keep only the `a`/`b`
    /// size cases of the zoo
    pub quick: bool,
}

impl Default for CrossvalOpts {
    fn default() -> Self {
        CrossvalOpts {
            base: Config::default(),
            split: Split::LeaveOneKernelOut,
            quick: false,
        }
    }
}

/// Outcome of one fold's fit: a (device, held-out key) pair for the
/// per-device splits, or a source device for the transfer split.
#[derive(Clone, Debug)]
pub struct FoldResult {
    /// device the fold's weights were fitted on
    pub device: String,
    /// held-out kernel name, size-case letter, or source device name
    pub fold: String,
    /// training cases (campaign + retained zoo cases)
    pub n_train: usize,
    /// training-set geomean relative error of the fold's model
    pub train_err: f64,
    /// the fold's fitted weight table (property label → weight), kept
    /// for weight-drift analysis across PRs
    pub weights: Vec<(String, f64)>,
    /// held-out predictions
    pub entries: Vec<Table1Entry>,
}

impl FoldResult {
    /// Geomean relative error over this fold's held-out cases.
    pub fn heldout_err(&self) -> f64 {
        let errs: Vec<f64> = self.entries.iter().map(Table1Entry::rel_err).collect();
        geometric_mean(&errs)
    }

    /// JSON form: fold identity, errors and the fitted weight table.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("device", Json::Str(self.device.clone())),
            ("fold", Json::Str(self.fold.clone())),
            ("n_train", Json::Num(self.n_train as f64)),
            ("train_err", Json::Num(self.train_err)),
            ("heldout_err", Json::Num(self.heldout_err())),
            (
                "weights",
                Json::Arr(
                    self.weights
                        .iter()
                        .map(|(label, w)| {
                            Json::obj(vec![
                                ("prop", Json::Str(label.clone())),
                                ("weight", Json::Num(*w)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Full cross-validation output.
#[derive(Debug)]
pub struct CrossvalResult {
    pub split: Split,
    pub folds: Vec<FoldResult>,
    /// all held-out predictions, Table-1 shaped
    pub table: Table1,
    /// the device×device matrix (present for the device split only)
    pub transfer: Option<TransferMatrix>,
}

impl CrossvalResult {
    /// Overall held-out geomean relative error across kernels and devices.
    pub fn overall_err(&self) -> f64 {
        self.table.overall_err()
    }

    /// Held-out geomean relative error for one device.
    pub fn device_err(&self, device: &str) -> f64 {
        self.table.device_err(device)
    }

    /// Render the held-out error report — the Table-1-style matrix (or
    /// the transfer matrix for the device split) — plus per-fold
    /// diagnostics.
    pub fn render(&self) -> String {
        let mut s = match &self.transfer {
            Some(tm) => render_transfer(tm),
            None => render_crossval(self.split.label(), &self.table),
        };
        s.push('\n');
        s.push_str("fold        device      train  train-gm  heldout-gm\n");
        for f in &self.folds {
            let _ = writeln!(
                s,
                "{:<12}{:<12}{:>5} {:>9.3} {:>11.3}",
                f.fold,
                f.device,
                f.n_train,
                f.train_err,
                f.heldout_err()
            );
        }
        s
    }

    /// JSON form: split, per-fold weight tables (the drift-analysis
    /// record persisted into `BENCH_crossval.json` /
    /// `BENCH_transfer.json` and the results directory), and the
    /// transfer matrix when present.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("split", Json::Str(self.split.label().to_string())),
            ("overall_err", Json::Num(self.overall_err())),
            (
                "folds",
                Json::Arr(self.folds.iter().map(FoldResult::to_json).collect()),
            ),
        ];
        if let Some(tm) = &self.transfer {
            pairs.push(("transfer", tm.to_json()));
        }
        Json::obj(pairs)
    }
}

/// Cut-down campaign filter for quick mode: the retained classes keep
/// every property family that the *full* §4.1 suite covers and the
/// evaluation zoo exercises — unit, strided and uniform global traffic
/// (`sg_*`, `vsadd`), local-memory staging with barriers
/// (`transpose_tiled`), uncoalesced classes (`transpose_cw`/`cr`),
/// every float-op kind including the n-body kernel's rsqrt (`arith_*`),
/// and the launch-overhead columns (`empty`). The uniform-class global
/// *store* gap the ROADMAP used to name is closed: `sg_storeuni`
/// matches the `sg_` prefix, so even the quick campaign exercises the
/// column reduce_tree's per-group result store needs.
/// Public so tests exercising "the quick campaign" reuse this exact
/// predicate instead of a drifting copy.
pub fn quick_campaign_case(label: &str) -> bool {
    label.starts_with("sg_")
        || label.starts_with("vsadd")
        || label.starts_with("transpose")
        || label.starts_with("arith_")
        || label.starts_with("empty/")
}

/// Quick-mode zoo filter: keep the `a` and `b` size cases.
fn quick_zoo_case(label: &str) -> bool {
    let mut parts = label.split('/');
    let _ = parts.next();
    matches!(parts.next(), Some("a") | Some("b"))
}

/// Fit and evaluate one fold on one device: train on the campaign plus
/// every zoo case outside the fold, predict the held-out cases. The
/// training-matrix assembly (incl. the §4.2 floor rule) and the fit are
/// engine jobs; this function owns the split's hold-out semantics.
fn run_fold(
    engine: &Engine,
    ctx: &FoldCtx,
    fold: &str,
    split: Split,
) -> Result<FoldResult, String> {
    let mut sp = Span::child("crossval.fold");
    if span::enabled() {
        sp.set_meta(format!("device={} fold={fold}", ctx.device));
    }
    let held: Vec<&ZooCase> = ctx
        .zoo
        .iter()
        .filter(|z| split.key(&z.kernel, &z.case) == fold)
        .collect();
    if held.is_empty() {
        return Err(format!("fold '{fold}' holds out no cases on {}", ctx.device));
    }
    let pm =
        engine.fold_training_matrix(ctx, &|z| split.key(&z.kernel, &z.case) != fold);
    let model = engine.fit_fold_model(ctx, &pm)?;
    let entries = held
        .iter()
        .map(|z| Table1Entry {
            device: ctx.device.clone(),
            kernel: z.kernel.clone(),
            case: z.case.clone(),
            predicted_s: model.predict(&z.props),
            actual_s: z.time_s,
        })
        .collect();
    Ok(FoldResult {
        device: ctx.device.clone(),
        fold: fold.to_string(),
        n_train: pm.n_cases(),
        train_err: model.train_rel_err_geomean,
        weights: model.weight_report(engine.schema()),
        entries,
    })
}

/// One transfer fold: fit on the source device's campaign plus its own
/// zoo, then predict every *other* device's zoo cases with the source
/// weights. The targets' zoo timings are genuinely held out — the
/// source model has never seen that hardware.
fn run_transfer_fold(
    engine: &Engine,
    contexts: &[FoldCtx],
    si: usize,
) -> Result<FoldResult, String> {
    let src = &contexts[si];
    let mut sp = Span::child("crossval.fold");
    if span::enabled() {
        sp.set_meta(format!("device={} fold=transfer", src.device));
    }
    let pm = engine.fold_training_matrix(src, &|_| true);
    let model = engine.fit_fold_model(src, &pm)?;
    let mut entries = Vec::new();
    for (ti, tgt) in contexts.iter().enumerate() {
        if ti == si {
            continue;
        }
        for z in &tgt.zoo {
            entries.push(Table1Entry {
                device: tgt.device.clone(),
                kernel: z.kernel.clone(),
                case: z.case.clone(),
                predicted_s: model.predict(&z.props),
                actual_s: z.time_s,
            });
        }
    }
    if entries.is_empty() {
        return Err(format!("transfer fold '{}' has no target cases", src.device));
    }
    Ok(FoldResult {
        device: src.device.clone(),
        fold: src.device.clone(),
        n_train: pm.n_cases(),
        train_err: model.train_rel_err_geomean,
        weights: model.weight_report(engine.schema()),
        entries,
    })
}

/// Run cross-validation over all configured devices (resolved through
/// the [`Config`]'s device registry, so JSON-loaded profiles
/// participate).
///
/// Stage 1 measures each device once on the shared engine (parallel
/// over devices); stage 2 fans the (device × fold) — or, for the
/// device split, per-source — fit/predict jobs out over the worker
/// pool. Job order — and therefore the assembled table and transfer
/// matrix — is deterministic: `par_map` preserves input order
/// regardless of scheduling.
pub fn run_crossval(opts: &CrossvalOpts) -> Result<CrossvalResult, String> {
    let cfg = &opts.base;
    if cfg.devices.is_empty() {
        return Err("no devices configured".into());
    }
    if opts.split == Split::LeaveOneDeviceOut && cfg.devices.len() < 2 {
        return Err("leave-one-device-out needs at least two devices".into());
    }
    let engine = Engine::new(cfg.clone());

    let mut profiles = Vec::with_capacity(cfg.devices.len());
    for name in &cfg.devices {
        profiles.push(engine.profile(name)?.clone());
    }

    let keep_all = |_: &str| true;
    let campaign_keep: &(dyn Fn(&str) -> bool + Sync) =
        if opts.quick { &quick_campaign_case } else { &keep_all };
    let zoo_keep: &(dyn Fn(&str) -> bool + Sync) =
        if opts.quick { &quick_zoo_case } else { &keep_all };
    // Flat scheduling: both the per-device fan-out and each device's
    // per-case timing fan-out request the full worker budget. Every
    // ticket drains the one process-wide executor queue
    // ([`crate::util::executor`]), so the (device, fold, case) work
    // flattens itself — inner case tickets fill whatever slots the
    // device level leaves idle — instead of the old static
    // device_workers × inner_workers split that oversubscribed wide
    // registries and starved narrow ones. Output order (and therefore
    // every assembled table) is still input order: `par_map` collects
    // by index regardless of scheduling.
    let workers = cfg.workers.max(1);
    let mut measure_span = Span::child("crossval.measure");
    if span::enabled() {
        measure_span.set_meta(format!("devices={}", cfg.devices.len()));
    }
    let ctxs = par_map(profiles, workers, |p| {
        engine.measure_fold_ctx(&p, campaign_keep, zoo_keep, workers)
    });
    drop(measure_span);
    let mut contexts = Vec::with_capacity(ctxs.len());
    for c in ctxs {
        contexts.push(c?);
    }

    let results = if opts.split == Split::LeaveOneDeviceOut {
        // one fold per source device, each predicting all other devices
        let sources: Vec<usize> = (0..contexts.len()).collect();
        par_map(sources, workers, |si| {
            run_transfer_fold(&engine, &contexts, si)
        })
    } else {
        // fold keys per device, in first-seen (suite) order
        let mut jobs: Vec<(usize, String)> = Vec::new();
        for (di, ctx) in contexts.iter().enumerate() {
            let mut keys: Vec<&str> = Vec::new();
            for z in &ctx.zoo {
                let key = opts.split.key(&z.kernel, &z.case);
                if !keys.contains(&key) {
                    keys.push(key);
                }
            }
            for key in keys {
                jobs.push((di, key.to_string()));
            }
        }
        par_map(jobs, workers, |(di, fold)| {
            run_fold(&engine, &contexts[di], &fold, opts.split)
        })
    };
    let mut folds = Vec::with_capacity(results.len());
    for r in results {
        folds.push(r?);
    }

    let mut table = Table1::default();
    for f in &folds {
        for e in &f.entries {
            table.push(e.clone());
        }
    }
    let transfer = if opts.split == Split::LeaveOneDeviceOut {
        let devices: Vec<String> = contexts.iter().map(|c| c.device.clone()).collect();
        let n = devices.len();
        let mut err = vec![vec![None; n]; n];
        for (si, f) in folds.iter().enumerate() {
            for (ti, d) in devices.iter().enumerate() {
                if ti == si {
                    continue;
                }
                let errs: Vec<f64> = f
                    .entries
                    .iter()
                    .filter(|e| &e.device == d)
                    .map(Table1Entry::rel_err)
                    .collect();
                err[si][ti] = Some(geometric_mean(&errs));
            }
        }
        Some(TransferMatrix { devices, err })
    } else {
        None
    };
    let result = CrossvalResult { split: opts.split, folds, table, transfer };
    if let Some(dir) = &cfg.out_dir {
        std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        let stem = match opts.split {
            Split::LeaveOneKernelOut => "crossval_kernel",
            Split::LeaveOneSizeCaseOut => "crossval_case",
            Split::LeaveOneDeviceOut => "crossval_device",
        };
        std::fs::write(dir.join(format!("{stem}.txt")), result.render())
            .map_err(|e| e.to_string())?;
        // fold weight tables (+ transfer matrix) for drift analysis
        std::fs::write(dir.join(format!("{stem}.json")), result.to_json().pretty())
            .map_err(|e| e.to_string())?;
    }
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::FitBackend;

    #[test]
    fn split_keys_and_labels() {
        assert_eq!(Split::LeaveOneKernelOut.key("fd5", "a"), "fd5");
        assert_eq!(Split::LeaveOneSizeCaseOut.key("fd5", "a"), "a");
        assert!(Split::LeaveOneKernelOut.label().contains("kernel"));
        assert!(Split::LeaveOneSizeCaseOut.label().contains("size-case"));
        assert!(Split::LeaveOneDeviceOut.label().contains("device"));
    }

    #[test]
    fn device_split_needs_two_devices() {
        let opts = CrossvalOpts {
            base: Config { devices: vec!["k40c".into()], ..Config::default() },
            split: Split::LeaveOneDeviceOut,
            quick: true,
        };
        let e = run_crossval(&opts).unwrap_err();
        assert!(e.contains("two devices"), "{e}");
    }

    #[test]
    fn quick_filters_keep_coverage_classes() {
        assert!(quick_campaign_case("sg_copy/t=0/n=4096/g=256"));
        assert!(quick_campaign_case("vsadd/s=2/t=1/n=65536/g=256"));
        assert!(quick_campaign_case("transpose_tiled/n=1024/g=16x16"));
        // rsqrt coverage: without arith_* the nbody LOKO fold would fit
        // the Special-op column as all-zero
        assert!(quick_campaign_case("arith_rsqrt/n=256/k=256/g=16x16"));
        assert!(quick_campaign_case("empty/n=512/g=16x16"));
        assert!(!quick_campaign_case("mm_tiled/square/b=256/g=16x16"));
        assert!(quick_zoo_case("reduce_tree/a/n=2097152"));
        assert!(quick_zoo_case("bmm8/b/nb=32768"));
        assert!(!quick_zoo_case("st3d7/c/n=256"));
    }

    #[test]
    fn no_devices_is_an_error() {
        let opts = CrossvalOpts {
            base: Config { devices: Vec::new(), ..Config::default() },
            ..CrossvalOpts::default()
        };
        assert!(run_crossval(&opts).is_err());
    }

    /// One-device leave-one-size-case-out smoke (the cheapest end-to-end
    /// path: quick campaign, zoo cases a/b, 2 folds). The heavier
    /// multi-device runs live in `rust/tests/crossval.rs`, and the
    /// engine-vs-hand-assembled parity pin in `rust/tests/engine.rs`.
    #[test]
    fn quick_loso_single_device() {
        let opts = CrossvalOpts {
            base: Config {
                devices: vec!["k40c".into()],
                backend: FitBackend::Native,
                ..Config::default()
            },
            split: Split::LeaveOneSizeCaseOut,
            quick: true,
        };
        let r = run_crossval(&opts).unwrap();
        assert_eq!(r.folds.len(), 2); // letters a and b
        for f in &r.folds {
            assert_eq!(f.entries.len(), 9, "fold {}", f.fold);
            for e in &f.entries {
                assert_eq!(e.case, f.fold);
                assert!(e.predicted_s.is_finite(), "{}/{}", e.kernel, e.case);
                assert!(e.actual_s > 0.0);
            }
        }
        assert!(r.overall_err().is_finite());
        let rendered = r.render();
        assert!(rendered.contains("reduce_tree") && rendered.contains("overall"));
    }
}
