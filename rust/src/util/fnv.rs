//! FNV-1a 64-bit hashing, as a tiny incremental writer.
//!
//! Used wherever the repo needs a *stable, process-independent* digest
//! of structured data: the service layer's structural kernel hash
//! ([`crate::service::hash`]), the model-artifact fingerprints
//! ([`crate::service::store`]) and the property-schema fingerprint
//! ([`crate::stats::Schema::fingerprint`]). `std::hash::Hasher`
//! implementations (SipHash) are randomly keyed per process and so
//! cannot be persisted; FNV-1a over an explicit byte encoding can.

/// Incremental FNV-1a 64-bit hasher.
#[derive(Clone, Copy, Debug)]
pub struct Fnv64(u64);

const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const PRIME: u64 = 0x100_0000_01b3;

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64(OFFSET)
    }
}

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64::default()
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(PRIME);
        }
        self
    }

    /// Hash a string *with* its length prefix, so consecutive strings
    /// cannot alias ("ab","c" vs "a","bc").
    pub fn write_str(&mut self, s: &str) -> &mut Self {
        self.write_u64(s.len() as u64);
        self.write_bytes(s.as_bytes())
    }

    pub fn write_u64(&mut self, x: u64) -> &mut Self {
        self.write_bytes(&x.to_le_bytes())
    }

    pub fn write_i64(&mut self, x: i64) -> &mut Self {
        self.write_bytes(&x.to_le_bytes())
    }

    pub fn write_u8(&mut self, x: u8) -> &mut Self {
        self.write_bytes(&[x])
    }

    /// Hash an `f64` by bit pattern (exact, round-trip stable).
    pub fn write_f64(&mut self, x: f64) -> &mut Self {
        self.write_u64(x.to_bits())
    }

    pub fn finish(&self) -> u64 {
        self.0
    }

    /// The digest as fixed-width lowercase hex (fingerprint form).
    pub fn hex(&self) -> String {
        format!("{:016x}", self.0)
    }
}

/// One-shot digest of a byte slice.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.write_bytes(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // standard FNV-1a test vectors
        assert_eq!(fnv64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn length_prefix_prevents_aliasing() {
        let mut a = Fnv64::new();
        a.write_str("ab").write_str("c");
        let mut b = Fnv64::new();
        b.write_str("a").write_str("bc");
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn hex_is_fixed_width() {
        assert_eq!(Fnv64::new().hex().len(), 16);
        let mut h = Fnv64::new();
        h.write_u64(7);
        assert_eq!(h.hex().len(), 16);
    }

    #[test]
    fn f64_bit_exact() {
        let mut a = Fnv64::new();
        a.write_f64(0.1 + 0.2);
        let mut b = Fnv64::new();
        b.write_f64(0.3);
        assert_ne!(a.finish(), b.finish());
    }
}
