//! Fixed-size thread pool with a scoped parallel `map` (offline stand-in
//! for `tokio`/`rayon`). The coordinator's workload — running measurement
//! campaigns across simulated devices — is CPU-bound fan-out, which maps
//! cleanly onto scoped threads and channels.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Run `f` over `items` with up to `workers` OS threads, preserving input
/// order in the output. Uses `std::thread::scope`, so `f` may borrow from
/// the caller.
pub fn par_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }
    let queue = Arc::new(Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>()));
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            let f = &f;
            scope.spawn(move || loop {
                let next = queue.lock().unwrap().pop();
                match next {
                    Some((i, item)) => {
                        let r = f(item);
                        if tx.send((i, r)).is_err() {
                            return;
                        }
                    }
                    None => return,
                }
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("worker died before producing result")).collect()
    })
}

/// Default worker count: one per available core, at least 1.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = par_map((0..100).collect::<Vec<i64>>(), 8, |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<i64>>());
    }

    #[test]
    fn single_worker_and_empty() {
        assert_eq!(par_map(vec![1, 2, 3], 1, |x| x + 1), vec![2, 3, 4]);
        assert_eq!(par_map(Vec::<i32>::new(), 4, |x| x), Vec::<i32>::new());
    }

    #[test]
    fn can_borrow_environment() {
        let base = 10;
        let out = par_map(vec![1, 2, 3], 3, |x| x + base);
        assert_eq!(out, vec![11, 12, 13]);
    }

    #[test]
    fn more_workers_than_items() {
        let out = par_map(vec![5], 16, |x| x * 2);
        assert_eq!(out, vec![10]);
    }
}
