//! Parallel execution primitives (offline stand-in for `tokio`/`rayon`).
//!
//! Three layers:
//!
//! * [`Executor`] — a process-wide shared pool of long-lived worker
//!   threads pulling from **one** flat job queue. Batch fan-outs
//!   anywhere in the process (fit, crossval, transfer, per-case
//!   measurement) all land in this single queue, so nested fan-outs
//!   compose without per-call thread spawning or multiplicative
//!   oversubscription: a worker blocked on an inner batch is
//!   complemented by the inner caller executing its own tickets inline,
//!   which guarantees progress even when every pooled thread is busy.
//! * [`par_map`] — order-preserving parallel map over a vector,
//!   dispatched as claim-tickets on the shared executor. Work is
//!   claimed by a single shared atomic cursor over item slots: each
//!   ticket claims the next index with `fetch_add` and takes the item
//!   out of its slot, which removes all lock contention from dispatch
//!   and processes items front-to-back.
//! * [`WorkerPool`] — a dedicated fixed pool with one shared handler
//!   closure, for callers (the event-driven serving reactor) that
//!   submit work continuously instead of in one batch and need
//!   deterministic drain-on-join semantics.

use crate::obs::span::{self, Span};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError};

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Hard ceiling on shared-executor threads, far above any sane
/// `--workers`; the pool only ever grows to the largest single request.
const EXEC_MAX_THREADS: usize = 256;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct QJob {
    batch: u64,
    job: Job,
}

/// Per-batch completion accounting for [`Executor::run_tickets`].
struct Ctl {
    finished: Mutex<usize>,
    cv: Condvar,
    panicked: AtomicBool,
}

/// The process-wide shared executor: one flat queue, lazily-grown
/// workers, no per-call thread spawning. Obtain via [`Executor::global`].
pub struct Executor {
    queue: Mutex<VecDeque<QJob>>,
    available: Condvar,
    threads: Mutex<usize>,
}

static NEXT_BATCH: AtomicU64 = AtomicU64::new(1);

impl Executor {
    pub fn global() -> &'static Executor {
        static EXEC: OnceLock<Executor> = OnceLock::new();
        EXEC.get_or_init(|| Executor {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            threads: Mutex::new(0),
        })
    }

    /// Worker threads currently in the pool.
    pub fn threads(&self) -> usize {
        *lock(&self.threads)
    }

    /// Submit a detached fire-and-forget job.
    pub fn submit(&self, job: Job) {
        self.ensure_workers(1);
        self.push(0, job);
    }

    /// Run `ticket` on up to `extra` pooled threads concurrently with
    /// the caller, which always runs it once inline (guaranteeing
    /// progress even when the pool is saturated by blocked outer
    /// batches). Returns once every *started* ticket has finished;
    /// tickets still queued when the inline run completes are withdrawn
    /// unexecuted. Panics if any ticket panicked.
    pub fn run_tickets<F: Fn() + Sync>(&self, extra: usize, ticket: &F) {
        if extra == 0 {
            ticket();
            return;
        }
        self.ensure_workers(extra);
        let batch = NEXT_BATCH.fetch_add(1, Ordering::Relaxed);
        let ctl = Arc::new(Ctl {
            finished: Mutex::new(0),
            cv: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        let tref: &(dyn Fn() + Sync) = ticket;
        // SAFETY: every submitted ticket either runs to completion
        // before `WaitGuard` drops (the guard blocks on the finished
        // count, including during unwind) or is withdrawn from the
        // queue unexecuted, so the erased borrow never outlives this
        // frame.
        let tref: &'static (dyn Fn() + Sync) = unsafe { std::mem::transmute(tref) };
        for _ in 0..extra {
            let ctl = Arc::clone(&ctl);
            let job: Job = Box::new(move || {
                if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| tref()))
                    .is_err()
                {
                    ctl.panicked.store(true, Ordering::SeqCst);
                }
                let mut done = lock(&ctl.finished);
                *done += 1;
                drop(done);
                ctl.cv.notify_all();
            });
            self.push(batch, job);
        }
        let guard = WaitGuard { exec: self, batch, submitted: extra, ctl: &ctl };
        ticket();
        drop(guard);
        if ctl.panicked.load(Ordering::SeqCst) {
            panic!("executor ticket panicked");
        }
    }

    fn push(&self, batch: u64, job: Job) {
        let mut q = lock(&self.queue);
        q.push_back(QJob { batch, job });
        drop(q);
        self.available.notify_one();
    }

    /// Remove all still-queued jobs of one batch; returns how many.
    fn withdraw(&self, batch: u64) -> usize {
        let mut q = lock(&self.queue);
        let before = q.len();
        q.retain(|j| j.batch != batch);
        before - q.len()
    }

    /// Grow the pool to at least `want` threads (bounded; spawn failure
    /// degrades gracefully — the inline ticket still makes progress).
    fn ensure_workers(&self, want: usize) {
        let want = want.min(EXEC_MAX_THREADS);
        let mut t = lock(&self.threads);
        while *t < want {
            let spawned = std::thread::Builder::new()
                .name("uniperf-exec".into())
                .spawn(|| Executor::global().worker_loop());
            if spawned.is_err() {
                break;
            }
            *t += 1;
        }
    }

    fn worker_loop(&self) {
        loop {
            let qjob = {
                let mut q = lock(&self.queue);
                loop {
                    if let Some(j) = q.pop_front() {
                        break j;
                    }
                    q = self
                        .available
                        .wait(q)
                        .unwrap_or_else(PoisonError::into_inner);
                }
            };
            // ticket wrappers catch their own panics; a raw detached job
            // panicking must not kill the pooled worker either
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(qjob.job));
        }
    }
}

/// Blocks (even on unwind) until every started ticket of a batch has
/// finished, after withdrawing the unstarted ones — the linchpin of the
/// lifetime-erasure safety argument in [`Executor::run_tickets`].
struct WaitGuard<'x> {
    exec: &'x Executor,
    batch: u64,
    submitted: usize,
    ctl: &'x Arc<Ctl>,
}

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        let withdrawn = self.exec.withdraw(self.batch);
        let target = self.submitted - withdrawn;
        let mut done = lock(&self.ctl.finished);
        while *done < target {
            done = self
                .ctl
                .cv
                .wait(done)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }
}

/// Run `f` over `items` with up to `workers` concurrent claim-tickets on
/// the shared executor, preserving input order in the output. `f` may
/// borrow from the caller. The worker count is clamped to the item
/// count, so small batches never pay for idle tickets.
pub fn par_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let out: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let ticket = || {
        // one root span per ticket: its duration against the items it
        // claimed is the utilization signal the trace export surfaces
        // (inert when tracing is off)
        let mut sp = Span::root("par_map.worker");
        let mut claimed = 0usize;
        loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            let item = lock(&slots[i]).take().expect("work item claimed twice");
            let r = f(item);
            *lock(&out[i]) = Some(r);
            claimed += 1;
        }
        if span::enabled() {
            sp.set_meta(format!("items={claimed}"));
        }
    };
    Executor::global().run_tickets(workers - 1, &ticket);
    out.into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap_or_else(PoisonError::into_inner)
                .expect("worker died before producing result")
        })
        .collect()
}

/// Default worker count: one per available core, at least 1.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// A fixed pool of long-lived worker threads pulling jobs off one
/// shared queue — the persistent complement to [`par_map`]'s batch
/// fan-out, for callers (the event-driven serving reactor) that submit
/// work continuously instead of in one batch.
///
/// Jobs are handled by one shared closure; results travel through
/// whatever channel the closure captures. [`WorkerPool::join`] is
/// deterministic: already-queued jobs are drained before the workers
/// exit, so a caller that stops submitting and then joins has seen
/// every job handled.
pub struct WorkerPool<J: Send + 'static> {
    shared: Arc<PoolShared<J>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

struct PoolShared<J> {
    queue: Mutex<VecDeque<J>>,
    available: Condvar,
    stop: AtomicBool,
}

impl<J: Send + 'static> WorkerPool<J> {
    /// Spawn `workers` (at least 1) threads, each running `handle` over
    /// jobs claimed from the shared queue.
    pub fn new<F>(workers: usize, handle: F) -> WorkerPool<J>
    where
        F: Fn(J) + Send + Sync + 'static,
    {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            stop: AtomicBool::new(false),
        });
        let handle = Arc::new(handle);
        let handles = (0..workers.max(1))
            .map(|_| {
                let shared = Arc::clone(&shared);
                let handle = Arc::clone(&handle);
                std::thread::spawn(move || worker_loop(&shared, &*handle))
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Enqueue one job; a parked worker wakes to claim it.
    pub fn submit(&self, job: J) {
        let mut q = lock(&self.shared.queue);
        q.push_back(job);
        drop(q);
        self.shared.available.notify_one();
    }

    /// Jobs submitted but not yet claimed by a worker.
    pub fn queued(&self) -> usize {
        lock(&self.shared.queue).len()
    }

    /// Drain the queue and stop: workers finish every job already
    /// submitted, then exit; returns once all of them have been joined.
    pub fn join(self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn worker_loop<J: Send>(shared: &PoolShared<J>, handle: &(dyn Fn(J) + Sync)) {
    loop {
        let job = {
            let mut q = lock(&shared.queue);
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if shared.stop.load(Ordering::SeqCst) {
                    break None;
                }
                q = shared
                    .available
                    .wait(q)
                    .unwrap_or_else(PoisonError::into_inner);
            }
        };
        match job {
            Some(j) => handle(j),
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn preserves_order() {
        let out = par_map((0..100).collect::<Vec<i64>>(), 8, |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<i64>>());
    }

    #[test]
    fn single_worker_and_empty() {
        assert_eq!(par_map(vec![1, 2, 3], 1, |x| x + 1), vec![2, 3, 4]);
        assert_eq!(par_map(Vec::<i32>::new(), 4, |x| x), Vec::<i32>::new());
    }

    #[test]
    fn can_borrow_environment() {
        let base = 10;
        let out = par_map(vec![1, 2, 3], 3, |x| x + base);
        assert_eq!(out, vec![11, 12, 13]);
    }

    #[test]
    fn more_workers_than_items() {
        let out = par_map(vec![5], 16, |x| x * 2);
        assert_eq!(out, vec![10]);
    }

    /// Regression for the worker-count clamp: a batch of k items must
    /// execute on at most k distinct threads no matter how many workers
    /// the caller asks for — small folds never pay idle spawn/dispatch.
    #[test]
    fn small_batches_use_at_most_item_count_threads() {
        use std::collections::HashSet;
        let ids = Mutex::new(HashSet::new());
        let _ = par_map(vec![1, 2, 3], 64, |x: i32| {
            ids.lock().unwrap().insert(std::thread::current().id());
            std::thread::sleep(std::time::Duration::from_millis(2));
            x
        });
        let distinct = ids.lock().unwrap().len();
        assert!(distinct <= 3, "3 items ran on {distinct} threads");
    }

    /// Nested fan-outs share the flat executor queue: inner maps run on
    /// the same pool while the outer caller helps inline, with no
    /// deadlock and order preserved at both levels.
    #[test]
    fn nested_fanout_shares_the_pool_and_preserves_order() {
        let out = par_map((0..8i64).collect::<Vec<_>>(), 4, |d| {
            par_map((0..16i64).collect::<Vec<_>>(), 4, |c| d * 100 + c)
        });
        assert_eq!(out.len(), 8);
        for (d, inner) in out.iter().enumerate() {
            let want: Vec<i64> = (0..16).map(|c| d as i64 * 100 + c).collect();
            assert_eq!(inner, &want, "device {d}");
        }
        // the shared pool stayed bounded instead of spawning per call
        assert!(Executor::global().threads() <= EXEC_MAX_THREADS);
    }

    #[test]
    fn panicking_job_propagates_to_caller_without_hanging() {
        let r = std::panic::catch_unwind(|| {
            par_map(vec![0i32, 1, 2, 3], 3, |x| {
                if x == 1 {
                    panic!("hostile item");
                }
                x
            })
        });
        assert!(r.is_err(), "item panic must propagate");
        // and the executor remains usable afterwards
        assert_eq!(par_map(vec![1, 2, 3], 3, |x| x * 2), vec![2, 4, 6]);
    }

    #[test]
    fn stress_many_items_many_workers() {
        // far more items than workers, and far more workers than cores:
        // every item must run exactly once and land at its own index.
        let n = 10_000usize;
        let executions = AtomicUsize::new(0);
        let out = par_map((0..n as i64).collect::<Vec<i64>>(), 32, |x| {
            executions.fetch_add(1, Ordering::Relaxed);
            // a little work so workers genuinely interleave
            let mut acc = x;
            for i in 0..50 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            (x, acc)
        });
        assert_eq!(executions.load(Ordering::Relaxed), n);
        assert_eq!(out.len(), n);
        for (i, (x, _)) in out.iter().enumerate() {
            assert_eq!(*x, i as i64, "result out of order at {i}");
        }
    }

    #[test]
    fn detached_submit_runs() {
        let done = Arc::new(AtomicUsize::new(0));
        let d = Arc::clone(&done);
        Executor::global().submit(Box::new(move || {
            d.fetch_add(1, Ordering::SeqCst);
        }));
        for _ in 0..500 {
            if done.load(Ordering::SeqCst) == 1 {
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        panic!("detached job never ran");
    }

    #[test]
    fn worker_pool_drains_every_submitted_job_on_join() {
        use std::sync::{Arc, Mutex};
        let seen = Arc::new(Mutex::new(Vec::new()));
        let pool = {
            let seen = Arc::clone(&seen);
            WorkerPool::new(4, move |j: usize| seen.lock().unwrap().push(j))
        };
        for j in 0..500 {
            pool.submit(j);
        }
        pool.join();
        let mut got = seen.lock().unwrap().clone();
        got.sort_unstable();
        assert_eq!(got, (0..500).collect::<Vec<usize>>());
    }

    #[test]
    fn worker_pool_survives_a_panicking_job() {
        let done = Arc::new(AtomicUsize::new(0));
        let pool = {
            let done = Arc::clone(&done);
            WorkerPool::new(2, move |j: usize| {
                if j == 0 {
                    panic!("hostile job");
                }
                done.fetch_add(1, Ordering::SeqCst);
            })
        };
        pool.submit(0);
        // the poisoned worker dies, but the queue stays usable and the
        // surviving workers keep draining
        for j in 1..10 {
            pool.submit(j);
        }
        pool.join();
        assert_eq!(done.load(Ordering::SeqCst), 9);
    }

    #[test]
    fn uneven_work_is_load_balanced_correctly() {
        // items with wildly different costs still produce ordered output
        let out = par_map((0..200i64).collect::<Vec<_>>(), 7, |x| {
            if x % 13 == 0 {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            x * 2
        });
        assert_eq!(out, (0..200i64).map(|x| x * 2).collect::<Vec<_>>());
    }
}
