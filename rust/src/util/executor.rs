//! Fixed-size thread pool with a scoped parallel `map` (offline stand-in
//! for `tokio`/`rayon`). The coordinator's workload — running measurement
//! campaigns across simulated devices — is CPU-bound fan-out, which maps
//! cleanly onto scoped threads.
//!
//! Work is dispatched by a single shared atomic cursor over a slice of
//! item slots: each worker claims the next index with `fetch_add` and
//! takes the item out of its slot. Compared to a `Mutex<Vec<_>>` queue
//! this removes all lock contention from dispatch (each slot mutex is
//! touched exactly once, uncontended) and processes items front-to-back
//! instead of the queue's back-to-front pop order.

use crate::obs::span::{self, Span};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Run `f` over `items` with up to `workers` OS threads, preserving input
/// order in the output. Uses `std::thread::scope`, so `f` may borrow from
/// the caller.
pub fn par_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let out: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                // one root span per worker thread: its duration against
                // the items it claimed is the utilization signal the
                // trace export surfaces (inert when tracing is off)
                let mut sp = Span::root("par_map.worker");
                let mut claimed = 0usize;
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let item = slots[i]
                        .lock()
                        .unwrap()
                        .take()
                        .expect("work item claimed twice");
                    let r = f(item);
                    *out[i].lock().unwrap() = Some(r);
                    claimed += 1;
                }
                if span::enabled() {
                    sp.set_meta(format!("items={claimed}"));
                }
            });
        }
    });
    out.into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap()
                .expect("worker died before producing result")
        })
        .collect()
}

/// Default worker count: one per available core, at least 1.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// A fixed pool of long-lived worker threads pulling jobs off one
/// shared queue — the persistent complement to [`par_map`]'s scoped
/// fan-out, for callers (the event-driven serving reactor) that submit
/// work continuously instead of in one batch.
///
/// Jobs are handled by one shared closure; results travel through
/// whatever channel the closure captures. [`WorkerPool::join`] is
/// deterministic: already-queued jobs are drained before the workers
/// exit, so a caller that stops submitting and then joins has seen
/// every job handled.
pub struct WorkerPool<J: Send + 'static> {
    shared: Arc<PoolShared<J>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

struct PoolShared<J> {
    queue: Mutex<VecDeque<J>>,
    available: Condvar,
    stop: AtomicBool,
}

impl<J: Send + 'static> WorkerPool<J> {
    /// Spawn `workers` (at least 1) threads, each running `handle` over
    /// jobs claimed from the shared queue.
    pub fn new<F>(workers: usize, handle: F) -> WorkerPool<J>
    where
        F: Fn(J) + Send + Sync + 'static,
    {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            stop: AtomicBool::new(false),
        });
        let handle = Arc::new(handle);
        let handles = (0..workers.max(1))
            .map(|_| {
                let shared = Arc::clone(&shared);
                let handle = Arc::clone(&handle);
                std::thread::spawn(move || worker_loop(&shared, &*handle))
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Enqueue one job; a parked worker wakes to claim it.
    pub fn submit(&self, job: J) {
        let mut q = self
            .shared
            .queue
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        q.push_back(job);
        drop(q);
        self.shared.available.notify_one();
    }

    /// Jobs submitted but not yet claimed by a worker.
    pub fn queued(&self) -> usize {
        self.shared
            .queue
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .len()
    }

    /// Drain the queue and stop: workers finish every job already
    /// submitted, then exit; returns once all of them have been joined.
    pub fn join(self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn worker_loop<J: Send>(shared: &PoolShared<J>, handle: &(dyn Fn(J) + Sync)) {
    loop {
        let job = {
            let mut q = shared
                .queue
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if shared.stop.load(Ordering::SeqCst) {
                    break None;
                }
                q = shared
                    .available
                    .wait(q)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
            }
        };
        match job {
            Some(j) => handle(j),
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn preserves_order() {
        let out = par_map((0..100).collect::<Vec<i64>>(), 8, |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<i64>>());
    }

    #[test]
    fn single_worker_and_empty() {
        assert_eq!(par_map(vec![1, 2, 3], 1, |x| x + 1), vec![2, 3, 4]);
        assert_eq!(par_map(Vec::<i32>::new(), 4, |x| x), Vec::<i32>::new());
    }

    #[test]
    fn can_borrow_environment() {
        let base = 10;
        let out = par_map(vec![1, 2, 3], 3, |x| x + base);
        assert_eq!(out, vec![11, 12, 13]);
    }

    #[test]
    fn more_workers_than_items() {
        let out = par_map(vec![5], 16, |x| x * 2);
        assert_eq!(out, vec![10]);
    }

    #[test]
    fn stress_many_items_many_workers() {
        // far more items than workers, and far more workers than cores:
        // every item must run exactly once and land at its own index.
        let n = 10_000usize;
        let executions = AtomicUsize::new(0);
        let out = par_map((0..n as i64).collect::<Vec<i64>>(), 32, |x| {
            executions.fetch_add(1, Ordering::Relaxed);
            // a little work so workers genuinely interleave
            let mut acc = x;
            for i in 0..50 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            (x, acc)
        });
        assert_eq!(executions.load(Ordering::Relaxed), n);
        assert_eq!(out.len(), n);
        for (i, (x, _)) in out.iter().enumerate() {
            assert_eq!(*x, i as i64, "result out of order at {i}");
        }
    }

    #[test]
    fn worker_pool_drains_every_submitted_job_on_join() {
        use std::sync::{Arc, Mutex};
        let seen = Arc::new(Mutex::new(Vec::new()));
        let pool = {
            let seen = Arc::clone(&seen);
            WorkerPool::new(4, move |j: usize| seen.lock().unwrap().push(j))
        };
        for j in 0..500 {
            pool.submit(j);
        }
        pool.join();
        let mut got = seen.lock().unwrap().clone();
        got.sort_unstable();
        assert_eq!(got, (0..500).collect::<Vec<usize>>());
    }

    #[test]
    fn worker_pool_survives_a_panicking_job() {
        let done = Arc::new(AtomicUsize::new(0));
        let pool = {
            let done = Arc::clone(&done);
            WorkerPool::new(2, move |j: usize| {
                if j == 0 {
                    panic!("hostile job");
                }
                done.fetch_add(1, Ordering::SeqCst);
            })
        };
        pool.submit(0);
        // the poisoned worker dies, but the queue stays usable and the
        // surviving workers keep draining
        for j in 1..10 {
            pool.submit(j);
        }
        pool.join();
        assert_eq!(done.load(Ordering::SeqCst), 9);
    }

    #[test]
    fn uneven_work_is_load_balanced_correctly() {
        // items with wildly different costs still produce ordered output
        let out = par_map((0..200i64).collect::<Vec<_>>(), 7, |x| {
            if x % 13 == 0 {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            x * 2
        });
        assert_eq!(out, (0..200i64).map(|x| x * 2).collect::<Vec<_>>());
    }
}
