//! Fixed-size thread pool with a scoped parallel `map` (offline stand-in
//! for `tokio`/`rayon`). The coordinator's workload — running measurement
//! campaigns across simulated devices — is CPU-bound fan-out, which maps
//! cleanly onto scoped threads.
//!
//! Work is dispatched by a single shared atomic cursor over a slice of
//! item slots: each worker claims the next index with `fetch_add` and
//! takes the item out of its slot. Compared to a `Mutex<Vec<_>>` queue
//! this removes all lock contention from dispatch (each slot mutex is
//! touched exactly once, uncontended) and processes items front-to-back
//! instead of the queue's back-to-front pop order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f` over `items` with up to `workers` OS threads, preserving input
/// order in the output. Uses `std::thread::scope`, so `f` may borrow from
/// the caller.
pub fn par_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let out: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    return;
                }
                let item = slots[i]
                    .lock()
                    .unwrap()
                    .take()
                    .expect("work item claimed twice");
                let r = f(item);
                *out[i].lock().unwrap() = Some(r);
            });
        }
    });
    out.into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap()
                .expect("worker died before producing result")
        })
        .collect()
}

/// Default worker count: one per available core, at least 1.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn preserves_order() {
        let out = par_map((0..100).collect::<Vec<i64>>(), 8, |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<i64>>());
    }

    #[test]
    fn single_worker_and_empty() {
        assert_eq!(par_map(vec![1, 2, 3], 1, |x| x + 1), vec![2, 3, 4]);
        assert_eq!(par_map(Vec::<i32>::new(), 4, |x| x), Vec::<i32>::new());
    }

    #[test]
    fn can_borrow_environment() {
        let base = 10;
        let out = par_map(vec![1, 2, 3], 3, |x| x + base);
        assert_eq!(out, vec![11, 12, 13]);
    }

    #[test]
    fn more_workers_than_items() {
        let out = par_map(vec![5], 16, |x| x * 2);
        assert_eq!(out, vec![10]);
    }

    #[test]
    fn stress_many_items_many_workers() {
        // far more items than workers, and far more workers than cores:
        // every item must run exactly once and land at its own index.
        let n = 10_000usize;
        let executions = AtomicUsize::new(0);
        let out = par_map((0..n as i64).collect::<Vec<i64>>(), 32, |x| {
            executions.fetch_add(1, Ordering::Relaxed);
            // a little work so workers genuinely interleave
            let mut acc = x;
            for i in 0..50 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            (x, acc)
        });
        assert_eq!(executions.load(Ordering::Relaxed), n);
        assert_eq!(out.len(), n);
        for (i, (x, _)) in out.iter().enumerate() {
            assert_eq!(*x, i as i64, "result out of order at {i}");
        }
    }

    #[test]
    fn uneven_work_is_load_balanced_correctly() {
        // items with wildly different costs still produce ordered output
        let out = par_map((0..200i64).collect::<Vec<_>>(), 7, |x| {
            if x % 13 == 0 {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            x * 2
        });
        assert_eq!(out, (0..200i64).map(|x| x * 2).collect::<Vec<_>>());
    }
}
