//! Dense linear algebra for the native fitting path.
//!
//! The model's weights solve a relative-error least-squares problem
//! (paper §4.3). The production path runs the AOT-compiled JAX/Pallas
//! artifact through [`crate::runtime`]; this module provides the
//! cross-checked native implementation (Gram + Cholesky with ridge, and a
//! Householder-QR fallback for ill-conditioned systems).

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Mat {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Mat { rows: r, cols: c, data: rows.into_iter().flatten().collect() }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// `self^T * self` (Gram matrix), the hot kernel of the fit. Blocked
    /// over rows for cache friendliness; mirrors the L1 Pallas kernel.
    pub fn gram(&self) -> Mat {
        let p = self.cols;
        let mut g = Mat::zeros(p, p);
        const RB: usize = 64;
        let mut r0 = 0;
        while r0 < self.rows {
            let r1 = (r0 + RB).min(self.rows);
            for r in r0..r1 {
                let row = self.row(r);
                // upper triangle only
                for i in 0..p {
                    let ri = row[i];
                    if ri == 0.0 {
                        continue;
                    }
                    let grow = &mut g.data[i * p..(i + 1) * p];
                    for j in i..p {
                        grow[j] += ri * row[j];
                    }
                }
            }
            r0 = r1;
        }
        // mirror
        for i in 0..p {
            for j in 0..i {
                g.data[i * p + j] = g.data[j * p + i];
            }
        }
        g
    }

    /// `self^T * v`.
    pub fn t_mul_vec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.rows);
        let mut out = vec![0.0; self.cols];
        for (r, &vr) in v.iter().enumerate() {
            if vr == 0.0 {
                continue;
            }
            let row = self.row(r);
            for (o, &x) in out.iter_mut().zip(row) {
                *o += vr * x;
            }
        }
        out
    }

    /// `self * v`.
    pub fn mul_vec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.cols);
        (0..self.rows).map(|r| dot(self.row(r), v)).collect()
    }
}

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation — the model-evaluation inner product is
    // the paper's "rapid evaluation" claim; keep it tight.
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Solve `(A + ridge*I) x = b` for symmetric positive-definite `A` via
/// Cholesky. Returns `None` if the factorization breaks down.
pub fn cholesky_solve(a: &Mat, b: &[f64], ridge: f64) -> Option<Vec<f64>> {
    assert_eq!(a.rows, a.cols);
    assert_eq!(b.len(), a.rows);
    let n = a.rows;
    let mut l = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.at(i, j) + if i == j { ridge } else { 0.0 };
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    // forward substitution L y = b
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * y[k];
        }
        y[i] = s / l[i * n + i];
    }
    // back substitution L^T x = y
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    Some(x)
}

/// Least squares `min ||A x - b||` via Householder QR with column norms
/// guarding rank deficiency (tiny diagonal -> zero weight). Used when the
/// Gram system is too ill-conditioned for Cholesky.
pub fn qr_solve(a: &Mat, b: &[f64]) -> Vec<f64> {
    let m = a.rows;
    let n = a.cols;
    assert!(m >= n, "qr_solve requires rows >= cols");
    let mut r = a.clone();
    let mut qtb = b.to_vec();
    for k in 0..n {
        // Householder vector for column k
        let mut norm = 0.0;
        for i in k..m {
            norm += r.at(i, k) * r.at(i, k);
        }
        let norm = norm.sqrt();
        if norm < 1e-300 {
            continue;
        }
        let alpha = if r.at(k, k) > 0.0 { -norm } else { norm };
        let mut v = vec![0.0; m];
        for i in k..m {
            v[i] = r.at(i, k);
        }
        v[k] -= alpha;
        let vtv = v[k..].iter().map(|x| x * x).sum::<f64>();
        if vtv < 1e-300 {
            continue;
        }
        // apply H = I - 2 v v^T / v^T v to R and qtb
        for j in k..n {
            let mut s = 0.0;
            for i in k..m {
                s += v[i] * r.at(i, j);
            }
            let s = 2.0 * s / vtv;
            for i in k..m {
                *r.at_mut(i, j) -= s * v[i];
            }
        }
        let mut s = 0.0;
        for i in k..m {
            s += v[i] * qtb[i];
        }
        let s = 2.0 * s / vtv;
        for i in k..m {
            qtb[i] -= s * v[i];
        }
    }
    // back substitution on the upper-triangular R
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let d = r.at(i, i);
        if d.abs() < 1e-12 {
            x[i] = 0.0; // rank-deficient column -> zero weight
            continue;
        }
        let mut s = qtb[i];
        for j in i + 1..n {
            s -= r.at(i, j) * x[j];
        }
        x[i] = s / d;
    }
    x
}

/// Geometric mean of strictly positive values (Fleming & Wallace, the
/// paper's §5 summary statistic). Degenerate entries are handled
/// explicitly rather than silently corrupting the mean:
///
/// * non-positive values are clamped to `1e-12` (a zero error would
///   otherwise annihilate the whole mean);
/// * `+inf` entries (the `Model::rel_err` sentinel for a degenerate
///   measurement) propagate to an infinite mean so the failure stays
///   visible;
/// * `NaN` entries (e.g. predictions from a broken fit) are treated
///   like the `+inf` sentinel — the mean becomes `+inf` rather than
///   the `NaN` poisoning every comparison, and unlike skipping, the
///   failure cannot masquerade as a perfect score.
pub fn geometric_mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = 0.0;
    for &x in xs {
        if x.is_nan() {
            return f64::INFINITY;
        }
        s += x.max(1e-12).ln();
    }
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gram_matches_naive() {
        let a = Mat::from_rows(vec![
            vec![1.0, 2.0, 3.0],
            vec![0.0, 1.0, -1.0],
            vec![2.0, 0.5, 0.0],
            vec![1.0, 1.0, 1.0],
        ]);
        let g = a.gram();
        for i in 0..3 {
            for j in 0..3 {
                let want: f64 = (0..4).map(|r| a.at(r, i) * a.at(r, j)).sum();
                assert!((g.at(i, j) - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn cholesky_solves_spd() {
        // A = M^T M + I is SPD
        let m = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let mut g = m.gram();
        *g.at_mut(0, 0) += 1.0;
        *g.at_mut(1, 1) += 1.0;
        let x_true = vec![0.3, -0.7];
        let b = g.mul_vec(&x_true);
        let x = cholesky_solve(&g, &b, 0.0).unwrap();
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-10);
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(vec![vec![0.0, 1.0], vec![1.0, 0.0]]);
        assert!(cholesky_solve(&a, &[1.0, 1.0], 0.0).is_none());
    }

    #[test]
    fn qr_matches_cholesky_on_well_conditioned() {
        let a = Mat::from_rows(vec![
            vec![1.0, 0.0, 1.0],
            vec![1.0, 1.0, 0.0],
            vec![0.0, 1.0, 1.0],
            vec![1.0, 1.0, 1.0],
            vec![2.0, 0.0, 0.5],
        ]);
        let b = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let xq = qr_solve(&a, &b);
        let g = a.gram();
        let atb = a.t_mul_vec(&b);
        let xc = cholesky_solve(&g, &atb, 0.0).unwrap();
        for (q, c) in xq.iter().zip(&xc) {
            assert!((q - c).abs() < 1e-8, "{xq:?} vs {xc:?}");
        }
    }

    #[test]
    fn qr_handles_rank_deficiency() {
        // third column = first + second
        let a = Mat::from_rows(vec![
            vec![1.0, 0.0, 1.0],
            vec![0.0, 1.0, 1.0],
            vec![1.0, 1.0, 2.0],
            vec![2.0, 1.0, 3.0],
        ]);
        let b = vec![1.0, 1.0, 2.0, 3.0];
        let x = qr_solve(&a, &b);
        // residual should still be (near) zero since b is in the column space
        let r: f64 = a
            .mul_vec(&x)
            .iter()
            .zip(&b)
            .map(|(p, t)| (p - t) * (p - t))
            .sum();
        assert!(r < 1e-16, "residual {r}");
    }

    #[test]
    fn geomean_examples() {
        assert!((geometric_mean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geometric_mean(&[0.16, 0.14, 0.06, 0.42])
            - (0.16f64 * 0.14 * 0.06 * 0.42).powf(0.25))
        .abs()
            < 1e-12);
    }

    #[test]
    fn geomean_zero_and_nan_edge_cases() {
        // empty slices carry no information
        assert_eq!(geometric_mean(&[]), 0.0);
        // NaN entries surface as the inf sentinel, never as NaN (which
        // would poison comparisons) or as a skipped perfect score
        let g = geometric_mean(&[4.0, f64::NAN, 1.0]);
        assert!(g.is_infinite() && g > 0.0, "{g}");
        let g = geometric_mean(&[f64::NAN, f64::NAN]);
        assert!(g.is_infinite() && g > 0.0, "{g}");
        // zeros clamp to 1e-12 instead of annihilating the mean
        let z = geometric_mean(&[0.0, 0.0]);
        assert!(z > 0.9e-12 && z < 1.1e-12, "{z}");
        assert!(geometric_mean(&[1.0, 0.0]) > 0.0);
        // the rel_err inf sentinel stays visible
        assert!(geometric_mean(&[1.0, f64::INFINITY]).is_infinite());
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        let a: Vec<f64> = (0..37).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..37).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-9);
    }
}
