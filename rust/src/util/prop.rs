//! Property-based testing harness (offline stand-in for `proptest`).
//!
//! A property is a closure from a seeded [`Rng`](crate::util::rng::Rng) to
//! `Result<(), String>`. The runner executes `cases` random cases; on the
//! first failure it re-derives the failing case seed and panics with a
//! reproduction line. Generators are free functions over `Rng`, so
//! properties compose naturally.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy)]
pub struct Config {
    pub cases: u32,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // Fixed default seed -> deterministic CI; override locally to fuzz.
        Config { cases: 256, seed: 0x5EED_CAFE }
    }
}

/// Run `prop` for `cfg.cases` independent cases. Each case gets an `Rng`
/// seeded from (seed, case index) so any failure is reproducible from the
/// printed line alone.
pub fn check(name: &str, cfg: Config, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ ((case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case}/{}: {msg}\n  reproduce: seed={case_seed:#x}",
                cfg.cases
            );
        }
    }
}

/// Shorthand with default config.
pub fn quickcheck(name: &str, prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    check(name, Config::default(), prop);
}

/// Assert helper returning `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// Generator: small usize in [lo, hi).
pub fn gen_usize(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    rng.range_u64(lo as u64, hi as u64) as usize
}

/// Generator: f64 in [lo, hi).
pub fn gen_f64(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
    lo + rng.f64() * (hi - lo)
}

/// Generator: vector of f64.
pub fn gen_vec_f64(rng: &mut Rng, len: usize, lo: f64, hi: f64) -> Vec<f64> {
    (0..len).map(|_| gen_f64(rng, lo, hi)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        quickcheck("add_commutes", |rng| {
            let a = rng.range_i64(-1000, 1000);
            let b = rng.range_i64(-1000, 1000);
            prop_assert!(a + b == b + a, "a={a} b={b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always_fails' failed")]
    fn failing_property_reports() {
        check("always_fails", Config { cases: 3, seed: 1 }, |_rng| Err("nope".into()));
    }

    #[test]
    fn generators_in_bounds() {
        quickcheck("gen_bounds", |rng| {
            let u = gen_usize(rng, 2, 10);
            prop_assert!((2..10).contains(&u), "u={u}");
            let f = gen_f64(rng, -1.0, 1.0);
            prop_assert!((-1.0..1.0).contains(&f), "f={f}");
            Ok(())
        });
    }
}
