//! Mini-criterion: warmup + sampled wall-clock timing with summary
//! statistics. All `benches/*.rs` use `harness = false` and drive this.
//!
//! Output format is one line per benchmark:
//! `bench <name> ... median 1.234 ms  mean 1.240 ms ± 0.5%  (20 samples)`

use crate::obs::log::Level;
use crate::olog;
use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub samples: usize,
}

impl Sample {
    pub fn report_line(&self) -> String {
        let rel = if self.mean_ns > 0.0 { 100.0 * self.stddev_ns / self.mean_ns } else { 0.0 };
        format!(
            "bench {:<44} median {:>12}  mean {:>12} ± {:>4.1}%  ({} samples)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            rel,
            self.samples
        )
    }
}

/// Human-readable duration.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with criterion-like warmup/measure phases.
pub struct Bench {
    /// minimum time spent warming up
    pub warmup: Duration,
    /// number of measured samples
    pub samples: usize,
    /// minimum total measurement time; iterations per sample are scaled so
    /// a sample takes at least `min_sample`.
    pub min_sample: Duration,
    results: Vec<Sample>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            samples: 15,
            min_sample: Duration::from_millis(20),
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fast profile for expensive end-to-end benches.
    pub fn end_to_end() -> Self {
        Bench {
            warmup: Duration::from_millis(50),
            samples: 5,
            min_sample: Duration::from_millis(1),
            results: Vec::new(),
        }
    }

    /// Run `f` repeatedly, returning and recording stats. The closure's
    /// return value is consumed through `std::hint::black_box` so the
    /// optimizer cannot elide the work.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> Sample {
        // Warmup and calibration: figure out iterations per sample.
        let warm_start = Instant::now();
        let mut one = Duration::ZERO;
        while warm_start.elapsed() < self.warmup {
            let t = Instant::now();
            std::hint::black_box(f());
            one = t.elapsed();
            if one > self.warmup {
                break; // single run longer than entire warmup budget
            }
        }
        let per_iter = one.max(Duration::from_nanos(1));
        let iters = (self.min_sample.as_nanos() / per_iter.as_nanos()).max(1) as usize;

        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            times.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let var =
            times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / times.len() as f64;
        let s = Sample {
            name: name.to_string(),
            median_ns: median,
            mean_ns: mean,
            stddev_ns: var.sqrt(),
            samples: times.len(),
        };
        println!("{}", s.report_line());
        self.results.push(s.clone());
        s
    }

    /// All recorded samples.
    pub fn results(&self) -> &[Sample] {
        &self.results
    }

    /// Print a footer; call at the end of a bench binary.
    pub fn finish(&self, suite: &str) {
        println!("--- {suite}: {} benchmarks complete ---", self.results.len());
    }

    /// Serialize all recorded samples to a JSON value (the shape the CI
    /// perf-trajectory artifacts use).
    pub fn to_json(&self, suite: &str) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("suite", Json::Str(suite.to_string())),
            (
                "results",
                Json::Arr(
                    self.results
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("name", Json::Str(s.name.clone())),
                                ("median_ns", Json::Num(s.median_ns)),
                                ("mean_ns", Json::Num(s.mean_ns)),
                                ("stddev_ns", Json::Num(s.stddev_ns)),
                                ("samples", Json::Num(s.samples as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Print the footer and persist results to `BENCH_<suite>.json` in
    /// the current directory, so the perf trajectory is recorded run
    /// over run (consumed by CI).
    pub fn finish_json(&self, suite: &str) {
        self.finish(suite);
        let path = format!("BENCH_{suite}.json");
        match std::fs::write(&path, self.to_json(suite).pretty()) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => olog!(Level::Error, "could not write {path}: {e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench {
            warmup: Duration::from_millis(5),
            samples: 5,
            min_sample: Duration::from_micros(200),
            results: Vec::new(),
        };
        let s = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(s.median_ns > 0.0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(12_000_000_000.0).ends_with('s'));
    }
}
