//! Tiny command-line parser (offline stand-in for `clap`).
//!
//! Supports subcommands, `--flag`, `--opt value` / `--opt=value`, and
//! positional arguments; generates usage text from registered specs.

use std::collections::BTreeMap;

/// Declarative option spec used for parsing + usage generation.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// true -> boolean flag, false -> takes a value
    pub is_flag: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    pub opts: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Parse `argv` (without the program name) against `specs`.
pub fn parse(argv: &[String], specs: &[OptSpec]) -> Result<Args, String> {
    let mut args = Args::default();
    for s in specs {
        if let (false, Some(d)) = (s.is_flag, s.default) {
            args.opts.insert(s.name.to_string(), d.to_string());
        }
    }
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(stripped) = a.strip_prefix("--") {
            let (name, inline_val) = match stripped.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (stripped, None),
            };
            let spec = specs
                .iter()
                .find(|s| s.name == name)
                .ok_or_else(|| format!("unknown option --{name}"))?;
            if spec.is_flag {
                if inline_val.is_some() {
                    return Err(format!("--{name} is a flag and takes no value"));
                }
                args.flags.push(name.to_string());
            } else {
                let val = match inline_val {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i).cloned().ok_or_else(|| format!("--{name} expects a value"))?
                    }
                };
                args.opts.insert(name.to_string(), val);
            }
        } else {
            args.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(args)
}

/// Render usage text for a subcommand.
pub fn usage(cmd: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut s = format!("{cmd} — {about}\n\noptions:\n");
    for spec in specs {
        let head = if spec.is_flag {
            format!("  --{}", spec.name)
        } else {
            format!("  --{} <value>", spec.name)
        };
        s.push_str(&format!("{head:<28}{}", spec.help));
        if let Some(d) = spec.default {
            s.push_str(&format!(" [default: {d}]"));
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "device", help: "device name", is_flag: false, default: Some("titan_x") },
            OptSpec { name: "runs", help: "number of runs", is_flag: false, default: Some("30") },
            OptSpec { name: "verbose", help: "chatty", is_flag: true, default: None },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&sv(&[]), &specs()).unwrap();
        assert_eq!(a.get("device"), Some("titan_x"));
        assert_eq!(a.get_usize("runs", 0).unwrap(), 30);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = parse(&sv(&["--device", "k40c", "--runs=10", "--verbose", "pos"]), &specs()).unwrap();
        assert_eq!(a.get("device"), Some("k40c"));
        assert_eq!(a.get_usize("runs", 0).unwrap(), 10);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos".to_string()]);
    }

    #[test]
    fn errors() {
        assert!(parse(&sv(&["--nope"]), &specs()).is_err());
        assert!(parse(&sv(&["--device"]), &specs()).is_err());
        assert!(parse(&sv(&["--verbose=1"]), &specs()).is_err());
        assert!(parse(&sv(&["--runs", "abc"]), &specs()).unwrap().get_usize("runs", 0).is_err());
    }

    #[test]
    fn usage_mentions_all() {
        let u = usage("fit", "fit a device", &specs());
        for name in ["device", "runs", "verbose"] {
            assert!(u.contains(name));
        }
    }
}
