//! Deterministic fault injection for chaos testing.
//!
//! A [`FaultPlan`] names *sites* — fixed string keys compiled into the
//! code at the places where things can go wrong — and gives each a
//! probability. Production code asks [`FaultPlan::should_inject`] at a
//! site; with no plan installed the call never happens (the plan is
//! threaded as `Option<Arc<FaultPlan>>` and checked with `if let`), so
//! the no-fault configuration is byte-identical to a build without the
//! feature.
//!
//! Decisions are **counter-based, not clock-based**: the n-th query of a
//! site under seed `s` always returns the same answer, independent of
//! wall clock, thread timing or process layout. That makes chaos runs
//! reproducible — re-running the same plan against the same request
//! stream injects the same faults — which is what lets
//! `rust/tests/chaos.rs` pin exact accounting instead of "roughly no
//! crashes".
//!
//! Sites currently compiled in:
//!
//! | site              | where                         | effect                              |
//! |-------------------|-------------------------------|-------------------------------------|
//! | `measure.fail`    | `gpusim::timing` via `SimGpu` | timing run returns `Err`            |
//! | `measure.outlier` | `gpusim::timing` via `SimGpu` | one sample made spuriously fast     |
//! | `solver.make`     | `engine` solver construction  | solver construction returns `Err`   |
//! | `reload.io`       | `engine::Reloader`            | artifact re-read fails after change |
//! | `conn.abort`      | `service::tcp` accept loop    | accepted connection dropped unread  |
//! | `conn.slow`       | `service::tcp` per-connection | connection handling delayed ~25 ms  |
//!
//! Unknown site names in a plan are allowed (they simply never fire from
//! code that doesn't query them); querying a site absent from the plan
//! never injects. Per-site `attempts`/`injected` counters are exported
//! on the service health surface via [`FaultPlan::counters_json`].

use std::collections::BTreeMap;
use std::path::Path;

use crate::obs::Counter;

use crate::util::json::Json;
use crate::util::rng::splitmix64;

/// One named fault site: an injection rate plus live counters.
#[derive(Debug)]
struct Site {
    rate: f64,
    /// Injection ceiling: once `injected` reaches `max`, the site goes
    /// quiet (attempts still count). `u64::MAX` = unlimited.
    max: u64,
    attempts: Counter,
    injected: Counter,
    draws: Counter,
}

/// A seeded, counter-based fault plan. See the module docs.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    sites: BTreeMap<String, Site>,
}

/// Uniform-in-[0,1) decision value for attempt `k` of `site` under
/// `seed`. FNV-mix of the site name keeps distinct sites on distinct
/// streams; splitmix64 whitens the counter so consecutive attempts are
/// independent.
fn decision(seed: u64, site: &str, k: u64, salt: u64) -> u64 {
    let mut h = seed ^ salt;
    for b in site.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    let mut s = h ^ k.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(&mut s)
}

const INJECT_SALT: u64 = 0xA076_1D64_78BD_642F;
const DRAW_SALT: u64 = 0x2545_F491_4F6C_DD1D;

fn unit(x: u64) -> f64 {
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

impl FaultPlan {
    /// Empty plan (no sites — never injects) under `seed`.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, sites: BTreeMap::new() }
    }

    /// Builder: add `name` with injection probability `rate` (clamped to
    /// [0,1]) and no injection ceiling.
    pub fn site(self, name: &str, rate: f64) -> FaultPlan {
        self.site_max(name, rate, u64::MAX)
    }

    /// Builder: add `name` with probability `rate` and at most `max`
    /// total injections.
    pub fn site_max(mut self, name: &str, rate: f64, max: u64) -> FaultPlan {
        self.sites.insert(
            name.to_string(),
            Site {
                rate: rate.clamp(0.0, 1.0),
                max,
                attempts: Counter::new(),
                injected: Counter::new(),
                draws: Counter::new(),
            },
        );
        self
    }

    /// Parse `{"seed": n, "sites": {"name": {"rate": r, "max"?: m}, …}}`.
    pub fn from_json(j: &Json) -> Result<FaultPlan, String> {
        let seed = match j.get("seed") {
            None => 0,
            Some(v) => v
                .as_i64()
                .ok_or("fault plan: 'seed' must be an integer")?
                as u64,
        };
        let mut plan = FaultPlan::new(seed);
        let sites = match j.get("sites") {
            None => return Ok(plan),
            Some(Json::Obj(m)) => m,
            Some(_) => return Err("fault plan: 'sites' must be an object".into()),
        };
        for (name, sj) in sites {
            let rate = sj
                .get_f64("rate")
                .ok_or_else(|| format!("fault plan: site '{name}' needs a numeric 'rate'"))?;
            if !(0.0..=1.0).contains(&rate) {
                return Err(format!(
                    "fault plan: site '{name}' rate {rate} outside [0, 1]"
                ));
            }
            let max = match sj.get("max") {
                None => u64::MAX,
                Some(v) => v
                    .as_i64()
                    .filter(|m| *m >= 0)
                    .ok_or_else(|| {
                        format!("fault plan: site '{name}' 'max' must be a non-negative integer")
                    })? as u64,
            };
            plan = plan.site_max(name, rate, max);
        }
        Ok(plan)
    }

    /// Load a plan from a JSON file (the `--faults <plan.json>` flag).
    pub fn load(path: &Path) -> Result<FaultPlan, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("fault plan {}: {e}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| format!("fault plan {}: {e}", path.display()))?;
        FaultPlan::from_json(&j)
    }

    /// The plan's seed (exported so health output identifies the plan).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Should the next occurrence at `site` fail? Advances the site's
    /// attempt counter; deterministic in (seed, site, attempt index).
    /// Unknown sites never inject (and count nothing).
    pub fn should_inject(&self, site: &str) -> bool {
        let Some(s) = self.sites.get(site) else {
            return false;
        };
        let k = s.attempts.next();
        if unit(decision(self.seed, site, k, INJECT_SALT)) >= s.rate {
            return false;
        }
        // Reserve an injection slot; back out if the ceiling is reached
        // so `injected` never exceeds `max` even under concurrency.
        let prev = s.injected.next();
        if prev >= s.max {
            s.injected.dec();
            return false;
        }
        true
    }

    /// Deterministic auxiliary value for `site` (e.g. which sample of a
    /// timing run to corrupt). Advances its own counter so interleaving
    /// draws with injection decisions doesn't perturb either stream.
    pub fn draw(&self, site: &str) -> u64 {
        let Some(s) = self.sites.get(site) else {
            return 0;
        };
        let k = s.draws.next();
        decision(self.seed, site, k, DRAW_SALT)
    }

    /// Times `site` has been queried (0 for unknown sites).
    pub fn attempts(&self, site: &str) -> u64 {
        self.sites
            .get(site)
            .map(|s| s.attempts.get())
            .unwrap_or(0)
    }

    /// Times `site` actually injected (0 for unknown sites).
    pub fn injected(&self, site: &str) -> u64 {
        self.sites
            .get(site)
            .map(|s| s.injected.get())
            .unwrap_or(0)
    }

    /// Per-site counters for the health surface:
    /// `{"site": {"rate": r, "attempts": n, "injected": m}, …}`.
    pub fn counters_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("seed".to_string(), Json::Num(self.seed as f64));
        for (name, s) in &self.sites {
            m.insert(
                name.clone(),
                Json::obj(vec![
                    ("rate", Json::Num(s.rate)),
                    ("attempts", Json::Num(s.attempts.get() as f64)),
                    ("injected", Json::Num(s.injected.get() as f64)),
                ]),
            );
        }
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_deterministic_per_seed_and_counter() {
        let a = FaultPlan::new(42).site("x", 0.5);
        let b = FaultPlan::new(42).site("x", 0.5);
        let sa: Vec<bool> = (0..256).map(|_| a.should_inject("x")).collect();
        let sb: Vec<bool> = (0..256).map(|_| b.should_inject("x")).collect();
        assert_eq!(sa, sb);
        let hits = sa.iter().filter(|x| **x).count();
        assert!(hits > 64 && hits < 192, "rate 0.5 gave {hits}/256");
    }

    #[test]
    fn different_seeds_and_sites_get_different_streams() {
        let a = FaultPlan::new(1).site("x", 0.5).site("y", 0.5);
        let b = FaultPlan::new(2).site("x", 0.5);
        let ax: Vec<bool> = (0..128).map(|_| a.should_inject("x")).collect();
        let ay: Vec<bool> = (0..128).map(|_| a.should_inject("y")).collect();
        let bx: Vec<bool> = (0..128).map(|_| b.should_inject("x")).collect();
        assert_ne!(ax, ay);
        assert_ne!(ax, bx);
    }

    #[test]
    fn rate_edges_and_unknown_sites() {
        let p = FaultPlan::new(7).site("never", 0.0).site("always", 1.0);
        for _ in 0..64 {
            assert!(!p.should_inject("never"));
            assert!(p.should_inject("always"));
            assert!(!p.should_inject("no-such-site"));
        }
        assert_eq!(p.attempts("never"), 64);
        assert_eq!(p.injected("never"), 0);
        assert_eq!(p.injected("always"), 64);
        assert_eq!(p.attempts("no-such-site"), 0);
    }

    #[test]
    fn max_caps_injections_but_not_attempts() {
        let p = FaultPlan::new(3).site_max("x", 1.0, 2);
        let hits = (0..10).filter(|_| p.should_inject("x")).count();
        assert_eq!(hits, 2);
        assert_eq!(p.attempts("x"), 10);
        assert_eq!(p.injected("x"), 2);
    }

    #[test]
    fn draws_do_not_perturb_decisions() {
        let a = FaultPlan::new(11).site("x", 0.5);
        let b = FaultPlan::new(11).site("x", 0.5);
        let sa: Vec<bool> = (0..64)
            .map(|_| {
                let _ = a.draw("x");
                a.should_inject("x")
            })
            .collect();
        let sb: Vec<bool> = (0..64).map(|_| b.should_inject("x")).collect();
        assert_eq!(sa, sb);
        // draws themselves are a deterministic stream
        let c = FaultPlan::new(11).site("x", 0.5);
        let d = FaultPlan::new(11).site("x", 0.5);
        let da: Vec<u64> = (0..32).map(|_| c.draw("x")).collect();
        let db: Vec<u64> = (0..32).map(|_| d.draw("x")).collect();
        assert_eq!(da, db);
        assert!(da.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    fn json_roundtrip_and_validation() {
        let j = Json::parse(
            r#"{"seed": 9, "sites": {"measure.fail": {"rate": 0.25},
                 "reload.io": {"rate": 1.0, "max": 2}}}"#,
        )
        .unwrap();
        let p = FaultPlan::from_json(&j).unwrap();
        assert_eq!(p.seed(), 9);
        let hits = (0..8).filter(|_| p.should_inject("reload.io")).count();
        assert_eq!(hits, 2);
        // same seed via the builder gives the same stream
        let q = FaultPlan::new(9).site("measure.fail", 0.25);
        let sp: Vec<bool> = (0..128).map(|_| p.should_inject("measure.fail")).collect();
        let sq: Vec<bool> = (0..128).map(|_| q.should_inject("measure.fail")).collect();
        assert_eq!(sp, sq);

        for bad in [
            r#"{"seed": "x"}"#,
            r#"{"sites": []}"#,
            r#"{"sites": {"a": {}}}"#,
            r#"{"sites": {"a": {"rate": 1.5}}}"#,
            r#"{"sites": {"a": {"rate": 0.5, "max": -1}}}"#,
        ] {
            assert!(FaultPlan::from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn counters_json_reports_every_site() {
        let p = FaultPlan::new(5).site("a", 1.0).site("b", 0.0);
        let _ = p.should_inject("a");
        let j = p.counters_json();
        assert_eq!(j.get("seed").and_then(Json::as_f64), Some(5.0));
        assert_eq!(j.get("a").and_then(|s| s.get_f64("injected")), Some(1.0));
        assert_eq!(j.get("b").and_then(|s| s.get_f64("attempts")), Some(0.0));
    }
}
