//! Minimal JSON for campaign persistence (`harness` saves timing data and
//! fitted weights for future use, per §4.2 of the paper).
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Object key order is preserved so emitted
//! files diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps keys sorted -> deterministic output.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric object field (`None` for missing keys, non-objects and
    /// non-numeric values). Shorthand for `get(key).and_then(as_f64)`
    /// used by record loaders like `DeviceProfile::from_json`.
    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Json::as_f64)
    }

    /// String object field (`None` for missing keys, non-objects and
    /// non-string values).
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integer value of a JSON number: `Some` only when the value is
    /// integral and exactly representable (|x| < 2^53), so the cast can
    /// neither truncate a fraction nor round a too-large magnitude.
    /// The shared coercion for every loader that reads integer fields
    /// (kernel specs, request envs, profile override tables).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 && x.abs() < 9_007_199_254_740_992.0 => {
                Some(*x as i64)
            }
            _ => None,
        }
    }

    /// Integer object field (see [`Json::as_i64`] for the coercion).
    pub fn get_i64(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(Json::as_i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Serialize compactly.
    pub fn compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    x.write(out, indent + 1, pretty);
                }
                if pretty && !v.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    x.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (must consume all non-whitespace input).
    /// Nesting is capped at [`MAX_DEPTH`]: the parser is recursive
    /// descent, and untrusted input (the prediction service reads
    /// request lines off sockets) must produce an `Err`, not a stack
    /// overflow that aborts the process.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.compact())
    }
}

/// Maximum container nesting [`Json::parse`] accepts. Far above any
/// legitimate document in this repo (campaigns, model artifacts,
/// kernel specs nest a handful of levels; expression trees a few
/// dozen) and far below the thread-stack budget of the recursive
/// parser and the recursive consumers downstream of it
/// (`service::spec::expr_of`, `service::hash`).
pub const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH} levels at byte {}", self.i));
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value(depth + 1)?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.compact()).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let v = Json::obj(vec![
            ("device", Json::Str("titan_x".into())),
            (
                "times",
                Json::Arr(vec![Json::Num(0.32), Json::Num(1.03), Json::Num(4.27)]),
            ),
            ("meta", Json::obj(vec![("runs", Json::Num(30.0)), ("drop", Json::Num(4.0))])),
        ]);
        let text = v.pretty();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\te".into());
        assert_eq!(Json::parse(&v.compact()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn nesting_is_depth_capped_not_stack_overflowed() {
        // far past the cap: a clean error, not a process abort
        let deep = "[".repeat(100_000) + &"]".repeat(100_000);
        let e = Json::parse(&deep).unwrap_err();
        assert!(e.contains("nesting"), "{e}");
        let deep_obj = "{\"a\":".repeat(5_000) + "1" + &"}".repeat(5_000);
        assert!(Json::parse(&deep_obj).is_err());
        // comfortably nested documents still parse
        let ok = "[".repeat(40) + "1" + &"]".repeat(40);
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(30.0).compact(), "30");
        assert_eq!(Json::Num(0.5).compact(), "0.5");
    }

    #[test]
    fn object_field_helpers() {
        let v = Json::obj(vec![("a", Json::Num(2.0)), ("b", Json::Str("x".into()))]);
        assert_eq!(v.get_f64("a"), Some(2.0));
        assert_eq!(v.get_str("b"), Some("x"));
        assert_eq!(v.get_f64("b"), None);
        assert_eq!(v.get_str("missing"), None);
        assert_eq!(Json::Num(1.0).get_f64("a"), None);
        assert_eq!(v.get_i64("a"), Some(2));
        assert_eq!(v.get_i64("b"), None);
    }

    #[test]
    fn as_i64_rejects_fractions_and_unrepresentable_magnitudes() {
        assert_eq!(Json::Num(42.0).as_i64(), Some(42));
        assert_eq!(Json::Num(-3.0).as_i64(), Some(-3));
        assert_eq!(Json::Num(2.5).as_i64(), None);
        assert_eq!(Json::Str("7".into()).as_i64(), None);
        // 2^53 is the first integer whose neighbors alias in f64
        assert_eq!(Json::Num(9_007_199_254_740_992.0).as_i64(), None);
        assert_eq!(Json::Num(9_007_199_254_740_991.0).as_i64(), Some(9_007_199_254_740_991));
        assert_eq!(Json::Num(1e300).as_i64(), None);
    }
}
