//! Global symbol interning and dense symbol-keyed environments.
//!
//! Every identifier the analysis layers juggle — size parameters (`n`),
//! inames (`g0`, `l0`, `kt`), array names (`a`, `tile`) — is interned
//! once into a process-global table and thereafter carried as a
//! [`Sym`]: a `Copy` 32-bit handle. Comparing, hashing and map-keying
//! symbols costs one integer op instead of a string walk, and a
//! parameter binding becomes an [`Env`]: a dense `Vec<i64>` indexed by
//! symbol id, so the evaluation hot paths (qpoly re-evaluation, the
//! simulator's per-lane interpreter, the timing engine's warp sampler)
//! index a flat slot frame instead of probing `BTreeMap<String, i64>`.
//!
//! The intern table is append-only; symbol strings are leaked (their
//! total size is bounded by the distinct identifiers ever seen, a few
//! hundred in any realistic run) so `as_str` can hand out `&'static
//! str` without holding a lock for the caller's lifetime.

use std::collections::HashMap;
use std::fmt;
use std::sync::{OnceLock, RwLock};

/// An interned identifier. `Ord`/`Hash` operate on the 32-bit id, so
/// symbol-keyed `BTreeMap`s iterate in *interning* order, not
/// lexicographic order — callers that need name order must sort by
/// [`Sym::as_str`] explicitly.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Sym(u32);

struct Interner {
    lookup: HashMap<&'static str, u32>,
    names: Vec<&'static str>,
}

fn interner() -> &'static RwLock<Interner> {
    static TABLE: OnceLock<RwLock<Interner>> = OnceLock::new();
    TABLE.get_or_init(|| {
        RwLock::new(Interner { lookup: HashMap::new(), names: Vec::new() })
    })
}

impl Sym {
    /// Intern a string, returning its stable handle. Idempotent and
    /// thread-safe; the read path is lock-shared and allocation-free.
    pub fn intern(name: &str) -> Sym {
        {
            let table = interner().read().unwrap();
            if let Some(&id) = table.lookup.get(name) {
                return Sym(id);
            }
        }
        let mut table = interner().write().unwrap();
        if let Some(&id) = table.lookup.get(name) {
            return Sym(id);
        }
        let leaked: &'static str = Box::leak(name.to_string().into_boxed_str());
        let id = table.names.len() as u32;
        table.names.push(leaked);
        table.lookup.insert(leaked, id);
        Sym(id)
    }

    /// Look up an already-interned string without interning it. Returns
    /// `None` for names the process has never interned — use this for
    /// query paths (e.g. [`Env::get_name`]) so probing with arbitrary
    /// strings cannot grow the intern table.
    pub fn lookup(name: &str) -> Option<Sym> {
        interner().read().unwrap().lookup.get(name).map(|&id| Sym(id))
    }

    /// The interned string.
    pub fn as_str(self) -> &'static str {
        interner().read().unwrap().names[self.0 as usize]
    }

    /// Raw slot id (index into dense [`Env`] frames and compiled tapes).
    #[inline]
    pub fn id(self) -> u32 {
        self.0
    }

    /// Reconstruct a `Sym` from a raw id previously obtained via
    /// [`Sym::id`]. The id must have come from this process's interner.
    #[inline]
    pub fn from_id(id: u32) -> Sym {
        Sym(id)
    }
}

impl fmt::Display for Sym {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl fmt::Debug for Sym {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl From<&Sym> for Sym {
    fn from(s: &Sym) -> Sym {
        *s
    }
}

/// NOTE: converting a `&str` interns it. Lookup-style APIs bounded on
/// `Into<Sym>` (`BoxDomain::dim`, `Kernel::array`, …) therefore grow
/// the intern table when probed with a novel string; when querying
/// with dynamic, possibly-missing names, resolve through
/// [`Sym::lookup`] first so misses stay allocation-free.
impl From<&str> for Sym {
    fn from(s: &str) -> Sym {
        Sym::intern(s)
    }
}

impl From<&String> for Sym {
    fn from(s: &String) -> Sym {
        Sym::intern(s)
    }
}

impl From<String> for Sym {
    fn from(s: String) -> Sym {
        Sym::intern(&s)
    }
}

/// A parameter/iname binding: a dense slot frame indexed by symbol id.
///
/// `get`/`bind` are O(1) array indexing — this is the "flat `Vec<i64>`
/// environment" the compiled evaluation tapes and the simulator's
/// per-lane interpreter run against.
#[derive(Clone, Default)]
pub struct Env {
    vals: Vec<i64>,
    set: Vec<bool>,
}

impl Env {
    pub fn new() -> Env {
        Env::default()
    }

    /// Build from `(name, value)` pairs.
    pub fn from_pairs(pairs: &[(&str, i64)]) -> Env {
        let mut e = Env::new();
        for (k, v) in pairs {
            e.bind(Sym::intern(k), *v);
        }
        e
    }

    /// Bind `sym` to `v` (growing the frame if needed).
    #[inline]
    pub fn bind(&mut self, sym: Sym, v: i64) {
        let i = sym.id() as usize;
        if i >= self.vals.len() {
            self.vals.resize(i + 1, 0);
            self.set.resize(i + 1, false);
        }
        self.vals[i] = v;
        self.set[i] = true;
    }

    /// Name-based insert; returns the previous binding, if any.
    pub fn insert<S: Into<Sym>>(&mut self, name: S, v: i64) -> Option<i64> {
        let s = name.into();
        let prev = self.get(s);
        self.bind(s, v);
        prev
    }

    /// Remove a binding (the slot stays allocated).
    #[inline]
    pub fn unbind(&mut self, sym: Sym) {
        if let Some(flag) = self.set.get_mut(sym.id() as usize) {
            *flag = false;
        }
    }

    /// Value bound to `sym`, if any. O(1).
    #[inline]
    pub fn get(&self, sym: Sym) -> Option<i64> {
        self.get_id(sym.id())
    }

    /// Value bound to the raw slot id, if any. O(1).
    #[inline]
    pub fn get_id(&self, id: u32) -> Option<i64> {
        let i = id as usize;
        if *self.set.get(i)? {
            Some(self.vals[i])
        } else {
            None
        }
    }

    /// Name-based lookup. Does not intern: a name nothing has bound
    /// cannot have a value, so unseen names simply return `None`.
    pub fn get_name(&self, name: &str) -> Option<i64> {
        self.get(Sym::lookup(name)?)
    }

    /// Iterate bound `(sym, value)` pairs in slot order.
    pub fn iter(&self) -> impl Iterator<Item = (Sym, i64)> + '_ {
        self.vals
            .iter()
            .zip(self.set.iter())
            .enumerate()
            .filter(|(_, (_, &s))| s)
            .map(|(i, (&v, _))| (Sym::from_id(i as u32), v))
    }

    /// Mutable iteration over bound values (binding set is unchanged).
    pub fn values_mut(&mut self) -> impl Iterator<Item = &mut i64> + '_ {
        self.vals
            .iter_mut()
            .zip(self.set.iter())
            .filter(|(_, &s)| s)
            .map(|(v, _)| v)
    }

    /// Number of bound symbols.
    pub fn len(&self) -> usize {
        self.set.iter().filter(|&&s| s).count()
    }

    /// Width of the dense slot table (highest ever-bound slot id + 1;
    /// stale unbound slots count). A batched evaluation frame must
    /// allocate columns up to this width to cover every binding.
    pub fn slot_width(&self) -> usize {
        self.set.len()
    }

    pub fn is_empty(&self) -> bool {
        !self.set.iter().any(|&s| s)
    }
}

impl PartialEq for Env {
    fn eq(&self, other: &Env) -> bool {
        // compare bindings only; stale slot values must not matter
        let n = self.set.len().max(other.set.len());
        for i in 0..n {
            let a = self.get_id(i as u32);
            let b = other.get_id(i as u32);
            if a != b {
                return false;
            }
        }
        true
    }
}

impl Eq for Env {}

impl std::ops::Index<&str> for Env {
    type Output = i64;

    fn index(&self, name: &str) -> &i64 {
        let sym = Sym::lookup(name).unwrap_or_else(|| panic!("unbound parameter '{name}'"));
        let i = sym.id() as usize;
        assert!(
            self.set.get(i).copied().unwrap_or(false),
            "unbound parameter '{name}'"
        );
        &self.vals[i]
    }
}

impl fmt::Debug for Env {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut pairs: Vec<(&'static str, i64)> =
            self.iter().map(|(s, v)| (s.as_str(), v)).collect();
        pairs.sort();
        f.debug_map().entries(pairs).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interning_is_idempotent_and_distinct() {
        let a = Sym::intern("alpha_test_sym");
        let b = Sym::intern("alpha_test_sym");
        let c = Sym::intern("beta_test_sym");
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.as_str(), "alpha_test_sym");
        assert_eq!(Sym::from_id(a.id()), a);
    }

    #[test]
    fn env_bind_get_unbind() {
        let mut e = Env::new();
        let n = Sym::intern("env_test_n");
        assert_eq!(e.get(n), None);
        e.bind(n, 42);
        assert_eq!(e.get(n), Some(42));
        assert_eq!(e["env_test_n"], 42);
        e.unbind(n);
        assert_eq!(e.get(n), None);
        assert!(e.is_empty());
    }

    #[test]
    fn env_equality_ignores_stale_slots() {
        let n = Sym::intern("env_eq_n");
        let m = Sym::intern("env_eq_m");
        let mut a = Env::new();
        a.bind(n, 1);
        a.bind(m, 9);
        a.unbind(m);
        let mut b = Env::new();
        b.bind(n, 1);
        assert_eq!(a, b);
        b.bind(m, 2);
        assert_ne!(a, b);
    }

    #[test]
    fn env_iteration_and_values_mut() {
        let mut e = Env::from_pairs(&[("env_it_x", 3), ("env_it_y", 4)]);
        assert_eq!(e.len(), 2);
        for v in e.values_mut() {
            *v *= 10;
        }
        assert_eq!(e.get_name("env_it_x"), Some(30));
        assert_eq!(e.get_name("env_it_y"), Some(40));
        let names: Vec<&str> = e.iter().map(|(s, _)| s.as_str()).collect();
        assert!(names.contains(&"env_it_x") && names.contains(&"env_it_y"));
    }

    #[test]
    fn lookup_does_not_intern() {
        assert!(Sym::lookup("lookup_never_interned_a").is_none());
        let e = Env::new();
        assert_eq!(e.get_name("lookup_never_interned_b"), None);
        // the probe above must not have interned the name
        assert!(Sym::lookup("lookup_never_interned_b").is_none());
        let s = Sym::intern("lookup_interned");
        assert_eq!(Sym::lookup("lookup_interned"), Some(s));
    }

    #[test]
    fn concurrent_interning_agrees() {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                std::thread::spawn(|| {
                    (0..100)
                        .map(|i| Sym::intern(&format!("conc_sym_{i}")))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        let results: Vec<Vec<Sym>> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        for r in &results[1..] {
            assert_eq!(r, &results[0]);
        }
    }
}
