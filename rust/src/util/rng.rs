//! Deterministic pseudo-random number generation.
//!
//! `gpusim` needs reproducible measurement noise (the paper's timing
//! protocol is designed around run-to-run variance) and the property-test
//! harness needs seeded case generation. A splitmix64-seeded
//! xoshiro256**-style generator is plenty for both.

/// Splitmix64 — used to expand a single `u64` seed into generator state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Deterministic, seedable, fast; not cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)` (requires `lo < hi`).
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo < hi);
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform integer in `[lo, hi)` for i64 bounds.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo < hi);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    /// Pick an element of a slice uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[(self.next_u64() % xs.len() as u64) as usize]
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let mut u1 = self.f64();
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with multiplicative sigma: returns `exp(sigma * N(0,1))`.
    /// `sigma = 0.02` models ~2% run-to-run timing noise.
    pub fn lognormal(&mut self, sigma: f64) -> f64 {
        (sigma * self.normal()).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.range_i64(-5, 17);
            assert!((-5..17).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(1234);
        let n = 200_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn lognormal_is_positive_and_centered() {
        let mut r = Rng::new(99);
        let mut sum = 0.0;
        for _ in 0..50_000 {
            let x = r.lognormal(0.02);
            assert!(x > 0.0);
            sum += x;
        }
        let mean = sum / 50_000.0;
        assert!((mean - 1.0).abs() < 0.01, "mean {mean}");
    }
}
