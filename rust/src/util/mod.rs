//! Small in-tree substrates for facilities that would normally come from
//! crates.io (the build environment is offline; only the `xla` closure is
//! vendored). Each submodule is a deliberately minimal but real
//! implementation, unit-tested like the rest of the library:
//!
//! * [`rng`] — deterministic xorshift/splitmix RNG with normal/log-normal
//!   sampling (no `rand`).
//! * [`json`] — JSON value model, serializer and recursive-descent parser
//!   (no `serde_json`), used for campaign persistence.
//! * [`cli`] — flag/option command-line parser (no `clap`).
//! * [`bench`] — a mini-criterion: warmup + sampled timing with
//!   mean/median/stddev reporting, used by all `benches/*.rs`
//!   (`harness = false`).
//! * [`prop`] — property-based testing harness (no `proptest`): seeded
//!   generators + failure-case reporting with linear shrinking.
//! * [`executor`] — fixed thread pool with a scoped `map` primitive (no
//!   `tokio`; the coordinator's parallelism is CPU-bound fan-out, for
//!   which threads are the right tool).
//! * [`linalg`] — dense matrices, Cholesky and QR solves for the native
//!   fitting path.
//! * [`intern`] — global symbol interner ([`intern::Sym`]) and dense
//!   symbol-indexed environments ([`intern::Env`]); the substrate for
//!   the compiled evaluation tapes in [`crate::qpoly::tape`].
//! * [`fnv`] — FNV-1a 64-bit hashing for process-independent digests
//!   (structural kernel hashes, model-artifact fingerprints).
//! * [`fault`] — seeded, counter-based fault injection
//!   ([`fault::FaultPlan`]) behind named sites in `gpusim`, `engine`
//!   and `service`; the substrate for `rust/tests/chaos.rs`.
pub mod fault;
pub mod fnv;
pub mod intern;
pub mod rng;
pub mod json;
pub mod cli;
pub mod bench;
pub mod prop;
pub mod executor;
pub mod linalg;
