//! The engine's measurement→fit jobs: the campaign/fit prefix shared
//! by `run_device` and `fit --save`, the full per-device pipeline, and
//! the fold machinery the cross-validation splits fan out on the
//! executor.
//!
//! Everything here takes `&Engine`, so one engine (one registry, one
//! props cache, one solver factory) backs every job regardless of
//! which entry point — `coordinator`, `crossval` or a test — issued
//! it.

use super::Engine;
use crate::gpusim::{DeviceProfile, SimGpu};
use crate::harness::{self, measure_cases, run_campaign, run_campaign_robust};
use crate::kernels;
use crate::obs::span::{self, Span};
use crate::perfmodel::{self, Model, PropertyMatrix, Solver};
use crate::service::{ModelStore, StoredModel};
use crate::util::executor::par_map;

/// Per-device pipeline output.
#[derive(Clone, Debug)]
pub struct DeviceResult {
    pub device: String,
    pub model: Model,
    pub launch_overhead_s: f64,
    pub n_measurement_cases: usize,
    /// (kernel, case letter, predicted, actual) for the §5 test kernels
    pub tests: Vec<(String, String, f64, f64)>,
    /// campaign warnings (e.g. the zero-overhead calibration fallback)
    pub warnings: Vec<String>,
    /// (case label, reason) for measurement cases quarantined from the
    /// fit instead of aborting the campaign
    pub quarantined: Vec<(String, String)>,
}

/// What a campaign degraded on, carried alongside the fit so callers
/// can report it ([`DeviceResult`], the CLI, the service health page).
#[derive(Clone, Debug, Default)]
pub struct CampaignNotes {
    pub warnings: Vec<String>,
    pub quarantined: Vec<(String, String)>,
}

/// One measured zoo case, ready for fold assembly.
#[derive(Clone, Debug)]
pub struct ZooCase {
    pub kernel: String,
    pub case: String,
    pub label: String,
    pub props: Vec<f64>,
    pub time_s: f64,
}

/// Per-device measurements (and the fit backend) shared by every fold
/// of that device — the solver is instantiated once here rather than
/// per fold, so an XLA artifact is loaded at most once per device.
pub struct FoldCtx {
    pub device: String,
    pub campaign: PropertyMatrix,
    pub overhead: f64,
    pub zoo: Vec<ZooCase>,
    pub solver: Box<dyn Solver + Send + Sync>,
}

impl Engine {
    /// The campaign + fit prefix shared by [`Engine::run_device`] and
    /// [`Engine::fit_store`]: simulate the device, run the §4.1/§4.2
    /// measurement campaign, and fit the §4.3 weights. Returns the
    /// simulated device, the (filtered) property matrix, the fitted
    /// model and the calibrated launch overhead.
    pub fn campaign_and_fit(
        &self,
        device: &str,
    ) -> Result<(SimGpu, PropertyMatrix, Model, f64, CampaignNotes), String> {
        let cfg = self.config();
        let profile = self.profile(device)?.clone();
        let gpu = self.sim_gpu(profile);

        // 1. measurement campaign (§4.1 + §4.2), capability-derived
        //    from the profile. The robust runner quarantines failing
        //    cases and survives calibration failure; with no faults in
        //    play it produces the same matrix as `run_campaign`.
        let cases = kernels::measurement_suite(&gpu.profile);
        let mut campaign_span = Span::child("pipeline.campaign");
        if span::enabled() {
            campaign_span.set_meta(format!("device={device} cases={}", cases.len()));
        }
        let outcome = run_campaign_robust(
            &gpu,
            &cases,
            self.schema(),
            &cfg.protocol,
            cfg.extract,
            cfg.workers,
        )?;
        drop(campaign_span);
        let notes = CampaignNotes {
            warnings: outcome.overhead_warning.clone().into_iter().collect(),
            quarantined: outcome
                .quarantined
                .iter()
                .map(|q| (q.label.clone(), q.reason.clone()))
                .collect(),
        };
        self.note_campaign(&notes);

        // 2. fit (§4.3)
        let solver = self.solver()?;
        let mut fit_span = Span::child("pipeline.fit");
        if span::enabled() {
            fit_span.set_meta(format!("device={device}"));
        }
        let model =
            perfmodel::fit(device, &outcome.matrix, self.schema(), solver.as_ref())?;
        drop(fit_span);
        Ok((gpu, outcome.matrix, model, outcome.overhead, notes))
    }

    /// Run the full per-device pipeline: measurement campaign → fit →
    /// test kernels → Table-1-shaped entries.
    pub fn run_device(&self, device: &str) -> Result<DeviceResult, String> {
        let cfg = self.config();
        let (gpu, pm, model, overhead, notes) = self.campaign_and_fit(device)?;

        // 3. test kernels (§5, or the full zoo behind `eval_zoo`):
        //    predict + measure, through the same parallel measurement
        //    path the cross-validation subsystem uses
        let suite = if cfg.eval_zoo {
            kernels::eval_suite(&gpu.profile)
        } else {
            kernels::test_suite(&gpu.profile)
        };
        let mut predict_span = Span::child("pipeline.predict");
        if span::enabled() {
            predict_span.set_meta(format!("device={device} cases={}", suite.len()));
        }
        let measurements = measure_cases(
            &gpu,
            &suite,
            self.schema(),
            &cfg.protocol,
            cfg.extract,
            cfg.workers,
        )?;
        let mut tests = Vec::new();
        for (case, m) in suite.iter().zip(&measurements) {
            // label format: "<kernel>/<letter>/..."
            let mut parts = case.label.split('/');
            let kname = parts.next().unwrap_or("?").to_string();
            let letter = parts.next().unwrap_or("?").to_string();
            tests.push((kname, letter, model.predict(&m.props), m.time_s));
        }
        drop(predict_span);

        // 4. optional persistence
        if let Some(dir) = &cfg.out_dir {
            std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
            let cj = harness::campaign_to_json(&pm, device, overhead);
            std::fs::write(dir.join(format!("campaign_{device}.json")), cj.pretty())
                .map_err(|e| e.to_string())?;
            std::fs::write(
                dir.join(format!("model_{device}.json")),
                model.to_json(self.schema()).pretty(),
            )
            .map_err(|e| e.to_string())?;
        }

        Ok(DeviceResult {
            device: device.to_string(),
            model,
            launch_overhead_s: overhead,
            n_measurement_cases: pm.n_cases(),
            tests,
            warnings: notes.warnings,
            quarantined: notes.quarantined,
        })
    }

    /// Fit every configured device and assemble a persistable model
    /// store (the `fit --save` flow): one measurement campaign + fit
    /// per device — and nothing else; the test-kernel evaluation pass
    /// of [`Engine::run_device`] contributes nothing to an artifact and
    /// is skipped — fanned out on the executor, each weight table
    /// fingerprinted against the profile and capability-derived suite
    /// that produced it. The returned store is what `predict --models`
    /// and `serve` answer from (install it with
    /// [`Engine::install_store`] to serve it from this engine).
    pub fn fit_store(&self) -> Result<ModelStore, String> {
        let cfg = self.config();
        // Flat scheduling: every level of the fan-out (devices here,
        // per-case timing inside each campaign) requests the full
        // worker budget — all tickets drain one process-wide executor
        // queue ([`crate::util::executor`]), so inner case work fills
        // whatever slots the device level leaves idle instead of a
        // static device×case split oversubscribing either level.
        let workers = cfg.workers.max(1);
        let results = par_map(cfg.devices.clone(), workers, |dev| {
            self.campaign_and_fit(&dev).map(|(gpu, pm, model, overhead, _notes)| {
                (gpu.profile, pm.n_cases(), model, overhead)
            })
        });
        let mut store = ModelStore::new(self.schema(), cfg.extract);
        for r in results {
            let (profile, n_cases, model, overhead) = r?;
            store.insert(StoredModel::new(model, overhead, n_cases, &profile));
        }
        Ok(store)
    }

    /// Measure one device for fold evaluation: run the (possibly
    /// filtered) measurement campaign and the (possibly filtered)
    /// evaluation-kernel zoo once, and instantiate the fold solver.
    /// The filters receive case labels; cross-validation's quick mode
    /// passes its coverage-preserving predicates here.
    pub fn measure_fold_ctx(
        &self,
        profile: &DeviceProfile,
        campaign_keep: &(dyn Fn(&str) -> bool + Sync),
        zoo_keep: &(dyn Fn(&str) -> bool + Sync),
        workers: usize,
    ) -> Result<FoldCtx, String> {
        let cfg = self.config();
        let gpu = self.sim_gpu(profile.clone());
        let mut cases = kernels::measurement_suite(&gpu.profile);
        cases.retain(|c| campaign_keep(&c.label));
        let (campaign, overhead) = run_campaign(
            &gpu,
            &cases,
            self.schema(),
            &cfg.protocol,
            cfg.extract,
            workers,
        )?;

        let mut zoo_cases = kernels::eval_suite(&gpu.profile);
        zoo_cases.retain(|c| zoo_keep(&c.label));
        let measurements = measure_cases(
            &gpu,
            &zoo_cases,
            self.schema(),
            &cfg.protocol,
            cfg.extract,
            workers,
        )?;
        let zoo = zoo_cases
            .iter()
            .zip(measurements)
            .map(|(c, m)| {
                let mut parts = c.label.split('/');
                let kernel = parts.next().unwrap_or("?").to_string();
                let case = parts.next().unwrap_or("?").to_string();
                ZooCase { kernel, case, label: m.label, props: m.props, time_s: m.time_s }
            })
            .collect();
        Ok(FoldCtx {
            device: profile.name.clone(),
            campaign,
            overhead,
            zoo,
            solver: self.solver()?,
        })
    }

    /// Assemble a fold's training set: the device's campaign plus every
    /// zoo case passing `keep`. The §4.2 minimum-size floor applies to
    /// training cases only — held-out cases are never floor-filtered —
    /// and this is the single place the rule lives, shared by every
    /// split.
    pub fn fold_training_matrix(
        &self,
        ctx: &FoldCtx,
        keep: &dyn Fn(&ZooCase) -> bool,
    ) -> PropertyMatrix {
        let floor = self.config().protocol.min_time_factor * ctx.overhead;
        let mut pm = ctx.campaign.clone();
        for z in &ctx.zoo {
            if keep(z) && z.time_s >= floor {
                pm.push(z.label.clone(), z.props.clone(), z.time_s);
            }
        }
        pm
    }

    /// Fit one fold's model on an assembled training matrix, using the
    /// context's per-device solver.
    pub fn fit_fold_model(
        &self,
        ctx: &FoldCtx,
        pm: &PropertyMatrix,
    ) -> Result<Model, String> {
        perfmodel::fit(&ctx.device, pm, self.schema(), ctx.solver.as_ref())
    }
}
