//! `engine` — the one measurement→extraction→fit→predict core every
//! entry point shares.
//!
//! Before this module existed the paper's pipeline (symbolic count
//! extraction → linear fit → prediction) was re-assembled three
//! slightly-different times: `coordinator::run_device`/`fit_models`,
//! the per-fold jobs in `crossval`, and `service::Service` each wired
//! registry lookup, suite construction, props caching and solver
//! plumbing by hand. Following the cross-machine framing of the model
//! as a reusable artifact (Stevens & Klöckner, arXiv:1904.09538) and
//! the fast-portable-prediction product view (Braun et al.,
//! arXiv:2001.07104), [`Engine`] now owns the shared state:
//!
//! * the **device registry** — the catalogue every device name
//!   resolves against;
//! * the **props cache** ([`crate::service::SharedPropsCache`]) — one
//!   eviction-bounded, sharded symbolic-extraction cache shared by
//!   every prediction path, optionally layered over a persistent
//!   append-only extraction log ([`Config::props_cache`]) so a
//!   restarted process warm-starts on its predecessor's corpus;
//! * **suite construction** — capability-derived evaluation suites,
//!   built lazily once per device and shared;
//! * the **solver factory** ([`make_solver`]) — backend selection for
//!   every fit;
//! * an **atomically-swappable [`ModelStore`]** — the serving weights,
//!   installed behind an `RwLock<Arc<…>>` so a hot reload
//!   ([`Reloader`]) swaps a validated artifact in one store while
//!   in-flight predictions keep the snapshot they started with.
//!
//! The batch pipelines ([`crate::coordinator`]), the cross-validation
//! folds ([`crate::crossval`]) and the prediction server
//! ([`crate::service`]) are all thin layers over the methods here —
//! scaling work changes one place instead of three.
//!
//! Serving code must not panic on poisoned locks or assumed invariants:
//! `unwrap`/`expect` are denied throughout this module tree (test code
//! opts back in per `mod tests`).
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod pipeline;

pub use pipeline::{CampaignNotes, DeviceResult, FoldCtx, ZooCase};

use crate::gpusim::{registry, DeviceProfile, DeviceRegistry, SimGpu, TimingCache};
use crate::harness::{MeasCacheFile, Protocol};
use crate::kernels::{self, KernelCase};
use crate::obs::log::Level;
use crate::obs::metrics;
use crate::obs::span::{self, Span};
use crate::olog;
use crate::perfmodel::{NativeSolver, Solver};
use crate::service::hash::structural_hash;
use crate::service::request::{KernelRef, MatrixRequest, PredictRequest};
use crate::service::{ModelStore, SharedPropsCache};
use crate::stats::{BatchArena, ExtractOpts, KernelProps, Schema};
use crate::util::executor::{default_workers, par_map};
use crate::util::fault::FaultPlan;
use crate::util::intern::Env;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::{Instant, SystemTime};

/// Poison-recovering lock acquisition: a thread that panicked while
/// holding one of these locks leaves plain data (maps, counters, an
/// `Arc` slot) in a consistent state, so serving continues instead of
/// cascading the panic through every subsequent request.
fn read_lock<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(std::sync::PoisonError::into_inner)
}

fn write_lock<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(std::sync::PoisonError::into_inner)
}

fn mutex_lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Which fit backend to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FitBackend {
    /// in-process Cholesky/QR ([`NativeSolver`])
    Native,
    /// AOT-compiled JAX/Pallas artifact through PJRT
    Xla,
    /// try the artifact, fall back to native if unavailable
    Auto,
}

/// Pipeline configuration (shared by every engine entry point).
#[derive(Clone, Debug)]
pub struct Config {
    /// devices to run, by name; resolved through [`Config::registry`]
    pub devices: Vec<String>,
    /// the device catalogue names resolve against. Defaults to the
    /// built-in registry; the CLI's `--devices <profiles.json>` flag
    /// extends it with user profiles at runtime.
    pub registry: DeviceRegistry,
    pub protocol: Protocol,
    pub backend: FitBackend,
    pub extract: ExtractOpts,
    /// results directory (None = don't persist)
    pub out_dir: Option<PathBuf>,
    pub workers: usize,
    /// evaluate the full 9-class evaluation-kernel zoo (§5 test kernels
    /// plus the zoo expansion) instead of the four §5 test kernels
    pub eval_zoo: bool,
    /// deterministic fault plan (chaos testing / the `--faults` flag);
    /// `None` — the default — is a true no-op: no site is ever queried
    pub faults: Option<Arc<FaultPlan>>,
    /// degraded-mode prediction: when the installed store lacks the
    /// requested device, answer from the nearest-capability device the
    /// store *does* hold, flagging the response `degraded` (off by
    /// default — a missing model is then an error, as before)
    pub degraded: bool,
    /// persistent extraction-cache file
    /// ([`crate::service::diskcache::PropsCacheFile`]): extractions are
    /// appended as they happen and preloaded at startup, so a restarted
    /// process warm-starts on its predecessor's corpus. An incompatible
    /// file (format/schema/options mismatch) is refused with a warning
    /// and the engine runs cold — never trusted
    pub props_cache: Option<PathBuf>,
    /// persistent campaign measurement cache
    /// ([`crate::harness::meascache::MeasCacheFile`]): raw timing
    /// streams are appended as they are measured and preloaded at
    /// startup, so a repeated `fit`/`crossval`/`transfer` replays its
    /// campaigns bit-identically with zero simulation. An incompatible
    /// file (format/protocol/seed mismatch) is refused with a warning
    /// and the engine measures cold — never trusted
    pub meas_cache: Option<PathBuf>,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            devices: vec![
                "titan_x".into(),
                "c2070".into(),
                "k40c".into(),
                "r9_fury".into(),
            ],
            registry: registry::builtins().clone(),
            protocol: Protocol::default(),
            backend: FitBackend::Auto,
            extract: ExtractOpts::default(),
            out_dir: None,
            workers: default_workers(),
            eval_zoo: false,
            faults: None,
            degraded: false,
            props_cache: None,
            meas_cache: None,
        }
    }
}

/// Instantiate the fit backend. The engine holds one solver per
/// measurement context rather than per fold, so an XLA artifact is
/// loaded at most once per device — hence the thread-safety bounds.
pub fn make_solver(backend: FitBackend) -> Result<Box<dyn Solver + Send + Sync>, String> {
    match backend {
        FitBackend::Native => Ok(Box::new(NativeSolver::new())),
        FitBackend::Xla => Ok(Box::new(crate::runtime::XlaSolver::from_artifacts()?)),
        FitBackend::Auto => match crate::runtime::XlaSolver::from_artifacts() {
            Ok(s) => Ok(Box::new(s)),
            Err(_) => Ok(Box::new(NativeSolver::new())),
        },
    }
}

/// One resolved + predicted request ([`Engine::predict`]).
pub struct Prediction {
    /// request `id`, echoed for correlation
    pub id: Option<Json>,
    pub device: String,
    pub kernel: String,
    /// size-case letter when the request resolved to a suite case
    pub case: Option<String>,
    pub predicted_s: f64,
    pub cache_hit: bool,
    /// wall time of the symbolic extraction, `None` on a cache hit (a
    /// hit is a non-run — the [`crate::harness::Sample::Cached`] rule)
    pub extract_s: Option<f64>,
    /// the answer came from another device's model ([`Config::degraded`]
    /// fallback); advisory only — nothing degraded is ever cached, the
    /// props cache is device-agnostic by construction
    pub degraded: bool,
    /// the store device that actually answered, when `degraded`
    pub served_by: Option<String>,
}

/// A request resolved up to — but not including — tape evaluation:
/// device and kernel looked up, launch validated, symbolic extraction
/// served from cache/disk/fresh. Holding the props `Arc`, the binding
/// and the store snapshot, it can be finished on the scalar path or
/// grouped with siblings for one batched SoA evaluation
/// (`Engine::finish_batched`).
struct Resolved {
    id: Option<Json>,
    device: String,
    kernel: String,
    case: Option<String>,
    env: Env,
    props: Arc<KernelProps>,
    cache_hit: bool,
    extract_s: Option<f64>,
    /// the degraded-mode fallback device, when one answered
    served_by: Option<String>,
    /// the store device whose weights answer (requested or fallback)
    weights_device: String,
    /// the store snapshot the whole request is served from
    store: Arc<ModelStore>,
}

/// One device×kernel matrix prediction ([`Engine::predict_matrix`]):
/// the request parsed once, predicted across every named device.
pub struct MatrixPrediction {
    pub id: Option<Json>,
    pub kernel: String,
    /// the requested size-case letter (per-device resolutions carry
    /// their own letter in [`Prediction::case`])
    pub case: Option<String>,
    /// per-device outcome, in request (or store) device order
    pub per_device: Vec<(String, Result<Prediction, String>)>,
}

/// The shared pipeline core. See the module docs for the ownership
/// graph. `Engine` is `Sync`: every entry point takes `&self`, so one
/// `Arc<Engine>` serves the batch pipelines, all cross-validation
/// folds and every server connection concurrently.
pub struct Engine {
    cfg: Config,
    schema: Schema,
    cache: SharedPropsCache,
    /// the serving weights; `None` until a store is installed.
    /// Swapped atomically under the write lock; readers clone the
    /// `Arc` and keep their snapshot for the whole request.
    store: RwLock<Option<Arc<ModelStore>>>,
    /// lazily built, capability-derived evaluation suites per device
    suites: RwLock<BTreeMap<String, Arc<Vec<KernelCase>>>>,
    /// robustness bookkeeping (quarantine counts, campaign warnings,
    /// extraction circuit breakers) surfaced on the service health page
    robust: RobustState,
    /// the persistent campaign measurement cache, when configured and
    /// accepted ([`Config::meas_cache`]); attached to every [`SimGpu`]
    /// this engine constructs
    meas: Option<Arc<MeasCacheFile>>,
}

/// Consecutive inline-extraction failures before the circuit opens for
/// that kernel structure.
const BREAKER_THRESHOLD: u32 = 3;

/// Cap on retained campaign warnings (health surface; oldest dropped).
const MAX_WARNINGS: usize = 32;

#[derive(Default)]
struct RobustState {
    /// measurement cases quarantined across all campaigns on this engine
    quarantined: AtomicU64,
    /// campaign warnings (e.g. the zero-overhead calibration fallback)
    warnings: Mutex<Vec<String>>,
    /// consecutive inline-extraction failures per structural hash; an
    /// entry at [`BREAKER_THRESHOLD`] or above is an open circuit
    breakers: Mutex<BTreeMap<u64, u32>>,
    /// times any circuit transitioned closed -> open
    breaker_trips: AtomicU64,
}

impl Engine {
    /// Build an engine over a pipeline configuration with the default
    /// props-cache capacity.
    pub fn new(cfg: Config) -> Engine {
        Engine::with_cache_capacity(cfg, crate::service::cache::DEFAULT_CAPACITY)
    }

    /// Build an engine whose props cache is bounded to roughly
    /// `cache_capacity` entries (see
    /// [`SharedPropsCache::with_capacity`]).
    pub fn with_cache_capacity(cfg: Config, cache_capacity: usize) -> Engine {
        let schema = Schema::full();
        let mut cache = SharedPropsCache::with_capacity(cache_capacity);
        if let Some(path) = &cfg.props_cache {
            // construction stays infallible: a refused or unreadable
            // file costs the warm start, never the engine
            match crate::service::diskcache::PropsCacheFile::open(path, &schema, cfg.extract) {
                Ok(f) => {
                    if f.loaded() > 0 {
                        olog!(
                            Level::Info,
                            "uniperf: props cache {}: preloaded {} extractions",
                            path.display(),
                            f.loaded()
                        );
                    }
                    cache.attach_persist(Arc::new(f));
                }
                Err(e) => {
                    olog!(Level::Warn, "uniperf: props cache disabled (starting cold): {e}")
                }
            }
        }
        let mut meas = None;
        if let Some(path) = &cfg.meas_cache {
            // same posture as the props cache: a refused or unreadable
            // file costs the warm replay, never the engine
            match MeasCacheFile::open(path, &cfg.protocol, crate::gpusim::DEFAULT_SEED) {
                Ok(f) => {
                    if f.loaded() > 0 {
                        olog!(
                            Level::Info,
                            "uniperf: meas cache {}: preloaded {} measurement streams",
                            path.display(),
                            f.loaded()
                        );
                    }
                    meas = Some(Arc::new(f));
                }
                Err(e) => {
                    metrics::campaign().counter("meascache_refused_total").inc();
                    olog!(Level::Warn, "uniperf: meas cache disabled (measuring cold): {e}")
                }
            }
        }
        Engine {
            cfg,
            schema,
            cache,
            store: RwLock::new(None),
            suites: RwLock::new(BTreeMap::new()),
            robust: RobustState::default(),
            meas,
        }
    }

    pub fn config(&self) -> &Config {
        &self.cfg
    }

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    pub fn registry(&self) -> &DeviceRegistry {
        &self.cfg.registry
    }

    pub fn cache(&self) -> &SharedPropsCache {
        &self.cache
    }

    /// Resolve a device name through the registry.
    pub fn profile(&self, device: &str) -> Result<&DeviceProfile, String> {
        self.cfg
            .registry
            .get(device)
            .ok_or_else(|| format!("unknown device '{device}'"))
    }

    /// The capability-derived evaluation suite for a registry device,
    /// built once and shared (named-kernel resolution for every
    /// prediction path).
    pub fn eval_suite_for(&self, device: &str) -> Result<Arc<Vec<KernelCase>>, String> {
        if let Some(s) = read_lock(&self.suites).get(device) {
            return Ok(Arc::clone(s));
        }
        let profile = self.profile(device)?;
        let suite = Arc::new(kernels::eval_suite(profile));
        let mut map = write_lock(&self.suites);
        // a racing builder may have inserted meanwhile; keep the first
        // so every caller shares one Arc
        Ok(Arc::clone(
            map.entry(device.to_string()).or_insert(suite),
        ))
    }

    /// Validate a model store against this engine's registry, schema
    /// and extraction options, then swap it in atomically. In-flight
    /// predictions finish on the snapshot they started with; the next
    /// request sees the new weights. On error nothing is swapped.
    pub fn install_store(&self, store: ModelStore) -> Result<(), String> {
        store.validate_for_serving(&self.cfg.registry, &self.schema, self.cfg.extract)?;
        *write_lock(&self.store) = Some(Arc::new(store));
        Ok(())
    }

    /// The currently installed store, if any (an `Arc` snapshot — the
    /// caller keeps it consistent across a whole request even if a
    /// reload swaps the store mid-flight).
    pub fn store_snapshot(&self) -> Option<Arc<ModelStore>> {
        read_lock(&self.store).clone()
    }

    fn store_required(&self) -> Result<Arc<ModelStore>, String> {
        self.store_snapshot()
            .ok_or_else(|| "no model artifact installed (run `fit --save`)".to_string())
    }

    /// A [`SimGpu`] over `profile` carrying this engine's fault plan —
    /// the one constructor every engine measurement path uses, so
    /// `measure.*` sites cover campaigns and fold measurement alike.
    pub fn sim_gpu(&self, profile: DeviceProfile) -> SimGpu {
        SimGpu::new(profile)
            .with_faults(self.cfg.faults.clone())
            .with_meas_cache(
                self.meas.clone().map(|m| m as Arc<dyn TimingCache>),
            )
    }

    /// The attached campaign measurement cache, when one was configured
    /// and accepted (for hit/miss summaries on the fit/crossval paths).
    pub fn meas_cache(&self) -> Option<&Arc<MeasCacheFile>> {
        self.meas.as_ref()
    }

    /// Instantiate the configured fit backend ([`make_solver`]), with
    /// the `solver.make` fault site in front for chaos coverage of the
    /// fit paths.
    pub fn solver(&self) -> Result<Box<dyn Solver + Send + Sync>, String> {
        if let Some(plan) = &self.cfg.faults {
            if plan.should_inject("solver.make") {
                return Err(
                    "injected solver construction failure (fault site solver.make)".into(),
                );
            }
        }
        make_solver(self.cfg.backend)
    }

    /// Record a robust campaign's degradations (engine-level totals for
    /// the health surface).
    pub(crate) fn note_campaign(&self, notes: &CampaignNotes) {
        self.robust
            .quarantined
            .fetch_add(notes.quarantined.len() as u64, Ordering::Relaxed);
        if !notes.warnings.is_empty() {
            let mut w = mutex_lock(&self.robust.warnings);
            w.extend(notes.warnings.iter().cloned());
            if w.len() > MAX_WARNINGS {
                let drop_n = w.len() - MAX_WARNINGS;
                w.drain(..drop_n);
            }
        }
    }

    /// Total measurement cases quarantined across this engine's
    /// campaigns.
    pub fn quarantined_total(&self) -> u64 {
        self.robust.quarantined.load(Ordering::Relaxed)
    }

    /// Retained campaign warnings (most recent [`MAX_WARNINGS`]).
    pub fn campaign_warnings(&self) -> Vec<String> {
        mutex_lock(&self.robust.warnings).clone()
    }

    /// Currently open extraction circuits (structural hashes whose
    /// consecutive failure count reached the threshold).
    pub fn breaker_open_count(&self) -> u64 {
        mutex_lock(&self.robust.breakers)
            .values()
            .filter(|f| **f >= BREAKER_THRESHOLD)
            .count() as u64
    }

    /// Times any extraction circuit transitioned closed -> open.
    pub fn breaker_trips(&self) -> u64 {
        self.robust.breaker_trips.load(Ordering::Relaxed)
    }

    fn breaker_is_open(&self, structural: u64) -> bool {
        mutex_lock(&self.robust.breakers)
            .get(&structural)
            .is_some_and(|f| *f >= BREAKER_THRESHOLD)
    }

    fn breaker_note(&self, structural: u64, failed: bool) {
        let mut breakers = mutex_lock(&self.robust.breakers);
        if failed {
            let f = breakers.entry(structural).or_insert(0);
            *f += 1;
            if *f == BREAKER_THRESHOLD {
                self.robust.breaker_trips.fetch_add(1, Ordering::Relaxed);
            }
        } else {
            breakers.remove(&structural);
        }
    }

    /// Resolve + predict one parsed request against the installed
    /// store: registry lookup, suite resolution, cached symbolic
    /// extraction, tape evaluation, one inner product.
    pub fn predict(&self, req: &PredictRequest) -> Result<Prediction, String> {
        let r = self.resolve(req)?;
        let v = r.props.eval(&self.schema, &r.env)?;
        self.finish(r, &v)
    }

    /// Everything [`Engine::predict`] does *before* tape evaluation:
    /// store/device/kernel resolution, launch validation, cached (and
    /// optionally disk-backed) symbolic extraction. The returned
    /// [`Resolved`] carries the props `Arc` and the binding, so the
    /// caller chooses between scalar evaluation ([`Engine::finish`])
    /// and the batched SoA path ([`Engine::finish_batched`]).
    fn resolve(&self, req: &PredictRequest) -> Result<Resolved, String> {
        let store = self.store_required()?;
        let profile = self.profile(&req.device)?;
        // degraded-mode resolution: a registry device the store has no
        // weights for may be answered by the nearest-capability device
        // the store *does* hold (when `Config::degraded` opts in) —
        // flagged, never cached, and validated against the *requested*
        // device's limits below
        let (weights_device, served_by) = match store.get(&req.device) {
            Some(_) => (req.device.clone(), None),
            None if self.cfg.degraded => {
                let nearest =
                    nearest_capability(&store, &self.cfg.registry, profile).ok_or_else(
                        || {
                            format!(
                                "no fitted model for device '{}' and no degraded \
                                 fallback (the store is empty)",
                                req.device
                            )
                        },
                    )?;
                (nearest.clone(), Some(nearest))
            }
            None => {
                return Err(format!(
                    "no fitted model for device '{}' in the artifact (have: {})",
                    req.device,
                    store.devices().join(", ")
                ));
            }
        };

        // resolve the kernel + parameter binding
        let user_env = |pairs: &[(String, i64)]| {
            let mut e = Env::new();
            for (k, v) in pairs {
                e.insert(k.as_str(), *v);
            }
            e
        };
        let suite;
        let (kernel, env, kname, case_letter) = match &req.kref {
            KernelRef::Named { name, case } => {
                suite = self.eval_suite_for(&req.device)?;
                let cases: Vec<&KernelCase> =
                    suite.iter().filter(|c| c.kernel.name == *name).collect();
                if cases.is_empty() {
                    let mut known: Vec<&str> = Vec::new();
                    for c in suite.iter() {
                        if !known.contains(&c.kernel.name.as_str()) {
                            known.push(&c.kernel.name);
                        }
                    }
                    return Err(format!(
                        "unknown kernel '{name}' (known: {})",
                        known.join(", ")
                    ));
                }
                let (kernel, env, case_letter) = match (case, &req.env) {
                    (Some(letter), _) => {
                        let found = cases
                            .iter()
                            .find(|c| c.label.split('/').nth(1) == Some(letter.as_str()))
                            .ok_or_else(|| {
                                format!("kernel '{name}' has no size case '{letter}' (a-d)")
                            })?;
                        (&found.kernel, found.env.clone(), Some(letter.clone()))
                    }
                    (None, Some(pairs)) => (&cases[0].kernel, user_env(pairs), None),
                    (None, None) => {
                        // default: the smallest (`a`) size case
                        let found = cases
                            .iter()
                            .find(|c| c.label.split('/').nth(1) == Some("a"))
                            .unwrap_or(&cases[0]);
                        (
                            &found.kernel,
                            found.env.clone(),
                            found.label.split('/').nth(1).map(|s| s.to_string()),
                        )
                    }
                };
                (kernel, env, name.clone(), case_letter)
            }
            KernelRef::Inline(k) => {
                let pairs = req.env.as_ref().ok_or_else(|| {
                    "inline kernel request is missing 'env' (the parser should \
                     have rejected it)"
                        .to_string()
                })?;
                (k.as_ref(), user_env(pairs), k.name.clone(), None)
            }
        };

        // every size parameter must be bound
        for p in &kernel.params {
            if env.get(*p).is_none() {
                return Err(format!("kernel '{kname}' requires parameter '{p}' in env"));
            }
        }
        // reject launches the target device cannot run
        let (gs0, gs1) = kernel.group_size_at(&env)?;
        if gs0 * gs1 > profile.max_group_size as i64 {
            return Err(format!(
                "group size {}x{} exceeds {}'s limit of {}",
                gs0, gs1, profile.name, profile.max_group_size
            ));
        }

        // cached symbolic extraction -> tape evaluation -> inner product.
        // Suite-configured library cases share one entry across sizes
        // and devices (their stride classes are size-structural by
        // construction); any request supplying its *own* binding —
        // inline kernels and named kernels with a user env — is
        // additionally keyed by that binding, so a degenerate size
        // cannot poison the shared classification.
        let env_keyed =
            matches!(&req.kref, KernelRef::Inline(_)) || req.env.is_some();
        // circuit breaker on inline-spec extraction: a structure whose
        // extraction keeps failing is refused fast instead of re-running
        // the failing symbolic pass per request. Keyed by structural
        // hash (same key as the props cache), inline requests only —
        // suite kernels are extraction-validated at build time.
        let breaker_key = match &req.kref {
            KernelRef::Inline(k) => Some(structural_hash(k)),
            KernelRef::Named { .. } => None,
        };
        if let Some(h) = breaker_key {
            if self.breaker_is_open(h) {
                return Err(format!(
                    "extraction circuit open for kernel '{kname}' (structure \
                     {h:016x} failed {BREAKER_THRESHOLD}+ consecutive \
                     extractions; a successful extraction resets it)"
                ));
            }
        }
        // no span here: a cache hit is a hash probe counted by the
        // always-on hit/miss counters, and a miss is already traced by
        // the `engine.extract` span inside the cache — so warm requests
        // record nothing on this path
        let t0 = Instant::now();
        let extracted = self.cache.props_for(kernel, &env, self.cfg.extract, env_keyed);
        if let Some(h) = breaker_key {
            self.breaker_note(h, extracted.is_err());
        }
        let (props, hit) = extracted?;
        let extract_s = (!hit).then(|| t0.elapsed().as_secs_f64());
        Ok(Resolved {
            id: req.id.clone(),
            device: req.device.clone(),
            kernel: kname,
            case: case_letter,
            env,
            props,
            cache_hit: hit,
            extract_s,
            served_by,
            weights_device,
            store,
        })
    }

    /// The inner product closing a resolved request: look the weights
    /// up in the request's store snapshot and fold them against the
    /// evaluated property vector `v`.
    fn finish(&self, r: Resolved, v: &[f64]) -> Result<Prediction, String> {
        let sm = r.store.get(&r.weights_device).ok_or_else(|| {
            format!("model for device '{}' vanished from the store", r.weights_device)
        })?;
        Ok(Prediction {
            id: r.id,
            device: r.device,
            kernel: r.kernel,
            case: r.case,
            predicted_s: sm.model.predict(v),
            cache_hit: r.cache_hit,
            extract_s: r.extract_s,
            degraded: r.served_by.is_some(),
            served_by: r.served_by,
        })
    }

    /// Evaluate + finish a set of resolved requests through the batched
    /// SoA tape path: requests sharing one compiled tape program
    /// ([`KernelProps::tape_id`]) are grouped, identical bindings
    /// within a group are deduplicated into one lane, and each tape
    /// instruction is walked once across all lanes
    /// ([`KernelProps::eval_batch`]). Batched rows are bit-identical
    /// to scalar [`KernelProps::eval`] (pinned by the stats and tape
    /// test suites), so this is a pure throughput change.
    ///
    /// A batch evaluation error (an unbound parameter or an i64
    /// overflow in *any* lane) fails that group's batch as a whole; the
    /// affected requests then re-run on the scalar path so each gets
    /// its exact own diagnostic — an overflowing binding always comes
    /// back as that request's error, never as a wrapped value and never
    /// as another request's failure.
    fn finish_batched(
        &self,
        resolved: Vec<Result<Resolved, String>>,
    ) -> Vec<Result<Prediction, String>> {
        let m = self.schema.len();
        // group by compiled tape program; dedupe identical bindings
        // within a group (lane count = distinct envs, not requests)
        struct Group {
            props: Arc<KernelProps>,
            envs: Vec<Env>,
            /// (resolved index, lane) per member request
            members: Vec<(usize, usize)>,
        }
        let mut groups: BTreeMap<usize, Group> = BTreeMap::new();
        for (i, r) in resolved.iter().enumerate() {
            let Ok(r) = r else { continue };
            let g = groups.entry(r.props.tape_id()).or_insert_with(|| Group {
                props: Arc::clone(&r.props),
                envs: Vec::new(),
                members: Vec::new(),
            });
            let lane = match g.envs.iter().position(|e| *e == r.env) {
                Some(l) => l,
                None => {
                    g.envs.push(r.env.clone());
                    g.envs.len() - 1
                }
            };
            g.members.push((i, lane));
        }
        let mut rows: Vec<Option<Vec<f64>>> = (0..resolved.len()).map(|_| None).collect();
        let mut arena = BatchArena::new();
        let mut flat: Vec<f64> = Vec::new();
        let mut eval_span = Span::child("engine.tape_eval");
        if span::enabled() {
            eval_span.set_meta(format!("groups={} requests={}", groups.len(), resolved.len()));
        }
        for g in groups.into_values() {
            let env_refs: Vec<&Env> = g.envs.iter().collect();
            if g.props.eval_batch(&self.schema, &env_refs, &mut arena, &mut flat).is_ok() {
                for &(i, lane) in &g.members {
                    rows[i] = Some(flat[lane * m..(lane + 1) * m].to_vec());
                }
            }
            // on Err: leave the rows empty — the members fall back to
            // the scalar path below for per-request diagnostics
        }
        drop(eval_span);
        resolved
            .into_iter()
            .zip(rows)
            .map(|(r, row)| {
                let r = r?;
                match row {
                    Some(v) => self.finish(r, &v),
                    None => {
                        let v = r.props.eval(&self.schema, &r.env)?;
                        self.finish(r, &v)
                    }
                }
            })
            .collect()
    }

    /// Predict a batch of parsed requests, preserving input order.
    /// Resolution (parsing-adjacent lookups and the cached, possibly
    /// milliseconds-long symbolic extraction) runs in parallel on the
    /// executor; evaluation then runs batched per shared tape program
    /// ([`Engine::finish_batched`]). The request-line serving loops
    /// ([`crate::service::Service`]) ride this after parsing.
    pub fn predict_batch(
        &self,
        reqs: Vec<PredictRequest>,
        workers: usize,
    ) -> Vec<Result<Prediction, String>> {
        let resolved = par_map(reqs, workers, |r| self.resolve(&r));
        self.finish_batched(resolved)
    }

    /// One device×kernel matrix request: the kernel spec and binding
    /// are parsed once (upstream), then predicted for every named
    /// device — or, when the request names none, every device the
    /// installed store holds weights for. Per-device failures (no
    /// weights, group-size cap) are reported per cell; the call itself
    /// only fails when nothing can be resolved at all.
    pub fn predict_matrix(&self, req: &MatrixRequest) -> Result<MatrixPrediction, String> {
        let store = self.store_required()?;
        let devices = match &req.devices {
            Some(d) => d.clone(),
            None => store.devices(),
        };
        if devices.is_empty() {
            return Err("matrix request: the model store holds no devices".into());
        }
        let kernel = match &req.kref {
            KernelRef::Named { name, .. } => name.clone(),
            KernelRef::Inline(k) => k.name.clone(),
        };
        let case = match &req.kref {
            KernelRef::Named { case, .. } => case.clone(),
            KernelRef::Inline(_) => None,
        };
        // resolve serially (deterministic cache accounting: the first
        // device pays the one extraction, every later device hits),
        // then evaluate all cells in one batched pass — they share one
        // tape program and one binding, so the SoA evaluator walks the
        // kernel's instructions once for the whole row of devices
        let (names, resolved): (Vec<String>, Vec<Result<Resolved, String>>) = devices
            .into_iter()
            .map(|device| {
                let preq = PredictRequest {
                    id: None,
                    device: device.clone(),
                    kref: req.kref.clone(),
                    env: req.env.clone(),
                    deadline_ms: None,
                };
                let outcome = self.resolve(&preq);
                (device, outcome)
            })
            .unzip();
        let per_device = names.into_iter().zip(self.finish_batched(resolved)).collect();
        Ok(MatrixPrediction { id: req.id.clone(), kernel, case, per_device })
    }
}

/// Squared log-ratio distance between two device capability vectors:
/// peak f32 throughput, DRAM bandwidth and local-memory bandwidth, each
/// compared as `ln(a/b)²` so "half the bandwidth" and "double the
/// bandwidth" are equally far and absolute scale cancels out.
fn capability_distance(a: &DeviceProfile, b: &DeviceProfile) -> f64 {
    let ln_ratio = |x: f64, y: f64| (x.max(1e-300) / y.max(1e-300)).ln();
    let df = ln_ratio(a.peak_f32(), b.peak_f32());
    let db = ln_ratio(a.dram_bw, b.dram_bw);
    let dl = ln_ratio(a.local_bw, b.local_bw);
    df * df + db * db + dl * dl
}

/// The store device whose registry profile is capability-nearest to
/// `want` (degraded-mode fallback). Store order breaks ties, so the
/// choice is deterministic; store devices missing from the registry
/// (impossible for a serving-validated store) are skipped.
fn nearest_capability(
    store: &ModelStore,
    registry: &DeviceRegistry,
    want: &DeviceProfile,
) -> Option<String> {
    let mut best: Option<(f64, String)> = None;
    for device in store.devices() {
        let Some(profile) = registry.get(&device) else {
            continue;
        };
        let d = capability_distance(want, profile);
        let closer = match &best {
            None => true,
            Some((bd, _)) => d < *bd,
        };
        if closer {
            best = Some((d, device));
        }
    }
    best.map(|(_, device)| device)
}

/// Hot artifact reload: re-stat a `models.json` between batches or
/// connections and atomically swap the validated store into an
/// [`Engine`]. A bad new artifact (unparseable, stale fingerprints,
/// mismatched extraction options) leaves the old store serving.
pub struct Reloader {
    path: PathBuf,
    state: Mutex<ReloadState>,
    /// fault plan for the `reload.io` site (injected artifact I/O
    /// errors once a change is detected)
    faults: Option<Arc<FaultPlan>>,
}

struct ReloadState {
    /// (mtime, length) of the artifact as last examined — length joins
    /// the fingerprint so rewrites within one coarse mtime granule are
    /// still noticed when they change the payload size
    seen: Option<(SystemTime, u64)>,
    /// the watch file was unstatable last poll (deleted mid-serve);
    /// remembered so the condition is reported once, not per poll
    stat_failed: bool,
    /// the most recent reload failure (stat, parse, validate or an
    /// injected I/O fault) — errors are reported once and then
    /// suppressed while the file is unchanged, so the health surface
    /// keeps the last one visible here. Cleared by a successful swap.
    last_error: Option<String>,
}

impl Reloader {
    /// Watch `path`, treating its *current* state as already loaded —
    /// the first [`Reloader::maybe_reload`] only swaps if the file
    /// changed after this call.
    pub fn primed(path: &Path) -> Reloader {
        let seen = std::fs::metadata(path)
            .ok()
            .and_then(|m| m.modified().ok().map(|t| (t, m.len())));
        Reloader {
            path: path.to_path_buf(),
            state: Mutex::new(ReloadState {
                seen,
                stat_failed: false,
                last_error: None,
            }),
            faults: None,
        }
    }

    /// Attach a fault plan (builder-style; `None` detaches).
    pub fn with_faults(mut self, faults: Option<Arc<FaultPlan>>) -> Reloader {
        self.faults = faults;
        self
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The most recent reload failure, including ones whose per-poll
    /// reporting is already suppressed (`None` after a successful swap
    /// or when nothing ever failed).
    pub fn last_error(&self) -> Option<String> {
        mutex_lock(&self.state).last_error.clone()
    }

    /// If the watched file changed since last examined, load + validate
    /// + install it into `engine`. Returns `Ok(true)` when a new store
    /// was swapped in, `Ok(false)` when the file is unchanged, and
    /// `Err` when the changed file failed to stat, load or validate —
    /// the previously installed store keeps serving, and the failed
    /// state is remembered so the same broken artifact (or missing
    /// file) is reported once, not re-examined on every poll.
    ///
    /// Non-blocking: when another thread is already mid-poll, this
    /// returns `Ok(false)` immediately — concurrent per-connection
    /// serving loops never serialize on the watch, and the one winner
    /// pays for the stat (and, rarely, the load + validate) alone.
    pub fn maybe_reload(&self, engine: &Engine) -> Result<bool, String> {
        let Ok(mut state) = self.state.try_lock() else {
            return Ok(false);
        };
        let meta = match std::fs::metadata(&self.path) {
            Ok(m) => m,
            Err(e) => {
                if state.stat_failed {
                    return Ok(false); // already reported
                }
                state.stat_failed = true;
                let msg = format!("stat {}: {e}", self.path.display());
                state.last_error = Some(msg.clone());
                return Err(msg);
            }
        };
        state.stat_failed = false;
        let cur = (
            match meta.modified() {
                Ok(t) => t,
                Err(e) => {
                    let msg = format!("mtime {}: {e}", self.path.display());
                    state.last_error = Some(msg.clone());
                    return Err(msg);
                }
            },
            meta.len(),
        );
        if state.seen == Some(cur) {
            return Ok(false);
        }
        // remember the state up front: a broken artifact is reported
        // once and then ignored until it changes again
        state.seen = Some(cur);
        if let Some(plan) = &self.faults {
            if plan.should_inject("reload.io") {
                let msg = format!(
                    "injected artifact I/O failure reading {} (fault site reload.io)",
                    self.path.display()
                );
                state.last_error = Some(msg.clone());
                return Err(msg);
            }
        }
        let swap = ModelStore::load(&self.path, engine.schema())
            .and_then(|store| engine.install_store(store));
        match swap {
            Ok(()) => {
                state.last_error = None;
                Ok(true)
            }
            Err(e) => {
                state.last_error = Some(e.clone());
                Err(e)
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::service::testutil;

    /// A store whose prediction is exactly `const_w` for every case
    /// (only the constant column is weighted).
    fn toy_store(device: &str, const_w: f64) -> ModelStore {
        testutil::toy_store(&[(device, 0.0, const_w)])
    }

    fn engine_with(device: &str, const_w: f64) -> Engine {
        let engine = Engine::new(Config::default());
        engine.install_store(toy_store(device, const_w)).unwrap();
        engine
    }

    #[test]
    fn predict_requires_an_installed_store() {
        let engine = Engine::new(Config::default());
        let req = PredictRequest {
            id: None,
            device: "k40c".into(),
            kref: KernelRef::Named { name: "fd5".into(), case: Some("a".into()) },
            env: None,
            deadline_ms: None,
        };
        let e = engine.predict(&req).unwrap_err();
        assert!(e.contains("no model artifact"), "{e}");
        assert!(engine.store_snapshot().is_none());
    }

    #[test]
    fn install_store_swaps_atomically_and_validates() {
        let engine = engine_with("k40c", 5e-6);
        let req = PredictRequest {
            id: None,
            device: "k40c".into(),
            kref: KernelRef::Named { name: "fd5".into(), case: Some("a".into()) },
            env: None,
            deadline_ms: None,
        };
        let p1 = engine.predict(&req).unwrap().predicted_s;
        assert_eq!(p1, 5e-6);
        // swap in doubled weights: next prediction sees them
        engine.install_store(toy_store("k40c", 1e-5)).unwrap();
        assert_eq!(engine.predict(&req).unwrap().predicted_s, 1e-5);
        // an invalid store is refused and the good one keeps serving
        let mut bad = toy_store("k40c", 2e-5);
        bad.schema_fp = "0000000000000000".into();
        assert!(engine.install_store(bad).is_err());
        assert_eq!(engine.predict(&req).unwrap().predicted_s, 1e-5);
    }

    #[test]
    fn eval_suites_are_built_once_and_shared() {
        let engine = Engine::new(Config::default());
        let a = engine.eval_suite_for("k40c").unwrap();
        let b = engine.eval_suite_for("k40c").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert!(engine.eval_suite_for("gtx480").is_err());
    }

    #[test]
    fn matrix_prediction_covers_store_devices_and_reports_cell_errors() {
        let engine = Engine::new(Config::default());
        let mut store = toy_store("k40c", 5e-6);
        let titan = toy_store("titan_x", 7e-6);
        store.insert(titan.get("titan_x").unwrap().clone());
        engine.install_store(store).unwrap();

        let req = MatrixRequest {
            id: Some(Json::Num(9.0)),
            devices: None,
            kref: KernelRef::Named { name: "fd5".into(), case: Some("a".into()) },
            env: None,
            deadline_ms: None,
        };
        let mp = engine.predict_matrix(&req).unwrap();
        assert_eq!(mp.kernel, "fd5");
        assert_eq!(mp.case.as_deref(), Some("a"));
        assert_eq!(mp.per_device.len(), 2);
        let names: Vec<&str> = mp.per_device.iter().map(|(d, _)| d.as_str()).collect();
        assert_eq!(names, vec!["k40c", "titan_x"]);
        for (d, r) in &mp.per_device {
            let p = r.as_ref().unwrap();
            let want = if d == "k40c" { 5e-6 } else { 7e-6 };
            assert_eq!(p.predicted_s, want, "{d}");
        }

        // an explicit device list may name devices without weights —
        // that is a per-cell error, not a request failure
        let req = MatrixRequest {
            id: None,
            devices: Some(vec!["k40c".into(), "c2070".into()]),
            kref: KernelRef::Named { name: "fd5".into(), case: Some("a".into()) },
            env: None,
            deadline_ms: None,
        };
        let mp = engine.predict_matrix(&req).unwrap();
        assert!(mp.per_device[0].1.is_ok());
        let e = mp.per_device[1].1.as_ref().unwrap_err();
        assert!(e.contains("no fitted model"), "{e}");
    }

    #[test]
    fn reloader_swaps_on_change_and_keeps_old_store_on_bad_artifact() {
        let dir = std::env::temp_dir()
            .join(format!("uniperf_engine_reload_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("models.json");
        let schema = Schema::full();
        toy_store("k40c", 5e-6).save(&path, &schema).unwrap();

        let engine = Engine::new(Config::default());
        engine
            .install_store(ModelStore::load(&path, &schema).unwrap())
            .unwrap();
        let reloader = Reloader::primed(&path);
        let req = PredictRequest {
            id: None,
            device: "k40c".into(),
            kref: KernelRef::Named { name: "fd5".into(), case: Some("a".into()) },
            env: None,
            deadline_ms: None,
        };
        // unchanged file: no reload
        assert!(!reloader.maybe_reload(&engine).unwrap());
        assert_eq!(engine.predict(&req).unwrap().predicted_s, 5e-6);

        // rewritten artifact (different weight -> different byte length
        // too): swapped in atomically
        toy_store("k40c", 1.25e-5).save(&path, &schema).unwrap();
        assert!(reloader.maybe_reload(&engine).unwrap());
        assert_eq!(engine.predict(&req).unwrap().predicted_s, 1.25e-5);

        // a garbage rewrite errors once, keeps the old store, and is
        // not re-reported while unchanged
        std::fs::write(&path, "{not json at all").unwrap();
        assert!(reloader.maybe_reload(&engine).is_err());
        assert_eq!(engine.predict(&req).unwrap().predicted_s, 1.25e-5);
        assert!(!reloader.maybe_reload(&engine).unwrap());

        // recovery: a good artifact swaps in again
        toy_store("k40c", 2e-6).save(&path, &schema).unwrap();
        assert!(reloader.maybe_reload(&engine).unwrap());
        assert_eq!(engine.predict(&req).unwrap().predicted_s, 2e-6);

        // a deleted watch file errors once, then goes quiet until it
        // reappears (no per-poll report spam)
        std::fs::remove_file(&path).unwrap();
        assert!(reloader.maybe_reload(&engine).is_err());
        assert_eq!(reloader.maybe_reload(&engine), Ok(false));
        assert_eq!(engine.predict(&req).unwrap().predicted_s, 2e-6, "old store serves on");
        toy_store("k40c", 3e-6).save(&path, &schema).unwrap();
        assert!(reloader.maybe_reload(&engine).unwrap());
        assert_eq!(engine.predict(&req).unwrap().predicted_s, 3e-6);
    }

    fn predict_req(device: &str) -> PredictRequest {
        PredictRequest {
            id: None,
            device: device.into(),
            kref: KernelRef::Named { name: "fd5".into(), case: Some("a".into()) },
            env: None,
            deadline_ms: None,
        }
    }

    #[test]
    fn degraded_mode_answers_from_the_nearest_capability_device() {
        // store holds k40c only; titan_x is in the registry but unfitted
        let engine = Engine::new(Config { degraded: true, ..Config::default() });
        engine.install_store(toy_store("k40c", 5e-6)).unwrap();

        let p = engine.predict(&predict_req("titan_x")).unwrap();
        assert!(p.degraded);
        assert_eq!(p.served_by.as_deref(), Some("k40c"));
        assert_eq!(p.device, "titan_x", "the response names the requested device");
        assert_eq!(p.predicted_s, 5e-6);

        // a direct hit is never flagged
        let p = engine.predict(&predict_req("k40c")).unwrap();
        assert!(!p.degraded);
        assert!(p.served_by.is_none());

        // nearest-capability: with two candidates, the requested
        // device's own model wins over a farther one — and for c2070
        // (no weights) the choice is deterministic
        let mut store = toy_store("k40c", 5e-6);
        store.insert(toy_store("titan_x", 7e-6).get("titan_x").unwrap().clone());
        engine.install_store(store).unwrap();
        let p = engine.predict(&predict_req("c2070")).unwrap();
        assert!(p.degraded);
        // c2070 (Fermi, 1 TFLOP/s, 144 GB/s) is capability-closer to
        // k40c than to the much faster titan_x
        assert_eq!(p.served_by.as_deref(), Some("k40c"));
    }

    #[test]
    fn degraded_mode_off_by_default_keeps_the_error_contract() {
        let engine = engine_with("k40c", 5e-6);
        let e = engine.predict(&predict_req("titan_x")).unwrap_err();
        assert!(e.contains("no fitted model"), "{e}");
        // unknown devices stay errors even in degraded mode: the
        // registry, not the store, defines what exists
        let engine = Engine::new(Config { degraded: true, ..Config::default() });
        engine.install_store(toy_store("k40c", 5e-6)).unwrap();
        let e = engine.predict(&predict_req("gtx480")).unwrap_err();
        assert!(e.contains("unknown device"), "{e}");
    }

    #[test]
    fn extraction_breaker_opens_after_repeated_inline_failures() {
        use crate::lpir::builder::{gid_lin_1d, KernelBuilder};
        use crate::lpir::{Access, DType, Expr, Layout};
        use crate::qpoly::LinExpr;
        let engine = engine_with("k40c", 5e-6);
        // a *structurally valid* kernel whose extraction fails: array
        // `b`'s outer stride depends on `m`, which the kernel never
        // declares as a parameter — build() passes (ranks and inames
        // check out), the param-binding check passes (only `n` is
        // declared), and stride evaluation then dies with "unbound
        // parameter 'm'" on every request
        let bad = KernelBuilder::new("badk", &["n"])
            .group_dims_1d(LinExpr::var("n"), 64)
            .global_array("a", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .global_array(
                "b",
                DType::F32,
                vec![LinExpr::var("n"), LinExpr::var("m")],
                Layout::RowMajor,
                false,
            )
            .insn(
                Access::new("a", vec![gid_lin_1d(64)]),
                Expr::load("b", vec![gid_lin_1d(64), gid_lin_1d(64)]),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap();
        let req = PredictRequest {
            id: None,
            device: "k40c".into(),
            kref: KernelRef::Inline(Box::new(bad)),
            env: Some(vec![("n".to_string(), 4096_i64)]),
            deadline_ms: None,
        };
        let mut saw_breaker = false;
        for _ in 0..BREAKER_THRESHOLD + 2 {
            let e = engine.predict(&req).unwrap_err();
            if e.contains("circuit open") {
                saw_breaker = true;
                break;
            }
        }
        assert!(saw_breaker, "breaker never opened");
        assert_eq!(engine.breaker_open_count(), 1);
        assert_eq!(engine.breaker_trips(), 1);
        // named-kernel requests are unaffected
        assert!(engine.predict(&predict_req("k40c")).is_ok());
    }

    #[test]
    fn solver_fault_site_fails_construction_deterministically() {
        let plan = Arc::new(crate::util::fault::FaultPlan::new(1).site_max("solver.make", 1.0, 1));
        let engine = Engine::new(Config {
            backend: FitBackend::Native,
            faults: Some(plan.clone()),
            ..Config::default()
        });
        let e = engine.solver().unwrap_err();
        assert!(e.contains("solver.make"), "{e}");
        // ceiling reached: the next construction succeeds
        assert!(engine.solver().is_ok());
        assert_eq!(plan.injected("solver.make"), 1);
    }

    #[test]
    fn reloader_records_last_error_for_the_health_surface() {
        let dir = std::env::temp_dir()
            .join(format!("uniperf_reloader_err_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("models.json");
        let schema = Schema::full();
        toy_store("k40c", 5e-6).save(&path, &schema).unwrap();

        let engine = Engine::new(Config::default());
        engine.install_store(ModelStore::load(&path, &schema).unwrap()).unwrap();
        let reloader = Reloader::primed(&path);
        assert!(reloader.last_error().is_none());

        // a garbage rewrite: reported once, then suppressed — but the
        // health surface still sees it
        std::fs::write(&path, "{not json at all").unwrap();
        assert!(reloader.maybe_reload(&engine).is_err());
        assert!(!reloader.maybe_reload(&engine).unwrap());
        let err = reloader.last_error().unwrap();
        assert!(!err.is_empty());

        // recovery clears it
        toy_store("k40c", 6e-6).save(&path, &schema).unwrap();
        assert!(reloader.maybe_reload(&engine).unwrap());
        assert!(reloader.last_error().is_none());

        // injected reload.io fault: change detected, read fails once
        let plan = Arc::new(crate::util::fault::FaultPlan::new(3).site_max("reload.io", 1.0, 1));
        let reloader = Reloader::primed(&path).with_faults(Some(plan.clone()));
        toy_store("k40c", 7e-6).save(&path, &schema).unwrap();
        let e = reloader.maybe_reload(&engine).unwrap_err();
        assert!(e.contains("reload.io"), "{e}");
        assert_eq!(engine.predict(&predict_req("k40c")).unwrap().predicted_s, 6e-6);
        assert!(reloader.last_error().unwrap().contains("reload.io"));
        assert_eq!(plan.injected("reload.io"), 1);
        // the injected failure consumed the change; the *next* rewrite
        // reloads cleanly (ceiling reached)
        toy_store("k40c", 8e-6).save(&path, &schema).unwrap();
        assert!(reloader.maybe_reload(&engine).unwrap());
        assert!(reloader.last_error().is_none());
        assert_eq!(engine.predict(&predict_req("k40c")).unwrap().predicted_s, 8e-6);
    }
}
