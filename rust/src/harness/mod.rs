//! Measurement harness: the paper's §4.2 execution + timing protocol.
//!
//! * Launch-overhead calibration with the empty kernel.
//! * 30 timed runs per case, discarding the first 4 (first-touch
//!   allocation and second-run variance), taking the minimum.
//! * Minimum-size filtering: cases whose run time does not comfortably
//!   exceed the launch overhead are excluded (the paper adjusts minimum
//!   sizes per device for the same reason).
//! * Property extraction is cached per kernel: the symbolic counts are
//!   extracted once and re-evaluated per size case (the paper's "cheaply
//!   reevaluated for changed values of the parameter vector").
//! * Campaign persistence as JSON.

use crate::gpusim::SimGpu;
use crate::kernels::KernelCase;
use crate::perfmodel::PropertyMatrix;
use crate::stats::{extract, ExtractOpts, KernelProps, Schema};
use crate::util::executor::par_map;
use crate::util::json::Json;
use std::collections::BTreeMap;


/// The §4.2 timing protocol.
#[derive(Clone, Copy, Debug)]
pub struct Protocol {
    /// total runs per kernel configuration
    pub runs: usize,
    /// leading runs to discard (first-touch + second-run variance)
    pub discard: usize,
    /// cases faster than `min_time_factor · launch_overhead` are dropped
    /// (except the empty kernel, which *measures* the overhead)
    pub min_time_factor: f64,
}

impl Default for Protocol {
    fn default() -> Self {
        Protocol { runs: 30, discard: 4, min_time_factor: 2.0 }
    }
}

impl Protocol {
    /// The runs retained after the warmup discard. Degenerate input —
    /// an empty `times` slice — is an explicit error rather than the
    /// silent `+inf`/`NaN` the naive fold would produce; when fewer
    /// runs than `discard` exist, the final run is retained so the
    /// reduction always has at least one sample.
    fn retained<'a>(&self, times: &'a [f64]) -> Result<&'a [f64], String> {
        if times.is_empty() {
            return Err("timing protocol: no runs to reduce".into());
        }
        Ok(&times[self.discard.min(times.len() - 1)..])
    }

    /// Reduce raw per-run times to the reported wall time: minimum of the
    /// retained runs (§4.2; the minimum and the mean differ by <5% when
    /// times exceed the overhead — validated in `benches/protocol.rs`).
    /// Errors on empty input.
    pub fn reduce(&self, times: &[f64]) -> Result<f64, String> {
        Ok(self.retained(times)?.iter().cloned().fold(f64::INFINITY, f64::min))
    }

    /// Mean of the retained runs (for the §4.2 min-vs-mean validation).
    /// Errors on empty input.
    pub fn reduce_mean(&self, times: &[f64]) -> Result<f64, String> {
        let kept = self.retained(times)?;
        Ok(kept.iter().sum::<f64>() / kept.len() as f64)
    }
}

/// One timing observation for protocol reduction: either a genuinely
/// timed run, or a **zero-cost cache hit** (the work was answered from a
/// cache/artifact and nothing ran). Cache hits used to be tempting to
/// record as `0.0` seconds, which silently poisons min-of-runs
/// statistics (the minimum becomes 0 and every real sample is
/// discarded); the distinct marker makes them reportable without
/// entering the reduction. Used by the prediction service's per-request
/// extraction-time accounting ([`crate::service`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sample {
    /// a real wall-time observation, in seconds
    Timed(f64),
    /// answered from cache; excluded from timing reductions
    Cached,
}

impl Sample {
    /// The wall time, if this sample was actually timed.
    pub fn timed(&self) -> Option<f64> {
        match self {
            Sample::Timed(t) => Some(*t),
            Sample::Cached => None,
        }
    }

    pub fn is_cached(&self) -> bool {
        matches!(self, Sample::Cached)
    }
}

impl Protocol {
    /// Reduce a mixed stream of [`Sample`]s: `Cached` markers are
    /// excluded *before* the warmup discard and min-of-runs reduction
    /// (they are not fast runs — they are non-runs). Errors when no
    /// timed sample remains.
    pub fn reduce_samples(&self, samples: &[Sample]) -> Result<f64, String> {
        let times: Vec<f64> = samples.iter().filter_map(Sample::timed).collect();
        if times.is_empty() {
            return Err(
                "timing protocol: only cached samples (no timed run to reduce)".into()
            );
        }
        self.reduce(&times)
    }
}

/// One measured + extracted case.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub label: String,
    pub props: Vec<f64>,
    pub time_s: f64,
}

/// Calibrate the device's launch overhead by timing the empty kernel at
/// its smallest configuration (§4.2). The group shape is the device's
/// standard 2-D shape ((16, 16) on every part admitting 256-thread
/// groups), so calibration works for any registry profile, including
/// ones with smaller group caps.
pub fn calibrate_overhead(gpu: &SimGpu, protocol: &Protocol) -> Result<f64, String> {
    let (gx, gy) = crate::kernels::two_d_groups(&gpu.profile).standard();
    let k = crate::kernels::measure::empty(gx, gy);
    let n = crate::kernels::snap(16 * gx.max(gy), crate::kernels::lcm(gx, gy));
    let env = crate::qpoly::env(&[("n", n)]);
    let times = gpu.time(&k, &env, protocol.runs)?;
    protocol.reduce(&times)
}

/// Extraction cache: symbolic properties are computed once per distinct
/// kernel (name + group) and re-evaluated per parameter binding.
#[derive(Default)]
pub struct PropsCache {
    cache: BTreeMap<String, KernelProps>,
}

impl PropsCache {
    pub fn props_for(
        &mut self,
        case: &KernelCase,
        opts: ExtractOpts,
    ) -> Result<KernelProps, String> {
        let key = format!("{}/{}x{}/{}", case.kernel.name, case.group.0, case.group.1,
            opts.collapse_utilization);
        if let Some(p) = self.cache.get(&key) {
            return Ok(p.clone());
        }
        let p = extract(&case.kernel, &case.env, opts)?;
        self.cache.insert(key, p.clone());
        Ok(p)
    }

    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }
}

/// Measure a set of cases (timing + dense property evaluation) without
/// the minimum-size filter, returning one [`Measurement`] per input case
/// in order. Symbolic extraction runs once per distinct kernel through a
/// [`PropsCache`]; timing and tape evaluation fan out over `workers`.
/// Used by [`run_campaign`] and by the cross-validation subsystem
/// ([`crate::crossval`]) to measure the evaluation-kernel zoo.
pub fn measure_cases(
    gpu: &SimGpu,
    cases: &[KernelCase],
    schema: &Schema,
    protocol: &Protocol,
    opts: ExtractOpts,
    workers: usize,
) -> Result<Vec<Measurement>, String> {
    // symbolic extraction once per kernel (sequential: the cache is shared)
    let mut cache = PropsCache::default();
    let mut sym: Vec<KernelProps> = Vec::with_capacity(cases.len());
    for case in cases {
        sym.push(cache.props_for(case, opts)?);
    }

    // timing + evaluation in parallel over cases
    let work: Vec<(usize, &KernelCase)> = cases.iter().enumerate().collect();
    let results = par_map(work, workers, |(i, case)| -> Result<Measurement, String> {
        let times = gpu.time(&case.kernel, &case.env, protocol.runs)?;
        let time_s = protocol.reduce(&times)?;
        let props = sym[i].eval(schema, &case.env)?;
        Ok(Measurement { label: case.label.clone(), props, time_s })
    });
    results.into_iter().collect()
}

/// Run a measurement campaign: time every case with the protocol, extract
/// property vectors, apply the minimum-size filter, and assemble the
/// [`PropertyMatrix`] for fitting.
pub fn run_campaign(
    gpu: &SimGpu,
    cases: &[KernelCase],
    schema: &Schema,
    protocol: &Protocol,
    opts: ExtractOpts,
    workers: usize,
) -> Result<(PropertyMatrix, f64), String> {
    let overhead = calibrate_overhead(gpu, protocol)?;
    let measurements = measure_cases(gpu, cases, schema, protocol, opts, workers)?;
    let mut pm = PropertyMatrix::default();
    for m in measurements {
        let is_empty_kernel = m.label.starts_with("empty/");
        if !is_empty_kernel && m.time_s < protocol.min_time_factor * overhead {
            continue; // below the reliable-timing floor (§4.2)
        }
        pm.push(m.label, m.props, m.time_s);
    }
    if pm.n_cases() == 0 {
        return Err("all cases filtered out by the overhead floor".into());
    }
    Ok((pm, overhead))
}

/// Persist a campaign to JSON.
pub fn campaign_to_json(pm: &PropertyMatrix, device: &str, overhead: f64) -> Json {
    Json::obj(vec![
        ("device", Json::Str(device.into())),
        ("launch_overhead_s", Json::Num(overhead)),
        (
            "cases",
            Json::Arr(
                pm.cases
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("label", Json::Str(c.label.clone())),
                            ("time_s", Json::Num(c.time_s)),
                            (
                                "props",
                                Json::Arr(c.props.iter().map(|&p| Json::Num(p)).collect()),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Load a campaign from JSON produced by [`campaign_to_json`].
pub fn campaign_from_json(j: &Json) -> Result<(PropertyMatrix, String, f64), String> {
    let device = j.get("device").and_then(Json::as_str).ok_or("missing device")?.to_string();
    let overhead =
        j.get("launch_overhead_s").and_then(Json::as_f64).ok_or("missing overhead")?;
    let mut pm = PropertyMatrix::default();
    for case in j.get("cases").and_then(Json::as_arr).ok_or("missing cases")? {
        let label = case.get("label").and_then(Json::as_str).ok_or("missing label")?;
        let time = case.get("time_s").and_then(Json::as_f64).ok_or("missing time")?;
        let props: Vec<f64> = case
            .get("props")
            .and_then(Json::as_arr)
            .ok_or("missing props")?
            .iter()
            .map(|p| p.as_f64().ok_or_else(|| "bad prop".to_string()))
            .collect::<Result<_, _>>()?;
        pm.push(label.to_string(), props, time);
    }
    Ok((pm, device, overhead))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::measure;
    use crate::qpoly::env;

    #[test]
    fn protocol_reduce_drops_warmup() {
        let p = Protocol::default();
        let mut times = vec![10.0, 5.0, 1.5, 1.4]; // discarded
        times.extend(vec![1.2, 1.1, 1.3, 1.15]);
        assert_eq!(p.reduce(&times).unwrap(), 1.1);
        let mean = p.reduce_mean(&times).unwrap();
        assert!((mean - 1.1875).abs() < 1e-12);
    }

    #[test]
    fn protocol_reduce_rejects_empty_and_handles_short_input() {
        let p = Protocol::default();
        // degenerate: no runs at all -> error, not +inf/NaN
        assert!(p.reduce(&[]).is_err());
        assert!(p.reduce_mean(&[]).is_err());
        // fewer runs than the discard window: the last run is retained
        assert_eq!(p.reduce(&[3.0, 2.0]).unwrap(), 2.0);
        assert_eq!(p.reduce_mean(&[3.0, 2.0]).unwrap(), 2.0);
        // exactly one run
        assert_eq!(p.reduce(&[7.0]).unwrap(), 7.0);
    }

    #[test]
    fn cached_samples_carry_a_marker_not_a_zero() {
        let p = Protocol { runs: 8, discard: 2, min_time_factor: 2.0 };
        // the naive encoding of a cache hit — a 0-second sample —
        // poisons the min-of-runs statistic:
        assert_eq!(p.reduce(&[3.0, 2.5, 2.0, 0.0, 2.1]).unwrap(), 0.0);
        // the distinct marker keeps hits out of the reduction entirely
        let samples = [
            Sample::Timed(3.0),
            Sample::Timed(2.5),
            Sample::Cached,
            Sample::Timed(2.0),
            Sample::Cached,
            Sample::Timed(2.1),
        ];
        let timed: Vec<f64> = samples.iter().filter_map(Sample::timed).collect();
        assert_eq!(
            p.reduce_samples(&samples).unwrap(),
            p.reduce(&timed).unwrap()
        );
        assert_eq!(p.reduce_samples(&samples).unwrap(), 2.0);
        // marker bookkeeping
        assert!(Sample::Cached.is_cached());
        assert_eq!(Sample::Timed(1.5).timed(), Some(1.5));
        assert_eq!(Sample::Cached.timed(), None);
    }

    #[test]
    fn all_cached_is_an_error_not_a_degenerate_min() {
        let p = Protocol::default();
        let e = p.reduce_samples(&[Sample::Cached, Sample::Cached]).unwrap_err();
        assert!(e.contains("cached"), "{e}");
        assert!(p.reduce_samples(&[]).is_err());
    }

    #[test]
    fn overhead_calibration_positive() {
        let gpu = SimGpu::named("r9_fury").unwrap();
        let o = calibrate_overhead(&gpu, &Protocol::default()).unwrap();
        // the Fury has ~45 µs launch overhead
        assert!(o > 20e-6 && o < 200e-6, "{o}");
    }

    #[test]
    fn small_campaign_runs_and_filters() {
        let gpu = SimGpu::named("titan_x").unwrap();
        let schema = Schema::full();
        // a small slice: copy kernels at several sizes
        let k = measure::global_access(measure::GlobalAccessConfig::Copy, 256);
        let mut cases = Vec::new();
        for t in 0..5 {
            let n = 1i64 << (14 + 2 * t);
            cases.push(KernelCase {
                kernel: k.clone(),
                env: env(&[("n", n)]),
                label: format!("sg_copy/n={n}/g=256"),
                group: (256, 1),
            });
        }
        let (pm, overhead) = run_campaign(
            &gpu,
            &cases,
            &schema,
            &Protocol::default(),
            ExtractOpts::default(),
            2,
        )
        .unwrap();
        assert!(overhead > 0.0);
        assert!(pm.n_cases() >= 3, "kept {}", pm.n_cases());
        // larger sizes must be kept; tiny ones may be filtered
        assert!(pm.cases.iter().any(|c| c.label.contains("n=4194304")));
    }

    #[test]
    fn measure_cases_keeps_every_case_in_order() {
        let gpu = SimGpu::named("titan_x").unwrap();
        let schema = Schema::full();
        let k = measure::global_access(measure::GlobalAccessConfig::Copy, 256);
        let mut cases = Vec::new();
        for t in 0..5 {
            // includes tiny sizes that run_campaign would filter out
            let n = 1i64 << (10 + 2 * t);
            cases.push(KernelCase {
                kernel: k.clone(),
                env: env(&[("n", n)]),
                label: format!("sg_copy/n={n}/g=256"),
                group: (256, 1),
            });
        }
        let ms = measure_cases(
            &gpu,
            &cases,
            &schema,
            &Protocol::default(),
            ExtractOpts::default(),
            2,
        )
        .unwrap();
        assert_eq!(ms.len(), cases.len());
        for (m, c) in ms.iter().zip(&cases) {
            assert_eq!(m.label, c.label);
            assert!(m.time_s > 0.0);
        }
    }

    #[test]
    fn campaign_json_roundtrip() {
        let mut pm = PropertyMatrix::default();
        pm.push("a".into(), vec![1.0, 0.0, 2.0], 1e-3);
        pm.push("b".into(), vec![0.0, 3.0, 4.0], 2e-3);
        let j = campaign_to_json(&pm, "k40c", 8e-6);
        let parsed = Json::parse(&j.pretty()).unwrap();
        let (pm2, dev, ovh) = campaign_from_json(&parsed).unwrap();
        assert_eq!(dev, "k40c");
        assert_eq!(ovh, 8e-6);
        assert_eq!(pm2.n_cases(), 2);
        assert_eq!(pm2.cases[0].props, vec![1.0, 0.0, 2.0]);
        assert_eq!(pm2.cases[1].time_s, 2e-3);
    }

    #[test]
    fn props_cache_reuses_symbolic_extraction() {
        let k = measure::global_access(measure::GlobalAccessConfig::Copy, 256);
        let mut cache = PropsCache::default();
        for t in 0..4 {
            let case = KernelCase {
                kernel: k.clone(),
                env: env(&[("n", 1i64 << (16 + t))]),
                label: format!("c{t}"),
                group: (256, 1),
            };
            cache.props_for(&case, ExtractOpts::default()).unwrap();
        }
        assert_eq!(cache.len(), 1);
    }
}
