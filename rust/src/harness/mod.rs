//! Measurement harness: the paper's §4.2 execution + timing protocol.
//!
//! * Launch-overhead calibration with the empty kernel.
//! * 30 timed runs per case, discarding the first 4 (first-touch
//!   allocation and second-run variance), taking the minimum.
//! * Minimum-size filtering: cases whose run time does not comfortably
//!   exceed the launch overhead are excluded (the paper adjusts minimum
//!   sizes per device for the same reason).
//! * Property extraction is cached per kernel: the symbolic counts are
//!   extracted once and re-evaluated per size case (the paper's "cheaply
//!   reevaluated for changed values of the parameter vector"). The
//!   re-evaluation itself is batched: cases sharing one cached
//!   extraction are evaluated in a single structure-of-arrays pass over
//!   the compiled tapes ([`KernelProps::eval_batch`]) instead of one
//!   allocating scalar walk per case — bit-identical rows, one tape
//!   traversal per kernel per campaign.
//! * Campaign persistence as JSON.

pub mod meascache;

pub use meascache::MeasCacheFile;

use crate::gpusim::SimGpu;
use crate::kernels::KernelCase;
use crate::lpir::Kernel;
use crate::obs::metrics;
use crate::obs::span::{self, Span};
use crate::perfmodel::PropertyMatrix;
use crate::stats::{extract, BatchArena, ExtractOpts, KernelProps, Schema};
use crate::util::executor::par_map;
use crate::util::intern::Env;
use crate::util::json::Json;
use std::collections::BTreeMap;


/// The §4.2 timing protocol.
#[derive(Clone, Copy, Debug)]
pub struct Protocol {
    /// total runs per kernel configuration
    pub runs: usize,
    /// leading runs to discard (first-touch + second-run variance)
    pub discard: usize,
    /// cases faster than `min_time_factor · launch_overhead` are dropped
    /// (except the empty kernel, which *measures* the overhead)
    pub min_time_factor: f64,
    /// extra attempts when a timing run fails outright (transient
    /// measurement errors); 0 = fail on the first error
    pub retries: usize,
    /// MAD outlier rejection: retained samples more than `mad_k`
    /// median-absolute-deviations from the median are dropped before
    /// reduction. 0.0 (the default) disables the filter, keeping the
    /// reduction byte-identical to the historical protocol. The filter
    /// matters because the reduction is min-of-runs: a spuriously *fast*
    /// sample (measurement glitch, cache artifact) poisons the minimum,
    /// while slow outliers are already harmless.
    pub mad_k: f64,
}

impl Default for Protocol {
    fn default() -> Self {
        Protocol {
            runs: 30,
            discard: 4,
            min_time_factor: 2.0,
            retries: 2,
            mad_k: 0.0,
        }
    }
}

/// Reject samples more than `k` MADs from the median. The MAD scale is
/// floored at a relative epsilon of the median so a perfectly-repeating
/// stream (MAD = 0) doesn't reject every sample; if rejection would
/// empty the input (pathological `k`), the input is returned unchanged.
pub fn mad_filter(times: &[f64], k: f64) -> Vec<f64> {
    fn median(sorted: &[f64]) -> f64 {
        let n = sorted.len();
        if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        }
    }
    if times.len() < 3 {
        return times.to_vec();
    }
    let mut sorted = times.to_vec();
    sorted.sort_by(f64::total_cmp);
    let m = median(&sorted);
    let mut dev: Vec<f64> = times.iter().map(|t| (t - m).abs()).collect();
    dev.sort_by(f64::total_cmp);
    let scale = median(&dev).max(1e-12 * m.abs());
    let kept: Vec<f64> =
        times.iter().cloned().filter(|t| (t - m).abs() <= k * scale).collect();
    if kept.is_empty() {
        times.to_vec()
    } else {
        kept
    }
}

impl Protocol {
    /// The runs retained after the warmup discard. Degenerate input —
    /// an empty `times` slice — is an explicit error rather than the
    /// silent `+inf`/`NaN` the naive fold would produce; when fewer
    /// runs than `discard` exist, the final run is retained so the
    /// reduction always has at least one sample.
    fn retained<'a>(&self, times: &'a [f64]) -> Result<&'a [f64], String> {
        if times.is_empty() {
            return Err("timing protocol: no runs to reduce".into());
        }
        Ok(&times[self.discard.min(times.len() - 1)..])
    }

    /// The retained runs after warmup discard and (when `mad_k > 0`)
    /// MAD outlier rejection.
    fn kept(&self, times: &[f64]) -> Result<Vec<f64>, String> {
        let retained = self.retained(times)?;
        if self.mad_k > 0.0 {
            Ok(mad_filter(retained, self.mad_k))
        } else {
            Ok(retained.to_vec())
        }
    }

    /// Reduce raw per-run times to the reported wall time: minimum of the
    /// retained runs (§4.2; the minimum and the mean differ by <5% when
    /// times exceed the overhead — validated in `benches/protocol.rs`),
    /// after MAD outlier rejection when `mad_k > 0`. Errors on empty
    /// input.
    pub fn reduce(&self, times: &[f64]) -> Result<f64, String> {
        Ok(self.kept(times)?.iter().cloned().fold(f64::INFINITY, f64::min))
    }

    /// Mean of the retained runs (for the §4.2 min-vs-mean validation).
    /// Errors on empty input.
    pub fn reduce_mean(&self, times: &[f64]) -> Result<f64, String> {
        let kept = self.kept(times)?;
        Ok(kept.iter().sum::<f64>() / kept.len() as f64)
    }
}

/// One timing observation for protocol reduction: either a genuinely
/// timed run, or a **zero-cost cache hit** (the work was answered from a
/// cache/artifact and nothing ran). Cache hits used to be tempting to
/// record as `0.0` seconds, which silently poisons min-of-runs
/// statistics (the minimum becomes 0 and every real sample is
/// discarded); the distinct marker makes them reportable without
/// entering the reduction. Used by the prediction service's per-request
/// extraction-time accounting ([`crate::service`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sample {
    /// a real wall-time observation, in seconds
    Timed(f64),
    /// answered from cache; excluded from timing reductions
    Cached,
}

impl Sample {
    /// The wall time, if this sample was actually timed.
    pub fn timed(&self) -> Option<f64> {
        match self {
            Sample::Timed(t) => Some(*t),
            Sample::Cached => None,
        }
    }

    pub fn is_cached(&self) -> bool {
        matches!(self, Sample::Cached)
    }
}

impl Protocol {
    /// Reduce a mixed stream of [`Sample`]s: `Cached` markers are
    /// excluded *before* the warmup discard and min-of-runs reduction
    /// (they are not fast runs — they are non-runs). Errors when no
    /// timed sample remains.
    pub fn reduce_samples(&self, samples: &[Sample]) -> Result<f64, String> {
        let times: Vec<f64> = samples.iter().filter_map(Sample::timed).collect();
        if times.is_empty() {
            return Err(
                "timing protocol: only cached samples (no timed run to reduce)".into()
            );
        }
        self.reduce(&times)
    }
}

/// One measured + extracted case.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub label: String,
    pub props: Vec<f64>,
    pub time_s: f64,
}

/// Time one kernel configuration under the protocol's retry budget:
/// outright timing failures (transient measurement errors, injected
/// `measure.fail` faults) are retried up to `protocol.retries` extra
/// times before the last error is surfaced.
pub fn time_with_retry(
    gpu: &SimGpu,
    kernel: &Kernel,
    env: &Env,
    protocol: &Protocol,
) -> Result<Vec<f64>, String> {
    let budget = protocol.retries + 1;
    // Warm path: an attached measurement cache replays the raw stream
    // with zero simulation. A fault plan bypasses the cache entirely —
    // counter-based fault draws must advance exactly as they would
    // live, and corrupted streams must never be recorded.
    let cache = if gpu.faults.is_none() { gpu.meas.as_deref() } else { None };
    if let Some(mc) = cache {
        if let Some(times) = mc.lookup(&gpu.profile, kernel, env, protocol.runs, gpu.seed) {
            return Ok(times);
        }
    }
    // The compiled artifact, base time and stream hash are paid once;
    // retry attempts only re-run noise sampling plus the fault plan. A
    // lowering error is deterministic — it would fail every attempt
    // identically — so it surfaces immediately, message unchanged.
    let prepared = match gpu.prepare(kernel, env) {
        Ok(p) => p,
        Err(e) => return Err(format!("measurement failed after {budget} attempt(s): {e}")),
    };
    let mut last = String::new();
    for _ in 0..budget {
        match prepared.time(protocol.runs) {
            Ok(times) => {
                if let Some(mc) = cache {
                    mc.store(&gpu.profile, kernel, env, protocol.runs, gpu.seed, &times);
                }
                return Ok(times);
            }
            Err(e) => last = e,
        }
    }
    Err(format!("measurement failed after {budget} attempt(s): {last}"))
}

/// Calibrate the device's launch overhead by timing the empty kernel at
/// its smallest configuration (§4.2). The group shape is the device's
/// standard 2-D shape ((16, 16) on every part admitting 256-thread
/// groups), so calibration works for any registry profile, including
/// ones with smaller group caps.
pub fn calibrate_overhead(gpu: &SimGpu, protocol: &Protocol) -> Result<f64, String> {
    let (gx, gy) = crate::kernels::two_d_groups(&gpu.profile).standard();
    let k = crate::kernels::measure::empty(gx, gy);
    let n = crate::kernels::snap(16 * gx.max(gy), crate::kernels::lcm(gx, gy));
    let env = crate::qpoly::env(&[("n", n)]);
    let times = time_with_retry(gpu, &k, &env, protocol)?;
    protocol.reduce(&times)
}

/// Extraction cache: symbolic properties are computed once per distinct
/// kernel (name + group) and re-evaluated per parameter binding.
#[derive(Default)]
pub struct PropsCache {
    cache: BTreeMap<String, KernelProps>,
}

impl PropsCache {
    pub fn props_for(
        &mut self,
        case: &KernelCase,
        opts: ExtractOpts,
    ) -> Result<KernelProps, String> {
        let key = format!("{}/{}x{}/{}", case.kernel.name, case.group.0, case.group.1,
            opts.collapse_utilization);
        if let Some(p) = self.cache.get(&key) {
            return Ok(p.clone());
        }
        let p = extract(&case.kernel, &case.env, opts)?;
        self.cache.insert(key, p.clone());
        Ok(p)
    }

    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }
}

/// Batched property evaluation for a measurement campaign: items
/// sharing one compiled tape program ([`KernelProps::tape_id`] — i.e.
/// one [`PropsCache`] entry) are grouped and evaluated in a single
/// [`KernelProps::eval_batch`] SoA pass. Rows come back per item, in
/// order, bit-identical to scalar [`KernelProps::eval`]. A group whose
/// batch fails (an unbound parameter or i64 overflow in *any* of its
/// bindings fails the whole batch) re-runs each member on the scalar
/// path, so error attribution stays per case — a robust campaign
/// quarantines exactly the offending case, not its whole kernel group.
pub(crate) fn eval_props_batched(
    items: &[(&KernelProps, &Env)],
    schema: &Schema,
) -> Vec<Result<Vec<f64>, String>> {
    let m = schema.len();
    let mut groups: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for (i, (p, _)) in items.iter().enumerate() {
        groups.entry(p.tape_id()).or_default().push(i);
    }
    let mut rows: Vec<Result<Vec<f64>, String>> =
        (0..items.len()).map(|_| Ok(Vec::new())).collect();
    let mut arena = BatchArena::new();
    let mut flat: Vec<f64> = Vec::new();
    for members in groups.into_values() {
        let (props, _) = items[members[0]];
        let envs: Vec<&Env> = members.iter().map(|&i| items[i].1).collect();
        match props.eval_batch(schema, &envs, &mut arena, &mut flat) {
            Ok(()) => {
                for (lane, &i) in members.iter().enumerate() {
                    rows[i] = Ok(flat[lane * m..(lane + 1) * m].to_vec());
                }
            }
            Err(_) => {
                for &i in &members {
                    let (p, env) = items[i];
                    rows[i] = p.eval(schema, env);
                }
            }
        }
    }
    rows
}

/// Measure a set of cases (timing + dense property evaluation) without
/// the minimum-size filter, returning one [`Measurement`] per input case
/// in order. Symbolic extraction runs once per distinct kernel through a
/// [`PropsCache`]; timing and tape evaluation fan out over `workers`.
/// Used by [`run_campaign`] and by the cross-validation subsystem
/// ([`crate::crossval`]) to measure the evaluation-kernel zoo.
pub fn measure_cases(
    gpu: &SimGpu,
    cases: &[KernelCase],
    schema: &Schema,
    protocol: &Protocol,
    opts: ExtractOpts,
    workers: usize,
) -> Result<Vec<Measurement>, String> {
    // symbolic extraction once per kernel (sequential: the cache is shared)
    let mut cache = PropsCache::default();
    let mut sym: Vec<KernelProps> = Vec::with_capacity(cases.len());
    for case in cases {
        sym.push(cache.props_for(case, opts)?);
    }
    // batched property evaluation: one SoA tape pass per distinct kernel
    let items: Vec<(&KernelProps, &Env)> =
        sym.iter().zip(cases).map(|(p, c)| (p, &c.env)).collect();
    let rows = eval_props_batched(&items, schema);

    // campaign-plane accounting: one labeled counter per device
    metrics::campaign()
        .counter(&format!("campaign_cases_total{{device=\"{}\"}}", gpu.profile.name))
        .add(cases.len() as u64);

    // timing in parallel over cases
    let work: Vec<(usize, &KernelCase)> = cases.iter().enumerate().collect();
    let mut measure_span = Span::child("harness.measure");
    if span::enabled() {
        measure_span.set_meta(format!("cases={}", work.len()));
    }
    let results = par_map(work, workers, |(i, case)| -> Result<Measurement, String> {
        let times = time_with_retry(gpu, &case.kernel, &case.env, protocol)?;
        let time_s = protocol.reduce(&times)?;
        let props = rows[i].as_ref().map_err(Clone::clone)?.clone();
        Ok(Measurement { label: case.label.clone(), props, time_s })
    });
    drop(measure_span);
    results.into_iter().collect()
}

/// Run a measurement campaign: time every case with the protocol, extract
/// property vectors, apply the minimum-size filter, and assemble the
/// [`PropertyMatrix`] for fitting.
pub fn run_campaign(
    gpu: &SimGpu,
    cases: &[KernelCase],
    schema: &Schema,
    protocol: &Protocol,
    opts: ExtractOpts,
    workers: usize,
) -> Result<(PropertyMatrix, f64), String> {
    let overhead = calibrate_overhead(gpu, protocol)?;
    let measurements = measure_cases(gpu, cases, schema, protocol, opts, workers)?;
    let mut pm = PropertyMatrix::default();
    for m in measurements {
        let is_empty_kernel = m.label.starts_with("empty/");
        if !is_empty_kernel && m.time_s < protocol.min_time_factor * overhead {
            continue; // below the reliable-timing floor (§4.2)
        }
        pm.push(m.label, m.props, m.time_s);
    }
    if pm.n_cases() == 0 {
        return Err("all cases filtered out by the overhead floor".into());
    }
    Ok((pm, overhead))
}

/// A case excluded from a robust campaign, with the reason it failed
/// (carried into the report instead of aborting the device).
#[derive(Clone, Debug, PartialEq)]
pub struct Quarantine {
    pub label: String,
    pub reason: String,
}

/// What a robust campaign produced: the fit-ready matrix plus the
/// degradations that occurred along the way.
#[derive(Clone, Debug)]
pub struct CampaignOutcome {
    pub matrix: PropertyMatrix,
    pub overhead: f64,
    /// `Some` when launch-overhead calibration failed and the campaign
    /// fell back to the zero-overhead default (disabling the
    /// minimum-size floor for this device).
    pub overhead_warning: Option<String>,
    /// Cases that failed measurement or extraction after the retry
    /// budget, excluded from the fit.
    pub quarantined: Vec<Quarantine>,
}

/// [`run_campaign`] with graceful degradation: calibration failure falls
/// back to a zero launch overhead (with a warning — the minimum-size
/// floor is disabled, so the fit sees every case and §4.2's
/// unreliable-timing protection is lost for this device only), and a
/// case that fails measurement or extraction after the retry budget is
/// **quarantined** — recorded with its reason and excluded from the fit
/// — instead of aborting the whole device campaign. Fault-free runs
/// produce a matrix identical to [`run_campaign`]'s.
///
/// Errors only when *no* case survives: a fit needs at least one row.
pub fn run_campaign_robust(
    gpu: &SimGpu,
    cases: &[KernelCase],
    schema: &Schema,
    protocol: &Protocol,
    opts: ExtractOpts,
    workers: usize,
) -> Result<CampaignOutcome, String> {
    let calibrate_span = Span::child("harness.calibrate");
    let (overhead, overhead_warning) = match calibrate_overhead(gpu, protocol) {
        Ok(o) => (o, None),
        Err(e) => (
            0.0,
            Some(format!(
                "launch-overhead calibration failed ({e}); falling back to the \
                 zero-overhead default — the minimum-size floor is disabled for \
                 this campaign"
            )),
        ),
    };
    drop(calibrate_span);

    // symbolic extraction once per kernel; a failure quarantines every
    // case of that kernel rather than aborting
    let mut cache = PropsCache::default();
    let mut sym: Vec<Result<KernelProps, String>> = Vec::with_capacity(cases.len());
    for case in cases {
        sym.push(cache.props_for(case, opts));
    }
    // batched property evaluation over the extractable cases; the
    // helper's per-case scalar fallback keeps quarantine attribution
    // exact when one binding in a kernel group is bad
    let ok_items: Vec<(usize, (&KernelProps, &Env))> = sym
        .iter()
        .enumerate()
        .filter_map(|(i, r)| r.as_ref().ok().map(|p| (i, (p, &cases[i].env))))
        .collect();
    let flat_items: Vec<(&KernelProps, &Env)> =
        ok_items.iter().map(|(_, it)| *it).collect();
    let evaled = eval_props_batched(&flat_items, schema);
    let mut rows: Vec<Result<Vec<f64>, String>> = sym
        .iter()
        .map(|r| match r {
            Err(e) => Err(e.clone()),
            Ok(_) => Ok(Vec::new()),
        })
        .collect();
    for ((i, _), row) in ok_items.into_iter().zip(evaled) {
        rows[i] = row;
    }

    let work: Vec<(usize, &KernelCase)> = cases.iter().enumerate().collect();
    let mut measure_span = Span::child("harness.measure");
    if span::enabled() {
        measure_span.set_meta(format!("cases={}", work.len()));
    }
    let results = par_map(work, workers, |(i, case)| -> Result<Measurement, String> {
        let times = time_with_retry(gpu, &case.kernel, &case.env, protocol)?;
        let time_s = protocol.reduce(&times)?;
        let props = rows[i].as_ref().map_err(Clone::clone)?.clone();
        Ok(Measurement { label: case.label.clone(), props, time_s })
    });
    drop(measure_span);

    let mut pm = PropertyMatrix::default();
    let mut quarantined = Vec::new();
    for (case, r) in cases.iter().zip(results) {
        match r {
            Ok(m) => {
                let is_empty_kernel = m.label.starts_with("empty/");
                if !is_empty_kernel && m.time_s < protocol.min_time_factor * overhead {
                    continue; // below the reliable-timing floor (§4.2)
                }
                pm.push(m.label, m.props, m.time_s);
            }
            Err(reason) => {
                quarantined.push(Quarantine { label: case.label.clone(), reason });
            }
        }
    }
    if pm.n_cases() == 0 {
        return Err(format!(
            "no usable measurement cases: {} quarantined, the rest filtered by \
             the overhead floor",
            quarantined.len()
        ));
    }
    Ok(CampaignOutcome { matrix: pm, overhead, overhead_warning, quarantined })
}

/// Persist a campaign to JSON.
pub fn campaign_to_json(pm: &PropertyMatrix, device: &str, overhead: f64) -> Json {
    Json::obj(vec![
        ("device", Json::Str(device.into())),
        ("launch_overhead_s", Json::Num(overhead)),
        (
            "cases",
            Json::Arr(
                pm.cases
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("label", Json::Str(c.label.clone())),
                            ("time_s", Json::Num(c.time_s)),
                            (
                                "props",
                                Json::Arr(c.props.iter().map(|&p| Json::Num(p)).collect()),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Load a campaign from JSON produced by [`campaign_to_json`].
pub fn campaign_from_json(j: &Json) -> Result<(PropertyMatrix, String, f64), String> {
    let device = j.get("device").and_then(Json::as_str).ok_or("missing device")?.to_string();
    let overhead =
        j.get("launch_overhead_s").and_then(Json::as_f64).ok_or("missing overhead")?;
    let mut pm = PropertyMatrix::default();
    for case in j.get("cases").and_then(Json::as_arr).ok_or("missing cases")? {
        let label = case.get("label").and_then(Json::as_str).ok_or("missing label")?;
        let time = case.get("time_s").and_then(Json::as_f64).ok_or("missing time")?;
        let props: Vec<f64> = case
            .get("props")
            .and_then(Json::as_arr)
            .ok_or("missing props")?
            .iter()
            .map(|p| p.as_f64().ok_or_else(|| "bad prop".to_string()))
            .collect::<Result<_, _>>()?;
        pm.push(label.to_string(), props, time);
    }
    Ok((pm, device, overhead))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::measure;
    use crate::qpoly::env;

    #[test]
    fn protocol_reduce_drops_warmup() {
        let p = Protocol::default();
        let mut times = vec![10.0, 5.0, 1.5, 1.4]; // discarded
        times.extend(vec![1.2, 1.1, 1.3, 1.15]);
        assert_eq!(p.reduce(&times).unwrap(), 1.1);
        let mean = p.reduce_mean(&times).unwrap();
        assert!((mean - 1.1875).abs() < 1e-12);
    }

    #[test]
    fn protocol_reduce_rejects_empty_and_handles_short_input() {
        let p = Protocol::default();
        // degenerate: no runs at all -> error, not +inf/NaN
        assert!(p.reduce(&[]).is_err());
        assert!(p.reduce_mean(&[]).is_err());
        // fewer runs than the discard window: the last run is retained
        assert_eq!(p.reduce(&[3.0, 2.0]).unwrap(), 2.0);
        assert_eq!(p.reduce_mean(&[3.0, 2.0]).unwrap(), 2.0);
        // exactly one run
        assert_eq!(p.reduce(&[7.0]).unwrap(), 7.0);
    }

    #[test]
    fn cached_samples_carry_a_marker_not_a_zero() {
        let p = Protocol { runs: 8, discard: 2, ..Protocol::default() };
        // the naive encoding of a cache hit — a 0-second sample —
        // poisons the min-of-runs statistic:
        assert_eq!(p.reduce(&[3.0, 2.5, 2.0, 0.0, 2.1]).unwrap(), 0.0);
        // the distinct marker keeps hits out of the reduction entirely
        let samples = [
            Sample::Timed(3.0),
            Sample::Timed(2.5),
            Sample::Cached,
            Sample::Timed(2.0),
            Sample::Cached,
            Sample::Timed(2.1),
        ];
        let timed: Vec<f64> = samples.iter().filter_map(Sample::timed).collect();
        assert_eq!(
            p.reduce_samples(&samples).unwrap(),
            p.reduce(&timed).unwrap()
        );
        assert_eq!(p.reduce_samples(&samples).unwrap(), 2.0);
        // marker bookkeeping
        assert!(Sample::Cached.is_cached());
        assert_eq!(Sample::Timed(1.5).timed(), Some(1.5));
        assert_eq!(Sample::Cached.timed(), None);
    }

    #[test]
    fn all_cached_is_an_error_not_a_degenerate_min() {
        let p = Protocol::default();
        let e = p.reduce_samples(&[Sample::Cached, Sample::Cached]).unwrap_err();
        assert!(e.contains("cached"), "{e}");
        assert!(p.reduce_samples(&[]).is_err());
    }

    #[test]
    fn overhead_calibration_positive() {
        let gpu = SimGpu::named("r9_fury").unwrap();
        let o = calibrate_overhead(&gpu, &Protocol::default()).unwrap();
        // the Fury has ~45 µs launch overhead
        assert!(o > 20e-6 && o < 200e-6, "{o}");
    }

    #[test]
    fn small_campaign_runs_and_filters() {
        let gpu = SimGpu::named("titan_x").unwrap();
        let schema = Schema::full();
        // a small slice: copy kernels at several sizes
        let k = measure::global_access(measure::GlobalAccessConfig::Copy, 256);
        let mut cases = Vec::new();
        for t in 0..5 {
            let n = 1i64 << (14 + 2 * t);
            cases.push(KernelCase {
                kernel: k.clone(),
                env: env(&[("n", n)]),
                label: format!("sg_copy/n={n}/g=256"),
                group: (256, 1),
            });
        }
        let (pm, overhead) = run_campaign(
            &gpu,
            &cases,
            &schema,
            &Protocol::default(),
            ExtractOpts::default(),
            2,
        )
        .unwrap();
        assert!(overhead > 0.0);
        assert!(pm.n_cases() >= 3, "kept {}", pm.n_cases());
        // larger sizes must be kept; tiny ones may be filtered
        assert!(pm.cases.iter().any(|c| c.label.contains("n=4194304")));
    }

    #[test]
    fn measure_cases_keeps_every_case_in_order() {
        let gpu = SimGpu::named("titan_x").unwrap();
        let schema = Schema::full();
        let k = measure::global_access(measure::GlobalAccessConfig::Copy, 256);
        let mut cases = Vec::new();
        for t in 0..5 {
            // includes tiny sizes that run_campaign would filter out
            let n = 1i64 << (10 + 2 * t);
            cases.push(KernelCase {
                kernel: k.clone(),
                env: env(&[("n", n)]),
                label: format!("sg_copy/n={n}/g=256"),
                group: (256, 1),
            });
        }
        let ms = measure_cases(
            &gpu,
            &cases,
            &schema,
            &Protocol::default(),
            ExtractOpts::default(),
            2,
        )
        .unwrap();
        assert_eq!(ms.len(), cases.len());
        for (m, c) in ms.iter().zip(&cases) {
            assert_eq!(m.label, c.label);
            assert!(m.time_s > 0.0);
        }
    }

    #[test]
    fn campaign_json_roundtrip() {
        let mut pm = PropertyMatrix::default();
        pm.push("a".into(), vec![1.0, 0.0, 2.0], 1e-3);
        pm.push("b".into(), vec![0.0, 3.0, 4.0], 2e-3);
        let j = campaign_to_json(&pm, "k40c", 8e-6);
        let parsed = Json::parse(&j.pretty()).unwrap();
        let (pm2, dev, ovh) = campaign_from_json(&parsed).unwrap();
        assert_eq!(dev, "k40c");
        assert_eq!(ovh, 8e-6);
        assert_eq!(pm2.n_cases(), 2);
        assert_eq!(pm2.cases[0].props, vec![1.0, 0.0, 2.0]);
        assert_eq!(pm2.cases[1].time_s, 2e-3);
    }

    #[test]
    fn mad_filter_rejects_fast_outliers_min_would_keep() {
        // a spuriously-fast sample poisons min-of-runs...
        let times = [10.0, 5.0, 1.5, 1.4, 1.2, 1.1, 0.04, 1.15];
        let plain = Protocol::default();
        assert_eq!(plain.reduce(&times).unwrap(), 0.04);
        // ...and MAD rejection recovers the honest minimum
        let robust = Protocol { mad_k: 3.5, ..Protocol::default() };
        assert_eq!(robust.reduce(&times).unwrap(), 1.1);
        // mad_k = 0 stays byte-identical to the historical reduction
        let zero = Protocol { mad_k: 0.0, ..Protocol::default() };
        assert_eq!(zero.reduce(&times).unwrap(), plain.reduce(&times).unwrap());
        // degenerate inputs: short slices and zero-MAD streams pass through
        assert_eq!(mad_filter(&[1.0, 2.0], 3.0), vec![1.0, 2.0]);
        assert_eq!(mad_filter(&[5.0, 5.0, 5.0, 5.0], 3.0), vec![5.0; 4]);
    }

    #[test]
    fn mad_rejection_defeats_injected_outliers_end_to_end() {
        use crate::util::fault::FaultPlan;
        use std::sync::Arc;
        let k = measure::global_access(measure::GlobalAccessConfig::Copy, 256);
        let env = env(&[("n", 1 << 22)]);
        let clean_gpu = SimGpu::named("titan_x").unwrap();
        let faulted_gpu = clean_gpu
            .clone()
            .with_faults(Some(Arc::new(FaultPlan::new(1).site("measure.outlier", 1.0))));
        let p = Protocol { runs: 12, ..Protocol::default() };
        let clean = p.reduce(&clean_gpu.time(&k, &env, p.runs).unwrap()).unwrap();
        let corrupted = faulted_gpu.time(&k, &env, p.runs).unwrap();
        // the outlier may land in the discard window; draw until it
        // corrupts a retained sample so the assertion is meaningful
        let (mut corrupted, mut tries) = (corrupted, 0);
        while p.reduce(&corrupted).unwrap() > 0.5 * clean && tries < 32 {
            corrupted = faulted_gpu.time(&k, &env, p.runs).unwrap();
            tries += 1;
        }
        assert!(
            p.reduce(&corrupted).unwrap() <= 0.05 * clean,
            "outlier never landed in a retained sample"
        );
        let robust = Protocol { mad_k: 3.5, ..p };
        let recovered = robust.reduce(&corrupted).unwrap();
        assert!(
            (recovered - clean).abs() <= 0.15 * clean,
            "recovered {recovered} vs clean {clean}"
        );
    }

    #[test]
    fn retry_budget_survives_transient_measurement_failures() {
        use crate::util::fault::FaultPlan;
        use std::sync::Arc;
        // first two attempts fail, the third succeeds: within budget
        let plan = Arc::new(FaultPlan::new(2).site_max("measure.fail", 1.0, 2));
        let gpu = SimGpu::named("k40c").unwrap().with_faults(Some(plan.clone()));
        let p = Protocol { runs: 6, retries: 2, ..Protocol::default() };
        let o = calibrate_overhead(&gpu, &p).unwrap();
        assert!(o > 0.0);
        assert_eq!(plan.injected("measure.fail"), 2);
        // budget exhausted -> the error names the attempt count and site
        let plan2 = Arc::new(FaultPlan::new(2).site("measure.fail", 1.0));
        let gpu2 = SimGpu::named("k40c").unwrap().with_faults(Some(plan2));
        let e = calibrate_overhead(&gpu2, &p).unwrap_err();
        assert!(e.contains("3 attempt(s)") && e.contains("measure.fail"), "{e}");
    }

    fn copy_cases(n_cases: usize) -> Vec<KernelCase> {
        let k = measure::global_access(measure::GlobalAccessConfig::Copy, 256);
        (0..n_cases)
            .map(|t| {
                let n = 1i64 << (18 + t as u32);
                KernelCase {
                    kernel: k.clone(),
                    env: env(&[("n", n)]),
                    label: format!("sg_copy/n={n}/g=256"),
                    group: (256, 1),
                }
            })
            .collect()
    }

    #[test]
    fn robust_campaign_falls_back_when_calibration_fails() {
        use crate::util::fault::FaultPlan;
        use std::sync::Arc;
        // exactly one timing call fails: calibration, which runs first
        let plan = Arc::new(FaultPlan::new(5).site_max("measure.fail", 1.0, 1));
        let gpu = SimGpu::named("titan_x").unwrap().with_faults(Some(plan));
        let cases = copy_cases(5);
        let p = Protocol { runs: 6, retries: 0, ..Protocol::default() };
        let out = run_campaign_robust(
            &gpu, &cases, &Schema::full(), &p, ExtractOpts::default(), 1,
        )
        .unwrap();
        assert_eq!(out.overhead, 0.0);
        let w = out.overhead_warning.as_deref().unwrap();
        assert!(w.contains("zero-overhead default"), "{w}");
        // the floor is disabled, so every case survives; none quarantined
        assert_eq!(out.matrix.n_cases(), cases.len());
        assert!(out.quarantined.is_empty());
    }

    #[test]
    fn robust_campaign_quarantines_failing_cases_with_reasons() {
        use crate::util::fault::FaultPlan;
        use std::sync::Arc;
        // first three timing calls fail with no retries: calibration
        // (call 1) falls back, cases 0 and 1 (calls 2-3, sequential with
        // workers=1) are quarantined, the rest are measured
        let plan = Arc::new(FaultPlan::new(5).site_max("measure.fail", 1.0, 3));
        let gpu = SimGpu::named("titan_x").unwrap().with_faults(Some(plan));
        let cases = copy_cases(6);
        let p = Protocol { runs: 6, retries: 0, ..Protocol::default() };
        let out = run_campaign_robust(
            &gpu, &cases, &Schema::full(), &p, ExtractOpts::default(), 1,
        )
        .unwrap();
        assert!(out.overhead_warning.is_some());
        assert_eq!(out.quarantined.len(), 2);
        assert_eq!(out.quarantined[0].label, cases[0].label);
        assert_eq!(out.quarantined[1].label, cases[1].label);
        assert!(out.quarantined[0].reason.contains("measure.fail"));
        assert_eq!(out.matrix.n_cases() + out.quarantined.len(), cases.len());
        // every surviving case is absent from quarantine and vice versa
        for q in &out.quarantined {
            assert!(out.matrix.cases.iter().all(|c| c.label != q.label));
        }
    }

    #[test]
    fn robust_campaign_without_faults_matches_strict_campaign() {
        let gpu = SimGpu::named("titan_x").unwrap();
        let cases = copy_cases(5);
        let p = Protocol { runs: 6, ..Protocol::default() };
        let (pm, overhead) = run_campaign(
            &gpu, &cases, &Schema::full(), &p, ExtractOpts::default(), 2,
        )
        .unwrap();
        let out = run_campaign_robust(
            &gpu, &cases, &Schema::full(), &p, ExtractOpts::default(), 2,
        )
        .unwrap();
        assert_eq!(out.overhead, overhead);
        assert!(out.overhead_warning.is_none());
        assert!(out.quarantined.is_empty());
        assert_eq!(out.matrix.n_cases(), pm.n_cases());
        for (a, b) in out.matrix.cases.iter().zip(&pm.cases) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.time_s, b.time_s);
            assert_eq!(a.props, b.props);
        }
    }

    #[test]
    fn all_cases_quarantined_is_an_error() {
        use crate::util::fault::FaultPlan;
        use std::sync::Arc;
        let plan = Arc::new(FaultPlan::new(5).site("measure.fail", 1.0));
        let gpu = SimGpu::named("titan_x").unwrap().with_faults(Some(plan));
        let cases = copy_cases(3);
        let p = Protocol { runs: 6, retries: 0, ..Protocol::default() };
        let e = run_campaign_robust(
            &gpu, &cases, &Schema::full(), &p, ExtractOpts::default(), 1,
        )
        .unwrap_err();
        assert!(e.contains("3 quarantined"), "{e}");
    }

    #[test]
    fn props_cache_reuses_symbolic_extraction() {
        let k = measure::global_access(measure::GlobalAccessConfig::Copy, 256);
        let mut cache = PropsCache::default();
        for t in 0..4 {
            let case = KernelCase {
                kernel: k.clone(),
                env: env(&[("n", 1i64 << (16 + t))]),
                label: format!("c{t}"),
                group: (256, 1),
            };
            cache.props_for(&case, ExtractOpts::default()).unwrap();
        }
        assert_eq!(cache.len(), 1);
    }
}
