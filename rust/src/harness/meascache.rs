//! `meascache` — a persistent, append-only campaign measurement cache.
//!
//! Timing a campaign case is the expensive step of the fit plane (30
//! simulated runs through the transaction-level cost engine per case,
//! ~400+ cases per device), and under a fixed seed its result is
//! *pure*: the raw stream is a function of the device profile, the
//! kernel (structure and name — the noise hash folds the literal
//! name), the env, the run count and the seed. That makes it safe to
//! persist: a [`MeasCacheFile`] records every measured stream as one
//! JSON line, and a later `fit`/`crossval`/`transfer` invocation
//! replays its cases bit-identically with **zero simulations** (the
//! reduction runs on the recorded raw samples, so every downstream
//! byte — `PerfMatrix`, fold JSON, reports — is unchanged).
//!
//! ## File format (`uniperf-meascache-v1`)
//!
//! Line-delimited JSON. Line 1 is the header, pinning everything that
//! shapes a raw stream globally:
//!
//! ```json
//! {"format": "uniperf-meascache-v1", "runs": 30, "discard": 4,
//!  "min_time_factor": 2, "retries": 2, "mad_k": 0,
//!  "seed": "00000000000d15c0"}
//! ```
//!
//! Every later line is one recorded stream, keyed by the per-case
//! inputs:
//!
//! ```json
//! {"dev": "<16-hex profile fingerprint>",
//!  "kernel": "<16-hex structural hash + name fold>",
//!  "env": "<16-hex env fingerprint>", "times": [..30 raw samples..]}
//! ```
//!
//! The kernel key folds the kernel *name* on top of the
//! rename-invariant structural hash because the noise stream folds the
//! literal name: two structurally identical kernels with different
//! names draw different streams and must not share entries. Raw f64
//! samples round-trip exactly through the JSON layer (shortest
//! round-trip formatting), which is what makes warm replay
//! bit-identical rather than merely close.
//!
//! ## Trust model: validate, never assume
//!
//! Same contract as the extraction cache
//! ([`crate::service::diskcache`]): [`open`] refuses a file whose
//! format tag, timing protocol or seed disagree with this run — the
//! caller warns and starts cold; a refused file is never read from or
//! appended to, and is left byte-identical on disk. A torn tail (the
//! crash-truncated last line an append-only log can always have) is
//! tolerated: loading stops at the first unparseable line with one
//! warning, keeping every entry before it. Appends are single
//! `write(2)` calls of one complete line.
//!
//! [`open`]: MeasCacheFile::open

use super::Protocol;
use crate::gpusim::{DeviceProfile, TimingCache};
use crate::lpir::Kernel;
use crate::obs::log::Level;
use crate::obs::metrics;
use crate::olog;
use crate::util::fnv::Fnv64;
use crate::util::intern::Env;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

/// The cache-file format this build writes and reads.
pub const FORMAT: &str = "uniperf-meascache-v1";

/// Poison-tolerant lock (a torn in-memory map beats cascading a panic
/// through a whole campaign).
fn locked<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Entry key: (device-profile fingerprint, structural hash ⊕ kernel
/// name, env fingerprint). The protocol and seed are file-global
/// (header-pinned), so they are not part of the per-entry key.
pub type MeasKey = (u64, u64, u64);

/// Integer form of [`crate::service::store::profile_fingerprint`]
/// (same bytes hashed; the hex string there is this value formatted).
fn device_fp(p: &DeviceProfile) -> u64 {
    let mut h = Fnv64::new();
    h.write_str(&p.to_json().compact());
    h.finish()
}

/// Kernel key: the rename-invariant structural hash plus the literal
/// kernel name (the noise stream folds the name — see module docs).
fn kernel_fp(kernel: &Kernel) -> u64 {
    let mut h = Fnv64::new();
    h.write_u64(crate::service::hash::structural_hash(kernel));
    h.write_str(&kernel.name);
    h.finish()
}

/// The key for one measured case.
pub fn meas_key(profile: &DeviceProfile, kernel: &Kernel, env: &Env) -> MeasKey {
    (
        device_fp(profile),
        kernel_fp(kernel),
        crate::service::cache::env_fingerprint(env),
    )
}

/// A loaded + appendable measurement-cache file. See the module docs
/// for the format and trust model. All methods are `&self`; the engine
/// holds one behind an `Arc` and attaches it to every [`crate::gpusim::SimGpu`]
/// it constructs (as the [`TimingCache`] implementation the harness
/// retry loop consults).
#[derive(Debug)]
pub struct MeasCacheFile {
    protocol: Protocol,
    seed: u64,
    /// preloaded + appended streams, keyed [`MeasKey`]
    entries: Mutex<BTreeMap<MeasKey, Arc<Vec<f64>>>>,
    /// append handle; one complete line per `write`
    file: Mutex<std::fs::File>,
    /// entries preloaded from disk at open (excludes later appends)
    loaded: usize,
    /// replayed lookups (this file; the process-global
    /// `meascache_hits_total` counter aggregates across files)
    hits: AtomicU64,
    /// eligible lookups that fell through to simulation
    misses: AtomicU64,
}

impl MeasCacheFile {
    /// Open (or create) the cache file at `path` for this run's
    /// `protocol` and `seed`.
    ///
    /// A missing or empty file is created with a fresh header. An
    /// existing file must carry a matching header — format tag, every
    /// timing-protocol field and the noise seed — or this returns
    /// `Err` and the file is left byte-identical on disk: the caller
    /// logs the reason and measures cold rather than replaying streams
    /// drawn under a different discipline. Unreadable trailing lines
    /// (a torn append) stop loading with one warning; everything
    /// before them is kept.
    pub fn open(path: &Path, protocol: &Protocol, seed: u64) -> Result<MeasCacheFile, String> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => String::new(),
            Err(e) => return Err(format!("meas cache {}: {e}", path.display())),
        };
        let header = Json::obj(vec![
            ("format", Json::Str(FORMAT.into())),
            ("runs", Json::Num(protocol.runs as f64)),
            ("discard", Json::Num(protocol.discard as f64)),
            ("min_time_factor", Json::Num(protocol.min_time_factor)),
            ("retries", Json::Num(protocol.retries as f64)),
            ("mad_k", Json::Num(protocol.mad_k)),
            ("seed", Json::Str(format!("{seed:016x}"))),
        ]);
        let mut lines = text.lines();
        let fresh = match lines.next() {
            None => true,
            Some(first) => {
                let j = Json::parse(first).map_err(|e| {
                    format!("meas cache {}: unreadable header: {e}", path.display())
                })?;
                crate::service::store::check_format(&j, FORMAT, "meas cache")?;
                let num = |field: &str| -> Result<f64, String> {
                    j.get_f64(field).ok_or_else(|| {
                        format!("meas cache {}: header missing '{field}'", path.display())
                    })
                };
                let same_protocol = num("runs")? == protocol.runs as f64
                    && num("discard")? == protocol.discard as f64
                    && num("min_time_factor")? == protocol.min_time_factor
                    && num("retries")? == protocol.retries as f64
                    && num("mad_k")? == protocol.mad_k;
                if !same_protocol {
                    return Err(format!(
                        "meas cache {}: recorded timing protocol does not match this \
                         run's ({protocol:?}); streams measured under another protocol \
                         are not replayable",
                        path.display()
                    ));
                }
                let file_seed = j
                    .get_str("seed")
                    .ok_or_else(|| {
                        format!("meas cache {}: header missing 'seed'", path.display())
                    })
                    .and_then(|s| {
                        u64::from_str_radix(s, 16).map_err(|e| {
                            format!("meas cache {}: header 'seed': {e}", path.display())
                        })
                    })?;
                if file_seed != seed {
                    return Err(format!(
                        "meas cache {}: recorded seed {file_seed:#x} does not match \
                         this run's seed ({seed:#x})",
                        path.display()
                    ));
                }
                false
            }
        };

        // entries: stop at the first torn/invalid line (append-only
        // logs can always have a crash-truncated tail), keep the rest
        let mut entries: BTreeMap<MeasKey, Arc<Vec<f64>>> = BTreeMap::new();
        for (i, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            match parse_entry(line) {
                Ok((key, times)) => {
                    entries.insert(key, Arc::new(times));
                }
                Err(e) => {
                    olog!(
                        Level::Warn,
                        "uniperf: meas cache {}: line {}: {e}; keeping the {} entries \
                         before it and ignoring the rest",
                        path.display(),
                        i + 2,
                        entries.len()
                    );
                    break;
                }
            }
        }
        let loaded = entries.len();

        let mut file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .map_err(|e| format!("meas cache {}: open for append: {e}", path.display()))?;
        if fresh {
            file.write_all(format!("{}\n", header.compact()).as_bytes())
                .map_err(|e| format!("meas cache {}: write header: {e}", path.display()))?;
        }
        Ok(MeasCacheFile {
            protocol: *protocol,
            seed,
            entries: Mutex::new(entries),
            file: Mutex::new(file),
            loaded,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        })
    }

    /// Record one stream: one complete JSON line, appended under the
    /// file lock in a single write. Persistence is best-effort — a
    /// full disk degrades the *next* run's warm start, never this
    /// measurement — and the in-memory copy is always kept, so
    /// repeated appends of the same key stay idempotent. Non-finite
    /// samples are never recorded (they would not survive the JSON
    /// round trip).
    pub fn append(&self, key: MeasKey, times: &[f64]) {
        if times.iter().any(|t| !t.is_finite()) {
            return;
        }
        let line = Json::obj(vec![
            ("dev", Json::Str(format!("{:016x}", key.0))),
            ("kernel", Json::Str(format!("{:016x}", key.1))),
            ("env", Json::Str(format!("{:016x}", key.2))),
            ("times", Json::Arr(times.iter().copied().map(Json::Num).collect())),
        ]);
        {
            let mut entries = locked(&self.entries);
            if entries.contains_key(&key) {
                return;
            }
            entries.insert(key, Arc::new(times.to_vec()));
        }
        let mut f = locked(&self.file);
        let _ = f.write_all(format!("{}\n", line.compact()).as_bytes());
    }

    /// Streams replayed from this file so far.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Eligible lookups that fell through to live simulation so far.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Entries currently held (preloaded + appended).
    pub fn len(&self) -> usize {
        locked(&self.entries).len()
    }

    pub fn is_empty(&self) -> bool {
        locked(&self.entries).is_empty()
    }

    /// Entries preloaded from disk when the file was opened — the warm
    /// start a previous campaign handed this one.
    pub fn loaded(&self) -> usize {
        self.loaded
    }
}

impl TimingCache for MeasCacheFile {
    fn lookup(
        &self,
        profile: &DeviceProfile,
        kernel: &Kernel,
        env: &Env,
        runs: usize,
        seed: u64,
    ) -> Option<Vec<f64>> {
        // a stream drawn under a different run count or seed is a
        // different stream — not a miss, simply not this file's domain
        if runs != self.protocol.runs || seed != self.seed {
            return None;
        }
        let key = meas_key(profile, kernel, env);
        let hit = locked(&self.entries).get(&key).map(|t| t.as_ref().clone());
        if hit.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            metrics::campaign().counter("meascache_hits_total").inc();
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
            metrics::campaign().counter("meascache_misses_total").inc();
        }
        hit
    }

    fn store(
        &self,
        profile: &DeviceProfile,
        kernel: &Kernel,
        env: &Env,
        runs: usize,
        seed: u64,
        times: &[f64],
    ) {
        if runs != self.protocol.runs || seed != self.seed {
            return;
        }
        self.append(meas_key(profile, kernel, env), times);
    }
}

/// Parse one entry line into its key and raw samples.
fn parse_entry(line: &str) -> Result<(MeasKey, Vec<f64>), String> {
    let j = Json::parse(line).map_err(|e| format!("unreadable entry: {e}"))?;
    let hex = |field: &str| -> Result<u64, String> {
        let s = j
            .get_str(field)
            .ok_or_else(|| format!("entry missing '{field}'"))?;
        u64::from_str_radix(s, 16).map_err(|e| format!("entry '{field}': {e}"))
    };
    let key = (hex("dev")?, hex("kernel")?, hex("env")?);
    let times = match j.get("times") {
        Some(Json::Arr(xs)) => {
            let mut v = Vec::with_capacity(xs.len());
            for x in xs {
                match x {
                    Json::Num(t) => v.push(*t),
                    _ => return Err("entry 'times': non-numeric sample".into()),
                }
            }
            v
        }
        _ => return Err("entry missing 'times'".into()),
    };
    Ok((key, times))
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::lpir::builder::{gid_lin_1d, KernelBuilder};
    use crate::lpir::{Access, DType, Expr, Layout};
    use crate::qpoly::{env, LinExpr};

    /// A unique temp path per test (no tempdir dependency; collisions
    /// avoided via the test name).
    fn tmp(name: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir()
            .join(format!("uniperf_meascache_{name}_{}", std::process::id()));
        let _ = std::fs::remove_file(&p);
        p
    }

    fn sample_kernel(name: &str) -> Kernel {
        KernelBuilder::new(name, &["n"])
            .group_dims_1d(LinExpr::var("n"), 128)
            .global_array("a", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, false)
            .global_array("b", DType::F32, vec![LinExpr::var("n")], Layout::RowMajor, true)
            .insn(
                Access::new("b", vec![gid_lin_1d(128)]),
                Expr::load("a", vec![gid_lin_1d(128)]),
                &["g0", "l0"],
                &[],
            )
            .build()
            .unwrap()
    }

    #[test]
    fn round_trips_raw_streams_bit_for_bit() {
        let path = tmp("round_trip");
        let protocol = Protocol::default();
        let profile = crate::gpusim::device("k40c").unwrap();
        let kernel = sample_kernel("copy_rt");
        let e = env(&[("n", 1 << 20)]);
        // awkward values: non-terminating binary fractions, denormal
        // territory, an exact integer
        let times = vec![1.0 / 3.0, 6.02e-23, 1.25e-3, 4.0];
        {
            let f = MeasCacheFile::open(&path, &protocol, 0xD15C_0).unwrap();
            assert_eq!(f.loaded(), 0, "fresh file preloads nothing");
            assert!(
                f.lookup(&profile, &kernel, &e, protocol.runs, 0xD15C_0).is_none(),
                "cold lookup misses"
            );
            f.store(&profile, &kernel, &e, protocol.runs, 0xD15C_0, &times);
            f.store(&profile, &kernel, &e, protocol.runs, 0xD15C_0, &times); // idempotent
            assert_eq!(f.len(), 1);
            assert_eq!((f.hits(), f.misses()), (0, 1));
        }
        let f = MeasCacheFile::open(&path, &protocol, 0xD15C_0).unwrap();
        assert_eq!(f.loaded(), 1, "restart preloads the stream");
        let got = f.lookup(&profile, &kernel, &e, protocol.runs, 0xD15C_0).unwrap();
        let want: Vec<u64> = times.iter().map(|t| t.to_bits()).collect();
        let got_bits: Vec<u64> = got.iter().map(|t| t.to_bits()).collect();
        assert_eq!(got_bits, want, "samples survive the JSON round trip bit-for-bit");
        assert_eq!((f.hits(), f.misses()), (1, 0));
        // out-of-domain lookups answer None without counting
        assert!(f.lookup(&profile, &kernel, &e, protocol.runs + 1, 0xD15C_0).is_none());
        assert!(f.lookup(&profile, &kernel, &e, protocol.runs, 1).is_none());
        assert_eq!((f.hits(), f.misses()), (1, 0), "mismatched runs/seed count nothing");
        // the kernel *name* is part of the key (structural hash alone
        // is rename-invariant, but the noise stream is not)
        let renamed = sample_kernel("copy_rt2");
        assert!(f.lookup(&profile, &renamed, &e, protocol.runs, 0xD15C_0).is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn refuses_mismatched_headers_and_leaves_the_file_untouched() {
        let path = tmp("mismatch");
        let protocol = Protocol::default();
        drop(MeasCacheFile::open(&path, &protocol, 7).unwrap());
        let before = std::fs::read(&path).unwrap();
        // protocol mismatch
        let other = Protocol { runs: 31, ..protocol };
        let e = MeasCacheFile::open(&path, &other, 7).unwrap_err();
        assert!(e.contains("protocol"), "{e}");
        // seed mismatch
        let e = MeasCacheFile::open(&path, &protocol, 8).unwrap_err();
        assert!(e.contains("seed"), "{e}");
        assert_eq!(
            std::fs::read(&path).unwrap(),
            before,
            "a refused file is left byte-identical"
        );
        // format mismatch
        std::fs::write(&path, "{\"format\": \"uniperf-meascache-v999\"}\n").unwrap();
        let e = MeasCacheFile::open(&path, &protocol, 7).unwrap_err();
        assert!(e.contains("format"), "{e}");
        // tagless garbage
        std::fs::write(&path, "{\"hello\": 1}\n").unwrap();
        let e = MeasCacheFile::open(&path, &protocol, 7).unwrap_err();
        assert!(e.contains("missing 'format'"), "{e}");
        // unparseable header
        std::fs::write(&path, "not json at all\n").unwrap();
        let e = MeasCacheFile::open(&path, &protocol, 7).unwrap_err();
        assert!(e.contains("unreadable header"), "{e}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tolerates_a_torn_tail() {
        let path = tmp("torn");
        let protocol = Protocol::default();
        {
            let f = MeasCacheFile::open(&path, &protocol, 7).unwrap();
            f.append((1, 1, 1), &[0.5, 0.25]);
            f.append((2, 2, 2), &[0.125, 0.0625]);
        }
        // simulate a crash mid-append: truncate the last line
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() - 20]).unwrap();
        let f = MeasCacheFile::open(&path, &protocol, 7).unwrap();
        assert_eq!(f.loaded(), 1, "entries before the torn line survive");
        // the file is still appendable after recovery
        f.append((3, 3, 3), &[1.0]);
        assert_eq!(f.len(), 2);
        let _ = std::fs::remove_file(&path);
    }
}
