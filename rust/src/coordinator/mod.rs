//! The pipeline coordinator: orchestrates measurement campaigns, fits and
//! test-kernel evaluation across the simulated devices — the paper's
//! Figure 1 wired end to end.
//!
//! Devices are processed in parallel on a thread pool
//! ([`crate::util::executor`]); within one device, timing runs fan out
//! over cases. Results (campaigns, models, tables) can be persisted to a
//! JSON results directory.

use crate::gpusim::{registry, DeviceRegistry, SimGpu};
use crate::harness::{self, Protocol};
use crate::kernels;
use crate::perfmodel::{self, Model, NativeSolver, Solver};
use crate::report::{render_table2, Table1, Table1Entry};
use crate::stats::{ExtractOpts, Schema};
use crate::util::executor::{default_workers, par_map};
use std::path::PathBuf;

/// Which fit backend to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FitBackend {
    /// in-process Cholesky/QR ([`NativeSolver`])
    Native,
    /// AOT-compiled JAX/Pallas artifact through PJRT
    Xla,
    /// try the artifact, fall back to native if unavailable
    Auto,
}

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// devices to run, by name; resolved through [`Config::registry`]
    pub devices: Vec<String>,
    /// the device catalogue names resolve against. Defaults to the
    /// built-in registry; the CLI's `--devices <profiles.json>` flag
    /// extends it with user profiles at runtime.
    pub registry: DeviceRegistry,
    pub protocol: Protocol,
    pub backend: FitBackend,
    pub extract: ExtractOpts,
    /// results directory (None = don't persist)
    pub out_dir: Option<PathBuf>,
    pub workers: usize,
    /// evaluate the full 9-class evaluation-kernel zoo (§5 test kernels
    /// plus the zoo expansion) instead of the four §5 test kernels
    pub eval_zoo: bool,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            devices: vec![
                "titan_x".into(),
                "c2070".into(),
                "k40c".into(),
                "r9_fury".into(),
            ],
            registry: registry::builtins().clone(),
            protocol: Protocol::default(),
            backend: FitBackend::Auto,
            extract: ExtractOpts::default(),
            out_dir: None,
            workers: default_workers(),
            eval_zoo: false,
        }
    }
}

/// Per-device pipeline output.
#[derive(Clone, Debug)]
pub struct DeviceResult {
    pub device: String,
    pub model: Model,
    pub launch_overhead_s: f64,
    pub n_measurement_cases: usize,
    /// (kernel, case letter, predicted, actual) for the §5 test kernels
    pub tests: Vec<(String, String, f64, f64)>,
}

/// Full pipeline output.
#[derive(Debug)]
pub struct PipelineResult {
    pub per_device: Vec<DeviceResult>,
    pub table1: Table1,
}

/// Instantiate the fit backend (shared with [`crate::crossval`], which
/// holds one solver per device across its fold fan-out — hence the
/// thread-safety bounds).
pub fn make_solver(backend: FitBackend) -> Result<Box<dyn Solver + Send + Sync>, String> {
    match backend {
        FitBackend::Native => Ok(Box::new(NativeSolver::new())),
        FitBackend::Xla => Ok(Box::new(crate::runtime::XlaSolver::from_artifacts()?)),
        FitBackend::Auto => match crate::runtime::XlaSolver::from_artifacts() {
            Ok(s) => Ok(Box::new(s)),
            Err(_) => Ok(Box::new(NativeSolver::new())),
        },
    }
}

/// The campaign + fit prefix shared by [`run_device`] and
/// [`fit_models`]: simulate the device, run the §4.1/§4.2 measurement
/// campaign, and fit the §4.3 weights. Returns the simulated device,
/// the (filtered) property matrix, the fitted model and the calibrated
/// launch overhead.
fn campaign_and_fit(
    device: &str,
    schema: &Schema,
    cfg: &Config,
) -> Result<(SimGpu, perfmodel::PropertyMatrix, Model, f64), String> {
    let profile = cfg
        .registry
        .get(device)
        .cloned()
        .ok_or_else(|| format!("unknown device '{device}'"))?;
    let gpu = SimGpu::new(profile);

    // 1. measurement campaign (§4.1 + §4.2), capability-derived from
    //    the profile
    let cases = kernels::measurement_suite(&gpu.profile);
    let (pm, overhead) =
        harness::run_campaign(&gpu, &cases, schema, &cfg.protocol, cfg.extract, cfg.workers)?;

    // 2. fit (§4.3)
    let solver = make_solver(cfg.backend)?;
    let model = perfmodel::fit(device, &pm, schema, solver.as_ref())?;
    Ok((gpu, pm, model, overhead))
}

/// Run the full per-device pipeline: measurement campaign → fit → test
/// kernels → Table-1 entries.
pub fn run_device(
    device: &str,
    schema: &Schema,
    cfg: &Config,
) -> Result<DeviceResult, String> {
    let (gpu, pm, model, overhead) = campaign_and_fit(device, schema, cfg)?;

    // 3. test kernels (§5, or the full zoo behind `eval_zoo`): predict
    //    + measure, through the same parallel measurement path the
    //    cross-validation subsystem uses
    let suite = if cfg.eval_zoo {
        kernels::eval_suite(&gpu.profile)
    } else {
        kernels::test_suite(&gpu.profile)
    };
    let measurements =
        harness::measure_cases(&gpu, &suite, schema, &cfg.protocol, cfg.extract, cfg.workers)?;
    let mut tests = Vec::new();
    for (case, m) in suite.iter().zip(&measurements) {
        // label format: "<kernel>/<letter>/..."
        let mut parts = case.label.split('/');
        let kname = parts.next().unwrap_or("?").to_string();
        let letter = parts.next().unwrap_or("?").to_string();
        tests.push((kname, letter, model.predict(&m.props), m.time_s));
    }

    // 4. optional persistence
    if let Some(dir) = &cfg.out_dir {
        std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        let cj = harness::campaign_to_json(&pm, device, overhead);
        std::fs::write(dir.join(format!("campaign_{device}.json")), cj.pretty())
            .map_err(|e| e.to_string())?;
        std::fs::write(
            dir.join(format!("model_{device}.json")),
            model.to_json(schema).pretty(),
        )
        .map_err(|e| e.to_string())?;
    }

    Ok(DeviceResult {
        device: device.to_string(),
        model,
        launch_overhead_s: overhead,
        n_measurement_cases: pm.n_cases(),
        tests,
    })
}

/// Fit every configured device and assemble a persistable model store
/// (the `fit --save` flow of [`crate::service`]): one measurement
/// campaign + fit per device — and nothing else; the test-kernel
/// evaluation pass of [`run_device`] contributes nothing to an
/// artifact and is skipped — fanned out on the executor, each weight
/// table fingerprinted against the profile and capability-derived
/// suite that produced it. The returned store is what `predict
/// --models` and `serve` answer from, so saving it is the boundary
/// between the batch pipeline and the serving system.
pub fn fit_models(cfg: &Config) -> Result<crate::service::ModelStore, String> {
    use crate::service::{ModelStore, StoredModel};
    let schema = Schema::full();
    let device_workers = cfg.workers.min(cfg.devices.len()).max(1);
    let results = par_map(cfg.devices.clone(), device_workers, |dev| {
        campaign_and_fit(&dev, &schema, cfg).map(|(gpu, pm, model, overhead)| {
            (gpu.profile, pm.n_cases(), model, overhead)
        })
    });
    let mut store = ModelStore::new(&schema, cfg.extract);
    for r in results {
        let (profile, n_cases, model, overhead) = r?;
        store.insert(StoredModel::new(model, overhead, n_cases, &profile));
    }
    Ok(store)
}

/// Run the pipeline across all configured devices (in parallel) and
/// assemble Table 1.
pub fn run_pipeline(cfg: &Config) -> Result<PipelineResult, String> {
    let schema = Schema::full();
    let device_workers = cfg.workers.min(cfg.devices.len()).max(1);
    let results = par_map(cfg.devices.clone(), device_workers, |dev| {
        run_device(&dev, &schema, cfg)
    });
    let mut per_device = Vec::new();
    for r in results {
        per_device.push(r?);
    }
    let mut table1 = Table1::default();
    for dr in &per_device {
        for (kernel, case, pred, act) in &dr.tests {
            table1.push(Table1Entry {
                device: dr.device.clone(),
                kernel: kernel.clone(),
                case: case.clone(),
                predicted_s: *pred,
                actual_s: *act,
            });
        }
    }
    if let Some(dir) = &cfg.out_dir {
        std::fs::write(dir.join("table1.txt"), table1.render())
            .map_err(|e| e.to_string())?;
        for dr in &per_device {
            std::fs::write(
                dir.join(format!("table2_{}.txt", dr.device)),
                render_table2(&dr.model, &schema),
            )
            .map_err(|e| e.to_string())?;
        }
    }
    Ok(PipelineResult { per_device, table1 })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A reduced-scope end-to-end smoke test: one device, native solver.
    /// (The full 4-device pipeline runs in `rust/tests/` and the
    /// `paper_tables` example.)
    #[test]
    fn single_device_pipeline_produces_model_and_tests() {
        let cfg = Config {
            devices: vec!["k40c".into()],
            backend: FitBackend::Native,
            ..Config::default()
        };
        let schema = Schema::full();
        let dr = run_device("k40c", &schema, &cfg).unwrap();
        assert_eq!(dr.tests.len(), 16);
        assert!(dr.n_measurement_cases > 300, "{}", dr.n_measurement_cases);
        assert!(dr.launch_overhead_s > 0.0);
        // the fitted model should predict its own training set decently
        assert!(
            dr.model.train_rel_err_geomean < 0.5,
            "train geomean {}",
            dr.model.train_rel_err_geomean
        );
        // test-kernel predictions should be positive and finite
        for (k, c, pred, act) in &dr.tests {
            assert!(pred.is_finite() && *act > 0.0, "{k}/{c}: pred={pred} act={act}");
        }
    }
}
