//! The pipeline coordinator: orchestrates measurement campaigns, fits
//! and test-kernel evaluation across the simulated devices — the
//! paper's Figure 1 wired end to end.
//!
//! Since the engine refactor this module is a thin layer over
//! [`crate::engine::Engine`], which owns the shared
//! measurement→extraction→fit→predict core (registry, props cache,
//! suite construction, solver factory). The coordinator contributes
//! the multi-device fan-out ([`run_pipeline`] — devices in parallel on
//! [`crate::util::executor`]) and Table-1/Table-2 assembly +
//! persistence. `Config`, `FitBackend`, `make_solver` and
//! `DeviceResult` now live in `engine` and are re-exported here so
//! existing call sites keep working.

pub use crate::engine::{make_solver, Config, DeviceResult, FitBackend};

use crate::engine::Engine;
use crate::report::{render_table2, Table1, Table1Entry};
use crate::stats::Schema;
use crate::util::executor::par_map;

/// Full pipeline output.
#[derive(Debug)]
pub struct PipelineResult {
    pub per_device: Vec<DeviceResult>,
    pub table1: Table1,
}

/// Guard the historical `schema` parameter: the engine pins the full
/// §2 schema (the only layout artifacts and suites are fingerprinted
/// against), so a caller-supplied schema must be column-identical.
fn check_schema(schema: &Schema, engine: &Engine) -> Result<(), String> {
    if schema.fingerprint() != engine.schema().fingerprint() {
        return Err(
            "the engine-backed pipeline fits against the full property schema; \
             a different column layout would silently misalign weights"
                .into(),
        );
    }
    Ok(())
}

/// Run the full per-device pipeline: measurement campaign → fit → test
/// kernels → Table-1 entries. Delegates to [`Engine::run_device`] on a
/// fresh engine over `cfg`.
pub fn run_device(
    device: &str,
    schema: &Schema,
    cfg: &Config,
) -> Result<DeviceResult, String> {
    let engine = Engine::new(cfg.clone());
    check_schema(schema, &engine)?;
    engine.run_device(device)
}

/// Fit every configured device and assemble a persistable model store
/// (the `fit --save` flow of [`crate::service`]). Delegates to
/// [`Engine::fit_store`].
pub fn fit_models(cfg: &Config) -> Result<crate::service::ModelStore, String> {
    Engine::new(cfg.clone()).fit_store()
}

/// Run the pipeline across all configured devices (in parallel on one
/// shared engine) and assemble Table 1.
pub fn run_pipeline(cfg: &Config) -> Result<PipelineResult, String> {
    let engine = Engine::new(cfg.clone());
    let schema = Schema::full();
    let device_workers = cfg.workers.min(cfg.devices.len()).max(1);
    let results = par_map(cfg.devices.clone(), device_workers, |dev| {
        engine.run_device(&dev)
    });
    let mut per_device = Vec::new();
    for r in results {
        per_device.push(r?);
    }
    let mut table1 = Table1::default();
    for dr in &per_device {
        for (kernel, case, pred, act) in &dr.tests {
            table1.push(Table1Entry {
                device: dr.device.clone(),
                kernel: kernel.clone(),
                case: case.clone(),
                predicted_s: *pred,
                actual_s: *act,
            });
        }
    }
    if let Some(dir) = &cfg.out_dir {
        std::fs::write(dir.join("table1.txt"), table1.render())
            .map_err(|e| e.to_string())?;
        for dr in &per_device {
            std::fs::write(
                dir.join(format!("table2_{}.txt", dr.device)),
                render_table2(&dr.model, &schema),
            )
            .map_err(|e| e.to_string())?;
        }
    }
    Ok(PipelineResult { per_device, table1 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::Protocol;

    /// A reduced-scope end-to-end smoke test: one device, native solver.
    /// (The full 4-device pipeline runs in `rust/tests/` and the
    /// `paper_tables` example.)
    #[test]
    fn single_device_pipeline_produces_model_and_tests() {
        let cfg = Config {
            devices: vec!["k40c".into()],
            backend: FitBackend::Native,
            ..Config::default()
        };
        let schema = Schema::full();
        let dr = run_device("k40c", &schema, &cfg).unwrap();
        assert_eq!(dr.tests.len(), 16);
        assert!(dr.n_measurement_cases > 300, "{}", dr.n_measurement_cases);
        assert!(dr.launch_overhead_s > 0.0);
        // the fitted model should predict its own training set decently
        assert!(
            dr.model.train_rel_err_geomean < 0.5,
            "train geomean {}",
            dr.model.train_rel_err_geomean
        );
        // test-kernel predictions should be positive and finite
        for (k, c, pred, act) in &dr.tests {
            assert!(pred.is_finite() && *act > 0.0, "{k}/{c}: pred={pred} act={act}");
        }
    }

    /// The engine wrappers guard the historical `schema` parameter by
    /// fingerprint; column-identical layouts (every constructor the
    /// crate exposes) pass.
    #[test]
    fn schema_fingerprint_guard_accepts_identical_layouts() {
        let cfg = Config {
            devices: vec!["k40c".into()],
            backend: FitBackend::Native,
            protocol: Protocol { runs: 6, ..Protocol::default() },
            ..Config::default()
        };
        // Schema::without_utilization shares the full column layout by
        // design, so it passes the fingerprint guard
        let dr = run_device("k40c", &Schema::without_utilization(), &cfg);
        assert!(dr.is_ok(), "{dr:?}");
    }
}
